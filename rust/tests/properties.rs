//! Cross-module property tests (own mini-proptest framework,
//! swap::testutil). These pin the coordinator's invariants listed in
//! DESIGN.md §Key invariants. No artifacts required.

use swap::coordinator::allreduce;
use swap::data::{sampler, EpochSampler};
use swap::optim::Schedule;
use swap::tensor::{self, Tensor};
use swap::testutil::{assert_close, property, Gen};
use swap::util::{Json, Rng};

fn rand_set(g: &mut Gen, ntensors: usize) -> Vec<Tensor> {
    (0..ntensors)
        .map(|_| {
            let n = g.usize_in(1..40);
            Tensor::new(vec![n], (0..n).map(|_| g.normal()).collect()).unwrap()
        })
        .collect()
}

#[test]
fn prop_ring_allreduce_is_mean() {
    property(40, |g| {
        let w = g.usize_in(1..10);
        let n = g.usize_in(1..80);
        let sets: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| g.normal()).collect())
            .collect();
        let mut ring = sets.clone();
        allreduce::ring_mean_inplace(&mut ring).unwrap();
        let mut naive = vec![0.0f32; n];
        let views: Vec<&[f32]> = sets.iter().map(|s| s.as_slice()).collect();
        tensor::flat::mean_into(1, &mut naive, &views);
        for (x, y) in ring[0].iter().zip(&naive) {
            assert!((x - y).abs() < 1e-5 * (1.0 + y.abs()));
        }
    });
}

#[test]
fn prop_ring_reference_matches_tensor_naive_mean() {
    // the retained legacy oracle still equals the naive per-tensor mean
    property(30, |g| {
        let w = g.usize_in(1..8);
        let shapes: Vec<usize> = (0..g.usize_in(1..4)).map(|_| g.usize_in(1..30)).collect();
        let sets: Vec<Vec<Tensor>> = (0..w)
            .map(|_| {
                shapes
                    .iter()
                    .map(|&n| {
                        Tensor::new(vec![n], (0..n).map(|_| g.normal()).collect()).unwrap()
                    })
                    .collect()
            })
            .collect();
        let ring = allreduce::ring_mean_reference(&sets).unwrap();
        let naive = allreduce::naive_mean(&sets).unwrap();
        for (a, b) in ring.iter().zip(&naive) {
            for (x, y) in a.data().iter().zip(b.data()) {
                assert!((x - y).abs() < 1e-5 * (1.0 + y.abs()));
            }
        }
    });
}

#[test]
fn prop_averaging_contracts_toward_any_center() {
    // ||mean(thetas) - c|| <= max_w ||theta_w - c|| for every c:
    // phase 3 cannot move farther from the basin center than the worst
    // worker (convexity of the mean).
    property(60, |g| {
        let w = g.usize_in(1..9);
        let n = g.usize_in(1..50);
        let sets: Vec<Vec<Tensor>> = (0..w)
            .map(|_| vec![Tensor::new(vec![n], (0..n).map(|_| g.normal()).collect()).unwrap()])
            .collect();
        let c = vec![Tensor::new(vec![n], (0..n).map(|_| g.normal()).collect()).unwrap()];
        let avg = tensor::average_sets(&sets).unwrap();
        let d_avg = tensor::sets_distance(&avg, &c).unwrap();
        let d_max = sets
            .iter()
            .map(|s| tensor::sets_distance(s, &c).unwrap())
            .fold(0.0, f64::max);
        assert!(d_avg <= d_max + 1e-6, "{d_avg} > {d_max}");
    });
}

#[test]
fn prop_average_linearity() {
    // mean(a + t*d) == mean(a) + t*mean(d)
    property(40, |g| {
        let w = g.usize_in(1..6);
        let n = g.usize_in(1..30);
        let t = g.f32_in(-2.0..2.0);
        let a: Vec<Vec<Tensor>> = (0..w)
            .map(|_| vec![Tensor::new(vec![n], (0..n).map(|_| g.normal()).collect()).unwrap()])
            .collect();
        let d: Vec<Vec<Tensor>> = (0..w)
            .map(|_| vec![Tensor::new(vec![n], (0..n).map(|_| g.normal()).collect()).unwrap()])
            .collect();
        let moved: Vec<Vec<Tensor>> = a
            .iter()
            .zip(&d)
            .map(|(ai, di)| tensor::sets_add_scaled(ai, t, di).unwrap())
            .collect();
        let lhs = tensor::average_sets(&moved).unwrap();
        let mut rhs = tensor::average_sets(&a).unwrap();
        let dm = tensor::average_sets(&d).unwrap();
        tensor::sets_axpy(&mut rhs, t, &dm).unwrap();
        for (x, y) in lhs[0].data().iter().zip(rhs[0].data()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    });
}

#[test]
fn prop_average_sets_permutation_invariant() {
    // phase 3 must not care which order the workers report in
    property(40, |g| {
        let w = g.usize_in(2..8);
        let n = g.usize_in(1..40);
        let sets: Vec<Vec<Tensor>> = (0..w)
            .map(|_| vec![Tensor::new(vec![n], (0..n).map(|_| g.normal()).collect()).unwrap()])
            .collect();
        let avg = tensor::average_sets(&sets).unwrap();
        let mut perm: Vec<usize> = (0..w).collect();
        g.rng().shuffle(&mut perm);
        let shuffled: Vec<Vec<Tensor>> = perm.iter().map(|&i| sets[i].clone()).collect();
        let avg2 = tensor::average_sets(&shuffled).unwrap();
        for (a, b) in avg[0].data().iter().zip(avg2[0].data()) {
            assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "{a} vs {b}");
        }
    });
}

#[test]
fn prop_average_of_identical_sets_is_identity() {
    property(40, |g| {
        let w = g.usize_in(1..9);
        let s = rand_set(g, 2);
        let avg = tensor::average_sets(&vec![s.clone(); w]).unwrap();
        for (t, orig) in avg.iter().zip(&s) {
            for (a, b) in t.data().iter().zip(orig.data()) {
                assert_close(*a as f64, *b as f64, 1e-6, "identity mean");
            }
        }
    });
}

#[test]
fn prop_average_agrees_with_manual_mean() {
    property(40, |g| {
        let w = g.usize_in(1..9);
        let n = g.usize_in(1..40);
        let sets: Vec<Vec<Tensor>> = (0..w)
            .map(|_| vec![Tensor::new(vec![n], (0..n).map(|_| g.normal()).collect()).unwrap()])
            .collect();
        let avg = tensor::average_sets(&sets).unwrap();
        for j in 0..n {
            let manual: f64 = sets.iter().map(|s| s[0].data()[j] as f64).sum::<f64>() / w as f64;
            assert_close(avg[0].data()[j] as f64, manual, 1e-5, "elementwise mean");
        }
    });
}

#[test]
fn prop_cosine_in_unit_interval() {
    property(60, |g| {
        let a = rand_set(g, 2);
        let b: Vec<Tensor> = a
            .iter()
            .map(|t| {
                Tensor::new(
                    t.shape().to_vec(),
                    t.data().iter().map(|_| g.normal()).collect(),
                )
                .unwrap()
            })
            .collect();
        let c = tensor::sets_cosine(&a, &b).unwrap();
        assert!((-1.0 - 1e-9..=1.0 + 1e-9).contains(&c), "cosine {c}");
    });
}

#[test]
fn prop_shard_partition() {
    property(50, |g| {
        let w = g.usize_in(1..9);
        let per = g.usize_in(1..20);
        let global: Vec<usize> = (0..w * per).map(|i| i * 3 + 1).collect();
        let shards = sampler::shard(&global, w);
        let mut seen = std::collections::HashSet::new();
        for sh in &shards {
            assert_eq!(sh.len(), per);
            for &i in *sh {
                assert!(seen.insert(i), "index {i} in two shards");
            }
        }
        assert_eq!(seen.len(), global.len());
    });
}

#[test]
fn prop_epoch_sampler_is_permutation_every_epoch() {
    property(30, |g| {
        let n = g.usize_in(8..120);
        let batch = g.usize_in(1..(n / 2).max(2));
        let mut s = EpochSampler::new(n, batch, g.rng().next_u64(), 0);
        for _epoch in 0..2 {
            let mut seen = std::collections::HashSet::new();
            for _ in 0..n / batch {
                for &i in s.next_batch() {
                    assert!(i < n);
                    assert!(seen.insert(i), "repeat within epoch");
                }
            }
        }
    });
}

#[test]
fn prop_schedules_nonnegative_and_finite() {
    property(80, |g| {
        let total = g.usize_in(10..300);
        let sched = match g.usize_in(0..5) {
            0 => Schedule::Constant(g.f32_in(0.0..3.0)),
            1 => Schedule::Triangle {
                peak: g.f32_in(0.001..3.0),
                warmup: g.usize_in(1..total),
                total,
                end_lr: 0.0,
            },
            2 => Schedule::Cyclic {
                high: g.f32_in(0.5..2.0),
                low: g.f32_in(0.0..0.5),
                period: g.usize_in(2..60),
            },
            3 => Schedule::Piecewise(vec![
                (0, g.f32_in(0.0..1.0)),
                (g.usize_in(1..100), g.f32_in(0.0..1.0)),
                (g.usize_in(100..300), g.f32_in(0.0..1.0)),
            ]),
            _ => Schedule::Sequence(vec![
                (g.usize_in(1..50), Schedule::Constant(g.f32_in(0.0..1.0))),
                (
                    g.usize_in(1..50),
                    Schedule::Cyclic {
                        high: g.f32_in(0.1..1.0),
                        low: 0.0,
                        period: g.usize_in(2..20),
                    },
                ),
            ]),
        };
        for step in 0..total + 50 {
            let lr = sched.lr(step);
            assert!(lr.is_finite() && lr >= 0.0, "{lr} at {step}");
        }
        // scaling by k scales lr by k
        let k = g.f32_in(0.1..4.0);
        let scaled = sched.scaled(k);
        for step in [0usize, total / 2, total] {
            assert_close(
                scaled.lr(step) as f64,
                (k * sched.lr(step)) as f64,
                1e-5,
                "scaled lr",
            );
        }
    });
}

#[test]
fn prop_cosine_schedule_bounded_and_warmup_monotone() {
    property(60, |g| {
        let warmup = g.usize_in(1..40);
        let total = warmup + g.usize_in(1..200);
        let peak = g.f32_in(0.01..2.0);
        let end_lr = g.f32_in(0.0..1.0) * peak;
        let s = Schedule::Cosine { peak, warmup, total, end_lr };
        let lo = end_lr.min(0.0); // warmup starts at 0
        for step in 0..total + 30 {
            let lr = s.lr(step);
            assert!(lr.is_finite());
            assert!(
                lr >= lo - 1e-6 && lr <= peak + 1e-6,
                "cosine lr {lr} outside [{lo}, {peak}] at {step}"
            );
        }
        // warmup is monotone nondecreasing, decay monotone nonincreasing
        for t in 0..warmup.saturating_sub(1) {
            assert!(s.lr(t + 1) >= s.lr(t) - 1e-6, "warmup not monotone at {t}");
        }
        for t in warmup..total + 10 {
            assert!(s.lr(t + 1) <= s.lr(t) + 1e-6, "decay not monotone at {t}");
        }
        // endpoints
        assert!((s.lr(warmup) - peak).abs() < 1e-5);
        assert!((s.lr(total + 29) - end_lr).abs() < 1e-5);
    });
}

#[test]
fn prop_piecewise_schedule_within_breakpoint_envelope() {
    // linear interpolation can never leave [min bp, max bp]
    property(60, |g| {
        let k = g.usize_in(2..6);
        let mut step = 0usize;
        let mut pts = Vec::with_capacity(k);
        for _ in 0..k {
            pts.push((step, g.f32_in(0.0..2.0)));
            step += g.usize_in(1..50);
        }
        let lo = pts.iter().map(|(_, l)| *l).fold(f32::INFINITY, f32::min);
        let hi = pts.iter().map(|(_, l)| *l).fold(f32::NEG_INFINITY, f32::max);
        let s = Schedule::Piecewise(pts);
        for t in 0..step + 20 {
            let lr = s.lr(t);
            assert!(
                lr >= lo - 1e-6 && lr <= hi + 1e-6,
                "piecewise lr {lr} outside [{lo}, {hi}] at {t}"
            );
        }
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(g: &mut Gen, depth: usize) -> Json {
        match if depth > 2 { g.usize_in(0..4) } else { g.usize_in(0..6) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f64_in(-1e6..1e6) * 100.0).round() / 100.0),
            3 => Json::Str(
                (0..g.usize_in(0..12))
                    .map(|_| char::from_u32(g.usize_in(32..1200) as u32).unwrap_or('x'))
                    .collect(),
            ),
            4 => Json::Arr((0..g.usize_in(0..5)).map(|_| random_json(g, depth + 1)).collect()),
            _ => Json::Obj(
                (0..g.usize_in(0..5))
                    .map(|i| (format!("k{i}"), random_json(g, depth + 1)))
                    .collect(),
            ),
        }
    }
    property(80, |g| {
        let v = random_json(g, 0);
        let compact = Json::parse(&v.to_string()).unwrap();
        assert_eq!(compact, v);
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(pretty, v);
    });
}

#[test]
fn prop_rng_streams_reproducible() {
    property(30, |g| {
        let seed = g.rng().next_u64();
        let id = g.usize_in(0..64) as u64;
        let mut a = Rng::stream(seed, id);
        let mut b = Rng::stream(seed, id);
        for _ in 0..20 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    });
}

#[test]
fn prop_sgd_momentum_zero_reduces_to_plain_sgd() {
    use swap::model::ParamSet;
    use swap::optim::{SgdConfig, SgdOptimizer};
    property(30, |g| {
        let n = g.usize_in(1..40);
        let p0: Vec<f32> = (0..n).map(|_| g.normal()).collect();
        let grad: Vec<f32> = (0..n).map(|_| g.normal()).collect();
        let lr = g.f32_in(0.001..0.5);
        let mut params = ParamSet::from_vec(p0.clone());
        let mut opt = SgdOptimizer::new(
            SgdConfig { momentum: 0.0, weight_decay: 0.0 },
            &params,
        );
        opt.step(&mut params, &grad, lr).unwrap();
        for i in 0..n {
            assert_close(
                params.data()[i] as f64,
                (p0[i] - lr * grad[i]) as f64,
                1e-5,
                "plain sgd",
            );
        }
    });
}
