//! Blocked-GEMM oracle tests: the production blocked/packed kernels
//! (`runtime::native::gemm`) must be **bitwise** identical to the
//! original scalar ikj reference kernels (`kernels::*_reference`) —
//! over randomized shapes, over every (m, k, n) the ResNet9s actually
//! emits (forward, dW and dX matmuls plus the head), at thread counts
//! 1..4, and on inputs laced with exact zeros (the reference's historic
//! `av == 0.0` sparsity skip only diverges on NaN/Inf data, which no
//! training path produces).
//!
//! The fused im2col packing (`conv3x3_into` / `conv3x3_dw_into`) is also
//! pinned against materialize-then-multiply with the reference kernels.
//!
//! The `*_tier` entry points additionally pin every SIMD dispatch tier
//! this host can run (scalar always, avx2/neon when detected) against the
//! same reference, over shapes biased onto the ragged tile edges where
//! the vector kernels hand off to the scalar fallback.

use swap::runtime::native::gemm::{
    conv3x3_dw_into, conv3x3_dw_into_tier, conv3x3_into, conv3x3_into_tier, matmul_into,
    matmul_into_tier, matmul_nt_into, matmul_nt_into_tier, matmul_tn_into, matmul_tn_into_tier,
    GemmScratch, KC, MR, NR,
};
use swap::runtime::native::kernels::{
    im2col, matmul_nt_reference, matmul_reference, matmul_tn_reference,
};
use swap::runtime::native::model::{conv_layers, Dims};
use swap::util::simd;

/// Deterministic pseudo-random buffer with exact zeros sprinkled in so
/// the reference's sparsity branch actually takes both sides.
fn wave(n: usize, f: f32) -> Vec<f32> {
    (0..n)
        .map(|i| {
            if i % 13 == 7 {
                0.0
            } else {
                (i as f32 * f + 0.1).sin() * 1.9
            }
        })
        .collect()
}

fn assert_bitwise(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}[{i}]: blocked {g} vs reference {w}"
        );
    }
}

/// out(m,n) = a(m,k) @ b(k,n), blocked vs reference, threads 1..4.
fn check_nn(m: usize, k: usize, n: usize, scratch: &mut GemmScratch) {
    let a = wave(m * k, 0.37);
    let b = wave(k * n, 0.73);
    let want = matmul_reference(&a, &b, m, k, n, 1);
    assert_bitwise(
        &want,
        &matmul_reference(&a, &b, m, k, n, 4),
        "reference t-invariance",
    );
    for threads in 1..=4 {
        let mut out = vec![f32::NAN; m * n];
        matmul_into(&mut out, &a, &b, m, k, n, threads, scratch);
        assert_bitwise(&out, &want, &format!("nn m={m} k={k} n={n} t={threads}"));
    }
}

/// out(m,n) = a(r,m)ᵀ @ b(r,n), blocked vs reference, threads 1..4.
fn check_tn(r: usize, m: usize, n: usize, scratch: &mut GemmScratch) {
    let a = wave(r * m, 0.53);
    let b = wave(r * n, 0.41);
    let want = matmul_tn_reference(&a, &b, r, m, n, 1);
    for threads in 1..=4 {
        let mut out = vec![f32::NAN; m * n];
        matmul_tn_into(&mut out, &a, &b, r, m, n, threads, scratch);
        assert_bitwise(&out, &want, &format!("tn r={r} m={m} n={n} t={threads}"));
    }
}

/// out(m,n) = a(m,k) @ b(n,k)ᵀ, blocked vs reference, threads 1..4.
fn check_nt(m: usize, k: usize, n: usize, scratch: &mut GemmScratch) {
    let a = wave(m * k, 0.61);
    let b = wave(n * k, 0.29);
    let want = matmul_nt_reference(&a, &b, m, k, n, 1);
    for threads in 1..=4 {
        let mut out = vec![f32::NAN; m * n];
        matmul_nt_into(&mut out, &a, &b, m, k, n, threads, scratch);
        assert_bitwise(&out, &want, &format!("nt m={m} k={k} n={n} t={threads}"));
    }
}

fn check_triple(m: usize, k: usize, n: usize, scratch: &mut GemmScratch) {
    check_nn(m, k, n, scratch);
    check_tn(k, m, n, scratch);
    check_nt(m, k, n, scratch);
}

/// nn pinned per dispatch tier: every tier this host can run (scalar is
/// always in the list, so scalar == reference is covered too) must match
/// the reference bitwise at threads 1 and 4.
fn check_nn_tiers(m: usize, k: usize, n: usize, scratch: &mut GemmScratch) {
    let a = wave(m * k, 0.37);
    let b = wave(k * n, 0.73);
    let want = matmul_reference(&a, &b, m, k, n, 1);
    for tier in simd::tiers_available() {
        for threads in [1, 4] {
            let mut out = vec![f32::NAN; m * n];
            matmul_into_tier(&mut out, &a, &b, m, k, n, threads, tier, scratch);
            assert_bitwise(&out, &want, &format!("nn {tier:?} m={m} k={k} n={n} t={threads}"));
        }
    }
}

/// tn (dW orientation) pinned per dispatch tier.
fn check_tn_tiers(r: usize, m: usize, n: usize, scratch: &mut GemmScratch) {
    let a = wave(r * m, 0.53);
    let b = wave(r * n, 0.41);
    let want = matmul_tn_reference(&a, &b, r, m, n, 1);
    for tier in simd::tiers_available() {
        for threads in [1, 4] {
            let mut out = vec![f32::NAN; m * n];
            matmul_tn_into_tier(&mut out, &a, &b, r, m, n, threads, tier, scratch);
            assert_bitwise(&out, &want, &format!("tn {tier:?} r={r} m={m} n={n} t={threads}"));
        }
    }
}

/// nt (dX orientation) pinned per dispatch tier.
fn check_nt_tiers(m: usize, k: usize, n: usize, scratch: &mut GemmScratch) {
    let a = wave(m * k, 0.61);
    let b = wave(n * k, 0.29);
    let want = matmul_nt_reference(&a, &b, m, k, n, 1);
    for tier in simd::tiers_available() {
        for threads in [1, 4] {
            let mut out = vec![f32::NAN; m * n];
            matmul_nt_into_tier(&mut out, &a, &b, m, k, n, threads, tier, scratch);
            assert_bitwise(&out, &want, &format!("nt {tier:?} m={m} k={k} n={n} t={threads}"));
        }
    }
}

#[test]
fn blocked_matches_reference_on_randomized_shapes() {
    let mut scratch = GemmScratch::default();
    // a small LCG over odd shapes, crossing every tile edge case
    let mut state = 0x2545f491u64;
    let mut next = |lo: usize, hi: usize| {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        lo + ((state >> 33) as usize) % (hi - lo + 1)
    };
    for _ in 0..14 {
        let m = next(1, 40);
        let k = next(1, 70);
        let n = next(1, 24);
        check_triple(m, k, n, &mut scratch);
    }
    // tile-boundary exact shapes
    for &(m, k, n) in &[(8usize, 8usize, 8usize), (16, 256, 8), (64, 257, 16), (65, 256, 9)] {
        check_triple(m, k, n, &mut scratch);
    }
}

#[test]
fn blocked_matches_reference_on_resnet9s_shapes() {
    let mut scratch = GemmScratch::default();
    // the tiny test preset and a wider model, small batches: every
    // (m, k, n) orientation the model's forward/backward actually emits
    for (d, b) in [
        (Dims { width: 4, num_classes: 10, image_size: 16 }, 3usize),
        (Dims { width: 16, num_classes: 10, image_size: 32 }, 1),
    ] {
        for (_name, cin, cout, side) in conv_layers(&d) {
            let rows = b * side * side;
            // forward: patches(rows, 9cin) @ W(9cin, cout)
            check_nn(rows, 9 * cin, cout, &mut scratch);
            // dW: patches(rows, 9cin)ᵀ @ dU(rows, cout)
            check_tn(rows, 9 * cin, cout, &mut scratch);
            // dX: dU(rows, cout) @ W(9cin, cout)ᵀ
            check_nt(rows, cout, 9 * cin, &mut scratch);
        }
        // the head matmul (tiny m: the per-chunk spawn gate keeps it
        // sequential, which must not change any bit) + its dW/dX twins
        check_nn(b, 8 * d.width, d.num_classes, &mut scratch);
        check_tn(b, 8 * d.width, d.num_classes, &mut scratch);
        check_nt(b, d.num_classes, 8 * d.width, &mut scratch);
    }
}

#[test]
fn fused_im2col_packing_matches_materialized_patches() {
    let mut scratch = GemmScratch::default();
    for (bs, h, w, c, cout) in [(2usize, 8usize, 8usize, 4usize, 8usize), (1, 6, 10, 3, 5)] {
        let x = wave(bs * h * w * c, 0.83);
        let wts = wave(9 * c * cout, 0.47);
        let patches = im2col(&x, bs, h, w, c, 1);
        let rows = bs * h * w;

        let want = matmul_reference(&patches, &wts, rows, 9 * c, cout, 1);
        for threads in 1..=4 {
            let mut out = vec![f32::NAN; rows * cout];
            conv3x3_into(&mut out, &x, bs, h, w, c, &wts, cout, threads, &mut scratch);
            assert_bitwise(&out, &want, &format!("fused conv t={threads}"));
        }

        let du = wave(rows * cout, 0.31);
        let want = matmul_tn_reference(&patches, &du, rows, 9 * c, cout, 1);
        for threads in 1..=4 {
            let mut out = vec![f32::NAN; 9 * c * cout];
            conv3x3_dw_into(&mut out, &x, bs, h, w, c, &du, cout, threads, &mut scratch);
            assert_bitwise(&out, &want, &format!("fused dW t={threads}"));
        }
    }
}

#[test]
fn every_tier_matches_reference_on_ragged_edge_tiles() {
    let mut scratch = GemmScratch::default();
    // randomized shapes pinned OFF every tile boundary: mr < MR ragged
    // row tiles, nr < NR ragged column strips (the scalar-fallback edge
    // of the SIMD kernels), and k never a multiple of KC
    let mut state = 0x7f4a7c15u64;
    let mut next = |lo: usize, hi: usize| {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        lo + ((state >> 33) as usize) % (hi - lo + 1)
    };
    for _ in 0..5 {
        let m = next(0, 4) * MR + next(1, MR - 1);
        let n = next(0, 2) * NR + next(1, NR - 1);
        let k = next(0, 1) * KC + next(1, KC - 1);
        check_nn_tiers(m, k, n, &mut scratch);
        check_tn_tiers(k, m, n, &mut scratch);
        check_nt_tiers(m, k, n, &mut scratch);
    }
    // degenerate strips: a single ragged row tile, a 1x1 output with a
    // long k chain crossing KC panels, and a lone ragged column strip
    check_nn_tiers(MR - 1, 3, NR + 5, &mut scratch);
    check_nn_tiers(1, 2 * KC + 1, 1, &mut scratch);
    check_nn_tiers(MR + 3, KC + 7, NR - 1, &mut scratch);
}

#[test]
fn fused_conv_matches_reference_per_tier() {
    let mut scratch = GemmScratch::default();
    // ragged everywhere: cout = 5 and 9c = 27 are never full NR strips,
    // so the fused path exercises the vector kernel AND its scalar edge
    let (bs, h, w, c, cout) = (1usize, 5usize, 7usize, 3usize, 5usize);
    let x = wave(bs * h * w * c, 0.83);
    let wts = wave(9 * c * cout, 0.47);
    let patches = im2col(&x, bs, h, w, c, 1);
    let rows = bs * h * w;
    let want = matmul_reference(&patches, &wts, rows, 9 * c, cout, 1);
    let du = wave(rows * cout, 0.31);
    let want_dw = matmul_tn_reference(&patches, &du, rows, 9 * c, cout, 1);
    for tier in simd::tiers_available() {
        for threads in [1, 4] {
            let mut out = vec![f32::NAN; rows * cout];
            conv3x3_into_tier(&mut out, &x, bs, h, w, c, &wts, cout, threads, tier, &mut scratch);
            assert_bitwise(&out, &want, &format!("fused conv {tier:?} t={threads}"));
            let mut dw = vec![f32::NAN; 9 * c * cout];
            conv3x3_dw_into_tier(&mut dw, &x, bs, h, w, c, &du, cout, threads, tier, &mut scratch);
            assert_bitwise(&dw, &want_dw, &format!("fused dW {tier:?} t={threads}"));
        }
    }
}

#[test]
fn scratch_reuse_across_shapes_is_clean() {
    // one scratch across wildly different shapes: panels are re-packed
    // fully per call, so stale bytes from a bigger previous call must
    // never leak into a smaller one
    let mut scratch = GemmScratch::default();
    let (m1, k1, n1) = (70, 300, 20);
    let a = wave(m1 * k1, 0.71);
    let b = wave(k1 * n1, 0.13);
    let mut big = vec![0.0f32; m1 * n1];
    matmul_into(&mut big, &a, &b, m1, k1, n1, 4, &mut scratch);

    let (m2, k2, n2) = (3, 5, 2);
    let a2 = wave(m2 * k2, 0.91);
    let b2 = wave(k2 * n2, 0.57);
    let want = matmul_reference(&a2, &b2, m2, k2, n2, 1);
    let mut out = vec![f32::NAN; m2 * n2];
    matmul_into(&mut out, &a2, &b2, m2, k2, n2, 4, &mut scratch);
    assert_bitwise(&out, &want, "small after big");
}
