//! Allocation regression tests for the flat weight-space, input and
//! native-kernel hot paths.
//!
//! The pre-refactor `ParamSet::average` built a full `Vec<Vec<Tensor>>`
//! copy of every worker's tensors before averaging — O(W·P) intermediate
//! bytes for a P-parameter model and W workers. The flat arena's
//! streaming `average_mt` allocates exactly one output arena; the
//! in-place ring all-reduce allocates nothing at all. Likewise,
//! `augment::shift` used to clone every image it touched (`img.to_vec()`
//! per augmented example); assembly now reuses one scratch buffer, so the
//! steady-state augmented batch-assembly loop allocates ZERO bytes. And
//! the native backend used to `vec![0.0; …]` every im2col/activation/
//! gradient buffer per forward/backward call — it now runs out of a
//! pooled persistent `Workspace` (blocked GEMM panels included), so a
//! full steady-state training step (batch assembly + forward + backward
//! + fused SGD — the per-step work of the prefetched phase-2 hot loop)
//! allocates ZERO bytes too. This file pins all of it with a counting
//! global allocator.
//!
//! The file contains a single #[test] so no concurrent test can perturb
//! the counters.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use swap::coordinator::{allreduce, Candidate, CandidateKind, StreamingMean};
use swap::coordinator::averaging::UniformPolicy;
use swap::coordinator::AveragingPolicy;
use swap::data::{AugStream, AugmentSpec, Batcher, Generator, SynthSpec};
use swap::model::{FlatParams, ParamLayout};

struct CountingAlloc;

static BYTES: AtomicUsize = AtomicUsize::new(0);
static CALLS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        BYTES.fetch_add(layout.size(), Ordering::Relaxed);
        CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        BYTES.fetch_add(new_size, Ordering::Relaxed);
        CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn measured<T>(f: impl FnOnce() -> T) -> (T, usize, usize) {
    let b0 = BYTES.load(Ordering::Relaxed);
    let c0 = CALLS.load(Ordering::Relaxed);
    let out = f();
    (
        out,
        BYTES.load(Ordering::Relaxed) - b0,
        CALLS.load(Ordering::Relaxed) - c0,
    )
}

#[test]
fn average_and_ring_allocation_budgets() {
    const W: usize = 8;
    const N: usize = 40_000;

    // ---- phase-3 averaging: one output arena, never O(W·P) clones ------
    let layout = ParamLayout::single(N);
    let sets: Vec<FlatParams> = (0..W)
        .map(|w| {
            FlatParams::from_data(
                layout.clone(),
                (0..N).map(|i| ((i + w * 131) as f32 * 0.01).sin()).collect(),
            )
            .unwrap()
        })
        .collect();

    let (avg, avg_bytes, _calls) = measured(|| FlatParams::average_mt(&sets, 1).unwrap());
    assert_eq!(avg.numel(), N);
    let arena_bytes = N * 4;
    let legacy_floor = W * arena_bytes; // what the old W-way clone copied
    assert!(
        avg_bytes < legacy_floor / 2,
        "average allocated {avg_bytes}B — regressed toward the legacy \
         O(W*P) clone ({legacy_floor}B)"
    );
    assert!(
        avg_bytes <= 2 * arena_bytes + 16_384,
        "average allocated {avg_bytes}B, budget is one {arena_bytes}B output \
         arena (+slack)"
    );

    // ---- streaming averaging policy: bounded to ~2 arenas --------------
    // The AveragingPolicy refactor must keep phase 3 streaming: observing
    // W candidates holds ONE running-sum arena (cloned from candidate 0),
    // and reading the average clones + scales it — never the W-arena
    // retention a naive "collect then average" policy would cost.
    let ((sum_mean, stream_avg), stream_bytes, stream_calls) = measured(|| {
        let mut mean = StreamingMean::new();
        for s in &sets {
            mean.push(s, 1).unwrap();
        }
        let avg = mean.mean(1).unwrap();
        (mean, avg)
    });
    assert_eq!(sum_mean.count(), W);
    assert_eq!(
        stream_avg, avg,
        "streamed mean must stay bitwise-identical to the terminal average"
    );
    assert!(
        stream_bytes < legacy_floor / 2,
        "streaming mean allocated {stream_bytes}B over {stream_calls} allocs \
         — regressed toward W-arena candidate retention ({legacy_floor}B)"
    );
    assert!(
        stream_bytes <= 2 * arena_bytes + 16_384,
        "streaming mean allocated {stream_bytes}B, budget is the running sum \
         + the read-out arena (2 x {arena_bytes}B + slack)"
    );
    drop((sum_mean, stream_avg));

    // the full UniformPolicy wrapper obeys the same budget (it is the
    // phase-3 default and must not add per-candidate bookkeeping arenas)
    let ((pol, pol_avg), pol_bytes, pol_calls) = measured(|| {
        let mut pol = UniformPolicy::new();
        for (w, s) in sets.iter().enumerate() {
            pol.observe(
                s,
                Candidate { kind: CandidateKind::Worker(w), val_acc: None },
                1,
            )
            .unwrap();
        }
        let avg = pol.average(1).unwrap();
        (pol, avg)
    });
    assert_eq!(pol.contributing(), W);
    assert_eq!(pol_avg, avg, "uniform policy must match the terminal average");
    assert!(
        pol_bytes <= 2 * arena_bytes + 16_384,
        "uniform policy allocated {pol_bytes}B over {pol_calls} allocs, \
         budget is one running sum + one read-out arena"
    );
    drop((pol, pol_avg));

    // ---- in-place ring all-reduce: ZERO allocation ---------------------
    let mut bufs: Vec<Vec<f32>> = sets.iter().map(|s| s.data().to_vec()).collect();
    let ((), ring_bytes, ring_calls) =
        measured(|| allreduce::ring_mean_inplace(&mut bufs).unwrap());
    assert!(
        ring_bytes < 1024,
        "in-place ring allocated {ring_bytes}B across {ring_calls} calls; \
         the schedule must run without per-step snapshots"
    );

    // ---- augmented batch assembly: steady-state ZERO allocation --------
    // (regression: shift() cloned every image with img.to_vec())
    let ds = Generator::new(SynthSpec::for_preset(10, 16, 3)).sample(32, 10);
    let mut batcher = Batcher::new(8, 16, AugmentSpec::cifar_default());
    let mut hb = batcher.make_batch();
    let key = AugStream { seed: 1, stream: 0 };
    let idx: Vec<usize> = (0..8).collect();
    // warmup grows the HostBatch buffers and the shift scratch once
    for step in 0..3u64 {
        batcher.assemble_step_into(&ds, &idx, key, step, 0, &mut hb);
    }
    let ((), asm_bytes, asm_calls) = measured(|| {
        for step in 3..53u64 {
            batcher.assemble_step_into(&ds, &idx, key, step, 0, &mut hb);
        }
    });
    assert_eq!(
        asm_bytes, 0,
        "augmented assembly allocated {asm_bytes}B over {asm_calls} allocs; \
         the hot loop must reuse the scratch + HostBatch buffers"
    );

    // ---- steady-state native training step: ZERO allocation ------------
    // assembly + gradients (forward/backward into the pooled workspace,
    // blocked GEMM panels included) + the fused whole-arena SGD step —
    // the exact per-step work the prefetched training loop performs.
    // threads = 1 (the tiny preset): the measured loop must not even
    // spawn a thread. Warmup builds the workspace pool, the packed GEMM
    // panel buffers and the batch buffers once; after that, nothing.
    use swap::model::ParamSet;
    use swap::runtime::{Backend, NativeBackend};
    let engine = NativeBackend::tiny();
    let mut params = ParamSet::init(engine.manifest(), 3);
    let mut mom = params.zeros_like();
    for step in 60..63u64 {
        batcher.assemble_step_into(&ds, &idx, key, step, 0, &mut hb);
        engine
            .train_step(params.as_mut_slice(), mom.as_mut_slice(), &hb, 0.01)
            .unwrap();
    }
    let ((), step_bytes, step_calls) = measured(|| {
        for step in 63..113u64 {
            batcher.assemble_step_into(&ds, &idx, key, step, 0, &mut hb);
            engine
                .train_step(params.as_mut_slice(), mom.as_mut_slice(), &hb, 0.01)
                .unwrap();
        }
    });
    assert_eq!(
        step_bytes, 0,
        "steady-state train step allocated {step_bytes}B over {step_calls} \
         allocs; forward/backward/SGD must run entirely out of the engine \
         workspace"
    );

    // ---- steady-state serving: ZERO allocation per request -------------
    // The dynamic batcher's slot arena, the pending ring and each shard
    // worker's engine buffers are all preallocated, and Server::start
    // warms every batch shape. A served request (client copy-in,
    // coalesce, infer, copy-out, condvar handshake) must allocate
    // nothing — on either numeric tier. The counting allocator is
    // global, so this also pins the shard worker threads.
    use std::sync::Arc;
    use swap::serving::{ServeConfig, ServeModel, ServeTier, Server, ShardEngine};
    let il = 16 * 16 * 3;
    for tier in [ServeTier::F32, ServeTier::Int8] {
        let eng = NativeBackend::tiny();
        let sp = ParamSet::init(eng.manifest(), 3);
        let sbn = swap::model::BnState::init(eng.manifest());
        let model = Arc::new(ServeModel::new(eng, sp, sbn, tier).unwrap());
        let cfg = ServeConfig {
            shards: 1,
            max_batch: 4,
            max_delay: std::time::Duration::ZERO,
            queue_slots: 8,
        };
        let server = Server::start(model, cfg).unwrap();
        let mut logits = vec![0.0f32; 10];
        // warmup: first trips through the condvar handshake per slot
        for i in 0..8 {
            let img = &ds.images[i * il..(i + 1) * il];
            server.classify_into(img, &mut logits).unwrap();
        }
        let ((), srv_bytes, srv_calls) = measured(|| {
            for r in 0..40 {
                let i = r % 8;
                let img = &ds.images[i * il..(i + 1) * il];
                server.classify_into(img, &mut logits).unwrap();
            }
        });
        assert_eq!(
            srv_bytes, 0,
            "steady-state {} serving allocated {srv_bytes}B over {srv_calls} \
             allocs; the request path must run out of the slot arena + shard \
             workspaces",
            tier.name()
        );
        drop(server);
    }

    // ---- shard engine across alternating batch shapes: ZERO alloc ------
    // warm() grows every buffer to the max shape once; ragged coalesced
    // batches must reuse them (grow-only workspace + fixed staging).
    let eng = NativeBackend::tiny();
    let sp = ParamSet::init(eng.manifest(), 3);
    let sbn = swap::model::BnState::init(eng.manifest());
    let qmodel = ServeModel::new(eng, sp, sbn, ServeTier::Int8).unwrap();
    let mut shard = ShardEngine::new(&qmodel, 8);
    shard.warm(&qmodel).unwrap();
    let ((), shape_bytes, shape_calls) = measured(|| {
        for &b in &[8usize, 3, 1, 5, 2, 8, 1] {
            shard.infer(&qmodel, b).unwrap();
        }
    });
    assert_eq!(
        shape_bytes, 0,
        "alternating batch shapes allocated {shape_bytes}B over \
         {shape_calls} allocs; warm() must cover every shape <= max_batch"
    );
}
