//! Serving subsystem integration suite.
//!
//! Pins the three contracts the `serve-model` path is built on:
//!
//! 1. **Ragged-batch invariance (f32)** — the dynamic batcher may coalesce
//!    requests into any batch shape; per-example f32 logits must be
//!    bitwise identical to serving each request alone at batch 1, both at
//!    the engine level and end-to-end through a running [`Server`].
//! 2. **int8 parity oracle** — the quantized tier is a *tolerance*
//!    contract against f32 (top-1 agreement + bounded logit error), but
//!    the quantized path itself is bitwise deterministic across every
//!    available SIMD tier and across intra-op thread counts (exact i32
//!    accumulation).
//! 3. **Servable checkpoints** — `save_model`/`load_model` round-trip the
//!    param + BN bundle bitwise and reject truncated/corrupt files.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use swap::data::{Generator, SynthSpec};
use swap::model::{load_model, save_model, BnState, ParamSet};
use swap::runtime::native::workspace::Workspace;
use swap::runtime::native::NativeBackend;
use swap::runtime::Backend;
use swap::serving::{argmax, ServeConfig, ServeModel, ServeTier, Server};
use swap::util::simd::{self, Tier};

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("swap-serving-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Engine + randomized-but-deterministic weights/BN and a synthetic
/// image set sized for the tiny preset.
fn fixture(seed: u64, n: usize) -> (NativeBackend, ParamSet, BnState, Vec<f32>) {
    let engine = NativeBackend::tiny();
    let params = ParamSet::init(engine.manifest(), seed);
    let bn = BnState::init(engine.manifest());
    let d = engine.dims();
    let ds = Generator::new(SynthSpec::for_preset(d.num_classes, d.image_size, seed)).sample(n, 7);
    (engine, params, bn, ds.images)
}

/// Reference logits: each image alone at batch 1 through the f32 path.
fn batch1_logits(
    engine: &NativeBackend,
    params: &ParamSet,
    bn: &BnState,
    images: &[f32],
) -> Vec<f32> {
    let d = engine.dims();
    let il = d.image_size * d.image_size * 3;
    let n = images.len() / il;
    let mut ws = Workspace::new();
    let mut out = vec![0.0f32; n * d.num_classes];
    for i in 0..n {
        let img = &images[i * il..(i + 1) * il];
        let row = &mut out[i * d.num_classes..(i + 1) * d.num_classes];
        let r = engine.eval_logits_ws(params.as_slice(), bn.as_slice(), img, 1, 1, &mut ws, row);
        r.unwrap();
    }
    out
}

#[test]
fn ragged_batch_shapes_match_batch1_bitwise_f32() {
    let (engine, params, bn, images) = fixture(11, 11);
    let d = engine.dims();
    let (il, nc) = (d.image_size * d.image_size * 3, d.num_classes);
    let reference = batch1_logits(&engine, &params, &bn, &images);

    // one grow-only workspace reused across every ragged shape
    let mut ws = Workspace::new();
    let mut got = vec![0.0f32; 11 * nc];
    let mut at = 0usize;
    for &b in &[4usize, 3, 1, 2, 1] {
        let imgs = &images[at * il..(at + b) * il];
        let rows = &mut got[at * nc..(at + b) * nc];
        let r = engine.eval_logits_ws(params.as_slice(), bn.as_slice(), imgs, b, 1, &mut ws, rows);
        r.unwrap();
        at += b;
    }
    assert_eq!(at, 11);
    for i in 0..11 {
        assert_eq!(
            got[i * nc..(i + 1) * nc],
            reference[i * nc..(i + 1) * nc],
            "image {i}: ragged-batch f32 logits differ from batch=1"
        );
    }
}

#[test]
fn server_coalesced_requests_match_direct_eval_bitwise() {
    let n = 10usize;
    let (engine, params, bn, images) = fixture(3, n);
    let reference = batch1_logits(&engine, &params, &bn, &images);
    let d = engine.dims();
    let (il, nc) = (d.image_size * d.image_size * 3, d.num_classes);

    let model = Arc::new(ServeModel::new(engine, params, bn, ServeTier::F32).unwrap());
    let cfg = ServeConfig {
        shards: 2,
        max_batch: 4,
        // generous window so concurrent requests actually coalesce
        max_delay: Duration::from_millis(20),
        queue_slots: 16,
    };
    let server = Server::start(model, cfg).unwrap();

    // two rounds over the same slots to exercise slot recycling
    for _round in 0..2 {
        let mismatches = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for i in 0..n {
                let (server, reference, images, mismatches) =
                    (&server, &reference, &images, &mismatches);
                s.spawn(move || {
                    let mut logits = vec![0.0f32; nc];
                    let img = &images[i * il..(i + 1) * il];
                    let top1 = server.classify_into(img, &mut logits).unwrap();
                    let want = &reference[i * nc..(i + 1) * nc];
                    if logits != want || top1 != argmax(want) {
                        mismatches.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(mismatches.load(Ordering::Relaxed), 0, "served logits differ from batch=1");
    }

    let st = server.stats();
    assert_eq!(st.requests, 2 * n as u64);
    assert_eq!(st.infer_errors, 0);
    assert!(st.batches >= 1 && st.batches <= st.requests);
    assert!(st.max_batch_seen >= 1 && st.max_batch_seen <= 4);
}

#[test]
fn int8_parity_oracle_across_simd_tiers() {
    let n = 64usize;
    let (engine, params, bn, images) = fixture(5, n);
    let d = engine.dims();
    let (il, nc) = (d.image_size * d.image_size * 3, d.num_classes);
    let f32_logits = batch1_logits(&engine, &params, &bn, &images);
    let qm = engine.quantize_model(params.as_slice()).unwrap();

    // quantized logits per tier, batched in chunks of 16
    let run_tier = |tier: Tier, threads: usize| -> Vec<f32> {
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; n * nc];
        for c in 0..(n / 16) {
            let imgs = &images[c * 16 * il..(c + 1) * 16 * il];
            let rows = &mut out[c * 16 * nc..(c + 1) * 16 * nc];
            let r = engine.eval_logits_quant_ws(
                &qm,
                params.as_slice(),
                bn.as_slice(),
                imgs,
                16,
                threads,
                tier,
                &mut ws,
                rows,
            );
            r.unwrap();
        }
        out
    };

    let scalar = run_tier(Tier::Scalar, 1);
    // exact i32 accumulation: every SIMD tier and thread count is bitwise
    // identical to the scalar tier
    for tier in simd::tiers_available() {
        let got = run_tier(tier, 1);
        assert_eq!(got, scalar, "int8 logits differ: {tier:?} t=1 vs scalar");
        let got_t3 = run_tier(tier, 3);
        assert_eq!(got_t3, scalar, "int8 logits differ: {tier:?} t=3 vs scalar");
    }

    // tolerance contract vs f32: bounded logit error, high top-1 agreement
    let amax = f32_logits.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let bound = 0.15 * amax + 1e-3;
    let mut agree = 0usize;
    for i in 0..n {
        let fr = &f32_logits[i * nc..(i + 1) * nc];
        let qr = &scalar[i * nc..(i + 1) * nc];
        let mut err = 0.0f32;
        for (a, b) in fr.iter().zip(qr) {
            err = err.max((a - b).abs());
        }
        assert!(err <= bound, "image {i}: int8 logit error {err} > bound {bound} (amax {amax})");
        // when the f32 margin dominates the error bound, top-1 MUST agree
        let top = argmax(fr);
        let margin = fr[top] - runner_up(fr, top);
        if margin > 2.0 * bound {
            assert_eq!(argmax(qr), top, "image {i}: top-1 flip despite margin {margin}");
        }
        if argmax(qr) == top {
            agree += 1;
        }
    }
    let frac = agree as f64 / n as f64;
    assert!(frac >= 0.8, "int8 top-1 agreement {frac} < 0.8 ({agree}/{n})");
}

fn runner_up(row: &[f32], top: usize) -> f32 {
    let mut best = f32::NEG_INFINITY;
    for (j, &v) in row.iter().enumerate() {
        if j != top && v > best {
            best = v;
        }
    }
    best
}

#[test]
fn int8_server_end_to_end() {
    let n = 8usize;
    let (engine, params, bn, images) = fixture(9, n);
    let d = engine.dims();
    let (il, nc) = (d.image_size * d.image_size * 3, d.num_classes);

    let model = Arc::new(ServeModel::new(engine, params, bn, ServeTier::Int8).unwrap());
    let cfg = ServeConfig {
        shards: 1,
        max_batch: 4,
        max_delay: Duration::from_micros(200),
        queue_slots: 8,
    };
    let server = Server::start(model, cfg).unwrap();
    let mut logits = vec![0.0f32; nc];
    for i in 0..n {
        let img = &images[i * il..(i + 1) * il];
        let top1 = server.classify_into(img, &mut logits).unwrap();
        assert!(top1 < nc);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(top1, argmax(&logits));
    }
    assert_eq!(server.stats().requests, n as u64);
    assert_eq!(server.stats().infer_errors, 0);
    // explicit drop: shuts the queue down and joins the workers
    drop(server);
}

#[test]
fn saturated_server_sheds_with_overload_error() {
    let (engine, params, bn, images) = fixture(13, 1);
    let d = engine.dims();
    let il = d.image_size * d.image_size * 3;
    let img = &images[..il];

    let model = Arc::new(ServeModel::new(engine, params, bn, ServeTier::F32).unwrap());
    // a deliberately tiny arena: far more concurrent clients than slots
    let cfg = ServeConfig {
        shards: 1,
        max_batch: 2,
        max_delay: Duration::from_micros(100),
        queue_slots: 2,
    };
    let server = Server::start(model, cfg).unwrap();

    // Waves of concurrent clients against 2 slots: admitted requests must
    // succeed, saturated ones must come back Overloaded immediately (the
    // old behaviour blocked forever here, so a regression turns this loop
    // into a deadline failure, not a hang).
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let sheds = AtomicUsize::new(0);
    let served = AtomicUsize::new(0);
    while sheds.load(Ordering::Relaxed) == 0 && std::time::Instant::now() < deadline {
        std::thread::scope(|s| {
            for _ in 0..32 {
                let (server, sheds, served) = (&server, &sheds, &served);
                s.spawn(move || match server.classify(img) {
                    Ok(top1) => {
                        assert!(top1 < d.num_classes);
                        served.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        assert!(e.is_overloaded(), "unexpected serve error class: {e}");
                        sheds.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
    }
    let (sheds, served) = (sheds.load(Ordering::Relaxed), served.load(Ordering::Relaxed));
    assert!(sheds > 0, "32-way waves on a 2-slot arena never shed a request");
    assert!(served > 0, "saturation shed every request — admission is broken");
    let st = server.stats();
    assert_eq!(st.sheds, sheds as u64, "shed counter disagrees with client-observed sheds");
    assert_eq!(st.requests, served as u64, "sheds must not count as served requests");
    assert_eq!(st.infer_errors, 0);

    // after the storm the server still serves cleanly
    assert!(server.classify(img).unwrap() < d.num_classes);
}

#[test]
fn servable_checkpoint_roundtrip_and_corruption() {
    let dir = scratch("ckpt");
    let path = dir.join("model.ckpt");
    let (engine, params, bn, _) = fixture(21, 1);
    let manifest = engine.manifest();

    save_model(&path, manifest, &params, &bn).unwrap();
    let (p2, bn2) = load_model(&path, manifest).unwrap();
    assert_eq!(p2.data(), params.data(), "param arena not bitwise after round-trip");
    assert_eq!(bn2.as_slice(), bn.as_slice(), "bn arena not bitwise after round-trip");

    // a loaded bundle must serve; logits must match the in-memory model
    let images = fixture(21, 2).3;
    let want = batch1_logits(&engine, &params, &bn, &images);
    let got = batch1_logits(&engine, &p2, &bn2, &images);
    assert_eq!(got, want);

    // truncated file: must error, not mis-shape silently
    let bytes = std::fs::read(&path).unwrap();
    let cut = dir.join("truncated.ckpt");
    std::fs::write(&cut, &bytes[..bytes.len() / 2]).unwrap();
    assert!(load_model(&cut, manifest).is_err(), "truncated checkpoint loaded");

    // missing file
    assert!(load_model(dir.join("absent.ckpt"), manifest).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_validation_rejects_bad_shapes_and_configs() {
    let (engine, params, bn, images) = fixture(2, 1);
    let d = engine.dims();
    let nc = d.num_classes;

    // a param arena that doesn't match the engine layout is rejected at
    // model assembly, not at the first request
    let bad_layout = swap::model::ParamLayout::single(3);
    let wrong = swap::model::FlatParams::from_data(bad_layout, vec![0.0; 3]).unwrap();
    let spare = NativeBackend::tiny();
    let spare_bn = BnState::init(spare.manifest());
    assert!(ServeModel::new(spare, wrong, spare_bn, ServeTier::F32).is_err());

    let model = Arc::new(ServeModel::new(engine, params, bn, ServeTier::F32).unwrap());

    // queue_slots < max_batch can never fill a batch
    let bad = ServeConfig {
        shards: 1,
        max_batch: 8,
        max_delay: Duration::ZERO,
        queue_slots: 4,
    };
    assert!(Server::start(model.clone(), bad).is_err());

    let server = Server::start(model, ServeConfig::for_shards(1)).unwrap();
    // wrong image length
    assert!(server.classify(&images[..7]).is_err());
    // wrong logits buffer length
    let mut logits = vec![0.0f32; nc + 1];
    let il = d.image_size * d.image_size * 3;
    assert!(server.classify_into(&images[..il], &mut logits).is_err());
    // a healthy request still works on the same server afterwards
    let mut ok = vec![0.0f32; nc];
    assert!(server.classify_into(&images[..il], &mut ok).is_ok());

    // tier knob surface
    assert!(ServeTier::from_knob("bf16").is_err());
    assert_eq!(ServeTier::from_knob("int8").unwrap().name(), "int8");
}
