//! Integration: the full SWAP coordinator on the tiny native backend — all
//! three phases compose, baselines/SWA/local-SGD run, clocks behave,
//! results are bitwise deterministic per seed, and averaging helps. Fully
//! hermetic: synthetic data + the pure-rust engine, no artifacts.

use swap::coordinator::{
    run_baseline, run_local_sgd, run_swa, run_swap, run_sync_training, AveragingSpec,
    BaselineConfig, LocalSgdConfig, SwaConfig, SwapConfig, SyncTrainConfig, TrainEnv,
};
use swap::data::{AugmentSpec, Dataset, Generator, SynthSpec};
use swap::model::ParamSet;
use swap::optim::Schedule;
use swap::runtime::{Backend, NativeBackend};
use swap::sim::{ClusterClock, CostModel, DeviceModel, NetModel};

struct Fixture {
    engine: NativeBackend,
    cost: CostModel,
    train: Dataset,
    test: Dataset,
}

fn fixture() -> Fixture {
    let engine = NativeBackend::tiny();
    let m = engine.manifest().clone();
    let gen = Generator::new(SynthSpec::for_preset(m.model.num_classes, m.model.image_size, 99));
    let train = gen.sample(96, 10);
    let test = gen.sample(32, 11);
    let cost = CostModel::new(DeviceModel::v100_like(), NetModel::pcie_like(), &m);
    Fixture { engine, cost, train, test }
}

fn env_threads(f: &Fixture, threads: usize) -> TrainEnv<'_> {
    TrainEnv {
        engine: &f.engine,
        cost: &f.cost,
        train: &f.train,
        test: &f.test,
        val: None,
        augment: AugmentSpec::none(),
        exec_batch: 8,
        bn_batches: 2,
        threads,
        // the CI prefetch lane (SWAP_PREFETCH=1) turns the overlapped
        // input pipeline on for this whole suite — results are identical
        // by contract, only the data-time accounting moves
        prefetch: swap::data::prefetch::default_prefetch(),
    }
}

/// Env with every knob explicit (the prefetch-vs-serial comparisons).
fn env_with(f: &Fixture, threads: usize, prefetch: bool) -> TrainEnv<'_> {
    TrainEnv { prefetch, ..env_threads(f, threads) }
}

/// Default env: real parallelism as configured for the process (the CI
/// parallel lane sets SWAP_THREADS=4), exercising the threaded paths in
/// every test below — results are identical either way by contract.
fn env(f: &Fixture) -> TrainEnv<'_> {
    let threads = swap::coordinator::parallel::default_threads();
    env_threads(f, threads)
}

fn tiny_swap_config(seed: u64) -> SwapConfig {
    SwapConfig {
        workers: 2,
        group_devices: 1,
        phase1_max_epochs: 2,
        phase1_stop_acc: 1.1,
        phase1_sched: Schedule::Constant(0.08),
        phase2_epochs: 2,
        phase2_sched: Schedule::Constant(0.02),
        seed,
        averaging: AveragingSpec::Uniform,
        snapshot_every: None,
        phase1_snapshot_every: None,
        phase1_dist: false,
        phase1_record_every: 1,
    }
}

#[test]
fn swap_three_phases_compose() {
    let f = fixture();
    let env = env(&f);
    let r = run_swap(&env, &tiny_swap_config(1)).unwrap();

    // phase 1 ran the full 2 epochs of B=16 (96/16 = 6 steps/epoch)
    assert_eq!(r.phase1.steps, 12);
    // two divergent workers
    assert_eq!(r.worker_params.len(), 2);
    assert!(
        r.worker_params[0].distance(&r.worker_params[1], 1).unwrap() > 0.0,
        "independent workers must diverge"
    );
    // the averaged model is the mean
    let manual = ParamSet::average(&r.worker_params).unwrap();
    assert!(manual.distance(&r.final_params, 1).unwrap() < 1e-9);
    // stats sane
    assert!(r.final_stats.examples == 32);
    assert!(r.final_stats.accuracy1() >= 0.0 && r.final_stats.accuracy1() <= 1.0);
    // clock ordering: phase1 < phase2-end < total; eval not in training time
    assert!(r.phase1_seconds > 0.0);
    assert!(r.phase2_seconds > r.phase1_seconds);
    assert!(r.clock.seconds > r.phase2_seconds, "phase 3 BN must be charged");
    assert!(r.clock.eval > 0.0);
    assert!(r.clock.comm > 0.0, "phase 1 all-reduce must be priced");
}

#[test]
fn swap_phase2_parallel_time_is_max_not_sum() {
    let f = fixture();
    let env = env(&f);
    let r2 = run_swap(&env, &tiny_swap_config(2)).unwrap();
    // phase-2 cluster time = steps * step_time (one worker's duration),
    // NOT workers * that. 2 epochs * 12 steps/epoch at B=8.
    let per_worker = 24.0 * f.cost.train_step_time(8);
    let measured = r2.phase2_seconds - r2.phase1_seconds;
    assert!(
        (measured - per_worker).abs() < 0.2 * per_worker,
        "phase2 cluster time {measured} vs one-worker {per_worker}"
    );
}

#[test]
fn swap_bitwise_deterministic_per_seed() {
    let f = fixture();
    let env = env(&f);
    let a = run_swap(&env, &tiny_swap_config(5)).unwrap();
    let b = run_swap(&env, &tiny_swap_config(5)).unwrap();
    // the native backend is pure f32 arithmetic in a fixed order: the same
    // seed must reproduce the final parameters BITWISE, not just closely
    assert_eq!(
        a.final_params, b.final_params,
        "same seed must give bitwise-identical final params"
    );
    assert_eq!(a.final_stats.correct1, b.final_stats.correct1);
    assert_eq!(a.final_stats.sum_loss.to_bits(), b.final_stats.sum_loss.to_bits());
    for (wa, wb) in a.worker_params.iter().zip(&b.worker_params) {
        assert_eq!(wa, wb);
    }
    // a different seed diverges
    let c = run_swap(&env, &tiny_swap_config(6)).unwrap();
    assert!(a.final_params.distance(&c.final_params, 1).unwrap() > 0.0);
}

#[test]
fn different_seed_streams_diverge_in_phase2() {
    // SWAP requires each phase-2 worker to see a different randomization
    // (Algorithm 1, line 22): the same start params trained under two
    // seed_streams must end in different places, the same stream twice in
    // bitwise-identical places.
    let f = fixture();
    let env = env(&f);
    let start = ParamSet::init(f.engine.manifest(), 3);

    let run_stream = |stream: u64| {
        let mut p = start.clone();
        let mut m = p.zeros_like();
        let mut clock = ClusterClock::new();
        run_sync_training(
            &env,
            &mut p,
            &mut m,
            &SyncTrainConfig {
                devices: 1,
                global_batch: 8,
                max_epochs: 1,
                stop_train_acc: 1.1,
                sched: Schedule::Constant(0.05),
                sched_offset: 0,
                seed_stream: stream,
                seed: 3,
            },
            &mut clock,
            |_, _, _| {},
        )
        .unwrap();
        p
    };

    let s100 = run_stream(100);
    let s100_again = run_stream(100);
    let s101 = run_stream(101);
    assert_eq!(s100, s100_again, "same stream must be bitwise reproducible");
    assert!(
        s100.distance(&s101, 1).unwrap() > 0.0,
        "different seed_streams must produce divergent workers"
    );
}

#[test]
fn swap_averaging_beats_mean_worker() {
    // The paper's core claim on this testbed (acceptance criterion): after
    // phase 2 the averaged model's test accuracy is at least the mean of
    // the per-worker accuracies. Phase 1 runs to a basin; phase 2 uses a
    // small decaying LR so the workers stay in it.
    let f = fixture();
    let env = env(&f);
    let cfg = SwapConfig {
        workers: 4,
        group_devices: 1,
        phase1_max_epochs: 4,
        phase1_stop_acc: 1.1,
        phase1_sched: Schedule::Triangle { peak: 0.1, warmup: 3, total: 12, end_lr: 0.02 },
        phase2_epochs: 1,
        phase2_sched: Schedule::Triangle { peak: 0.01, warmup: 1, total: 12, end_lr: 0.0 },
        seed: 42,
        averaging: AveragingSpec::Uniform,
        snapshot_every: None,
        phase1_snapshot_every: None,
        phase1_dist: false,
        phase1_record_every: 1,
    };
    let r = run_swap(&env, &cfg).unwrap();
    assert_eq!(r.worker_stats.len(), 4);
    // workers did move independently
    assert!(r.worker_params[0].distance(&r.worker_params[3], 1).unwrap() > 0.0);
    let before = r.before_avg_acc1();
    let after = r.final_stats.accuracy1();
    assert!(
        after >= before,
        "averaging must not hurt: after {after:.4} < mean-worker {before:.4}"
    );
}

#[test]
fn before_avg_accuracy_is_mean_of_worker_stats() {
    // the SwapResult accessor is the single source of truth: it must equal
    // the arithmetic mean of the per-worker stats it carries
    let f = fixture();
    let env = env(&f);
    let r = run_swap(&env, &tiny_swap_config(7)).unwrap();
    let manual: f64 = r.worker_stats.iter().map(|s| s.accuracy1()).sum::<f64>()
        / r.worker_stats.len() as f64;
    assert!((r.before_avg_acc1() - manual).abs() < 1e-12);
    let manual5: f64 = r.worker_stats.iter().map(|s| s.accuracy5()).sum::<f64>()
        / r.worker_stats.len() as f64;
    assert!((r.before_avg_acc5() - manual5).abs() < 1e-12);
}

#[test]
fn baseline_sb_and_lb_run() {
    let f = fixture();
    let env = env(&f);
    let sb = run_baseline(
        &env,
        &BaselineConfig {
            devices: 1,
            epochs: 2,
            sched: Schedule::Constant(0.05),
            stop_train_acc: 1.1,
            seed: 3,
        },
    )
    .unwrap();
    let lb = run_baseline(
        &env,
        &BaselineConfig {
            devices: 4,
            epochs: 2,
            sched: Schedule::Constant(0.2),
            stop_train_acc: 1.1,
            seed: 3,
        },
    )
    .unwrap();
    // SB: 12 steps/epoch; LB(4x): 3 steps/epoch
    assert_eq!(sb.progress.steps, 24);
    assert_eq!(lb.progress.steps, 6);
    // large batch trains faster on the virtual cluster
    assert!(
        lb.outcome.cluster_seconds < sb.outcome.cluster_seconds,
        "LB {} !< SB {}",
        lb.outcome.cluster_seconds,
        sb.outcome.cluster_seconds
    );
    // comm only on the multi-device arm
    assert_eq!(sb.clock.comm, 0.0);
    assert!(lb.clock.comm > 0.0);
}

#[test]
fn early_stopping_respects_tau() {
    let f = fixture();
    let env = env(&f);
    // tau = 0 stops after the first epoch (any accuracy >= 0)
    let r = run_baseline(
        &env,
        &BaselineConfig {
            devices: 1,
            epochs: 50,
            sched: Schedule::Constant(0.05),
            stop_train_acc: 0.0,
            seed: 4,
        },
    )
    .unwrap();
    assert_eq!(r.progress.steps, 12, "must stop at the first epoch boundary");
}

#[test]
fn swa_samples_and_averages() {
    let f = fixture();
    let env = env(&f);
    let mut params = ParamSet::init(f.engine.manifest(), 8);
    let mut clock = ClusterClock::new();
    let r = run_swa(
        &env,
        &mut params,
        &SwaConfig {
            devices: 1,
            cycles: 3,
            cycle_epochs: 1,
            high_lr: 0.05,
            low_lr: 0.005,
            seed: 8,
            seed_stream: 0,
            averaging: AveragingSpec::Uniform,
            keep_samples: true,
        },
        &mut clock,
    )
    .unwrap();
    assert_eq!(r.samples.len(), 3);
    // samples are distinct iterates
    assert!(r.samples[0].distance(&r.samples[2], 1).unwrap() > 0.0);
    // averaged model equals the mean of samples
    let manual = ParamSet::average(&r.samples).unwrap();
    assert!(manual.distance(&r.averaged, 1).unwrap() < 1e-9);
    assert!(clock.seconds > 0.0);
}

#[test]
fn local_sgd_syncs_parameters() {
    let f = fixture();
    let env = env(&f);
    let r = run_local_sgd(
        &env,
        &LocalSgdConfig {
            devices: 2,
            sync_epochs: 1,
            sync_sched: Schedule::Constant(0.08),
            local_epochs: 1,
            local_sched: Schedule::Constant(0.02),
            h_steps: 4,
            seed: 12,
            averaging: AveragingSpec::Uniform,
        },
    )
    .unwrap();
    // 12 local steps at B=8 with H=4 -> 3 sync events
    assert_eq!(r.sync_events, 3);
    assert!(r.outcome.test_acc1 >= 0.0 && r.outcome.test_acc1 <= 1.0);
    assert!(r.outcome.cluster_seconds > 0.0);
}

#[test]
fn resumable_swap_reproduces_fresh_run() {
    use swap::coordinator::{run_swap_resumable, RunDir};
    let f = fixture();
    let env = env(&f);
    let cfg = tiny_swap_config(31);
    let fresh = run_swap(&env, &cfg).unwrap();

    let dir_path = std::env::temp_dir().join(format!("swap-resume-{}", std::process::id()));
    std::fs::remove_dir_all(&dir_path).ok();
    let dir = RunDir::new(&dir_path).unwrap();

    // first resumable run: everything computed + persisted
    let a = run_swap_resumable(&env, &cfg, &dir).unwrap();
    assert!(a.final_params.distance(&fresh.final_params, 1).unwrap() < 1e-9,
            "resumable(fresh) must equal run_swap");
    assert!((a.clock.seconds - fresh.clock.seconds).abs() < 1e-9);

    // second run: phase 1 + both workers loaded from disk, same outputs
    assert!(dir.has_phase1());
    assert_eq!(dir.finished_workers(cfg.workers), vec![0, 1]);
    let b = run_swap_resumable(&env, &cfg, &dir).unwrap();
    assert!(b.final_params.distance(&fresh.final_params, 1).unwrap() < 1e-9);
    assert!((b.clock.seconds - fresh.clock.seconds).abs() < 1e-6,
            "modeled time must be identical on resume: {} vs {}",
            b.clock.seconds, fresh.clock.seconds);

    // partial resume: delete one worker, keep phase 1
    std::fs::remove_file(dir_path.join("worker1.ckpt")).unwrap();
    let c = run_swap_resumable(&env, &cfg, &dir).unwrap();
    assert!(c.final_params.distance(&fresh.final_params, 1).unwrap() < 1e-9,
            "partial resume must still reproduce the fresh run");
    std::fs::remove_dir_all(&dir_path).ok();
}

#[test]
fn swap_parallel_threads_bitwise_equal_sequential() {
    // The tentpole acceptance property: running the phase-2 workers (and
    // phase-1 device shards) on real OS threads must change nothing but
    // wall time — `threads=N` equals `threads=1` BITWISE, end to end.
    let f = fixture();
    let seq_env = env_threads(&f, 1);
    let par_env = env_threads(&f, 4);
    let mut cfg = tiny_swap_config(9);
    cfg.workers = 4;
    cfg.snapshot_every = Some(6);
    let a = run_swap(&seq_env, &cfg).unwrap();
    let b = run_swap(&par_env, &cfg).unwrap();
    assert_eq!(a.final_params, b.final_params, "threads=4 must equal threads=1 bitwise");
    for (wa, wb) in a.worker_params.iter().zip(&b.worker_params) {
        assert_eq!(wa, wb, "every worker replica must match bitwise");
    }
    assert_eq!(a.final_stats.correct1, b.final_stats.correct1);
    assert_eq!(a.final_stats.sum_loss.to_bits(), b.final_stats.sum_loss.to_bits());
    // snapshot trails (taken inside worker threads) must match too
    assert_eq!(a.snapshots.len(), b.snapshots.len());
    for (ta, tb) in a.snapshots.iter().zip(&b.snapshots) {
        assert_eq!(ta.len(), tb.len());
        for ((sa, pa), (sb, pb)) in ta.iter().zip(tb) {
            assert_eq!(sa, sb);
            assert_eq!(pa, pb);
        }
    }
    // the modeled cluster clock is execution-order independent
    assert_eq!(a.clock.seconds.to_bits(), b.clock.seconds.to_bits());
    assert_eq!(a.clock.comm.to_bits(), b.clock.comm.to_bits());
}

#[test]
fn swap_parallel_shards_bitwise_with_group_devices() {
    // group_devices > 1: phase 1 runs 4 shard gradients per step and each
    // phase-2 group runs 2 — both fan-outs must stay bitwise across
    // thread counts
    let f = fixture();
    let seq_env = env_threads(&f, 1);
    let par_env = env_threads(&f, 3);
    let mut cfg = tiny_swap_config(11);
    cfg.workers = 2;
    cfg.group_devices = 2;
    let a = run_swap(&seq_env, &cfg).unwrap();
    let b = run_swap(&par_env, &cfg).unwrap();
    assert_eq!(a.final_params, b.final_params);
    assert_eq!(a.final_stats.correct1, b.final_stats.correct1);
    // a data-parallel phase-2 group pays all-reduce time; the absorbed
    // slowest-worker clock must carry that comm component (bug fix: it
    // used to be booked as pure compute)
    assert!(a.clock.comm > 0.0);
    // phase 1: 2 epochs of B=32 over 4 devices -> 6 steps, comm each
    let phase1_comm: f64 = 6.0 * f.cost.allreduce_time(4);
    assert!(
        a.clock.comm > phase1_comm * 1.5,
        "phase-2 group all-reduce must appear in the comm breakdown: \
         comm {} vs phase-1 only {}",
        a.clock.comm,
        phase1_comm
    );
}

#[test]
fn prefetched_swap_bitwise_equals_serial_assembly() {
    // THE input-pipeline acceptance property: with augmentation ON (the
    // path that actually consumes randomness), a SWAP run with the
    // double-buffered background producer must equal the serial
    // assemble-then-compute path BITWISE — params, stats, and snapshot
    // trails — for any thread count.
    let f = fixture();
    let aug_env = |threads: usize, prefetch: bool| TrainEnv {
        augment: AugmentSpec::cifar_default(),
        ..env_with(&f, threads, prefetch)
    };
    let mut cfg = tiny_swap_config(13);
    cfg.workers = 4;
    cfg.snapshot_every = Some(4);
    let serial = run_swap(&aug_env(1, false), &cfg).unwrap();
    let pre1 = run_swap(&aug_env(1, true), &cfg).unwrap();
    let pre4 = run_swap(&aug_env(4, true), &cfg).unwrap();

    for (tag, r) in [("threads=1", &pre1), ("threads=4", &pre4)] {
        assert_eq!(
            serial.final_params, r.final_params,
            "{tag}: prefetched final params must equal serial assembly"
        );
        for (wa, wb) in serial.worker_params.iter().zip(&r.worker_params) {
            assert_eq!(wa, wb, "{tag}: worker replicas must match bitwise");
        }
        assert_eq!(serial.final_stats.correct1, r.final_stats.correct1);
        assert_eq!(
            serial.final_stats.sum_loss.to_bits(),
            r.final_stats.sum_loss.to_bits()
        );
        assert_eq!(serial.snapshots.len(), r.snapshots.len());
        for (ta, tb) in serial.snapshots.iter().zip(&r.snapshots) {
            assert_eq!(ta, tb, "{tag}: snapshot trails must match");
        }
    }

    // the modeled clock accounts data time differently — that is the
    // point: serial assembly sits on the critical path, the prefetched
    // pipeline hides it behind compute
    assert!(serial.clock.data_exposed > 0.0, "serial input must be exposed");
    assert_eq!(serial.clock.data_hidden, 0.0);
    assert!(pre4.clock.data_hidden > 0.0, "prefetched input must hide");
    assert_eq!(pre4.clock.data_exposed, 0.0, "tiny batches fit the budget");
    assert!(pre4.clock.seconds < serial.clock.seconds);
    // and the accounting is execution-strategy independent: threads=1 and
    // threads=4 prefetched runs model the identical cluster
    assert_eq!(pre1.clock.seconds.to_bits(), pre4.clock.seconds.to_bits());
    assert_eq!(pre1.clock.data_hidden.to_bits(), pre4.clock.data_hidden.to_bits());
}

#[test]
fn local_sgd_prefetch_matches_serial() {
    let f = fixture();
    let cfg = LocalSgdConfig {
        devices: 2,
        sync_epochs: 1,
        sync_sched: Schedule::Constant(0.08),
        local_epochs: 1,
        local_sched: Schedule::Constant(0.02),
        h_steps: 4,
        seed: 33,
        averaging: AveragingSpec::Uniform,
    };
    let a = run_local_sgd(&env_with(&f, 1, false), &cfg).unwrap();
    let b = run_local_sgd(&env_with(&f, 4, true), &cfg).unwrap();
    assert_eq!(a.params, b.params, "prefetched local SGD must match serial bitwise");
    assert_eq!(a.sync_events, b.sync_events);
    assert_eq!(a.outcome.test_acc1, b.outcome.test_acc1);
    assert!(a.outcome.cluster_seconds >= b.outcome.cluster_seconds);
}

#[test]
fn recompute_bn_errors_on_empty_dataset() {
    // regression: the wrap-around order fill used to spin forever when
    // train.n == 0 — it must be a clean error now
    let engine = NativeBackend::tiny();
    let m = engine.manifest().clone();
    let gen = Generator::new(SynthSpec::for_preset(m.model.num_classes, m.model.image_size, 5));
    let train = gen.sample(0, 10);
    let test = gen.sample(8, 11);
    let cost = CostModel::new(DeviceModel::v100_like(), NetModel::pcie_like(), &m);
    let env = TrainEnv {
        engine: &engine,
        cost: &cost,
        train: &train,
        test: &test,
        val: None,
        augment: AugmentSpec::none(),
        exec_batch: 8,
        bn_batches: 2,
        threads: 1,
        prefetch: false,
    };
    let params = ParamSet::init(&m, 3);
    let mut clock = ClusterClock::new();
    let err = env.recompute_bn(&params, 3, &mut clock, false);
    assert!(err.is_err(), "empty training set must error, not hang");
    assert!(err.unwrap_err().to_string().contains("empty"));
}

#[test]
fn evaluate_covers_ragged_final_batch() {
    // n_test = 32 isn't interesting (divisible); build a 27-example test
    // set: examples must be 27, not floor(27/8)*8 = 24
    let engine = NativeBackend::tiny();
    let m = engine.manifest().clone();
    let gen = Generator::new(SynthSpec::for_preset(m.model.num_classes, m.model.image_size, 5));
    let train = gen.sample(96, 10);
    let test = gen.sample(27, 11);
    let cost = CostModel::new(DeviceModel::v100_like(), NetModel::pcie_like(), &m);
    let env = TrainEnv {
        engine: &engine,
        cost: &cost,
        train: &train,
        test: &test,
        val: None,
        augment: AugmentSpec::none(),
        exec_batch: 8,
        bn_batches: 2,
        threads: 1,
        prefetch: false,
    };
    let params = ParamSet::init(&m, 3);
    let mut clock = ClusterClock::new();
    let stats = env.bn_and_eval(&params, 3, &mut clock).unwrap();
    assert_eq!(
        stats.examples, 27,
        "evaluation must cover the whole test set, including the ragged final batch"
    );
    // and through the full SWAP pipeline as well
    let r = run_swap(&env, &tiny_swap_config(3)).unwrap();
    assert_eq!(r.final_stats.examples, 27);
    for ws in &r.worker_stats {
        assert_eq!(ws.examples, 27);
    }
}

#[test]
fn local_sgd_parallel_matches_sequential() {
    let f = fixture();
    let cfg = LocalSgdConfig {
        devices: 2,
        sync_epochs: 1,
        sync_sched: Schedule::Constant(0.08),
        local_epochs: 1,
        local_sched: Schedule::Constant(0.02),
        h_steps: 4,
        seed: 21,
        averaging: AveragingSpec::Uniform,
    };
    let a = run_local_sgd(&env_threads(&f, 1), &cfg).unwrap();
    let b = run_local_sgd(&env_threads(&f, 4), &cfg).unwrap();
    assert!(a.params.distance(&b.params, 1).unwrap() < 1e-12);
    assert_eq!(a.sync_events, b.sync_events);
    assert_eq!(a.outcome.test_acc1, b.outcome.test_acc1);
}
