//! Phase-1 + phase-2 transport integration: the elastic fault-tolerance
//! contract.
//!
//! * Zero-failure socket runs are BITWISE identical to in-memory runs —
//!   the transport decides where workers execute, never what they
//!   compute. With `phase1_dist` that covers the phase-1 collective too:
//!   params, snapshot trail, eval stats, and the modeled clock.
//! * An injected fault (worker error, crashed process, hung process)
//!   drops that worker from the phase-3 average; the survivors' average
//!   is bitwise equal to averaging the same replicas from an honest run,
//!   and the drop is recorded in `SwapResult::dropped` + `clock.lost`.
//! * A phase-1 member killed mid-all-reduce is dropped at the broken
//!   step: the ring re-forms from the survivors (down to `min_workers`,
//!   below which the collective aborts loudly), the discarded shard
//!   compute is booked as lost, and a restarted process re-adopts the
//!   freed slot at the current step.
//! * A quorum abort is crash-safe: the fsync'd phase-1 progress record
//!   resumes the collective at the last recorded step, bitwise.
//! * Measured wire traffic matches `CostModel::phase1_comm_bytes` +
//!   `CostModel::phase2_comm_bytes`; the `hub_exchange` α–β clock term is
//!   held against a real loopback socket pair.
//! * Run directories are pinned to one config fingerprint; resume retries
//!   exactly the dropped workers.

use std::time::Duration;

use swap::coordinator::transport::loopback;
use swap::coordinator::transport::run_fingerprint;
use swap::coordinator::transport::wire::{self, Msg};
use swap::coordinator::{
    join_phase1, join_run, run_swap, run_swap_resumable, run_swap_resumable_with, run_swap_with,
    FailurePolicy, MemoryTransport, NetStats, Phase1Outcome, RunDir, SocketTransport, SwapConfig,
    TrainEnv, TrainProgress,
};
use swap::data::{AugStream, AugmentSpec, Batcher, Dataset, EpochSampler, Generator, SynthSpec};
use swap::model::ParamSet;
use swap::optim::Schedule;
use swap::runtime::{Backend, NativeBackend};
use swap::sim::{ClusterClock, CostModel, DeviceModel, NetModel};

struct Fixture {
    engine: NativeBackend,
    cost: CostModel,
    train: Dataset,
    test: Dataset,
}

fn fixture() -> Fixture {
    let engine = NativeBackend::tiny();
    let m = engine.manifest().clone();
    let gen = Generator::new(SynthSpec::for_preset(m.model.num_classes, m.model.image_size, 99));
    let train = gen.sample(96, 10);
    let test = gen.sample(32, 11);
    let cost = CostModel::new(DeviceModel::v100_like(), NetModel::pcie_like(), &m);
    Fixture { engine, cost, train, test }
}

fn env_threads(f: &Fixture, threads: usize) -> TrainEnv<'_> {
    TrainEnv {
        engine: &f.engine,
        cost: &f.cost,
        train: &f.train,
        test: &f.test,
        val: None,
        augment: AugmentSpec::none(),
        exec_batch: 8,
        bn_batches: 2,
        threads,
        prefetch: swap::data::prefetch::default_prefetch(),
    }
}

fn env(f: &Fixture) -> TrainEnv<'_> {
    env_threads(f, swap::coordinator::parallel::default_threads())
}

fn tiny_swap_config(seed: u64) -> SwapConfig {
    SwapConfig {
        workers: 2,
        group_devices: 1,
        phase1_max_epochs: 2,
        phase1_stop_acc: 1.1,
        phase1_sched: Schedule::Constant(0.08),
        phase2_epochs: 2,
        phase2_sched: Schedule::Constant(0.02),
        seed,
        averaging: swap::coordinator::AveragingSpec::Uniform,
        snapshot_every: None,
        phase1_snapshot_every: None,
        phase1_dist: false,
        phase1_record_every: 1,
    }
}

/// Socket-test failure policy: quick heartbeats and retries, generous
/// deadlines (nothing should be dropped on a healthy run even on a
/// heavily loaded CI machine).
fn fast_policy() -> FailurePolicy {
    FailurePolicy {
        min_workers: 1,
        connect_timeout: Duration::from_secs(60),
        io_timeout: Duration::from_secs(20),
        heartbeat: Duration::from_millis(50),
        straggler_grace: Duration::from_secs(60),
        join_retries: 600,
        retry_backoff: Duration::from_millis(25),
    }
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("swap-transport-{tag}-{}", std::process::id()))
}

// ---------------------------------------------------------------------
// In-memory transport: fault injection
// ---------------------------------------------------------------------

#[test]
fn memory_fault_injection_averages_survivors() {
    // THE bug this module exists to fix: one failing worker used to kill
    // the run. Now it must be dropped, and the final model must be the
    // bitwise average of the SURVIVING replicas — which are themselves
    // bitwise identical to the same workers in a fully honest run,
    // because worker w's replica is a pure function of (seed, 100 + w).
    let f = fixture();
    let env = env(&f);
    let mut cfg = tiny_swap_config(15);
    cfg.workers = 3;

    let honest = run_swap(&env, &cfg).unwrap();
    assert!(honest.dropped.is_empty());
    assert_eq!(honest.clock.lost, 0.0);

    let faulty = MemoryTransport { fail_workers: vec![1] };
    let r = run_swap_with(&env, &cfg, &faulty, &FailurePolicy::default()).unwrap();

    // the drop is booked, not fatal
    assert_eq!(r.dropped.len(), 1);
    assert_eq!(r.dropped[0].0, 1);
    assert!(r.dropped[0].1.contains("injected fault"), "reason: {}", r.dropped[0].1);
    assert!(r.clock.lost > 0.0, "a dropped worker's modeled time must be booked as lost");
    assert_eq!(r.net, NetStats::default(), "in-memory transport moves no wire bytes");

    // survivors are the honest run's workers 0 and 2, bit for bit
    assert_eq!(r.worker_params.len(), 2);
    assert_eq!(r.worker_params[0], honest.worker_params[0]);
    assert_eq!(r.worker_params[1], honest.worker_params[2]);

    // and the final model is exactly their 2-way average
    let expected = ParamSet::average_mt(
        &[honest.worker_params[0].clone(), honest.worker_params[2].clone()],
        env.threads,
    )
    .unwrap();
    assert_eq!(r.final_params, expected, "survivor average must be bitwise exact");
}

#[test]
fn min_workers_floor_is_enforced() {
    let f = fixture();
    let env = env(&f);
    let cfg = tiny_swap_config(16);

    // every worker failing must still error out (an empty average is
    // undefined) even under the most permissive policy
    let all_fail = MemoryTransport { fail_workers: vec![0, 1] };
    let err = run_swap_with(&env, &cfg, &all_fail, &FailurePolicy::default()).unwrap_err();
    assert!(err.to_string().contains("0/2"), "unexpected error: {err}");

    // a stricter floor turns one drop into a failure
    let one_fail = MemoryTransport { fail_workers: vec![1] };
    let strict = FailurePolicy { min_workers: 2, ..FailurePolicy::default() };
    let err = run_swap_with(&env, &cfg, &one_fail, &strict).unwrap_err();
    assert!(err.to_string().contains("1/2"), "unexpected error: {err}");

    // the same drop under the default floor succeeds
    assert!(run_swap_with(&env, &cfg, &one_fail, &FailurePolicy::default()).is_ok());
}

// ---------------------------------------------------------------------
// Run-directory fingerprint + resume-after-drop
// ---------------------------------------------------------------------

#[test]
fn phase1_meta_round_trips_eval_and_lost_seconds() {
    // regression: eval (and now lost) seconds used to vanish across a
    // save/load of the phase-1 meta, so a resumed run under-reported the
    // cluster's evaluation time
    let f = fixture();
    let env = env(&f);
    let dir_path = tmp_dir("meta");
    std::fs::remove_dir_all(&dir_path).ok();
    let dir = RunDir::new(&dir_path).unwrap();

    let params = ParamSet::init(f.engine.manifest(), 3);
    let progress = TrainProgress { steps: 12, epochs: 2.0, train_acc: 0.5, train_loss: 1.25 };
    let mut clock = ClusterClock::new();
    clock.advance_compute(2.0);
    clock.note_eval(1.25);
    clock.note_drop(0.5);

    dir.save_phase1(&env, &params, &progress, &clock).unwrap();
    let (_, p, back) = dir.load_phase1(&env).unwrap();
    assert_eq!(p.steps, 12);
    assert!((back.seconds - clock.seconds).abs() < 1e-9);
    assert!((back.eval - 1.25).abs() < 1e-9, "eval seconds must survive the round trip");
    assert!((back.lost - 0.5).abs() < 1e-9, "lost seconds must survive the round trip");
    std::fs::remove_dir_all(&dir_path).ok();
}

#[test]
fn resume_rejects_mismatched_fingerprint() {
    // a run dir belongs to ONE configuration: resuming it with a
    // different recipe must hard-error instead of averaging checkpoints
    // from two different runs
    let f = fixture();
    let env = env(&f);
    let dir_path = tmp_dir("fingerprint");
    std::fs::remove_dir_all(&dir_path).ok();
    let dir = RunDir::new(&dir_path).unwrap();

    let cfg = tiny_swap_config(31);
    run_swap_resumable(&env, &cfg, &dir).unwrap();

    let other_seed = tiny_swap_config(32);
    let err = run_swap_resumable(&env, &other_seed, &dir).unwrap_err();
    assert!(
        err.to_string().contains("different configuration"),
        "unexpected error: {err}"
    );

    let mut other_workers = tiny_swap_config(31);
    other_workers.workers = 3;
    assert!(run_swap_resumable(&env, &other_workers, &dir).is_err());

    // the original configuration still resumes cleanly
    assert!(run_swap_resumable(&env, &cfg, &dir).is_ok());
    std::fs::remove_dir_all(&dir_path).ok();
}

#[test]
fn resumable_run_retries_only_dropped_workers() {
    // a drop leaves no checkpoint, so re-entering the same run dir
    // retries exactly the dropped ids — and reproduces the honest run
    let f = fixture();
    let env = env(&f);
    let cfg = tiny_swap_config(33);
    let fresh = run_swap(&env, &cfg).unwrap();

    let dir_path = tmp_dir("retry");
    std::fs::remove_dir_all(&dir_path).ok();
    let dir = RunDir::new(&dir_path).unwrap();

    let faulty = MemoryTransport { fail_workers: vec![1] };
    let r1 =
        run_swap_resumable_with(&env, &cfg, &dir, &faulty, &FailurePolicy::default()).unwrap();
    assert_eq!(r1.dropped.len(), 1);
    assert_eq!(r1.dropped[0].0, 1);
    assert_eq!(
        dir.finished_workers(cfg.workers),
        vec![0],
        "the dropped worker must not leave a checkpoint"
    );

    // second pass: worker 0 loads from disk, worker 1 retrains
    let r2 = run_swap_resumable(&env, &cfg, &dir).unwrap();
    assert!(r2.dropped.is_empty());
    assert!(
        r2.final_params.distance(&fresh.final_params, 1).unwrap() < 1e-9,
        "resume-after-drop must converge to the honest run"
    );
    std::fs::remove_dir_all(&dir_path).ok();
}

// ---------------------------------------------------------------------
// Socket transport (unix sockets: hermetic, no port collisions)
// ---------------------------------------------------------------------

#[cfg(unix)]
fn sock_addr(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("swap-{tag}-{}.sock", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

#[cfg(unix)]
fn connect_retry(addr: &str) -> std::os::unix::net::UnixStream {
    for _ in 0..2400 {
        if let Ok(s) = std::os::unix::net::UnixStream::connect(addr) {
            return s;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    panic!("could not connect to {addr}");
}

#[cfg(unix)]
#[test]
fn socket_transport_bitwise_equals_memory() {
    // the acceptance property: a multi-process socket run computes the
    // IDENTICAL model — weights cross the wire as exact little-endian f32
    // bytes and worker w's recipe is pinned by its id — at any thread
    // count on either side
    let f = fixture();
    let cfg = tiny_swap_config(9);
    let policy = fast_policy();

    for threads in [1usize, 4] {
        let env = env_threads(&f, threads);
        let mem = run_swap(&env, &cfg).unwrap();

        let addr = sock_addr(&format!("zf{threads}"));
        let transport = SocketTransport::new(addr.clone());
        let sock = std::thread::scope(|s| {
            let server = s.spawn(|| run_swap_with(&env, &cfg, &transport, &policy));
            let joins: Vec<_> = (0..cfg.workers)
                .map(|_| s.spawn(|| join_run(&env, &cfg, &addr, &policy, None)))
                .collect();
            let mut ids: Vec<usize> =
                joins.into_iter().map(|j| j.join().unwrap().unwrap().worker).collect();
            ids.sort();
            assert_eq!(ids, vec![0, 1], "each join must adopt a distinct worker id");
            server.join().unwrap()
        })
        .unwrap();
        std::fs::remove_file(&addr).ok();

        assert!(sock.dropped.is_empty(), "healthy run must drop nobody");
        assert_eq!(
            sock.final_params, mem.final_params,
            "threads={threads}: socket must equal memory bitwise"
        );
        assert_eq!(sock.worker_params.len(), mem.worker_params.len());
        for (a, b) in sock.worker_params.iter().zip(&mem.worker_params) {
            assert_eq!(a, b, "threads={threads}: every replica must match bitwise");
        }
        assert_eq!(sock.final_stats.correct1, mem.final_stats.correct1);
        assert_eq!(
            sock.clock.seconds.to_bits(),
            mem.clock.seconds.to_bits(),
            "worker clocks cross the wire bit-exactly"
        );

        // byte accounting: the cost model's prediction is exactly the f32
        // payload a zero-drop run moves (broadcast down + upload up per
        // worker); framing adds a measurable but small overhead on top
        assert_eq!(sock.net.param_bytes, f.cost.phase2_comm_bytes(cfg.workers));
        assert!(
            sock.net.framed_bytes > sock.net.param_bytes,
            "framing overhead must be accounted: framed {} vs payload {}",
            sock.net.framed_bytes,
            sock.net.param_bytes
        );
        assert_eq!(mem.net, NetStats::default());
    }
}

/// A worker process that joins, takes its assignment, then misbehaves:
/// `hang = false` closes the connection immediately (a crash mid-phase-2);
/// `hang = true` stays connected but silent until the coordinator's
/// `io_timeout` drops it and shuts the link down.
#[cfg(unix)]
fn faulty_client(addr: &str, fingerprint: &str, want: usize, hang: bool) {
    let mut conn = connect_retry(addr);
    conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    wire::write_msg(
        &mut conn,
        &Msg::Join { fingerprint: fingerprint.to_string(), resume: Some(want) },
    )
    .unwrap();
    let (msg, _) = wire::read_msg(&mut conn).unwrap();
    let Msg::Assign { worker, .. } = msg else {
        panic!("faulty client expected Assign, got {msg:?}")
    };
    assert_eq!(worker, want, "a free requested id must be honored");
    if hang {
        let r = wire::read_msg(&mut conn);
        assert!(r.is_err(), "the silent link must be shut down by the coordinator");
    }
}

#[cfg(unix)]
#[test]
fn socket_crash_and_hang_workers_are_dropped() {
    // one honest worker, one that crashes right after assignment, one
    // that hangs silently: the run must complete on the single survivor,
    // with both failures booked as drops
    let f = fixture();
    let env = env(&f);
    let mut cfg = tiny_swap_config(17);
    cfg.workers = 3;
    let honest = run_swap(&env, &cfg).unwrap();

    let policy = FailurePolicy {
        io_timeout: Duration::from_millis(1500),
        straggler_grace: Duration::from_secs(60),
        ..fast_policy()
    };
    let addr = sock_addr("fault");
    let fp = run_fingerprint(&env, &cfg);
    let transport = SocketTransport::new(addr.clone());
    let (r, summary) = std::thread::scope(|s| {
        let server = s.spawn(|| run_swap_with(&env, &cfg, &transport, &policy));
        let worker = s.spawn(|| join_run(&env, &cfg, &addr, &policy, Some(0)));
        s.spawn(|| faulty_client(&addr, &fp, 1, false)); // crash
        s.spawn(|| faulty_client(&addr, &fp, 2, true)); // hang
        (server.join().unwrap().unwrap(), worker.join().unwrap().unwrap())
    });
    std::fs::remove_file(&addr).ok();

    // the honest worker got the id it asked for and trained to the end
    assert_eq!(summary.worker, 0);
    assert_eq!(summary.steps, 24, "2 epochs x 12 steps at B=8");
    let numel = f.engine.manifest().num_params;
    assert_eq!(summary.bytes_received, wire::assign_frame_bytes(numel));
    assert!(summary.bytes_sent >= wire::done_frame_bytes(numel));

    // both misbehaving workers were dropped, the survivor carried the run
    assert_eq!(r.worker_params.len(), 1);
    let mut dropped_ids: Vec<usize> = r.dropped.iter().map(|(w, _)| *w).collect();
    dropped_ids.sort();
    assert_eq!(dropped_ids, vec![1, 2], "drops: {:?}", r.dropped);
    assert!(r.clock.lost > 0.0);

    // a single-survivor "average" is that replica verbatim, and the
    // replica is bitwise the honest run's worker 0
    assert_eq!(r.final_params, honest.worker_params[0]);

    // actual payload: 3 broadcasts down, 1 upload back — less than the
    // zero-drop prediction of 2 x 3 x param_bytes
    assert_eq!(r.net.param_bytes, 4 * f.cost.param_bytes);
    assert!(r.net.param_bytes < f.cost.phase2_comm_bytes(cfg.workers));
}

#[cfg(unix)]
#[test]
fn socket_rejects_mismatched_fingerprint_then_admits_honest_join() {
    // a join presenting a different config fingerprint must be refused
    // WITHOUT consuming a worker slot; a correct join afterwards succeeds
    let f = fixture();
    let env = env(&f);
    let mut cfg = tiny_swap_config(19);
    cfg.workers = 1;
    let policy = fast_policy();
    let addr = sock_addr("reject");
    let transport = SocketTransport::new(addr.clone());
    let r = std::thread::scope(|s| {
        let server = s.spawn(|| run_swap_with(&env, &cfg, &transport, &policy));
        let client = s.spawn(|| {
            let wrong = tiny_swap_config(20); // different seed => fingerprint
            let err = join_run(&env, &wrong, &addr, &policy, None).unwrap_err();
            assert!(
                err.to_string().contains("rejected"),
                "unexpected error: {err}"
            );
            join_run(&env, &cfg, &addr, &policy, None).unwrap()
        });
        assert_eq!(client.join().unwrap().worker, 0);
        server.join().unwrap()
    })
    .unwrap();
    std::fs::remove_file(&addr).ok();
    assert!(r.dropped.is_empty());
    assert_eq!(r.worker_params.len(), 1);
}

// ---------------------------------------------------------------------
// Distributed phase 1 (phase1_dist): collective over the wire
// ---------------------------------------------------------------------

/// A wire-level phase-1 member mirroring `join_phase1`'s honest shard
/// compute (same sampler draws, same counter-keyed augmentation, same
/// absolute-device batch slices), but scriptable: an optional per-step
/// delay holds the collective open while another thread rejoins, and
/// `die_after = Some(k)` processes `k` steps honestly then drops the
/// connection on the next broadcast — a process killed mid-all-reduce,
/// no goodbye frame. Returns the number of sync steps it computed.
#[cfg(unix)]
fn phase1_wire_member(
    addr: &str,
    env: &TrainEnv,
    cfg: &SwapConfig,
    want: usize,
    die_after: Option<u64>,
    step_delay: Duration,
) -> u64 {
    let mut conn = connect_retry(addr);
    conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let fp = run_fingerprint(env, cfg);
    wire::write_msg(&mut conn, &Msg::P1Join { fingerprint: fp, slot: Some(want) }).unwrap();
    let (msg, _) = wire::read_msg(&mut conn).unwrap();
    let Msg::P1Assign { slot, step: first_step } = msg else {
        panic!("wire member expected P1Assign, got {msg:?}")
    };
    assert_eq!(slot, want, "a free requested slot must be honored");

    // the recipe `join_phase1` derives from phase1_train_config: the
    // global batch spans every device shard, sampler/augment stream 0,
    // augmentation keyed by seed ^ 0xAE6
    let gd = cfg.group_devices;
    let total_devices = cfg.workers * gd;
    let global_batch = total_devices * env.exec_batch;
    let mut sampler = EpochSampler::new(env.train.n, global_batch, cfg.seed, 0);
    let mut batcher = Batcher::new(env.exec_batch, env.image_size(), env.augment);
    let aug = AugStream { seed: cfg.seed ^ 0xAE6, stream: 0 };
    for _ in 0..first_step {
        sampler.next_batch();
    }
    let mut next_draw = first_step;
    let mut hb = batcher.make_batch();
    let mut steps = 0u64;
    loop {
        let (msg, _) = wire::read_msg(&mut conn).unwrap();
        match msg {
            Msg::P1Step { step, params } => {
                if die_after == Some(steps) {
                    return steps; // drops conn: crashed mid-all-reduce
                }
                std::thread::sleep(step_delay);
                wire::write_msg(&mut conn, &Msg::Heartbeat { worker: slot, step }).unwrap();
                for _ in next_draw..step {
                    sampler.next_batch();
                }
                next_draw = step + 1;
                let global = sampler.next_batch();
                let per = global.len() / total_devices;
                for d in 0..gd {
                    let dev = slot * gd + d;
                    let rows = &global[dev * per..(dev + 1) * per];
                    batcher.assemble_step_into(
                        env.train,
                        rows,
                        aug,
                        step,
                        (dev * per) as u64,
                        &mut hb,
                    );
                    let g = env.engine.grad(&params, &hb).unwrap();
                    wire::write_msg(
                        &mut conn,
                        &Msg::P1Grad { device: dev, step, stats: g.stats, grads: g.grads },
                    )
                    .unwrap();
                }
                steps += 1;
            }
            Msg::P1Done { .. } => return steps,
            other => panic!("wire member got unexpected frame {other:?}"),
        }
    }
}

#[cfg(unix)]
#[test]
fn socket_phase1_collective_bitwise_equals_in_process() {
    // the distribution acceptance property: with phase1_dist the sync
    // phase runs as hub + remote shard members over the wire, and a
    // zero-failure run is BITWISE the in-process run — params, snapshot
    // trail, eval stats, and the modeled clock — at any thread count
    let f = fixture();
    let mut cfg = tiny_swap_config(23);
    cfg.phase1_dist = true;
    cfg.phase1_snapshot_every = Some(3);
    let policy = fast_policy();

    for threads in [1usize, 4] {
        let env = env_threads(&f, threads);
        // the in-memory transport ignores phase1_dist: this IS the
        // historical in-process run
        let mem = run_swap(&env, &cfg).unwrap();

        let addr = sock_addr(&format!("p1zf{threads}"));
        let transport = SocketTransport::new(addr.clone());
        let sock = std::thread::scope(|s| {
            let server = s.spawn(|| run_swap_with(&env, &cfg, &transport, &policy));
            let members: Vec<_> = (0..cfg.workers)
                .map(|w| {
                    let (env, cfg, addr, policy) = (&env, &cfg, &addr, &policy);
                    s.spawn(move || {
                        // one thread = one `swap join` process: phase-1
                        // membership, then the phase-2 replica
                        let o = join_phase1(env, cfg, addr, policy, Some(w)).unwrap();
                        let Phase1Outcome::Participated(p) = o else {
                            panic!("member {w}: collective finished without us")
                        };
                        assert_eq!(p.slot, w);
                        assert_eq!(p.first_step, 0, "a fresh collective starts at step 0");
                        assert_eq!(p.steps, 12, "2 epochs x 6 steps at global batch 16");
                        assert!(p.bytes_sent > 0 && p.bytes_received > 0);
                        join_run(env, cfg, addr, policy, Some(w)).unwrap()
                    })
                })
                .collect();
            for (w, m) in members.into_iter().enumerate() {
                assert_eq!(m.join().unwrap().worker, w);
            }
            server.join().unwrap()
        })
        .unwrap();
        std::fs::remove_file(&addr).ok();

        assert!(sock.dropped.is_empty(), "healthy run must drop nobody");
        assert_eq!(sock.phase1.steps, 12);
        assert_eq!(
            sock.final_params, mem.final_params,
            "threads={threads}: distributed phase 1 must equal in-process bitwise"
        );
        for (a, b) in sock.worker_params.iter().zip(&mem.worker_params) {
            assert_eq!(a, b, "threads={threads}: every replica must match bitwise");
        }
        assert_eq!(sock.final_stats.correct1, mem.final_stats.correct1);
        assert_eq!(
            sock.clock.seconds.to_bits(),
            mem.clock.seconds.to_bits(),
            "a zero-failure collective books the identical modeled clock"
        );
        assert_eq!(sock.phase1_snapshots.len(), mem.phase1_snapshots.len());
        for ((sa, pa), (sb, pb)) in sock.phase1_snapshots.iter().zip(&mem.phase1_snapshots) {
            assert_eq!(sa, sb, "snapshot steps must line up");
            assert_eq!(pa, pb, "threads={threads}: phase-1 snapshot trail must match bitwise");
        }

        // byte accounting: per step the hub broadcasts one arena per
        // member and gathers one per device — exactly phase1_comm_bytes —
        // on top of phase 2's broadcast-down/upload-up per worker
        let devices = cfg.workers * cfg.group_devices;
        assert_eq!(
            sock.net.param_bytes,
            f.cost.phase1_comm_bytes(sock.phase1.steps, cfg.workers, devices)
                + f.cost.phase2_comm_bytes(cfg.workers)
        );
        assert!(
            sock.net.framed_bytes > sock.net.param_bytes,
            "framing overhead must be accounted"
        );
    }
}

#[cfg(unix)]
#[test]
fn socket_phase1_member_death_repairs_ring_and_admits_rejoin() {
    // kill one member mid-all-reduce: the hub must drop it at the broken
    // step, re-form the ring from the survivor (min_workers = 1), book
    // the discarded shard compute as lost time, and admit a restarted
    // process into the freed slot at the current step — then finish a
    // full phase 2 with both slots
    let f = fixture();
    let env = env(&f);
    let mut cfg = tiny_swap_config(25);
    cfg.phase1_dist = true;
    let policy = fast_policy();

    let addr = sock_addr("p1repair");
    let transport = SocketTransport::new(addr.clone());
    let r = std::thread::scope(|s| {
        let server = s.spawn(|| run_swap_with(&env, &cfg, &transport, &policy));
        // slot 0: honest, held to >= 25ms per step so the collective is
        // still open when the restarted process comes knocking
        let survivor = s.spawn(|| {
            let steps =
                phase1_wire_member(&addr, &env, &cfg, 0, None, Duration::from_millis(25));
            assert_eq!(steps, 12, "the survivor carries the whole collective");
            join_run(&env, &cfg, &addr, &policy, Some(0)).unwrap()
        });
        // slot 1: dies after 3 honest steps, then rejoins as a restarted
        // process asking for its old slot back
        let rejoin = s.spawn(|| {
            let died_at = phase1_wire_member(&addr, &env, &cfg, 1, Some(3), Duration::ZERO);
            assert_eq!(died_at, 3);
            let outcome = loop {
                match join_phase1(&env, &cfg, &addr, &policy, Some(1)) {
                    Ok(o) => break o,
                    // the hub frees the slot only once the death surfaces
                    // at the next exchange; keep knocking until then
                    Err(e) if e.to_string().contains("all member slots taken") => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => panic!("rejoin failed: {e}"),
                }
            };
            let Phase1Outcome::Participated(p) = outcome else {
                panic!("collective finished before the rejoin (12 steps at >=25ms each)")
            };
            assert_eq!(p.slot, 1, "a rejoiner must adopt its freed slot");
            assert!(p.first_step > 0, "a rejoiner enters at the current step, not step 0");
            assert!(p.steps > 0);
            join_run(&env, &cfg, &addr, &policy, Some(1)).unwrap()
        });
        assert_eq!(survivor.join().unwrap().worker, 0);
        assert_eq!(rejoin.join().unwrap().worker, 1);
        server.join().unwrap()
    })
    .unwrap();
    std::fs::remove_file(&addr).ok();

    assert_eq!(r.phase1.steps, 12, "the repaired collective must run to completion");
    assert!(r.clock.lost > 0.0, "the dead member's discarded shard compute must be booked");
    assert!(r.dropped.is_empty(), "phase 2 is healthy: both slots rejoined");
    assert_eq!(r.worker_params.len(), 2);

    // the death cost the run at least one gathered arena vs a clean one
    let devices = cfg.workers * cfg.group_devices;
    assert!(
        r.net.param_bytes
            < f.cost.phase1_comm_bytes(r.phase1.steps, cfg.workers, devices)
                + f.cost.phase2_comm_bytes(cfg.workers),
        "a dropped member's unsent shards must be missing from the payload count"
    );
}

#[cfg(unix)]
#[test]
fn socket_phase1_quorum_abort_is_crash_safe_and_resumes_bitwise() {
    // below min_workers the collective must abort loudly — and because
    // the progress record is fsync'd per recorded step, restarting the
    // whole cluster resumes at the last completed sync step and finishes
    // bitwise identical to a never-crashed run
    let f = fixture();
    let env = env(&f);
    let mut cfg = tiny_swap_config(27);
    cfg.phase1_dist = true;
    let strict = FailurePolicy { min_workers: 2, ..fast_policy() };

    let honest = run_swap(&env, &cfg).unwrap();

    let dir_path = tmp_dir("p1resume");
    std::fs::remove_dir_all(&dir_path).ok();
    let dir = RunDir::new(&dir_path).unwrap();
    let addr = sock_addr("p1resume");
    let transport = SocketTransport::new(addr.clone());

    // attempt 1: slot 1 dies after 4 honest steps; one survivor is below
    // min_workers = 2, so the hub must fail the collective
    let err = std::thread::scope(|s| {
        let server = s.spawn(|| {
            run_swap_resumable_with(&env, &cfg, &dir, &transport, &strict).unwrap_err()
        });
        // the survivor is torn down with the hub; its error is noise
        s.spawn(|| {
            let _ = join_phase1(&env, &cfg, &addr, &strict, Some(0));
        });
        s.spawn(|| phase1_wire_member(&addr, &env, &cfg, 1, Some(4), Duration::ZERO));
        server.join().unwrap()
    });
    assert!(err.to_string().contains("below min_workers"), "unexpected error: {err}");

    // attempt 2, "restart everything": the record resumes the collective
    // at the last recorded step — members are assigned first_step > 0 and
    // fast-forward their sampler draws — and the run finishes bitwise
    let r = std::thread::scope(|s| {
        let server =
            s.spawn(|| run_swap_resumable_with(&env, &cfg, &dir, &transport, &strict));
        let members: Vec<_> = (0..cfg.workers)
            .map(|w| {
                let (env, cfg, addr, strict) = (&env, &cfg, &addr, &strict);
                s.spawn(move || {
                    let o = join_phase1(env, cfg, addr, strict, Some(w)).unwrap();
                    let Phase1Outcome::Participated(p) = o else {
                        panic!("member {w}: resumed collective reported already done")
                    };
                    assert_eq!(p.slot, w);
                    assert!(p.first_step > 0, "resume must skip the recorded steps");
                    assert_eq!(p.first_step + p.steps, 12, "resume + remainder = full phase");
                    join_run(env, cfg, addr, strict, Some(w)).unwrap()
                })
            })
            .collect();
        for m in members {
            m.join().unwrap();
        }
        server.join().unwrap()
    })
    .unwrap();
    std::fs::remove_file(&addr).ok();
    std::fs::remove_dir_all(&dir_path).ok();

    assert_eq!(r.phase1.steps, 12);
    assert!(r.dropped.is_empty());
    assert_eq!(
        r.final_params, honest.final_params,
        "resume-from-record must reproduce the honest run bitwise"
    );
    assert_eq!(r.final_stats.correct1, honest.final_stats.correct1);
}

#[test]
fn loopback_hub_exchange_tracks_cluster_clock_model() {
    // ROADMAP item 1's validation half: the α–β hub_exchange term must
    // price a real loopback phase-1 step within an order of magnitude.
    // (This CI band is deliberately loose for noisy shared runners; the
    // transport bench asserts the tight factor-of-4 band and reports the
    // measured-vs-predicted rows in BENCH_transport.json.)
    let cal = loopback::calibrate(24, 1 << 16).unwrap();
    assert!(cal.latency > 0.0 && cal.bandwidth > 0.0, "degenerate calibration: {cal:?}");
    let net = cal.net_model();

    let (members, gd, numel) = (2usize, 1usize, 1usize << 12);
    let measured = loopback::time_hub_exchange(members, gd, numel, 8).unwrap();
    let predicted = net.hub_exchange(4 * numel as u64, members, members * gd);
    let ratio = measured / predicted.max(1e-12);
    assert!(
        ratio > 0.1 && ratio < 10.0,
        "hub_exchange model off by more than 10x on loopback: measured {measured:.3e}s \
         vs predicted {predicted:.3e}s (ratio {ratio:.2})"
    );
}
