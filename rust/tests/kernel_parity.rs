//! Native-vs-reference kernel parity: asserts the pure-rust kernels and the
//! full native ResNet9s (forward, backward, BN moments, fused SGD step)
//! against JSON fixtures generated from the python reference oracles
//! (`python/compile/kernels/ref.py` + `python/compile/model.py` via
//! `jax.grad`). Regenerate with:
//!
//!     python3 python/tools/gen_parity_fixtures.py
//!
//! Tolerance: 1e-4 relative (f32 summation-order noise across languages).

use swap::model::{FlatParams, ParamLayout};
use swap::runtime::native::{kernels, model, NativeBackend, NativeSpec};
use swap::runtime::{Backend, HostBatch};
use swap::tensor::Tensor;
use swap::util::Json;

const TOL: f32 = 1e-4;

fn fixtures() -> Json {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/kernel_parity.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e} (run gen_parity_fixtures.py)", path.display()));
    Json::parse(&text).unwrap()
}

fn floats(j: &Json) -> Vec<f32> {
    j.as_arr()
        .expect("array of numbers")
        .iter()
        .map(|v| v.as_f64().expect("number") as f32)
        .collect()
}

fn ints(j: &Json) -> Vec<i32> {
    j.as_arr()
        .expect("array of ints")
        .iter()
        .map(|v| v.as_i64().expect("int") as i32)
        .collect()
}

/// (shape, data) of a fixture tensor object.
fn tensor_of(j: &Json) -> (Vec<usize>, Vec<f32>) {
    let shape = j
        .req("shape")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|d| d.as_usize().unwrap())
        .collect();
    (shape, floats(j.req("data").unwrap()))
}

fn assert_close_slice(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = TOL * (1.0 + w.abs());
        assert!(
            (g - w).abs() <= tol,
            "{what}[{i}]: got {g}, want {w} (tol {tol})"
        );
    }
}

#[test]
fn matmul_matches_reference() {
    let fx = fixtures();
    let m = fx.req("matmul").unwrap();
    let (ashape, a) = tensor_of(m.req("a").unwrap());
    let (bshape, b) = tensor_of(m.req("b").unwrap());
    let bias = floats(m.req("bias").unwrap());
    let (rows, k, n) = (ashape[0], ashape[1], bshape[1]);
    assert_eq!(bshape[0], k);

    let out = kernels::matmul(&a, &b, rows, k, n, 1);
    assert_close_slice(&out, &floats(m.req("out_nobias").unwrap()), "matmul");

    let mut with_bias = out.clone();
    for r in 0..rows {
        for j in 0..n {
            with_bias[r * n + j] += bias[j];
        }
    }
    assert_close_slice(&with_bias, &floats(m.req("out_none").unwrap()), "matmul+bias");

    let relu: Vec<f32> = with_bias.iter().map(|&v| v.max(0.0)).collect();
    assert_close_slice(&relu, &floats(m.req("out_relu").unwrap()), "matmul+bias+relu");
}

#[test]
fn sgd_matches_reference_sequence() {
    let fx = fixtures();
    let s = fx.req("sgd").unwrap();
    let mut p = floats(s.req("p0").unwrap());
    let mut m = floats(s.req("m0").unwrap());
    let lr = s.req("lr").unwrap().as_f64().unwrap() as f32;
    let mu = s.req("mu").unwrap().as_f64().unwrap() as f32;
    let wd = s.req("wd").unwrap().as_f64().unwrap() as f32;
    for g in s.req("grads").unwrap().as_arr().unwrap() {
        kernels::sgd_nesterov_inplace(&mut p, &mut m, &floats(g), lr, mu, wd);
    }
    assert_close_slice(&p, &floats(s.req("p_final").unwrap()), "sgd p");
    assert_close_slice(&m, &floats(s.req("m_final").unwrap()), "sgd m");
}

fn check_xent(case: &Json, what: &str) {
    let (shape, logits) = tensor_of(case.req("logits").unwrap());
    let labels = ints(case.req("labels").unwrap());
    let (b, k) = (shape[0], shape[1]);
    let (loss, c1, c5, dl) = kernels::cross_entropy(&logits, &labels, b, k);
    let want_loss = case.req("sum_loss").unwrap().as_f64().unwrap();
    assert!(
        (loss - want_loss).abs() <= 1e-4 * (1.0 + want_loss.abs()),
        "{what}: loss {loss} vs {want_loss}"
    );
    assert_eq!(c1, case.req("c1").unwrap().as_i64().unwrap(), "{what}: c1");
    assert_eq!(c5, case.req("c5").unwrap().as_i64().unwrap(), "{what}: c5");
    assert_close_slice(&dl, &floats(case.req("dlogits").unwrap()), what);
}

#[test]
fn cross_entropy_matches_reference_including_ties() {
    let fx = fixtures();
    check_xent(fx.req("xent").unwrap(), "xent");
    check_xent(fx.req("xent_ties").unwrap(), "xent_ties");
}

#[test]
fn conv3x3_matches_reference() {
    let fx = fixtures();
    let c = fx.req("conv3x3").unwrap();
    let (xshape, x) = tensor_of(c.req("x").unwrap());
    let (wshape, w) = tensor_of(c.req("w").unwrap());
    let (yshape, y) = tensor_of(c.req("y").unwrap());
    let (b, h, wd, cin) = (xshape[0], xshape[1], xshape[2], xshape[3]);
    let cout = wshape[1];
    assert_eq!(wshape[0], 9 * cin);
    assert_eq!(yshape, vec![b, h, wd, cout]);
    let patches = kernels::im2col(&x, b, h, wd, cin, 1);
    let out = kernels::matmul(&patches, &w, b * h * wd, 9 * cin, cout, 1);
    assert_close_slice(&out, &y, "conv3x3");
}

#[test]
fn batchnorm_matches_reference() {
    let fx = fixtures();
    let c = fx.req("batchnorm").unwrap();
    let (xshape, x) = tensor_of(c.req("x").unwrap());
    let gamma = floats(c.req("gamma").unwrap());
    let beta = floats(c.req("beta").unwrap());
    let rows = xshape[0] * xshape[1] * xshape[2];
    let ch = xshape[3];
    let (y, _xhat, mean, var, _invstd) = kernels::bn_train(&x, &gamma, &beta, rows, ch, 1);
    let (_, want_y) = tensor_of(c.req("y").unwrap());
    assert_close_slice(&y, &want_y, "bn y");
    assert_close_slice(&mean, &floats(c.req("mean").unwrap()), "bn mean");
    assert_close_slice(&var, &floats(c.req("var").unwrap()), "bn var");
}

#[test]
fn maxpool_matches_reference() {
    let fx = fixtures();
    let c = fx.req("maxpool2").unwrap();
    let (xshape, x) = tensor_of(c.req("x").unwrap());
    let (b, h, w, ch) = (xshape[0], xshape[1], xshape[2], xshape[3]);
    let (y, _idx) = kernels::maxpool2(&x, b, h, w, ch);
    let (_, want) = tensor_of(c.req("y").unwrap());
    assert_close_slice(&y, &want, "maxpool2");
}

/// The full-model case: grad / bnstats / eval / fused train step of the
/// native backend vs `jax.grad` + the python model entry points. The
/// per-tensor fixture data is flattened into the manifest-ordered arena
/// the backend consumes.
struct ModelFixture {
    backend: NativeBackend,
    params: FlatParams,
    batch: HostBatch,
    case: Json,
}

fn model_fixture() -> ModelFixture {
    let fx = fixtures();
    let m = fx.req("model").unwrap().clone();
    let width = m.req("width").unwrap().as_usize().unwrap();
    let classes = m.req("num_classes").unwrap().as_usize().unwrap();
    let image = m.req("image_size").unwrap().as_usize().unwrap();
    let backend = NativeBackend::new(NativeSpec::new("parity", width, classes, image)).unwrap();

    // the manifest layout must match the python param_specs order exactly
    let names: Vec<String> = m
        .req("param_names")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|n| n.as_str().unwrap().to_string())
        .collect();
    let manifest_names: Vec<String> =
        backend.manifest().params.iter().map(|s| s.name.clone()).collect();
    assert_eq!(manifest_names, names, "param order contract");

    let tensors: Vec<Tensor> = m
        .req("params")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| {
            let (shape, data) = tensor_of(t);
            Tensor::new(shape, data).unwrap()
        })
        .collect();
    // flatten through the manifest layout — validates fixture shapes too
    let params =
        FlatParams::from_tensors(ParamLayout::of_params(backend.manifest()), &tensors).unwrap();
    let batch = HostBatch {
        images: floats(m.req("images").unwrap()),
        labels: ints(m.req("labels").unwrap()),
        batch: m.req("batch").unwrap().as_usize().unwrap(),
        image_size: image,
    };
    ModelFixture { backend, params, batch, case: m }
}

#[test]
fn model_grad_matches_jax() {
    let f = model_fixture();
    let g = f.case.req("grad").unwrap();
    let r = f.backend.grad(f.params.as_slice(), &f.batch).unwrap();
    let want_loss = g.req("sum_loss").unwrap().as_f64().unwrap();
    assert!(
        (r.stats.sum_loss - want_loss).abs() <= 1e-4 * (1.0 + want_loss.abs()),
        "sum_loss {} vs {want_loss}",
        r.stats.sum_loss
    );
    assert_eq!(r.stats.correct1, g.req("c1").unwrap().as_i64().unwrap());
    assert_eq!(r.stats.correct5, g.req("c5").unwrap().as_i64().unwrap());
    let want = g.req("grads").unwrap().as_arr().unwrap();
    let layout = f.params.layout().clone();
    assert_eq!(r.grads.len(), layout.total());
    assert_eq!(layout.len(), want.len());
    for (i, w) in want.iter().enumerate() {
        let (shape, data) = tensor_of(w);
        assert_eq!(layout.spec(i).shape, shape, "grad {i} shape");
        let name = &layout.spec(i).name;
        assert_close_slice(&r.grads[layout.range(i)], &data, &format!("grad {name}"));
    }
}

#[test]
fn model_bn_moments_match_jax() {
    let f = model_fixture();
    let moments = f.backend.bn_moments(f.params.as_slice(), &f.batch).unwrap();
    let want = f.case.req("bn_moments").unwrap().as_arr().unwrap();
    let bn_layout = ParamLayout::of_bn(f.backend.manifest());
    assert_eq!(moments.len(), bn_layout.total());
    assert_eq!(bn_layout.len(), want.len());
    for (i, w) in want.iter().enumerate() {
        let (_, data) = tensor_of(w);
        let name = &bn_layout.spec(i).name;
        assert_close_slice(&moments[bn_layout.range(i)], &data, &format!("moment {name}"));
    }
}

#[test]
fn model_eval_matches_jax() {
    let f = model_fixture();
    // running stats = the batch moments (what the fixture's eval used)
    let bn = f.backend.bn_moments(f.params.as_slice(), &f.batch).unwrap();
    let stats = f
        .backend
        .eval_batch(f.params.as_slice(), &bn, &f.batch)
        .unwrap();
    let e = f.case.req("eval").unwrap();
    let want_loss = e.req("sum_loss").unwrap().as_f64().unwrap();
    assert!(
        (stats.sum_loss - want_loss).abs() <= 1e-4 * (1.0 + want_loss.abs()),
        "eval loss {} vs {want_loss}",
        stats.sum_loss
    );
    assert_eq!(stats.correct1, e.req("c1").unwrap().as_i64().unwrap());
    assert_eq!(stats.correct5, e.req("c5").unwrap().as_i64().unwrap());
}

#[test]
fn model_fused_train_step_matches_jax() {
    let f = model_fixture();
    let ts = f.case.req("train_step").unwrap();
    let lr = ts.req("lr").unwrap().as_f64().unwrap() as f32;
    let mut params = f.params.clone();
    let mut momentum = params.zeros_like();
    f.backend
        .train_step(params.as_mut_slice(), momentum.as_mut_slice(), &f.batch, lr)
        .unwrap();
    for (i, w) in ts.req("params_after").unwrap().as_arr().unwrap().iter().enumerate() {
        let (_, data) = tensor_of(w);
        let name = &f.backend.manifest().params[i].name;
        assert_close_slice(params.view(i), &data, &format!("p' {name}"));
    }
    for (i, w) in ts.req("momentum_after").unwrap().as_arr().unwrap().iter().enumerate() {
        let (_, data) = tensor_of(w);
        let name = &f.backend.manifest().params[i].name;
        assert_close_slice(momentum.view(i), &data, &format!("m' {name}"));
    }
}

#[test]
fn model_forward_dims_helpers() {
    // the conv-layer table the backward pass relies on, at fixture dims
    let d = model::Dims { width: 2, num_classes: 4, image_size: 8 };
    let layers = model::conv_layers(&d);
    assert_eq!(layers[0], ("prep", 3, 2, 8));
    assert_eq!(layers[7], ("res3b", 16, 16, 1));
    assert!(model::flops_fwd_per_example(&d) > 0);
}
