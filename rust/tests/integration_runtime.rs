//! Integration: the native execution backend behind the `Backend` trait
//! (tiny model — width 4, 10 classes, 16x16 images). No artifacts or XLA
//! toolchain required. These tests pin the backend contract numerically:
//!   * grad/train/eval/bnstats run and return sane shapes/values,
//!   * the fused train step equals the host-side Nesterov optimizer,
//!   * training on a fixed batch reduces the loss through the whole stack.

use swap::coordinator::TrainEnv;
use swap::data::{AugmentSpec, Batcher, Generator, SynthSpec};
use swap::model::{BnState, ParamSet};
use swap::optim::{SgdConfig, SgdOptimizer};
use swap::runtime::{Backend, HostBatch, NativeBackend, NativeSpec};
use swap::sim::{CostModel, DeviceModel, NetModel};

fn engine() -> NativeBackend {
    NativeBackend::tiny()
}

fn tiny_batch(engine: &NativeBackend, seed: u64) -> HostBatch {
    let m = engine.manifest();
    let gen = Generator::new(SynthSpec::for_preset(
        m.model.num_classes,
        m.model.image_size,
        seed,
    ));
    let ds = gen.sample(8, 10);
    let b = Batcher::new(8, m.model.image_size, AugmentSpec::none());
    b.assemble_clean(&ds, &(0..8).collect::<Vec<_>>())
}

#[test]
fn manifest_matches_model_contract() {
    let e = engine();
    let m = e.manifest();
    assert_eq!(e.name(), "native");
    assert_eq!(m.preset, "tiny");
    assert_eq!(m.model.arch, "resnet9s");
    assert_eq!(m.params.len(), 26);
    assert_eq!(m.bn_stats.len(), 16);
    assert!(m.batches.contains(&8));
    // the layout contract of the AOT artifacts: per-conv (w, gamma, beta)
    assert_eq!(m.params[0].name, "prep.w");
    assert_eq!(m.params[1].name, "prep.gamma");
    assert_eq!(m.params[2].name, "prep.beta");
    assert_eq!(m.params[25].name, "head.b");
    let declared: usize = m.params.iter().map(|s| s.numel()).sum();
    assert_eq!(m.num_params, declared);
    assert!(m.param_bytes() == 4 * declared as u64);
}

#[test]
fn grad_executes_with_correct_shapes() {
    let e = engine();
    let params = ParamSet::init(e.manifest(), 0);
    let hb = tiny_batch(&e, 1);
    let g = e.grad(params.as_slice(), &hb).unwrap();
    assert_eq!(g.grads.len(), params.tensors.len());
    for (gt, pt) in g.grads.iter().zip(&params.tensors) {
        assert_eq!(gt.shape(), pt.shape());
    }
    assert!(g.stats.sum_loss.is_finite() && g.stats.sum_loss > 0.0);
    assert!(g.stats.correct1 >= 0 && g.stats.correct1 <= 8);
    assert!(g.stats.correct5 >= g.stats.correct1);
    // gradients are not all zero
    let total: f64 = g.grads.iter().map(|t| t.sq_norm()).sum();
    assert!(total > 0.0);
}

#[test]
fn grad_is_deterministic() {
    let e = engine();
    let params = ParamSet::init(e.manifest(), 9);
    let hb = tiny_batch(&e, 2);
    let a = e.grad(params.as_slice(), &hb).unwrap();
    let b = e.grad(params.as_slice(), &hb).unwrap();
    assert_eq!(a.stats.sum_loss.to_bits(), b.stats.sum_loss.to_bits());
    for (x, y) in a.grads.iter().zip(&b.grads) {
        assert_eq!(x, y, "native grad must be bitwise deterministic");
    }
}

#[test]
fn fused_train_step_matches_host_optimizer() {
    let e = engine();
    let m = e.manifest().clone();
    let params0 = ParamSet::init(&m, 3);
    let hb = tiny_batch(&e, 2);
    let lr = 0.05f32;

    // host path: grads from the backend, then the host Nesterov update
    let g = e.grad(params0.as_slice(), &hb).unwrap();
    let mut host_params = params0.clone();
    let mut opt = SgdOptimizer::new(
        SgdConfig { momentum: m.model.momentum, weight_decay: m.model.weight_decay },
        &host_params,
    );
    opt.step(&mut host_params, &g.grads, lr).unwrap();

    // backend path: fused train step
    let mut dev_params = params0.clone();
    let mut dev_mom = params0.zeros_like();
    let stats = e
        .train_step(dev_params.as_mut_slice(), dev_mom.as_mut_slice(), &hb, lr)
        .unwrap();
    assert!((stats.sum_loss - g.stats.sum_loss).abs() < 1e-9 * g.stats.sum_loss.abs().max(1.0));

    // parity: parameters and momentum agree to f32 noise
    for ((hp, dp), name) in host_params
        .tensors
        .iter()
        .zip(&dev_params.tensors)
        .zip(m.params.iter().map(|s| &s.name))
    {
        let mut diff = hp.clone();
        diff.axpy(-1.0, dp).unwrap();
        let rel = diff.max_abs() / (1e-3 + hp.max_abs());
        assert!(rel < 1e-5, "param {name} host/device mismatch rel={rel}");
    }
    for (hm, dm) in opt.momentum.tensors.iter().zip(&dev_mom.tensors) {
        let mut diff = hm.clone();
        diff.axpy(-1.0, dm).unwrap();
        assert!(diff.max_abs() < 1e-5 + 1e-5 * hm.max_abs());
    }
}

#[test]
fn eval_and_bnstats_execute() {
    let e = engine();
    let m = e.manifest();
    let params = ParamSet::init(m, 5);
    let hb = tiny_batch(&e, 3);

    let bn = BnState::init(m);
    let stats = e.eval_batch(params.as_slice(), bn.as_slice(), &hb).unwrap();
    assert!(stats.sum_loss.is_finite());
    assert!(stats.correct1 <= 8 && stats.correct5 <= 8);

    let moments = e.bn_moments(params.as_slice(), &hb).unwrap();
    assert_eq!(moments.len(), m.bn_stats.len());
    // vars (odd positions) must be nonnegative
    for (i, t) in moments.iter().enumerate() {
        if i % 2 == 1 {
            assert!(t.data().iter().all(|&v| v >= -1e-6), "negative variance");
        }
    }
    // eval with the recomputed stats differs from eval with init stats
    let bn2 = BnState { tensors: moments };
    let stats2 = e.eval_batch(params.as_slice(), bn2.as_slice(), &hb).unwrap();
    assert!((stats2.sum_loss - stats.sum_loss).abs() > 1e-6);
}

#[test]
fn fused_training_reduces_loss_on_fixed_batch() {
    let e = engine();
    let mut params = ParamSet::init(e.manifest(), 7);
    let mut mom = params.zeros_like();
    let hb = tiny_batch(&e, 4);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..6 {
        let stats = e
            .train_step(params.as_mut_slice(), mom.as_mut_slice(), &hb, 0.08)
            .unwrap();
        last = stats.sum_loss;
        first.get_or_insert(stats.sum_loss);
    }
    assert!(
        last < first.unwrap(),
        "loss did not decrease: {} -> {last}",
        first.unwrap()
    );
}

#[test]
fn train_env_eval_and_bn_recompute() {
    let e = engine();
    let m = e.manifest().clone();
    let gen = Generator::new(SynthSpec::for_preset(m.model.num_classes, m.model.image_size, 11));
    let train = gen.sample(64, 10);
    let test = gen.sample(24, 11);
    let cost = CostModel::new(DeviceModel::v100_like(), NetModel::pcie_like(), &m);
    let env = TrainEnv {
        engine: &e,
        cost: &cost,
        train: &train,
        test: &test,
        augment: AugmentSpec::none(),
        exec_batch: 8,
        bn_batches: 2,
        threads: 1,
    };
    let params = ParamSet::init(&m, 1);
    let mut clock = swap::sim::ClusterClock::new();
    let bn = env.recompute_bn(&params, 1, &mut clock, true).unwrap();
    assert_eq!(bn.tensors.len(), m.bn_stats.len());
    assert!(clock.seconds > 0.0, "bn recompute must be charged");
    let stats = env.evaluate(&params, &bn, &mut clock).unwrap();
    assert_eq!(stats.examples, 24);
    assert!(clock.eval > 0.0);
}

#[test]
fn backend_accepts_any_batch_size() {
    // unlike per-batch AOT executables, the native backend is batch-agnostic
    let e = engine();
    let m = e.manifest();
    let params = ParamSet::init(m, 2);
    let gen = Generator::new(SynthSpec::for_preset(m.model.num_classes, m.model.image_size, 5));
    let ds = gen.sample(16, 10);
    for b in [1usize, 3, 16] {
        let batcher = Batcher::new(b, m.model.image_size, AugmentSpec::none());
        let hb = batcher.assemble_clean(&ds, &(0..b).collect::<Vec<_>>());
        let g = e.grad(params.as_slice(), &hb).unwrap();
        assert_eq!(g.stats.examples, b as i64);
    }
}

#[test]
fn threaded_backend_is_bitwise_identical() {
    // a backend with a kernel thread pool must be indistinguishable from
    // the sequential one, bit for bit, on every entry point — use a model
    // large enough that the kernels actually cross the spawn threshold
    let seq = NativeBackend::new(NativeSpec::new("mt", 8, 10, 32).with_batches(&[32])).unwrap();
    let par = NativeBackend::new(
        NativeSpec::new("mt", 8, 10, 32).with_batches(&[32]).with_threads(4),
    )
    .unwrap();
    let m = seq.manifest().clone();
    let gen = Generator::new(SynthSpec::for_preset(m.model.num_classes, m.model.image_size, 17));
    let ds = gen.sample(32, 10);
    let b = Batcher::new(32, m.model.image_size, AugmentSpec::none());
    let hb = b.assemble_clean(&ds, &(0..32).collect::<Vec<_>>());
    let params = ParamSet::init(&m, 4);

    let gs = seq.grad(params.as_slice(), &hb).unwrap();
    let gp = par.grad(params.as_slice(), &hb).unwrap();
    assert_eq!(gs.stats.sum_loss.to_bits(), gp.stats.sum_loss.to_bits());
    for (a, b) in gs.grads.iter().zip(&gp.grads) {
        assert_eq!(a, b, "gradients must match bitwise across thread counts");
    }

    let moments_s = seq.bn_moments(params.as_slice(), &hb).unwrap();
    let moments_p = par.bn_moments(params.as_slice(), &hb).unwrap();
    for (a, b) in moments_s.iter().zip(&moments_p) {
        assert_eq!(a, b, "bn moments must match bitwise");
    }

    let bn = BnState::from_moments(&[moments_s]).unwrap();
    let es = seq.eval_batch(params.as_slice(), bn.as_slice(), &hb).unwrap();
    let ep = par.eval_batch(params.as_slice(), bn.as_slice(), &hb).unwrap();
    assert_eq!(es.sum_loss.to_bits(), ep.sum_loss.to_bits());
    assert_eq!(es.correct1, ep.correct1);

    let mut ps = params.clone();
    let mut ms = ps.zeros_like();
    let mut pp = params.clone();
    let mut mp = pp.zeros_like();
    seq.train_step(ps.as_mut_slice(), ms.as_mut_slice(), &hb, 0.05).unwrap();
    par.train_step(pp.as_mut_slice(), mp.as_mut_slice(), &hb, 0.05).unwrap();
    assert_eq!(ps, pp, "fused train step must match bitwise");
}
