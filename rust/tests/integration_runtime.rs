//! Integration: the native execution backend behind the `Backend` trait
//! (tiny model — width 4, 10 classes, 16x16 images). No artifacts or XLA
//! toolchain required. These tests pin the backend contract numerically:
//!   * grad/train/eval/bnstats run over flat arenas and return sane values,
//!   * the fused train step equals the host-side Nesterov optimizer,
//!   * training on a fixed batch reduces the loss through the whole stack.

use swap::coordinator::TrainEnv;
use swap::data::{AugmentSpec, Batcher, Generator, SynthSpec};
use swap::model::{BnState, FlatParams, ParamLayout, ParamSet};
use swap::optim::{SgdConfig, SgdOptimizer};
use swap::runtime::{Backend, HostBatch, NativeBackend, NativeSpec};
use swap::sim::{CostModel, DeviceModel, NetModel};

fn engine() -> NativeBackend {
    NativeBackend::tiny()
}

fn tiny_batch(engine: &NativeBackend, seed: u64) -> HostBatch {
    let m = engine.manifest();
    let gen = Generator::new(SynthSpec::for_preset(
        m.model.num_classes,
        m.model.image_size,
        seed,
    ));
    let ds = gen.sample(8, 10);
    let b = Batcher::new(8, m.model.image_size, AugmentSpec::none());
    b.assemble_clean(&ds, &(0..8).collect::<Vec<_>>())
}

fn max_abs(s: &[f32]) -> f32 {
    s.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .fold(0.0f32, |m, (x, y)| m.max((x - y).abs()))
}

#[test]
fn manifest_matches_model_contract() {
    let e = engine();
    let m = e.manifest();
    assert_eq!(e.name(), "native");
    assert_eq!(m.preset, "tiny");
    assert_eq!(m.model.arch, "resnet9s");
    assert_eq!(m.params.len(), 26);
    assert_eq!(m.bn_stats.len(), 16);
    assert!(m.batches.contains(&8));
    // the layout contract of the AOT artifacts: per-conv (w, gamma, beta)
    assert_eq!(m.params[0].name, "prep.w");
    assert_eq!(m.params[1].name, "prep.gamma");
    assert_eq!(m.params[2].name, "prep.beta");
    assert_eq!(m.params[25].name, "head.b");
    let declared: usize = m.params.iter().map(|s| s.numel()).sum();
    assert_eq!(m.num_params, declared);
    assert!(m.param_bytes() == 4 * declared as u64);
    // the arena layout mirrors the manifest exactly
    let layout = ParamLayout::of_params(m);
    assert_eq!(layout.total(), m.num_params);
    assert_eq!(layout.len(), m.params.len());
    assert_eq!(layout.index_of("head.w"), Some(24));
}

#[test]
fn grad_executes_with_correct_shapes() {
    let e = engine();
    let params = ParamSet::init(e.manifest(), 0);
    let hb = tiny_batch(&e, 1);
    let g = e.grad(params.as_slice(), &hb).unwrap();
    // one contiguous arena in manifest order
    assert_eq!(g.grads.len(), e.manifest().num_params);
    assert_eq!(g.grads.len(), params.numel());
    assert!(g.stats.sum_loss.is_finite() && g.stats.sum_loss > 0.0);
    assert!(g.stats.correct1 >= 0 && g.stats.correct1 <= 8);
    assert!(g.stats.correct5 >= g.stats.correct1);
    // gradients are not all zero
    let total: f64 = g.grads.iter().map(|&v| v as f64 * v as f64).sum();
    assert!(total > 0.0);
}

#[test]
fn grad_is_deterministic() {
    let e = engine();
    let params = ParamSet::init(e.manifest(), 9);
    let hb = tiny_batch(&e, 2);
    let a = e.grad(params.as_slice(), &hb).unwrap();
    let b = e.grad(params.as_slice(), &hb).unwrap();
    assert_eq!(a.stats.sum_loss.to_bits(), b.stats.sum_loss.to_bits());
    assert_eq!(a.grads, b.grads, "native grad must be bitwise deterministic");
}

#[test]
fn fused_train_step_matches_host_optimizer() {
    let e = engine();
    let m = e.manifest().clone();
    let params0 = ParamSet::init(&m, 3);
    let hb = tiny_batch(&e, 2);
    let lr = 0.05f32;

    // host path: grads from the backend, then the host Nesterov update
    let g = e.grad(params0.as_slice(), &hb).unwrap();
    let mut host_params = params0.clone();
    let mut opt = SgdOptimizer::new(
        SgdConfig { momentum: m.model.momentum, weight_decay: m.model.weight_decay },
        &host_params,
    );
    opt.step(&mut host_params, &g.grads, lr).unwrap();

    // backend path: fused train step
    let mut dev_params = params0.clone();
    let mut dev_mom = params0.zeros_like();
    let stats = e
        .train_step(dev_params.as_mut_slice(), dev_mom.as_mut_slice(), &hb, lr)
        .unwrap();
    assert!((stats.sum_loss - g.stats.sum_loss).abs() < 1e-9 * g.stats.sum_loss.abs().max(1.0));

    // parity: parameters and momentum agree to f32 noise, per tensor
    let layout = host_params.layout().clone();
    for i in 0..layout.len() {
        let name = &layout.spec(i).name;
        let hp = host_params.view(i);
        let dp = dev_params.view(i);
        let rel = max_abs_diff(hp, dp) / (1e-3 + max_abs(hp));
        assert!(rel < 1e-5, "param {name} host/device mismatch rel={rel}");
        let hm = opt.momentum.view(i);
        let dm = dev_mom.view(i);
        assert!(max_abs_diff(hm, dm) < 1e-5 + 1e-5 * max_abs(hm));
    }
}

#[test]
fn eval_and_bnstats_execute() {
    let e = engine();
    let m = e.manifest();
    let params = ParamSet::init(m, 5);
    let hb = tiny_batch(&e, 3);

    let bn = BnState::init(m);
    let stats = e.eval_batch(params.as_slice(), bn.as_slice(), &hb).unwrap();
    assert!(stats.sum_loss.is_finite());
    assert!(stats.correct1 <= 8 && stats.correct5 <= 8);

    let bn_layout = ParamLayout::of_bn(m);
    let moments = e.bn_moments(params.as_slice(), &hb).unwrap();
    assert_eq!(moments.len(), bn_layout.total());
    // vars (odd layout positions) must be nonnegative
    let flat = FlatParams::from_data(bn_layout.clone(), moments).unwrap();
    for i in 0..bn_layout.len() {
        if i % 2 == 1 {
            assert!(
                flat.view(i).iter().all(|&v| v >= -1e-6),
                "negative variance in {}",
                bn_layout.spec(i).name
            );
        }
    }
    // eval with the recomputed stats differs from eval with init stats
    let bn2 = BnState::from_flat(flat);
    let stats2 = e.eval_batch(params.as_slice(), bn2.as_slice(), &hb).unwrap();
    assert!((stats2.sum_loss - stats.sum_loss).abs() > 1e-6);
}

#[test]
fn fused_training_reduces_loss_on_fixed_batch() {
    let e = engine();
    let mut params = ParamSet::init(e.manifest(), 7);
    let mut mom = params.zeros_like();
    let hb = tiny_batch(&e, 4);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..6 {
        let stats = e
            .train_step(params.as_mut_slice(), mom.as_mut_slice(), &hb, 0.08)
            .unwrap();
        last = stats.sum_loss;
        first.get_or_insert(stats.sum_loss);
    }
    assert!(
        last < first.unwrap(),
        "loss did not decrease: {} -> {last}",
        first.unwrap()
    );
}

#[test]
fn train_env_eval_and_bn_recompute() {
    let e = engine();
    let m = e.manifest().clone();
    let gen = Generator::new(SynthSpec::for_preset(m.model.num_classes, m.model.image_size, 11));
    let train = gen.sample(64, 10);
    let test = gen.sample(24, 11);
    let cost = CostModel::new(DeviceModel::v100_like(), NetModel::pcie_like(), &m);
    let env = TrainEnv {
        engine: &e,
        cost: &cost,
        train: &train,
        test: &test,
        val: None,
        augment: AugmentSpec::none(),
        exec_batch: 8,
        bn_batches: 2,
        threads: 1,
        prefetch: false,
    };
    let params = ParamSet::init(&m, 1);
    let mut clock = swap::sim::ClusterClock::new();
    let bn = env.recompute_bn(&params, 1, &mut clock, true).unwrap();
    assert_eq!(bn.layout().len(), m.bn_stats.len());
    assert!(clock.seconds > 0.0, "bn recompute must be charged");
    let stats = env.evaluate(&params, &bn, &mut clock).unwrap();
    assert_eq!(stats.examples, 24);
    assert!(clock.eval > 0.0);
}

#[test]
fn backend_accepts_any_batch_size() {
    // unlike per-batch AOT executables, the native backend is batch-agnostic
    let e = engine();
    let m = e.manifest();
    let params = ParamSet::init(m, 2);
    let gen = Generator::new(SynthSpec::for_preset(m.model.num_classes, m.model.image_size, 5));
    let ds = gen.sample(16, 10);
    for b in [1usize, 3, 16] {
        let batcher = Batcher::new(b, m.model.image_size, AugmentSpec::none());
        let hb = batcher.assemble_clean(&ds, &(0..b).collect::<Vec<_>>());
        let g = e.grad(params.as_slice(), &hb).unwrap();
        assert_eq!(g.stats.examples, b as i64);
    }
}

#[test]
fn threaded_backend_is_bitwise_identical() {
    // a backend with a kernel thread pool must be indistinguishable from
    // the sequential one, bit for bit, on every entry point — use a model
    // large enough that the kernels actually cross the spawn threshold
    let seq = NativeBackend::new(NativeSpec::new("mt", 8, 10, 32).with_batches(&[32])).unwrap();
    let par = NativeBackend::new(
        NativeSpec::new("mt", 8, 10, 32).with_batches(&[32]).with_threads(4),
    )
    .unwrap();
    let m = seq.manifest().clone();
    let gen = Generator::new(SynthSpec::for_preset(m.model.num_classes, m.model.image_size, 17));
    let ds = gen.sample(32, 10);
    let b = Batcher::new(32, m.model.image_size, AugmentSpec::none());
    let hb = b.assemble_clean(&ds, &(0..32).collect::<Vec<_>>());
    let params = ParamSet::init(&m, 4);

    let gs = seq.grad(params.as_slice(), &hb).unwrap();
    let gp = par.grad(params.as_slice(), &hb).unwrap();
    assert_eq!(gs.stats.sum_loss.to_bits(), gp.stats.sum_loss.to_bits());
    assert_eq!(gs.grads, gp.grads, "gradients must match bitwise across thread counts");

    let moments_s = seq.bn_moments(params.as_slice(), &hb).unwrap();
    let moments_p = par.bn_moments(params.as_slice(), &hb).unwrap();
    assert_eq!(moments_s, moments_p, "bn moments must match bitwise");

    let bn = BnState::from_moments(ParamLayout::of_bn(&m), &[moments_s]).unwrap();
    let es = seq.eval_batch(params.as_slice(), bn.as_slice(), &hb).unwrap();
    let ep = par.eval_batch(params.as_slice(), bn.as_slice(), &hb).unwrap();
    assert_eq!(es.sum_loss.to_bits(), ep.sum_loss.to_bits());
    assert_eq!(es.correct1, ep.correct1);

    let mut ps = params.clone();
    let mut ms = ps.zeros_like();
    let mut pp = params.clone();
    let mut mp = pp.zeros_like();
    seq.train_step(ps.as_mut_slice(), ms.as_mut_slice(), &hb, 0.05).unwrap();
    par.train_step(pp.as_mut_slice(), mp.as_mut_slice(), &hb, 0.05).unwrap();
    assert_eq!(ps, pp, "fused train step must match bitwise");
}
