//! Integration: the rust runtime executing the real AOT artifacts (tiny
//! preset). Requires `make artifacts` (the Makefile test target guarantees
//! this). These tests pin the python↔rust interface numerically:
//!   * grad/train/eval/bnstats run and return sane shapes/values,
//!   * the fused on-device SGD update equals the host-side optimizer,
//!   * training on a fixed batch reduces the loss through the whole stack.

use swap::coordinator::TrainEnv;
use swap::data::{AugmentSpec, Batcher, Generator, SynthSpec};
use swap::model::{BnState, ParamSet};
use swap::optim::{SgdConfig, SgdOptimizer};
use swap::runtime::{Engine, HostBatch};
use swap::sim::{CostModel, DeviceModel, NetModel};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .join("tiny")
}

fn engine() -> Engine {
    Engine::load(artifacts_dir()).expect("tiny artifacts missing — run `make artifacts`")
}

fn tiny_batch(engine: &Engine, seed: u64) -> HostBatch {
    let m = engine.manifest();
    let gen = Generator::new(SynthSpec::for_preset(
        m.model.num_classes,
        m.model.image_size,
        seed,
    ));
    let ds = gen.sample(8, 10);
    let mut b = Batcher::new(8, m.model.image_size, AugmentSpec::none());
    b.assemble_clean(&ds, &(0..8).collect::<Vec<_>>())
}

#[test]
fn manifest_loads_and_matches_model() {
    let e = engine();
    let m = e.manifest();
    assert_eq!(m.preset, "tiny");
    assert_eq!(m.model.arch, "resnet9s");
    assert_eq!(m.params.len(), 26);
    assert_eq!(m.bn_stats.len(), 16);
    assert!(m.batches.contains(&8));
}

#[test]
fn grad_executes_with_correct_shapes() {
    let e = engine();
    let params = ParamSet::init(e.manifest(), 0);
    let hb = tiny_batch(&e, 1);
    let g = e.grad(params.as_slice(), &hb).unwrap();
    assert_eq!(g.grads.len(), params.tensors.len());
    for (gt, pt) in g.grads.iter().zip(&params.tensors) {
        assert_eq!(gt.shape(), pt.shape());
    }
    assert!(g.stats.sum_loss.is_finite() && g.stats.sum_loss > 0.0);
    assert!(g.stats.correct1 >= 0 && g.stats.correct1 <= 8);
    assert!(g.stats.correct5 >= g.stats.correct1);
    // gradients are not all zero
    let total: f64 = g.grads.iter().map(|t| t.sq_norm()).sum();
    assert!(total > 0.0);
}

#[test]
fn fused_train_step_matches_host_optimizer() {
    let e = engine();
    let m = e.manifest();
    let params0 = ParamSet::init(m, 3);
    let hb = tiny_batch(&e, 2);
    let lr = 0.05f32;

    // host path: grads from grad_b8, then host Nesterov update
    let g = e.grad(params0.as_slice(), &hb).unwrap();
    let mut host_params = params0.clone();
    let mut opt = SgdOptimizer::new(
        SgdConfig { momentum: m.model.momentum, weight_decay: m.model.weight_decay },
        &host_params,
    );
    opt.step(&mut host_params, &g.grads, lr).unwrap();

    // device path: fused train_b8
    let mut dev_params = params0.clone();
    let mut dev_mom = params0.zeros_like();
    let stats = e
        .train_step(dev_params.as_mut_slice(), dev_mom.as_mut_slice(), &hb, lr)
        .unwrap();
    assert!((stats.sum_loss - g.stats.sum_loss).abs() < 1e-2 * g.stats.sum_loss.abs().max(1.0));

    // parity: parameters and momentum agree to f32 noise
    for ((hp, dp), name) in host_params
        .tensors
        .iter()
        .zip(&dev_params.tensors)
        .zip(m.params.iter().map(|s| &s.name))
    {
        let mut diff = hp.clone();
        diff.axpy(-1.0, dp).unwrap();
        let rel = diff.max_abs() / (1e-3 + hp.max_abs());
        assert!(rel < 2e-3, "param {name} host/device mismatch rel={rel}");
    }
    for (hm, dm) in opt.momentum.tensors.iter().zip(&dev_mom.tensors) {
        let mut diff = hm.clone();
        diff.axpy(-1.0, dm).unwrap();
        assert!(diff.max_abs() < 2e-3 + 1e-2 * hm.max_abs());
    }
}

#[test]
fn eval_and_bnstats_execute() {
    let e = engine();
    let m = e.manifest();
    let params = ParamSet::init(m, 5);
    let hb = tiny_batch(&e, 3);

    let bn = BnState::init(m);
    let stats = e.eval_batch(params.as_slice(), bn.as_slice(), &hb).unwrap();
    assert!(stats.sum_loss.is_finite());
    assert!(stats.correct1 <= 8 && stats.correct5 <= 8);

    let moments = e.bn_moments(params.as_slice(), &hb).unwrap();
    assert_eq!(moments.len(), m.bn_stats.len());
    // vars (odd positions) must be nonnegative
    for (i, t) in moments.iter().enumerate() {
        if i % 2 == 1 {
            assert!(t.data().iter().all(|&v| v >= -1e-6), "negative variance");
        }
    }
    // eval with the recomputed stats differs from eval with init stats
    let bn2 = BnState { tensors: moments };
    let stats2 = e.eval_batch(params.as_slice(), bn2.as_slice(), &hb).unwrap();
    assert!((stats2.sum_loss - stats.sum_loss).abs() > 1e-6);
}

#[test]
fn fused_training_reduces_loss_on_fixed_batch() {
    let e = engine();
    let mut params = ParamSet::init(e.manifest(), 7);
    let mut mom = params.zeros_like();
    let hb = tiny_batch(&e, 4);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..6 {
        let stats = e
            .train_step(params.as_mut_slice(), mom.as_mut_slice(), &hb, 0.08)
            .unwrap();
        last = stats.sum_loss;
        first.get_or_insert(stats.sum_loss);
    }
    assert!(
        last < first.unwrap(),
        "loss did not decrease: {} -> {last}",
        first.unwrap()
    );
}

#[test]
fn train_env_eval_and_bn_recompute() {
    let e = engine();
    let m = e.manifest().clone();
    let gen = Generator::new(SynthSpec::for_preset(m.model.num_classes, m.model.image_size, 11));
    let train = gen.sample(64, 10);
    let test = gen.sample(24, 11);
    let cost = CostModel::new(DeviceModel::v100_like(), NetModel::pcie_like(), &m);
    let env = TrainEnv {
        engine: &e,
        cost: &cost,
        train: &train,
        test: &test,
        augment: AugmentSpec::none(),
        exec_batch: 8,
        bn_batches: 2,
    };
    let params = ParamSet::init(&m, 1);
    let mut clock = swap::sim::ClusterClock::new();
    let bn = env.recompute_bn(&params, 1, &mut clock, true).unwrap();
    assert_eq!(bn.tensors.len(), m.bn_stats.len());
    assert!(clock.seconds > 0.0, "bn recompute must be charged");
    let stats = env.evaluate(&params, &bn, &mut clock).unwrap();
    assert_eq!(stats.examples, 24);
    assert!(clock.eval > 0.0);
}
