//! Flat weight-space arena: old-vs-new parity + round-trip properties.
//!
//! The flat-arena refactor (model::flat + tensor::flat) replaced the
//! per-tensor `Vec<Tensor>` hot paths with contiguous-arena kernels. These
//! tests pin the refactor bitwise against the retained legacy reference
//! implementations (`tensor::ops::sets_*`, `allreduce::ring_mean_reference`
//! and a literal transcription of the old per-tensor optimizer loop):
//! * flatten/unflatten round-trips over random layouts,
//! * the fused SGD step,
//! * the in-place ring all-reduce,
//! * phase-3 weight averaging,
//! * `Plane::point` / `Plane::project`.

use swap::coordinator::allreduce;
use swap::landscape::Plane;
use swap::model::{FlatParams, ParamLayout, ParamSet};
use swap::runtime::native::{native_manifest, NativeSpec};
use swap::runtime::TensorSpec;
use swap::tensor::{self, flat, Tensor};
use swap::testutil::{property, Gen};

/// Random layout of 1..6 tensors with random rank-0/1/2 shapes.
fn rand_specs(g: &mut Gen) -> Vec<TensorSpec> {
    let k = g.usize_in(1..6);
    (0..k)
        .map(|i| {
            let shape = match g.usize_in(0..3) {
                0 => vec![],
                1 => vec![g.usize_in(1..20)],
                _ => vec![g.usize_in(1..6), g.usize_in(1..6)],
            };
            TensorSpec { name: format!("t{i}"), shape }
        })
        .collect()
}

fn rand_tensors(g: &mut Gen, specs: &[TensorSpec]) -> Vec<Tensor> {
    specs
        .iter()
        .map(|s| {
            let n = s.numel();
            Tensor::new(s.shape.clone(), (0..n).map(|_| g.normal()).collect()).unwrap()
        })
        .collect()
}

fn flatten(tensors: &[Tensor]) -> Vec<f32> {
    let mut out = Vec::new();
    for t in tensors {
        out.extend_from_slice(t.data());
    }
    out
}

#[test]
fn prop_flatten_unflatten_roundtrip_random_layouts() {
    property(60, |g| {
        let specs = rand_specs(g);
        let layout = ParamLayout::from_specs(specs.clone());
        let tensors = rand_tensors(g, &specs);
        let fp = FlatParams::from_tensors(layout.clone(), &tensors).unwrap();
        // arena is the back-to-back manifest-order packing
        assert_eq!(fp.data(), flatten(&tensors).as_slice());
        // per-tensor views slice the arena exactly
        for (i, t) in tensors.iter().enumerate() {
            assert_eq!(fp.view(i), t.data());
            assert_eq!(&layout.spec(i).shape, &t.shape().to_vec());
        }
        // unflatten reproduces the originals bitwise
        assert_eq!(fp.to_tensors(), tensors);
        // raw-data round trip
        let data = fp.clone().into_data();
        let fp2 = FlatParams::from_data(layout, data).unwrap();
        assert_eq!(fp, fp2);
    });
}

#[test]
fn real_manifest_init_roundtrips() {
    let m = native_manifest(&NativeSpec::tiny());
    let p = ParamSet::init(&m, 42);
    assert_eq!(p.numel(), m.num_params);
    let tensors = p.to_tensors();
    assert_eq!(tensors.len(), m.params.len());
    let back = FlatParams::from_tensors(ParamLayout::of_params(&m), &tensors).unwrap();
    assert_eq!(p, back);
}

/// The pre-refactor optimizer: a literal transcription of the per-tensor
/// scalar loop `SgdOptimizer::step` used to run.
fn legacy_sgd_step(
    params: &mut [Tensor],
    momentum: &mut [Tensor],
    grads: &[Tensor],
    lr: f32,
    mu: f32,
    wd: f32,
) {
    for ((p, m), g) in params.iter_mut().zip(momentum.iter_mut()).zip(grads) {
        let (pd, md, gd) = (p.data_mut(), m.data_mut(), g.data());
        for i in 0..pd.len() {
            let g2 = gd[i] + wd * pd[i];
            let m2 = mu * md[i] + g2;
            pd[i] -= lr * (g2 + mu * m2);
            md[i] = m2;
        }
    }
}

#[test]
fn prop_sgd_step_flat_bitwise_matches_legacy() {
    property(40, |g| {
        let specs = rand_specs(g);
        let layout = ParamLayout::from_specs(specs.clone());
        let p0 = rand_tensors(g, &specs);
        let m0 = rand_tensors(g, &specs);
        let gr = rand_tensors(g, &specs);
        let (lr, mu, wd) = (g.f32_in(0.001..0.5), g.f32_in(0.0..0.99), g.f32_in(0.0..0.01));

        let mut lp = p0.clone();
        let mut lm = m0.clone();
        legacy_sgd_step(&mut lp, &mut lm, &gr, lr, mu, wd);

        let gflat = flatten(&gr);
        for threads in [1usize, 3] {
            let mut fp = FlatParams::from_tensors(layout.clone(), &p0).unwrap();
            let mut fm = FlatParams::from_tensors(layout.clone(), &m0).unwrap();
            flat::sgd_step(
                threads,
                fp.as_mut_slice(),
                fm.as_mut_slice(),
                &gflat,
                lr,
                mu,
                wd,
            );
            assert_eq!(fp.data(), flatten(&lp).as_slice(), "params, threads={threads}");
            assert_eq!(fm.data(), flatten(&lm).as_slice(), "momentum, threads={threads}");
        }
    });
}

#[test]
fn prop_ring_flat_bitwise_matches_legacy() {
    property(40, |g| {
        let w = g.usize_in(2..9);
        let specs = rand_specs(g);
        let tensor_sets: Vec<Vec<Tensor>> =
            (0..w).map(|_| rand_tensors(g, &specs)).collect();
        let reference = allreduce::ring_mean_reference(&tensor_sets).unwrap();
        let mut flat_sets: Vec<Vec<f32>> = tensor_sets.iter().map(|s| flatten(s)).collect();
        allreduce::ring_mean_inplace(&mut flat_sets).unwrap();
        assert_eq!(flat_sets[0], flatten(&reference), "W={w}");
    });
}

#[test]
fn prop_average_flat_bitwise_matches_legacy() {
    property(40, |g| {
        let w = g.usize_in(1..9);
        let specs = rand_specs(g);
        let layout = ParamLayout::from_specs(specs.clone());
        let tensor_sets: Vec<Vec<Tensor>> =
            (0..w).map(|_| rand_tensors(g, &specs)).collect();
        // legacy phase 3: per-tensor clone-accumulate-scale
        let legacy = tensor::average_sets(&tensor_sets).unwrap();
        let flat_sets: Vec<FlatParams> = tensor_sets
            .iter()
            .map(|s| FlatParams::from_tensors(layout.clone(), s).unwrap())
            .collect();
        for threads in [1usize, 4] {
            let avg = FlatParams::average_mt(&flat_sets, threads).unwrap();
            assert_eq!(
                avg.data(),
                flatten(&legacy).as_slice(),
                "W={w}, threads={threads}"
            );
        }
    });
}

/// The pre-refactor plane math, transcribed over the retained legacy
/// `tensor::ops::sets_*` reference functions.
struct LegacyPlane {
    origin: Vec<Tensor>,
    u: Vec<Tensor>,
    v: Vec<Tensor>,
    anchors: [(f64, f64); 3],
}

fn legacy_plane(t1: &[Tensor], t2: &[Tensor], t3: &[Tensor]) -> Option<LegacyPlane> {
    let d2 = tensor::sets_sub(t2, t1).unwrap();
    let d3 = tensor::sets_sub(t3, t1).unwrap();
    let n2 = tensor::sets_norm(&d2);
    if n2 == 0.0 {
        return None;
    }
    let mut u = d2;
    tensor::sets_scale(&mut u, (1.0 / n2) as f32);
    let a3 = tensor::sets_dot(&d3, &u).unwrap();
    let n3 = tensor::sets_norm(&d3);
    let mut v = d3;
    tensor::sets_axpy(&mut v, -a3 as f32, &u).unwrap();
    let nv = tensor::sets_norm(&v);
    if nv < 1e-5 * n3.max(1e-12) {
        return None;
    }
    tensor::sets_scale(&mut v, (1.0 / nv) as f32);
    Some(LegacyPlane {
        origin: t1.to_vec(),
        u,
        v,
        anchors: [(0.0, 0.0), (n2, 0.0), (a3, nv)],
    })
}

impl LegacyPlane {
    fn point(&self, alpha: f64, beta: f64) -> Vec<Tensor> {
        let mut t = self.origin.clone();
        tensor::sets_axpy(&mut t, alpha as f32, &self.u).unwrap();
        tensor::sets_axpy(&mut t, beta as f32, &self.v).unwrap();
        t
    }

    fn project(&self, theta: &[Tensor]) -> (f64, f64) {
        let d = tensor::sets_sub(theta, &self.origin).unwrap();
        (
            tensor::sets_dot(&d, &self.u).unwrap(),
            tensor::sets_dot(&d, &self.v).unwrap(),
        )
    }
}

#[test]
fn prop_plane_point_project_bitwise_matches_legacy() {
    property(40, |g| {
        let specs = rand_specs(g);
        let layout = ParamLayout::from_specs(specs.clone());
        let t1 = rand_tensors(g, &specs);
        let t2 = rand_tensors(g, &specs);
        let t3 = rand_tensors(g, &specs);
        let legacy = match legacy_plane(&t1, &t2, &t3) {
            Some(p) => p,
            None => return, // degenerate draw
        };
        let f1 = FlatParams::from_tensors(layout.clone(), &t1).unwrap();
        let f2 = FlatParams::from_tensors(layout.clone(), &t2).unwrap();
        let f3 = FlatParams::from_tensors(layout.clone(), &t3).unwrap();
        let plane = Plane::through(&f1, &f2, &f3).unwrap();

        // identical basis and anchor coordinates, bitwise
        assert_eq!(plane.u.data(), flatten(&legacy.u).as_slice());
        assert_eq!(plane.v.data(), flatten(&legacy.v).as_slice());
        for (a, b) in plane.anchors.iter().zip(&legacy.anchors) {
            assert_eq!(a.0.to_bits(), b.0.to_bits());
            assert_eq!(a.1.to_bits(), b.1.to_bits());
        }

        // point + project agree bitwise, sequential and chunk-parallel
        let (alpha, beta) = (g.f64_in(-2.0..2.0), g.f64_in(-2.0..2.0));
        let legacy_pt = flatten(&legacy.point(alpha, beta));
        let legacy_proj = legacy.project(&t3);
        for threads in [1usize, 3] {
            let pt = plane.point_mt(alpha, beta, threads).unwrap();
            assert_eq!(pt.data(), legacy_pt.as_slice(), "threads={threads}");
            let proj = plane.project_mt(&f3, threads).unwrap();
            assert_eq!(proj.0.to_bits(), legacy_proj.0.to_bits());
            assert_eq!(proj.1.to_bits(), legacy_proj.1.to_bits());
        }
    });
}

#[test]
fn checkpoint_flat_roundtrip_via_real_manifest() {
    // save_params/load_params stream the arena contiguously; the loaded
    // vector must be bitwise identical and share the manifest layout
    let m = native_manifest(&NativeSpec::tiny());
    let p = ParamSet::init(&m, 7);
    let dir = std::env::temp_dir().join("swap-weightspace-tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("flat-{}.ckpt", std::process::id()));
    swap::model::save_params(&path, &m, &p).unwrap();
    let loaded = swap::model::load_params(&path, &m).unwrap();
    assert_eq!(p, loaded);
    std::fs::remove_file(&path).ok();
}
