//! The AveragingPolicy refactor's bitwise-parity suite.
//!
//! The phase-3 / SWA averaging core moved from a hard-coded terminal
//! `ParamSet::average_mt` into the pluggable streaming policies of
//! `coordinator::averaging`. This file pins the refactor's contract:
//!   * Uniform (the default) is BITWISE-identical to the legacy terminal
//!     mean through every coordinator — SWAP phase 3, SWA cycles, and
//!     local-SGD consensus — at threads 1 and 4,
//!   * every policy's output is thread-count invariant bit for bit,
//!   * the swa/hierarchical/adaptive policies match hand-computed
//!     references through the real coordinators (not just unit vectors),
//!   * the cyclic-SWA step alignment holds on train sets whose size does
//!     NOT divide the global batch (the steps_per_epoch unification),
//!   * resumable runs persist the policy state in run.meta.json and a
//!     run directory refuses to resume under a different policy.

use swap::coordinator::{
    run_local_sgd, run_swa, run_swap, run_swap_resumable, AveragingSpec, LocalSgdConfig, RunDir,
    StreamingMean, SwaConfig, SwapConfig, TrainEnv,
};
use swap::data::{AugmentSpec, Dataset, Generator, SynthSpec};
use swap::model::ParamSet;
use swap::optim::Schedule;
use swap::runtime::{Backend, NativeBackend};
use swap::sim::{ClusterClock, CostModel, DeviceModel, NetModel};

struct Fixture {
    engine: NativeBackend,
    cost: CostModel,
    train: Dataset,
    test: Dataset,
    val: Option<Dataset>,
}

fn fixture_n(n_train: usize, val_examples: usize) -> Fixture {
    let engine = NativeBackend::tiny();
    let m = engine.manifest().clone();
    let gen = Generator::new(SynthSpec::for_preset(m.model.num_classes, m.model.image_size, 99));
    let train = gen.sample(n_train, 10);
    let test = gen.sample(32, 11);
    let val = (val_examples > 0).then(|| gen.sample(val_examples, 12));
    let cost = CostModel::new(DeviceModel::v100_like(), NetModel::pcie_like(), &m);
    Fixture { engine, cost, train, test, val }
}

fn fixture() -> Fixture {
    fixture_n(96, 0)
}

fn env_threads(f: &Fixture, threads: usize) -> TrainEnv<'_> {
    TrainEnv {
        engine: &f.engine,
        cost: &f.cost,
        train: &f.train,
        test: &f.test,
        val: f.val.as_ref(),
        augment: AugmentSpec::none(),
        exec_batch: 8,
        bn_batches: 2,
        threads,
        prefetch: false,
    }
}

fn swap_cfg(seed: u64, workers: usize, averaging: AveragingSpec) -> SwapConfig {
    SwapConfig {
        workers,
        group_devices: 1,
        phase1_max_epochs: 2,
        phase1_stop_acc: 1.1,
        phase1_sched: Schedule::Constant(0.08),
        phase2_epochs: 2,
        phase2_sched: Schedule::Constant(0.02),
        seed,
        averaging,
        snapshot_every: None,
        phase1_snapshot_every: None,
        phase1_dist: false,
        phase1_record_every: 1,
    }
}

fn swa_cfg(seed: u64, cycles: usize, averaging: AveragingSpec) -> SwaConfig {
    SwaConfig {
        devices: 1,
        cycles,
        cycle_epochs: 1,
        high_lr: 0.05,
        low_lr: 0.005,
        seed,
        seed_stream: 0,
        averaging,
        keep_samples: true,
    }
}

fn state_str(state: &swap::util::Json) -> String {
    state.to_string_pretty()
}

#[test]
fn streaming_mean_bitwise_matches_terminal_mean_on_model_arenas() {
    // the kernel-level pin on real model-shaped arenas (26 tensors), not
    // just hand-rolled unit vectors: ((s0+s1)+s2+...)*(1/n) streamed ==
    // flat::mean_into, at threads 1 and 4
    let f = fixture();
    let m = f.engine.manifest();
    let sets: Vec<ParamSet> = (0..5).map(|w| ParamSet::init(m, w as u64)).collect();
    let legacy = ParamSet::average_mt(&sets, 1).unwrap();
    for threads in [1usize, 4] {
        let mut mean = StreamingMean::new();
        for s in &sets {
            mean.push(s, threads).unwrap();
        }
        assert_eq!(
            mean.mean(threads).unwrap(),
            legacy,
            "threads={threads}: streamed mean must equal the terminal mean bitwise"
        );
    }
    // and the multi-threaded terminal mean itself is thread-invariant
    assert_eq!(ParamSet::average_mt(&sets, 4).unwrap(), legacy);
}

#[test]
fn swap_uniform_final_params_bitwise_equal_legacy_average() {
    // THE refactor acceptance criterion: a default (Uniform) SWAP run's
    // averaged model is bitwise what the pre-refactor hard-coded
    // `ParamSet::average_mt(&worker_params, threads)` produced — at
    // threads 1 and at threads 4
    let f = fixture();
    for threads in [1usize, 4] {
        let env = env_threads(&f, threads);
        let r = run_swap(&env, &swap_cfg(17, 3, AveragingSpec::Uniform)).unwrap();
        assert_eq!(r.worker_params.len(), 3);
        let legacy = ParamSet::average_mt(&r.worker_params, threads).unwrap();
        assert_eq!(
            r.final_params, legacy,
            "threads={threads}: uniform policy must be bitwise the legacy mean"
        );
        let st = r.averaging_state;
        assert_eq!(st.get("policy").and_then(|v| v.as_str()), Some("uniform"));
        assert_eq!(st.get("contributing").and_then(|v| v.as_usize()), Some(3));
    }
}

#[test]
fn every_policy_is_thread_invariant_through_swap() {
    let f = fixture();
    for spec in [
        AveragingSpec::Uniform,
        AveragingSpec::Swa,
        AveragingSpec::Hierarchical { groups: 2 },
    ] {
        let a = run_swap(&env_threads(&f, 1), &swap_cfg(23, 4, spec.clone())).unwrap();
        let b = run_swap(&env_threads(&f, 4), &swap_cfg(23, 4, spec.clone())).unwrap();
        assert_eq!(
            a.final_params,
            b.final_params,
            "{}: threads=4 must equal threads=1 bitwise",
            spec.id()
        );
        assert_eq!(
            a.final_stats.sum_loss.to_bits(),
            b.final_stats.sum_loss.to_bits()
        );
        assert_eq!(state_str(&a.averaging_state), state_str(&b.averaging_state));
    }
}

#[test]
fn swa_policy_through_swap_matches_incremental_recurrence() {
    // the Swa policy applies Izmailov's avg <- (avg*n + x)/(n+1) to the
    // workers in id order; replay the recurrence on the returned replicas
    let f = fixture();
    let env = env_threads(&f, 1);
    let r = run_swap(&env, &swap_cfg(29, 3, AveragingSpec::Swa)).unwrap();
    let mut want = r.worker_params[0].clone();
    for (n, wp) in r.worker_params[1..].iter().enumerate() {
        want.scale((n + 1) as f32, 1);
        want.add_assign_mt(wp, 1).unwrap();
        want.scale(1.0 / (n + 2) as f32, 1);
    }
    assert_eq!(r.final_params, want, "swa recurrence replay must match bitwise");
    assert_eq!(
        r.averaging_state.get("policy").and_then(|v| v.as_str()),
        Some("swa")
    );
}

#[test]
fn hierarchical_through_swap_matches_manual_group_means() {
    // groups=2 routes worker ids round-robin: group 0 = {w0, w2},
    // group 1 = {w1, w3}; final = mean(mean(g0), mean(g1))
    let f = fixture();
    let env = env_threads(&f, 1);
    let spec = AveragingSpec::Hierarchical { groups: 2 };
    let r = run_swap(&env, &swap_cfg(31, 4, spec)).unwrap();
    let w = &r.worker_params;
    let g0 = ParamSet::average_mt(&[w[0].clone(), w[2].clone()], 1).unwrap();
    let g1 = ParamSet::average_mt(&[w[1].clone(), w[3].clone()], 1).unwrap();
    let want = ParamSet::average_mt(&[g0, g1], 1).unwrap();
    assert_eq!(r.final_params, want, "grouped means must match bitwise");
    match r.averaging_state.get("group_counts") {
        Some(swap::util::Json::Arr(counts)) => {
            let counts: Vec<_> = counts.iter().map(|c| c.as_usize()).collect();
            assert_eq!(counts, vec![Some(2), Some(2)]);
        }
        other => panic!("group_counts must be an array, got {other:?}"),
    }

    // groups=1 degenerates to Uniform, bitwise (the across-group mean over
    // one set multiplies by 1.0, which is IEEE-exact)
    let one = run_swap(&env, &swap_cfg(31, 4, AveragingSpec::Hierarchical { groups: 1 })).unwrap();
    let uni = run_swap(&env, &swap_cfg(31, 4, AveragingSpec::Uniform)).unwrap();
    assert_eq!(one.final_params, uni.final_params, "groups=1 must be bitwise uniform");
}

#[test]
fn swa_uniform_averaged_bitwise_equals_mean_of_samples() {
    let f = fixture();
    for threads in [1usize, 4] {
        let env = env_threads(&f, threads);
        let mut params = ParamSet::init(f.engine.manifest(), 8);
        let mut clock = ClusterClock::new();
        let r = run_swa(&env, &mut params, &swa_cfg(8, 3, AveragingSpec::Uniform), &mut clock)
            .unwrap();
        assert_eq!(r.samples.len(), 3, "keep_samples must retain the trail");
        let legacy = ParamSet::average_mt(&r.samples, threads).unwrap();
        assert_eq!(
            r.averaged, legacy,
            "threads={threads}: streamed SWA average must equal the terminal mean"
        );
    }
}

#[test]
fn local_sgd_uniform_consensus_is_thread_invariant() {
    // the every-H sync and the final model now go through
    // averaging::consensus — with Uniform that is the legacy mean, and the
    // whole run stays bitwise across thread counts
    let f = fixture();
    let cfg = |averaging: AveragingSpec| LocalSgdConfig {
        devices: 2,
        sync_epochs: 1,
        sync_sched: Schedule::Constant(0.08),
        local_epochs: 1,
        local_sched: Schedule::Constant(0.02),
        h_steps: 4,
        seed: 12,
        averaging,
    };
    let a = run_local_sgd(&env_threads(&f, 1), &cfg(AveragingSpec::Uniform)).unwrap();
    let b = run_local_sgd(&env_threads(&f, 4), &cfg(AveragingSpec::Uniform)).unwrap();
    assert_eq!(a.params, b.params, "uniform consensus must be bitwise thread-invariant");
    assert_eq!(a.sync_events, b.sync_events);

    // validation-gated policies cannot drive a consensus round
    let spec = AveragingSpec::Adaptive { window: 2, min_improve: 0.0 };
    let err = run_local_sgd(&env_threads(&f, 1), &cfg(spec)).unwrap_err().to_string();
    assert!(err.contains("consensus"), "{err}");
}

#[test]
fn swa_cycles_align_on_non_divisible_train_set() {
    // regression (steps_per_epoch unification): n_train = 100 does not
    // divide the B=8 global batch — 12 steps/epoch with 4 examples
    // dropped. The cyclic period and the trainer's epoch length must come
    // from the same definition or run_swa's alignment check trips.
    let f = fixture_n(100, 0);
    let env = env_threads(&f, 2);
    let mut params = ParamSet::init(f.engine.manifest(), 5);
    let mut clock = ClusterClock::new();
    let r = run_swa(&env, &mut params, &swa_cfg(5, 2, AveragingSpec::Uniform), &mut clock)
        .expect("non-divisible n must not break cycle alignment");
    assert_eq!(r.samples.len(), 2);
    assert_eq!(
        r.averaging_state.get("contributing").and_then(|v| v.as_usize()),
        Some(2)
    );
    // and through SWAP on the same ragged train set
    let s = run_swap(&env, &swap_cfg(5, 2, AveragingSpec::Uniform)).unwrap();
    assert_eq!(s.worker_params.len(), 2);
    let legacy = ParamSet::average_mt(&s.worker_params, 2).unwrap();
    assert_eq!(s.final_params, legacy);
}

#[test]
fn adaptive_through_swa_gates_and_windows_on_validation() {
    // min_improve = 1.0 can never be beaten (accuracies live in [0, 1]),
    // so the gate deterministically opens at the SECOND cycle: candidate 0
    // seeds the running best, candidate 1 plateaus and starts the window.
    // With window = 2 over 4 cycles the window holds samples {2, 3}.
    let f = fixture_n(96, 24);
    let env = env_threads(&f, 2);
    let mut params = ParamSet::init(f.engine.manifest(), 9);
    let mut clock = ClusterClock::new();
    let spec = AveragingSpec::Adaptive { window: 2, min_improve: 1.0 };
    let r = run_swa(&env, &mut params, &swa_cfg(9, 4, spec), &mut clock).unwrap();
    let st = &r.averaging_state;
    assert_eq!(st.get("policy").and_then(|v| v.as_str()), Some("adaptive"));
    assert_eq!(st.get("started").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(st.get("opened_at").and_then(|v| v.as_usize()), Some(1));
    assert_eq!(st.get("observed").and_then(|v| v.as_usize()), Some(4));
    assert_eq!(st.get("contributing").and_then(|v| v.as_usize()), Some(2));
    let want =
        ParamSet::average_mt(&[r.samples[2].clone(), r.samples[3].clone()], 2).unwrap();
    assert_eq!(r.averaged, want, "late window must average the last two samples");
    // validation forward passes are booked as (uncharged-training) eval time
    assert!(clock.eval > 0.0);
}

#[test]
fn adaptive_without_validation_split_errors() {
    // env.val = None: the candidate arrives unscored and the policy raises
    // the actionable config error instead of silently degrading
    let f = fixture();
    let env = env_threads(&f, 1);
    let mut params = ParamSet::init(f.engine.manifest(), 3);
    let mut clock = ClusterClock::new();
    let spec = AveragingSpec::Adaptive { window: 2, min_improve: 0.0 };
    let err = run_swa(&env, &mut params, &swa_cfg(3, 2, spec), &mut clock)
        .unwrap_err()
        .to_string();
    assert!(err.contains("val_examples"), "{err}");
}

#[test]
fn resumable_swap_persists_policy_state_and_pins_the_policy() {
    let f = fixture();
    let env = env_threads(&f, 2);
    let cfg = swap_cfg(41, 2, AveragingSpec::Uniform);
    let dir_path =
        std::env::temp_dir().join(format!("swap-avgpolicy-{}", std::process::id()));
    std::fs::remove_dir_all(&dir_path).ok();
    let dir = RunDir::new(&dir_path).unwrap();

    let fresh = run_swap(&env, &cfg).unwrap();
    let a = run_swap_resumable(&env, &cfg, &dir).unwrap();
    assert_eq!(a.final_params, fresh.final_params);

    // the policy's scalar state landed in run.meta.json
    let st = dir.load_averaging_state().unwrap().expect("state must be persisted");
    assert_eq!(st.get("policy").and_then(|v| v.as_str()), Some("uniform"));
    assert_eq!(st.get("contributing").and_then(|v| v.as_usize()), Some(2));
    assert_eq!(state_str(&st), state_str(&a.averaging_state));

    // a resume of the SAME directory recomputes the identical state from
    // the checkpointed replicas
    let b = run_swap_resumable(&env, &cfg, &dir).unwrap();
    assert_eq!(b.final_params, fresh.final_params);
    assert_eq!(state_str(&b.averaging_state), state_str(&a.averaging_state));

    // ... but resuming under a DIFFERENT averaging policy hard-errors: the
    // policy id joins the run fingerprint
    let mut other = cfg.clone();
    other.averaging = AveragingSpec::Swa;
    let err = run_swap_resumable(&env, &other, &dir).unwrap_err().to_string();
    assert!(
        err.contains("different configuration"),
        "changing the averaging policy must trip the fingerprint check: {err}"
    );
    std::fs::remove_dir_all(&dir_path).ok();
}
