//! Substrate utilities: typed errors, JSON (no serde), deterministic RNG,
//! runtime SIMD dispatch, and a tiny stderr logger. Everything else in
//! the crate builds on these.

pub mod error;
pub mod json;
pub mod logger;
pub mod rng;
pub mod simd;

pub use error::{Error, Result};
pub use json::Json;
pub use rng::Rng;
