//! Substrate utilities: typed errors, JSON (no serde), deterministic RNG,
//! and a tiny stderr logger. Everything else in the crate builds on these.

pub mod error;
pub mod json;
pub mod logger;
pub mod rng;

pub use error::{Error, Result};
pub use json::Json;
pub use rng::Rng;
