//! Tiny leveled stderr logger. `SWAP_LOG=debug|info|warn|quiet` (default
//! info). No global state beyond one atomic — safe from worker threads.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(2); // 0 quiet, 1 warn, 2 info, 3 debug
static INIT: std::sync::Once = std::sync::Once::new();
static mut START: Option<Instant> = None;

fn init() {
    INIT.call_once(|| {
        let lvl = match std::env::var("SWAP_LOG").as_deref() {
            Ok("quiet") => 0,
            Ok("warn") => 1,
            Ok("debug") => 3,
            _ => 2,
        };
        LEVEL.store(lvl, Ordering::Relaxed);
        unsafe { START = Some(Instant::now()) };
    });
}

fn elapsed() -> f64 {
    unsafe {
        #[allow(static_mut_refs)]
        START.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0)
    }
}

pub fn enabled(level: u8) -> bool {
    init();
    LEVEL.load(Ordering::Relaxed) >= level
}

pub fn log(level: u8, tag: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{:9.3}s {tag}] {msg}", elapsed());
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logger::log(2, "info", format_args!($($t)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::logger::log(1, "warn", format_args!($($t)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logger::log(3, "debug", format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    #[test]
    fn log_does_not_panic() {
        crate::info!("hello {}", 1);
        crate::warn_!("warn {}", 2);
        crate::debug!("debug {}", 3);
        assert!(super::elapsed() >= 0.0);
    }
}
