//! Library error type, shared by the library, the binary and the examples
//! so downstream users can match on failure classes.

use std::fmt;

/// Errors produced by the swap-train library.
#[derive(Debug)]
pub enum Error {
    /// Filesystem / IO failure.
    Io(std::io::Error),
    /// XLA / PJRT failure (compile, execute, literal conversion).
    Xla(String),
    /// JSON parse or schema error (manifest, config, metrics).
    Json(String),
    /// Configuration error (unknown preset, invalid value, bad CLI flag).
    Config(String),
    /// Shape mismatch between host tensors / manifest / literals.
    Shape(String),
    /// Transient saturation: the service shed this request instead of
    /// queueing it without bound. Safe to retry after backing off.
    Overloaded(String),
    /// Anything else that indicates a bug or broken invariant.
    Invalid(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Overloaded(m) => write!(f, "overloaded: {m}"),
            Error::Invalid(m) => write!(f, "invalid: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Shorthand constructors used throughout the crate.
impl Error {
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    pub fn invalid(msg: impl Into<String>) -> Self {
        Error::Invalid(msg.into())
    }
    pub fn json(msg: impl Into<String>) -> Self {
        Error::Json(msg.into())
    }
    pub fn overloaded(msg: impl Into<String>) -> Self {
        Error::Overloaded(msg.into())
    }
    /// Whether this failure is transient saturation (shed load) — the
    /// retry-after-backoff class, distinct from every hard error.
    pub fn is_overloaded(&self) -> bool {
        matches!(self, Error::Overloaded(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_class_and_message() {
        let e = Error::config("bad preset");
        assert_eq!(e.to_string(), "config error: bad preset");
        let e = Error::shape("want [2,2] got [4]");
        assert!(e.to_string().contains("want [2,2]"));
        let e = Error::overloaded("queue full");
        assert_eq!(e.to_string(), "overloaded: queue full");
        assert!(e.is_overloaded());
        assert!(!Error::invalid("x").is_overloaded());
    }

    #[test]
    fn io_error_converts_and_sources() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "x");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
