//! Runtime SIMD dispatch for the explicit micro-kernels: one detected
//! **tier** per process, selected once and read by every hot kernel
//! (the blocked GEMM family, the flat-arena elementwise kernels, the BN
//! normalize/backward loops).
//!
//! Tiers: `scalar` (the always-available portable kernels), `avx2`
//! (x86_64, 8-lane f32), `neon` (aarch64, 4-lane f32). Every tier is
//! **bitwise identical**: the vector kernels assign whole output elements
//! to lanes (never splitting an accumulation chain) and use separate
//! multiply + add instructions — two roundings, exactly the scalar op
//! sequence — never fused multiply-add, whose single rounding would
//! diverge. `rust/tests/gemm_oracle.rs` and the in-module kernel tests
//! pin SIMD == scalar == reference per tier.
//!
//! Selection precedence: the `SWAP_SIMD` env var (CI's forced-scalar
//! lane) > the `simd` config knob (installed via [`set_active`] when a
//! backend loads) > runtime feature detection ([`detect`]). Requesting a
//! tier the CPU lacks fails loudly — silently running AVX2 code on a
//! non-AVX2 host would be an illegal-instruction crash mid-training.

use std::sync::atomic::{AtomicU8, Ordering};

use super::{Error, Result};

/// One SIMD dispatch tier. Kernels match on this; unavailable arms fall
/// back to the scalar kernel defensively.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Portable scalar kernels — always available, the parity oracle.
    Scalar,
    /// x86_64 AVX2: 8-lane f32 vectors (one full `NR`-wide GEMM strip).
    Avx2,
    /// aarch64 NEON: 4-lane f32 vectors (half a GEMM strip per register).
    Neon,
}

/// Knob vocabulary, for help/error text.
pub const TIER_NAMES: &str = "auto|scalar|avx2|neon";

impl Tier {
    pub fn name(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Avx2 => "avx2",
            Tier::Neon => "neon",
        }
    }

    /// Whether this CPU can execute the tier's kernels.
    pub fn available(self) -> bool {
        match self {
            Tier::Scalar => true,
            Tier::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            Tier::Neon => {
                #[cfg(target_arch = "aarch64")]
                {
                    std::arch::is_aarch64_feature_detected!("neon")
                }
                #[cfg(not(target_arch = "aarch64"))]
                {
                    false
                }
            }
        }
    }

    fn from_name(name: &str) -> Result<Tier> {
        match name {
            "scalar" => Ok(Tier::Scalar),
            "avx2" => Ok(Tier::Avx2),
            "neon" => Ok(Tier::Neon),
            other => Err(Error::config(format!(
                "unknown simd tier '{other}' (expected {TIER_NAMES})"
            ))),
        }
    }
}

/// The best tier this CPU supports: avx2 on x86_64, neon on aarch64,
/// else scalar.
pub fn detect() -> Tier {
    for t in [Tier::Avx2, Tier::Neon] {
        if t.available() {
            return t;
        }
    }
    Tier::Scalar
}

/// Every tier the current CPU can run — what the per-tier parity tests
/// and benches iterate over (always contains at least `Scalar`).
pub fn tiers_available() -> Vec<Tier> {
    [Tier::Scalar, Tier::Avx2, Tier::Neon]
        .into_iter()
        .filter(|t| t.available())
        .collect()
}

/// Resolve a `simd` knob value to a concrete tier: the `SWAP_SIMD` env
/// var wins (so CI's forced-scalar lane overrides any config), then the
/// knob; "auto" (or empty) means [`detect`]. A named tier the CPU lacks
/// is a loud error, never a silent fallback.
pub fn resolve(knob: &str) -> Result<Tier> {
    let name = match std::env::var("SWAP_SIMD") {
        Ok(v) => v,
        Err(_) => knob.to_string(),
    };
    let name = name.trim().to_ascii_lowercase();
    if name.is_empty() || name == "auto" {
        return Ok(detect());
    }
    let tier = Tier::from_name(&name)?;
    if !tier.available() {
        return Err(Error::config(format!(
            "simd tier '{}' is not available on this cpu (arch {}); use 'auto'",
            tier.name(),
            std::env::consts::ARCH
        )));
    }
    Ok(tier)
}

// Process-wide active tier: 0 = not yet resolved, else encode(tier).
// Relaxed ordering suffices — the value is write-once in practice and
// every resolution path (lazy or explicit) computes the same tier for
// the same env/knob, so racing initializations agree.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

fn encode(t: Tier) -> u8 {
    match t {
        Tier::Scalar => 1,
        Tier::Avx2 => 2,
        Tier::Neon => 3,
    }
}

fn decode(v: u8) -> Tier {
    match v {
        2 => Tier::Avx2,
        3 => Tier::Neon,
        _ => Tier::Scalar,
    }
}

/// The tier the kernels dispatch on. First use resolves "auto" (honoring
/// `SWAP_SIMD`) and caches the answer; a malformed `SWAP_SIMD` panics
/// here rather than silently running a different kernel than asked for.
pub fn active() -> Tier {
    match ACTIVE.load(Ordering::Relaxed) {
        0 => {
            let t = resolve("auto").unwrap_or_else(|e| panic!("{e}"));
            ACTIVE.store(encode(t), Ordering::Relaxed);
            t
        }
        v => decode(v),
    }
}

/// Install the resolved tier for the process (the config/CLI path —
/// called by `ExperimentConfig::load_backend`). `SWAP_SIMD` still wins
/// inside [`resolve`]. Returns the tier that became active.
pub fn set_active(knob: &str) -> Result<Tier> {
    let t = resolve(knob)?;
    ACTIVE.store(encode(t), Ordering::Relaxed);
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_always_available() {
        assert!(Tier::Scalar.available());
        let tiers = tiers_available();
        assert!(tiers.contains(&Tier::Scalar));
        assert!(tiers.contains(&detect()));
    }

    #[test]
    fn detect_is_available_and_named() {
        let t = detect();
        assert!(t.available());
        assert!(["scalar", "avx2", "neon"].contains(&t.name()));
    }

    #[test]
    fn resolve_knob_values() {
        // the env override (if CI set one) must itself resolve cleanly
        match std::env::var("SWAP_SIMD") {
            Ok(_) => {
                let forced = resolve("auto").unwrap();
                // with the env set, every knob resolves to the same tier
                assert_eq!(resolve("scalar").unwrap(), forced);
            }
            Err(_) => {
                assert_eq!(resolve("auto").unwrap(), detect());
                assert_eq!(resolve("").unwrap(), detect());
                assert_eq!(resolve(" Scalar ").unwrap(), Tier::Scalar);
                assert!(resolve("sse9").is_err());
                // a tier for a foreign arch is rejected, not crashed on
                if !Tier::Neon.available() {
                    assert!(resolve("neon").is_err());
                }
                if !Tier::Avx2.available() {
                    assert!(resolve("avx2").is_err());
                }
            }
        }
    }

    #[test]
    fn active_is_stable_and_available() {
        let t = active();
        assert!(t.available());
        assert_eq!(active(), t, "active tier is cached");
    }
}
