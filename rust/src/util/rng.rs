//! Deterministic RNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Everything stochastic in the coordinator (data shuffling, synthetic
//! dataset generation, augmentation, worker seeds) flows through this
//! module, so a run is reproducible from a single root seed. Worker w in
//! phase 2 gets `Rng::stream(seed, w)` — SWAP requires each worker to see a
//! *different* randomization of the data (Algorithm 1, line 22).

/// xoshiro256** PRNG (public-domain reference algorithm by Blackman/Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller sample
    spare_normal: Option<f32>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded generator. Any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Independent stream `id` of a root seed (worker seeds, dataset splits).
    pub fn stream(seed: u64, id: u64) -> Self {
        // feed both through splitmix so nearby ids decorrelate
        let mut sm = seed ^ 0xA076_1D64_78BD_642F_u64.wrapping_mul(id.wrapping_add(1));
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Stateless counter-based derivation: the generator for coordinate
    /// `(stream, step, row)` of a root seed. Unlike [`Rng::stream`], which
    /// hands out one *sequential* generator that must then be consumed in a
    /// fixed order, `counter` is a pure function of its four arguments —
    /// deriving the generator for any (step, row) requires no other draws.
    /// Work keyed this way (per-example data augmentation) can therefore be
    /// computed by any thread, in any order, with bitwise-identical
    /// results.
    pub fn counter(seed: u64, stream: u64, step: u64, row: u64) -> Self {
        // absorb each coordinate through a full splitmix round, with a
        // distinct odd salt per coordinate so permuting coordinate values
        // cannot alias (and v = 0 still contributes its position)
        let mut h = seed;
        for (v, salt) in [
            (stream, 0xA076_1D64_78BD_642F_u64),
            (step, 0xE703_7ED1_A0B4_28DB_u64),
            (row, 0x8EBC_6AF0_9C88_C6E3_u64),
        ] {
            let mut sm = h ^ v.wrapping_mul(salt).wrapping_add(salt);
            h = splitmix64(&mut sm);
        }
        let mut sm = h;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased enough for data
    /// shuffling; n must be > 0).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_f64() * n as f64) as usize % n
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f32 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.next_f32();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f32();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// N(mu, sigma^2)
    pub fn normal_scaled(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.normal()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A shuffled index permutation [0, n).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx
    }

    /// Bernoulli(p).
    pub fn coin(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn streams_decorrelate() {
        let mut xs = std::collections::HashSet::new();
        for id in 0..64 {
            let mut r = Rng::stream(7, id);
            assert!(xs.insert(r.next_u64()), "stream {id} collided");
        }
    }

    #[test]
    fn counter_is_a_pure_function() {
        // same coordinates -> bitwise-identical draw sequences, no matter
        // how many other counters were derived in between
        let mut a = Rng::counter(7, 1, 5, 3);
        let _ = Rng::counter(7, 1, 5, 4).next_u64();
        let _ = Rng::counter(9, 0, 0, 0).next_u64();
        let mut b = Rng::counter(7, 1, 5, 3);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn counter_coordinates_decorrelate() {
        // every distinct (stream, step, row) must yield a distinct first
        // draw — including permutations of the same coordinate values
        let mut seen = std::collections::HashSet::new();
        for stream in 0..4u64 {
            for step in 0..8u64 {
                for row in 0..8u64 {
                    let x = Rng::counter(3, stream, step, row).next_u64();
                    assert!(
                        seen.insert(x),
                        "counter collision at ({stream},{step},{row})"
                    );
                }
            }
        }
        // seed also matters
        assert_ne!(
            Rng::counter(1, 0, 0, 0).next_u64(),
            Rng::counter(2, 0, 0, 0).next_u64()
        );
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f32() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn below_covers_range_without_overflow() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(r.below(1), 0);
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(13);
        let p = r.permutation(257);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..257).collect::<Vec<_>>());
        // and not the identity with overwhelming probability
        assert_ne!(p, (0..257).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_deterministic_per_seed() {
        let mut a = Rng::new(21);
        let mut b = Rng::new(21);
        assert_eq!(a.permutation(100), b.permutation(100));
    }
}
