//! Minimal JSON parser + writer (no serde in the vendored crate set).
//!
//! Used for: artifact manifests (read), metrics/event logs (write), bench
//! result files (write), checkpoint metadata (read/write). Supports the
//! full JSON grammar including unicode escapes; numbers are stored as f64
//! (the manifest only carries shapes/scalars, well within 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::error::{Error, Result};

/// A JSON value. Object keys are sorted (BTreeMap) so output is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------------
    // Accessors
    // ------------------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest loading reads nicer.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::json(format!("missing key '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0).map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ------------------------------------------------------------------
    // Builders
    // ------------------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ------------------------------------------------------------------
    // Parse
    // ------------------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::json(format!("trailing data at byte {}", p.i)));
        }
        Ok(v)
    }

    // ------------------------------------------------------------------
    // Write
    // ------------------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.fract() == 0.0 && x.abs() < 9e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::json(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(Error::json(format!("bad literal at byte {}", self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::json(format!(
                "unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.i
            ))),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(Error::json(format!("bad array at byte {}", self.i))),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(Error::json(format!("bad object at byte {}", self.i))),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self
                .peek()
                .ok_or_else(|| Error::json("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| Error::json("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| Error::json("bad \\u escape"))?);
                        }
                        _ => return Err(Error::json("bad escape")),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte utf-8: copy the full sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.i - 1;
                    self.i = start + len;
                    let chunk = self
                        .b
                        .get(start..self.i)
                        .ok_or_else(|| Error::json("truncated utf-8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk)
                            .map_err(|_| Error::json("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let chunk = self
            .b
            .get(self.i..self.i + 4)
            .ok_or_else(|| Error::json("truncated \\u"))?;
        self.i += 4;
        u32::from_str_radix(
            std::str::from_utf8(chunk).map_err(|_| Error::json("bad \\u"))?,
            16,
        )
        .map_err(|_| Error::json("bad \\u digits"))
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::json(format!("bad number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e3 ").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
        // raw multi-byte utf-8 passes through
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"nums":[1,2.5,-3],"s":"x\"y","t":true,"n":null,"o":{}}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn req_reports_key() {
        let v = Json::parse("{}").unwrap();
        let err = v.req("missing").unwrap_err().to_string();
        assert!(err.contains("missing"), "{err}");
    }

    #[test]
    fn parses_real_manifest() {
        // shape of the aot.py manifest
        let src = r#"{"preset":"tiny","params":[{"name":"prep.w","shape":[27,4]}],
                      "num_params":26350,"batches":[8]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("num_params").unwrap().as_usize(), Some(26350));
        let p = &v.req("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("shape").unwrap().as_arr().unwrap().len(), 2);
    }
}
