//! Ablations over SWAP's design choices (the knobs DESIGN.md calls out and
//! §6 of the paper discusses): worker count W, the transition threshold τ,
//! phase-2 length, averaging frequency (SWAP's average-once vs post-local
//! SGD's average-every-H), and the interconnect model.
//!
//! CLI: `swap-train ablate-workers | ablate-tau | ablate-phase2 |
//! ablate-freq | ablate-net`. Each prints a Table and writes results/.

use super::lab::Lab;
use crate::bench::Table;
use crate::coordinator::{run_local_sgd, run_swap, LocalSgdConfig};
use crate::sim::{CostModel, NetModel};
use crate::util::Result;

/// W sweep: more independent workers → better averaged model (up to the
/// phase-1 batch the devices imply), constant phase-2 wall time.
pub fn ablate_workers(lab: &Lab, sweep: &[usize]) -> Result<Table> {
    let mut t = Table::new(
        "Ablation — worker count W (phase-2 independent replicas)",
        &["W", "before avg (%)", "after avg (%)", "gain (pts)", "modeled time (s)"],
    );
    for &w in sweep {
        let mut cfg = lab.swap_arm(lab.cfg.seed);
        cfg.workers = w;
        cfg.group_devices = 1;
        // keep the phase-1 global batch feasible for the dataset
        let max_dev = lab.cfg.n_train / lab.cfg.exec_batch;
        let devices = (w).min(max_dev);
        cfg.workers = devices;
        let r = run_swap(&lab.env(), &cfg)?;
        let before = r.before_avg_acc1() * 100.0;
        let after = r.final_stats.accuracy1() * 100.0;
        t.row(&[
            format!("{devices}"),
            format!("{before:.2}"),
            format!("{after:.2}"),
            format!("{:+.2}", after - before),
            format!("{:.3}", r.clock.seconds),
        ]);
    }
    Ok(t)
}

/// τ sweep: where to hand over from large-batch to the parallel refinement.
/// Too late (τ→1): phase 2 starts from a stuck point and cannot improve;
/// too early: phase 1's speed advantage is wasted.
pub fn ablate_tau(lab: &Lab, sweep: &[f64]) -> Result<Table> {
    let mut t = Table::new(
        "Ablation — phase-1 exit threshold τ",
        &["tau", "phase1 epochs", "after avg (%)", "modeled time (s)"],
    );
    for &tau in sweep {
        let mut cfg = lab.swap_arm(lab.cfg.seed);
        cfg.phase1_stop_acc = tau;
        let r = run_swap(&lab.env(), &cfg)?;
        t.row(&[
            format!("{tau:.2}"),
            format!("{:.0}", r.phase1.epochs),
            format!("{:.2}", r.final_stats.accuracy1() * 100.0),
            format!("{:.3}", r.clock.seconds),
        ]);
    }
    Ok(t)
}

/// Phase-2 length sweep (the Table-4 row-4/row-5 axis, finer).
pub fn ablate_phase2(lab: &Lab, sweep: &[usize]) -> Result<Table> {
    let mut t = Table::new(
        "Ablation — phase-2 epochs per worker",
        &["epochs", "before avg (%)", "after avg (%)", "modeled time (s)"],
    );
    for &ep in sweep {
        let mut cfg = lab.swap_arm(lab.cfg.seed);
        cfg.phase2_epochs = ep;
        cfg.phase2_sched = lab.cfg.phase2_schedule(
            lab.cfg.n_train / (lab.cfg.group_devices * lab.cfg.exec_batch),
        );
        let r = run_swap(&lab.env(), &cfg)?;
        t.row(&[
            format!("{ep}"),
            format!("{:.2}", r.before_avg_acc1() * 100.0),
            format!("{:.2}", r.final_stats.accuracy1() * 100.0),
            format!("{:.3}", r.clock.seconds),
        ]);
    }
    Ok(t)
}

/// Averaging frequency: SWAP (average once at the end) vs post-local SGD
/// with parameter averaging every H steps (§2: "Post-local SGD averages
/// after at most 32 updates; SWAP after tens of thousands").
pub fn ablate_averaging_frequency(lab: &Lab, h_sweep: &[usize]) -> Result<Table> {
    let env = lab.env();
    let mut t = Table::new(
        "Ablation — averaging frequency (post-local SGD H vs SWAP once)",
        &["method", "H (steps)", "test acc (%)", "modeled time (s)", "sync events"],
    );
    let spe_lb = lab.spe(lab.cfg.lb_devices);
    for &h in h_sweep {
        let r = run_local_sgd(
            &env,
            &LocalSgdConfig {
                devices: lab.cfg.lb_devices,
                sync_epochs: lab.cfg.phase1_max_epochs / 2,
                sync_sched: lab.cfg.phase1_schedule(spe_lb),
                local_epochs: lab.cfg.phase2_epochs,
                local_sched: lab.cfg.phase2_schedule(lab.spe(1)),
                h_steps: h,
                seed: lab.cfg.seed,
                averaging: lab.averaging.clone(),
            },
        )?;
        t.row(&[
            "post-local SGD".into(),
            format!("{h}"),
            format!("{:.2}", r.outcome.test_acc1 * 100.0),
            format!("{:.3}", r.outcome.cluster_seconds),
            format!("{}", r.sync_events),
        ]);
    }
    let r = run_swap(&env, &lab.swap_arm(lab.cfg.seed))?;
    let p2_steps = lab.cfg.phase2_epochs * lab.spe(lab.cfg.group_devices);
    t.row(&[
        "SWAP (average once)".into(),
        format!("{p2_steps}"),
        format!("{:.2}", r.final_stats.accuracy1() * 100.0),
        format!("{:.3}", r.clock.seconds),
        "1".into(),
    ]);
    Ok(t)
}

/// Interconnect ablation: how much of SWAP's advantage over plain LB
/// training comes from skipping synchronization in phase 2? With an
/// NVLink-class fabric the all-reduce tax shrinks and LB closes the gap.
pub fn ablate_network(lab: &Lab) -> Result<Table> {
    let mut t = Table::new(
        "Ablation — interconnect (α–β model) vs phase-1 all-reduce tax",
        &["fabric", "allreduce W=8 (ms)", "share of LB step (%)", "LB epoch (s)", "SWAP p2 epoch (s)"],
    );
    let nets = [("pcie-like", NetModel::pcie_like()), ("nvlink-like", NetModel::nvlink_like())];
    for (name, net) in nets {
        let cost = CostModel {
            net,
            ..lab.cost.clone()
        };
        let step = cost.train_step_time(lab.cfg.exec_batch);
        let ar = cost.allreduce_time(lab.cfg.lb_devices);
        let spe_lb = lab.spe(lab.cfg.lb_devices) as f64;
        let spe_sb = lab.spe(lab.cfg.group_devices) as f64;
        t.row(&[
            name.into(),
            format!("{:.3}", ar * 1e3),
            format!("{:.1}", 100.0 * ar / (step + ar)),
            format!("{:.4}", spe_lb * (step + ar)),
            format!("{:.4}", spe_sb * step),
        ]);
    }
    Ok(t)
}
