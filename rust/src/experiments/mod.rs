//! Experiment drivers: one function per paper table/figure, shared by the
//! CLI (`swap-train table1`, ...), the bench binaries (`cargo bench`), and
//! the examples. DESIGN.md's per-experiment index maps each paper artifact
//! to the driver here that regenerates it.

pub mod ablations;
pub mod figures;
pub mod lab;
pub mod tables;

pub use lab::Lab;
