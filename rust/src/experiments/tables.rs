//! Reproduction drivers for the paper's Tables 1-4 (+ the DAWNBench §5.1
//! claim). Each driver runs every arm over the lab's seeds and prints the
//! paper's row next to the measured row, then writes a CSV under results/.

use super::lab::Lab;
use crate::bench::{pm, Table};
use crate::coordinator::{run_baseline, run_swa, run_swap, SwaConfig};
use crate::metrics::{summarize, RunOutcome};
use crate::model::ParamSet;
use crate::sim::ClusterClock;
use crate::util::Result;

fn outcome_of_swap(lab: &Lab, seed: u64) -> Result<(RunOutcome, RunOutcome)> {
    let env = lab.env();
    let r = run_swap(&env, &lab.swap_arm(seed))?;
    let before = RunOutcome {
        test_acc1: r.before_avg_acc1(),
        test_acc5: r.before_avg_acc5(),
        test_loss: 0.0,
        cluster_seconds: r.phase2_seconds,
        wall_seconds: r.wall_seconds,
    };
    let after = RunOutcome {
        test_acc1: r.final_stats.accuracy1(),
        test_acc5: r.final_stats.accuracy5(),
        test_loss: r.final_stats.mean_loss(),
        cluster_seconds: r.clock.seconds,
        wall_seconds: r.wall_seconds,
    };
    Ok((before, after))
}

/// Tables 1 and 2 (and the accuracy/time part of Table 3): SB vs LB vs
/// SWAP before/after averaging. `top5` adds the Top-5 column (Table 3).
pub fn table_sgd_vs_swap(lab: &Lab, title: &str, paper_rows: &[(&str, &str, &str)],
                         top5: bool) -> Result<Table> {
    let mut sb = Vec::new();
    let mut lb = Vec::new();
    let mut swap_before = Vec::new();
    let mut swap_after = Vec::new();
    for seed in lab.run_seeds() {
        crate::info!("{title}: seed {seed}");
        sb.push(run_baseline(&lab.env(), &lab.sb_arm(seed))?.outcome);
        lb.push(run_baseline(&lab.env(), &lab.lb_arm(seed))?.outcome);
        let (before, after) = outcome_of_swap(lab, seed)?;
        swap_before.push(before);
        swap_after.push(after);
    }

    let mut headers = vec!["arm", "paper acc (%)", "measured acc (%)"];
    if top5 {
        headers.push("measured top5 (%)");
    }
    headers.extend_from_slice(&["paper time (s)", "modeled time (s)", "wall (s)"]);
    let mut t = Table::new(title, &headers);
    let arms: [(&str, &[RunOutcome]); 4] = [
        ("SGD (small-batch)", &sb),
        ("SGD (large-batch)", &lb),
        ("SWAP (before averaging)", &swap_before),
        ("SWAP (after averaging)", &swap_after),
    ];
    for ((name, outs), (_, paper_acc, paper_time)) in arms.iter().zip(paper_rows) {
        let s = summarize(outs);
        let mut row = vec![
            name.to_string(),
            paper_acc.to_string(),
            pm(s.acc1.mean * 100.0, s.acc1.std * 100.0),
        ];
        if top5 {
            row.push(pm(s.acc5.mean * 100.0, s.acc5.std * 100.0));
        }
        row.extend_from_slice(&[
            paper_time.to_string(),
            pm(s.cluster.mean, s.cluster.std),
            format!("{:.1}", s.wall.mean),
        ]);
        t.row(&row);
    }
    Ok(t)
}

pub fn table1(lab: &Lab) -> Result<Table> {
    table_sgd_vs_swap(
        lab,
        "Table 1 — CIFAR10(sim): SGD vs SWAP",
        &[
            ("sb", "95.24 ± 0.09", "254.12 ± 0.62"),
            ("lb", "94.77 ± 0.23", "132.62 ± 1.09"),
            ("swap-", "94.70 ± 0.20", "167.57 ± 3.25"),
            ("swap+", "95.23 ± 0.08", "169.20 ± 3.25"),
        ],
        false,
    )
}

pub fn table2(lab: &Lab) -> Result<Table> {
    table_sgd_vs_swap(
        lab,
        "Table 2 — CIFAR100(sim): SGD vs SWAP",
        &[
            ("sb", "77.01 ± 0.25", "573.76 ± 2.25"),
            ("lb", "75.84 ± 0.35", "116.13 ± 1.35"),
            ("swap-", "75.74 ± 0.15", "123.11 ± 1.85"),
            ("swap+", "78.18 ± 0.21", "125.34 ± 1.85"),
        ],
        false,
    )
}

pub fn table3(lab: &Lab) -> Result<Table> {
    table_sgd_vs_swap(
        lab,
        "Table 3 — ImageNet(sim): SGD vs SWAP (Top1; Top5 measured col)",
        &[
            ("sb", "76.14 ± 0.07", "235.29 ± 0.33"),
            ("lb", "75.86 ± 0.03", "127.20 ± 0.78"),
            ("swap-", "75.96 ± 0.02", "149.12 ± 0.55"),
            ("swap+", "76.19 ± 0.03", "156.55 ± 0.56"),
        ],
        true,
    )
}

/// Table 4 — SWA vs SWAP on CIFAR100(sim). Five arms:
///   1. large-batch SWA (cyclic sampling stays at the large batch)
///   2. large-batch-to-τ then small-batch SWA (sequential refinement)
///   3. small-batch SWA (from a full SB run)
///   4. SWAP (standard phase-2 length)
///   5. SWAP with a longer phase 2 (the "relaxed" row)
pub fn table4(lab: &Lab) -> Result<Table> {
    let env = lab.env();
    let cycles = lab.cfg.swa_cycles;
    let mut arms: [Vec<(f64, f64, f64)>; 5] = Default::default(); // (before, after, time)

    for seed in lab.run_seeds() {
        crate::info!("table4: seed {seed}");
        // -- arm 1: LB SWA ------------------------------------------------
        {
            let lbr = run_baseline(&env, &lab.lb_arm(seed))?;
            let mut params = lbr.params;
            let mut clock = lbr.clock;
            let swa = run_swa(
                &env,
                &mut params,
                &SwaConfig {
                    devices: lab.cfg.lb_devices,
                    high_lr: lab.cfg.swa_high_lr * 4.0, // linear-scaling rule
                    ..lab.swa_arm(lab.cfg.lb_devices, cycles, seed)
                },
                &mut clock,
            )?;
            arms[0].push((
                swa.last_stats.accuracy1(),
                swa.final_stats.accuracy1(),
                clock.seconds,
            ));
        }
        // -- arm 2: LB-to-τ then sequential SB SWA -------------------------
        {
            let mut lb_cfg = lab.lb_arm(seed);
            lb_cfg.stop_train_acc = lab.cfg.phase1_stop_acc;
            lb_cfg.epochs = lab.cfg.phase1_max_epochs;
            let lbr = run_baseline(&env, &lb_cfg)?;
            let mut params = lbr.params;
            let mut clock = lbr.clock;
            let swa = run_swa(&env, &mut params, &lab.swa_arm(1, cycles, seed), &mut clock)?;
            arms[1].push((
                swa.last_stats.accuracy1(),
                swa.final_stats.accuracy1(),
                clock.seconds,
            ));
        }
        // -- arm 3: SB SWA -------------------------------------------------
        {
            let sbr = run_baseline(&env, &lab.sb_arm(seed))?;
            let mut params = sbr.params;
            let mut clock = sbr.clock;
            let swa = run_swa(&env, &mut params, &lab.swa_arm(1, cycles, seed), &mut clock)?;
            arms[2].push((
                swa.last_stats.accuracy1(),
                swa.final_stats.accuracy1(),
                clock.seconds,
            ));
        }
        // -- arm 4: SWAP (standard) ---------------------------------------
        {
            let (before, after) = outcome_of_swap(lab, seed)?;
            arms[3].push((before.test_acc1, after.test_acc1, after.cluster_seconds));
        }
        // -- arm 5: SWAP with a longer, cyclic phase 2 (paper: two 20-epoch
        //    cycles instead of one 10-epoch cycle; scaled 2x here) --------
        {
            let mut cfg = lab.swap_arm(seed);
            cfg.phase2_epochs *= 2;
            cfg.phase2_sched = crate::optim::Schedule::Cyclic {
                high: lab.cfg.swa_high_lr,
                low: lab.cfg.swa_low_lr,
                period: (lab.cfg.swa_cycle_epochs * lab.spe(lab.cfg.group_devices)).max(1),
            };
            let r = run_swap(&lab.env(), &cfg)?;
            arms[4].push((
                r.before_avg_acc1(),
                r.final_stats.accuracy1(),
                r.clock.seconds,
            ));
        }
    }

    let paper = [
        ("Large-batch SWA", "76.06", "76.00", "376.4"),
        ("LB then small-batch SWA", "76.26", "78.12", "398.0"),
        ("Small-batch SWA", "76.80", "79.09", "848.6"),
        ("SWAP (short phase 2)", "75.74", "78.18", "125.3"),
        ("SWAP (long phase 2)", "76.19", "79.11", "241.5"),
    ];
    let mut t = Table::new(
        "Table 4 — SWA vs SWAP (CIFAR100(sim))",
        &[
            "arm",
            "paper before (%)",
            "measured before (%)",
            "paper after (%)",
            "measured after (%)",
            "paper time (s)",
            "modeled time (s)",
        ],
    );
    for (vals, (name, pb, pa, pt)) in arms.iter().zip(&paper) {
        let before = crate::bench::stats(&vals.iter().map(|v| v.0 * 100.0).collect::<Vec<_>>());
        let after = crate::bench::stats(&vals.iter().map(|v| v.1 * 100.0).collect::<Vec<_>>());
        let time = crate::bench::stats(&vals.iter().map(|v| v.2).collect::<Vec<_>>());
        t.row(&[
            name.to_string(),
            pb.to_string(),
            pm(before.mean, before.std),
            pa.to_string(),
            pm(after.mean, after.std),
            pt.to_string(),
            pm(time.mean, time.std),
        ]);
    }
    Ok(t)
}

/// §5.1 DAWNBench claim: time-to-target-accuracy for a fast SWAP setting
/// (shorter phase 1 + one-epoch-scale phase 2) vs the SB baseline. The
/// paper reaches CIFAR10-94% in 27s vs the 37s front-runner (0.73x).
pub fn dawnbench(lab: &Lab, target_frac_of_sb: f64) -> Result<Table> {
    let env = lab.env();
    let mut rows = Vec::new();
    for seed in lab.run_seeds() {
        // the target: a fraction of what the SB baseline achieves
        let sbr = run_baseline(&env, &lab.sb_arm(seed))?;
        let target = sbr.outcome.test_acc1 * target_frac_of_sb;

        // fast SWAP: phase 1 stops earlier, phase 2 is 1/3 the epochs
        let mut cfg = lab.swap_arm(seed);
        cfg.phase1_stop_acc = (lab.cfg.phase1_stop_acc - 0.1).max(0.3);
        cfg.phase2_epochs = (lab.cfg.phase2_epochs / 3).max(1);
        let r = run_swap(&env, &cfg)?;
        rows.push((
            target,
            sbr.outcome.cluster_seconds,
            r.final_stats.accuracy1(),
            r.clock.seconds,
        ));
    }
    let mut t = Table::new(
        "DAWNBench §5.1 — time to target accuracy (paper: 27s vs 37s = 0.73x)",
        &["seed run", "target acc (%)", "SB time (s)", "fast-SWAP acc (%)", "fast-SWAP time (s)", "ratio"],
    );
    for (i, (target, sb_time, acc, time)) in rows.iter().enumerate() {
        t.row(&[
            format!("{i}"),
            format!("{:.2}", target * 100.0),
            format!("{sb_time:.2}"),
            format!("{:.2}{}", acc * 100.0, if acc >= target { "" } else { " (missed)" }),
            format!("{time:.2}"),
            format!("{:.2}x", time / sb_time),
        ]);
    }
    Ok(t)
}

/// Write a rendered table + CSV under results/.
pub fn save_table(t: &Table, name: &str) -> Result<()> {
    std::fs::create_dir_all("results")?;
    std::fs::write(format!("results/{name}.txt"), t.render())?;
    std::fs::write(format!("results/{name}.csv"), t.to_csv())?;
    Ok(())
}

/// Shape assertions shared by the table benches: SWAP-after >= max(workers
/// before, LB) - slack, and modeled SWAP time within [LB, SB] bounds-ish.
/// Returns human-readable findings instead of panicking (benches print).
pub fn check_table_shape(sb: &RunOutcome, lb: &RunOutcome, before: &RunOutcome,
                         after: &RunOutcome) -> Vec<String> {
    let mut findings = Vec::new();
    if after.test_acc1 + 1e-9 < before.test_acc1 {
        findings.push(format!(
            "averaging did not help: after {:.4} < before {:.4}",
            after.test_acc1, before.test_acc1
        ));
    }
    if after.cluster_seconds >= sb.cluster_seconds {
        findings.push(format!(
            "SWAP not faster than SB: {:.2}s vs {:.2}s",
            after.cluster_seconds, sb.cluster_seconds
        ));
    }
    if lb.cluster_seconds >= sb.cluster_seconds {
        findings.push(format!(
            "LB not faster than SB: {:.2}s vs {:.2}s",
            lb.cluster_seconds, sb.cluster_seconds
        ));
    }
    findings
}

/// Thin struct so benches can reuse the ParamSet type without re-importing.
pub type Params = ParamSet;
pub type Clock = ClusterClock;
