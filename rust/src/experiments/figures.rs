//! Reproduction drivers for the paper's Figures 1-6. Each driver produces
//! CSV series under results/ (the same data the paper plots) plus a small
//! printed summary of the figure's qualitative claim.

use super::lab::Lab;
use crate::analysis;
use crate::coordinator::{run_swap, TrainEnv};
use crate::landscape::{eval_grid, GridResult, GridSpec, Plane};
use crate::metrics::SeriesLog;
use crate::model::ParamSet;
use crate::optim::{imagenet_piecewise, Schedule};
use crate::runtime::Backend;
use crate::sim::ClusterClock;
use crate::util::Result;

fn results_path(name: &str) -> String {
    format!("results/{name}")
}

/// Figure 1: LR schedule + per-worker test accuracy + on-the-fly averaged
/// accuracy through both phases. Returns (lr series, accuracy series).
pub fn fig1(lab: &Lab) -> Result<(SeriesLog, SeriesLog)> {
    let env = lab.env();
    let spe2 = lab.spe(lab.cfg.group_devices);
    let snap = (spe2 / 2).max(1);
    let mut cfg = lab.swap_arm(lab.cfg.seed);
    cfg.snapshot_every = Some(snap);
    cfg.phase1_snapshot_every = Some((lab.spe(lab.cfg.lb_devices)).max(1));
    let r = run_swap(&env, &cfg)?;

    // LR schedule series (x = phase-1 steps then phase-2 steps appended)
    let mut lr = SeriesLog::new(&["step", "lr", "phase"]);
    for s in 0..r.phase1.steps {
        lr.push(&[s as f64, cfg.phase1_sched.lr(s) as f64, 1.0]);
    }
    let p2_steps = lab.cfg.phase2_epochs * spe2;
    for s in 0..p2_steps {
        lr.push(&[(r.phase1.steps + s) as f64, cfg.phase2_sched.lr(s) as f64, 2.0]);
    }

    // accuracy series: phase-1 shared model, then per-worker + average
    let mut acc = SeriesLog::new(&[
        "step", "phase", "worker", "test_acc",
    ]);
    let mut clock = ClusterClock::new();
    for (step, params) in &r.phase1_snapshots {
        let stats = env.bn_and_eval(params, lab.cfg.seed, &mut clock)?;
        acc.push(&[*step as f64, 1.0, -1.0, stats.accuracy1()]);
    }
    // phase 2: align snapshots across workers by index
    let n_snaps = r.snapshots.iter().map(|t| t.len()).min().unwrap_or(0);
    for i in 0..n_snaps {
        let step = r.snapshots[0][i].0;
        let mut snap_params = Vec::new();
        for (w, trail) in r.snapshots.iter().enumerate() {
            let stats = env.bn_and_eval(&trail[i].1, lab.cfg.seed, &mut clock)?;
            acc.push(&[
                (r.phase1.steps + step) as f64,
                2.0,
                w as f64,
                stats.accuracy1(),
            ]);
            snap_params.push(trail[i].1.clone());
        }
        let avg = ParamSet::average(&snap_params)?;
        let stats = env.bn_and_eval(&avg, lab.cfg.seed, &mut clock)?;
        acc.push(&[(r.phase1.steps + step) as f64, 2.0, 99.0, stats.accuracy1()]);
    }
    lr.write_csv(results_path("fig1_lr.csv"))?;
    acc.write_csv(results_path("fig1_accuracy.csv"))?;
    Ok((lr, acc))
}

/// Figures 2 and 3: error surfaces over weight planes.
/// Fig 2 plane: (LB, one worker, SWAP). Fig 3 plane: (3 workers) + SWAP.
pub struct LandscapeFigures {
    pub fig2: GridResult,
    pub fig2_anchors: Vec<(String, f64, f64)>,
    pub fig3: GridResult,
    pub fig3_anchors: Vec<(String, f64, f64)>,
}

pub fn fig2_fig3(lab: &Lab, grid: &GridSpec) -> Result<LandscapeFigures> {
    let env = lab.env();
    let mut cfg = lab.swap_arm(lab.cfg.seed);
    if cfg.workers < 3 {
        cfg.workers = 3; // Fig 3 needs three independent workers
        cfg.group_devices = 1;
    }
    let r = run_swap(&env, &cfg)?;
    let mut clock = ClusterClock::new();

    // -- Fig 2: plane through LB (phase-1 output), worker 0, SWAP ---------
    let plane2 = Plane::through(&r.phase1_params, &r.worker_params[0], &r.final_params)?;
    let fig2 = eval_grid(&env, &plane2, grid, lab.cfg.seed, &mut clock)?;
    let mut fig2_anchors = vec![
        ("LB".to_string(), plane2.anchors[0].0, plane2.anchors[0].1),
        ("SGD".to_string(), plane2.anchors[1].0, plane2.anchors[1].1),
        ("SWAP".to_string(), plane2.anchors[2].0, plane2.anchors[2].1),
    ];

    // -- Fig 3: plane through three workers; SWAP + BEST projected in -----
    let plane3 = Plane::through(&r.worker_params[0], &r.worker_params[1], &r.worker_params[2])?;
    let fig3 = eval_grid(&env, &plane3, grid, lab.cfg.seed, &mut clock)?;
    let swap_proj = plane3.project(&r.final_params)?;
    let mut fig3_anchors = vec![
        ("SGD1".to_string(), plane3.anchors[0].0, plane3.anchors[0].1),
        ("SGD2".to_string(), plane3.anchors[1].0, plane3.anchors[1].1),
        ("SGD3".to_string(), plane3.anchors[2].0, plane3.anchors[2].1),
        ("SWAP".to_string(), swap_proj.0, swap_proj.1),
        (
            "BEST".to_string(),
            fig3.best_test.alpha,
            fig3.best_test.beta,
        ),
    ];

    fig2.to_series().write_csv(results_path("fig2_surface.csv"))?;
    fig3.to_series().write_csv(results_path("fig3_surface.csv"))?;
    let write_anchors = |name: &str, anchors: &mut Vec<(String, f64, f64)>| -> Result<()> {
        let mut s = SeriesLog::new(&["alpha", "beta", "tag"]);
        for (i, (_n, a, b)) in anchors.iter().enumerate() {
            s.push(&[*a, *b, i as f64]);
        }
        s.write_csv(results_path(name))
    };
    write_anchors("fig2_anchors.csv", &mut fig2_anchors)?;
    write_anchors("fig3_anchors.csv", &mut fig3_anchors)?;
    Ok(LandscapeFigures { fig2, fig2_anchors, fig3, fig3_anchors })
}

/// Figure 4: cosine similarity between −g_t and θ_swap − θ_t over a
/// worker's phase-2 trajectory.
pub fn fig4(lab: &Lab) -> Result<SeriesLog> {
    let env = lab.env();
    let spe2 = lab.spe(lab.cfg.group_devices);
    let mut cfg = lab.swap_arm(lab.cfg.seed);
    cfg.snapshot_every = Some((spe2 / 2).max(1));
    let r = run_swap(&env, &cfg)?;
    let series = analysis::cosine_to_target(&env, &r.snapshots[0], &r.final_params, lab.cfg.seed)?;
    series.write_csv(results_path("fig4_cosine.csv"))?;
    Ok(series)
}

/// Figure 5: the ImageNet LR + batch-size schedules — original (8 GPU),
/// doubled (16 GPU), and the SWAP composition (doubled then original).
pub fn fig5(lab: &Lab) -> Result<SeriesLog> {
    let spe = lab.spe(lab.cfg.sb_devices).max(1);
    let total = 28 * spe;
    let orig = imagenet_piecewise(spe, lab.cfg.sb_peak_lr);
    let doubled = orig.scaled(2.0);
    let swap_combo = Schedule::Sequence(vec![
        (22 * spe, doubled.clone()),
        (6 * spe, orig.clone()),
    ]);
    let mut s = SeriesLog::new(&[
        "step", "lr_original", "lr_doubled", "lr_swap", "batch_original", "batch_doubled",
    ]);
    let (b_orig, b_doubled) = (
        (lab.cfg.sb_devices * lab.cfg.exec_batch) as f64,
        (lab.cfg.lb_devices * lab.cfg.exec_batch) as f64,
    );
    for t in 0..total {
        s.push(&[
            t as f64,
            orig.lr(t) as f64,
            doubled.lr(t) as f64,
            swap_combo.lr(t) as f64,
            b_orig,
            b_doubled,
        ]);
    }
    s.write_csv(results_path("fig5_imagenet_schedules.csv"))?;
    Ok(s)
}

/// Figure 6: SWA cyclic-LR schedule illustrations — (a) large-batch SWA
/// cycles, (b) LB-to-τ then small-batch cycles.
pub fn fig6(lab: &Lab) -> Result<SeriesLog> {
    let spe = lab.spe(1).max(1);
    let period = (lab.cfg.swa_cycle_epochs * spe).max(1);
    let warm = Schedule::Triangle {
        peak: lab.cfg.lb_peak_lr,
        warmup: (spe * 2).max(1),
        total: 6 * spe,
        end_lr: lab.cfg.swa_high_lr,
    };
    let cycles = Schedule::Cyclic {
        high: lab.cfg.swa_high_lr,
        low: lab.cfg.swa_low_lr,
        period,
    };
    let a = Schedule::Sequence(vec![(6 * spe, warm.clone()), (4 * period, cycles.clone())]);
    let b = Schedule::Sequence(vec![
        (4 * spe, warm.scaled(1.0)),
        (4 * period, cycles.scaled(0.5)),
    ]);
    let total = 6 * spe + 4 * period;
    let mut s = SeriesLog::new(&["step", "lr_lb_swa", "lr_lb_then_sb_swa", "sample_marker"]);
    for t in 0..total {
        let marker = if t > 6 * spe && (t - 6 * spe) % period == period - 1 {
            1.0
        } else {
            0.0
        };
        s.push(&[t as f64, a.lr(t) as f64, b.lr(t) as f64, marker]);
    }
    s.write_csv(results_path("fig6_swa_schedules.csv"))?;
    Ok(s)
}

/// Weight-travel extra (Hoffer et al. §2 discussion): distance from init
/// for SB vs LB — used by the microbench/ablation suite.
pub fn travel(lab: &Lab) -> Result<SeriesLog> {
    let env: TrainEnv = lab.env();
    let mut cfg = lab.swap_arm(lab.cfg.seed);
    cfg.phase1_snapshot_every = Some(lab.spe(lab.cfg.lb_devices).max(1));
    let r = run_swap(&env, &cfg)?;
    let init = ParamSet::init(lab.engine.manifest(), lab.cfg.seed);
    let s = analysis::travel_series(&r.phase1_snapshots, &init)?;
    s.write_csv(results_path("travel_phase1.csv"))?;
    Ok(s)
}
