//! The "lab": owns the engine + datasets for one preset config and builds
//! the per-arm configurations (SB / LB / SWAP / SWA) from it. Every table
//! bench, figure bench, example, and CLI subcommand goes through this.

use crate::config::ExperimentConfig;
use crate::coordinator::{AveragingSpec, BaselineConfig, SwaConfig, SwapConfig, TrainEnv};
use crate::data::Dataset;
use crate::runtime::Backend;
use crate::sim::{CostModel, DeviceModel, NetModel};
use crate::util::{Error, Result};

pub struct Lab {
    pub cfg: ExperimentConfig,
    pub engine: Box<dyn Backend>,
    pub cost: CostModel,
    pub train: Dataset,
    pub test: Dataset,
    /// held-out validation split (val_examples > 0) for validation-gated
    /// averaging policies
    pub val: Option<Dataset>,
    /// the parsed averaging policy every arm built from this lab uses
    pub averaging: AveragingSpec,
}

impl Lab {
    pub fn new(cfg: ExperimentConfig) -> Result<Lab> {
        cfg.validate()?;
        let averaging = cfg.averaging_spec()?;
        let engine = cfg.load_backend()?;
        let m = engine.manifest().clone();
        let source = cfg.data_source()?;
        let (train, test, val) = source.load_with_val(cfg.val_examples)?;
        // the loaded data must fit the model contract, whatever fed it
        for (ds, what) in [(Some(&train), "train"), (Some(&test), "test"), (val.as_ref(), "val")]
            .into_iter()
            .filter_map(|(ds, what)| ds.map(|d| (d, what)))
        {
            if ds.num_classes != m.model.num_classes || ds.image_size != m.model.image_size {
                return Err(Error::config(format!(
                    "data source '{}' {what} split is {}x{} with {} classes, \
                     but the model wants {}x{} with {} classes",
                    source.name(),
                    ds.image_size,
                    ds.image_size,
                    ds.num_classes,
                    m.model.image_size,
                    m.model.image_size,
                    m.model.num_classes
                )));
            }
        }
        let cost = CostModel::new(DeviceModel::v100_like(), NetModel::pcie_like(), &m);
        crate::info!(
            "lab ready: preset={} backend={} data={} params={} train={} test={} val={}",
            cfg.preset,
            engine.name(),
            source.name(),
            m.num_params,
            train.n,
            test.n,
            val.as_ref().map_or(0, |v| v.n)
        );
        Ok(Lab { cfg, engine, cost, train, test, val, averaging })
    }

    pub fn env(&self) -> TrainEnv<'_> {
        TrainEnv {
            engine: self.engine.as_ref(),
            cost: &self.cost,
            train: &self.train,
            test: &self.test,
            val: self.val.as_ref(),
            augment: self.cfg.augment_spec(),
            exec_batch: self.cfg.exec_batch,
            bn_batches: self.cfg.bn_batches,
            threads: self.cfg.resolved_threads(),
            prefetch: self.cfg.resolved_prefetch(),
        }
    }

    /// Steps per epoch for a given device count.
    pub fn spe(&self, devices: usize) -> usize {
        self.cfg.n_train / (devices * self.cfg.exec_batch)
    }

    pub fn sb_arm(&self, seed: u64) -> BaselineConfig {
        BaselineConfig {
            devices: self.cfg.sb_devices,
            epochs: self.cfg.sb_epochs,
            sched: self.cfg.sb_schedule(self.spe(self.cfg.sb_devices)),
            stop_train_acc: 1.1,
            seed,
        }
    }

    pub fn lb_arm(&self, seed: u64) -> BaselineConfig {
        BaselineConfig {
            devices: self.cfg.lb_devices,
            epochs: self.cfg.lb_epochs,
            sched: self.cfg.lb_schedule(self.spe(self.cfg.lb_devices)),
            stop_train_acc: 1.1,
            seed,
        }
    }

    pub fn swap_arm(&self, seed: u64) -> SwapConfig {
        SwapConfig {
            workers: self.cfg.workers,
            group_devices: self.cfg.group_devices,
            phase1_max_epochs: self.cfg.phase1_max_epochs,
            phase1_stop_acc: self.cfg.phase1_stop_acc,
            phase1_sched: self.cfg.phase1_schedule(self.spe(self.cfg.lb_devices)),
            phase2_epochs: self.cfg.phase2_epochs,
            phase2_sched: self.cfg.phase2_schedule(self.spe(self.cfg.group_devices)),
            seed,
            averaging: self.averaging.clone(),
            snapshot_every: None,
            phase1_snapshot_every: None,
            phase1_dist: self.cfg.phase1_dist,
            phase1_record_every: self.cfg.phase1_record_every,
        }
    }

    /// SWA arm on `devices` with a given number of cycles (Table 4 rows).
    pub fn swa_arm(&self, devices: usize, cycles: usize, seed: u64) -> SwaConfig {
        SwaConfig {
            devices,
            cycles,
            cycle_epochs: self.cfg.swa_cycle_epochs,
            high_lr: self.cfg.swa_high_lr,
            low_lr: self.cfg.swa_low_lr,
            seed,
            seed_stream: 7,
            averaging: self.averaging.clone(),
            keep_samples: false,
        }
    }

    /// Run seeds: base seed + run index.
    pub fn run_seeds(&self) -> Vec<u64> {
        (0..self.runs()).map(|r| self.cfg.seed + 1000 * r as u64).collect()
    }

    /// Number of statistical repeats (env SWAP_RUNS overrides the preset).
    pub fn runs(&self) -> usize {
        std::env::var("SWAP_RUNS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(self.cfg.runs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    #[test]
    fn lab_builds_native_backend_without_artifacts() {
        let lab = Lab::new(preset("tiny").unwrap()).unwrap();
        assert_eq!(lab.engine.name(), "native");
        assert_eq!(lab.engine.manifest().model.width, 4);
        assert_eq!(lab.train.n, 96);
        assert_eq!(lab.spe(1), 12);
        assert_eq!(lab.env().exec_batch, 8);
    }
}
