//! Scaled-down analogues of the paper's experiment settings.
//!
//! Mapping (DESIGN.md): the paper's 50k-image CIFAR runs on 8 V100s shrink
//! to a few-thousand-image synthetic set on the virtual cluster, keeping
//! the *structure*: LB batch = W x SB batch, LB peak LR = (batch ratio) x
//! SB peak (linear-scaling rule, paper §5.2), phase 2 shorter than the SB
//! run with ~2.5x smaller peak LR (Appendix A: 0.3 -> 0.12 for CIFAR10),
//! and τ chosen a few points below the plateau training accuracy.

use super::ExperimentConfig;
use crate::util::{Error, Result};

pub fn preset(name: &str) -> Result<ExperimentConfig> {
    let base = ExperimentConfig {
        preset: name.to_string(),
        backend: "native".to_string(),
        artifacts_root: "artifacts".to_string(),
        seed: 42,
        runs: 3,
        threads: 0, // auto: SWAP_THREADS env or available parallelism
        simd: "auto".to_string(), // runtime feature detection; SWAP_SIMD overrides
        model_width: 8,
        num_classes: 10,
        image_size: 32,
        data: "synth".to_string(),
        data_dir: String::new(),
        n_train: 1024,
        n_test: 512,
        augment: true,
        prefetch: true,
        exec_batch: 64,
        bn_batches: 8,
        workers: 8,
        group_devices: 1,
        sb_devices: 1,
        lb_devices: 8,
        addr: String::new(),
        min_workers: 1,
        connect_timeout_ms: 60_000,
        io_timeout_ms: 10_000,
        heartbeat_ms: 1_000,
        straggler_ms: 600_000,
        join_retries: 60,
        retry_backoff_ms: 500,
        phase1_dist: false,
        phase1_record_every: 1,
        sb_epochs: 20,
        sb_peak_lr: 0.15,
        sb_warmup_frac: 0.3,
        lb_epochs: 24,
        lb_peak_lr: 0.6,
        lb_warmup_frac: 0.3,
        phase1_max_epochs: 32,
        phase1_stop_acc: 0.5, // τ scaled: plateau train acc here is ~0.56
        phase2_epochs: 6,
        phase2_peak_lr: 0.08,
        swa_cycles: 6,
        swa_cycle_epochs: 2,
        swa_high_lr: 0.06,
        swa_low_lr: 0.006,
        averaging: "uniform".to_string(),
        avg_groups: 2,
        avg_window: 4,
        avg_min_improve: 0.0,
        val_examples: 0,
        imagenet_style: false,
        serve_threads: 0,
        serve_max_batch: 8,
        serve_max_delay_us: 2000,
        serve_quant: "f32".to_string(),
        serve_queue_depth: 0, // auto: shards x serve_max_batch x 2
    };
    let cfg = match name {
        // fast unit/integration testing target (B=8 artifacts)
        "tiny" => ExperimentConfig {
            runs: 2,
            model_width: 4,
            num_classes: 10,
            image_size: 16,
            n_train: 96,
            n_test: 32,
            augment: false,
            exec_batch: 8,
            bn_batches: 2,
            workers: 2,
            lb_devices: 2,
            sb_epochs: 3,
            sb_peak_lr: 0.1,
            lb_epochs: 3,
            lb_peak_lr: 0.2,
            phase1_max_epochs: 2,
            phase1_stop_acc: 1.1,
            phase2_epochs: 2,
            phase2_peak_lr: 0.04,
            swa_cycles: 2,
            swa_cycle_epochs: 1,
            ..base
        },
        // demo preset for the pure-rust engine: tiny model, a bit more
        // data, CPU-sized batches — `swap-train swap --preset native`
        // completes a full three-phase run in seconds with no artifacts
        "native" => ExperimentConfig {
            runs: 2,
            model_width: 4,
            num_classes: 10,
            image_size: 16,
            n_train: 512,
            n_test: 256,
            exec_batch: 16,
            bn_batches: 4,
            workers: 4,
            lb_devices: 4,
            sb_epochs: 8,
            sb_peak_lr: 0.1,
            lb_epochs: 8,
            lb_peak_lr: 0.4,
            phase1_max_epochs: 10,
            phase1_stop_acc: 0.6,
            phase2_epochs: 3,
            phase2_peak_lr: 0.04,
            swa_cycles: 3,
            swa_cycle_epochs: 1,
            ..base.clone()
        },
        // Table 1 analogue: B1=512 over 8 workers, B2=64, τ scaled
        "cifar10sim" => base,
        // Table 2 analogue: 100 classes; the paper stops phase 1 earlier
        // (τ=90%) and runs a shorter phase 2 (10 epochs -> 3 here)
        "cifar100sim" => ExperimentConfig {
            num_classes: 100,
            phase1_stop_acc: 0.30, // 100 classes: plateau train acc is lower
            phase2_epochs: 4,
            phase2_peak_lr: 0.05,
            swa_cycle_epochs: 2,
            ..base.clone()
        },
        // Table 3 analogue: 2 phase-2 workers, each itself data-parallel
        // over 2 devices; LB = 2x batch + 2x LR of SB; piecewise schedule
        "imagenetsim" => ExperimentConfig {
            model_width: 12,
            num_classes: 64,
            n_train: 2048,
            n_test: 512,
            workers: 2,
            group_devices: 2,
            sb_devices: 2,
            lb_devices: 4,
            sb_epochs: 16,
            sb_peak_lr: 0.3,
            lb_epochs: 13,
            lb_peak_lr: 0.6,
            phase1_max_epochs: 13,
            phase1_stop_acc: 1.1, // ImageNet SWAP switches on epoch count (22/28)
            phase2_epochs: 4,
            phase2_peak_lr: 0.3,
            imagenet_style: true,
            ..base.clone()
        },
        other => {
            return Err(Error::config(format!(
                "unknown preset '{other}' (tiny|native|cifar10sim|cifar100sim|imagenetsim)"
            )))
        }
    };
    Ok(cfg)
}
