//! Experiment configuration: presets mirroring the paper's hyperparameter
//! tables (Appendix A, Tables 5-6; §5.2), scaled to this testbed per
//! DESIGN.md, plus a small TOML-subset loader and `--set key=value`
//! overrides so every knob is reachable from the CLI without recompiling.

pub mod presets;

pub use presets::preset;

use crate::data::{cifar, AugmentSpec, CifarSource, CifarVariant, DataSource, SynthSource};
use crate::optim::{imagenet_piecewise, Schedule};
use crate::runtime::{Backend, NativeBackend, NativeSpec};
use crate::util::{Error, Result};

/// The selectable execution backends — the single source for both
/// `validate()` and `load_backend()`.
pub const BACKENDS: &[&str] = &["native", "xla"];

/// The selectable dataset sources (`data` knob).
pub const DATA_SOURCES: &[&str] = &["synth", "cifar10", "cifar100"];

fn unknown_backend(name: &str) -> Error {
    Error::config(format!(
        "unknown backend '{name}' (expected one of: {})",
        BACKENDS.join("|")
    ))
}

fn unknown_data(name: &str) -> Error {
    Error::config(format!(
        "unknown data source '{name}' (expected one of: {})",
        DATA_SOURCES.join("|")
    ))
}

/// All knobs of one experiment family (one dataset preset).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// preset name (tiny / native / cifar10sim / ...)
    pub preset: String,
    /// execution backend: "native" (pure rust, default) or "xla" (PJRT
    /// over AOT artifacts; needs `--features xla` and artifacts from
    /// `python -m compile.aot`)
    pub backend: String,
    pub artifacts_root: String,
    pub seed: u64,
    /// statistics are collected over this many runs (paper: 10 / 3)
    pub runs: usize,
    /// OS worker threads for real parallel execution (phase-2 workers,
    /// phase-1 device shards, native kernels). 0 = auto (the SWAP_THREADS
    /// env var if set, else available parallelism); 1 reproduces the fully
    /// sequential path; every value is bitwise-identical.
    pub threads: usize,
    /// SIMD dispatch tier for the native kernels: "auto" (runtime feature
    /// detection), "scalar", "avx2" or "neon". The SWAP_SIMD env var
    /// overrides the knob; every tier is bitwise-identical.
    pub simd: String,

    // ---- model (resnet9s) ----
    /// base channel count c (mirrors python/compile/aot.py presets)
    pub model_width: usize,
    pub num_classes: usize,
    pub image_size: usize,

    // ---- data ----
    /// dataset source: "synth" (generated, default) or an on-disk
    /// "cifar10" / "cifar100" binary directory (see `data_dir`)
    pub data: String,
    /// directory holding the CIFAR binary files (data_batch_*.bin /
    /// train.bin + the test file); unused for "synth"
    pub data_dir: String,
    pub n_train: usize,
    pub n_test: usize,
    pub augment: bool,
    /// overlap batch assembly with backend compute (double-buffered
    /// background producer). Bitwise-free: only wall/modeled data time
    /// changes. SWAP_PREFETCH env var overrides.
    pub prefetch: bool,
    /// per-executable batch size (must exist in the artifact manifest)
    pub exec_batch: usize,
    /// batches for phase-3 BN recomputation
    pub bn_batches: usize,

    // ---- cluster shape ----
    /// SWAP phase-2 independent workers W
    pub workers: usize,
    /// devices inside each phase-2 worker (ImageNet: 2 groups x 8 GPUs)
    pub group_devices: usize,
    /// devices for the small-batch baseline arm
    pub sb_devices: usize,
    /// devices for the large-batch baseline arm (= workers*group_devices)
    pub lb_devices: usize,

    // ---- phase-2 transport / failure policy ----
    /// socket address for `serve`/`join`: "host:port" (TCP) or a
    /// filesystem path (Unix socket). Empty = phase 2 stays in-process.
    pub addr: String,
    /// fewest phase-2 survivors the phase-3 average may be taken over
    /// (1 = any non-empty subset, the paper's minimum)
    pub min_workers: usize,
    /// serve: join window for workers to connect after phase 1 (ms);
    /// join: overall connect deadline is join_retries x retry_backoff_ms
    pub connect_timeout_ms: u64,
    /// per-link silence tolerated before a worker is declared dead (ms)
    pub io_timeout_ms: u64,
    /// interval at which a joined worker heartbeats (ms)
    pub heartbeat_ms: u64,
    /// straggler deadline after the first finished worker (ms)
    pub straggler_ms: u64,
    /// client-side connect attempts before `join` gives up
    pub join_retries: usize,
    /// base backoff between connect attempts (ms); attempt k waits k+1
    /// windows plus up to one window of per-process jitter
    pub retry_backoff_ms: u64,
    /// distribute phase 1 over the socket transport too: `serve` becomes
    /// the hub of the synchronous collective and each `join` process owns
    /// `group_devices` gradient shards (bitwise identical to in-process
    /// when nothing fails). Ignored without an `addr`.
    pub phase1_dist: bool,
    /// append a crash-safe phase-1 progress record every this many sync
    /// steps (resumable runs; 1 = every step)
    pub phase1_record_every: usize,

    // ---- small-batch baseline schedule ----
    pub sb_epochs: usize,
    pub sb_peak_lr: f32,
    pub sb_warmup_frac: f64,

    // ---- large-batch baseline schedule ----
    pub lb_epochs: usize,
    pub lb_peak_lr: f32,
    pub lb_warmup_frac: f64,

    // ---- SWAP phases ----
    pub phase1_max_epochs: usize,
    /// τ: phase 1 exits at this training accuracy
    pub phase1_stop_acc: f64,
    pub phase2_epochs: usize,
    pub phase2_peak_lr: f32,

    // ---- SWA baseline (Table 4) ----
    pub swa_cycles: usize,
    pub swa_cycle_epochs: usize,
    pub swa_high_lr: f32,
    pub swa_low_lr: f32,

    // ---- averaging policy (phase 3 / SWA samples / local-SGD consensus) ----
    /// how candidate models are combined: "uniform" (bitwise the
    /// historical mean, default), "swa" (incremental running average),
    /// "hierarchical" (within-group then across-group), "adaptive"
    /// (validation-gated start + last-`avg_window` window)
    pub averaging: String,
    /// hierarchical: number of round-robin candidate groups
    pub avg_groups: usize,
    /// adaptive: size of the late averaging window (last-k)
    pub avg_window: usize,
    /// adaptive: minimum validation-accuracy improvement that keeps the
    /// gate closed (candidates still improving are not yet averaged)
    pub avg_min_improve: f64,
    /// held-out validation examples for validation-gated policies
    /// (0 = no validation split; synth mints a disjoint split, disk
    /// sources carve the train tail)
    pub val_examples: usize,

    /// use the piecewise ImageNet-style schedule (Fig 5) instead of the
    /// warmup-triangle for the baselines/phase 1
    pub imagenet_style: bool,

    // ---- inference serving (`swap serve-model`) ----------------------
    /// shard engine workers, each with its own workspace (0 = auto:
    /// resolved like `threads`)
    pub serve_threads: usize,
    /// largest batch the dynamic batcher coalesces requests into
    pub serve_max_batch: usize,
    /// longest a batch waits (microseconds) for co-batched requests past
    /// its first request; 0 = serve immediately
    pub serve_max_delay_us: u64,
    /// serving numeric tier: "f32" (bitwise eval path) or "int8"
    /// (post-training-quantized GEMMs, tolerance parity)
    pub serve_quant: String,
    /// pending-request ring capacity; a full ring sheds the request with
    /// an overload error instead of blocking the submitter
    /// (0 = auto: shards x serve_max_batch x 2)
    pub serve_queue_depth: usize,
}

impl ExperimentConfig {
    pub fn artifacts_dir(&self) -> std::path::PathBuf {
        std::path::Path::new(&self.artifacts_root).join(&self.preset)
    }

    /// Resolved worker-thread count (0 = auto -> SWAP_THREADS env var or
    /// available parallelism).
    pub fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            crate::coordinator::parallel::default_threads()
        } else {
            self.threads
        }
    }

    /// Resolved prefetch mode (the SWAP_PREFETCH env var overrides the
    /// config knob — CI's prefetch lane).
    pub fn resolved_prefetch(&self) -> bool {
        crate::data::prefetch::env_override().unwrap_or(self.prefetch)
    }

    /// Resolved SIMD dispatch tier (the SWAP_SIMD env var overrides the
    /// config knob — CI's forced-scalar lane). Errors if the knob names a
    /// tier this CPU cannot run.
    pub fn resolved_simd(&self) -> Result<crate::util::simd::Tier> {
        crate::util::simd::resolve(&self.simd)
    }

    /// Resolved serving shard count (0 = auto, like `threads`).
    pub fn resolved_serve_threads(&self) -> usize {
        if self.serve_threads == 0 {
            crate::coordinator::parallel::default_threads()
        } else {
            self.serve_threads
        }
    }

    /// The dynamic-batcher configuration from the `serve_*` knob family.
    pub fn serve_config(&self) -> crate::serving::ServeConfig {
        let mut sc = crate::serving::ServeConfig::for_shards(self.resolved_serve_threads());
        sc.max_batch = self.serve_max_batch;
        sc.max_delay = std::time::Duration::from_micros(self.serve_max_delay_us);
        sc.queue_slots = if self.serve_queue_depth > 0 {
            self.serve_queue_depth.max(self.serve_max_batch)
        } else {
            (sc.shards * self.serve_max_batch * 2).max(self.serve_max_batch)
        };
        sc
    }

    /// Serving numeric tier from the `serve_quant` knob.
    pub fn serve_tier(&self) -> Result<crate::serving::ServeTier> {
        crate::serving::ServeTier::from_knob(&self.serve_quant)
    }

    /// Instantiate the selected dataset source.
    pub fn data_source(&self) -> Result<Box<dyn DataSource>> {
        match self.data.as_str() {
            "synth" => Ok(Box::new(SynthSource {
                num_classes: self.num_classes,
                image_size: self.image_size,
                seed: self.seed,
                n_train: self.n_train,
                n_test: self.n_test,
            })),
            other => match CifarVariant::from_name(other) {
                Some(variant) => Ok(Box::new(CifarSource::new(
                    variant,
                    &self.data_dir,
                    self.n_train,
                    self.n_test,
                ))),
                None => Err(unknown_data(other)),
            },
        }
    }

    /// The native-backend model spec derived from this config.
    pub fn native_spec(&self) -> NativeSpec {
        NativeSpec::new(&self.preset, self.model_width, self.num_classes, self.image_size)
            .with_batches(&[self.exec_batch])
            .with_threads(self.resolved_threads())
    }

    /// Instantiate the selected execution backend. Also installs the
    /// process-wide SIMD dispatch tier from the `simd` knob (SWAP_SIMD
    /// still wins), so every kernel the backend runs dispatches on it.
    pub fn load_backend(&self) -> Result<Box<dyn Backend>> {
        crate::util::simd::set_active(&self.simd)?;
        match self.backend.as_str() {
            "native" => Ok(Box::new(NativeBackend::new(self.native_spec())?)),
            "xla" => self.load_xla_backend(),
            other => Err(unknown_backend(other)),
        }
    }

    #[cfg(feature = "xla")]
    fn load_xla_backend(&self) -> Result<Box<dyn Backend>> {
        Ok(Box::new(crate::runtime::Engine::load(self.artifacts_dir())?))
    }

    #[cfg(not(feature = "xla"))]
    fn load_xla_backend(&self) -> Result<Box<dyn Backend>> {
        Err(Error::config(
            "backend 'xla' requires building with `--features xla` \
             (and AOT artifacts from `python -m compile.aot`)",
        ))
    }

    pub fn augment_spec(&self) -> AugmentSpec {
        if self.augment {
            AugmentSpec::cifar_default()
        } else {
            AugmentSpec::none()
        }
    }

    fn triangle(&self, peak: f32, epochs: usize, warmup_frac: f64, spe: usize) -> Schedule {
        let total = (epochs * spe).max(2);
        Schedule::Triangle {
            peak,
            warmup: ((total as f64 * warmup_frac) as usize).max(1),
            total,
            end_lr: 0.0,
        }
    }

    /// Small-batch baseline schedule given its steps/epoch.
    pub fn sb_schedule(&self, spe: usize) -> Schedule {
        if self.imagenet_style {
            imagenet_piecewise(spe * self.sb_epochs / 28.max(1), self.sb_peak_lr)
        } else {
            self.triangle(self.sb_peak_lr, self.sb_epochs, self.sb_warmup_frac, spe)
        }
    }

    /// Large-batch baseline schedule (linear-scaling rule already applied
    /// in `lb_peak_lr`).
    pub fn lb_schedule(&self, spe: usize) -> Schedule {
        if self.imagenet_style {
            imagenet_piecewise(spe * self.lb_epochs / 28.max(1), self.lb_peak_lr)
        } else {
            self.triangle(self.lb_peak_lr, self.lb_epochs, self.lb_warmup_frac, spe)
        }
    }

    /// SWAP phase 1 uses the LB schedule shape over its max epochs.
    pub fn phase1_schedule(&self, spe: usize) -> Schedule {
        if self.imagenet_style {
            imagenet_piecewise(spe * self.phase1_max_epochs / 22.max(1), self.lb_peak_lr)
        } else {
            self.triangle(self.lb_peak_lr, self.phase1_max_epochs, self.lb_warmup_frac, spe)
        }
    }

    /// The averaging policy spec derived from the `averaging`/`avg_*`
    /// knobs (validated: unknown names and out-of-range parameters error).
    pub fn averaging_spec(&self) -> Result<crate::coordinator::AveragingSpec> {
        crate::coordinator::AveragingSpec::from_knobs(
            &self.averaging,
            self.avg_groups,
            self.avg_window,
            self.avg_min_improve,
        )
    }

    /// The phase-2 failure policy derived from the `*_ms` knobs.
    pub fn failure_policy(&self) -> crate::coordinator::FailurePolicy {
        use std::time::Duration;
        crate::coordinator::FailurePolicy {
            min_workers: self.min_workers,
            connect_timeout: Duration::from_millis(self.connect_timeout_ms),
            io_timeout: Duration::from_millis(self.io_timeout_ms),
            heartbeat: Duration::from_millis(self.heartbeat_ms),
            straggler_grace: Duration::from_millis(self.straggler_ms),
            join_retries: self.join_retries,
            retry_backoff: Duration::from_millis(self.retry_backoff_ms),
        }
    }

    /// SWAP phase 2: no warmup, decay from the (lower) phase-2 peak to 0
    /// (Appendix A: warm-up epochs 0).
    pub fn phase2_schedule(&self, spe: usize) -> Schedule {
        Schedule::Triangle {
            peak: self.phase2_peak_lr,
            warmup: 1,
            total: (self.phase2_epochs * spe).max(2),
            end_lr: 0.0,
        }
    }

    /// Apply one `key = value` override. Returns an error on unknown keys
    /// so typos fail loudly.
    pub fn apply_kv(&mut self, key: &str, value: &str) -> Result<()> {
        fn p<T: std::str::FromStr>(k: &str, v: &str) -> Result<T> {
            v.trim().parse::<T>().map_err(|_| {
                Error::config(format!("bad value '{v}' for key '{k}'"))
            })
        }
        match key.trim() {
            "seed" => self.seed = p(key, value)?,
            "runs" => self.runs = p(key, value)?,
            "threads" => self.threads = p(key, value)?,
            "simd" => self.simd = value.trim().to_string(),
            "backend" => self.backend = value.trim().to_string(),
            "model_width" => self.model_width = p(key, value)?,
            "num_classes" => self.num_classes = p(key, value)?,
            "image_size" => self.image_size = p(key, value)?,
            "data" => self.data = value.trim().to_string(),
            "data_dir" => self.data_dir = value.trim().to_string(),
            "n_train" => self.n_train = p(key, value)?,
            "n_test" => self.n_test = p(key, value)?,
            "augment" => self.augment = p(key, value)?,
            "prefetch" => self.prefetch = p(key, value)?,
            "exec_batch" => self.exec_batch = p(key, value)?,
            "bn_batches" => self.bn_batches = p(key, value)?,
            "workers" => self.workers = p(key, value)?,
            "group_devices" => self.group_devices = p(key, value)?,
            "sb_devices" => self.sb_devices = p(key, value)?,
            "lb_devices" => self.lb_devices = p(key, value)?,
            "addr" => self.addr = value.trim().to_string(),
            "min_workers" => self.min_workers = p(key, value)?,
            "connect_timeout_ms" => self.connect_timeout_ms = p(key, value)?,
            "io_timeout_ms" => self.io_timeout_ms = p(key, value)?,
            "heartbeat_ms" => self.heartbeat_ms = p(key, value)?,
            "straggler_ms" => self.straggler_ms = p(key, value)?,
            "join_retries" => self.join_retries = p(key, value)?,
            "retry_backoff_ms" => self.retry_backoff_ms = p(key, value)?,
            "phase1_dist" => self.phase1_dist = p(key, value)?,
            "phase1_record_every" => self.phase1_record_every = p(key, value)?,
            "sb_epochs" => self.sb_epochs = p(key, value)?,
            "sb_peak_lr" => self.sb_peak_lr = p(key, value)?,
            "sb_warmup_frac" => self.sb_warmup_frac = p(key, value)?,
            "lb_epochs" => self.lb_epochs = p(key, value)?,
            "lb_peak_lr" => self.lb_peak_lr = p(key, value)?,
            "lb_warmup_frac" => self.lb_warmup_frac = p(key, value)?,
            "phase1_max_epochs" => self.phase1_max_epochs = p(key, value)?,
            "phase1_stop_acc" => self.phase1_stop_acc = p(key, value)?,
            "phase2_epochs" => self.phase2_epochs = p(key, value)?,
            "phase2_peak_lr" => self.phase2_peak_lr = p(key, value)?,
            "swa_cycles" => self.swa_cycles = p(key, value)?,
            "swa_cycle_epochs" => self.swa_cycle_epochs = p(key, value)?,
            "swa_high_lr" => self.swa_high_lr = p(key, value)?,
            "swa_low_lr" => self.swa_low_lr = p(key, value)?,
            "averaging" => self.averaging = value.trim().to_string(),
            "avg_groups" => self.avg_groups = p(key, value)?,
            "avg_window" => self.avg_window = p(key, value)?,
            "avg_min_improve" => self.avg_min_improve = p(key, value)?,
            "val_examples" => self.val_examples = p(key, value)?,
            "artifacts_root" => self.artifacts_root = value.trim().to_string(),
            "imagenet_style" => self.imagenet_style = p(key, value)?,
            "serve_threads" => self.serve_threads = p(key, value)?,
            "serve_max_batch" => self.serve_max_batch = p(key, value)?,
            "serve_max_delay_us" => self.serve_max_delay_us = p(key, value)?,
            "serve_quant" => self.serve_quant = value.trim().to_string(),
            "serve_queue_depth" => self.serve_queue_depth = p(key, value)?,
            other => {
                return Err(Error::config(format!("unknown config key '{other}'")))
            }
        }
        Ok(())
    }

    /// Load `key = value` lines (TOML subset: comments with '#', blank
    /// lines and [section] headers ignored).
    pub fn apply_file(&mut self, path: &str) -> Result<()> {
        let text = std::fs::read_to_string(path)?;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() || line.starts_with('[') {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::config(format!("{path}:{}: expected key = value", lineno + 1))
            })?;
            self.apply_kv(k, v)?;
        }
        Ok(())
    }

    /// Sanity-check cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if !BACKENDS.contains(&self.backend.as_str()) {
            return Err(unknown_backend(&self.backend));
        }
        // rejects unknown tier names and tiers this CPU cannot run
        self.resolved_simd()?;
        if self.image_size == 0 || self.image_size % 8 != 0 {
            return Err(Error::config(format!(
                "image_size {} must be a positive multiple of 8",
                self.image_size
            )));
        }
        match self.data.as_str() {
            "synth" => {}
            other => {
                let Some(variant) = CifarVariant::from_name(other) else {
                    return Err(unknown_data(other));
                };
                if self.data_dir.is_empty() {
                    return Err(Error::config(format!(
                        "data = {} needs data_dir (the directory holding the \
                         binary batch files)",
                        self.data
                    )));
                }
                if self.image_size != cifar::CIFAR_HW {
                    return Err(Error::config(format!(
                        "data = {} requires image_size {}, config has {}",
                        self.data,
                        cifar::CIFAR_HW,
                        self.image_size
                    )));
                }
                if self.num_classes != variant.num_classes() {
                    return Err(Error::config(format!(
                        "data = {} has {} classes, config has num_classes {}",
                        self.data,
                        variant.num_classes(),
                        self.num_classes
                    )));
                }
            }
        }
        if self.lb_devices != self.workers * self.group_devices {
            return Err(Error::config(format!(
                "lb_devices {} must equal workers {} x group_devices {}",
                self.lb_devices, self.workers, self.group_devices
            )));
        }
        let need = |b: usize, what: &str| -> Result<()> {
            if b * self.exec_batch > self.n_train {
                return Err(Error::config(format!(
                    "{what}: global batch {} exceeds n_train {}",
                    b * self.exec_batch,
                    self.n_train
                )));
            }
            Ok(())
        };
        need(self.lb_devices, "large batch")?;
        need(self.sb_devices, "small batch")?;
        if self.runs == 0 {
            return Err(Error::config("runs must be >= 1"));
        }
        if self.min_workers == 0 || self.min_workers > self.workers {
            return Err(Error::config(format!(
                "min_workers {} must be in 1..={} (workers)",
                self.min_workers, self.workers
            )));
        }
        if self.heartbeat_ms >= self.io_timeout_ms {
            return Err(Error::config(format!(
                "heartbeat_ms {} must be below io_timeout_ms {} or live \
                 workers get dropped between heartbeats",
                self.heartbeat_ms, self.io_timeout_ms
            )));
        }
        let spec = self.averaging_spec()?;
        if spec.needs_validation() && self.val_examples == 0 {
            return Err(Error::config(format!(
                "averaging = {} scores candidates on a validation split; \
                 set val_examples > 0",
                self.averaging
            )));
        }
        if self.val_examples > 0 && self.data != "synth" {
            // disk sources carve the split off the train tail, so the
            // remaining train set must still feed every baseline's batch
            let widest = self.lb_devices.max(self.sb_devices) * self.exec_batch;
            if self.val_examples + widest > self.n_train {
                return Err(Error::config(format!(
                    "val_examples {} leaves fewer than one global batch \
                     ({widest}) of the {} train examples",
                    self.val_examples, self.n_train
                )));
            }
        }
        if self.phase1_record_every == 0 {
            return Err(Error::config("phase1_record_every must be >= 1"));
        }
        if self.serve_max_batch == 0 {
            return Err(Error::config("serve_max_batch must be >= 1"));
        }
        crate::serving::ServeTier::from_knob(&self.serve_quant)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_loads_and_validates() {
        for name in ["tiny", "native", "cifar10sim", "cifar100sim", "imagenetsim"] {
            let cfg = preset(name).unwrap();
            cfg.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(cfg.preset, name);
            assert_eq!(cfg.backend, "native");
        }
        assert!(preset("nope").is_err());
    }

    #[test]
    fn backend_selection() {
        let mut cfg = preset("tiny").unwrap();
        let b = cfg.load_backend().unwrap();
        assert_eq!(b.name(), "native");
        assert_eq!(b.manifest().model.width, cfg.model_width);
        assert_eq!(b.manifest().model.num_classes, cfg.num_classes);
        cfg.apply_kv("backend", "nonsense").unwrap();
        assert!(cfg.validate().is_err());
        assert!(cfg.load_backend().is_err());
        // the xla backend needs --features xla and artifacts; without
        // either, selection must fail with a config/io error, not panic
        cfg.apply_kv("backend", "xla").unwrap();
        cfg.validate().unwrap();
        assert!(cfg.load_backend().is_err());
    }

    #[test]
    fn apply_kv_overrides() {
        let mut cfg = preset("tiny").unwrap();
        cfg.apply_kv("runs", "7").unwrap();
        assert_eq!(cfg.runs, 7);
        cfg.apply_kv("threads", "3").unwrap();
        assert_eq!(cfg.threads, 3);
        assert_eq!(cfg.resolved_threads(), 3);
        cfg.apply_kv("sb_peak_lr", "0.42").unwrap();
        assert!((cfg.sb_peak_lr - 0.42).abs() < 1e-6);
        cfg.apply_kv("augment", "false").unwrap();
        assert!(!cfg.augment);
        assert!(cfg.apply_kv("nonsense", "1").is_err());
        assert!(cfg.apply_kv("runs", "notanumber").is_err());
    }

    #[test]
    fn apply_file_parses_toml_subset() {
        let path = std::env::temp_dir().join(format!("swap-cfg-{}.toml", std::process::id()));
        std::fs::write(&path, "# comment\n[section]\nruns = 5\nseed=123 # trailing\n").unwrap();
        let mut cfg = preset("tiny").unwrap();
        cfg.apply_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg.runs, 5);
        assert_eq!(cfg.seed, 123);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn validate_catches_inconsistency() {
        let mut cfg = preset("tiny").unwrap();
        cfg.lb_devices = 3; // != workers * group_devices
        assert!(cfg.validate().is_err());
        let mut cfg = preset("tiny").unwrap();
        cfg.n_train = 8; // smaller than the LB global batch
        assert!(cfg.validate().is_err());
        let mut cfg = preset("tiny").unwrap();
        cfg.apply_kv("min_workers", "0").unwrap();
        assert!(cfg.validate().is_err());
        cfg.apply_kv("min_workers", "99").unwrap();
        assert!(cfg.validate().is_err());
        let mut cfg = preset("tiny").unwrap();
        cfg.apply_kv("heartbeat_ms", "5000").unwrap();
        cfg.apply_kv("io_timeout_ms", "1000").unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn failure_policy_knobs_flow_through() {
        let mut cfg = preset("tiny").unwrap();
        cfg.apply_kv("addr", "127.0.0.1:7070").unwrap();
        cfg.apply_kv("min_workers", "2").unwrap();
        cfg.apply_kv("io_timeout_ms", "2500").unwrap();
        cfg.apply_kv("heartbeat_ms", "250").unwrap();
        cfg.apply_kv("straggler_ms", "4000").unwrap();
        cfg.apply_kv("join_retries", "7").unwrap();
        cfg.apply_kv("retry_backoff_ms", "100").unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.addr, "127.0.0.1:7070");
        let p = cfg.failure_policy();
        assert_eq!(p.min_workers, 2);
        assert_eq!(p.io_timeout.as_millis(), 2500);
        assert_eq!(p.heartbeat.as_millis(), 250);
        assert_eq!(p.straggler_grace.as_millis(), 4000);
        assert_eq!(p.join_retries, 7);
        assert_eq!(p.retry_backoff.as_millis(), 100);
    }

    #[test]
    fn phase1_and_serving_knobs_flow_through() {
        let mut cfg = preset("tiny").unwrap();
        assert!(!cfg.phase1_dist, "phase 1 stays in-process by default");
        assert_eq!(cfg.phase1_record_every, 1);
        cfg.apply_kv("phase1_dist", "true").unwrap();
        cfg.apply_kv("phase1_record_every", "4").unwrap();
        cfg.validate().unwrap();
        assert!(cfg.phase1_dist);
        assert_eq!(cfg.phase1_record_every, 4);
        cfg.apply_kv("phase1_record_every", "0").unwrap();
        assert!(cfg.validate().is_err());

        let mut cfg = preset("tiny").unwrap();
        assert_eq!(cfg.serve_queue_depth, 0, "queue depth defaults to auto");
        let auto = cfg.serve_config().queue_slots;
        assert!(auto >= cfg.serve_max_batch);
        cfg.apply_kv("serve_queue_depth", "97").unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.serve_config().queue_slots, 97);
        // a depth below one batch is raised to it: the batcher must be
        // able to hold at least one full batch
        cfg.apply_kv("serve_queue_depth", "1").unwrap();
        assert_eq!(cfg.serve_config().queue_slots, cfg.serve_max_batch.max(1));
    }

    #[test]
    fn data_knob_selects_and_validates() {
        let mut cfg = preset("cifar10sim").unwrap();
        assert_eq!(cfg.data, "synth");
        assert_eq!(cfg.data_source().unwrap().name(), "synth");
        // cifar10 needs a data_dir
        cfg.apply_kv("data", "cifar10").unwrap();
        assert!(cfg.validate().is_err());
        cfg.apply_kv("data_dir", "/tmp/cifar").unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.data_source().unwrap().name(), "cifar10");
        // class-count mismatch fails loudly
        cfg.apply_kv("data", "cifar100").unwrap();
        assert!(cfg.validate().is_err());
        // wrong image size (tiny preset is 16x16)
        let mut tiny = preset("tiny").unwrap();
        tiny.apply_kv("data", "cifar10").unwrap();
        tiny.apply_kv("data_dir", "/tmp/cifar").unwrap();
        assert!(tiny.validate().is_err());
        // unknown source rejected by both paths
        let mut bad = preset("tiny").unwrap();
        bad.apply_kv("data", "imagenet").unwrap();
        assert!(bad.validate().is_err());
        assert!(bad.data_source().is_err());
    }

    #[test]
    fn averaging_knobs_parse_and_validate() {
        use crate::coordinator::AveragingSpec;
        let mut cfg = preset("tiny").unwrap();
        assert_eq!(cfg.averaging, "uniform");
        assert_eq!(cfg.averaging_spec().unwrap(), AveragingSpec::Uniform);
        cfg.apply_kv("averaging", "swa").unwrap();
        assert_eq!(cfg.averaging_spec().unwrap(), AveragingSpec::Swa);
        cfg.validate().unwrap();
        cfg.apply_kv("averaging", "hierarchical").unwrap();
        cfg.apply_kv("avg_groups", "3").unwrap();
        assert_eq!(
            cfg.averaging_spec().unwrap(),
            AveragingSpec::Hierarchical { groups: 3 }
        );
        cfg.validate().unwrap();
        // adaptive needs a validation split
        cfg.apply_kv("averaging", "adaptive").unwrap();
        cfg.apply_kv("avg_window", "2").unwrap();
        cfg.apply_kv("avg_min_improve", "0.01").unwrap();
        assert!(cfg.validate().is_err(), "adaptive without val_examples");
        cfg.apply_kv("val_examples", "16").unwrap();
        cfg.validate().unwrap();
        match cfg.averaging_spec().unwrap() {
            AveragingSpec::Adaptive { window, min_improve } => {
                assert_eq!(window, 2);
                assert!((min_improve - 0.01).abs() < 1e-12);
            }
            other => panic!("wrong spec: {other:?}"),
        }
        // unknown policy / degenerate parameters fail loudly
        cfg.apply_kv("averaging", "nonsense").unwrap();
        assert!(cfg.averaging_spec().is_err());
        assert!(cfg.validate().is_err());
        let mut cfg = preset("tiny").unwrap();
        cfg.apply_kv("averaging", "hierarchical").unwrap();
        cfg.apply_kv("avg_groups", "0").unwrap();
        assert!(cfg.validate().is_err());
        // disk sources carve val off the train tail — it must leave at
        // least one global batch standing
        let mut cfg = preset("cifar10sim").unwrap();
        cfg.apply_kv("data", "cifar10").unwrap();
        cfg.apply_kv("data_dir", "/tmp/cifar").unwrap();
        cfg.apply_kv("val_examples", "100000").unwrap();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn prefetch_knob_parses() {
        let mut cfg = preset("tiny").unwrap();
        assert!(cfg.prefetch, "prefetch defaults on");
        cfg.apply_kv("prefetch", "false").unwrap();
        assert!(!cfg.prefetch);
        assert!(cfg.apply_kv("prefetch", "maybe").is_err());
        // without the env override the knob is authoritative
        if std::env::var("SWAP_PREFETCH").is_err() {
            assert!(!cfg.resolved_prefetch());
        }
    }

    #[test]
    fn threads_zero_resolves_to_auto() {
        let mut cfg = preset("tiny").unwrap();
        cfg.threads = 0;
        assert!(cfg.resolved_threads() >= 1);
        // the native spec inherits the resolved count
        cfg.threads = 2;
        assert_eq!(cfg.native_spec().threads, 2);
    }

    #[test]
    fn simd_knob_resolves_and_validates() {
        let mut cfg = preset("tiny").unwrap();
        assert_eq!(cfg.simd, "auto");
        let auto = cfg.resolved_simd().unwrap();
        assert!(auto.available());
        cfg.validate().unwrap();
        // unknown tier names fail validation loudly (unless the env
        // override is set, in which case it wins — CI's scalar lane)
        cfg.apply_kv("simd", "sse9").unwrap();
        assert_eq!(cfg.simd, "sse9");
        if std::env::var("SWAP_SIMD").is_err() {
            assert!(cfg.validate().is_err());
            assert!(cfg.load_backend().is_err());
            // scalar is available on every host
            cfg.apply_kv("simd", "scalar").unwrap();
            assert_eq!(cfg.resolved_simd().unwrap(), crate::util::simd::Tier::Scalar);
        }
    }

    #[test]
    fn schedules_have_positive_peaks() {
        let cfg = preset("cifar10sim").unwrap();
        let spe = cfg.n_train / (cfg.sb_devices * cfg.exec_batch);
        let s = cfg.sb_schedule(spe);
        let peak = (0..cfg.sb_epochs * spe).map(|t| s.lr(t)).fold(0.0f32, f32::max);
        assert!((peak - cfg.sb_peak_lr).abs() < 0.05 * cfg.sb_peak_lr);
        // phase-2 schedule starts near its peak (no warmup)
        let p2 = cfg.phase2_schedule(spe);
        assert!(p2.lr(1) > 0.8 * cfg.phase2_peak_lr);
    }
}
