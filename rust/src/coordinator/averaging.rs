//! Pluggable averaging policies — the "what and when to average" axis of
//! the paper (phase 3 of Algorithm 1), refactored from a single hard-coded
//! terminal mean into an experimentable subsystem.
//!
//! A policy *observes* candidate weight vectors as they are produced —
//! phase-2 worker replicas ([`CandidateKind::Worker`]), SWA end-of-cycle
//! samples ([`CandidateKind::CycleEnd`]), or periodic checkpoints /
//! local-SGD replicas ([`CandidateKind::Checkpoint`]) — and maintains a
//! **streaming** running average on the flat arena via the chunk-parallel
//! `tensor::flat` kernels. No policy retains O(candidates x W) clones; the
//! only exception is the last-k window of the adaptive policy, which is
//! bounded by its (small, configured) window cap.
//!
//! The four policies and their lineage (PAPERS.md):
//! * [`AveragingSpec::Uniform`] — the paper's phase-3 mean over all
//!   candidates, streamed in observation order. **Bitwise-pinned** against
//!   the legacy `ParamSet::average_mt`: a running sum built by one
//!   elementwise `flat::add` per candidate followed by a single terminal
//!   `scale(1/n)` reproduces `flat::mean_into`'s accumulation order
//!   `((s0 + s1) + s2 + ...) * (1/n)` bit for bit.
//! * [`AveragingSpec::Swa`] — Izmailov et al. 2018: the incremental SWA
//!   recurrence `avg <- (avg * n + x) / (n + 1)` over cyclic-LR samples.
//!   Same mathematical mean as Uniform, different f32 rounding (the
//!   historical SWA implementation's arithmetic).
//! * [`AveragingSpec::Hierarchical`] — Gu et al. (Hierarchical Weight
//!   Averaging): candidates are routed to `groups` round-robin by id
//!   (`id % groups`), each group keeps an *online* streaming mean, and the
//!   final average is the *offline* mean of the group means. With
//!   `groups = 1` this degenerates to Uniform exactly (bitwise: the
//!   across-group `mean_into` over one set multiplies by 1.0, which is
//!   IEEE-exact).
//! * [`AveragingSpec::Adaptive`] — validation-gated late-window averaging:
//!   Demir & Ünal's Adaptive SWA start rule (begin averaging when the
//!   held-out validation accuracy stops improving) combined with Ajroldi
//!   et al.'s LAWA-style last-k checkpoint window. Requires candidates
//!   scored on a validation split (`Candidate::val_acc`); callers thread
//!   one through `TrainEnv::val` / the `val_examples` config knob.
//!
//! Determinism contract: every policy is elementwise over the arena, so
//! results are bitwise-identical for every `threads` value, and a policy's
//! output is a pure function of the observation sequence — transports,
//! resume, and thread counts can never change which bits come out.

use std::fmt;

use super::trainer::TrainEnv;
use crate::model::ParamSet;
use crate::sim::ClusterClock;
use crate::util::{Error, Json, Result};

/// The selectable policy names (config `averaging` knob) — single source
/// for parsing and error messages.
pub const POLICIES: &[&str] = &["uniform", "swa", "hierarchical", "adaptive"];

/// A parsed, validated averaging-policy configuration: which policy plus
/// its knobs. Cheap to clone; `build()` mints the stateful policy.
#[derive(Debug, Clone, PartialEq)]
pub enum AveragingSpec {
    /// the paper's phase-3 uniform mean, streamed (bitwise == legacy)
    Uniform,
    /// incremental SWA recurrence (Izmailov et al.)
    Swa,
    /// online within-group + offline across-group (Gu et al.)
    Hierarchical { groups: usize },
    /// validation-gated start + last-k window (Demir; Ajroldi et al.)
    Adaptive { window: usize, min_improve: f64 },
}

impl Default for AveragingSpec {
    fn default() -> Self {
        AveragingSpec::Uniform
    }
}

impl fmt::Display for AveragingSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.id())
    }
}

impl AveragingSpec {
    /// Build a spec from the flat config knobs, validating ranges.
    pub fn from_knobs(
        name: &str,
        groups: usize,
        window: usize,
        min_improve: f64,
    ) -> Result<AveragingSpec> {
        match name.trim() {
            "uniform" => Ok(AveragingSpec::Uniform),
            "swa" => Ok(AveragingSpec::Swa),
            "hierarchical" => {
                if groups == 0 {
                    return Err(Error::config("averaging: avg_groups must be >= 1"));
                }
                Ok(AveragingSpec::Hierarchical { groups })
            }
            "adaptive" => {
                if window == 0 {
                    return Err(Error::config("averaging: avg_window must be >= 1"));
                }
                if !(0.0..=1.0).contains(&min_improve) {
                    return Err(Error::config(format!(
                        "averaging: avg_min_improve {min_improve} must be in [0, 1]"
                    )));
                }
                Ok(AveragingSpec::Adaptive { window, min_improve })
            }
            other => Err(Error::config(format!(
                "unknown averaging policy '{other}' (expected one of: {})",
                POLICIES.join("|")
            ))),
        }
    }

    /// Canonical identity string — joins the run fingerprint, so resuming
    /// a run directory under a different policy hard-errors.
    pub fn id(&self) -> String {
        match self {
            AveragingSpec::Uniform => "uniform".to_string(),
            AveragingSpec::Swa => "swa".to_string(),
            AveragingSpec::Hierarchical { groups } => format!("hierarchical(groups={groups})"),
            AveragingSpec::Adaptive { window, min_improve } => {
                format!("adaptive(window={window},min_improve={min_improve})")
            }
        }
    }

    /// Whether candidates must arrive scored on a held-out validation
    /// split (`Candidate::val_acc`).
    pub fn needs_validation(&self) -> bool {
        matches!(self, AveragingSpec::Adaptive { .. })
    }

    /// Mint a fresh stateful policy.
    pub fn build(&self) -> Box<dyn AveragingPolicy> {
        match self {
            AveragingSpec::Uniform => Box::new(UniformPolicy::new()),
            AveragingSpec::Swa => Box::new(SwaPolicy::new()),
            AveragingSpec::Hierarchical { groups } => Box::new(HierarchicalPolicy::new(*groups)),
            AveragingSpec::Adaptive { window, min_improve } => {
                Box::new(AdaptivePolicy::new(*window, *min_improve))
            }
        }
    }
}

/// Where a candidate weight vector came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CandidateKind {
    /// phase-2 worker replica (SWAP phase 3); id = worker id
    Worker(usize),
    /// end-of-cycle low-LR sample (SWA); id = cycle index
    CycleEnd(usize),
    /// periodic checkpoint / local-SGD replica; id = ordinal
    Checkpoint(usize),
}

impl CandidateKind {
    /// The stable id hierarchical grouping routes on.
    pub fn id(&self) -> usize {
        match self {
            CandidateKind::Worker(w) => *w,
            CandidateKind::CycleEnd(c) => *c,
            CandidateKind::Checkpoint(k) => *k,
        }
    }

    fn label(&self) -> String {
        match self {
            CandidateKind::Worker(w) => format!("worker {w}"),
            CandidateKind::CycleEnd(c) => format!("cycle {c}"),
            CandidateKind::Checkpoint(k) => format!("checkpoint {k}"),
        }
    }
}

/// Metadata accompanying one observed candidate.
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    pub kind: CandidateKind,
    /// held-out validation top-1 accuracy of this candidate, if the caller
    /// has a validation split (required by validation-gated policies)
    pub val_acc: Option<f64>,
}

/// A streaming averaging policy over the flat weight arena.
///
/// Contract: `observe` is called once per candidate, in a deterministic
/// order fixed by the caller (workers sorted by id, cycles in sequence);
/// `average` may be called at any point after at least one observation and
/// does not consume the policy. All arena arithmetic must go through the
/// chunk-parallel `tensor::flat` kernels so results are bitwise-identical
/// for every `threads` value.
pub trait AveragingPolicy: Send {
    fn name(&self) -> &'static str;

    /// Whether this policy requires `Candidate::val_acc` to be present.
    fn needs_validation(&self) -> bool {
        false
    }

    /// Feed one candidate weight vector into the running state.
    fn observe(&mut self, params: &ParamSet, meta: Candidate, threads: usize) -> Result<()>;

    /// The current averaged model (errors before the first observation).
    fn average(&self, threads: usize) -> Result<ParamSet>;

    /// Number of candidates contributing to the current average.
    fn contributing(&self) -> usize;

    /// Serializable policy state (scalars, never weights) — persisted in
    /// `run.meta.json` by resumable runs.
    fn state(&self) -> Json;
}

// ----------------------------------------------------------------------
// Streaming mean primitive
// ----------------------------------------------------------------------

/// Running sum + count with a terminal scale: the streaming form of
/// `flat::mean_into`. Candidate 0 is cloned into the sum arena; each later
/// candidate is added elementwise (`flat::add`) in observation order; the
/// mean is `sum * (1/n)` computed once at read time. Per element that is
/// `((s0 + s1) + s2 + ...) * (1/n)` — exactly `mean_into`'s accumulation
/// order, so the streamed mean is bitwise-identical to the legacy terminal
/// `ParamSet::average_mt` while holding ONE arena instead of n.
#[derive(Debug, Default)]
pub struct StreamingMean {
    sum: Option<ParamSet>,
    n: usize,
}

impl StreamingMean {
    pub fn new() -> StreamingMean {
        StreamingMean { sum: None, n: 0 }
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn push(&mut self, x: &ParamSet, threads: usize) -> Result<()> {
        match &mut self.sum {
            None => self.sum = Some(x.clone()),
            Some(sum) => sum.add_assign_mt(x, threads)?,
        }
        self.n += 1;
        Ok(())
    }

    pub fn mean(&self, threads: usize) -> Result<ParamSet> {
        let sum = self
            .sum
            .as_ref()
            .ok_or_else(|| Error::invalid("averaging: no candidates observed"))?;
        let mut out = sum.clone();
        out.scale(1.0 / self.n as f32, threads);
        Ok(out)
    }
}

// ----------------------------------------------------------------------
// Uniform — the paper's phase 3, streamed (bitwise-pinned vs legacy)
// ----------------------------------------------------------------------

/// Uniform mean over every observed candidate. The default everywhere;
/// bitwise-identical to the pre-refactor `ParamSet::average_mt` (pinned by
/// rust/tests/averaging_policy.rs and the `averaging` bench).
pub struct UniformPolicy {
    mean: StreamingMean,
}

impl UniformPolicy {
    pub fn new() -> UniformPolicy {
        UniformPolicy { mean: StreamingMean::new() }
    }
}

impl Default for UniformPolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl AveragingPolicy for UniformPolicy {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn observe(&mut self, params: &ParamSet, _meta: Candidate, threads: usize) -> Result<()> {
        self.mean.push(params, threads)
    }

    fn average(&self, threads: usize) -> Result<ParamSet> {
        self.mean.mean(threads)
    }

    fn contributing(&self) -> usize {
        self.mean.count()
    }

    fn state(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::str("uniform")),
            ("observed", Json::Num(self.mean.count() as f64)),
            ("contributing", Json::Num(self.contributing() as f64)),
        ])
    }
}

// ----------------------------------------------------------------------
// SWA — Izmailov et al.'s incremental recurrence
// ----------------------------------------------------------------------

/// The incremental SWA update `avg <- (avg * n + x) / (n + 1)`, kept
/// in-place on one arena. Mathematically the same uniform mean, but with
/// the rounding profile of the published SWA implementations (one
/// rescale per sample instead of a terminal scale).
pub struct SwaPolicy {
    avg: Option<ParamSet>,
    n: usize,
}

impl SwaPolicy {
    pub fn new() -> SwaPolicy {
        SwaPolicy { avg: None, n: 0 }
    }
}

impl Default for SwaPolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl AveragingPolicy for SwaPolicy {
    fn name(&self) -> &'static str {
        "swa"
    }

    fn observe(&mut self, params: &ParamSet, _meta: Candidate, threads: usize) -> Result<()> {
        match &mut self.avg {
            None => self.avg = Some(params.clone()),
            Some(avg) => {
                avg.scale(self.n as f32, threads);
                avg.add_assign_mt(params, threads)?;
                avg.scale(1.0 / (self.n + 1) as f32, threads);
            }
        }
        self.n += 1;
        Ok(())
    }

    fn average(&self, _threads: usize) -> Result<ParamSet> {
        self.avg
            .clone()
            .ok_or_else(|| Error::invalid("averaging: no candidates observed"))
    }

    fn contributing(&self) -> usize {
        self.n
    }

    fn state(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::str("swa")),
            ("observed", Json::Num(self.n as f64)),
            ("contributing", Json::Num(self.n as f64)),
        ])
    }
}

// ----------------------------------------------------------------------
// Hierarchical — Gu et al.: online within-group, offline across-group
// ----------------------------------------------------------------------

/// Candidates are routed round-robin to `groups` by `kind.id() % groups`;
/// each group keeps an online streaming mean and the final model is the
/// offline mean of the (non-empty) group means. `groups = 1` is bitwise
/// Uniform.
pub struct HierarchicalPolicy {
    groups: Vec<StreamingMean>,
}

impl HierarchicalPolicy {
    pub fn new(groups: usize) -> HierarchicalPolicy {
        assert!(groups >= 1, "hierarchical: groups must be >= 1");
        HierarchicalPolicy {
            groups: (0..groups).map(|_| StreamingMean::new()).collect(),
        }
    }
}

impl AveragingPolicy for HierarchicalPolicy {
    fn name(&self) -> &'static str {
        "hierarchical"
    }

    fn observe(&mut self, params: &ParamSet, meta: Candidate, threads: usize) -> Result<()> {
        let g = meta.kind.id() % self.groups.len();
        self.groups[g].push(params, threads)
    }

    fn average(&self, threads: usize) -> Result<ParamSet> {
        // online step: each non-empty group's streamed mean; offline step:
        // the terminal mean across groups (group order is fixed, so the
        // accumulation order — and hence every bit — is too)
        let mut group_means = Vec::with_capacity(self.groups.len());
        for g in &self.groups {
            if g.count() > 0 {
                group_means.push(g.mean(threads)?);
            }
        }
        if group_means.is_empty() {
            return Err(Error::invalid("averaging: no candidates observed"));
        }
        ParamSet::average_mt(&group_means, threads)
    }

    fn contributing(&self) -> usize {
        self.groups.iter().map(|g| g.count()).sum()
    }

    fn state(&self) -> Json {
        let counts: Vec<usize> = self.groups.iter().map(|g| g.count()).collect();
        Json::obj(vec![
            ("policy", Json::str("hierarchical")),
            ("groups", Json::Num(self.groups.len() as f64)),
            ("group_counts", Json::arr_usize(&counts)),
            ("observed", Json::Num(self.contributing() as f64)),
            ("contributing", Json::Num(self.contributing() as f64)),
        ])
    }
}

// ----------------------------------------------------------------------
// Adaptive — validation-gated start + last-k (LAWA-style) window
// ----------------------------------------------------------------------

/// Averaging starts once the held-out validation accuracy plateaus (the
/// first candidate that fails to beat the running best by more than
/// `min_improve` opens the gate and is included); from then on the model
/// is the uniform mean of the last `window` candidates. If the gate never
/// opens the average falls back to the last observed candidate (the most
/// trained model). The window retains at most `window` arenas — the one
/// policy with (bounded, configured) candidate retention, which is what
/// "late-window" means.
pub struct AdaptivePolicy {
    window_cap: usize,
    min_improve: f64,
    window: Vec<ParamSet>,
    /// fallback when the gate never opens (kept only pre-gate)
    last: Option<ParamSet>,
    best: Option<f64>,
    started: bool,
    /// observation ordinal (0-based) at which the gate opened
    opened_at: Option<usize>,
    seen: usize,
}

impl AdaptivePolicy {
    pub fn new(window: usize, min_improve: f64) -> AdaptivePolicy {
        assert!(window >= 1, "adaptive: window must be >= 1");
        AdaptivePolicy {
            window_cap: window,
            min_improve,
            window: Vec::new(),
            last: None,
            best: None,
            started: false,
            opened_at: None,
            seen: 0,
        }
    }
}

impl AveragingPolicy for AdaptivePolicy {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    fn needs_validation(&self) -> bool {
        true
    }

    fn observe(&mut self, params: &ParamSet, meta: Candidate, _threads: usize) -> Result<()> {
        let acc = meta.val_acc.ok_or_else(|| {
            Error::config(format!(
                "averaging policy 'adaptive' needs validation-scored candidates \
                 but {} arrived unscored: set val_examples > 0 so a held-out \
                 validation split is threaded through the run",
                meta.kind.label()
            ))
        })?;
        if !self.started {
            match self.best {
                // the first candidate only seeds the running best
                None => self.best = Some(acc),
                Some(best) if acc > best + self.min_improve => self.best = Some(acc),
                // no meaningful improvement: the plateau begins here
                _ => {
                    self.started = true;
                    self.opened_at = Some(self.seen);
                }
            }
        }
        if self.started {
            self.last = None; // the window supersedes the fallback
            self.window.push(params.clone());
            if self.window.len() > self.window_cap {
                // ParamSet is a thin handle (Vec + Arc), so evicting the
                // oldest entry shifts pointers, not weights
                self.window.remove(0);
            }
        } else {
            self.last = Some(params.clone());
        }
        self.seen += 1;
        Ok(())
    }

    fn average(&self, threads: usize) -> Result<ParamSet> {
        if !self.window.is_empty() {
            return ParamSet::average_mt(&self.window, threads);
        }
        self.last
            .clone()
            .ok_or_else(|| Error::invalid("averaging: no candidates observed"))
    }

    fn contributing(&self) -> usize {
        if self.window.is_empty() {
            usize::from(self.last.is_some())
        } else {
            self.window.len()
        }
    }

    fn state(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::str("adaptive")),
            ("observed", Json::Num(self.seen as f64)),
            ("contributing", Json::Num(self.contributing() as f64)),
            ("started", Json::Bool(self.started)),
            (
                "opened_at",
                self.opened_at.map_or(Json::Null, |k| Json::Num(k as f64)),
            ),
            ("best_val_acc", self.best.map_or(Json::Null, Json::Num)),
            ("window", Json::Num(self.window.len() as f64)),
            ("window_cap", Json::Num(self.window_cap as f64)),
            ("min_improve", Json::Num(self.min_improve)),
        ])
    }
}

// ----------------------------------------------------------------------
// Helpers shared by the coordinators
// ----------------------------------------------------------------------

/// One-shot consensus over a fixed set of replicas (local-SGD's every-H
/// sync and final model): a fresh policy observes each replica in index
/// order and the average is read once. With the default Uniform spec this
/// is bitwise-identical to the legacy `ParamSet::average_mt` call it
/// replaces. Validation-gated policies error here — a consensus round has
/// no scored candidates (and no plateau to detect).
pub fn consensus(spec: &AveragingSpec, replicas: &[ParamSet], threads: usize) -> Result<ParamSet> {
    if spec.needs_validation() {
        return Err(Error::config(format!(
            "averaging policy '{}' is validation-gated and cannot drive a \
             local-SGD consensus round; use uniform, swa, or hierarchical",
            spec.id()
        )));
    }
    let mut policy = spec.build();
    for (k, p) in replicas.iter().enumerate() {
        policy.observe(
            p,
            Candidate { kind: CandidateKind::Checkpoint(k), val_acc: None },
            threads,
        )?;
    }
    policy.average(threads)
}

/// Score a candidate for a validation-gated policy: `None` when the
/// policy doesn't need scores OR the environment has no validation split
/// (the policy's `observe` then raises the actionable config error).
/// Validation forward passes are booked as eval time, like the
/// reporting-only per-worker evaluations.
pub fn maybe_val_acc(
    policy: &dyn AveragingPolicy,
    env: &TrainEnv,
    params: &ParamSet,
    seed: u64,
    clock: &mut ClusterClock,
) -> Result<Option<f64>> {
    if !policy.needs_validation() {
        return Ok(None);
    }
    env.val_acc(params, seed, clock)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(v: &[f32]) -> ParamSet {
        ParamSet::from_vec(v.to_vec())
    }

    fn observe_all(policy: &mut dyn AveragingPolicy, sets: &[ParamSet]) {
        for (k, s) in sets.iter().enumerate() {
            policy
                .observe(
                    s,
                    Candidate { kind: CandidateKind::Worker(k), val_acc: Some(0.5) },
                    1,
                )
                .unwrap();
        }
    }

    #[test]
    fn spec_parsing_and_ids() {
        assert_eq!(
            AveragingSpec::from_knobs("uniform", 2, 4, 0.0).unwrap(),
            AveragingSpec::Uniform
        );
        assert_eq!(AveragingSpec::from_knobs("swa", 2, 4, 0.0).unwrap(), AveragingSpec::Swa);
        assert_eq!(
            AveragingSpec::from_knobs("hierarchical", 3, 4, 0.0).unwrap().id(),
            "hierarchical(groups=3)"
        );
        assert!(AveragingSpec::from_knobs("adaptive", 2, 4, 0.01)
            .unwrap()
            .needs_validation());
        assert!(AveragingSpec::from_knobs("nope", 2, 4, 0.0).is_err());
        assert!(AveragingSpec::from_knobs("hierarchical", 0, 4, 0.0).is_err());
        assert!(AveragingSpec::from_knobs("adaptive", 2, 0, 0.0).is_err());
        assert!(AveragingSpec::from_knobs("adaptive", 2, 4, 1.5).is_err());
        assert_eq!(AveragingSpec::default(), AveragingSpec::Uniform);
    }

    #[test]
    fn uniform_streams_bitwise_equal_to_terminal_mean() {
        let sets = vec![p(&[1.0, 0.25, -3.5]), p(&[0.5, 2.0, 1.0]), p(&[-0.125, 4.0, 0.75])];
        let legacy = ParamSet::average_mt(&sets, 1).unwrap();
        let mut pol = UniformPolicy::new();
        observe_all(&mut pol, &sets);
        assert_eq!(pol.average(1).unwrap(), legacy);
        assert_eq!(pol.contributing(), 3);
    }

    #[test]
    fn swa_recurrence_matches_scalar_reference() {
        let sets = vec![p(&[1.0]), p(&[2.0]), p(&[4.0])];
        let mut pol = SwaPolicy::new();
        observe_all(&mut pol, &sets);
        // ((1*1 + 2)/2 * 2 + 4)/3 in f32
        let mut want = 1.0f32;
        for (n, x) in [2.0f32, 4.0].iter().enumerate() {
            want = (want * (n + 1) as f32 + x) * (1.0 / (n + 2) as f32);
        }
        assert_eq!(pol.average(1).unwrap().data(), &[want]);
    }

    #[test]
    fn hierarchical_groups_round_robin() {
        // groups=2: ids 0,2 -> group 0 (mean 2.0); id 1 -> group 1 (4.0);
        // final = (2.0 + 4.0) / 2 = 3.0
        let sets = vec![p(&[1.0]), p(&[4.0]), p(&[3.0])];
        let mut pol = HierarchicalPolicy::new(2);
        observe_all(&mut pol, &sets);
        assert_eq!(pol.average(1).unwrap().data(), &[3.0]);
        assert_eq!(pol.contributing(), 3);
        let st = pol.state();
        assert_eq!(st.get("groups").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn hierarchical_single_group_is_bitwise_uniform() {
        let sets = vec![p(&[0.1, -0.2]), p(&[0.7, 0.3]), p(&[-1.1, 0.9])];
        let mut uni = UniformPolicy::new();
        let mut hier = HierarchicalPolicy::new(1);
        observe_all(&mut uni, &sets);
        observe_all(&mut hier, &sets);
        assert_eq!(uni.average(1).unwrap(), hier.average(1).unwrap());
    }

    #[test]
    fn adaptive_gates_on_plateau_and_windows() {
        let mut pol = AdaptivePolicy::new(2, 0.0);
        let obs = |pol: &mut AdaptivePolicy, v: f32, acc: f64, k: usize| {
            pol.observe(
                &p(&[v]),
                Candidate { kind: CandidateKind::CycleEnd(k), val_acc: Some(acc) },
                1,
            )
            .unwrap();
        };
        // rising: 0.2 -> 0.4 (gate closed, fallback tracks the last)
        obs(&mut pol, 1.0, 0.2, 0);
        obs(&mut pol, 2.0, 0.4, 1);
        assert_eq!(pol.average(1).unwrap().data(), &[2.0]);
        assert_eq!(pol.contributing(), 1);
        // plateau at 0.4: gate opens, window starts here
        obs(&mut pol, 4.0, 0.4, 2);
        obs(&mut pol, 6.0, 0.41, 3);
        obs(&mut pol, 8.0, 0.39, 4);
        // window cap 2 keeps the last two: (6 + 8) / 2
        assert_eq!(pol.average(1).unwrap().data(), &[7.0]);
        let st = pol.state();
        assert_eq!(st.get("started").unwrap().as_bool(), Some(true));
        assert_eq!(st.get("opened_at").unwrap().as_usize(), Some(2));
        assert_eq!(st.get("observed").unwrap().as_usize(), Some(5));
    }

    #[test]
    fn adaptive_requires_val_scores() {
        let mut pol = AdaptivePolicy::new(2, 0.0);
        let err = pol
            .observe(
                &p(&[1.0]),
                Candidate { kind: CandidateKind::Worker(0), val_acc: None },
                1,
            )
            .unwrap_err()
            .to_string();
        assert!(err.contains("val_examples"), "{err}");
    }

    #[test]
    fn consensus_uniform_matches_average_mt() {
        let sets = vec![p(&[1.0, 2.0]), p(&[3.0, -4.0]), p(&[0.5, 0.5])];
        let legacy = ParamSet::average_mt(&sets, 1).unwrap();
        let got = consensus(&AveragingSpec::Uniform, &sets, 1).unwrap();
        assert_eq!(got, legacy);
        // validation-gated policies cannot drive a consensus round
        let spec = AveragingSpec::Adaptive { window: 2, min_improve: 0.0 };
        assert!(consensus(&spec, &sets, 1).is_err());
    }

    #[test]
    fn empty_policies_error() {
        for spec in [
            AveragingSpec::Uniform,
            AveragingSpec::Swa,
            AveragingSpec::Hierarchical { groups: 2 },
            AveragingSpec::Adaptive { window: 2, min_improve: 0.0 },
        ] {
            assert!(spec.build().average(1).is_err(), "{}", spec.id());
        }
    }
}
