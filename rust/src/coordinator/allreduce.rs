//! Gradient all-reduce: an actual ring-reduce-scatter + all-gather schedule
//! over in-process worker shards (the Horovod algorithm the paper runs),
//! plus the trivial mean as an oracle. The property tests assert the ring
//! schedule produces exactly the arithmetic mean; the α–β *cost* of the
//! ring lives in `sim::NetModel`.
//!
//! Since the flat-arena refactor gradients arrive already flattened
//! (manifest order), so [`ring_mean_inplace`] runs the whole schedule in
//! place with ZERO allocation: within one ring step no (worker, chunk)
//! pair is both read and written — the receiver adds the sender's send
//! chunk into its own copy of that same chunk, while each worker only ever
//! writes a *different* chunk of its own buffer — so the per-step chunk
//! snapshots the legacy implementation cloned were pure overhead. The
//! element order of every addition is unchanged, so the result is bitwise
//! identical to the legacy path (pinned by rust/tests/weightspace.rs).
//!
//! [`ring_mean_reference`] keeps the legacy `Vec<Tensor>` implementation
//! as the oracle for parity tests and the old-vs-new bench rows.

use std::ops::Range;

use crate::tensor::Tensor;
use crate::util::{Error, Result};

/// Naive oracle: elementwise mean of the workers' gradient sets (legacy
/// per-tensor representation).
pub fn naive_mean(worker_grads: &[Vec<Tensor>]) -> Result<Vec<Tensor>> {
    crate::tensor::average_sets(worker_grads)
}

/// Split borrow of two distinct worker buffers: (&mut xs[i], &xs[j]).
fn pair_mut<'a>(xs: &'a mut [Vec<f32>], i: usize, j: usize) -> (&'a mut [f32], &'a [f32]) {
    debug_assert_ne!(i, j);
    if i < j {
        let (lo, hi) = xs.split_at_mut(j);
        (lo[i].as_mut_slice(), hi[0].as_slice())
    } else {
        let (lo, hi) = xs.split_at_mut(i);
        (hi[0].as_mut_slice(), lo[j].as_slice())
    }
}

/// Ring all-reduce over W workers' flat gradient arenas, fully in place.
///
/// Implements the standard two-phase schedule on W chunks:
///   * reduce-scatter: in step s, worker w sends chunk (w - s) and adds the
///     received chunk into its accumulator; after W-1 steps worker w owns
///     the fully-reduced chunk (w + 1).
///   * all-gather: the owned chunks circulate for W-1 more steps.
///
/// On return every buffer holds the full elementwise SUM and `workers[0]`
/// has been divided by W — i.e. `workers[0]` is the averaged gradient
/// arena. No allocation takes place.
pub fn ring_mean_inplace(workers: &mut [Vec<f32>]) -> Result<()> {
    let w = workers.len();
    if w == 0 {
        return Err(Error::invalid("ring_mean: no workers"));
    }
    let total = workers[0].len();
    if workers.iter().any(|v| v.len() != total) {
        return Err(Error::shape("ring_mean: inconsistent gradient sizes"));
    }
    if w == 1 {
        return Ok(()); // the mean of one worker is itself
    }
    // chunk boundaries (W chunks, last one takes the remainder)
    let chunk = |c: usize| -> Range<usize> {
        let per = total / w;
        let start = c * per;
        let end = if c == w - 1 { total } else { start + per };
        start..end
    };

    // reduce-scatter: worker r receives the chunk its ring predecessor
    // sends and accumulates it in place
    for s in 0..w - 1 {
        for r in 0..w {
            let sender = (r + w - 1) % w;
            let c = (sender + w - s) % w;
            let rng = chunk(c);
            let (dst, src) = pair_mut(workers, r, sender);
            for (d, &v) in dst[rng.clone()].iter_mut().zip(&src[rng]) {
                *d += v;
            }
        }
    }
    // after reduce-scatter, worker r owns fully-reduced chunk (r + 1) % w
    // all-gather: the owned chunks circulate
    for s in 0..w - 1 {
        for r in 0..w {
            let sender = (r + w - 1) % w;
            let c = (sender + 1 + w - s) % w;
            let rng = chunk(c);
            let (dst, src) = pair_mut(workers, r, sender);
            dst[rng.clone()].copy_from_slice(&src[rng]);
        }
    }

    // every worker now holds the identical full sum; divide worker 0
    let inv = 1.0 / w as f32;
    for x in workers[0].iter_mut() {
        *x *= inv;
    }
    Ok(())
}

/// Legacy reference: the same ring schedule over per-tensor sets, with
/// explicit flatten + per-step snapshot copies. Kept as the bitwise oracle
/// for [`ring_mean_inplace`] (tests) and the old-vs-new bench rows.
pub fn ring_mean_reference(worker_grads: &[Vec<Tensor>]) -> Result<Vec<Tensor>> {
    let w = worker_grads.len();
    if w == 0 {
        return Err(Error::invalid("ring_mean: no workers"));
    }
    if w == 1 {
        return Ok(worker_grads[0].clone());
    }
    let shapes: Vec<Vec<usize>> = worker_grads[0].iter().map(|t| t.shape().to_vec()).collect();
    let total: usize = worker_grads[0].iter().map(|t| t.numel()).sum();
    let mut flat: Vec<Vec<f32>> = worker_grads
        .iter()
        .map(|set| {
            if set.len() != shapes.len() {
                return Err(Error::shape("ring_mean: ragged worker sets"));
            }
            let mut v = Vec::with_capacity(total);
            for t in set {
                v.extend_from_slice(t.data());
            }
            Ok(v)
        })
        .collect::<Result<Vec<_>>>()?;
    if flat.iter().any(|v| v.len() != total) {
        return Err(Error::shape("ring_mean: inconsistent gradient sizes"));
    }
    let chunk = |c: usize| -> Range<usize> {
        let per = total / w;
        let start = c * per;
        let end = if c == w - 1 { total } else { start + per };
        start..end
    };
    // reduce-scatter with per-step snapshots (the legacy allocation)
    for s in 0..w - 1 {
        let snapshots: Vec<Vec<f32>> = (0..w)
            .map(|r| {
                let c = (r + w - s) % w; // chunk each worker SENDS this step
                flat[r][chunk(c)].to_vec()
            })
            .collect();
        for r in 0..w {
            let sender = (r + w - 1) % w;
            let c = (sender + w - s) % w;
            let rng = chunk(c);
            let recv = &snapshots[sender];
            for (dst, src) in flat[r][rng].iter_mut().zip(recv) {
                *dst += src;
            }
        }
    }
    // all-gather
    for s in 0..w - 1 {
        let snapshots: Vec<(usize, Vec<f32>)> = (0..w)
            .map(|r| {
                let c = (r + 1 + w - s) % w;
                (c, flat[r][chunk(c)].to_vec())
            })
            .collect();
        for r in 0..w {
            let sender = (r + w - 1) % w;
            let (c, ref data) = snapshots[sender];
            let rng = chunk(c);
            flat[r][rng].copy_from_slice(data);
        }
    }
    let inv = 1.0 / w as f32;
    let result = &mut flat[0];
    for x in result.iter_mut() {
        *x *= inv;
    }
    let mut out = Vec::with_capacity(shapes.len());
    let mut off = 0;
    for shape in &shapes {
        let n: usize = shape.iter().product();
        out.push(Tensor::new(shape.clone(), result[off..off + n].to_vec())?);
        off += n;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::property;

    fn rand_flat_sets(g: &mut crate::testutil::Gen, w: usize, n: usize) -> Vec<Vec<f32>> {
        (0..w)
            .map(|_| (0..n).map(|_| g.normal()).collect())
            .collect()
    }

    #[test]
    fn ring_equals_naive_mean_property() {
        property(60, |g| {
            let w = g.usize_in(1..9);
            let n = g.usize_in(1..60);
            let sets = rand_flat_sets(g, w, n);
            let mut ring = sets.clone();
            ring_mean_inplace(&mut ring).unwrap();
            for j in 0..n {
                let naive: f64 =
                    sets.iter().map(|s| s[j] as f64).sum::<f64>() / w as f64;
                let got = ring[0][j] as f64;
                assert!(
                    (got - naive).abs() <= 1e-5 * (1.0 + naive.abs()),
                    "{got} vs {naive} (W={w}, j={j})"
                );
            }
        });
    }

    #[test]
    fn inplace_matches_reference_bitwise() {
        // the no-snapshot schedule must reproduce the legacy ring exactly
        property(40, |g| {
            let w = g.usize_in(2..8);
            let shapes = [g.usize_in(1..20), g.usize_in(1..9)];
            let tensor_sets: Vec<Vec<Tensor>> = (0..w)
                .map(|_| {
                    shapes
                        .iter()
                        .map(|&n| {
                            Tensor::new(vec![n], (0..n).map(|_| g.normal()).collect()).unwrap()
                        })
                        .collect()
                })
                .collect();
            let mut flat_sets: Vec<Vec<f32>> = tensor_sets
                .iter()
                .map(|set| {
                    let mut v = Vec::new();
                    for t in set {
                        v.extend_from_slice(t.data());
                    }
                    v
                })
                .collect();
            let reference = ring_mean_reference(&tensor_sets).unwrap();
            ring_mean_inplace(&mut flat_sets).unwrap();
            let mut ref_flat = Vec::new();
            for t in &reference {
                ref_flat.extend_from_slice(t.data());
            }
            assert_eq!(flat_sets[0], ref_flat, "W={w}");
        });
    }

    #[test]
    fn single_worker_identity() {
        let mut set = vec![vec![1.0f32, 2.0, 3.0]];
        ring_mean_inplace(&mut set).unwrap();
        assert_eq!(set[0], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn two_workers_mean() {
        let mut sets = vec![vec![0.0f32, 4.0], vec![2.0f32, 0.0]];
        ring_mean_inplace(&mut sets).unwrap();
        assert_eq!(sets[0], vec![1.0, 2.0]);
    }

    #[test]
    fn tiny_buffer_fewer_elements_than_workers() {
        // total elements < W exercises the degenerate chunking path
        let mut sets: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32, 1.0]).collect();
        ring_mean_inplace(&mut sets).unwrap();
        assert!((sets[0][0] - 2.0).abs() < 1e-6);
        assert!((sets[0][1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn ragged_and_empty_error() {
        assert!(ring_mean_inplace(&mut []).is_err());
        let mut ragged = vec![vec![1.0f32], vec![1.0f32, 2.0]];
        assert!(ring_mean_inplace(&mut ragged).is_err());
        assert!(ring_mean_reference(&[]).is_err());
    }

    #[test]
    fn reference_two_workers_mean() {
        let a = vec![Tensor::new(vec![2], vec![0.0, 4.0]).unwrap()];
        let b = vec![Tensor::new(vec![2], vec![2.0, 0.0]).unwrap()];
        let m = ring_mean_reference(&[a, b]).unwrap();
        assert_eq!(m[0].data(), &[1.0, 2.0]);
    }
}
