//! Gradient all-reduce: an actual ring-reduce-scatter + all-gather schedule
//! over in-process worker shards (the Horovod algorithm the paper runs),
//! plus the trivial mean as an oracle. The property tests assert the ring
//! schedule produces exactly the arithmetic mean; the α–β *cost* of the
//! ring lives in `sim::NetModel`.

use crate::tensor::Tensor;
use crate::util::{Error, Result};

/// Naive oracle: elementwise mean of the workers' gradient sets.
pub fn naive_mean(worker_grads: &[Vec<Tensor>]) -> Result<Vec<Tensor>> {
    crate::tensor::average_sets(worker_grads)
}

/// Ring all-reduce over W workers' flattened gradients.
///
/// Implements the standard two-phase schedule on W chunks:
///   * reduce-scatter: in step s, worker w sends chunk (w - s) and adds the
///     received chunk into its accumulator; after W-1 steps worker w owns
///     the fully-reduced chunk (w + 1).
///   * all-gather: the owned chunks circulate for W-1 more steps.
///
/// Returns the averaged gradient set (divided by W at the end).
pub fn ring_mean(worker_grads: &[Vec<Tensor>]) -> Result<Vec<Tensor>> {
    let w = worker_grads.len();
    if w == 0 {
        return Err(Error::invalid("ring_mean: no workers"));
    }
    if w == 1 {
        return Ok(worker_grads[0].clone());
    }
    // Flatten each worker's set into one vector (the real implementation
    // fuses tensors into buckets exactly like this).
    let shapes: Vec<Vec<usize>> = worker_grads[0].iter().map(|t| t.shape().to_vec()).collect();
    let total: usize = worker_grads[0].iter().map(|t| t.numel()).sum();
    let mut flat: Vec<Vec<f32>> = worker_grads
        .iter()
        .map(|set| {
            if set.len() != shapes.len() {
                return Err(Error::shape("ring_mean: ragged worker sets"));
            }
            let mut v = Vec::with_capacity(total);
            for t in set {
                v.extend_from_slice(t.data());
            }
            Ok(v)
        })
        .collect::<Result<Vec<_>>>()?;
    if flat.iter().any(|v| v.len() != total) {
        return Err(Error::shape("ring_mean: inconsistent gradient sizes"));
    }

    // chunk boundaries (W chunks, last one takes the remainder)
    let chunk = |c: usize| -> std::ops::Range<usize> {
        let per = total / w;
        let start = c * per;
        let end = if c == w - 1 { total } else { start + per };
        start..end
    };

    // reduce-scatter
    for s in 0..w - 1 {
        // worker r receives chunk (r - s - 1) from worker (r - 1)
        let snapshots: Vec<Vec<f32>> = (0..w)
            .map(|r| {
                let c = (r + w - s) % w; // chunk each worker SENDS this step
                flat[r][chunk(c)].to_vec()
            })
            .collect();
        for r in 0..w {
            let sender = (r + w - 1) % w;
            let c = (sender + w - s) % w;
            let rng = chunk(c);
            let recv = &snapshots[sender];
            for (dst, src) in flat[r][rng].iter_mut().zip(recv) {
                *dst += src;
            }
        }
    }
    // after reduce-scatter, worker r owns fully-reduced chunk (r + 1) % w
    // all-gather
    for s in 0..w - 1 {
        let snapshots: Vec<(usize, Vec<f32>)> = (0..w)
            .map(|r| {
                let c = (r + 1 + w - s) % w; // chunk each worker sends
                (c, flat[r][chunk(c)].to_vec())
            })
            .collect();
        for r in 0..w {
            let sender = (r + w - 1) % w;
            let (c, ref data) = snapshots[sender];
            let rng = chunk(c);
            flat[r][rng].copy_from_slice(data);
        }
    }

    // every worker now holds the identical full sum; divide and un-flatten
    let inv = 1.0 / w as f32;
    let result = &mut flat[0];
    for x in result.iter_mut() {
        *x *= inv;
    }
    let mut out = Vec::with_capacity(shapes.len());
    let mut off = 0;
    for shape in &shapes {
        let n: usize = shape.iter().product();
        out.push(Tensor::new(shape.clone(), result[off..off + n].to_vec())?);
        off += n;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::property;

    fn rand_sets(g: &mut crate::testutil::Gen, w: usize) -> Vec<Vec<Tensor>> {
        let shapes: Vec<Vec<usize>> = vec![
            vec![g.usize_in(1..20)],
            vec![g.usize_in(1..7), g.usize_in(1..7)],
        ];
        (0..w)
            .map(|_| {
                shapes
                    .iter()
                    .map(|s| {
                        let n: usize = s.iter().product();
                        Tensor::new(s.clone(), (0..n).map(|_| g.normal()).collect()).unwrap()
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn ring_equals_naive_mean_property() {
        property(60, |g| {
            let w = g.usize_in(1..9);
            let sets = rand_sets(g, w);
            let ring = ring_mean(&sets).unwrap();
            let naive = naive_mean(&sets).unwrap();
            for (a, b) in ring.iter().zip(&naive) {
                assert_eq!(a.shape(), b.shape());
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert!((x - y).abs() <= 1e-5 * (1.0 + y.abs()), "{x} vs {y} (W={w})");
                }
            }
        });
    }

    #[test]
    fn single_worker_identity() {
        let set = vec![vec![Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap()]];
        assert_eq!(ring_mean(&set).unwrap(), set[0]);
    }

    #[test]
    fn two_workers_mean() {
        let a = vec![Tensor::new(vec![2], vec![0.0, 4.0]).unwrap()];
        let b = vec![Tensor::new(vec![2], vec![2.0, 0.0]).unwrap()];
        let m = ring_mean(&[a, b]).unwrap();
        assert_eq!(m[0].data(), &[1.0, 2.0]);
    }

    #[test]
    fn tiny_tensor_fewer_elements_than_workers() {
        // total elements < W exercises the degenerate chunking path
        let sets: Vec<Vec<Tensor>> = (0..5)
            .map(|i| vec![Tensor::new(vec![2], vec![i as f32, 1.0]).unwrap()])
            .collect();
        let m = ring_mean(&sets).unwrap();
        assert!((m[0].data()[0] - 2.0).abs() < 1e-6);
        assert!((m[0].data()[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn empty_errors() {
        assert!(ring_mean(&[]).is_err());
    }
}
