//! Crash-safe phase-1 progress record: an append-only binary log in the
//! run directory that lets a restarted `serve` (or an in-process resumable
//! run) re-enter the synchronous collective at the last completed sync
//! step instead of redoing phase 1 from step 0.
//!
//! File layout (`phase1.progress`, all integers little-endian):
//!
//! ```text
//! header (28 bytes):
//!   magic            8  b"SWP1PRG1"
//!   version          4  u32 = 1
//!   fingerprint      8  FNV-1a of the run fingerprint string
//!   arena_len        8  parameter count (u64)
//! entry (repeated, 140 bytes each):
//!   payload_len      4  u32 = 128
//!   checksum         8  FNV-1a of the payload bytes
//!   payload        128  16 x u64/f64 slots (see `encode_payload`)
//! ```
//!
//! Durability contract: each entry is appended with a single `write_all`
//! followed by `sync_all`, AFTER the step's weight/momentum part files
//! were atomically published (tmp + fsync + rename) and BEFORE the
//! previous step's parts are deleted — so at every crash point at least
//! one recorded step has both a valid entry and matching arenas on disk.
//! A torn tail write (partial length, short payload, or checksum
//! mismatch) invalidates only the tail: parsing stops at the first bad
//! entry, the file is truncated back to the last valid one, and the run
//! resumes from there. A header that names a different fingerprint or
//! arena length is a hard error — resuming a collective under a different
//! configuration must never silently restart it.

use std::fs::{File, OpenOptions};
use std::io::{Seek, SeekFrom, Write};
use std::path::Path;

use crate::runtime::BatchStats;
use crate::sim::ClusterClock;
use crate::util::{Error, Result};

const MAGIC: &[u8; 8] = b"SWP1PRG1";
const VERSION: u32 = 1;
const HEADER_BYTES: usize = 8 + 4 + 8 + 8;
const ENTRY_PAYLOAD: usize = 16 * 8;
const ENTRY_BYTES: usize = 4 + 8 + ENTRY_PAYLOAD;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// FNV-1a over raw bytes — the record's only integrity primitive (no
/// crypto needed: the threat model is torn writes, not adversaries).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a over an f32 arena's little-endian bytes: fingerprints the
/// weight/momentum part files so resume can verify an arena on disk is
/// the one the entry was recorded against.
pub fn fnv1a_f32s(xs: &[f32]) -> u64 {
    let mut h = FNV_OFFSET;
    for x in xs {
        for b in x.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// One recorded sync step: everything `SyncResume` needs plus the clock
/// and the hashes of the step's published weight/momentum part files.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Phase1Progress {
    /// completed optimizer steps at record time
    pub step: u64,
    /// partial statistics of the in-progress epoch
    pub epoch_stats: BatchStats,
    pub last_epoch_acc: f64,
    pub last_epoch_loss: f64,
    pub clock: ClusterClock,
    /// FNV-1a of `phase1.part-<step>.ckpt`'s f32 arena
    pub params_hash: u64,
    /// FNV-1a of `phase1.part-<step>.mom`'s f32 arena
    pub momentum_hash: u64,
}

fn encode_payload(e: &Phase1Progress) -> [u8; ENTRY_PAYLOAD] {
    let slots: [u64; 16] = [
        e.step,
        e.epoch_stats.sum_loss.to_bits(),
        e.epoch_stats.correct1 as u64,
        e.epoch_stats.correct5 as u64,
        e.epoch_stats.examples as u64,
        e.last_epoch_acc.to_bits(),
        e.last_epoch_loss.to_bits(),
        e.clock.seconds.to_bits(),
        e.clock.compute.to_bits(),
        e.clock.comm.to_bits(),
        e.clock.data_hidden.to_bits(),
        e.clock.data_exposed.to_bits(),
        e.clock.eval.to_bits(),
        e.clock.lost.to_bits(),
        e.params_hash,
        e.momentum_hash,
    ];
    let mut out = [0u8; ENTRY_PAYLOAD];
    for (i, s) in slots.iter().enumerate() {
        out[i * 8..(i + 1) * 8].copy_from_slice(&s.to_le_bytes());
    }
    out
}

fn decode_payload(p: &[u8]) -> Phase1Progress {
    let slot = |i: usize| u64::from_le_bytes(p[i * 8..(i + 1) * 8].try_into().unwrap());
    Phase1Progress {
        step: slot(0),
        epoch_stats: BatchStats {
            sum_loss: f64::from_bits(slot(1)),
            correct1: slot(2) as i64,
            correct5: slot(3) as i64,
            examples: slot(4) as i64,
        },
        last_epoch_acc: f64::from_bits(slot(5)),
        last_epoch_loss: f64::from_bits(slot(6)),
        clock: ClusterClock {
            seconds: f64::from_bits(slot(7)),
            compute: f64::from_bits(slot(8)),
            comm: f64::from_bits(slot(9)),
            data_hidden: f64::from_bits(slot(10)),
            data_exposed: f64::from_bits(slot(11)),
            eval: f64::from_bits(slot(12)),
            lost: f64::from_bits(slot(13)),
        },
        params_hash: slot(14),
        momentum_hash: slot(15),
    }
}

/// Parse the valid prefix of a record file's bytes. A full-but-wrong
/// header errors; a torn tail entry just ends the prefix.
fn parse(bytes: &[u8], fp_hash: u64, arena_len: u64) -> Result<Vec<Phase1Progress>> {
    if &bytes[..8] != MAGIC {
        return Err(Error::invalid("phase1 progress: bad magic (not a progress record)"));
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != VERSION {
        return Err(Error::invalid(format!("phase1 progress: unknown version {version}")));
    }
    let have_fp = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
    if have_fp != fp_hash {
        return Err(Error::config(
            "phase1 progress record belongs to a different run configuration; \
             use a fresh --run-dir instead of mixing runs",
        ));
    }
    let have_len = u64::from_le_bytes(bytes[20..28].try_into().unwrap());
    if have_len != arena_len {
        return Err(Error::config(format!(
            "phase1 progress record expects {have_len} parameters, this model has {arena_len}"
        )));
    }
    let mut entries = Vec::new();
    let mut i = HEADER_BYTES;
    while bytes.len() - i >= ENTRY_BYTES {
        let len = u32::from_le_bytes(bytes[i..i + 4].try_into().unwrap()) as usize;
        if len != ENTRY_PAYLOAD {
            break; // torn or foreign tail
        }
        let checksum = u64::from_le_bytes(bytes[i + 4..i + 12].try_into().unwrap());
        let payload = &bytes[i + 12..i + 12 + ENTRY_PAYLOAD];
        if fnv1a(payload) != checksum {
            break; // torn write: only the tail entry can be affected
        }
        entries.push(decode_payload(payload));
        i += ENTRY_BYTES;
    }
    Ok(entries)
}

/// Append-only writer over the progress record. `open` returns every
/// valid entry already on disk (oldest first) and truncates any torn
/// tail, so subsequent appends extend a clean file.
pub struct Phase1Recorder {
    file: File,
}

impl Phase1Recorder {
    pub fn open(
        path: &Path,
        fingerprint: &str,
        arena_len: u64,
    ) -> Result<(Self, Vec<Phase1Progress>)> {
        let fp_hash = fnv1a(fingerprint.as_bytes());
        let existing = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e.into()),
        };
        if existing.len() < HEADER_BYTES {
            // absent, empty, or torn mid-header: nothing was recorded yet
            let mut file =
                OpenOptions::new().create(true).write(true).truncate(true).open(path)?;
            let mut h = Vec::with_capacity(HEADER_BYTES);
            h.extend_from_slice(MAGIC);
            h.extend_from_slice(&VERSION.to_le_bytes());
            h.extend_from_slice(&fp_hash.to_le_bytes());
            h.extend_from_slice(&arena_len.to_le_bytes());
            file.write_all(&h)?;
            file.sync_all()?;
            return Ok((Phase1Recorder { file }, Vec::new()));
        }
        let entries = parse(&existing, fp_hash, arena_len)?;
        let file = OpenOptions::new().write(true).open(path)?;
        file.set_len((HEADER_BYTES + entries.len() * ENTRY_BYTES) as u64)?;
        Ok((Phase1Recorder { file }, entries))
    }

    /// Append one entry and fsync. The caller publishes the step's part
    /// files BEFORE this and deletes the previous step's parts AFTER —
    /// see the module docs for why that ordering is crash-safe.
    pub fn append(&mut self, e: &Phase1Progress) -> Result<()> {
        let payload = encode_payload(e);
        let mut rec = Vec::with_capacity(ENTRY_BYTES);
        rec.extend_from_slice(&(ENTRY_PAYLOAD as u32).to_le_bytes());
        rec.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        rec.extend_from_slice(&payload);
        self.file.seek(SeekFrom::End(0))?;
        self.file.write_all(&rec)?;
        self.file.sync_all()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("swap-p1prg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{tag}.progress"))
    }

    fn entry(step: u64) -> Phase1Progress {
        let mut clock = ClusterClock::new();
        clock.advance_compute(step as f64 * 0.25);
        clock.note_drop(0.5);
        Phase1Progress {
            step,
            epoch_stats: BatchStats {
                sum_loss: 1.5 * step as f64,
                correct1: step as i64,
                correct5: 2 * step as i64,
                examples: 8 * step as i64,
            },
            last_epoch_acc: 0.25,
            last_epoch_loss: 2.0,
            clock,
            params_hash: 0x1111 + step,
            momentum_hash: 0x2222 + step,
        }
    }

    #[test]
    fn round_trips_entries() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let (mut rec, got) = Phase1Recorder::open(&path, "fp-a", 10).unwrap();
        assert!(got.is_empty());
        for s in [4, 8, 12] {
            rec.append(&entry(s)).unwrap();
        }
        drop(rec);
        let (_, got) = Phase1Recorder::open(&path, "fp-a", 10).unwrap();
        assert_eq!(got, vec![entry(4), entry(8), entry(12)]);
    }

    #[test]
    fn torn_tail_is_dropped_and_appendable() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        let (mut rec, _) = Phase1Recorder::open(&path, "fp", 3).unwrap();
        rec.append(&entry(1)).unwrap();
        rec.append(&entry(2)).unwrap();
        drop(rec);
        // tear the last entry at every cut point: only entry 1 survives
        let full = std::fs::read(&path).unwrap();
        for cut in (full.len() - ENTRY_BYTES + 1)..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            let (_, got) = Phase1Recorder::open(&path, "fp", 3).unwrap();
            assert_eq!(got, vec![entry(1)], "cut at {cut}");
        }
        // the torn tail was truncated away; appending extends cleanly
        let (mut rec, _) = Phase1Recorder::open(&path, "fp", 3).unwrap();
        rec.append(&entry(3)).unwrap();
        drop(rec);
        let (_, got) = Phase1Recorder::open(&path, "fp", 3).unwrap();
        assert_eq!(got, vec![entry(1), entry(3)]);
    }

    #[test]
    fn corrupt_checksum_invalidates_tail() {
        let path = tmp("cksum");
        let _ = std::fs::remove_file(&path);
        let (mut rec, _) = Phase1Recorder::open(&path, "fp", 3).unwrap();
        rec.append(&entry(1)).unwrap();
        rec.append(&entry(2)).unwrap();
        drop(rec);
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0xFF; // flip a byte inside entry 2's payload
        std::fs::write(&path, &bytes).unwrap();
        let (_, got) = Phase1Recorder::open(&path, "fp", 3).unwrap();
        assert_eq!(got, vec![entry(1)]);
    }

    #[test]
    fn wrong_fingerprint_or_arena_is_fatal() {
        let path = tmp("fp-mismatch");
        let _ = std::fs::remove_file(&path);
        let (mut rec, _) = Phase1Recorder::open(&path, "fp-a", 7).unwrap();
        rec.append(&entry(1)).unwrap();
        drop(rec);
        assert!(Phase1Recorder::open(&path, "fp-b", 7).is_err());
        assert!(Phase1Recorder::open(&path, "fp-a", 8).is_err());
        assert!(Phase1Recorder::open(&path, "fp-a", 7).is_ok());
    }

    #[test]
    fn torn_header_restarts_empty() {
        let path = tmp("torn-header");
        let _ = std::fs::remove_file(&path);
        std::fs::write(&path, &MAGIC[..5]).unwrap();
        let (mut rec, got) = Phase1Recorder::open(&path, "fp", 3).unwrap();
        assert!(got.is_empty());
        rec.append(&entry(9)).unwrap();
        drop(rec);
        let (_, got) = Phase1Recorder::open(&path, "fp", 3).unwrap();
        assert_eq!(got, vec![entry(9)]);
    }
}
