//! In-process transport: phase-2 workers as OS threads via
//! `parallel_map`, exactly the execution the coordinator always had. On
//! the zero-failure path this is bitwise-identical to the historical
//! `run_swap` (same closure, same float-op order, same collection order —
//! pinned by rust/tests/transport.rs); what changed is only that a worker
//! `Err` now becomes a `Dropped` outcome instead of killing the run.

use super::super::parallel;
use super::super::swap::phase2_worker_config;
use super::super::trainer::run_sync_training;
use super::{Phase2Ctx, Phase2Report, Transport, WorkerOutcome};
use crate::model::{save_params, ParamSet};
use crate::runtime::Backend;
use crate::sim::ClusterClock;
use crate::util::{Error, Result};

/// Phase-2 workers on in-process OS threads (`env.threads` of them).
#[derive(Debug, Clone, Default)]
pub struct MemoryTransport {
    /// Test-only fault injection: these worker ids fail instead of
    /// training (the in-memory analogue of a crashed remote process).
    pub fail_workers: Vec<usize>,
}

impl MemoryTransport {
    pub fn new() -> Self {
        MemoryTransport::default()
    }
}

impl Transport for MemoryTransport {
    fn name(&self) -> &'static str {
        "memory"
    }

    fn run_phase2(&self, ctx: &Phase2Ctx) -> Result<Phase2Report> {
        let env = ctx.env;
        let cfg = ctx.cfg;
        let snap = cfg.snapshot_every;
        // Each worker's state (params, momentum, sampler, augmentation
        // RNG, clock, snapshot trail) is derived from its own
        // (seed, 100 + w) stream inside the closure, so the result is
        // bitwise identical for any thread count, including the fully
        // sequential `threads = 1` path.
        type Run = (ParamSet, ClusterClock, Vec<(usize, ParamSet)>);
        let runs = parallel::parallel_map(
            env.threads,
            ctx.pending.to_vec(),
            |_, w| -> (usize, Result<Run>) {
                if self.fail_workers.contains(&w) {
                    return (w, Err(Error::invalid(format!("injected fault: worker {w}"))));
                }
                let run = (|| {
                    let mut wp = ctx.start.clone();
                    let mut wm = wp.zeros_like();
                    let mut wclock = ClusterClock::new();
                    let mut trail = Vec::new();
                    run_sync_training(
                        env,
                        &mut wp,
                        &mut wm,
                        &phase2_worker_config(cfg, env, w),
                        &mut wclock,
                        |step, ps, _| {
                            if let Some(every) = snap {
                                if step % every == 0 {
                                    trail.push((step, ps.clone()));
                                }
                            }
                        },
                    )?;
                    // persist immediately (resumable runs): a later crash
                    // only loses the workers still in flight
                    if let Some(dir) = ctx.run_dir {
                        save_params(dir.worker_ckpt(w), env.engine.manifest(), &wp)?;
                    }
                    Ok((wp, wclock, trail))
                })();
                (w, run)
            },
        );
        let outcomes = runs
            .into_iter()
            .map(|(w, run)| match run {
                Ok((params, clock, trail)) => (w, WorkerOutcome::Done { params, clock, trail }),
                Err(e) => (w, WorkerOutcome::Dropped { reason: e.to_string() }),
            })
            .collect();
        Ok(Phase2Report { outcomes, net: Default::default() })
    }
}
