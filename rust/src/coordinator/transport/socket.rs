//! Socket transport: phase-2 workers as separate processes over TCP or a
//! Unix domain socket, speaking the framed protocol of [`super::wire`].
//!
//! Coordinator side (`serve_phase2`, via `swap-train serve`): after phase
//! 1 the coordinator listens on `addr`, admits workers during a join
//! window (checking each one's config fingerprint, assigning unfinished
//! worker ids — a rejoining process may request a specific id), broadcasts
//! the phase-1 weights, then supervises one reader thread per link. A
//! worker that uploads its replica is `Done`; one that disconnects, stays
//! silent past `FailurePolicy::io_timeout`, or outlives the straggler
//! deadline (first finisher + `straggler_grace`) is `Dropped` — its link
//! is shut down and the run proceeds without it.
//!
//! Worker side ([`join_run`], via `swap-train join`): connect with bounded
//! retry/backoff (the coordinator may still be in phase 1), present the
//! fingerprint, receive a worker id + phase-1 weights, train the worker's
//! deterministic `(seed, 100 + w)` recipe while heartbeating, and upload
//! the replica. The weight arenas cross the wire as exact little-endian
//! f32 bytes, so a socket run is bitwise-identical to an in-memory run.
//!
//! `addr` selects the family: anything containing ':' is a TCP
//! host:port, anything else is a Unix socket path.

use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::super::swap::{phase2_worker_config, SwapConfig};
use super::super::trainer::{run_sync_training, TrainEnv};
use super::wire::{self, Msg};
use super::{FailurePolicy, NetStats, Phase2Ctx, Phase2Report, Transport, WorkerOutcome};
use crate::model::{save_params, ParamLayout, ParamSet};
use crate::runtime::Backend;
use crate::sim::ClusterClock;
use crate::util::{Error, Result};

/// Phase-2 workers as remote processes; see the module docs.
#[derive(Debug, Clone)]
pub struct SocketTransport {
    /// "host:port" for TCP, a filesystem path for a Unix socket
    pub addr: String,
}

impl SocketTransport {
    pub fn new(addr: impl Into<String>) -> Self {
        SocketTransport { addr: addr.into() }
    }
}

impl Transport for SocketTransport {
    fn name(&self) -> &'static str {
        "socket"
    }

    fn run_phase2(&self, ctx: &Phase2Ctx) -> Result<Phase2Report> {
        serve_phase2(&self.addr, ctx)
    }
}

// ---------------------------------------------------------------------
// Address-family abstraction
// ---------------------------------------------------------------------

fn is_tcp(addr: &str) -> bool {
    addr.contains(':')
}

enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    fn connect(addr: &str) -> io::Result<Conn> {
        if is_tcp(addr) {
            return Ok(Conn::Tcp(TcpStream::connect(addr)?));
        }
        #[cfg(unix)]
        {
            Ok(Conn::Unix(UnixStream::connect(addr)?))
        }
        #[cfg(not(unix))]
        {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                format!("addr '{addr}' is a unix socket path, unsupported on this platform"),
            ))
        }
    }

    fn try_clone(&self) -> io::Result<Conn> {
        match self {
            Conn::Tcp(s) => Ok(Conn::Tcp(s.try_clone()?)),
            #[cfg(unix)]
            Conn::Unix(s) => Ok(Conn::Unix(s.try_clone()?)),
        }
    }

    fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_read_timeout(d),
        }
    }

    fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_nonblocking(nb),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_nonblocking(nb),
        }
    }

    /// Force any blocked read on a clone of this stream to return.
    fn shutdown(&self) {
        match self {
            Conn::Tcp(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
            #[cfg(unix)]
            Conn::Unix(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn bind(addr: &str) -> Result<Listener> {
        if is_tcp(addr) {
            return Ok(Listener::Tcp(TcpListener::bind(addr)?));
        }
        #[cfg(unix)]
        {
            // a previous run's socket file would make bind fail
            let _ = std::fs::remove_file(addr);
            Ok(Listener::Unix(UnixListener::bind(addr)?))
        }
        #[cfg(not(unix))]
        {
            Err(Error::config(format!(
                "addr '{addr}' is a unix socket path, unsupported on this platform"
            )))
        }
    }

    fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(nb),
        }
    }

    fn accept(&self) -> io::Result<Conn> {
        match self {
            Listener::Tcp(l) => Ok(Conn::Tcp(l.accept()?.0)),
            #[cfg(unix)]
            Listener::Unix(l) => Ok(Conn::Unix(l.accept()?.0)),
        }
    }
}

// ---------------------------------------------------------------------
// Coordinator side
// ---------------------------------------------------------------------

/// Per-link state shared between its reader thread and the supervisor.
struct LinkState {
    worker: usize,
    outcome: Mutex<Option<WorkerOutcome>>,
    last_heard: Mutex<Instant>,
}

/// First writer wins: the supervisor may drop a link (timeout, straggler)
/// in the same instant its reader delivers a verdict.
fn set_once(slot: &Mutex<Option<WorkerOutcome>>, outcome: WorkerOutcome) {
    let mut g = slot.lock().unwrap();
    if g.is_none() {
        *g = Some(outcome);
    }
}

fn serve_phase2(addr: &str, ctx: &Phase2Ctx) -> Result<Phase2Report> {
    let policy = ctx.policy;
    let listener = Listener::bind(addr)?;
    listener.set_nonblocking(true)?;
    crate::info!(
        "serve: listening on {addr} for {} phase-2 workers (join window {:?})",
        ctx.pending.len(),
        policy.connect_timeout
    );

    let sent = AtomicU64::new(0);
    let recvd = AtomicU64::new(0);
    let payload = AtomicU64::new(0);

    // ---- join window ---------------------------------------------------
    let mut links: Vec<(usize, Conn)> = Vec::new();
    let mut unassigned: Vec<usize> = ctx.pending.to_vec();
    let deadline = Instant::now() + policy.connect_timeout;
    while !unassigned.is_empty() && Instant::now() < deadline {
        match listener.accept() {
            Ok(conn) => {
                if let Some((w, conn)) =
                    handshake(conn, ctx, &mut unassigned, &sent, &recvd, &payload)
                {
                    crate::info!("serve: worker {w} joined");
                    links.push((w, conn));
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => return Err(e.into()),
        }
    }
    let mut outcomes: Vec<(usize, WorkerOutcome)> = unassigned
        .iter()
        .map(|&w| {
            (
                w,
                WorkerOutcome::Dropped {
                    reason: format!("no worker joined within {:?}", policy.connect_timeout),
                },
            )
        })
        .collect();

    // ---- supervise one reader thread per link --------------------------
    let layout = ctx.start.layout().clone();
    let mut states: Vec<LinkState> = Vec::with_capacity(links.len());
    let mut ctls: Vec<Conn> = Vec::with_capacity(links.len());
    let mut conns: Vec<Conn> = Vec::with_capacity(links.len());
    for (w, conn) in links {
        ctls.push(conn.try_clone()?);
        states.push(LinkState {
            worker: w,
            outcome: Mutex::new(None),
            last_heard: Mutex::new(Instant::now()),
        });
        conns.push(conn);
    }
    std::thread::scope(|s| {
        for (i, conn) in conns.into_iter().enumerate() {
            let st = &states[i];
            let layout = &layout;
            let recvd = &recvd;
            let payload = &payload;
            s.spawn(move || reader_loop(conn, st, ctx, layout, recvd, payload));
        }
        // the supervisor: polls liveness and applies the failure policy,
        // shutting down a link to force its blocked reader to return
        let mut first_done: Option<Instant> = None;
        loop {
            let now = Instant::now();
            let mut open = 0usize;
            let mut any_done = false;
            for st in &states {
                match &*st.outcome.lock().unwrap() {
                    Some(WorkerOutcome::Done { .. }) => any_done = true,
                    Some(WorkerOutcome::Dropped { .. }) => {}
                    None => open += 1,
                }
            }
            if any_done && first_done.is_none() {
                first_done = Some(now);
            }
            if open == 0 {
                break;
            }
            for (st, ctl) in states.iter().zip(&ctls) {
                if st.outcome.lock().unwrap().is_some() {
                    continue;
                }
                let silent = now.duration_since(*st.last_heard.lock().unwrap());
                if silent > policy.io_timeout {
                    set_once(
                        &st.outcome,
                        WorkerOutcome::Dropped {
                            reason: format!("no heartbeat within {:?}", policy.io_timeout),
                        },
                    );
                    ctl.shutdown();
                } else if let Some(t0) = first_done {
                    if now.duration_since(t0) > policy.straggler_grace {
                        set_once(
                            &st.outcome,
                            WorkerOutcome::Dropped {
                                reason: format!(
                                    "straggler: unfinished {:?} after the first worker",
                                    policy.straggler_grace
                                ),
                            },
                        );
                        ctl.shutdown();
                    }
                }
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    });
    for st in states {
        let outcome = st.outcome.into_inner().unwrap().unwrap_or(WorkerOutcome::Dropped {
            reason: "link reader exited without a verdict".to_string(),
        });
        outcomes.push((st.worker, outcome));
    }
    Ok(Phase2Report {
        outcomes,
        net: NetStats {
            framed_bytes: sent.load(Ordering::Relaxed) + recvd.load(Ordering::Relaxed),
            param_bytes: payload.load(Ordering::Relaxed),
        },
    })
}

/// Admit one candidate connection: read its Join, check the fingerprint,
/// assign a worker id (the requested unfinished id if free, else the
/// lowest), send the phase-1 weights. `None` drops the candidate without
/// consuming a worker slot.
fn handshake(
    conn: Conn,
    ctx: &Phase2Ctx,
    unassigned: &mut Vec<usize>,
    sent: &AtomicU64,
    recvd: &AtomicU64,
    payload: &AtomicU64,
) -> Option<(usize, Conn)> {
    let mut conn = conn;
    // the listener is non-blocking; the handshake itself must not be (but
    // also must not hang the join loop on a silent client)
    conn.set_nonblocking(false).ok()?;
    conn.set_read_timeout(Some(ctx.policy.io_timeout)).ok()?;
    let (msg, nb) = match wire::read_msg(&mut conn) {
        Ok(x) => x,
        Err(e) => {
            crate::warn_!("serve: handshake failed: {e}");
            return None;
        }
    };
    recvd.fetch_add(nb, Ordering::Relaxed);
    let Msg::Join { fingerprint, resume } = msg else {
        crate::warn_!("serve: candidate spoke out of protocol, dropped");
        return None;
    };
    if fingerprint != ctx.fingerprint {
        crate::warn_!("serve: rejected join with a mismatched config fingerprint");
        let reject = Msg::Reject {
            reason: format!(
                "config fingerprint mismatch: coordinator runs {}, you presented {}",
                ctx.fingerprint, fingerprint
            ),
        };
        if let Ok(nb) = wire::write_msg(&mut conn, &reject) {
            sent.fetch_add(nb, Ordering::Relaxed);
        }
        return None;
    }
    let w = match resume {
        Some(r) if unassigned.contains(&r) => r,
        _ => *unassigned.iter().min()?,
    };
    let assign = Msg::Assign { worker: w, params: ctx.start.data().to_vec() };
    match wire::write_msg(&mut conn, &assign) {
        Ok(nb) => {
            sent.fetch_add(nb, Ordering::Relaxed);
            payload.fetch_add(4 * ctx.start.numel() as u64, Ordering::Relaxed);
        }
        Err(e) => {
            crate::warn_!("serve: could not send weights to a joining worker: {e}");
            return None;
        }
    }
    conn.set_read_timeout(None).ok()?;
    unassigned.retain(|&x| x != w);
    Some((w, conn))
}

fn reader_loop(
    mut conn: Conn,
    st: &LinkState,
    ctx: &Phase2Ctx,
    layout: &Arc<ParamLayout>,
    recvd: &AtomicU64,
    payload: &AtomicU64,
) {
    let w = st.worker;
    loop {
        match wire::read_msg(&mut conn) {
            Ok((msg, nb)) => {
                recvd.fetch_add(nb, Ordering::Relaxed);
                *st.last_heard.lock().unwrap() = Instant::now();
                match msg {
                    Msg::Heartbeat { .. } => {}
                    Msg::Done { worker: _, params, clock } => {
                        payload.fetch_add(4 * params.len() as u64, Ordering::Relaxed);
                        let outcome = match ParamSet::from_data(layout.clone(), params) {
                            Ok(wp) => {
                                if let Some(dir) = ctx.run_dir {
                                    if let Err(e) =
                                        save_params(dir.worker_ckpt(w), ctx.env.engine.manifest(), &wp)
                                    {
                                        crate::warn_!("serve: checkpoint of worker {w} failed: {e}");
                                    }
                                }
                                WorkerOutcome::Done { params: wp, clock, trail: Vec::new() }
                            }
                            Err(e) => WorkerOutcome::Dropped {
                                reason: format!("bad weight upload: {e}"),
                            },
                        };
                        set_once(&st.outcome, outcome);
                        break;
                    }
                    Msg::Abort { worker: _, reason } => {
                        set_once(
                            &st.outcome,
                            WorkerOutcome::Dropped { reason: format!("worker aborted: {reason}") },
                        );
                        break;
                    }
                    _ => {
                        set_once(
                            &st.outcome,
                            WorkerOutcome::Dropped { reason: "spoke out of protocol".to_string() },
                        );
                        conn.shutdown();
                        break;
                    }
                }
            }
            Err(e) => {
                // EOF, reset, or a supervisor-initiated shutdown (in which
                // case the outcome is already set and this is a no-op)
                set_once(
                    &st.outcome,
                    WorkerOutcome::Dropped { reason: format!("connection lost: {e}") },
                );
                break;
            }
        }
    }
}

// ---------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------

/// What a successful `join_run` did, for CLI reporting.
#[derive(Debug, Clone, Copy)]
pub struct JoinSummary {
    pub worker: usize,
    pub steps: usize,
    pub bytes_sent: u64,
    pub bytes_received: u64,
}

/// Join a coordinator at `addr` as one phase-2 worker: connect (with
/// bounded retry — the coordinator may still be in phase 1), present this
/// process's config fingerprint, train the assigned worker's deterministic
/// recipe from the broadcast phase-1 weights, and upload the replica.
/// `want` asks to adopt a specific unfinished worker id (rejoining after
/// a crash); the coordinator honors it when free.
pub fn join_run(
    env: &TrainEnv,
    cfg: &SwapConfig,
    addr: &str,
    policy: &FailurePolicy,
    want: Option<usize>,
) -> Result<JoinSummary> {
    let fingerprint = super::run_fingerprint(env, cfg);
    let mut conn = None;
    for attempt in 0..=policy.join_retries {
        match Conn::connect(addr) {
            Ok(c) => {
                conn = Some(c);
                break;
            }
            Err(e) => {
                if attempt == policy.join_retries {
                    return Err(Error::config(format!(
                        "join: cannot reach {addr} after {} attempts: {e}",
                        attempt + 1
                    )));
                }
                std::thread::sleep(policy.retry_backoff * (attempt as u32 + 1));
            }
        }
    }
    let mut conn = conn.expect("loop either set a connection or returned");
    let mut sent = 0u64;
    let mut recvd = 0u64;
    sent += wire::write_msg(&mut conn, &Msg::Join { fingerprint, resume: want })?;
    conn.set_read_timeout(Some(policy.io_timeout))?;
    let (msg, nb) = wire::read_msg(&mut conn)?;
    recvd += nb;
    let (w, start) = match msg {
        Msg::Assign { worker, params } => {
            let layout = ParamLayout::of_params(env.engine.manifest());
            (worker, ParamSet::from_data(layout, params)?)
        }
        Msg::Reject { reason } => return Err(Error::config(format!("join rejected: {reason}"))),
        _ => return Err(Error::invalid("join: coordinator spoke out of protocol")),
    };
    conn.set_read_timeout(None)?;
    crate::info!("join: assigned worker {w}, training");

    let mut wp = start;
    let mut wm = wp.zeros_like();
    let mut wclock = ClusterClock::new();
    let mut last_hb = Instant::now();
    let mut hb_dead = false;
    let progress = run_sync_training(
        env,
        &mut wp,
        &mut wm,
        &phase2_worker_config(cfg, env, w),
        &mut wclock,
        |step, _, _| {
            if !hb_dead && last_hb.elapsed() >= policy.heartbeat {
                match wire::write_msg(&mut conn, &Msg::Heartbeat { worker: w, step: step as u64 }) {
                    Ok(nb) => {
                        sent += nb;
                        last_hb = Instant::now();
                    }
                    // the coordinator is gone or dropped us; keep training
                    // (the result is still correct) and let the final
                    // upload surface the error
                    Err(_) => hb_dead = true,
                }
            }
        },
    );
    match progress {
        Ok(p) => {
            sent += wire::write_msg(
                &mut conn,
                &Msg::Done { worker: w, params: wp.into_data(), clock: wclock },
            )?;
            crate::info!("join: worker {w} done after {} steps", p.steps);
            Ok(JoinSummary { worker: w, steps: p.steps, bytes_sent: sent, bytes_received: recvd })
        }
        Err(e) => {
            let _ = wire::write_msg(
                &mut conn,
                &Msg::Abort { worker: w, reason: e.to_string() },
            );
            Err(e)
        }
    }
}
