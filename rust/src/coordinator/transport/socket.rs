//! Socket transport: SWAP's phases as separate processes over TCP or a
//! Unix domain socket, speaking the framed protocol of [`super::wire`].
//!
//! Phase 1 (`serve_phase1` / [`join_phase1`], when `cfg.phase1_dist`):
//! the coordinator is the hub of a synchronous collective. Each of the
//! `cfg.workers` members owns `group_devices` consecutive device shards;
//! per step the hub broadcasts the weights (`P1Step`), every member
//! assembles its shard batches (pure functions of the step index) and
//! uploads one `P1Grad` per device, and the hub runs the ring all-reduce
//! and optimizer — bitwise the in-process loop when nothing fails. A
//! member that dies or straggles mid-collective is dropped, the ring
//! re-forms from the survivors (the mean re-normalizes over the surviving
//! shard set by construction), its discarded shard compute is booked into
//! `ClusterClock::lost`, and a restarted process re-joins between steps.
//! With a run dir the hub also appends the crash-safe phase-1 progress
//! record, so a killed coordinator resumes the collective at the last
//! recorded sync step.
//!
//! Phase 2 (`serve_phase2`, via `swap-train serve`): the coordinator
//! admits workers during a join window (checking each one's config
//! fingerprint, assigning unfinished worker ids — a rejoining process may
//! request a specific id), broadcasts the phase-1 weights, then
//! supervises one reader thread per link. A worker that uploads its
//! replica is `Done`; one that disconnects, stays silent past
//! `FailurePolicy::io_timeout`, or outlives the straggler deadline (first
//! finisher + `straggler_grace`) is `Dropped` — its link is shut down and
//! the run proceeds without it.
//!
//! Worker side ([`join_run`], via `swap-train join`): connect with bounded
//! retry/backoff (the coordinator may still be in phase 1), present the
//! fingerprint, receive a worker id + phase-1 weights, train the worker's
//! deterministic `(seed, 100 + w)` recipe while heartbeating, and upload
//! the replica. The weight arenas cross the wire as exact little-endian
//! f32 bytes, so a socket run is bitwise-identical to an in-memory run.
//!
//! `addr` selects the family: anything containing ':' is a TCP
//! host:port, anything else is a Unix socket path.

use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::super::swap::{phase1_train_config, phase2_worker_config, SwapConfig};
use super::super::trainer::{
    run_sync_collective, run_sync_training, CollectiveStep, ProgressHook, SyncState, TrainEnv,
};
use super::progress::Phase1Recorder;
use super::wire::{self, Msg};
use super::{
    FailurePolicy, NetStats, Phase1Ctx, Phase1Report, Phase2Ctx, Phase2Report, Transport,
    WorkerOutcome,
};
use crate::data::{AugStream, Batcher, EpochSampler};
use crate::model::{save_params, ParamLayout, ParamSet};
use crate::runtime::{Backend, BatchStats};
use crate::sim::ClusterClock;
use crate::util::{Error, Result};

/// SWAP's phases as remote processes; see the module docs.
#[derive(Debug, Clone)]
pub struct SocketTransport {
    /// "host:port" for TCP, a filesystem path for a Unix socket
    pub addr: String,
    /// the run's listener, bound once and reused by every phase served
    /// from this transport: rebinding `addr` between phases races against
    /// TIME_WAIT left by links the previous phase actively closed
    listener: Arc<Mutex<Option<Listener>>>,
}

impl SocketTransport {
    pub fn new(addr: impl Into<String>) -> Self {
        SocketTransport { addr: addr.into(), listener: Arc::new(Mutex::new(None)) }
    }

    /// Take the run's listener, binding it on first use (non-blocking:
    /// every accept loop in this module polls).
    fn acquire(&self) -> Result<Listener> {
        if let Some(l) = self.listener.lock().unwrap().take() {
            return Ok(l);
        }
        let l = Listener::bind(&self.addr)?;
        l.set_nonblocking(true)?;
        Ok(l)
    }

    fn release(&self, l: Listener) {
        *self.listener.lock().unwrap() = Some(l);
    }
}

impl Transport for SocketTransport {
    fn name(&self) -> &'static str {
        "socket"
    }

    fn run_phase1(
        &self,
        ctx: &Phase1Ctx,
        params: &mut ParamSet,
        momentum: &mut ParamSet,
        clock: &mut ClusterClock,
    ) -> Result<Phase1Report> {
        if !ctx.cfg.phase1_dist {
            // phase 1 stays on the coordinator; only phase 2 distributes
            return super::run_phase1_local(ctx, params, momentum, clock);
        }
        let listener = self.acquire()?;
        let r = serve_phase1(&self.addr, &listener, ctx, params, momentum, clock);
        self.release(listener);
        r
    }

    fn run_phase2(&self, ctx: &Phase2Ctx) -> Result<Phase2Report> {
        let listener = self.acquire()?;
        let r = serve_phase2(&self.addr, &listener, ctx);
        self.release(listener);
        r
    }
}

// ---------------------------------------------------------------------
// Address-family abstraction
// ---------------------------------------------------------------------

fn is_tcp(addr: &str) -> bool {
    addr.contains(':')
}

enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    fn connect(addr: &str) -> io::Result<Conn> {
        if is_tcp(addr) {
            return Ok(Conn::Tcp(TcpStream::connect(addr)?));
        }
        #[cfg(unix)]
        {
            Ok(Conn::Unix(UnixStream::connect(addr)?))
        }
        #[cfg(not(unix))]
        {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                format!("addr '{addr}' is a unix socket path, unsupported on this platform"),
            ))
        }
    }

    fn try_clone(&self) -> io::Result<Conn> {
        match self {
            Conn::Tcp(s) => Ok(Conn::Tcp(s.try_clone()?)),
            #[cfg(unix)]
            Conn::Unix(s) => Ok(Conn::Unix(s.try_clone()?)),
        }
    }

    fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_read_timeout(d),
        }
    }

    fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_nonblocking(nb),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_nonblocking(nb),
        }
    }

    /// Force any blocked read on a clone of this stream to return.
    fn shutdown(&self) {
        match self {
            Conn::Tcp(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
            #[cfg(unix)]
            Conn::Unix(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

#[derive(Debug)]
enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn bind(addr: &str) -> Result<Listener> {
        if is_tcp(addr) {
            return Ok(Listener::Tcp(TcpListener::bind(addr)?));
        }
        #[cfg(unix)]
        {
            // a previous run's socket file would make bind fail
            let _ = std::fs::remove_file(addr);
            Ok(Listener::Unix(UnixListener::bind(addr)?))
        }
        #[cfg(not(unix))]
        {
            Err(Error::config(format!(
                "addr '{addr}' is a unix socket path, unsupported on this platform"
            )))
        }
    }

    fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(nb),
        }
    }

    fn accept(&self) -> io::Result<Conn> {
        match self {
            Listener::Tcp(l) => Ok(Conn::Tcp(l.accept()?.0)),
            #[cfg(unix)]
            Listener::Unix(l) => Ok(Conn::Unix(l.accept()?.0)),
        }
    }
}

// ---------------------------------------------------------------------
// Phase 1: the coordinator as hub of a distributed collective
// ---------------------------------------------------------------------

/// Reject reason the phase-2 handshake sends a `P1Join` that arrives
/// after the collective finished — [`join_phase1`] maps it to
/// [`Phase1Outcome::AlreadyDone`] so the process falls through to
/// [`join_run`].
pub(crate) const PHASE1_DONE_REJECT: &str = "phase 1 already complete";

/// Read-timeout quantum of the hub's single-threaded member pump: short
/// enough that one silent member never stalls the others' drains.
const PUMP_TICK: Duration = Duration::from_millis(2);

fn would_block(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Accumulates raw socket bytes and yields complete frames. The hub
/// multiplexes many members on one thread, so it must never sit in
/// `read_exact` mid-frame on one link while others have data ready —
/// partial reads stay buffered here and the pump moves on.
struct FrameReader {
    buf: Vec<u8>,
}

impl FrameReader {
    fn new() -> FrameReader {
        FrameReader { buf: Vec::new() }
    }

    /// Pull whatever the socket has ready and return the next complete
    /// frame, if any. A read timeout is "no frame yet", never an error;
    /// EOF and malformed framing are.
    fn poll(&mut self, conn: &mut Conn) -> Result<Option<(Msg, u64)>> {
        loop {
            if let Some(r) = self.take_frame()? {
                return Ok(Some(r));
            }
            let mut chunk = [0u8; 64 * 1024];
            match conn.read(&mut chunk) {
                Ok(0) => return Err(Error::invalid("connection closed by peer")),
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) if would_block(&e) => return Ok(None),
                Err(e) => return Err(e.into()),
            }
        }
    }

    fn take_frame(&mut self) -> Result<Option<(Msg, u64)>> {
        if self.buf.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(self.buf[..4].try_into().unwrap()) as usize;
        if len == 0 || len > wire::MAX_FRAME {
            return Err(Error::invalid(format!("wire: bad frame length {len}")));
        }
        if self.buf.len() < 4 + len {
            return Ok(None);
        }
        let msg = wire::decode_payload(&self.buf[4..4 + len])?;
        self.buf.drain(..4 + len);
        Ok(Some((msg, 4 + len as u64)))
    }
}

/// One live collective member, indexed by its slot.
struct MemberLink {
    conn: Conn,
    reader: FrameReader,
    last_heard: Instant,
}

/// The coordinator side of the distributed phase-1 collective: owns the
/// member links and implements one `exchange` per sync step for
/// [`run_sync_collective`].
struct Phase1Hub<'h, 'e> {
    addr: &'h str,
    listener: &'h Listener,
    ctx: &'h Phase1Ctx<'e>,
    /// slot -> live link; `None` is a free slot (never joined or dropped)
    members: Vec<Option<MemberLink>>,
    sent: u64,
    recvd: u64,
    payload: u64,
    /// members dropped mid-collective over the whole phase
    deaths: usize,
}

impl<'h, 'e> Phase1Hub<'h, 'e> {
    fn live(&self) -> usize {
        self.members.iter().filter(|m| m.is_some()).count()
    }

    fn min_members(&self) -> usize {
        self.ctx.policy.min_workers.max(1)
    }

    /// The elastic floor: a shrunken ring is fine down to `min_workers`
    /// members; below that the collective fails loudly.
    fn check_quorum(&self, step: u64) -> Result<()> {
        let live = self.live();
        if live < self.min_members() {
            return Err(Error::config(format!(
                "phase 1 collective at step {step}: {live} of {} members left, below \
                 min_workers {} — aborting",
                self.members.len(),
                self.min_members()
            )));
        }
        Ok(())
    }

    /// Wait up to `connect_timeout` for the full membership, then start
    /// with whoever came (at least `min_workers`).
    fn join_window(&mut self, start_step: u64) -> Result<()> {
        let want = self.members.len();
        crate::info!(
            "serve: phase 1 hub on {} waiting for {want} members (join window {:?})",
            self.addr,
            self.ctx.policy.connect_timeout
        );
        let deadline = Instant::now() + self.ctx.policy.connect_timeout;
        while self.live() < want && Instant::now() < deadline {
            match self.listener.accept() {
                Ok(conn) => self.admit(conn, start_step),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) => return Err(e.into()),
            }
        }
        self.check_quorum(start_step)?;
        let live = self.live();
        if live < want {
            crate::warn_!("serve: phase 1 starting with {live} of {want} members");
        }
        Ok(())
    }

    /// Admit rejoining members at a step boundary (non-blocking): a
    /// restarted process re-enters the collective at the current step.
    fn poll_joins(&mut self, step: u64) {
        while let Ok(conn) = self.listener.accept() {
            self.admit(conn, step);
        }
    }

    /// Handshake one candidate: fingerprint check, slot assignment (the
    /// requested slot when free, else the lowest free one), `P1Assign`
    /// carrying the step the next broadcast will use.
    fn admit(&mut self, conn: Conn, step: u64) {
        let mut conn = conn;
        if conn.set_nonblocking(false).is_err()
            || conn.set_read_timeout(Some(self.ctx.policy.io_timeout)).is_err()
        {
            return;
        }
        let msg = match wire::read_msg(&mut conn) {
            Ok((msg, nb)) => {
                self.recvd += nb;
                msg
            }
            Err(e) => {
                crate::warn_!("serve: phase 1 handshake failed: {e}");
                return;
            }
        };
        let (fingerprint, wanted) = match msg {
            Msg::P1Join { fingerprint, slot } => (fingerprint, slot),
            Msg::Join { .. } => {
                // a phase-2 worker started early; it retries with backoff
                self.reject(&mut conn, "phase 1 in progress; retry to join phase 2".to_string());
                return;
            }
            _ => {
                crate::warn_!("serve: phase 1 candidate spoke out of protocol, dropped");
                return;
            }
        };
        if fingerprint != self.ctx.fingerprint {
            self.reject(
                &mut conn,
                format!(
                    "config fingerprint mismatch: coordinator runs {}, you presented {fingerprint}",
                    self.ctx.fingerprint
                ),
            );
            return;
        }
        let slot = match wanted {
            Some(s) if s < self.members.len() && self.members[s].is_none() => s,
            _ => match self.members.iter().position(|m| m.is_none()) {
                Some(s) => s,
                None => {
                    self.reject(&mut conn, "all member slots taken".to_string());
                    return;
                }
            },
        };
        match wire::write_msg(&mut conn, &Msg::P1Assign { slot, step }) {
            Ok(nb) => self.sent += nb,
            Err(e) => {
                crate::warn_!("serve: could not assign member slot {slot}: {e}");
                return;
            }
        }
        if conn.set_read_timeout(Some(PUMP_TICK)).is_err() {
            return;
        }
        crate::info!("serve: member {slot} joined the phase 1 collective at step {step}");
        self.members[slot] =
            Some(MemberLink { conn, reader: FrameReader::new(), last_heard: Instant::now() });
    }

    fn reject(&mut self, conn: &mut Conn, reason: String) {
        crate::warn_!("serve: rejected phase 1 join: {reason}");
        if let Ok(nb) = wire::write_msg(conn, &Msg::Reject { reason }) {
            self.sent += nb;
        }
    }

    fn drop_member(&mut self, s: usize, reason: &str) {
        if let Some(link) = self.members[s].take() {
            crate::warn_!("serve: phase 1 member {s} dropped: {reason}");
            link.conn.shutdown();
            self.deaths += 1;
        }
    }

    /// One sync step's gradient exchange: broadcast the weights, gather
    /// every live member's device shards, apply the failure policy to
    /// whoever goes quiet, and hand the surviving arenas (ascending
    /// absolute device order — the in-process order) to the collective
    /// loop. Dropping a member mid-gather discards its partial shards:
    /// the ring re-forms from complete members only, and the mean
    /// re-normalizes over that shard set inside `ring_mean_inplace`.
    fn exchange(&mut self, step: u64, ps: &ParamSet) -> Result<CollectiveStep> {
        let gd = self.ctx.cfg.group_devices;
        let numel = ps.numel();
        let step_compute = self.ctx.env.cost.train_step_time(self.ctx.env.exec_batch);
        let deaths0 = self.deaths;
        self.poll_joins(step);

        // ---- broadcast this step's weights --------------------------
        let bcast = Msg::P1Step { step, params: ps.data().to_vec() };
        for s in 0..self.members.len() {
            let wrote = match self.members[s].as_mut() {
                Some(link) => {
                    let r = wire::write_msg(&mut link.conn, &bcast);
                    if r.is_ok() {
                        link.last_heard = Instant::now();
                    }
                    r
                }
                None => continue,
            };
            match wrote {
                Ok(nb) => {
                    self.sent += nb;
                    self.payload += 4 * numel as u64;
                }
                Err(e) => self.drop_member(s, &format!("weight broadcast failed: {e}")),
            }
        }
        self.check_quorum(step)?;

        // ---- gather shard gradients ---------------------------------
        let members = self.members.len();
        let mut shards: Vec<Option<(Vec<f32>, BatchStats)>> = Vec::new();
        shards.resize_with(members * gd, || None);
        let done = |shards: &[Option<(Vec<f32>, BatchStats)>], s: usize| {
            shards[s * gd..(s + 1) * gd].iter().all(|x| x.is_some())
        };
        let mut first_complete: Option<Instant> = None;
        loop {
            let mut waiting = 0usize;
            for s in 0..members {
                let pumped = match self.members[s].as_mut() {
                    Some(link) => {
                        if done(&shards, s) {
                            continue;
                        }
                        pump_member(link, s, step, gd, numel, &mut shards)
                    }
                    None => continue,
                };
                match pumped {
                    Ok((framed, pay)) => {
                        self.recvd += framed;
                        self.payload += pay;
                    }
                    Err(reason) => {
                        self.drop_member(s, &reason);
                        // a dead member's partial shards never enter the mean
                        for sh in &mut shards[s * gd..(s + 1) * gd] {
                            *sh = None;
                        }
                        continue;
                    }
                }
                if done(&shards, s) {
                    if first_complete.is_none() {
                        first_complete = Some(Instant::now());
                    }
                } else {
                    waiting += 1;
                }
            }
            if waiting == 0 {
                break;
            }
            // failure-policy sweep over the members still owing shards
            let now = Instant::now();
            for s in 0..members {
                let silent = match &self.members[s] {
                    Some(link) if !done(&shards, s) => now.duration_since(link.last_heard),
                    _ => continue,
                };
                if silent > self.ctx.policy.io_timeout {
                    self.drop_member(
                        s,
                        &format!("no shard data within {:?}", self.ctx.policy.io_timeout),
                    );
                    for sh in &mut shards[s * gd..(s + 1) * gd] {
                        *sh = None;
                    }
                } else if let Some(t0) = first_complete {
                    if now.duration_since(t0) > self.ctx.policy.straggler_grace {
                        self.drop_member(
                            s,
                            &format!(
                                "straggler: shards unfinished {:?} after the first member",
                                self.ctx.policy.straggler_grace
                            ),
                        );
                        for sh in &mut shards[s * gd..(s + 1) * gd] {
                            *sh = None;
                        }
                    }
                }
            }
            self.check_quorum(step)?;
        }
        self.check_quorum(step)?;

        // ---- assemble in ascending absolute device order ------------
        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(self.live() * gd);
        let mut stats = BatchStats::default();
        for sh in shards.into_iter().flatten() {
            stats.accumulate(&sh.1);
            grads.push(sh.0);
        }
        let live_devices = grads.len();
        // every death this step wasted its gd shards' modeled compute
        let lost = (self.deaths - deaths0) as f64 * step_compute * gd as f64;
        Ok(CollectiveStep { grads, stats, live_devices, lost })
    }

    /// Release the surviving members: the collective is over.
    fn finish(&mut self, steps: u64) {
        let msg = Msg::P1Done { step: steps };
        for s in 0..self.members.len() {
            let wrote = match self.members[s].as_mut() {
                Some(link) => wire::write_msg(&mut link.conn, &msg),
                None => continue,
            };
            if let Ok(nb) = wrote {
                self.sent += nb;
            }
        }
    }
}

/// Drain every frame one member has ready this tick, filing its `P1Grad`
/// shards for the current step. `Err` is a drop reason (dead link,
/// protocol violation, foreign shard, bad arena); `Ok` carries the
/// (framed, weight-payload) byte counts drained.
fn pump_member(
    link: &mut MemberLink,
    s: usize,
    step: u64,
    gd: usize,
    numel: usize,
    shards: &mut [Option<(Vec<f32>, BatchStats)>],
) -> std::result::Result<(u64, u64), String> {
    let mut framed = 0u64;
    let mut payload = 0u64;
    loop {
        let msg = match link.reader.poll(&mut link.conn) {
            Ok(Some((msg, nb))) => {
                framed += nb;
                link.last_heard = Instant::now();
                msg
            }
            Ok(None) => return Ok((framed, payload)),
            Err(e) => return Err(format!("connection lost: {e}")),
        };
        match msg {
            Msg::Heartbeat { .. } => {}
            Msg::P1Grad { device, step: gstep, stats, grads } => {
                if gstep != step {
                    continue; // stale shard from a superseded step
                }
                if device / gd != s {
                    return Err(format!("delivered foreign device shard {device}"));
                }
                if grads.len() != numel {
                    return Err(format!(
                        "bad gradient arena: {} values, expected {numel}",
                        grads.len()
                    ));
                }
                payload += 4 * grads.len() as u64;
                shards[device] = Some((grads, stats));
            }
            _ => return Err("spoke out of protocol".to_string()),
        }
    }
}

/// The hub side of a distributed phase 1: identical bookkeeping and
/// progress recording to `run_phase1_local`, with the per-device
/// gradients gathered from remote members by a [`Phase1Hub`].
fn serve_phase1(
    addr: &str,
    listener: &Listener,
    ctx: &Phase1Ctx,
    params: &mut ParamSet,
    momentum: &mut ParamSet,
    clock: &mut ClusterClock,
) -> Result<Phase1Report> {
    let mut snapshots: Vec<(usize, ParamSet)> = Vec::new();
    let snap = ctx.cfg.phase1_snapshot_every;
    let observer = |step: usize, ps: &ParamSet, _: &BatchStats| {
        if let Some(every) = snap {
            if step % every == 0 {
                snapshots.push((step, ps.clone()));
            }
        }
    };

    let mut resume = None;
    let mut hook_state: Option<(Phase1Recorder, Option<u64>)> = None;
    if let Some(dir) = ctx.run_dir {
        let (rec, found) = super::open_phase1_record(ctx, dir, params, momentum, clock)?;
        hook_state = Some((rec, found.map(|r| r.start_step as u64)));
        resume = found;
    }
    let recording = hook_state.is_some();
    let record_every = ctx.cfg.phase1_record_every.max(1);
    let mut hook = |st: &SyncState| -> Result<()> {
        let Some((rec, prev)) = hook_state.as_mut() else { return Ok(()) };
        if st.step == 0 || st.step % record_every != 0 {
            return Ok(());
        }
        super::record_phase1_step(ctx, ctx.run_dir.unwrap(), rec, prev, st)
    };
    let progress: Option<ProgressHook> = if recording { Some(&mut hook) } else { None };

    let start_step = resume.as_ref().map_or(0, |r| r.start_step) as u64;
    let mut hub = Phase1Hub {
        addr,
        listener,
        ctx,
        members: (0..ctx.cfg.workers).map(|_| None).collect(),
        sent: 0,
        recvd: 0,
        payload: 0,
        deaths: 0,
    };
    hub.join_window(start_step)?;

    let p = run_sync_collective(
        ctx.env,
        params,
        momentum,
        &ctx.train,
        clock,
        observer,
        resume,
        progress,
        |step, ps| hub.exchange(step as u64, ps),
    )?;
    hub.finish(p.steps as u64);
    crate::info!(
        "serve: phase 1 collective done after {} steps ({} members dropped)",
        p.steps,
        hub.deaths
    );
    Ok(Phase1Report {
        progress: p,
        snapshots,
        net: NetStats { framed_bytes: hub.sent + hub.recvd, param_bytes: hub.payload },
    })
}

// ---------------------------------------------------------------------
// Coordinator side
// ---------------------------------------------------------------------

/// Per-link state shared between its reader thread and the supervisor.
struct LinkState {
    worker: usize,
    outcome: Mutex<Option<WorkerOutcome>>,
    last_heard: Mutex<Instant>,
}

/// First writer wins: the supervisor may drop a link (timeout, straggler)
/// in the same instant its reader delivers a verdict.
fn set_once(slot: &Mutex<Option<WorkerOutcome>>, outcome: WorkerOutcome) {
    let mut g = slot.lock().unwrap();
    if g.is_none() {
        *g = Some(outcome);
    }
}

fn serve_phase2(addr: &str, listener: &Listener, ctx: &Phase2Ctx) -> Result<Phase2Report> {
    let policy = ctx.policy;
    crate::info!(
        "serve: listening on {addr} for {} phase-2 workers (join window {:?})",
        ctx.pending.len(),
        policy.connect_timeout
    );

    let sent = AtomicU64::new(0);
    let recvd = AtomicU64::new(0);
    let payload = AtomicU64::new(0);

    // ---- join window ---------------------------------------------------
    let mut links: Vec<(usize, Conn)> = Vec::new();
    let mut unassigned: Vec<usize> = ctx.pending.to_vec();
    let deadline = Instant::now() + policy.connect_timeout;
    while !unassigned.is_empty() && Instant::now() < deadline {
        match listener.accept() {
            Ok(conn) => {
                if let Some((w, conn)) =
                    handshake(conn, ctx, &mut unassigned, &sent, &recvd, &payload)
                {
                    crate::info!("serve: worker {w} joined");
                    links.push((w, conn));
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => return Err(e.into()),
        }
    }
    let mut outcomes: Vec<(usize, WorkerOutcome)> = unassigned
        .iter()
        .map(|&w| {
            (
                w,
                WorkerOutcome::Dropped {
                    reason: format!("no worker joined within {:?}", policy.connect_timeout),
                },
            )
        })
        .collect();

    // ---- supervise one reader thread per link --------------------------
    let layout = ctx.start.layout().clone();
    let mut states: Vec<LinkState> = Vec::with_capacity(links.len());
    let mut ctls: Vec<Conn> = Vec::with_capacity(links.len());
    let mut conns: Vec<Conn> = Vec::with_capacity(links.len());
    for (w, conn) in links {
        ctls.push(conn.try_clone()?);
        states.push(LinkState {
            worker: w,
            outcome: Mutex::new(None),
            last_heard: Mutex::new(Instant::now()),
        });
        conns.push(conn);
    }
    std::thread::scope(|s| {
        for (i, conn) in conns.into_iter().enumerate() {
            let st = &states[i];
            let layout = &layout;
            let recvd = &recvd;
            let payload = &payload;
            s.spawn(move || reader_loop(conn, st, ctx, layout, recvd, payload));
        }
        // the supervisor: polls liveness and applies the failure policy,
        // shutting down a link to force its blocked reader to return
        let mut first_done: Option<Instant> = None;
        loop {
            let now = Instant::now();
            let mut open = 0usize;
            let mut any_done = false;
            for st in &states {
                match &*st.outcome.lock().unwrap() {
                    Some(WorkerOutcome::Done { .. }) => any_done = true,
                    Some(WorkerOutcome::Dropped { .. }) => {}
                    None => open += 1,
                }
            }
            if any_done && first_done.is_none() {
                first_done = Some(now);
            }
            if open == 0 {
                break;
            }
            for (st, ctl) in states.iter().zip(&ctls) {
                if st.outcome.lock().unwrap().is_some() {
                    continue;
                }
                let silent = now.duration_since(*st.last_heard.lock().unwrap());
                if silent > policy.io_timeout {
                    set_once(
                        &st.outcome,
                        WorkerOutcome::Dropped {
                            reason: format!("no heartbeat within {:?}", policy.io_timeout),
                        },
                    );
                    ctl.shutdown();
                } else if let Some(t0) = first_done {
                    if now.duration_since(t0) > policy.straggler_grace {
                        set_once(
                            &st.outcome,
                            WorkerOutcome::Dropped {
                                reason: format!(
                                    "straggler: unfinished {:?} after the first worker",
                                    policy.straggler_grace
                                ),
                            },
                        );
                        ctl.shutdown();
                    }
                }
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    });
    for st in states {
        let outcome = st.outcome.into_inner().unwrap().unwrap_or(WorkerOutcome::Dropped {
            reason: "link reader exited without a verdict".to_string(),
        });
        outcomes.push((st.worker, outcome));
    }
    Ok(Phase2Report {
        outcomes,
        net: NetStats {
            framed_bytes: sent.load(Ordering::Relaxed) + recvd.load(Ordering::Relaxed),
            param_bytes: payload.load(Ordering::Relaxed),
        },
    })
}

/// Admit one candidate connection: read its Join, check the fingerprint,
/// assign a worker id (the requested unfinished id if free, else the
/// lowest), send the phase-1 weights. `None` drops the candidate without
/// consuming a worker slot.
fn handshake(
    conn: Conn,
    ctx: &Phase2Ctx,
    unassigned: &mut Vec<usize>,
    sent: &AtomicU64,
    recvd: &AtomicU64,
    payload: &AtomicU64,
) -> Option<(usize, Conn)> {
    let mut conn = conn;
    // the listener is non-blocking; the handshake itself must not be (but
    // also must not hang the join loop on a silent client)
    conn.set_nonblocking(false).ok()?;
    conn.set_read_timeout(Some(ctx.policy.io_timeout)).ok()?;
    let (msg, nb) = match wire::read_msg(&mut conn) {
        Ok(x) => x,
        Err(e) => {
            crate::warn_!("serve: handshake failed: {e}");
            return None;
        }
    };
    recvd.fetch_add(nb, Ordering::Relaxed);
    let (fingerprint, resume) = match msg {
        Msg::Join { fingerprint, resume } => (fingerprint, resume),
        Msg::P1Join { .. } => {
            // a collective member restarted after phase 1 finished: tell
            // it so, and it falls through to a phase-2 join
            let reject = Msg::Reject { reason: PHASE1_DONE_REJECT.to_string() };
            if let Ok(nb) = wire::write_msg(&mut conn, &reject) {
                sent.fetch_add(nb, Ordering::Relaxed);
            }
            return None;
        }
        _ => {
            crate::warn_!("serve: candidate spoke out of protocol, dropped");
            return None;
        }
    };
    if fingerprint != ctx.fingerprint {
        crate::warn_!("serve: rejected join with a mismatched config fingerprint");
        let reject = Msg::Reject {
            reason: format!(
                "config fingerprint mismatch: coordinator runs {}, you presented {}",
                ctx.fingerprint, fingerprint
            ),
        };
        if let Ok(nb) = wire::write_msg(&mut conn, &reject) {
            sent.fetch_add(nb, Ordering::Relaxed);
        }
        return None;
    }
    let w = match resume {
        Some(r) if unassigned.contains(&r) => r,
        _ => *unassigned.iter().min()?,
    };
    let assign = Msg::Assign { worker: w, params: ctx.start.data().to_vec() };
    match wire::write_msg(&mut conn, &assign) {
        Ok(nb) => {
            sent.fetch_add(nb, Ordering::Relaxed);
            payload.fetch_add(4 * ctx.start.numel() as u64, Ordering::Relaxed);
        }
        Err(e) => {
            crate::warn_!("serve: could not send weights to a joining worker: {e}");
            return None;
        }
    }
    conn.set_read_timeout(None).ok()?;
    unassigned.retain(|&x| x != w);
    Some((w, conn))
}

fn reader_loop(
    mut conn: Conn,
    st: &LinkState,
    ctx: &Phase2Ctx,
    layout: &Arc<ParamLayout>,
    recvd: &AtomicU64,
    payload: &AtomicU64,
) {
    let w = st.worker;
    loop {
        match wire::read_msg(&mut conn) {
            Ok((msg, nb)) => {
                recvd.fetch_add(nb, Ordering::Relaxed);
                *st.last_heard.lock().unwrap() = Instant::now();
                match msg {
                    Msg::Heartbeat { .. } => {}
                    Msg::Done { worker: _, params, clock } => {
                        payload.fetch_add(4 * params.len() as u64, Ordering::Relaxed);
                        let outcome = match ParamSet::from_data(layout.clone(), params) {
                            Ok(wp) => {
                                if let Some(dir) = ctx.run_dir {
                                    if let Err(e) =
                                        save_params(dir.worker_ckpt(w), ctx.env.engine.manifest(), &wp)
                                    {
                                        crate::warn_!("serve: checkpoint of worker {w} failed: {e}");
                                    }
                                }
                                WorkerOutcome::Done { params: wp, clock, trail: Vec::new() }
                            }
                            Err(e) => WorkerOutcome::Dropped {
                                reason: format!("bad weight upload: {e}"),
                            },
                        };
                        set_once(&st.outcome, outcome);
                        break;
                    }
                    Msg::Abort { worker: _, reason } => {
                        set_once(
                            &st.outcome,
                            WorkerOutcome::Dropped { reason: format!("worker aborted: {reason}") },
                        );
                        break;
                    }
                    _ => {
                        set_once(
                            &st.outcome,
                            WorkerOutcome::Dropped { reason: "spoke out of protocol".to_string() },
                        );
                        conn.shutdown();
                        break;
                    }
                }
            }
            Err(e) => {
                // EOF, reset, or a supervisor-initiated shutdown (in which
                // case the outcome is already set and this is a no-op)
                set_once(
                    &st.outcome,
                    WorkerOutcome::Dropped { reason: format!("connection lost: {e}") },
                );
                break;
            }
        }
    }
}

// ---------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------

/// Bounded connect retry with jittered backoff: the coordinator may not
/// be listening yet, or may be busy inside an earlier phase.
fn connect_with_retry(addr: &str, policy: &FailurePolicy) -> Result<Conn> {
    let mut attempt = 0usize;
    loop {
        match Conn::connect(addr) {
            Ok(c) => return Ok(c),
            Err(e) => {
                if attempt >= policy.join_retries {
                    return Err(Error::config(format!(
                        "join: cannot reach {addr} after {} attempts: {e}",
                        attempt + 1
                    )));
                }
                std::thread::sleep(
                    policy.backoff_delay(attempt as u32, std::process::id() as u64),
                );
                attempt += 1;
            }
        }
    }
}

/// What a successful phase-1 membership did, for CLI reporting.
#[derive(Debug, Clone, Copy)]
pub struct Phase1JoinSummary {
    pub slot: usize,
    /// the step the hub admitted us at (0 for a fresh run; later when
    /// rejoining a collective in flight or resumed from its record)
    pub first_step: u64,
    /// sync steps this process computed shards for
    pub steps: u64,
    pub bytes_sent: u64,
    pub bytes_received: u64,
}

/// How a phase-1 join attempt resolved.
#[derive(Debug)]
pub enum Phase1Outcome {
    /// This process served as a collective member.
    Participated(Phase1JoinSummary),
    /// The coordinator already finished phase 1 (a restarted member can
    /// miss the whole collective); proceed straight to [`join_run`].
    AlreadyDone,
}

/// Join a coordinator at `addr` as one phase-1 collective member owning
/// `group_devices` consecutive device shards. Per `P1Step` the member
/// assembles its shard batches — pure functions of the step index, the
/// same sampler draws and counter-keyed augmentation as the hub's
/// in-process path — computes the gradients, and uploads one `P1Grad`
/// per device. `want` asks to re-adopt a specific member slot after a
/// restart; the hub honors it when free. Returns
/// [`Phase1Outcome::AlreadyDone`] when the hub has moved on to phase 2.
pub fn join_phase1(
    env: &TrainEnv,
    cfg: &SwapConfig,
    addr: &str,
    policy: &FailurePolicy,
    want: Option<usize>,
) -> Result<Phase1Outcome> {
    let fingerprint = super::run_fingerprint(env, cfg);
    let train = phase1_train_config(cfg, env);
    let gd = cfg.group_devices;
    let total_devices = cfg.total_devices();
    let numel = env.engine.manifest().num_params;

    let mut conn = connect_with_retry(addr, policy)?;
    let mut sent = 0u64;
    let mut recvd = 0u64;
    sent += wire::write_msg(&mut conn, &Msg::P1Join { fingerprint, slot: want })?;
    conn.set_read_timeout(Some(policy.io_timeout))?;
    let (msg, nb) = wire::read_msg(&mut conn)?;
    recvd += nb;
    let (slot, first_step) = match msg {
        Msg::P1Assign { slot, step } => (slot, step),
        Msg::Reject { reason } if reason == PHASE1_DONE_REJECT => {
            crate::info!("join: {reason}; proceeding to phase 2");
            return Ok(Phase1Outcome::AlreadyDone);
        }
        Msg::Reject { reason } => {
            return Err(Error::config(format!("phase 1 join rejected: {reason}")))
        }
        _ => return Err(Error::invalid("phase 1 join: hub spoke out of protocol")),
    };
    if slot >= cfg.workers {
        return Err(Error::invalid(format!("phase 1 join: slot {slot} out of range")));
    }
    crate::info!("join: phase 1 member {slot} from step {first_step}, computing shards");
    // a hub waiting out another member's straggler grace must not look
    // dead to us
    conn.set_read_timeout(Some(policy.io_timeout + policy.straggler_grace))?;

    let mut sampler =
        EpochSampler::new(env.train.n, train.global_batch, train.seed, train.seed_stream);
    let mut batcher = Batcher::new(env.exec_batch, env.image_size(), env.augment);
    let aug = AugStream { seed: train.seed ^ 0xAE6, stream: train.seed_stream };
    // batch t is the t-th draw of the sampler sequence on every path:
    // skip the draws the steps before our admission already consumed
    for _ in 0..first_step {
        sampler.next_batch();
    }
    let mut next_draw = first_step;
    let mut hb = batcher.make_batch();
    let mut steps = 0u64;
    loop {
        let (msg, nb) = wire::read_msg(&mut conn)?;
        recvd += nb;
        match msg {
            Msg::P1Step { step, params } => {
                if params.len() != numel {
                    return Err(Error::invalid(format!(
                        "phase 1 join: broadcast carried {} weights, expected {numel}",
                        params.len()
                    )));
                }
                if step < next_draw {
                    return Err(Error::invalid(format!(
                        "phase 1 join: hub stepped backwards ({step} < {next_draw})"
                    )));
                }
                // liveness before the (long) shard compute
                sent += wire::write_msg(&mut conn, &Msg::Heartbeat { worker: slot, step })?;
                for _ in next_draw..step {
                    sampler.next_batch();
                }
                next_draw = step + 1;
                let global = sampler.next_batch();
                let per = global.len() / total_devices;
                for d in 0..gd {
                    let dev = slot * gd + d;
                    let rows = &global[dev * per..(dev + 1) * per];
                    batcher.assemble_step_into(
                        env.train,
                        rows,
                        aug,
                        step,
                        (dev * per) as u64,
                        &mut hb,
                    );
                    let g = env.engine.grad(&params, &hb)?;
                    sent += wire::write_msg(
                        &mut conn,
                        &Msg::P1Grad { device: dev, step, stats: g.stats, grads: g.grads },
                    )?;
                }
                steps += 1;
            }
            Msg::P1Done { step } => {
                crate::info!("join: phase 1 complete at step {step} ({steps} steps computed)");
                return Ok(Phase1Outcome::Participated(Phase1JoinSummary {
                    slot,
                    first_step,
                    steps,
                    bytes_sent: sent,
                    bytes_received: recvd,
                }));
            }
            Msg::Reject { reason } => {
                return Err(Error::config(format!("phase 1 join: dropped by hub: {reason}")))
            }
            _ => return Err(Error::invalid("phase 1 join: hub spoke out of protocol")),
        }
    }
}

/// What a successful `join_run` did, for CLI reporting.
#[derive(Debug, Clone, Copy)]
pub struct JoinSummary {
    pub worker: usize,
    pub steps: usize,
    pub bytes_sent: u64,
    pub bytes_received: u64,
}

/// Join a coordinator at `addr` as one phase-2 worker: connect (with
/// bounded retry — the coordinator may still be in phase 1), present this
/// process's config fingerprint, train the assigned worker's deterministic
/// recipe from the broadcast phase-1 weights, and upload the replica.
/// `want` asks to adopt a specific unfinished worker id (rejoining after
/// a crash); the coordinator honors it when free.
pub fn join_run(
    env: &TrainEnv,
    cfg: &SwapConfig,
    addr: &str,
    policy: &FailurePolicy,
    want: Option<usize>,
) -> Result<JoinSummary> {
    let fingerprint = super::run_fingerprint(env, cfg);
    let mut conn = connect_with_retry(addr, policy)?;
    let mut sent = 0u64;
    let mut recvd = 0u64;
    sent += wire::write_msg(&mut conn, &Msg::Join { fingerprint, resume: want })?;
    conn.set_read_timeout(Some(policy.io_timeout))?;
    let (msg, nb) = wire::read_msg(&mut conn)?;
    recvd += nb;
    let (w, start) = match msg {
        Msg::Assign { worker, params } => {
            let layout = ParamLayout::of_params(env.engine.manifest());
            (worker, ParamSet::from_data(layout, params)?)
        }
        Msg::Reject { reason } => return Err(Error::config(format!("join rejected: {reason}"))),
        _ => return Err(Error::invalid("join: coordinator spoke out of protocol")),
    };
    conn.set_read_timeout(None)?;
    crate::info!("join: assigned worker {w}, training");

    let mut wp = start;
    let mut wm = wp.zeros_like();
    let mut wclock = ClusterClock::new();
    let mut last_hb = Instant::now();
    let mut hb_dead = false;
    let progress = run_sync_training(
        env,
        &mut wp,
        &mut wm,
        &phase2_worker_config(cfg, env, w),
        &mut wclock,
        |step, _, _| {
            if !hb_dead && last_hb.elapsed() >= policy.heartbeat {
                match wire::write_msg(&mut conn, &Msg::Heartbeat { worker: w, step: step as u64 }) {
                    Ok(nb) => {
                        sent += nb;
                        last_hb = Instant::now();
                    }
                    // the coordinator is gone or dropped us; keep training
                    // (the result is still correct) and let the final
                    // upload surface the error
                    Err(_) => hb_dead = true,
                }
            }
        },
    );
    match progress {
        Ok(p) => {
            sent += wire::write_msg(
                &mut conn,
                &Msg::Done { worker: w, params: wp.into_data(), clock: wclock },
            )?;
            crate::info!("join: worker {w} done after {} steps", p.steps);
            Ok(JoinSummary { worker: w, steps: p.steps, bytes_sent: sent, bytes_received: recvd })
        }
        Err(e) => {
            let _ = wire::write_msg(
                &mut conn,
                &Msg::Abort { worker: w, reason: e.to_string() },
            );
            Err(e)
        }
    }
}
