//! Loopback wire calibration — the measurement half of ROADMAP item 1.
//!
//! `ClusterClock` prices communication with an α–β [`NetModel`]; this
//! module measures α and β on a REAL loopback socket pair and times the
//! phase-1 hub-exchange pattern over real sockets, so the modeled comm
//! time can be held against measured wall clock on the same machine.
//! rust/tests/transport.rs asserts the two agree within a stated
//! tolerance, and rust/benches/transport.rs reports the
//! measured-vs-predicted rows in BENCH_transport.json.
//!
//! TCP on 127.0.0.1 with ephemeral ports: nothing here touches the
//! transport's own listener, and no fixed port can collide in CI.

use std::net::{TcpListener, TcpStream};
use std::time::Instant;

use super::wire::{self, Msg};
use crate::runtime::BatchStats;
use crate::sim::NetModel;
use crate::util::{Error, Result};

/// Measured loopback constants, in [`NetModel`] units.
#[derive(Debug, Clone, Copy)]
pub struct Calibration {
    /// one-way per-frame latency α in seconds
    pub latency: f64,
    /// payload bandwidth β in bytes/sec
    pub bandwidth: f64,
}

impl Calibration {
    /// A [`NetModel`] priced with the measured constants — plug into a
    /// [`crate::sim::CostModel`] to predict wire time on THIS machine.
    pub fn net_model(&self) -> NetModel {
        NetModel { latency: self.latency, bandwidth: self.bandwidth }
    }
}

/// A connected loopback pair with Nagle disabled (coalescing would fold
/// whole round trips into one segment and poison the latency estimate).
fn pair() -> Result<(TcpStream, TcpStream)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let client = TcpStream::connect(addr)?;
    let (server, _) = listener.accept()?;
    client.set_nodelay(true)?;
    server.set_nodelay(true)?;
    Ok((client, server))
}

/// Measure α from `iters` small-frame round trips (rtt ≈ 2α) and β from
/// bulk frames of `bulk_bytes` f32 payload (per trip ≈ rtt + bytes/β) on
/// a fresh loopback pair.
pub fn calibrate(iters: usize, bulk_bytes: usize) -> Result<Calibration> {
    let (mut a, mut b) = pair()?;
    let iters = iters.max(1);
    let echo = std::thread::spawn(move || -> Result<()> {
        loop {
            let (msg, _) = wire::read_msg(&mut b)?;
            match msg {
                Msg::Heartbeat { .. } | Msg::P1Step { .. } => {
                    wire::write_msg(&mut b, &Msg::Heartbeat { worker: 0, step: 0 })?;
                }
                _ => return Ok(()),
            }
        }
    });

    let ping = Msg::Heartbeat { worker: 0, step: 0 };
    for _ in 0..iters.min(8) {
        wire::write_msg(&mut a, &ping)?;
        wire::read_msg(&mut a)?;
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        wire::write_msg(&mut a, &ping)?;
        wire::read_msg(&mut a)?;
    }
    let rtt = t0.elapsed().as_secs_f64() / iters as f64;
    let latency = (rtt / 2.0).max(1e-9);

    let numel = (bulk_bytes / 4).max(1);
    let bulk = Msg::P1Step { step: 0, params: vec![1.0f32; numel] };
    wire::write_msg(&mut a, &bulk)?;
    wire::read_msg(&mut a)?; // warm-up trip
    let reps = 8usize;
    let t0 = Instant::now();
    for _ in 0..reps {
        wire::write_msg(&mut a, &bulk)?;
        wire::read_msg(&mut a)?;
    }
    let per = t0.elapsed().as_secs_f64() / reps as f64;
    let transfer = (per - rtt).max(1e-9);
    let bandwidth = (4 * numel) as f64 / transfer;

    wire::write_msg(&mut a, &Msg::P1Done { step: 0 })?;
    echo.join().map_err(|_| Error::invalid("loopback echo thread panicked"))??;
    Ok(Calibration { latency, bandwidth })
}

/// Time `serve_phase1`'s per-step wire pattern in isolation: the hub
/// broadcasts a `numel`-weight `P1Step` to every member and gathers `gd`
/// same-sized `P1Grad`s per member back — no training, so the wall clock
/// is pure wire + codec. Returns mean seconds per step, the measured
/// counterpart of [`NetModel::hub_exchange`].
pub fn time_hub_exchange(members: usize, gd: usize, numel: usize, steps: usize) -> Result<f64> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let steps = steps.max(1);
    std::thread::scope(|s| -> Result<f64> {
        for m in 0..members {
            s.spawn(move || -> Result<()> {
                let mut conn = TcpStream::connect(addr)?;
                conn.set_nodelay(true)?;
                loop {
                    let (msg, _) = wire::read_msg(&mut conn)?;
                    match msg {
                        Msg::P1Step { step, params } => {
                            for d in 0..gd {
                                wire::write_msg(
                                    &mut conn,
                                    &Msg::P1Grad {
                                        device: m * gd + d,
                                        step,
                                        stats: BatchStats::default(),
                                        grads: params.clone(),
                                    },
                                )?;
                            }
                        }
                        _ => return Ok(()),
                    }
                }
            });
        }
        let mut links: Vec<TcpStream> = Vec::with_capacity(members);
        for _ in 0..members {
            let (conn, _) = listener.accept()?;
            conn.set_nodelay(true)?;
            links.push(conn);
        }
        let msg = Msg::P1Step { step: 0, params: vec![1.0f32; numel] };
        let mut exchange = |links: &mut [TcpStream]| -> Result<()> {
            for l in links.iter_mut() {
                wire::write_msg(l, &msg)?;
            }
            for l in links.iter_mut() {
                for _ in 0..gd {
                    wire::read_msg(l)?;
                }
            }
            Ok(())
        };
        exchange(&mut links)?; // warm-up step
        let t0 = Instant::now();
        for _ in 0..steps {
            exchange(&mut links)?;
        }
        let per = t0.elapsed().as_secs_f64() / steps as f64;
        for l in links.iter_mut() {
            wire::write_msg(l, &Msg::P1Done { step: steps as u64 })?;
        }
        Ok(per)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_is_finite_and_positive() {
        let c = calibrate(16, 1 << 16).unwrap();
        assert!(c.latency > 0.0 && c.latency.is_finite(), "latency {}", c.latency);
        assert!(c.bandwidth > 0.0 && c.bandwidth.is_finite(), "bandwidth {}", c.bandwidth);
        // loopback is fast, but not faster than light: sanity bounds only,
        // wide enough for the noisiest CI runner
        assert!(c.latency < 0.1);
        assert!(c.bandwidth > 1e4);
        let n = c.net_model();
        assert!(n.hub_exchange(1 << 20, 2, 4) > 0.0);
    }

    #[test]
    fn hub_exchange_timing_runs() {
        let per = time_hub_exchange(2, 2, 256, 4).unwrap();
        assert!(per > 0.0 && per.is_finite());
    }
}
