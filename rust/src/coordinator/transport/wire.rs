//! Length-prefix framed wire protocol for the socket transport.
//!
//! Every message is one frame: a `u32` little-endian payload length, then
//! the payload — a one-byte tag followed by the tag's fields. Fields are
//! fixed-width little-endian scalars, `u32`-length-prefixed UTF-8 strings,
//! and `u32`-count-prefixed `f32` arenas (the weight payload is the flat
//! parameter arena verbatim, so a replica round-trips bit-exactly).
//!
//! ```text
//! Join      { fingerprint: str, resume: u64 (MAX = none) }   worker -> coord
//! Assign    { worker: u64, params: f32s }                    coord  -> worker
//! Reject    { reason: str }                                  coord  -> worker
//! Heartbeat { worker: u64, step: u64 }                       worker -> coord
//! Done      { worker: u64, params: f32s, clock: 6 x f64 }    worker -> coord
//! Abort     { worker: u64, reason: str }                     worker -> coord
//! P1Join    { fingerprint: str, slot: u64 (MAX = none) }     worker -> coord
//! P1Assign  { slot: u64, step: u64 }                         coord  -> worker
//! P1Step    { step: u64, params: f32s }                      coord  -> worker
//! P1Grad    { device: u64, step: u64, stats: 4 x 8B, grads: f32s }
//!                                                            worker -> coord
//! P1Done    { step: u64 }                                    coord  -> worker
//! ```
//!
//! The `P1*` family carries the distributed phase-1 collective: a member
//! joins with `P1Join`, is assigned a shard slot and a resume step with
//! `P1Assign`, then per sync step receives the full parameter arena in
//! `P1Step`, replies one `P1Grad` per local device shard (the step echo
//! is the barrier token — a stale-step gradient is dropped, not summed),
//! and is released by `P1Done` when the phase completes.
//!
//! Every encode/decode returns the exact framed byte count, feeding the
//! transport's `NetStats` — the byte-accounting tests compare those
//! measurements against `CostModel::phase2_comm_bytes` and the frame-size
//! formulas below.

use std::io::{Read, Write};

use crate::runtime::BatchStats;
use crate::sim::ClusterClock;
use crate::util::{Error, Result};

/// Hard upper bound on one frame's payload (hostile-input guard; the
/// largest legitimate frame is a weight upload, well under this).
pub const MAX_FRAME: usize = 1 << 30;

const TAG_JOIN: u8 = 1;
const TAG_ASSIGN: u8 = 2;
const TAG_REJECT: u8 = 3;
const TAG_HEARTBEAT: u8 = 4;
const TAG_DONE: u8 = 5;
const TAG_ABORT: u8 = 6;
const TAG_P1_JOIN: u8 = 7;
const TAG_P1_ASSIGN: u8 = 8;
const TAG_P1_STEP: u8 = 9;
const TAG_P1_GRAD: u8 = 10;
const TAG_P1_DONE: u8 = 11;

/// One protocol message.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Worker asks to participate, presenting its config fingerprint and
    /// (optionally) the unfinished worker id it wants to adopt.
    Join { fingerprint: String, resume: Option<usize> },
    /// Coordinator assigns a worker id and broadcasts the phase-1 weights.
    Assign { worker: usize, params: Vec<f32> },
    /// Coordinator refuses a join (fingerprint mismatch, no free slot).
    Reject { reason: String },
    /// Worker liveness signal, sent every `FailurePolicy::heartbeat`.
    Heartbeat { worker: usize, step: u64 },
    /// Worker uploads its finished replica and its modeled clock.
    Done { worker: usize, params: Vec<f32>, clock: ClusterClock },
    /// Worker reports a terminal error (it will be dropped, not retried).
    Abort { worker: usize, reason: String },
    /// Member asks to participate in the phase-1 collective, presenting
    /// its fingerprint and (optionally) the member slot it wants back.
    P1Join { fingerprint: String, slot: Option<usize> },
    /// Coordinator admits a member: its shard slot and the sync step the
    /// collective is currently at (a rejoiner fast-forwards to it).
    P1Assign { slot: usize, step: u64 },
    /// Coordinator opens sync step `step`: the full parameter arena the
    /// member's shards compute gradients against.
    P1Step { step: u64, params: Vec<f32> },
    /// Member returns one device shard's gradient arena for `step`, with
    /// that shard's batch statistics. The step echo is the barrier token.
    P1Grad { device: usize, step: u64, stats: BatchStats, grads: Vec<f32> },
    /// Coordinator releases the members: phase 1 is complete.
    P1Done { step: u64 },
}

/// Encoded size of a `params` field (count prefix + f32 payload).
pub fn params_field_bytes(n: usize) -> u64 {
    4 + 4 * n as u64
}

/// Total framed size of an `Assign` carrying `n` parameters.
pub fn assign_frame_bytes(n: usize) -> u64 {
    4 + 1 + 8 + params_field_bytes(n)
}

/// Total framed size of a `Done` carrying `n` parameters.
pub fn done_frame_bytes(n: usize) -> u64 {
    4 + 1 + 8 + params_field_bytes(n) + 6 * 8
}

/// Total framed size of a `P1Step` carrying `n` parameters.
pub fn p1_step_frame_bytes(n: usize) -> u64 {
    4 + 1 + 8 + params_field_bytes(n)
}

/// Total framed size of a `P1Grad` carrying `n` gradient values.
pub fn p1_grad_frame_bytes(n: usize) -> u64 {
    4 + 1 + 8 + 8 + 4 * 8 + params_field_bytes(n)
}

fn put_u32(p: &mut Vec<u8>, v: u32) {
    p.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(p: &mut Vec<u8>, v: u64) {
    p.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(p: &mut Vec<u8>, v: f64) {
    p.extend_from_slice(&v.to_le_bytes());
}

fn put_str(p: &mut Vec<u8>, s: &str) {
    put_u32(p, s.len() as u32);
    p.extend_from_slice(s.as_bytes());
}

fn put_f32s(p: &mut Vec<u8>, xs: &[f32]) {
    put_u32(p, xs.len() as u32);
    p.reserve(4 * xs.len());
    for x in xs {
        p.extend_from_slice(&x.to_le_bytes());
    }
}

/// Write one framed message; returns the exact bytes put on the wire.
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> Result<u64> {
    let mut p = Vec::new();
    match msg {
        Msg::Join { fingerprint, resume } => {
            p.push(TAG_JOIN);
            put_str(&mut p, fingerprint);
            put_u64(&mut p, resume.map(|r| r as u64).unwrap_or(u64::MAX));
        }
        Msg::Assign { worker, params } => {
            p.push(TAG_ASSIGN);
            put_u64(&mut p, *worker as u64);
            put_f32s(&mut p, params);
        }
        Msg::Reject { reason } => {
            p.push(TAG_REJECT);
            put_str(&mut p, reason);
        }
        Msg::Heartbeat { worker, step } => {
            p.push(TAG_HEARTBEAT);
            put_u64(&mut p, *worker as u64);
            put_u64(&mut p, *step);
        }
        Msg::Done { worker, params, clock } => {
            p.push(TAG_DONE);
            put_u64(&mut p, *worker as u64);
            put_f32s(&mut p, params);
            put_f64(&mut p, clock.seconds);
            put_f64(&mut p, clock.compute);
            put_f64(&mut p, clock.comm);
            put_f64(&mut p, clock.data_hidden);
            put_f64(&mut p, clock.data_exposed);
            put_f64(&mut p, clock.eval);
        }
        Msg::Abort { worker, reason } => {
            p.push(TAG_ABORT);
            put_u64(&mut p, *worker as u64);
            put_str(&mut p, reason);
        }
        Msg::P1Join { fingerprint, slot } => {
            p.push(TAG_P1_JOIN);
            put_str(&mut p, fingerprint);
            put_u64(&mut p, slot.map(|s| s as u64).unwrap_or(u64::MAX));
        }
        Msg::P1Assign { slot, step } => {
            p.push(TAG_P1_ASSIGN);
            put_u64(&mut p, *slot as u64);
            put_u64(&mut p, *step);
        }
        Msg::P1Step { step, params } => {
            p.push(TAG_P1_STEP);
            put_u64(&mut p, *step);
            put_f32s(&mut p, params);
        }
        Msg::P1Grad { device, step, stats, grads } => {
            p.push(TAG_P1_GRAD);
            put_u64(&mut p, *device as u64);
            put_u64(&mut p, *step);
            put_f64(&mut p, stats.sum_loss);
            put_u64(&mut p, stats.correct1 as u64);
            put_u64(&mut p, stats.correct5 as u64);
            put_u64(&mut p, stats.examples as u64);
            put_f32s(&mut p, grads);
        }
        Msg::P1Done { step } => {
            p.push(TAG_P1_DONE);
            put_u64(&mut p, *step);
        }
    }
    if p.len() > MAX_FRAME {
        return Err(Error::invalid(format!("wire: frame too large ({} bytes)", p.len())));
    }
    w.write_all(&(p.len() as u32).to_le_bytes())?;
    w.write_all(&p)?;
    w.flush()?;
    Ok(4 + p.len() as u64)
}

/// Read one framed message; returns it with the exact bytes consumed.
/// IO errors (including read timeouts set on the stream) pass through as
/// `Error::Io`; malformed frames are `Error::Invalid`.
pub fn read_msg<R: Read>(r: &mut R) -> Result<(Msg, u64)> {
    let mut lb = [0u8; 4];
    r.read_exact(&mut lb)?;
    let len = u32::from_le_bytes(lb) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(Error::invalid(format!("wire: bad frame length {len}")));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok((decode(&buf)?, 4 + len as u64))
}

/// Decode a frame payload (the bytes after the 4-byte length prefix) that
/// arrived through a caller-managed buffer — the multiplexed phase-1 hub
/// accumulates partial reads itself and hands over complete payloads.
pub fn decode_payload(b: &[u8]) -> Result<Msg> {
    decode(b)
}

struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() - self.i < n {
            return Err(Error::invalid("wire: truncated frame"));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str_(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec()).map_err(|_| Error::invalid("wire: non-UTF-8 string"))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn i64(&mut self) -> Result<i64> {
        Ok(self.u64()? as i64)
    }

    fn stats(&mut self) -> Result<BatchStats> {
        Ok(BatchStats {
            sum_loss: self.f64()?,
            correct1: self.i64()?,
            correct5: self.i64()?,
            examples: self.i64()?,
        })
    }

    fn clock(&mut self) -> Result<ClusterClock> {
        Ok(ClusterClock {
            seconds: self.f64()?,
            compute: self.f64()?,
            comm: self.f64()?,
            data_hidden: self.f64()?,
            data_exposed: self.f64()?,
            eval: self.f64()?,
            lost: 0.0, // coordinator-side bookkeeping, never on the wire
        })
    }
}

fn decode(b: &[u8]) -> Result<Msg> {
    let mut c = Cur { b, i: 0 };
    let msg = match c.u8()? {
        TAG_JOIN => {
            let fingerprint = c.str_()?;
            let resume = match c.u64()? {
                u64::MAX => None,
                r => Some(r as usize),
            };
            Msg::Join { fingerprint, resume }
        }
        TAG_ASSIGN => Msg::Assign { worker: c.u64()? as usize, params: c.f32s()? },
        TAG_REJECT => Msg::Reject { reason: c.str_()? },
        TAG_HEARTBEAT => Msg::Heartbeat { worker: c.u64()? as usize, step: c.u64()? },
        TAG_DONE => Msg::Done {
            worker: c.u64()? as usize,
            params: c.f32s()?,
            clock: c.clock()?,
        },
        TAG_ABORT => Msg::Abort { worker: c.u64()? as usize, reason: c.str_()? },
        TAG_P1_JOIN => {
            let fingerprint = c.str_()?;
            let slot = match c.u64()? {
                u64::MAX => None,
                s => Some(s as usize),
            };
            Msg::P1Join { fingerprint, slot }
        }
        TAG_P1_ASSIGN => Msg::P1Assign { slot: c.u64()? as usize, step: c.u64()? },
        TAG_P1_STEP => Msg::P1Step { step: c.u64()?, params: c.f32s()? },
        TAG_P1_GRAD => Msg::P1Grad {
            device: c.u64()? as usize,
            step: c.u64()?,
            stats: c.stats()?,
            grads: c.f32s()?,
        },
        TAG_P1_DONE => Msg::P1Done { step: c.u64()? },
        other => return Err(Error::invalid(format!("wire: unknown message tag {other}"))),
    };
    if c.i != b.len() {
        return Err(Error::invalid("wire: trailing bytes in frame"));
    }
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(msg: Msg) -> (Msg, u64, u64) {
        let mut buf = Vec::new();
        let wrote = write_msg(&mut buf, &msg).unwrap();
        assert_eq!(wrote as usize, buf.len());
        let mut r: &[u8] = &buf;
        let (back, read) = read_msg(&mut r).unwrap();
        assert!(r.is_empty(), "frame fully consumed");
        (back, wrote, read)
    }

    #[test]
    fn all_messages_round_trip_bit_exact() {
        let mut clock = ClusterClock::new();
        clock.advance_compute(1.5);
        clock.advance_comm(0.25);
        clock.note_eval(0.125);
        let msgs = vec![
            Msg::Join { fingerprint: "{\"seed\":42}".into(), resume: None },
            Msg::Join { fingerprint: String::new(), resume: Some(3) },
            Msg::Assign { worker: 2, params: vec![1.0, -0.5, f32::MIN_POSITIVE, 3.25e-7] },
            Msg::Reject { reason: "fingerprint mismatch".into() },
            Msg::Heartbeat { worker: 7, step: 123456 },
            Msg::Done { worker: 0, params: vec![0.1, 0.2, 0.3], clock },
            Msg::Abort { worker: 1, reason: "io error: oh no".into() },
            Msg::P1Join { fingerprint: "{\"seed\":42}".into(), slot: None },
            Msg::P1Join { fingerprint: String::new(), slot: Some(1) },
            Msg::P1Assign { slot: 1, step: 77 },
            Msg::P1Step { step: 12, params: vec![-1.5, f32::MIN_POSITIVE, 0.0] },
            Msg::P1Grad {
                device: 3,
                step: 12,
                stats: BatchStats { sum_loss: 2.25, correct1: 5, correct5: 8, examples: -1 },
                grads: vec![0.5, -0.25, 1e-20],
            },
            Msg::P1Done { step: 96 },
        ];
        for msg in msgs {
            let (back, wrote, read) = round_trip(msg.clone());
            assert_eq!(back, msg);
            assert_eq!(wrote, read);
        }
    }

    #[test]
    fn frame_size_formulas_are_exact() {
        let params = vec![0.5f32; 17];
        let mut buf = Vec::new();
        let wrote = write_msg(&mut buf, &Msg::Assign { worker: 1, params: params.clone() }).unwrap();
        assert_eq!(wrote, assign_frame_bytes(17));
        let mut buf = Vec::new();
        let wrote = write_msg(
            &mut buf,
            &Msg::Done { worker: 1, params: params.clone(), clock: ClusterClock::new() },
        )
        .unwrap();
        assert_eq!(wrote, done_frame_bytes(17));
        let mut buf = Vec::new();
        let wrote = write_msg(&mut buf, &Msg::P1Step { step: 3, params: params.clone() }).unwrap();
        assert_eq!(wrote, p1_step_frame_bytes(17));
        let mut buf = Vec::new();
        let wrote = write_msg(
            &mut buf,
            &Msg::P1Grad { device: 0, step: 3, stats: BatchStats::default(), grads: params },
        )
        .unwrap();
        assert_eq!(wrote, p1_grad_frame_bytes(17));
    }

    #[test]
    fn hostile_frames_rejected() {
        // zero / oversized length prefix
        for lb in [0u32, (MAX_FRAME + 1) as u32] {
            let mut r: &[u8] = &lb.to_le_bytes();
            assert!(read_msg(&mut r).is_err());
        }
        // truncated payload
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Heartbeat { worker: 1, step: 2 }).unwrap();
        let mut r: &[u8] = &buf[..buf.len() - 3];
        assert!(read_msg(&mut r).is_err());
        // unknown tag
        let mut r: &[u8] = &[1, 0, 0, 0, 99];
        assert!(read_msg(&mut r).is_err());
        // short heartbeat body (frame ends mid-field)
        let mut frame = vec![6, 0, 0, 0, TAG_HEARTBEAT];
        frame.extend_from_slice(&[0; 5]); // heartbeat wants 16 body bytes
        let mut r: &[u8] = &frame;
        assert!(read_msg(&mut r).is_err());
        // trailing bytes after a complete message
        let mut p = vec![TAG_HEARTBEAT];
        p.extend_from_slice(&[0; 16]);
        p.push(0xAA); // one byte too many
        let mut frame = (p.len() as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(&p);
        let mut r: &[u8] = &frame;
        assert!(read_msg(&mut r).is_err());
        // truncated string inside a join
        let mut p = vec![TAG_JOIN];
        p.extend_from_slice(&100u32.to_le_bytes()); // claims 100 chars
        p.extend_from_slice(b"short");
        let mut frame = (p.len() as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(&p);
        let mut r: &[u8] = &frame;
        assert!(read_msg(&mut r).is_err());
    }

    /// Frame a raw payload (without the length sanity `write_msg` does).
    fn frame(p: &[u8]) -> Vec<u8> {
        let mut f = (p.len() as u32).to_le_bytes().to_vec();
        f.extend_from_slice(p);
        f
    }

    #[test]
    fn hostile_phase1_frames_rejected() {
        // every P1 tag with an empty body: all want at least one field
        for tag in [TAG_P1_JOIN, TAG_P1_ASSIGN, TAG_P1_STEP, TAG_P1_GRAD, TAG_P1_DONE] {
            let mut r: &[u8] = &frame(&[tag]);
            assert!(read_msg(&mut r).is_err(), "tag {tag} with empty body must be rejected");
        }
        // torn mid-field: each legitimate P1 frame truncated at every
        // prefix length must fail (either short read or short body)
        let msgs = vec![
            Msg::P1Join { fingerprint: "fp".into(), slot: Some(0) },
            Msg::P1Assign { slot: 0, step: 1 },
            Msg::P1Step { step: 1, params: vec![1.0, 2.0] },
            Msg::P1Grad {
                device: 0,
                step: 1,
                stats: BatchStats::default(),
                grads: vec![1.0, 2.0],
            },
            Msg::P1Done { step: 1 },
        ];
        for msg in msgs {
            let mut buf = Vec::new();
            write_msg(&mut buf, &msg).unwrap();
            for cut in 0..buf.len() {
                let mut r: &[u8] = &buf[..cut];
                assert!(read_msg(&mut r).is_err(), "truncation at {cut} must fail");
            }
        }
        // trailing garbage after a complete P1Done
        let mut p = vec![TAG_P1_DONE];
        p.extend_from_slice(&7u64.to_le_bytes());
        p.push(0x55);
        let mut r: &[u8] = &frame(&p);
        assert!(read_msg(&mut r).is_err());
        // arena count prefix claiming more f32s than the frame holds
        let mut p = vec![TAG_P1_STEP];
        p.extend_from_slice(&1u64.to_le_bytes());
        p.extend_from_slice(&1000u32.to_le_bytes()); // claims 1000 f32s
        p.extend_from_slice(&[0u8; 8]); // delivers 2
        let mut r: &[u8] = &frame(&p);
        assert!(read_msg(&mut r).is_err());
        // oversized length prefix on a P1 frame
        let mut r: &[u8] = &((MAX_FRAME + 1) as u32).to_le_bytes();
        assert!(read_msg(&mut r).is_err());
    }
}
