//! Phase-2 execution transports: how the coordinator hands the phase-1
//! weights to its W independent workers and collects the refined replicas
//! back, with a per-worker failure policy in between.
//!
//! The paper's phase-3 average is well-defined over ANY non-empty subset
//! of replicas (Algorithm 1 line 27 is a plain mean over workers), which
//! is exactly what makes SWAP elastic: a crashed, hung, or disconnected
//! worker should cost its contribution, not the run. The old coordinator
//! threw that property away — a single worker `Err` propagated out of
//! `run_swap` and destroyed every surviving worker's finished model. A
//! `Transport` instead reports a per-worker [`WorkerOutcome`] (`Done` or
//! `Dropped`), and the coordinator averages the survivors, logging each
//! drop and booking its wasted modeled time in `ClusterClock::lost`.
//!
//! Two implementations:
//! * [`MemoryTransport`] — phase-2 workers as in-process OS threads via
//!   `parallel_map`, exactly the historical execution; the zero-failure
//!   path is bitwise-identical to it (pinned by rust/tests/transport.rs).
//! * [`SocketTransport`] — workers as separate processes over TCP or a
//!   Unix socket (`swap-train serve` / `swap-train join`), speaking the
//!   length-prefix framed protocol of [`wire`]: join handshake, phase-1
//!   weight broadcast, heartbeats, worker-done weight upload.

pub mod loopback;
pub mod memory;
pub mod progress;
pub mod socket;
pub mod wire;

pub use memory::MemoryTransport;
pub use progress::{Phase1Progress, Phase1Recorder};
pub use socket::{join_phase1, join_run, JoinSummary, Phase1Outcome, SocketTransport};

use std::time::Duration;

use super::resume::RunDir;
use super::swap::SwapConfig;
use super::trainer::{
    run_sync_training_with, ProgressHook, SyncResume, SyncState, SyncTrainConfig, TrainEnv,
    TrainProgress,
};
use crate::model::{load_params, save_params, ParamSet};
use crate::runtime::{Backend, BatchStats};
use crate::sim::ClusterClock;
use crate::util::{Json, Result};

/// When to give up on a phase-2 worker instead of the whole run. All
/// timeouts govern the *executing* cluster (wall time), never the modeled
/// `ClusterClock` — a dropped worker changes which replicas are averaged,
/// not how the survivors' time is priced.
#[derive(Debug, Clone)]
pub struct FailurePolicy {
    /// fewest phase-2 survivors the phase-3 average may be taken over;
    /// below this the run errors out (1 = any non-empty subset, the
    /// paper's minimum for a well-defined average)
    pub min_workers: usize,
    /// join window: how long the coordinator waits for workers to connect
    /// after phase 1 before the missing ones are dropped
    pub connect_timeout: Duration,
    /// per-link silence (no heartbeat, progress, or upload) tolerated
    /// before a worker is declared dead
    pub io_timeout: Duration,
    /// interval at which a joined worker sends heartbeats
    pub heartbeat: Duration,
    /// straggler deadline: once the first worker uploads its replica, the
    /// rest have this much longer before they are dropped
    pub straggler_grace: Duration,
    /// client-side connect attempts before `join` gives up (the server
    /// may still be in phase 1 when a worker starts)
    pub join_retries: usize,
    /// base backoff between connect attempts: attempt k waits k+1 times
    /// this long, plus up to one extra window of deterministic per-process
    /// jitter (see [`FailurePolicy::backoff_delay`])
    pub retry_backoff: Duration,
}

impl FailurePolicy {
    /// Delay before reconnect attempt `attempt` (0-based): a bounded
    /// linear ramp plus jitter. Pure linear backoff makes workers that
    /// were restarted together reconnect in lockstep forever — each
    /// attempt hammers the coordinator's accept loop at the same instant.
    /// The jitter is FNV-1a of `(salt, attempt)` reduced into one backoff
    /// window: stateless, reproducible per process (callers pass the
    /// process id as salt), and decorrelated across processes.
    pub fn backoff_delay(&self, attempt: u32, salt: u64) -> Duration {
        let base = self.retry_backoff * (attempt + 1);
        let window = self.retry_backoff.as_nanos() as u64;
        if window == 0 {
            return base;
        }
        let mut key = [0u8; 12];
        key[..8].copy_from_slice(&salt.to_le_bytes());
        key[8..].copy_from_slice(&attempt.to_le_bytes());
        base + Duration::from_nanos(progress::fnv1a(&key) % window)
    }
}

impl Default for FailurePolicy {
    fn default() -> Self {
        FailurePolicy {
            min_workers: 1,
            connect_timeout: Duration::from_secs(60),
            io_timeout: Duration::from_secs(10),
            heartbeat: Duration::from_secs(1),
            straggler_grace: Duration::from_secs(600),
            join_retries: 60,
            retry_backoff: Duration::from_millis(500),
        }
    }
}

/// What one phase-2 worker came back with.
pub enum WorkerOutcome {
    /// The worker finished and delivered its refined replica.
    Done {
        params: ParamSet,
        clock: ClusterClock,
        /// phase-2 snapshot trail if requested (memory transport only —
        /// trails are figure instrumentation and are not shipped over
        /// the wire)
        trail: Vec<(usize, ParamSet)>,
    },
    /// The worker crashed, hung, disconnected, or never joined: it is
    /// excluded from the phase-3 average.
    Dropped { reason: String },
}

/// Everything a transport needs to run the pending phase-2 workers.
pub struct Phase2Ctx<'a> {
    pub env: &'a TrainEnv<'a>,
    pub cfg: &'a SwapConfig,
    /// the phase-1 weights every worker starts from
    pub start: &'a ParamSet,
    /// worker ids still to run, ascending (a resumed run omits the ids
    /// already finished on disk)
    pub pending: &'a [usize],
    pub policy: &'a FailurePolicy,
    /// persist each finished worker immediately (resumable runs), so a
    /// crash mid-phase-2 only loses in-flight workers
    pub run_dir: Option<&'a RunDir>,
    /// config fingerprint of this run — socket joins must present the
    /// identical string (see [`run_fingerprint`])
    pub fingerprint: String,
}

/// Wire-traffic accounting for one phase-2 round (zero for the in-memory
/// transport). Both directions are counted: the phase-1 broadcast down to
/// each worker and the finished replica uploaded back.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NetStats {
    /// total framed bytes moved (length prefixes, tags, and payloads)
    pub framed_bytes: u64,
    /// raw f32 weight payload inside those frames — comparable to
    /// `CostModel::phase2_comm_bytes`, which predicts exactly this
    pub param_bytes: u64,
}

/// Outcome of one phase-2 round over a transport.
#[derive(Default)]
pub struct Phase2Report {
    /// one entry per id in `Phase2Ctx::pending` (any order; the
    /// coordinator sorts by worker id before averaging)
    pub outcomes: Vec<(usize, WorkerOutcome)>,
    pub net: NetStats,
}

/// Everything a transport needs to run the phase-1 synchronous collective.
pub struct Phase1Ctx<'a> {
    pub env: &'a TrainEnv<'a>,
    pub cfg: &'a SwapConfig,
    /// the phase-1 sync-training recipe (ONE definition shared by every
    /// execution path — see `swap::phase1_train_config`)
    pub train: SyncTrainConfig,
    pub policy: &'a FailurePolicy,
    /// persist a crash-safe phase-1 progress record here (resumable runs)
    pub run_dir: Option<&'a RunDir>,
    /// config fingerprint of this run — phase-1 joins and the progress
    /// record must present/carry the identical string
    pub fingerprint: String,
}

/// Outcome of phase 1 over a transport. The weights/momentum/clock come
/// back through the `run_phase1` out-parameters; this carries the rest.
pub struct Phase1Report {
    pub progress: TrainProgress,
    /// phase-1 snapshot trail if requested (figure instrumentation)
    pub snapshots: Vec<(usize, ParamSet)>,
    /// wire traffic the collective moved (zero for in-process execution)
    pub net: NetStats,
}

/// How SWAP's phases are executed: in-process threads or remote processes.
/// The contract every implementation must honor: phase 1 trains with
/// `ctx.train` and worker `w` of phase 2 trains with
/// `phase2_worker_config(cfg, env, w)` from `ctx.start`, so the results
/// are pure functions of the config — transports can never change them,
/// only where they are computed (a zero-failure distributed phase 1 is
/// bitwise identical to the in-process loop).
pub trait Transport {
    fn name(&self) -> &'static str;

    /// Run the phase-1 synchronous collective, mutating the weight /
    /// momentum arenas and the modeled clock in place. The default is the
    /// historical in-process loop (with crash-safe progress recording
    /// when `ctx.run_dir` is set); `SocketTransport` overrides it to act
    /// as the hub of a multi-process collective when `cfg.phase1_dist`.
    fn run_phase1(
        &self,
        ctx: &Phase1Ctx,
        params: &mut ParamSet,
        momentum: &mut ParamSet,
        clock: &mut ClusterClock,
    ) -> Result<Phase1Report> {
        run_phase1_local(ctx, params, momentum, clock)
    }

    fn run_phase2(&self, ctx: &Phase2Ctx) -> Result<Phase2Report>;
}

/// The in-process phase 1: `run_sync_training_with` plus, when a run dir
/// is present, the crash-safe progress record — every
/// `cfg.phase1_record_every` steps the weight/momentum arenas are
/// published as part files and an fsync'd entry is appended, so a crashed
/// run re-enters the collective at the last recorded step (bitwise
/// identical to never having crashed; pinned in rust/tests/transport.rs).
pub fn run_phase1_local(
    ctx: &Phase1Ctx,
    params: &mut ParamSet,
    momentum: &mut ParamSet,
    clock: &mut ClusterClock,
) -> Result<Phase1Report> {
    let mut snapshots: Vec<(usize, ParamSet)> = Vec::new();
    let snap = ctx.cfg.phase1_snapshot_every;
    let observer = |step: usize, ps: &ParamSet, _: &BatchStats| {
        if let Some(every) = snap {
            if step % every == 0 {
                snapshots.push((step, ps.clone()));
            }
        }
    };

    let mut resume = None;
    let mut hook_state: Option<(Phase1Recorder, Option<u64>)> = None;
    if let Some(dir) = ctx.run_dir {
        let (rec, found) = open_phase1_record(ctx, dir, params, momentum, clock)?;
        hook_state = Some((rec, found.map(|r| r.start_step as u64)));
        resume = found;
    }
    let recording = hook_state.is_some();
    let record_every = ctx.cfg.phase1_record_every.max(1);
    let mut hook = |st: &SyncState| -> Result<()> {
        let Some((rec, prev)) = hook_state.as_mut() else { return Ok(()) };
        if st.step == 0 || st.step % record_every != 0 {
            return Ok(());
        }
        record_phase1_step(ctx, ctx.run_dir.unwrap(), rec, prev, st)
    };
    let progress: Option<ProgressHook> = if recording { Some(&mut hook) } else { None };

    let p = run_sync_training_with(
        ctx.env, params, momentum, &ctx.train, clock, observer, resume, progress,
    )?;
    Ok(Phase1Report { progress: p, snapshots, net: NetStats::default() })
}

/// Open (or create) the run dir's progress record and, if a recorded step
/// has both part files intact on disk (existence + arena-hash match),
/// restore the arenas/clock from it and return the matching
/// [`SyncResume`]. Recorded entries whose parts are missing or torn are
/// skipped — the scan walks backwards to the newest usable step.
pub(crate) fn open_phase1_record(
    ctx: &Phase1Ctx,
    dir: &RunDir,
    params: &mut ParamSet,
    momentum: &mut ParamSet,
    clock: &mut ClusterClock,
) -> Result<(Phase1Recorder, Option<SyncResume>)> {
    let (rec, entries) =
        Phase1Recorder::open(&dir.phase1_progress(), &ctx.fingerprint, params.numel() as u64)?;
    let manifest = ctx.env.engine.manifest();
    for e in entries.iter().rev() {
        let Ok(p) = load_params(dir.phase1_part(e.step, "ckpt"), manifest) else { continue };
        let Ok(m) = load_params(dir.phase1_part(e.step, "mom"), manifest) else { continue };
        if progress::fnv1a_f32s(p.data()) != e.params_hash
            || progress::fnv1a_f32s(m.data()) != e.momentum_hash
        {
            continue;
        }
        crate::info!("resume: phase 1 collective re-entered at step {}", e.step);
        *params = p;
        *momentum = m;
        *clock = e.clock;
        return Ok((
            rec,
            Some(SyncResume {
                start_step: e.step as usize,
                epoch_stats: e.epoch_stats,
                last_epoch_acc: e.last_epoch_acc,
                last_epoch_loss: e.last_epoch_loss,
            }),
        ));
    }
    Ok((rec, None))
}

/// One crash-safe record: publish the step's part files (atomic tmp +
/// fsync + rename), append the fsync'd entry, THEN delete the previous
/// step's parts — at every crash point at least one recorded step is
/// fully resumable.
pub(crate) fn record_phase1_step(
    ctx: &Phase1Ctx,
    dir: &RunDir,
    rec: &mut Phase1Recorder,
    prev: &mut Option<u64>,
    st: &SyncState,
) -> Result<()> {
    let step = st.step as u64;
    let manifest = ctx.env.engine.manifest();
    save_params(dir.phase1_part(step, "ckpt"), manifest, st.params)?;
    save_params(dir.phase1_part(step, "mom"), manifest, st.momentum)?;
    rec.append(&Phase1Progress {
        step,
        epoch_stats: *st.epoch_stats,
        last_epoch_acc: st.last_epoch_acc,
        last_epoch_loss: st.last_epoch_loss,
        clock: st.clock,
        params_hash: progress::fnv1a_f32s(st.params.data()),
        momentum_hash: progress::fnv1a_f32s(st.momentum.data()),
    })?;
    if let Some(p) = prev.take() {
        if p != step {
            let _ = std::fs::remove_file(dir.phase1_part(p, "ckpt"));
            let _ = std::fs::remove_file(dir.phase1_part(p, "mom"));
        }
    }
    *prev = Some(step);
    Ok(())
}

/// Everything that must agree for two processes (or two sessions of one
/// process) to contribute replicas to the same average: the model, the
/// data distribution, and the full phase recipe. Serialized as canonical
/// JSON (sorted keys) so equality is a string compare; written to
/// `run.meta.json` by resumable runs and exchanged in the socket join
/// handshake.
pub fn run_fingerprint(env: &TrainEnv, cfg: &SwapConfig) -> String {
    let m = env.engine.manifest();
    Json::obj(vec![
        ("arch", Json::str(m.model.arch.clone())),
        ("model_width", Json::Num(m.model.width as f64)),
        ("num_params", Json::Num(m.num_params as f64)),
        ("num_classes", Json::Num(env.train.num_classes as f64)),
        ("image_size", Json::Num(env.train.image_size as f64)),
        ("n_train", Json::Num(env.train.n as f64)),
        ("n_test", Json::Num(env.test.n as f64)),
        ("augment", Json::str(format!("{:?}", env.augment))),
        ("exec_batch", Json::Num(env.exec_batch as f64)),
        ("bn_batches", Json::Num(env.bn_batches as f64)),
        ("seed", Json::Num(cfg.seed as f64)),
        ("workers", Json::Num(cfg.workers as f64)),
        ("group_devices", Json::Num(cfg.group_devices as f64)),
        ("phase1_max_epochs", Json::Num(cfg.phase1_max_epochs as f64)),
        ("phase1_stop_acc", Json::Num(cfg.phase1_stop_acc)),
        ("phase1_sched", Json::str(format!("{:?}", cfg.phase1_sched))),
        ("phase2_epochs", Json::Num(cfg.phase2_epochs as f64)),
        ("phase2_sched", Json::str(format!("{:?}", cfg.phase2_sched))),
        // which averaging policy combined the replicas: resuming a run
        // directory under a different policy must hard-error, not
        // silently re-average the checkpoints another way
        ("averaging", Json::str(cfg.averaging.id())),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_schedule_ramps_with_bounded_jitter() {
        let p = FailurePolicy::default();
        let base = p.retry_backoff;
        for a in 0..6u32 {
            let d = p.backoff_delay(a, 42);
            // linear ramp floor, plus strictly less than one extra window
            assert!(d >= base * (a + 1), "attempt {a}: {d:?}");
            assert!(d < base * (a + 2), "attempt {a}: {d:?}");
        }
    }

    #[test]
    fn backoff_jitter_decorrelates_processes_deterministically() {
        let p = FailurePolicy::default();
        // two workers restarted together must not reconnect in lockstep
        let a: Vec<_> = (0..4).map(|k| p.backoff_delay(k, 1)).collect();
        let b: Vec<_> = (0..4).map(|k| p.backoff_delay(k, 2)).collect();
        assert_ne!(a, b);
        // but each process's schedule is reproducible
        let again: Vec<_> = (0..4).map(|k| p.backoff_delay(k, 1)).collect();
        assert_eq!(a, again);
    }

    #[test]
    fn backoff_zero_window_means_no_jitter() {
        let p = FailurePolicy { retry_backoff: Duration::ZERO, ..FailurePolicy::default() };
        assert_eq!(p.backoff_delay(3, 99), Duration::ZERO);
    }
}
