//! Phase-2 execution transports: how the coordinator hands the phase-1
//! weights to its W independent workers and collects the refined replicas
//! back, with a per-worker failure policy in between.
//!
//! The paper's phase-3 average is well-defined over ANY non-empty subset
//! of replicas (Algorithm 1 line 27 is a plain mean over workers), which
//! is exactly what makes SWAP elastic: a crashed, hung, or disconnected
//! worker should cost its contribution, not the run. The old coordinator
//! threw that property away — a single worker `Err` propagated out of
//! `run_swap` and destroyed every surviving worker's finished model. A
//! `Transport` instead reports a per-worker [`WorkerOutcome`] (`Done` or
//! `Dropped`), and the coordinator averages the survivors, logging each
//! drop and booking its wasted modeled time in `ClusterClock::lost`.
//!
//! Two implementations:
//! * [`MemoryTransport`] — phase-2 workers as in-process OS threads via
//!   `parallel_map`, exactly the historical execution; the zero-failure
//!   path is bitwise-identical to it (pinned by rust/tests/transport.rs).
//! * [`SocketTransport`] — workers as separate processes over TCP or a
//!   Unix socket (`swap-train serve` / `swap-train join`), speaking the
//!   length-prefix framed protocol of [`wire`]: join handshake, phase-1
//!   weight broadcast, heartbeats, worker-done weight upload.

pub mod memory;
pub mod socket;
pub mod wire;

pub use memory::MemoryTransport;
pub use socket::{join_run, JoinSummary, SocketTransport};

use std::time::Duration;

use super::resume::RunDir;
use super::swap::SwapConfig;
use super::trainer::TrainEnv;
use crate::model::ParamSet;
use crate::runtime::Backend;
use crate::sim::ClusterClock;
use crate::util::{Json, Result};

/// When to give up on a phase-2 worker instead of the whole run. All
/// timeouts govern the *executing* cluster (wall time), never the modeled
/// `ClusterClock` — a dropped worker changes which replicas are averaged,
/// not how the survivors' time is priced.
#[derive(Debug, Clone)]
pub struct FailurePolicy {
    /// fewest phase-2 survivors the phase-3 average may be taken over;
    /// below this the run errors out (1 = any non-empty subset, the
    /// paper's minimum for a well-defined average)
    pub min_workers: usize,
    /// join window: how long the coordinator waits for workers to connect
    /// after phase 1 before the missing ones are dropped
    pub connect_timeout: Duration,
    /// per-link silence (no heartbeat, progress, or upload) tolerated
    /// before a worker is declared dead
    pub io_timeout: Duration,
    /// interval at which a joined worker sends heartbeats
    pub heartbeat: Duration,
    /// straggler deadline: once the first worker uploads its replica, the
    /// rest have this much longer before they are dropped
    pub straggler_grace: Duration,
    /// client-side connect attempts before `join` gives up (the server
    /// may still be in phase 1 when a worker starts)
    pub join_retries: usize,
    /// backoff between connect attempts (linear: attempt k waits k times
    /// this long)
    pub retry_backoff: Duration,
}

impl Default for FailurePolicy {
    fn default() -> Self {
        FailurePolicy {
            min_workers: 1,
            connect_timeout: Duration::from_secs(60),
            io_timeout: Duration::from_secs(10),
            heartbeat: Duration::from_secs(1),
            straggler_grace: Duration::from_secs(600),
            join_retries: 60,
            retry_backoff: Duration::from_millis(500),
        }
    }
}

/// What one phase-2 worker came back with.
pub enum WorkerOutcome {
    /// The worker finished and delivered its refined replica.
    Done {
        params: ParamSet,
        clock: ClusterClock,
        /// phase-2 snapshot trail if requested (memory transport only —
        /// trails are figure instrumentation and are not shipped over
        /// the wire)
        trail: Vec<(usize, ParamSet)>,
    },
    /// The worker crashed, hung, disconnected, or never joined: it is
    /// excluded from the phase-3 average.
    Dropped { reason: String },
}

/// Everything a transport needs to run the pending phase-2 workers.
pub struct Phase2Ctx<'a> {
    pub env: &'a TrainEnv<'a>,
    pub cfg: &'a SwapConfig,
    /// the phase-1 weights every worker starts from
    pub start: &'a ParamSet,
    /// worker ids still to run, ascending (a resumed run omits the ids
    /// already finished on disk)
    pub pending: &'a [usize],
    pub policy: &'a FailurePolicy,
    /// persist each finished worker immediately (resumable runs), so a
    /// crash mid-phase-2 only loses in-flight workers
    pub run_dir: Option<&'a RunDir>,
    /// config fingerprint of this run — socket joins must present the
    /// identical string (see [`run_fingerprint`])
    pub fingerprint: String,
}

/// Wire-traffic accounting for one phase-2 round (zero for the in-memory
/// transport). Both directions are counted: the phase-1 broadcast down to
/// each worker and the finished replica uploaded back.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NetStats {
    /// total framed bytes moved (length prefixes, tags, and payloads)
    pub framed_bytes: u64,
    /// raw f32 weight payload inside those frames — comparable to
    /// `CostModel::phase2_comm_bytes`, which predicts exactly this
    pub param_bytes: u64,
}

/// Outcome of one phase-2 round over a transport.
#[derive(Default)]
pub struct Phase2Report {
    /// one entry per id in `Phase2Ctx::pending` (any order; the
    /// coordinator sorts by worker id before averaging)
    pub outcomes: Vec<(usize, WorkerOutcome)>,
    pub net: NetStats,
}

/// How phase 2 is executed: in-process threads or remote processes. The
/// contract every implementation must honor: worker `w` trains with
/// `phase2_worker_config(cfg, env, w)` from `ctx.start`, so its replica is
/// a pure function of `(cfg.seed, 100 + w)` — transports can never change
/// the result, only where it is computed.
pub trait Transport {
    fn name(&self) -> &'static str;
    fn run_phase2(&self, ctx: &Phase2Ctx) -> Result<Phase2Report>;
}

/// Everything that must agree for two processes (or two sessions of one
/// process) to contribute replicas to the same average: the model, the
/// data distribution, and the full phase recipe. Serialized as canonical
/// JSON (sorted keys) so equality is a string compare; written to
/// `run.meta.json` by resumable runs and exchanged in the socket join
/// handshake.
pub fn run_fingerprint(env: &TrainEnv, cfg: &SwapConfig) -> String {
    let m = env.engine.manifest();
    Json::obj(vec![
        ("arch", Json::str(m.model.arch.clone())),
        ("model_width", Json::Num(m.model.width as f64)),
        ("num_params", Json::Num(m.num_params as f64)),
        ("num_classes", Json::Num(env.train.num_classes as f64)),
        ("image_size", Json::Num(env.train.image_size as f64)),
        ("n_train", Json::Num(env.train.n as f64)),
        ("n_test", Json::Num(env.test.n as f64)),
        ("augment", Json::str(format!("{:?}", env.augment))),
        ("exec_batch", Json::Num(env.exec_batch as f64)),
        ("bn_batches", Json::Num(env.bn_batches as f64)),
        ("seed", Json::Num(cfg.seed as f64)),
        ("workers", Json::Num(cfg.workers as f64)),
        ("group_devices", Json::Num(cfg.group_devices as f64)),
        ("phase1_max_epochs", Json::Num(cfg.phase1_max_epochs as f64)),
        ("phase1_stop_acc", Json::Num(cfg.phase1_stop_acc)),
        ("phase1_sched", Json::str(format!("{:?}", cfg.phase1_sched))),
        ("phase2_epochs", Json::Num(cfg.phase2_epochs as f64)),
        ("phase2_sched", Json::str(format!("{:?}", cfg.phase2_sched))),
        // which averaging policy combined the replicas: resuming a run
        // directory under a different policy must hard-error, not
        // silently re-average the checkpoints another way
        ("averaging", Json::str(cfg.averaging.id())),
    ])
    .to_string()
}
