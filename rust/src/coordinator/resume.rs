//! Phase-level checkpointing: SWAP as a restartable pipeline.
//!
//! Production clusters preempt; a leader must be able to resume SWAP
//! without redoing phase 1 (the expensive synchronous part). This module
//! persists the phase-1 output (weights + clock + progress meta) and each
//! finished phase-2 worker, then re-enters the algorithm at the first
//! missing piece. File layout under a run directory:
//!
//! ```text
//! run/run.meta.json          config fingerprint this directory belongs to
//! run/phase1.progress        crash-safe mid-phase-1 record (transport::progress)
//! run/phase1.part-<s>.ckpt   weights at recorded sync step s (mid-phase only)
//! run/phase1.part-<s>.mom    momentum at recorded sync step s (mid-phase only)
//! run/phase1.ckpt            phase-1 weights (final)
//! run/phase1.meta.json       steps/epochs/train-acc/cluster-clock
//! run/worker<k>.ckpt         finished phase-2 replicas
//! ```
//!
//! A crash *inside* phase 1 resumes at the last recorded sync step via the
//! progress record; once `phase1.ckpt` is saved the mid-phase files are
//! cleared and a later resume skips phase 1 entirely.
//!
//! The fingerprint (see `transport::run_fingerprint`) pins the model,
//! dataset, and full phase recipe: resuming the directory with a different
//! seed / workers / group_devices / dataset hard-errors instead of
//! silently averaging incompatible weights.
//!
//! Determinism note: a resumed run reproduces the fresh run exactly —
//! worker k always uses seed stream `100 + k` regardless of which process
//! executed it (tested in rust/tests/integration_coordinator.rs).

use std::path::{Path, PathBuf};

use super::swap::{finish_swap, modeled_phase2_clock, phase1_train_config, SwapConfig, SwapResult};
use super::trainer::{TrainEnv, TrainProgress};
use super::transport::{
    self, FailurePolicy, MemoryTransport, NetStats, Phase1Ctx, Phase2Ctx, Phase2Report, Transport,
    WorkerOutcome,
};
use crate::model::{load_params, save_params, ParamSet};
use crate::runtime::Backend;
use crate::sim::ClusterClock;
use crate::util::{Error, Json, Result};

pub struct RunDir {
    dir: PathBuf,
}

impl RunDir {
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        std::fs::create_dir_all(dir.as_ref())?;
        Ok(RunDir { dir: dir.as_ref().to_path_buf() })
    }

    fn run_meta(&self) -> PathBuf {
        self.dir.join("run.meta.json")
    }

    fn phase1_ckpt(&self) -> PathBuf {
        self.dir.join("phase1.ckpt")
    }

    fn phase1_meta(&self) -> PathBuf {
        self.dir.join("phase1.meta.json")
    }

    pub(crate) fn worker_ckpt(&self, w: usize) -> PathBuf {
        self.dir.join(format!("worker{w}.ckpt"))
    }

    pub(crate) fn phase1_progress(&self) -> PathBuf {
        self.dir.join("phase1.progress")
    }

    pub(crate) fn phase1_part(&self, step: u64, kind: &str) -> PathBuf {
        self.dir.join(format!("phase1.part-{step}.{kind}"))
    }

    /// Remove the progress record and its part files: called once the
    /// final `phase1.ckpt` is saved (the record is mid-phase state, not a
    /// run artifact — leaving it behind would shadow nothing but waste a
    /// full arena on disk).
    pub(crate) fn clear_phase1_progress(&self) -> Result<()> {
        let _ = std::fs::remove_file(self.phase1_progress());
        for entry in std::fs::read_dir(&self.dir)? {
            let entry = entry?;
            if entry.file_name().to_string_lossy().starts_with("phase1.part-") {
                let _ = std::fs::remove_file(entry.path());
            }
        }
        Ok(())
    }

    pub fn has_phase1(&self) -> bool {
        self.phase1_ckpt().exists() && self.phase1_meta().exists()
    }

    pub fn finished_workers(&self, total: usize) -> Vec<usize> {
        (0..total).filter(|w| self.worker_ckpt(*w).exists()).collect()
    }

    /// Bind this directory to one config fingerprint: the first run writes
    /// `run.meta.json`, every later run must present the identical string.
    /// Without this check a directory seeded by a different
    /// seed/workers/group_devices/dataset would hand back checkpoints that
    /// average into garbage.
    pub fn check_fingerprint(&self, fingerprint: &str) -> Result<()> {
        let path = self.run_meta();
        if path.exists() {
            let meta = Json::parse(&std::fs::read_to_string(&path)?)?;
            let have = meta
                .req("fingerprint")?
                .as_str()
                .ok_or_else(|| Error::json("run meta: fingerprint must be a string"))?
                .to_string();
            if have != fingerprint {
                return Err(Error::config(format!(
                    "run dir {} belongs to a different configuration;\n  on disk:  {have}\n  this run: {fingerprint}\nuse a fresh --run-dir (or delete the old one) instead of mixing runs",
                    self.dir.display()
                )));
            }
        } else {
            let meta = Json::obj(vec![("fingerprint", Json::str(fingerprint))]);
            std::fs::write(path, meta.to_string_pretty())?;
        }
        Ok(())
    }

    /// Persist the phase-3 averaging policy's final scalar state into
    /// `run.meta.json` (merged alongside the fingerprint — scalars only,
    /// never weights). A later resume of the same directory recomputes
    /// the identical state from the checkpointed replicas; keeping it on
    /// disk makes the run's averaging decision auditable and lets tests
    /// pin the round-trip.
    pub fn save_averaging_state(&self, state: &Json) -> Result<()> {
        let path = self.run_meta();
        let mut meta = if path.exists() {
            Json::parse(&std::fs::read_to_string(&path)?)?
        } else {
            Json::obj(Vec::new())
        };
        if let Json::Obj(m) = &mut meta {
            m.insert("averaging".to_string(), state.clone());
        } else {
            return Err(Error::json("run meta: not a JSON object"));
        }
        std::fs::write(path, meta.to_string_pretty())?;
        Ok(())
    }

    /// The persisted averaging-policy state, if a finished run wrote one.
    pub fn load_averaging_state(&self) -> Result<Option<Json>> {
        let path = self.run_meta();
        if !path.exists() {
            return Ok(None);
        }
        let meta = Json::parse(&std::fs::read_to_string(&path)?)?;
        Ok(meta.get("averaging").cloned())
    }

    pub fn save_phase1(
        &self,
        env: &TrainEnv,
        params: &ParamSet,
        progress: &TrainProgress,
        clock: &ClusterClock,
    ) -> Result<()> {
        save_params(self.phase1_ckpt(), env.engine.manifest(), params)?;
        let meta = Json::obj(vec![
            ("steps", Json::Num(progress.steps as f64)),
            ("epochs", Json::Num(progress.epochs)),
            ("train_acc", Json::Num(progress.train_acc)),
            ("train_loss", Json::Num(progress.train_loss)),
            ("seconds", Json::Num(clock.seconds)),
            ("compute", Json::Num(clock.compute)),
            ("comm", Json::Num(clock.comm)),
            ("data_hidden", Json::Num(clock.data_hidden)),
            ("data_exposed", Json::Num(clock.data_exposed)),
            ("eval", Json::Num(clock.eval)),
            ("lost", Json::Num(clock.lost)),
        ]);
        std::fs::write(self.phase1_meta(), meta.to_string_pretty())?;
        Ok(())
    }

    pub fn load_phase1(&self, env: &TrainEnv) -> Result<(ParamSet, TrainProgress, ClusterClock)> {
        let params = load_params(self.phase1_ckpt(), env.engine.manifest())?;
        let meta = Json::parse(&std::fs::read_to_string(self.phase1_meta())?)?;
        let f = |k: &str| -> Result<f64> {
            meta.req(k)?
                .as_f64()
                .ok_or_else(|| Error::json(format!("phase1 meta: {k}")))
        };
        let progress = TrainProgress {
            steps: f("steps")? as usize,
            epochs: f("epochs")?,
            train_acc: f("train_acc")?,
            train_loss: f("train_loss")?,
        };
        // fields absent in checkpoints from older layouts default to 0
        let opt = |k: &str| -> f64 {
            meta.req(k).ok().and_then(|v| v.as_f64()).unwrap_or(0.0)
        };
        let clock = ClusterClock {
            seconds: f("seconds")?,
            compute: f("compute")?,
            comm: f("comm")?,
            data_hidden: opt("data_hidden"),
            data_exposed: opt("data_exposed"),
            eval: opt("eval"),
            lost: opt("lost"),
        };
        Ok((params, progress, clock))
    }
}

/// Run SWAP with on-disk phase checkpoints: skips phase 1 and any finished
/// phase-2 workers that are already present in `dir`. Produces the same
/// SwapResult a fresh `run_swap` would (modulo the snapshot trails, which
/// are not persisted).
pub fn run_swap_resumable(env: &TrainEnv, cfg: &SwapConfig, dir: &RunDir) -> Result<SwapResult> {
    run_swap_resumable_with(env, cfg, dir, &MemoryTransport::new(), &FailurePolicy::default())
}

/// [`run_swap_resumable`] with an explicit phase-2 [`Transport`] and
/// [`FailurePolicy`]. Only the *unfinished* workers go through the
/// transport — with sockets, a rejoining `swap join` process can request
/// its old worker id and adopt the slot. Workers the transport drops are
/// excluded from the average; their checkpoints simply never appear, so a
/// later resume of the same directory retries exactly those ids.
pub fn run_swap_resumable_with(
    env: &TrainEnv,
    cfg: &SwapConfig,
    dir: &RunDir,
    transport: &dyn Transport,
    policy: &FailurePolicy,
) -> Result<SwapResult> {
    if cfg.workers == 0 || cfg.group_devices == 0 {
        return Err(Error::config("swap: workers/group_devices must be > 0"));
    }
    let wall0 = std::time::Instant::now();
    let fingerprint = transport::run_fingerprint(env, cfg);
    dir.check_fingerprint(&fingerprint)?;

    // ---- phase 1 (or resume) -------------------------------------------
    // A fresh-or-interrupted phase 1 goes through the transport with the
    // run dir attached: the collective records crash-safe progress as it
    // goes (and re-enters at the last recorded step if this process is
    // itself a restart). Once the final checkpoint lands the mid-phase
    // record is cleared.
    let mut p1_net = NetStats::default();
    let (params, p1, clock) = if dir.has_phase1() {
        crate::info!("resume: phase 1 loaded from {}", dir.dir.display());
        dir.load_phase1(env)?
    } else {
        let mut params = ParamSet::init(env.engine.manifest(), cfg.seed);
        let mut momentum = params.zeros_like();
        let mut clock = ClusterClock::new();
        let report = transport.run_phase1(
            &Phase1Ctx {
                env,
                cfg,
                train: phase1_train_config(cfg, env),
                policy,
                run_dir: Some(dir),
                fingerprint: fingerprint.clone(),
            },
            &mut params,
            &mut momentum,
            &mut clock,
        )?;
        dir.save_phase1(env, &params, &report.progress, &clock)?;
        dir.clear_phase1_progress()?;
        p1_net = report.net;
        (params, report.progress, clock)
    };
    let phase1_seconds = clock.seconds;
    let phase1_params = params.clone();

    // ---- phase 2: load finished workers, run the rest -------------------
    // Every worker's modeled duration counts even when its work is loaded
    // from disk — the virtual cluster ran it either way. Worker k's result
    // is a pure function of (seed, 100 + k), so a resumed, fresh,
    // sequential, parallel, or remote run all agree bitwise.
    let finished = dir.finished_workers(cfg.workers);
    let pending: Vec<usize> =
        (0..cfg.workers).filter(|w| !finished.contains(w)).collect();
    let mut outcomes: Vec<(usize, WorkerOutcome)> = Vec::with_capacity(cfg.workers);
    for &w in &finished {
        crate::info!("resume: worker {w} loaded");
        let wp = load_params(dir.worker_ckpt(w), env.engine.manifest())?;
        outcomes.push((
            w,
            WorkerOutcome::Done {
                params: wp,
                clock: modeled_phase2_clock(env, cfg),
                trail: Vec::new(),
            },
        ));
    }
    let mut net = p1_net;
    if !pending.is_empty() {
        let report = transport.run_phase2(&Phase2Ctx {
            env,
            cfg,
            start: &params,
            pending: &pending,
            policy,
            run_dir: Some(dir),
            fingerprint,
        })?;
        outcomes.extend(report.outcomes);
        net.framed_bytes += report.net.framed_bytes;
        net.param_bytes += report.net.param_bytes;
    }

    // ---- phases 2½ + 3 (same tail as run_swap_with) ---------------------
    let result = finish_swap(
        env,
        cfg,
        policy,
        transport.name(),
        Phase2Report { outcomes, net },
        p1,
        phase1_seconds,
        phase1_params,
        Vec::new(),
        clock,
        wall0,
    )?;
    dir.save_averaging_state(&result.averaging_state)?;
    Ok(result)
}
