//! Phase-level checkpointing: SWAP as a restartable pipeline.
//!
//! Production clusters preempt; a leader must be able to resume SWAP
//! without redoing phase 1 (the expensive synchronous part). This module
//! persists the phase-1 output (weights + clock + progress meta) and each
//! finished phase-2 worker, then re-enters the algorithm at the first
//! missing piece. File layout under a run directory:
//!
//! ```text
//! run/phase1.ckpt          phase-1 weights
//! run/phase1.meta.json     steps/epochs/train-acc/cluster-clock
//! run/worker<k>.ckpt       finished phase-2 replicas
//! ```
//!
//! Determinism note: a resumed run reproduces the fresh run exactly —
//! worker k always uses seed stream `100 + k` regardless of which process
//! executed it (tested in rust/tests/integration_coordinator.rs).

use std::path::{Path, PathBuf};

use super::swap::{SwapConfig, SwapResult};
use super::trainer::{run_sync_training, SyncTrainConfig, TrainEnv, TrainProgress};
use crate::model::{load_params, save_params, ParamSet};
use crate::runtime::Backend;
use crate::sim::ClusterClock;
use crate::util::{Error, Json, Result};

pub struct RunDir {
    dir: PathBuf,
}

impl RunDir {
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        std::fs::create_dir_all(dir.as_ref())?;
        Ok(RunDir { dir: dir.as_ref().to_path_buf() })
    }

    fn phase1_ckpt(&self) -> PathBuf {
        self.dir.join("phase1.ckpt")
    }

    fn phase1_meta(&self) -> PathBuf {
        self.dir.join("phase1.meta.json")
    }

    fn worker_ckpt(&self, w: usize) -> PathBuf {
        self.dir.join(format!("worker{w}.ckpt"))
    }

    pub fn has_phase1(&self) -> bool {
        self.phase1_ckpt().exists() && self.phase1_meta().exists()
    }

    pub fn finished_workers(&self, total: usize) -> Vec<usize> {
        (0..total).filter(|w| self.worker_ckpt(*w).exists()).collect()
    }

    pub fn save_phase1(
        &self,
        env: &TrainEnv,
        params: &ParamSet,
        progress: &TrainProgress,
        clock: &ClusterClock,
    ) -> Result<()> {
        save_params(self.phase1_ckpt(), env.engine.manifest(), params)?;
        let meta = Json::obj(vec![
            ("steps", Json::Num(progress.steps as f64)),
            ("epochs", Json::Num(progress.epochs)),
            ("train_acc", Json::Num(progress.train_acc)),
            ("train_loss", Json::Num(progress.train_loss)),
            ("seconds", Json::Num(clock.seconds)),
            ("compute", Json::Num(clock.compute)),
            ("comm", Json::Num(clock.comm)),
            ("data_hidden", Json::Num(clock.data_hidden)),
            ("data_exposed", Json::Num(clock.data_exposed)),
        ]);
        std::fs::write(self.phase1_meta(), meta.to_string_pretty())?;
        Ok(())
    }

    pub fn load_phase1(&self, env: &TrainEnv) -> Result<(ParamSet, TrainProgress, ClusterClock)> {
        let params = load_params(self.phase1_ckpt(), env.engine.manifest())?;
        let meta = Json::parse(&std::fs::read_to_string(self.phase1_meta())?)?;
        let f = |k: &str| -> Result<f64> {
            meta.req(k)?
                .as_f64()
                .ok_or_else(|| Error::json(format!("phase1 meta: {k}")))
        };
        let progress = TrainProgress {
            steps: f("steps")? as usize,
            epochs: f("epochs")?,
            train_acc: f("train_acc")?,
            train_loss: f("train_loss")?,
        };
        // data fields are absent in pre-pipeline checkpoints: default 0
        let opt = |k: &str| -> f64 {
            meta.req(k).ok().and_then(|v| v.as_f64()).unwrap_or(0.0)
        };
        let clock = ClusterClock {
            seconds: f("seconds")?,
            compute: f("compute")?,
            comm: f("comm")?,
            data_hidden: opt("data_hidden"),
            data_exposed: opt("data_exposed"),
            eval: 0.0,
        };
        Ok((params, progress, clock))
    }
}

/// Run SWAP with on-disk phase checkpoints: skips phase 1 and any finished
/// phase-2 workers that are already present in `dir`. Produces the same
/// SwapResult a fresh `run_swap` would (modulo the snapshot trails, which
/// are not persisted).
pub fn run_swap_resumable(env: &TrainEnv, cfg: &SwapConfig, dir: &RunDir) -> Result<SwapResult> {
    let wall0 = std::time::Instant::now();
    let devices = cfg.total_devices();

    // ---- phase 1 (or resume) -------------------------------------------
    let (params, p1, mut clock) = if dir.has_phase1() {
        crate::info!("resume: phase 1 loaded from {}", dir.dir.display());
        dir.load_phase1(env)?
    } else {
        let mut params = ParamSet::init(env.engine.manifest(), cfg.seed);
        let mut momentum = params.zeros_like();
        let mut clock = ClusterClock::new();
        let p1 = run_sync_training(
            env,
            &mut params,
            &mut momentum,
            &SyncTrainConfig {
                devices,
                global_batch: devices * env.exec_batch,
                max_epochs: cfg.phase1_max_epochs,
                stop_train_acc: cfg.phase1_stop_acc,
                sched: cfg.phase1_sched.clone(),
                sched_offset: 0,
                seed_stream: 0,
                seed: cfg.seed,
            },
            &mut clock,
            |_, _, _| {},
        )?;
        dir.save_phase1(env, &params, &p1, &clock)?;
        (params, p1, clock)
    };
    let phase1_seconds = clock.seconds;
    let phase1_params = params.clone();

    // ---- phase 2 (skip finished workers) --------------------------------
    // Unfinished workers train CONCURRENTLY on `env.threads` OS threads
    // (checkpoint files are per-worker, so the saves are disjoint); worker
    // k's result is a pure function of (seed, 100 + k) either way, so a
    // resumed, fresh, sequential or parallel run all agree bitwise.
    let worker_runs = super::parallel::parallel_map(
        env.threads,
        (0..cfg.workers).collect::<Vec<_>>(),
        |_, w| -> crate::util::Result<(ParamSet, ClusterClock)> {
            let ckpt = dir.worker_ckpt(w);
            // every worker's modeled duration counts even when its work is
            // loaded from disk — the virtual cluster ran it either way
            let steps = cfg.phase2_epochs * (env.train.n / (cfg.group_devices * env.exec_batch));
            let mut wclock = ClusterClock::new();
            if ckpt.exists() {
                crate::info!("resume: worker {w} loaded");
                let wp = load_params(&ckpt, env.engine.manifest())?;
                wclock.advance_compute(steps as f64 * env.cost.train_step_time(env.exec_batch));
                if cfg.group_devices > 1 {
                    for _ in 0..steps {
                        wclock.advance_comm(env.cost.allreduce_time(cfg.group_devices));
                    }
                }
                // the original run priced its input pipeline every step;
                // the same booking (hidden vs exposed per env.prefetch)
                // must reappear on resume
                let step_budget = env.cost.train_step_time(env.exec_batch)
                    + if cfg.group_devices > 1 {
                        env.cost.allreduce_time(cfg.group_devices)
                    } else {
                        0.0
                    };
                let data_time = env.cost.assembly_time(cfg.group_devices * env.exec_batch);
                for _ in 0..steps {
                    wclock.note_data(data_time, step_budget, env.prefetch);
                }
                Ok((wp, wclock))
            } else {
                let mut wp = params.clone();
                let mut wm = wp.zeros_like();
                run_sync_training(
                    env,
                    &mut wp,
                    &mut wm,
                    &super::swap::phase2_worker_config(cfg, env, w),
                    &mut wclock,
                    |_, _, _| {},
                )?;
                save_params(&ckpt, env.engine.manifest(), &wp)?;
                Ok((wp, wclock))
            }
        },
    );
    let mut worker_params = Vec::with_capacity(cfg.workers);
    let mut group_clocks = Vec::with_capacity(cfg.workers);
    for run in worker_runs {
        let (wp, wclock) = run?;
        worker_params.push(wp);
        group_clocks.push(wclock);
    }
    clock.advance_parallel(&group_clocks);
    let phase2_seconds = clock.seconds;

    // ---- phase 3 (same as run_swap) --------------------------------------
    let mut worker_stats = Vec::with_capacity(cfg.workers);
    for wp in &worker_params {
        worker_stats.push(env.bn_and_eval(wp, cfg.seed, &mut clock)?);
    }
    let final_params = ParamSet::average_mt(&worker_params, env.threads)?;
    let final_bn = env.recompute_bn(&final_params, cfg.seed, &mut clock, true)?;
    let final_stats = env.evaluate(&final_params, &final_bn, &mut clock)?;

    Ok(SwapResult {
        phase1: p1,
        phase1_seconds,
        phase2_seconds,
        worker_params,
        worker_stats,
        final_params,
        final_bn,
        final_stats,
        clock,
        wall_seconds: wall0.elapsed().as_secs_f64(),
        snapshots: Vec::new(),
        phase1_params,
        phase1_snapshots: Vec::new(),
    })
}
