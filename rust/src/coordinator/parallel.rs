//! Real thread-parallel execution, built on `std::thread::scope` — zero
//! new dependencies. The paper's phase-2 workers "refine … independently
//! and in parallel"; this module is what makes the executed system match
//! the modeled one (the `ClusterClock` merely prices that parallelism).
//!
//! Determinism contract: every helper partitions work so each output
//! element is computed by exactly one thread with the same floating-point
//! operation order as the sequential path. Results are therefore bitwise
//! identical for every `threads` value, and `threads <= 1` short-circuits
//! to a plain loop on the calling thread (no thread is ever spawned).
//!
//! Used by the SWAP coordinator (phase-2 workers, phase-1 device shards,
//! local-SGD devices) and by the native backend's im2col/matmul/BN kernels
//! (`runtime::native::kernels`).

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

thread_local! {
    /// True on threads spawned by `parallel_map` — the signal that a
    /// coarser fan-out already owns the core budget, so the fine-grained
    /// kernel helpers below stay sequential instead of oversubscribing
    /// (workers x shards x kernels would otherwise multiply).
    static IN_PARALLEL_REGION: Cell<bool> = const { Cell::new(false) };
}

/// Whether the current thread is itself a `parallel_map` worker.
pub fn in_parallel_region() -> bool {
    IN_PARALLEL_REGION.with(|c| c.get())
}

/// Minimum useful per-item work (very roughly, scalar FLOPs) before a
/// per-step `parallel_map` fan-out beats its thread spawn/join cost —
/// callers with a work estimate (e.g. per-shard gradient FLOPs) drop to
/// `threads = 1` below it. Purely a wall-time knob: results never depend
/// on it.
pub const MIN_ITEM_WORK: usize = 1 << 20;

/// Hard cap on threads spawned by one helper call, whatever the `threads`
/// knob says — `--threads 100000` (a typo for 10) must degrade to a slow
/// run, not abort the process once the OS thread limit is hit. Results
/// are identical either way.
pub const MAX_SPAWN: usize = 256;

/// The spawn gate: use `threads` workers only when one item is worth more
/// than a thread spawn, else stay sequential. One source of truth for the
/// coordinator's per-step fan-outs (trainer shards, local-SGD devices).
pub fn gate(threads: usize, per_item_work: usize) -> usize {
    if per_item_work >= MIN_ITEM_WORK {
        threads
    } else {
        1
    }
}

/// Per-chunk spawn gate: the worker count such that every spawned thread
/// gets at least `min_work` of `total_work`. The old all-or-nothing gate
/// spawned the full `threads` once *total* work crossed the threshold, so
/// a tiny-N kernel (the 8c -> classes head matmul) could fan out into
/// threads that each did sub-threshold work and lost the spawn cost.
/// Purely a wall-time knob: results never depend on the worker count.
pub fn gate_per_chunk(threads: usize, total_work: usize, min_work: usize) -> usize {
    if threads <= 1 {
        return 1;
    }
    (total_work / min_work.max(1)).clamp(1, threads)
}

/// Default worker-thread count: the `SWAP_THREADS` environment variable if
/// set (CI's parallel lane), else `std::thread::available_parallelism()`.
pub fn default_threads() -> usize {
    if let Ok(s) = std::env::var("SWAP_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every item on up to `threads` OS threads; results come
/// back in item order. Items are claimed from a shared queue, so uneven
/// per-item cost load-balances. With `threads <= 1` (or a single item)
/// this is a sequential loop on the calling thread — the two paths are
/// observationally identical because `f(i, item)` owns all per-item state.
///
/// A panic inside `f` is caught on the worker, siblings finish their
/// current item and stop claiming new ones, and the FIRST panic is then
/// re-raised on the caller — no worker ever dies holding a queue/slot
/// mutex, so siblings never see a spurious `PoisonError` in place of the
/// real panic message.
pub fn parallel_map<I, T, F>(threads: usize, items: Vec<I>, f: F) -> Vec<T>
where
    I: Send,
    T: Send,
    F: Fn(usize, I) -> T + Sync,
{
    let n = items.len();
    // nested fan-outs (a phase-2 worker's own shard map) stay sequential:
    // the outer map already owns the cores, and one flat level of real
    // threads is both faster and easier to reason about
    if threads <= 1 || n <= 1 || in_parallel_region() {
        return items.into_iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let queue: Vec<Mutex<Option<I>>> =
        items.into_iter().map(|it| Mutex::new(Some(it))).collect();
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    let first_panic: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    let workers = threads.min(n).min(MAX_SPAWN);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                IN_PARALLEL_REGION.with(|c| c.set(true));
                loop {
                    if abort.load(Ordering::Relaxed) {
                        break;
                    }
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let item = queue[i].lock().unwrap().take().expect("item claimed once");
                    match catch_unwind(AssertUnwindSafe(|| f(i, item))) {
                        Ok(out) => *slots[i].lock().unwrap() = Some(out),
                        Err(payload) => {
                            abort.store(true, Ordering::Relaxed);
                            let mut slot = first_panic.lock().unwrap();
                            if slot.is_none() {
                                *slot = Some(payload);
                            }
                        }
                    }
                }
            });
        }
    });
    if let Some(payload) = first_panic.into_inner().unwrap() {
        resume_unwind(payload);
    }
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("every slot filled"))
        .collect()
}

/// Split `out` — a row-major buffer of `row_len`-element rows — into up to
/// `threads` contiguous row chunks and run `f(first_row, chunk)` on each
/// concurrently. `f` must compute every row independently of the chunking
/// (each row's value depends only on its own index), which makes the
/// result bitwise identical for every `threads`; with one worker `f` sees
/// the whole buffer, i.e. exactly the sequential loop.
pub fn parallel_row_chunks<T: Send>(
    threads: usize,
    out: &mut [T],
    row_len: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    if out.is_empty() || row_len == 0 {
        return;
    }
    assert_eq!(out.len() % row_len, 0, "buffer not a whole number of rows");
    let rows = out.len() / row_len;
    // a coarser fan-out (phase-2 workers, phase-1 shards) already owns the
    // cores: stay sequential rather than oversubscribe threads^2
    let workers = if in_parallel_region() {
        1
    } else {
        threads.min(rows).min(MAX_SPAWN).max(1)
    };
    if workers <= 1 {
        f(0, out);
        return;
    }
    let per = (rows + workers - 1) / workers;
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(per * row_len).enumerate() {
            let fr = &f;
            s.spawn(move || fr(ci * per, chunk));
        }
    });
}

/// [`parallel_row_chunks`] with a per-worker scratch: chunk `ci` runs with
/// exclusive access to `scratch[ci]` (the blocked-GEMM packing buffers).
/// Chunk row counts are rounded up to `granule` rows so tile-shaped work
/// splits on tile boundaries. The scratch slice bounds the worker count
/// (`workers <= scratch.len()`), and — as everywhere in this module — `f`
/// must compute each row independently of the chunking, which keeps the
/// result bitwise identical for every `threads` value.
pub fn parallel_row_chunks_scratch<T: Send, S: Send>(
    threads: usize,
    out: &mut [T],
    row_len: usize,
    granule: usize,
    scratch: &mut [S],
    f: impl Fn(usize, &mut [T], &mut S) + Sync,
) {
    if out.is_empty() || row_len == 0 {
        return;
    }
    assert_eq!(out.len() % row_len, 0, "buffer not a whole number of rows");
    assert!(!scratch.is_empty(), "need at least one scratch slot");
    let rows = out.len() / row_len;
    let g = granule.max(1);
    let max_chunks = (rows + g - 1) / g;
    let workers = if in_parallel_region() {
        1
    } else {
        threads
            .min(max_chunks)
            .min(scratch.len())
            .min(MAX_SPAWN)
            .max(1)
    };
    if workers <= 1 {
        f(0, out, &mut scratch[0]);
        return;
    }
    let per = ((rows + workers - 1) / workers + g - 1) / g * g;
    std::thread::scope(|s| {
        for (ci, (chunk, sc)) in out
            .chunks_mut(per * row_len)
            .zip(scratch.iter_mut())
            .enumerate()
        {
            let fr = &f;
            s.spawn(move || {
                IN_PARALLEL_REGION.with(|c| c.set(true));
                fr(ci * per, chunk, sc)
            });
        }
    });
}

/// Two-buffer variant of [`parallel_row_chunks`]: `a` and `b` share the
/// same row count and are chunked at the same row boundaries (e.g. BN's
/// `xhat` and `y`, written in one fused loop).
pub fn parallel_row_chunks2<T: Send, U: Send>(
    threads: usize,
    a: &mut [T],
    b: &mut [U],
    row_len_a: usize,
    row_len_b: usize,
    f: impl Fn(usize, &mut [T], &mut [U]) + Sync,
) {
    if a.is_empty() || row_len_a == 0 || row_len_b == 0 {
        return;
    }
    assert_eq!(a.len() % row_len_a, 0, "buffer a not a whole number of rows");
    assert_eq!(b.len() % row_len_b, 0, "buffer b not a whole number of rows");
    assert_eq!(
        a.len() / row_len_a,
        b.len() / row_len_b,
        "buffers disagree on row count"
    );
    let rows = a.len() / row_len_a;
    let workers = if in_parallel_region() {
        1
    } else {
        threads.min(rows).min(MAX_SPAWN).max(1)
    };
    if workers <= 1 {
        f(0, a, b);
        return;
    }
    let per = (rows + workers - 1) / workers;
    std::thread::scope(|s| {
        for (ci, (ca, cb)) in a
            .chunks_mut(per * row_len_a)
            .zip(b.chunks_mut(per * row_len_b))
            .enumerate()
        {
            let fr = &f;
            s.spawn(move || fr(ci * per, ca, cb));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order_and_runs_all() {
        for threads in [1, 2, 4, 9] {
            let items: Vec<usize> = (0..23).collect();
            let out = parallel_map(threads, items, |i, x| {
                assert_eq!(i, x);
                x * 10
            });
            assert_eq!(out, (0..23).map(|x| x * 10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn map_moves_items_in() {
        // non-Copy items are owned by the closure invocation
        let items: Vec<Vec<u8>> = (0..5).map(|i| vec![i as u8; 3]).collect();
        let out = parallel_map(3, items, |_, v| v.len());
        assert_eq!(out, vec![3; 5]);
    }

    #[test]
    fn map_empty_is_empty() {
        let out: Vec<usize> = parallel_map(4, Vec::<usize>::new(), |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn row_chunks_cover_disjointly() {
        for threads in [1, 2, 3, 8, 100] {
            let mut buf = vec![0u32; 7 * 4]; // 7 rows of 4
            parallel_row_chunks(threads, &mut buf, 4, |first_row, chunk| {
                for (li, row) in chunk.chunks_mut(4).enumerate() {
                    for v in row.iter_mut() {
                        *v = (first_row + li) as u32 + 1;
                    }
                }
            });
            let want: Vec<u32> = (0..7).flat_map(|r| [r + 1; 4]).collect();
            assert_eq!(buf, want, "threads={threads}");
        }
    }

    #[test]
    fn row_chunks2_share_boundaries() {
        for threads in [1, 4] {
            let mut a = vec![0u32; 5 * 2];
            let mut b = vec![0u32; 5 * 3];
            parallel_row_chunks2(threads, &mut a, &mut b, 2, 3, |r0, ca, cb| {
                assert_eq!(ca.len() / 2, cb.len() / 3);
                for (li, row) in ca.chunks_mut(2).enumerate() {
                    row.fill((r0 + li) as u32);
                }
                for (li, row) in cb.chunks_mut(3).enumerate() {
                    row.fill((r0 + li) as u32);
                }
            });
            for r in 0..5 {
                assert!(a[r * 2..(r + 1) * 2].iter().all(|&v| v == r as u32));
                assert!(b[r * 3..(r + 1) * 3].iter().all(|&v| v == r as u32));
            }
        }
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn panicking_item_propagates_original_panic() {
        // the original panic message must reach the caller (not a
        // PoisonError from a sibling tripping over a poisoned mutex)
        for threads in [1, 4] {
            let caught = std::panic::catch_unwind(|| {
                parallel_map(threads, (0..16).collect::<Vec<usize>>(), |_, x| {
                    if x == 3 {
                        panic!("worker 3 exploded");
                    }
                    x * 2
                })
            });
            let payload = caught.expect_err("the panic must propagate");
            let msg = payload
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("");
            assert!(msg.contains("worker 3 exploded"), "threads={threads}: got {msg:?}");
        }
    }

    #[test]
    fn per_chunk_gate_scales_with_work() {
        // below one chunk of work: sequential
        assert_eq!(gate_per_chunk(8, 100, 1000), 1);
        // enough for exactly three chunks
        assert_eq!(gate_per_chunk(8, 3000, 1000), 3);
        // work for more chunks than threads: capped
        assert_eq!(gate_per_chunk(4, 100_000, 1000), 4);
        assert_eq!(gate_per_chunk(1, 100_000, 1000), 1);
    }

    #[test]
    fn row_chunks_scratch_granule_and_exclusive_scratch() {
        for threads in [1, 2, 3, 8] {
            let mut buf = vec![0u32; 21 * 2]; // 21 rows of 2
            let mut scratch = vec![0usize; 8];
            parallel_row_chunks_scratch(threads, &mut buf, 2, 4, &mut scratch, |r0, chunk, s| {
                // granule 4: every chunk starts on a multiple of 4 rows
                assert_eq!(r0 % 4, 0);
                for (li, row) in chunk.chunks_mut(2).enumerate() {
                    row.fill((r0 + li) as u32 + 1);
                }
                *s += chunk.len() / 2;
            });
            let want: Vec<u32> = (0..21).flat_map(|r| [r + 1; 2]).collect();
            assert_eq!(buf, want, "threads={threads}");
            let covered: usize = scratch.iter().sum();
            assert_eq!(covered, 21, "threads={threads}");
        }
    }

    #[test]
    fn nested_fanout_degrades_to_sequential() {
        // inside a parallel_map worker the region flag is set, so nested
        // maps and row chunks run inline (no threads^2 oversubscription) —
        // and produce identical results either way
        assert!(!in_parallel_region());
        let out = parallel_map(4, vec![0usize, 1], |_, x| {
            assert!(in_parallel_region());
            let inner = parallel_map(4, vec![10usize, 20, 30], |_, y| y + x);
            let mut buf = vec![0u32; 8];
            parallel_row_chunks(4, &mut buf, 2, |r0, chunk| {
                for (li, row) in chunk.chunks_mut(2).enumerate() {
                    row.fill((r0 + li) as u32);
                }
            });
            (inner, buf)
        });
        assert_eq!(out[0].0, vec![10, 20, 30]);
        assert_eq!(out[1].0, vec![11, 21, 31]);
        assert_eq!(out[0].1, vec![0, 0, 1, 1, 2, 2, 3, 3]);
        // the flag is confined to worker threads, not the caller
        assert!(!in_parallel_region());
    }
}
