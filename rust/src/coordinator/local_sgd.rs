//! Post-local SGD (Lin et al. 2018) — the closest related method (§2) and
//! a future-work integration target (§6). Implemented as an extension so
//! the ablation benches can compare model-averaging *frequencies*: SWAP
//! averages once after many epochs; post-local SGD averages every H steps.
//!
//! Algorithm: synchronous large-batch SGD for `sync_epochs`, then the
//! devices switch to *local* updates (fused small-batch steps) and average
//! their PARAMETERS every `h_steps` steps.

use super::averaging::{self, AveragingSpec};
use super::parallel;
use super::trainer::{run_sync_training, SyncTrainConfig, TrainEnv};
use crate::data::{prefetch, AugStream, Batcher, EpochSampler};
use crate::metrics::RunOutcome;
use crate::model::ParamSet;
use crate::optim::Schedule;
use crate::runtime::{Backend, HostBatch};
use crate::sim::ClusterClock;
use crate::util::{Error, Result};

#[derive(Debug, Clone)]
pub struct LocalSgdConfig {
    pub devices: usize,
    /// synchronous large-batch epochs before going local
    pub sync_epochs: usize,
    pub sync_sched: Schedule,
    /// local epochs after the switch
    pub local_epochs: usize,
    pub local_sched: Schedule,
    /// parameter-averaging period in local steps (H)
    pub h_steps: usize,
    pub seed: u64,
    /// how the replicas reach consensus at every sync event and at the
    /// end (default Uniform — bitwise the historical mean; the
    /// validation-gated adaptive policy is rejected here)
    pub averaging: AveragingSpec,
}

pub struct LocalSgdResult {
    pub outcome: RunOutcome,
    pub params: ParamSet,
    /// number of parameter-averaging synchronizations in the local phase
    pub sync_events: usize,
}

pub fn run_local_sgd(env: &TrainEnv, cfg: &LocalSgdConfig) -> Result<LocalSgdResult> {
    if cfg.h_steps == 0 {
        return Err(Error::config("local sgd: h_steps must be > 0"));
    }
    let wall0 = std::time::Instant::now();
    let mut clock = ClusterClock::new();

    // Phase A: synchronous large batch (same machinery as SWAP phase 1).
    let mut params = ParamSet::init(env.engine.manifest(), cfg.seed);
    let mut momentum = params.zeros_like();
    run_sync_training(
        env,
        &mut params,
        &mut momentum,
        &SyncTrainConfig {
            devices: cfg.devices,
            global_batch: cfg.devices * env.exec_batch,
            max_epochs: cfg.sync_epochs,
            stop_train_acc: 1.1,
            sched: cfg.sync_sched.clone(),
            sched_offset: 0,
            seed_stream: 0,
            seed: cfg.seed,
        },
        &mut clock,
        |_, _, _| {},
    )?;

    // Phase B: local SGD with periodic parameter averaging.
    let b = env.exec_batch;
    let devices = cfg.devices;
    let mut worker_params: Vec<ParamSet> = (0..devices).map(|_| params.clone()).collect();
    let mut worker_mom: Vec<ParamSet> = worker_params.iter().map(|p| p.zeros_like()).collect();
    let mut samplers: Vec<EpochSampler> = (0..devices)
        .map(|w| EpochSampler::new(env.train.n, b, cfg.seed, 500 + w as u64))
        .collect();
    let mut batcher = Batcher::new(b, env.image_size(), env.augment);
    // counter-keyed augmentation: device w owns global rows [w*b, (w+1)*b)
    // of each local step, so assembly is order-free (see data::augment)
    let aug = AugStream { seed: cfg.seed ^ 0x10CA1, stream: 0 };
    let train = env.train;

    let steps_per_epoch = EpochSampler::steps_per_epoch(env.train.n, b);
    let total_local_steps = cfg.local_epochs * steps_per_epoch;
    let step_time = env.cost.train_step_time(b);
    let data_time = env.cost.assembly_time(devices * b);
    let mut sync_events = 0usize;
    // per-step fan-out only when one local step outweighs a thread spawn
    let step_work = 3 * env.engine.manifest().flops_fwd_per_example as usize * b;
    let step_threads = parallel::gate(env.threads, step_work);

    // the input pipeline: reused per-device HostBatches, double-buffered
    // when the prefetch producer may overlap with the device steps
    let overlap = env.spawn_prefetch();
    let slots: Vec<Vec<HostBatch>> =
        prefetch::make_slots(overlap, || (0..devices).map(|_| batcher.make_batch()).collect());

    let produce = move |step: usize, out: &mut Vec<HostBatch>| {
        for (w, hb) in out.iter_mut().enumerate() {
            let idx = samplers[w].next_batch();
            batcher.assemble_step_into(train, idx, aug, step as u64, (w * b) as u64, hb);
        }
    };

    let consume = |step: usize, batches: &mut Vec<HostBatch>| -> Result<bool> {
        // the devices really do step in parallel, each owning its replica
        // + momentum (disjoint &mut borrows) and reading its own batch
        let lr = cfg.local_sched.lr(step);
        let items: Vec<_> = worker_params
            .iter_mut()
            .zip(worker_mom.iter_mut())
            .zip(batches.iter())
            .map(|((wp, wm), hb)| (wp, wm, hb))
            .collect();
        let results = parallel::parallel_map(step_threads, items, |_, (wp, wm, hb)| {
            env.engine.train_step(wp.as_mut_slice(), wm.as_mut_slice(), hb, lr)
        });
        for r in results {
            r?;
        }
        // local steps run in parallel on the modeled cluster; assembly of
        // the next step hides behind them when the pipeline overlaps
        clock.advance_compute(step_time);
        clock.note_data(data_time, step_time, env.prefetch);
        if (step + 1) % cfg.h_steps == 0 {
            let avg = averaging::consensus(&cfg.averaging, &worker_params, env.threads)?;
            for wp in &mut worker_params {
                *wp = avg.clone();
            }
            clock.advance_comm(env.cost.allreduce_time(cfg.devices));
            sync_events += 1;
        }
        Ok(true)
    };

    prefetch::run_pipeline(total_local_steps, slots, overlap, produce, consume)?;

    // final consensus model
    params = averaging::consensus(&cfg.averaging, &worker_params, env.threads)?;
    if total_local_steps % cfg.h_steps != 0 {
        clock.advance_comm(env.cost.allreduce_time(cfg.devices));
        sync_events += 1;
    }
    let stats = env.bn_and_eval(&params, cfg.seed, &mut clock)?;
    Ok(LocalSgdResult {
        outcome: RunOutcome {
            test_acc1: stats.accuracy1(),
            test_acc5: stats.accuracy5(),
            test_loss: stats.mean_loss(),
            cluster_seconds: clock.seconds,
            wall_seconds: wall0.elapsed().as_secs_f64(),
        },
        params,
        sync_events,
    })
}
