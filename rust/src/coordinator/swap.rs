//! SWAP — Algorithm 1 of the paper, composed from the shared trainer.
//!
//! Phase 1: every device trains one shared model with the large global
//!          batch (synchronous gradient all-reduce each step, high LR),
//!          stopping at training accuracy τ *before* the loss reaches zero.
//! Phase 2: the devices split into `workers` independent groups; each group
//!          refines its own replica with the small batch, lower LR, and a
//!          different data randomization. No cross-group synchronization.
//! Phase 3: the divergent replicas are weight-averaged and the batch-norm
//!          statistics are recomputed over the training data.

use super::averaging::{maybe_val_acc, AveragingSpec, Candidate, CandidateKind};
use super::trainer::{SyncTrainConfig, TrainEnv, TrainProgress};
use super::transport::{
    self, FailurePolicy, MemoryTransport, NetStats, Phase1Ctx, Phase2Ctx, Phase2Report, Transport,
    WorkerOutcome,
};
use crate::data::EpochSampler;
use crate::model::{BnState, ParamSet};
use crate::optim::Schedule;
use crate::runtime::{Backend, BatchStats};
use crate::sim::ClusterClock;
use crate::util::{Error, Json, Result};

/// Full SWAP configuration (one experiment arm).
#[derive(Debug, Clone)]
pub struct SwapConfig {
    /// number of independent phase-2 workers (groups) W
    pub workers: usize,
    /// devices per group (1 for CIFAR presets; 2+ models the ImageNet
    /// setting where each phase-2 worker is itself data-parallel)
    pub group_devices: usize,
    /// phase-1 length cap and early-stop threshold τ
    pub phase1_max_epochs: usize,
    pub phase1_stop_acc: f64,
    pub phase1_sched: Schedule,
    /// phase-2 epochs per worker and schedule
    pub phase2_epochs: usize,
    pub phase2_sched: Schedule,
    pub seed: u64,
    /// how the surviving phase-2 replicas are combined in phase 3
    /// (default Uniform — the paper's mean, bitwise-pinned vs legacy)
    pub averaging: AveragingSpec,
    /// snapshot params every N phase-2 steps (figure instrumentation)
    pub snapshot_every: Option<usize>,
    /// snapshot the shared model every N phase-1 steps (Figure 1's left
    /// half plots the phase-1 accuracy trajectory)
    pub phase1_snapshot_every: Option<usize>,
    /// run phase 1 as a multi-process collective over the socket
    /// transport (`serve` is the hub, `join`ed workers compute the
    /// gradient shards); the in-memory transport ignores this
    pub phase1_dist: bool,
    /// resumable runs append a crash-safe phase-1 progress record every
    /// N sync steps (1 = every step)
    pub phase1_record_every: usize,
}

impl SwapConfig {
    pub fn total_devices(&self) -> usize {
        self.workers * self.group_devices
    }
}

/// Per-worker phase-2 snapshot trail (for Figures 1 and 4).
pub type Snapshots = Vec<Vec<(usize, ParamSet)>>;

/// The sync-training recipe of phase 1 — ONE definition shared by
/// `run_swap_with`, `run_swap_resumable_with`, and every transport, so an
/// in-process, distributed, fresh, or resumed phase 1 can never diverge
/// on the collective's configuration.
pub(crate) fn phase1_train_config(cfg: &SwapConfig, env: &TrainEnv) -> SyncTrainConfig {
    let devices = cfg.total_devices();
    SyncTrainConfig {
        devices,
        global_batch: devices * env.exec_batch,
        max_epochs: cfg.phase1_max_epochs,
        stop_train_acc: cfg.phase1_stop_acc,
        sched: cfg.phase1_sched.clone(),
        sched_offset: 0,
        seed_stream: 0,
        seed: cfg.seed,
    }
}

/// The sync-training recipe of phase-2 worker `w` — ONE definition shared
/// by `run_swap` and `run_swap_resumable`, so a fresh run and a resumed
/// run can never diverge on the worker configuration.
pub(crate) fn phase2_worker_config(cfg: &SwapConfig, env: &TrainEnv, w: usize) -> SyncTrainConfig {
    SyncTrainConfig {
        devices: cfg.group_devices,
        global_batch: cfg.group_devices * env.exec_batch,
        max_epochs: cfg.phase2_epochs,
        stop_train_acc: 1.1, // never early-stop in phase 2
        sched: cfg.phase2_sched.clone(),
        sched_offset: 0,
        seed_stream: 100 + w as u64, // different randomization per worker
        seed: cfg.seed,
    }
}

/// Everything the tables/figures need from one SWAP run.
pub struct SwapResult {
    pub phase1: TrainProgress,
    /// cluster seconds at the end of phase 1
    pub phase1_seconds: f64,
    /// cluster seconds at the end of phase 2 (= "before averaging" time)
    pub phase2_seconds: f64,
    /// the divergent phase-2 worker models
    pub worker_params: Vec<ParamSet>,
    /// per-worker test statistics before averaging
    pub worker_stats: Vec<BatchStats>,
    /// the averaged model + recomputed BN + its test statistics
    pub final_params: ParamSet,
    pub final_bn: BnState,
    pub final_stats: BatchStats,
    /// total modeled cluster time ("after averaging" time column)
    pub clock: ClusterClock,
    /// real wall seconds on this machine
    pub wall_seconds: f64,
    /// phase-2 snapshots if requested
    pub snapshots: Snapshots,
    /// the phase-1 output (the 'LB' anchor point for Figure 2)
    pub phase1_params: ParamSet,
    /// phase-1 snapshot trail if requested
    pub phase1_snapshots: Vec<(usize, ParamSet)>,
    /// workers excluded from the phase-3 average (id, reason) — empty on
    /// a fully healthy run
    pub dropped: Vec<(usize, String)>,
    /// final scalar state of the phase-3 averaging policy (persisted in
    /// run.meta.json by resumable runs)
    pub averaging_state: Json,
    /// wire traffic the phase-2 transport actually moved (zero in-memory)
    pub net: NetStats,
}

/// Run the full three-phase SWAP algorithm in-process with the default
/// failure policy — the historical entry point, bitwise-unchanged.
pub fn run_swap(env: &TrainEnv, cfg: &SwapConfig) -> Result<SwapResult> {
    run_swap_with(env, cfg, &MemoryTransport::new(), &FailurePolicy::default())
}

/// Run SWAP with an explicit phase-2 [`Transport`] and [`FailurePolicy`].
/// A worker the transport reports as `Dropped` (crash, hang, disconnect,
/// straggler) is excluded from the phase-3 average instead of aborting
/// the run; the run fails only when fewer than `policy.min_workers`
/// replicas survive.
pub fn run_swap_with(
    env: &TrainEnv,
    cfg: &SwapConfig,
    transport: &dyn Transport,
    policy: &FailurePolicy,
) -> Result<SwapResult> {
    if cfg.workers == 0 || cfg.group_devices == 0 {
        return Err(Error::config("swap: workers/group_devices must be > 0"));
    }
    let wall0 = std::time::Instant::now();
    let mut clock = ClusterClock::new();

    // ---------------- Phase 1: synchronous large batch -----------------
    // The transport decides where the collective runs (in-process device
    // threads, or a hub + remote shard workers over sockets); the recipe
    // is phase1_train_config either way, so the weights coming out are
    // transport-independent.
    let fingerprint = transport::run_fingerprint(env, cfg);
    let mut params = ParamSet::init(env.engine.manifest(), cfg.seed);
    let mut momentum = params.zeros_like();
    let p1_report = transport.run_phase1(
        &Phase1Ctx {
            env,
            cfg,
            train: phase1_train_config(cfg, env),
            policy,
            run_dir: None,
            fingerprint: fingerprint.clone(),
        },
        &mut params,
        &mut momentum,
        &mut clock,
    )?;
    let p1 = p1_report.progress;
    let phase1_snapshots = p1_report.snapshots;
    let phase1_seconds = clock.seconds;
    let phase1_params = params.clone();
    crate::info!(
        "phase 1 done: {:.2} epochs, train acc {:.3}, cluster {:.3}s",
        p1.epochs,
        p1.train_acc,
        phase1_seconds
    );

    // ---------------- Phase 2: independent refinement ------------------
    // Each group starts from the phase-1 weights with fresh momentum and a
    // distinct data stream; how/where they execute (in-process threads,
    // remote processes over sockets) is the transport's business. Worker
    // w's replica is a pure function of (cfg.seed, 100 + w), so the
    // transport can never change the result, only where it is computed.
    let pending: Vec<usize> = (0..cfg.workers).collect();
    let mut report = transport.run_phase2(&Phase2Ctx {
        env,
        cfg,
        start: &params,
        pending: &pending,
        policy,
        run_dir: None,
        fingerprint,
    })?;
    report.net.framed_bytes += p1_report.net.framed_bytes;
    report.net.param_bytes += p1_report.net.param_bytes;
    finish_swap(
        env,
        cfg,
        policy,
        transport.name(),
        report,
        p1,
        phase1_seconds,
        phase1_params,
        phase1_snapshots,
        clock,
        wall0,
    )
}

/// The modeled duration of ONE phase-2 worker — exactly what the live
/// per-worker `ClusterClock` accumulates over the worker's steps. Used to
/// price workers whose result is loaded from a checkpoint (resume) and to
/// book the time a dropped worker wasted (`ClusterClock::lost`).
pub(crate) fn modeled_phase2_clock(env: &TrainEnv, cfg: &SwapConfig) -> ClusterClock {
    let steps = cfg.phase2_epochs
        * EpochSampler::steps_per_epoch(env.train.n, cfg.group_devices * env.exec_batch);
    let mut wclock = ClusterClock::new();
    wclock.advance_compute(steps as f64 * env.cost.train_step_time(env.exec_batch));
    if cfg.group_devices > 1 {
        for _ in 0..steps {
            wclock.advance_comm(env.cost.allreduce_time(cfg.group_devices));
        }
    }
    // the original run priced its input pipeline every step; the same
    // booking (hidden vs exposed per env.prefetch) must reappear here
    let step_budget = env.cost.train_step_time(env.exec_batch)
        + if cfg.group_devices > 1 {
            env.cost.allreduce_time(cfg.group_devices)
        } else {
            0.0
        };
    let data_time = env.cost.assembly_time(cfg.group_devices * env.exec_batch);
    for _ in 0..steps {
        wclock.note_data(data_time, step_budget, env.prefetch);
    }
    wclock
}

/// Phases 2½ and 3, shared by `run_swap_with` and `run_swap_resumable_with`:
/// split the transport's outcomes into survivors and drops, enforce the
/// failure policy, advance the clock, then average + recompute BN + eval
/// exactly as the historical code did (a zero-drop run is bitwise
/// identical to it).
#[allow(clippy::too_many_arguments)]
pub(crate) fn finish_swap(
    env: &TrainEnv,
    cfg: &SwapConfig,
    policy: &FailurePolicy,
    transport_name: &str,
    report: Phase2Report,
    p1: TrainProgress,
    phase1_seconds: f64,
    phase1_params: ParamSet,
    phase1_snapshots: Vec<(usize, ParamSet)>,
    mut clock: ClusterClock,
    wall0: std::time::Instant,
) -> Result<SwapResult> {
    let mut outcomes = report.outcomes;
    outcomes.sort_by_key(|(w, _)| *w);
    let mut worker_ids = Vec::with_capacity(cfg.workers);
    let mut worker_params = Vec::with_capacity(cfg.workers);
    let mut group_clocks = Vec::with_capacity(cfg.workers);
    let mut snapshots: Snapshots = Vec::with_capacity(cfg.workers);
    let mut dropped: Vec<(usize, String)> = Vec::new();
    for (w, outcome) in outcomes {
        match outcome {
            WorkerOutcome::Done { params, clock: wclock, trail } => {
                worker_ids.push(w);
                worker_params.push(params);
                group_clocks.push(wclock);
                snapshots.push(trail);
            }
            WorkerOutcome::Dropped { reason } => {
                crate::warn_!(
                    "phase 2 ({transport_name}): worker {w} dropped from the average: {reason}"
                );
                dropped.push((w, reason));
            }
        }
    }
    if worker_params.len() < policy.min_workers.max(1) {
        return Err(Error::invalid(format!(
            "phase 2 ({transport_name}): only {}/{} workers survived, need at least {}",
            worker_params.len(),
            cfg.workers,
            policy.min_workers.max(1)
        )));
    }
    // the modeled cluster waits for the slowest surviving group, absorbing
    // its full compute/comm breakdown (not booking comm as compute); each
    // dropped worker's full modeled phase-2 slot is booked as lost
    clock.advance_parallel(&group_clocks);
    if !dropped.is_empty() {
        let wasted = modeled_phase2_clock(env, cfg).seconds;
        for _ in &dropped {
            clock.note_drop(wasted);
        }
    }
    let phase2_seconds = clock.seconds;

    // reporting-only: each survivor's test accuracy before averaging
    let mut worker_stats = Vec::with_capacity(worker_params.len());
    for wp in &worker_params {
        worker_stats.push(env.bn_and_eval(wp, cfg.seed, &mut clock)?);
    }

    // ---------------- Phase 3: average + BN recompute ------------------
    // the configured policy streams over the SURVIVORS in worker-id order
    // (the paper's average is well-defined for any non-empty subset). The
    // default Uniform policy is bitwise-identical to the historical
    // `ParamSet::average_mt` call, chunk-parallel across env.threads.
    let mut avg_policy = cfg.averaging.build();
    for (id, wp) in worker_ids.iter().zip(&worker_params) {
        let val_acc = maybe_val_acc(avg_policy.as_ref(), env, wp, cfg.seed, &mut clock)?;
        avg_policy.observe(
            wp,
            Candidate { kind: CandidateKind::Worker(*id), val_acc },
            env.threads,
        )?;
    }
    let final_params = avg_policy.average(env.threads)?;
    let averaging_state = avg_policy.state();
    let final_bn = env.recompute_bn(&final_params, cfg.seed, &mut clock, true)?;
    let final_stats = env.evaluate(&final_params, &final_bn, &mut clock)?;

    let result = SwapResult {
        phase1: p1,
        phase1_seconds,
        phase2_seconds,
        worker_params,
        worker_stats,
        final_params,
        final_bn,
        final_stats,
        clock,
        wall_seconds: wall0.elapsed().as_secs_f64(),
        snapshots,
        phase1_params,
        phase1_snapshots,
        dropped,
        averaging_state,
        net: report.net,
    };
    // one source of truth for the "before averaging" accuracy: the
    // SwapResult accessor (previously this log divided by cfg.workers
    // while the accessor divided by worker_stats.len())
    crate::info!(
        "phase 3 done: test acc {:.4} (workers before avg: {:.4}, {} dropped), cluster {:.3}s",
        result.final_stats.accuracy1(),
        result.before_avg_acc1(),
        result.dropped.len(),
        result.clock.seconds
    );
    Ok(result)
}

impl SwapResult {
    /// Mean worker accuracy before averaging (the paper's "SWAP (before
    /// averaging)" row).
    pub fn before_avg_acc1(&self) -> f64 {
        self.worker_stats.iter().map(|s| s.accuracy1()).sum::<f64>()
            / self.worker_stats.len().max(1) as f64
    }

    pub fn before_avg_acc5(&self) -> f64 {
        self.worker_stats.iter().map(|s| s.accuracy5()).sum::<f64>()
            / self.worker_stats.len().max(1) as f64
    }
}
