//! The L3 coordinator — the paper's system contribution.
//!
//! * `trainer`   — shared synchronous data-parallel loop + eval + BN
//! * `allreduce` — ring all-reduce (value) over worker gradient shards
//! * `parallel`  — real OS-thread execution (`std::thread::scope`), shared
//!                 by phase-2 workers, phase-1 shards, and native kernels
//! * `averaging` — pluggable phase-3/SWA averaging policies (uniform,
//!                 swa, hierarchical, adaptive/late-window) streaming over
//!                 the flat arena
//! * `swap`      — Algorithm 1 (three phases)
//! * `transport` — how phases 1 and 2 execute: in-process threads or
//!                 remote processes over sockets, with a failure policy
//!                 (timeouts, stragglers, elastic drop-out, ring repair,
//!                 crash-safe phase-1 progress records)
//! * `baseline`  — pure small-/large-batch SGD arms (Tables 1-3)
//! * `swa`       — sequential SWA baseline (Table 4)
//! * `local_sgd` — post-local SGD extension (§2/§6 related method)

pub mod allreduce;
pub mod averaging;
pub mod baseline;
pub mod local_sgd;
pub mod parallel;
pub mod resume;
pub mod swa;
pub mod swap;
pub mod trainer;
pub mod transport;

pub use averaging::{AveragingPolicy, AveragingSpec, Candidate, CandidateKind, StreamingMean};
pub use baseline::{run_baseline, BaselineConfig, BaselineResult};
pub use local_sgd::{run_local_sgd, LocalSgdConfig, LocalSgdResult};
pub use resume::{run_swap_resumable, run_swap_resumable_with, RunDir};
pub use swa::{run_swa, SwaConfig, SwaResult};
pub use swap::{run_swap, run_swap_with, SwapConfig, SwapResult};
pub use trainer::{
    run_sync_training, run_sync_training_with, SyncResume, SyncTrainConfig, TrainEnv,
    TrainProgress,
};
pub use transport::{
    join_phase1, join_run, FailurePolicy, JoinSummary, MemoryTransport, NetStats, Phase1Outcome,
    SocketTransport, Transport,
};
