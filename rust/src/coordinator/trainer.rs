//! Shared training machinery: the synchronous data-parallel loop (phase 1,
//! the LB/SB baselines, and each phase-2 sync *group*), evaluation, and
//! batch-norm recomputation.
//!
//! The input side is the pipelined subsystem of `data/`: augmentation is
//! keyed by a stateless counter (`(seed, stream, step, row)`), so batch
//! assembly is a pure function of the step index — any thread may build
//! any shard in any order. `run_sync_training` exploits that through
//! `data::prefetch::run_pipeline`, double-buffering the per-device
//! `HostBatch`es and assembling step t+1 on a background thread while the
//! backend computes step t. Prefetching is bitwise-free by construction;
//! only wall time and the `ClusterClock` data accounting (hidden vs
//! exposed) change.

use super::allreduce;
use super::parallel;
use crate::data::{
    prefetch, sequential_batches, AugStream, AugmentSpec, Batcher, Dataset, EpochSampler,
};
use crate::model::{BnState, ParamLayout, ParamSet};
use crate::optim::{Schedule, SgdConfig, SgdOptimizer};
use crate::runtime::{Backend, BatchStats, HostBatch};
use crate::sim::{ClusterClock, CostModel};
use crate::util::{Error, Result, Rng};

/// Everything a training run needs, borrowed once. The execution backend
/// is a trait object, so the same loop drives the native and XLA engines.
pub struct TrainEnv<'a> {
    pub engine: &'a dyn Backend,
    pub cost: &'a CostModel,
    pub train: &'a Dataset,
    pub test: &'a Dataset,
    /// held-out validation split for validation-gated averaging policies
    /// (the `val_examples` config knob); `None` = no split loaded
    pub val: Option<&'a Dataset>,
    pub augment: AugmentSpec,
    /// per-executable batch size (all artifacts share it)
    pub exec_batch: usize,
    /// training batches used to recompute BN statistics in phase 3
    pub bn_batches: usize,
    /// OS threads for real parallel execution (phase-2 workers, phase-1
    /// device shards). 1 = fully sequential; any value is bitwise
    /// reproducible (see `coordinator::parallel`).
    pub threads: usize,
    /// overlap batch assembly with backend compute (the input pipeline).
    /// Bitwise-free either way; governs the ClusterClock's data accounting
    /// (hidden behind compute vs exposed on the critical path).
    pub prefetch: bool,
}

impl<'a> TrainEnv<'a> {
    pub fn image_size(&self) -> usize {
        self.train.image_size
    }

    pub fn sgd_config(&self) -> SgdConfig {
        let m = self.engine.manifest();
        SgdConfig {
            momentum: m.model.momentum,
            weight_decay: m.model.weight_decay,
        }
    }

    /// Full-test-set evaluation with the given BN statistics.
    /// Adds modeled time to `clock.eval` (not training time).
    pub fn evaluate(
        &self,
        params: &ParamSet,
        bn: &BnState,
        clock: &mut ClusterClock,
    ) -> Result<BatchStats> {
        self.evaluate_on(self.test, params, bn, clock, usize::MAX)
    }

    /// Evaluate on an arbitrary dataset (landscape grids measure *train*
    /// error too), over at most `max_batches` leading batches. Runs on the
    /// same prefetch pipeline as training: batch k is a pure function of
    /// k, so assembly of batch k+1 overlaps the backend's eval of batch k.
    pub fn evaluate_on(
        &self,
        ds: &Dataset,
        params: &ParamSet,
        bn: &BnState,
        clock: &mut ClusterClock,
        max_batches: usize,
    ) -> Result<BatchStats> {
        self.eval_impl(ds, params, bn, clock, max_batches, true)
    }

    /// [`TrainEnv::evaluate_on`] for callers that only want accuracy: the
    /// backend may skip the cross-entropy/loss tail
    /// ([`Backend::eval_batch_top1`]), so the returned `sum_loss` is not
    /// meaningful. Accuracy counts are contractually identical to
    /// `evaluate_on`'s.
    pub fn evaluate_acc_on(
        &self,
        ds: &Dataset,
        params: &ParamSet,
        bn: &BnState,
        clock: &mut ClusterClock,
        max_batches: usize,
    ) -> Result<BatchStats> {
        self.eval_impl(ds, params, bn, clock, max_batches, false)
    }

    #[allow(clippy::too_many_arguments)]
    fn eval_impl(
        &self,
        ds: &Dataset,
        params: &ParamSet,
        bn: &BnState,
        clock: &mut ClusterClock,
        max_batches: usize,
        with_loss: bool,
    ) -> Result<BatchStats> {
        let b = self.exec_batch;
        let batcher = Batcher::new(b, self.image_size(), AugmentSpec::none());
        // sequential_batches yields the ragged final batch, so a full pass
        // scores ALL ds.n examples (examples == ds.n), not floor(n/b)*b —
        // except on AOT backends, whose per-batch executables can only run
        // whole batches (the tail is dropped there, as it always was)
        let ragged_ok = self.engine.supports_ragged_batch();
        let idx_lists: Vec<Vec<usize>> = sequential_batches(ds.n, b)
            .take(max_batches)
            .take_while(|idx| ragged_ok || idx.len() == b)
            .collect();
        let steps = idx_lists.len();
        let overlap = self.spawn_prefetch();
        let slots = prefetch::make_slots(overlap, || batcher.make_batch());
        let produce = move |k: usize, out: &mut HostBatch| {
            batcher.assemble_clean_into(ds, &idx_lists[k], out);
        };
        let mut total = BatchStats::default();
        prefetch::run_pipeline(steps, slots, overlap, produce, |_, hb: &mut HostBatch| {
            let stats = if with_loss {
                self.engine.eval_batch(params.as_slice(), bn.as_slice(), hb)?
            } else {
                self.engine.eval_batch_top1(params.as_slice(), bn.as_slice(), hb)?
            };
            total.accumulate(&stats);
            clock.note_eval(self.cost.eval_step_time(hb.batch));
            Ok(true)
        })?;
        if total.examples == 0 {
            return Err(Error::invalid(
                "evaluate: no runnable batch (dataset empty, or smaller than \
                 one batch on a backend without ragged-batch support)",
            ));
        }
        Ok(total)
    }

    /// Recompute BN running statistics from `self.bn_batches` training
    /// batches (Algorithm 1, line 28). Deterministic batch choice per seed.
    /// Counts as *training* time when `charge_clock` (phase 3 does; the
    /// reporting-only per-worker evals don't).
    pub fn recompute_bn(
        &self,
        params: &ParamSet,
        seed: u64,
        clock: &mut ClusterClock,
        charge_clock: bool,
    ) -> Result<BnState> {
        if self.train.n == 0 {
            // the wrap-around fill below can never grow on an empty
            // dataset — error out instead of spinning forever
            return Err(Error::invalid("recompute_bn: training dataset is empty"));
        }
        let b = self.exec_batch;
        let mut rng = Rng::stream(seed, 0xB7);
        let batcher = Batcher::new(b, self.image_size(), AugmentSpec::none());
        let mut moments: Vec<Vec<f32>> = Vec::with_capacity(self.bn_batches);
        let mut order = rng.permutation(self.train.n);
        if order.len() < b * self.bn_batches {
            // small datasets: wrap around
            while order.len() < b * self.bn_batches {
                let extra = rng.permutation(self.train.n);
                order.extend(extra);
            }
        }
        // batch k is a pure function of k (the order is fixed up front),
        // so BN recomputation rides the same prefetch pipeline
        let train = self.train;
        let overlap = self.spawn_prefetch();
        let slots = prefetch::make_slots(overlap, || batcher.make_batch());
        let produce = move |k: usize, out: &mut HostBatch| {
            batcher.assemble_clean_into(train, &order[k * b..(k + 1) * b], out);
        };
        prefetch::run_pipeline(self.bn_batches, slots, overlap, produce, |_, hb| {
            moments.push(self.engine.bn_moments(params.as_slice(), hb)?);
            let dt = self.cost.eval_step_time(b);
            if charge_clock {
                clock.advance_compute(dt);
            } else {
                clock.note_eval(dt);
            }
            Ok(true)
        })?;
        BnState::from_moments(ParamLayout::of_bn(self.engine.manifest()), &moments)
    }

    /// Top-1 accuracy of `params` on the held-out validation split, or
    /// `None` when no split is loaded. BN is recomputed uncharged and the
    /// forward passes are booked as eval time — validation scoring guides
    /// the averaging policy, it is not training compute.
    pub fn val_acc(
        &self,
        params: &ParamSet,
        seed: u64,
        clock: &mut ClusterClock,
    ) -> Result<Option<f64>> {
        let Some(val) = self.val else { return Ok(None) };
        let bn = self.recompute_bn(params, seed, clock, false)?;
        // accuracy-only: the loss tail is skipped on backends that can
        let stats = self.evaluate_acc_on(val, params, &bn, clock, usize::MAX)?;
        Ok(Some(stats.accuracy1()))
    }

    /// Convenience: recompute BN (uncharged) then evaluate.
    pub fn bn_and_eval(
        &self,
        params: &ParamSet,
        seed: u64,
        clock: &mut ClusterClock,
    ) -> Result<BatchStats> {
        let bn = self.recompute_bn(params, seed, clock, false)?;
        self.evaluate(params, &bn, clock)
    }

    /// Whether a run may actually spawn the prefetch producer thread:
    /// requested, with a thread budget, and not already inside a coarser
    /// fan-out (phase-2 workers own the cores). Never affects results or
    /// the modeled clock — only wall time.
    pub(crate) fn spawn_prefetch(&self) -> bool {
        self.prefetch && self.threads > 1 && !parallel::in_parallel_region()
    }
}

/// Configuration of one synchronous data-parallel training segment.
#[derive(Debug, Clone)]
pub struct SyncTrainConfig {
    /// number of data-parallel devices (1 = single-device fused path)
    pub devices: usize,
    /// global batch size (must be devices * exec_batch)
    pub global_batch: usize,
    /// hard stop after this many epochs
    pub max_epochs: usize,
    /// early stop once the epoch's training accuracy reaches this (1.0 = off)
    pub stop_train_acc: f64,
    pub sched: Schedule,
    /// schedule step offset (composing phases)
    pub sched_offset: usize,
    /// RNG stream id for sampling/augmentation (worker identity)
    pub seed_stream: u64,
    pub seed: u64,
}

/// Outcome of a sync segment.
#[derive(Debug, Clone, Copy)]
pub struct TrainProgress {
    pub steps: usize,
    pub epochs: f64,
    /// training accuracy over the last completed epoch
    pub train_acc: f64,
    pub train_loss: f64,
}

/// Mid-segment state to restart a sync segment from (the crash-safe
/// phase-1 progress record round-trips exactly these fields, plus the
/// weight/momentum arenas and the clock, which the caller restores).
/// `start_step` completed optimizer steps are skipped: the sampler is
/// fast-forwarded past their batches and the step/epoch counters resume
/// at the absolute index, so an interrupted-and-resumed segment is
/// bitwise identical to an uninterrupted one.
#[derive(Debug, Clone, Copy, Default)]
pub struct SyncResume {
    /// completed optimizer steps (absolute, within this segment)
    pub start_step: usize,
    /// partial statistics of the in-progress epoch
    pub epoch_stats: BatchStats,
    pub last_epoch_acc: f64,
    pub last_epoch_loss: f64,
}

/// Everything a per-step progress hook needs to persist a resumable
/// snapshot of the segment: handed to the hook after EVERY completed
/// optimizer step (epoch bookkeeping already applied), so `step`,
/// `epoch_stats`, and the arenas are exactly what [`SyncResume`] +
/// restored arenas would restart from.
pub struct SyncState<'a> {
    /// completed optimizer steps (absolute, within this segment)
    pub step: usize,
    pub params: &'a ParamSet,
    pub momentum: &'a ParamSet,
    pub epoch_stats: &'a BatchStats,
    pub last_epoch_acc: f64,
    pub last_epoch_loss: f64,
    pub clock: ClusterClock,
}

/// A per-step progress hook (crash-safe persistence); an `Err` aborts the
/// segment — tests use that to inject crashes at exact step boundaries.
pub type ProgressHook<'h> = &'h mut dyn FnMut(&SyncState) -> Result<()>;

/// Run synchronous SGD: `devices` workers each compute gradients on a
/// `global_batch / devices` shard, gradients are ring-averaged, and the
/// host applies the Nesterov update (phase 1 of Algorithm 1). With
/// `devices == 1` the fused on-device train step is used instead (the
/// phase-2 / small-batch path).
///
/// `observer` is called after every optimizer step with (global step index,
/// params) — the hook the figure benches use.
pub fn run_sync_training(
    env: &TrainEnv,
    params: &mut ParamSet,
    momentum: &mut ParamSet,
    cfg: &SyncTrainConfig,
    clock: &mut ClusterClock,
    observer: impl FnMut(usize, &ParamSet, &BatchStats),
) -> Result<TrainProgress> {
    run_sync_training_with(env, params, momentum, cfg, clock, observer, None, None)
}

fn check_sync_config(env: &TrainEnv, cfg: &SyncTrainConfig) -> Result<()> {
    if cfg.global_batch != cfg.devices * env.exec_batch {
        return Err(Error::config(format!(
            "global batch {} != devices {} x exec batch {}",
            cfg.global_batch, cfg.devices, env.exec_batch
        )));
    }
    if cfg.global_batch > env.train.n {
        return Err(Error::config("global batch larger than the dataset"));
    }
    Ok(())
}

/// [`run_sync_training`] with mid-segment resume and a per-step progress
/// hook. `resume = None, progress = None` is bitwise the plain call.
#[allow(clippy::too_many_arguments)]
pub fn run_sync_training_with(
    env: &TrainEnv,
    params: &mut ParamSet,
    momentum: &mut ParamSet,
    cfg: &SyncTrainConfig,
    clock: &mut ClusterClock,
    mut observer: impl FnMut(usize, &ParamSet, &BatchStats),
    resume: Option<SyncResume>,
    mut progress: Option<ProgressHook>,
) -> Result<TrainProgress> {
    check_sync_config(env, cfg)?;
    let sgd = env.sgd_config();
    // zero-copy ownership handoff of the momentum arena for the segment
    // (flat::sgd_step gates its own fan-out on the arena size)
    let mut opt = SgdOptimizer { cfg: sgd, momentum: momentum.take() };
    let mut sampler = EpochSampler::new(env.train.n, cfg.global_batch, cfg.seed, cfg.seed_stream);
    let mut batcher = Batcher::new(env.exec_batch, env.image_size(), env.augment);
    // stateless counter-keyed augmentation: global row r of step t draws
    // from Rng::counter(seed ^ 0xAE6, stream, t, r) — a pure function, so
    // the producer thread (or any shard order) reproduces the serial
    // assembly bit for bit
    let aug = AugStream { seed: cfg.seed ^ 0xAE6, stream: cfg.seed_stream };
    let devices = cfg.devices;
    let train = env.train;

    let steps_per_epoch = sampler.batches_per_epoch();
    let total_steps = cfg.max_epochs * steps_per_epoch;
    let resume = resume.unwrap_or(SyncResume {
        start_step: 0,
        epoch_stats: BatchStats::default(),
        last_epoch_acc: 0.0,
        last_epoch_loss: f64::INFINITY,
    });
    let start_step = resume.start_step;
    if start_step > total_steps {
        return Err(Error::config(format!(
            "resume step {start_step} beyond the segment's {total_steps} steps"
        )));
    }
    // skip the batches the completed steps already consumed: batch t is
    // the t-th draw of the (seed, stream) sampler sequence on every path
    for _ in 0..start_step {
        sampler.next_batch();
    }
    let mut epoch_stats = resume.epoch_stats;
    let mut last_epoch_acc = resume.last_epoch_acc;
    let mut last_epoch_loss = resume.last_epoch_loss;
    let mut steps = start_step;

    let step_compute = env.cost.train_step_time(env.exec_batch);
    let ar_time = env.cost.allreduce_time(cfg.devices);
    let data_time = env.cost.assembly_time(cfg.global_batch);
    // assembly of step t+1 can hide behind the whole device-side step t
    let step_budget = step_compute + if devices > 1 { ar_time } else { 0.0 };
    // fan the per-step shard gradients out only when one gradient is worth
    // more than a thread spawn (fwd+bwd ~ 3x fwd FLOPs per example)
    let grad_work = 3 * env.engine.manifest().flops_fwd_per_example as usize * env.exec_batch;
    let shard_threads = parallel::gate(env.threads, grad_work);

    // double-buffer per-device HostBatches when the producer thread may
    // run; a single slot otherwise (assemble-then-compute, same bits)
    let overlap = env.spawn_prefetch();
    let slots: Vec<Vec<HostBatch>> =
        prefetch::make_slots(overlap, || (0..devices).map(|_| batcher.make_batch()).collect());

    // the producer: a pure function of the step index (sampler order is
    // deterministic, augmentation is counter-keyed); the pipeline counts
    // local indices, the batch keys stay absolute
    let produce = move |k: usize, out: &mut Vec<HostBatch>| {
        let step = start_step + k;
        let global = sampler.next_batch();
        if devices == 1 {
            batcher.assemble_step_into(train, global, aug, step as u64, 0, &mut out[0]);
        } else {
            let per = global.len() / devices;
            for (d, hb) in out.iter_mut().enumerate() {
                let rows = &global[d * per..(d + 1) * per];
                batcher.assemble_step_into(train, rows, aug, step as u64, (d * per) as u64, hb);
            }
        }
    };

    // the consumer: the device-side step + bookkeeping (main thread)
    let consume = |k: usize, batches: &mut Vec<HostBatch>| -> Result<bool> {
        let step = start_step + k;
        let lr = cfg.sched.lr(cfg.sched_offset + step);
        let stats = if devices == 1 {
            env.engine.train_step(
                params.as_mut_slice(),
                opt.momentum.as_mut_slice(),
                &batches[0],
                lr,
            )?
        } else {
            // per-device gradients are pure functions of (params, batch):
            // compute them on real OS threads, then reduce in device order
            let results = parallel::parallel_map(
                shard_threads,
                batches.iter().collect(),
                |_, hb| env.engine.grad(params.as_slice(), hb),
            );
            let mut worker_grads: Vec<Vec<f32>> = Vec::with_capacity(devices);
            let mut stats = BatchStats::default();
            for g in results {
                let g = g?;
                stats.accumulate(&g.stats);
                worker_grads.push(g.grads);
            }
            // in-place ring: after this, worker_grads[0] is the mean arena
            allreduce::ring_mean_inplace(&mut worker_grads)?;
            opt.step_mt(params, &worker_grads[0], lr, env.threads)?;
            stats
        };
        // cluster time: all devices compute in parallel, then sync; input
        // assembly hides behind the step when the pipeline overlaps
        clock.advance_compute(step_compute);
        if devices > 1 {
            clock.advance_comm(ar_time);
        }
        clock.note_data(data_time, step_budget, env.prefetch);
        epoch_stats.accumulate(&stats);
        steps += 1;
        observer(cfg.sched_offset + steps - 1, params, &stats);

        let mut stop = false;
        if steps % steps_per_epoch == 0 {
            last_epoch_acc = epoch_stats.accuracy1();
            last_epoch_loss = epoch_stats.mean_loss();
            crate::debug!(
                "epoch {} train acc {:.4} loss {:.4}",
                steps / steps_per_epoch,
                last_epoch_acc,
                last_epoch_loss
            );
            epoch_stats = BatchStats::default();
            if last_epoch_acc >= cfg.stop_train_acc {
                stop = true;
            }
        }
        if let Some(h) = progress.as_mut() {
            (**h)(&SyncState {
                step: steps,
                params,
                momentum: &opt.momentum,
                epoch_stats: &epoch_stats,
                last_epoch_acc,
                last_epoch_loss,
                clock: *clock,
            })?;
        }
        Ok(!stop)
    };

    prefetch::run_pipeline(total_steps - start_step, slots, overlap, produce, consume)?;

    *momentum = opt.momentum;
    Ok(TrainProgress {
        steps,
        epochs: steps as f64 / steps_per_epoch as f64,
        train_acc: last_epoch_acc,
        train_loss: last_epoch_loss,
    })
}

/// What one sync step's distributed gradient exchange came back with.
pub struct CollectiveStep {
    /// per-device gradient arenas in ascending absolute device order —
    /// only the shards of members that delivered completely this step
    pub grads: Vec<Vec<f32>>,
    /// batch statistics accumulated over those shards in the same order
    pub stats: BatchStats,
    /// device shards that contributed (= `grads.len()`); prices the
    /// step's ring all-reduce time
    pub live_devices: usize,
    /// modeled seconds of shard compute discarded this step (members
    /// that died mid-collective), booked into `ClusterClock::lost`
    pub lost: f64,
}

/// The coordinator-side loop of a *distributed* phase 1: identical
/// bookkeeping to [`run_sync_training`] (same optimizer, schedule, stats,
/// epoch, early-stop, observer, and clock sequence), but the per-device
/// gradients come from `exchange(step, params)` — remote members
/// assembling their own shard batches — instead of local threads. On a
/// zero-failure run the exchange returns the same arenas in the same
/// order as the in-process path, so the result is bitwise identical; a
/// repaired (shrunken) ring returns fewer arenas and
/// `allreduce::ring_mean_inplace` re-normalizes the mean over the
/// surviving shard set by construction.
#[allow(clippy::too_many_arguments)]
pub fn run_sync_collective(
    env: &TrainEnv,
    params: &mut ParamSet,
    momentum: &mut ParamSet,
    cfg: &SyncTrainConfig,
    clock: &mut ClusterClock,
    mut observer: impl FnMut(usize, &ParamSet, &BatchStats),
    resume: Option<SyncResume>,
    mut progress: Option<ProgressHook>,
    mut exchange: impl FnMut(usize, &ParamSet) -> Result<CollectiveStep>,
) -> Result<TrainProgress> {
    check_sync_config(env, cfg)?;
    let sgd = env.sgd_config();
    let mut opt = SgdOptimizer { cfg: sgd, momentum: momentum.take() };
    let steps_per_epoch = EpochSampler::steps_per_epoch(env.train.n, cfg.global_batch);
    let total_steps = cfg.max_epochs * steps_per_epoch;
    let resume = resume.unwrap_or(SyncResume {
        start_step: 0,
        epoch_stats: BatchStats::default(),
        last_epoch_acc: 0.0,
        last_epoch_loss: f64::INFINITY,
    });
    if resume.start_step > total_steps {
        return Err(Error::config(format!(
            "resume step {} beyond the segment's {total_steps} steps",
            resume.start_step
        )));
    }
    let mut epoch_stats = resume.epoch_stats;
    let mut last_epoch_acc = resume.last_epoch_acc;
    let mut last_epoch_loss = resume.last_epoch_loss;
    let mut steps = resume.start_step;

    let step_compute = env.cost.train_step_time(env.exec_batch);
    let ar_time = env.cost.allreduce_time(cfg.devices);
    let data_time = env.cost.assembly_time(cfg.global_batch);
    let step_budget = step_compute + if cfg.devices > 1 { ar_time } else { 0.0 };

    while steps < total_steps {
        let step = steps;
        let lr = cfg.sched.lr(cfg.sched_offset + step);
        let ex = exchange(step, params)?;
        let mut worker_grads = ex.grads;
        allreduce::ring_mean_inplace(&mut worker_grads)?;
        opt.step_mt(params, &worker_grads[0], lr, env.threads)?;
        clock.advance_compute(step_compute);
        if ex.live_devices > 1 {
            clock.advance_comm(env.cost.allreduce_time(ex.live_devices));
        }
        clock.note_data(data_time, step_budget, env.prefetch);
        if ex.lost > 0.0 {
            clock.note_drop(ex.lost);
        }
        epoch_stats.accumulate(&ex.stats);
        steps += 1;
        observer(cfg.sched_offset + steps - 1, params, &ex.stats);

        let mut stop = false;
        if steps % steps_per_epoch == 0 {
            last_epoch_acc = epoch_stats.accuracy1();
            last_epoch_loss = epoch_stats.mean_loss();
            crate::debug!(
                "epoch {} train acc {:.4} loss {:.4}",
                steps / steps_per_epoch,
                last_epoch_acc,
                last_epoch_loss
            );
            epoch_stats = BatchStats::default();
            if last_epoch_acc >= cfg.stop_train_acc {
                stop = true;
            }
        }
        if let Some(h) = progress.as_mut() {
            (**h)(&SyncState {
                step: steps,
                params,
                momentum: &opt.momentum,
                epoch_stats: &epoch_stats,
                last_epoch_acc,
                last_epoch_loss,
                clock: *clock,
            })?;
        }
        if stop {
            break;
        }
    }

    *momentum = opt.momentum;
    Ok(TrainProgress {
        steps,
        epochs: steps as f64 / steps_per_epoch as f64,
        train_acc: last_epoch_acc,
        train_loss: last_epoch_loss,
    })
}
