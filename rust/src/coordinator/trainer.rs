//! Shared training machinery: the synchronous data-parallel loop (phase 1,
//! the LB/SB baselines, and each phase-2 sync *group*), evaluation, and
//! batch-norm recomputation.

use super::allreduce;
use super::parallel;
use crate::data::{sequential_batches, AugmentSpec, Batcher, Dataset, EpochSampler, shard};
use crate::model::{BnState, ParamLayout, ParamSet};
use crate::optim::{Schedule, SgdConfig, SgdOptimizer};
use crate::runtime::{Backend, BatchStats};
use crate::sim::{ClusterClock, CostModel};
use crate::util::{Error, Result, Rng};

/// Everything a training run needs, borrowed once. The execution backend
/// is a trait object, so the same loop drives the native and XLA engines.
pub struct TrainEnv<'a> {
    pub engine: &'a dyn Backend,
    pub cost: &'a CostModel,
    pub train: &'a Dataset,
    pub test: &'a Dataset,
    pub augment: AugmentSpec,
    /// per-executable batch size (all artifacts share it)
    pub exec_batch: usize,
    /// training batches used to recompute BN statistics in phase 3
    pub bn_batches: usize,
    /// OS threads for real parallel execution (phase-2 workers, phase-1
    /// device shards). 1 = fully sequential; any value is bitwise
    /// reproducible (see `coordinator::parallel`).
    pub threads: usize,
}

impl<'a> TrainEnv<'a> {
    pub fn image_size(&self) -> usize {
        self.train.image_size
    }

    pub fn sgd_config(&self) -> SgdConfig {
        let m = self.engine.manifest();
        SgdConfig {
            momentum: m.model.momentum,
            weight_decay: m.model.weight_decay,
        }
    }

    /// Full-test-set evaluation with the given BN statistics.
    /// Adds modeled time to `clock.eval` (not training time).
    pub fn evaluate(
        &self,
        params: &ParamSet,
        bn: &BnState,
        clock: &mut ClusterClock,
    ) -> Result<BatchStats> {
        self.evaluate_on(self.test, params, bn, clock, usize::MAX)
    }

    /// Evaluate on an arbitrary dataset (landscape grids measure *train*
    /// error too), over at most `max_batches` leading batches.
    pub fn evaluate_on(
        &self,
        ds: &Dataset,
        params: &ParamSet,
        bn: &BnState,
        clock: &mut ClusterClock,
        max_batches: usize,
    ) -> Result<BatchStats> {
        let b = self.exec_batch;
        let batcher = Batcher::new(b, self.image_size(), AugmentSpec::none());
        let mut hb = batcher.make_batch();
        let mut total = BatchStats::default();
        // sequential_batches yields the ragged final batch, so a full pass
        // scores ALL ds.n examples (examples == ds.n), not floor(n/b)*b —
        // except on AOT backends, whose per-batch executables can only run
        // whole batches (the tail is dropped there, as it always was)
        let ragged_ok = self.engine.supports_ragged_batch();
        for idx in sequential_batches(ds.n, b).take(max_batches) {
            if idx.len() != b && !ragged_ok {
                break;
            }
            batcher.assemble_clean_into(ds, &idx, &mut hb);
            let stats = self.engine.eval_batch(params.as_slice(), bn.as_slice(), &hb)?;
            total.accumulate(&stats);
            clock.note_eval(self.cost.eval_step_time(hb.batch));
        }
        if total.examples == 0 {
            return Err(Error::invalid(
                "evaluate: no runnable batch (dataset empty, or smaller than \
                 one batch on a backend without ragged-batch support)",
            ));
        }
        Ok(total)
    }

    /// Recompute BN running statistics from `self.bn_batches` training
    /// batches (Algorithm 1, line 28). Deterministic batch choice per seed.
    /// Counts as *training* time when `charge_clock` (phase 3 does; the
    /// reporting-only per-worker evals don't).
    pub fn recompute_bn(
        &self,
        params: &ParamSet,
        seed: u64,
        clock: &mut ClusterClock,
        charge_clock: bool,
    ) -> Result<BnState> {
        let b = self.exec_batch;
        let mut rng = Rng::stream(seed, 0xB7);
        let batcher = Batcher::new(b, self.image_size(), AugmentSpec::none());
        let mut hb = batcher.make_batch();
        let mut moments: Vec<Vec<f32>> = Vec::with_capacity(self.bn_batches);
        let mut order = rng.permutation(self.train.n);
        if order.len() < b * self.bn_batches {
            // small datasets: wrap around
            while order.len() < b * self.bn_batches {
                let extra = rng.permutation(self.train.n);
                order.extend(extra);
            }
        }
        for k in 0..self.bn_batches {
            let idx = &order[k * b..(k + 1) * b];
            batcher.assemble_clean_into(self.train, idx, &mut hb);
            moments.push(self.engine.bn_moments(params.as_slice(), &hb)?);
            let dt = self.cost.eval_step_time(b);
            if charge_clock {
                clock.advance_compute(dt);
            } else {
                clock.note_eval(dt);
            }
        }
        BnState::from_moments(ParamLayout::of_bn(self.engine.manifest()), &moments)
    }

    /// Convenience: recompute BN (uncharged) then evaluate.
    pub fn bn_and_eval(
        &self,
        params: &ParamSet,
        seed: u64,
        clock: &mut ClusterClock,
    ) -> Result<BatchStats> {
        let bn = self.recompute_bn(params, seed, clock, false)?;
        self.evaluate(params, &bn, clock)
    }
}

/// Configuration of one synchronous data-parallel training segment.
#[derive(Debug, Clone)]
pub struct SyncTrainConfig {
    /// number of data-parallel devices (1 = single-device fused path)
    pub devices: usize,
    /// global batch size (must be devices * exec_batch)
    pub global_batch: usize,
    /// hard stop after this many epochs
    pub max_epochs: usize,
    /// early stop once the epoch's training accuracy reaches this (1.0 = off)
    pub stop_train_acc: f64,
    pub sched: Schedule,
    /// schedule step offset (composing phases)
    pub sched_offset: usize,
    /// RNG stream id for sampling/augmentation (worker identity)
    pub seed_stream: u64,
    pub seed: u64,
}

/// Outcome of a sync segment.
#[derive(Debug, Clone, Copy)]
pub struct TrainProgress {
    pub steps: usize,
    pub epochs: f64,
    /// training accuracy over the last completed epoch
    pub train_acc: f64,
    pub train_loss: f64,
}

/// Run synchronous SGD: `devices` workers each compute gradients on a
/// `global_batch / devices` shard, gradients are ring-averaged, and the
/// host applies the Nesterov update (phase 1 of Algorithm 1). With
/// `devices == 1` the fused on-device train step is used instead (the
/// phase-2 / small-batch path).
///
/// `observer` is called after every optimizer step with (global step index,
/// params) — the hook the figure benches use.
pub fn run_sync_training(
    env: &TrainEnv,
    params: &mut ParamSet,
    momentum: &mut ParamSet,
    cfg: &SyncTrainConfig,
    clock: &mut ClusterClock,
    mut observer: impl FnMut(usize, &ParamSet, &BatchStats),
) -> Result<TrainProgress> {
    if cfg.global_batch != cfg.devices * env.exec_batch {
        return Err(Error::config(format!(
            "global batch {} != devices {} x exec batch {}",
            cfg.global_batch, cfg.devices, env.exec_batch
        )));
    }
    if cfg.global_batch > env.train.n {
        return Err(Error::config("global batch larger than the dataset"));
    }
    let sgd = env.sgd_config();
    // zero-copy ownership handoff of the momentum arena for the segment
    // (flat::sgd_step gates its own fan-out on the arena size)
    let mut opt = SgdOptimizer { cfg: sgd, momentum: momentum.take() };
    let mut sampler = EpochSampler::new(env.train.n, cfg.global_batch, cfg.seed, cfg.seed_stream);
    let batcher = Batcher::new(env.exec_batch, env.image_size(), env.augment);
    let mut aug_rng = Rng::stream(cfg.seed ^ 0xAE6, cfg.seed_stream);
    // one owned, reused HostBatch per device: the hot loop performs no
    // per-step allocation, and each grad thread reads its own batch
    let mut device_batches: Vec<crate::runtime::HostBatch> =
        (0..cfg.devices).map(|_| batcher.make_batch()).collect();

    let steps_per_epoch = sampler.batches_per_epoch();
    let total_steps = cfg.max_epochs * steps_per_epoch;
    let mut epoch_stats = BatchStats::default();
    let mut last_epoch_acc = 0.0;
    let mut last_epoch_loss = f64::INFINITY;
    let mut steps = 0usize;

    let step_compute = env.cost.train_step_time(env.exec_batch);
    let ar_time = env.cost.allreduce_time(cfg.devices);
    // fan the per-step shard gradients out only when one gradient is worth
    // more than a thread spawn (fwd+bwd ~ 3x fwd FLOPs per example)
    let grad_work = 3 * env.engine.manifest().flops_fwd_per_example as usize * env.exec_batch;
    let shard_threads = parallel::gate(env.threads, grad_work);

    'outer: for _ in 0..total_steps {
        let global = sampler.next_batch().to_vec();
        let stats = if cfg.devices == 1 {
            let hb = &mut device_batches[0];
            batcher.assemble_into(env.train, &global, &mut aug_rng, hb);
            let lr = cfg.sched.lr(cfg.sched_offset + steps);
            env.engine
                .train_step(params.as_mut_slice(), opt.momentum.as_mut_slice(), hb, lr)?
        } else {
            // assembly stays on this thread in shard order — the shared
            // augmentation RNG stream is consumed exactly as in the
            // sequential path, so any thread count is bitwise identical
            let shards = shard(&global, cfg.devices);
            for (sh, hb) in shards.iter().zip(device_batches.iter_mut()) {
                batcher.assemble_into(env.train, sh, &mut aug_rng, hb);
            }
            // per-device gradients are pure functions of (params, batch):
            // compute them on real OS threads, then reduce in device order
            let results = parallel::parallel_map(
                shard_threads,
                device_batches.iter().collect(),
                |_, hb| env.engine.grad(params.as_slice(), hb),
            );
            let mut worker_grads: Vec<Vec<f32>> = Vec::with_capacity(cfg.devices);
            let mut stats = BatchStats::default();
            for g in results {
                let g = g?;
                stats.accumulate(&g.stats);
                worker_grads.push(g.grads);
            }
            // in-place ring: after this, worker_grads[0] is the mean arena
            allreduce::ring_mean_inplace(&mut worker_grads)?;
            let lr = cfg.sched.lr(cfg.sched_offset + steps);
            opt.step_mt(params, &worker_grads[0], lr, env.threads)?;
            stats
        };
        // cluster time: all devices compute in parallel, then sync
        clock.advance_compute(step_compute);
        if cfg.devices > 1 {
            clock.advance_comm(ar_time);
        }
        epoch_stats.accumulate(&stats);
        steps += 1;
        observer(cfg.sched_offset + steps - 1, params, &stats);

        if steps % steps_per_epoch == 0 {
            last_epoch_acc = epoch_stats.accuracy1();
            last_epoch_loss = epoch_stats.mean_loss();
            crate::debug!(
                "epoch {} train acc {:.4} loss {:.4}",
                steps / steps_per_epoch,
                last_epoch_acc,
                last_epoch_loss
            );
            epoch_stats = BatchStats::default();
            if last_epoch_acc >= cfg.stop_train_acc {
                break 'outer;
            }
        }
    }
    *momentum = opt.momentum;
    Ok(TrainProgress {
        steps,
        epochs: steps as f64 / steps_per_epoch as f64,
        train_acc: last_epoch_acc,
        train_loss: last_epoch_loss,
    })
}
