//! Shared training machinery: the synchronous data-parallel loop (phase 1,
//! the LB/SB baselines, and each phase-2 sync *group*), evaluation, and
//! batch-norm recomputation.
//!
//! The input side is the pipelined subsystem of `data/`: augmentation is
//! keyed by a stateless counter (`(seed, stream, step, row)`), so batch
//! assembly is a pure function of the step index — any thread may build
//! any shard in any order. `run_sync_training` exploits that through
//! `data::prefetch::run_pipeline`, double-buffering the per-device
//! `HostBatch`es and assembling step t+1 on a background thread while the
//! backend computes step t. Prefetching is bitwise-free by construction;
//! only wall time and the `ClusterClock` data accounting (hidden vs
//! exposed) change.

use super::allreduce;
use super::parallel;
use crate::data::{
    prefetch, sequential_batches, AugStream, AugmentSpec, Batcher, Dataset, EpochSampler,
};
use crate::model::{BnState, ParamLayout, ParamSet};
use crate::optim::{Schedule, SgdConfig, SgdOptimizer};
use crate::runtime::{Backend, BatchStats, HostBatch};
use crate::sim::{ClusterClock, CostModel};
use crate::util::{Error, Result, Rng};

/// Everything a training run needs, borrowed once. The execution backend
/// is a trait object, so the same loop drives the native and XLA engines.
pub struct TrainEnv<'a> {
    pub engine: &'a dyn Backend,
    pub cost: &'a CostModel,
    pub train: &'a Dataset,
    pub test: &'a Dataset,
    /// held-out validation split for validation-gated averaging policies
    /// (the `val_examples` config knob); `None` = no split loaded
    pub val: Option<&'a Dataset>,
    pub augment: AugmentSpec,
    /// per-executable batch size (all artifacts share it)
    pub exec_batch: usize,
    /// training batches used to recompute BN statistics in phase 3
    pub bn_batches: usize,
    /// OS threads for real parallel execution (phase-2 workers, phase-1
    /// device shards). 1 = fully sequential; any value is bitwise
    /// reproducible (see `coordinator::parallel`).
    pub threads: usize,
    /// overlap batch assembly with backend compute (the input pipeline).
    /// Bitwise-free either way; governs the ClusterClock's data accounting
    /// (hidden behind compute vs exposed on the critical path).
    pub prefetch: bool,
}

impl<'a> TrainEnv<'a> {
    pub fn image_size(&self) -> usize {
        self.train.image_size
    }

    pub fn sgd_config(&self) -> SgdConfig {
        let m = self.engine.manifest();
        SgdConfig {
            momentum: m.model.momentum,
            weight_decay: m.model.weight_decay,
        }
    }

    /// Full-test-set evaluation with the given BN statistics.
    /// Adds modeled time to `clock.eval` (not training time).
    pub fn evaluate(
        &self,
        params: &ParamSet,
        bn: &BnState,
        clock: &mut ClusterClock,
    ) -> Result<BatchStats> {
        self.evaluate_on(self.test, params, bn, clock, usize::MAX)
    }

    /// Evaluate on an arbitrary dataset (landscape grids measure *train*
    /// error too), over at most `max_batches` leading batches. Runs on the
    /// same prefetch pipeline as training: batch k is a pure function of
    /// k, so assembly of batch k+1 overlaps the backend's eval of batch k.
    pub fn evaluate_on(
        &self,
        ds: &Dataset,
        params: &ParamSet,
        bn: &BnState,
        clock: &mut ClusterClock,
        max_batches: usize,
    ) -> Result<BatchStats> {
        self.eval_impl(ds, params, bn, clock, max_batches, true)
    }

    /// [`TrainEnv::evaluate_on`] for callers that only want accuracy: the
    /// backend may skip the cross-entropy/loss tail
    /// ([`Backend::eval_batch_top1`]), so the returned `sum_loss` is not
    /// meaningful. Accuracy counts are contractually identical to
    /// `evaluate_on`'s.
    pub fn evaluate_acc_on(
        &self,
        ds: &Dataset,
        params: &ParamSet,
        bn: &BnState,
        clock: &mut ClusterClock,
        max_batches: usize,
    ) -> Result<BatchStats> {
        self.eval_impl(ds, params, bn, clock, max_batches, false)
    }

    #[allow(clippy::too_many_arguments)]
    fn eval_impl(
        &self,
        ds: &Dataset,
        params: &ParamSet,
        bn: &BnState,
        clock: &mut ClusterClock,
        max_batches: usize,
        with_loss: bool,
    ) -> Result<BatchStats> {
        let b = self.exec_batch;
        let batcher = Batcher::new(b, self.image_size(), AugmentSpec::none());
        // sequential_batches yields the ragged final batch, so a full pass
        // scores ALL ds.n examples (examples == ds.n), not floor(n/b)*b —
        // except on AOT backends, whose per-batch executables can only run
        // whole batches (the tail is dropped there, as it always was)
        let ragged_ok = self.engine.supports_ragged_batch();
        let idx_lists: Vec<Vec<usize>> = sequential_batches(ds.n, b)
            .take(max_batches)
            .take_while(|idx| ragged_ok || idx.len() == b)
            .collect();
        let steps = idx_lists.len();
        let overlap = self.spawn_prefetch();
        let slots = prefetch::make_slots(overlap, || batcher.make_batch());
        let produce = move |k: usize, out: &mut HostBatch| {
            batcher.assemble_clean_into(ds, &idx_lists[k], out);
        };
        let mut total = BatchStats::default();
        prefetch::run_pipeline(steps, slots, overlap, produce, |_, hb: &mut HostBatch| {
            let stats = if with_loss {
                self.engine.eval_batch(params.as_slice(), bn.as_slice(), hb)?
            } else {
                self.engine.eval_batch_top1(params.as_slice(), bn.as_slice(), hb)?
            };
            total.accumulate(&stats);
            clock.note_eval(self.cost.eval_step_time(hb.batch));
            Ok(true)
        })?;
        if total.examples == 0 {
            return Err(Error::invalid(
                "evaluate: no runnable batch (dataset empty, or smaller than \
                 one batch on a backend without ragged-batch support)",
            ));
        }
        Ok(total)
    }

    /// Recompute BN running statistics from `self.bn_batches` training
    /// batches (Algorithm 1, line 28). Deterministic batch choice per seed.
    /// Counts as *training* time when `charge_clock` (phase 3 does; the
    /// reporting-only per-worker evals don't).
    pub fn recompute_bn(
        &self,
        params: &ParamSet,
        seed: u64,
        clock: &mut ClusterClock,
        charge_clock: bool,
    ) -> Result<BnState> {
        if self.train.n == 0 {
            // the wrap-around fill below can never grow on an empty
            // dataset — error out instead of spinning forever
            return Err(Error::invalid("recompute_bn: training dataset is empty"));
        }
        let b = self.exec_batch;
        let mut rng = Rng::stream(seed, 0xB7);
        let batcher = Batcher::new(b, self.image_size(), AugmentSpec::none());
        let mut moments: Vec<Vec<f32>> = Vec::with_capacity(self.bn_batches);
        let mut order = rng.permutation(self.train.n);
        if order.len() < b * self.bn_batches {
            // small datasets: wrap around
            while order.len() < b * self.bn_batches {
                let extra = rng.permutation(self.train.n);
                order.extend(extra);
            }
        }
        // batch k is a pure function of k (the order is fixed up front),
        // so BN recomputation rides the same prefetch pipeline
        let train = self.train;
        let overlap = self.spawn_prefetch();
        let slots = prefetch::make_slots(overlap, || batcher.make_batch());
        let produce = move |k: usize, out: &mut HostBatch| {
            batcher.assemble_clean_into(train, &order[k * b..(k + 1) * b], out);
        };
        prefetch::run_pipeline(self.bn_batches, slots, overlap, produce, |_, hb| {
            moments.push(self.engine.bn_moments(params.as_slice(), hb)?);
            let dt = self.cost.eval_step_time(b);
            if charge_clock {
                clock.advance_compute(dt);
            } else {
                clock.note_eval(dt);
            }
            Ok(true)
        })?;
        BnState::from_moments(ParamLayout::of_bn(self.engine.manifest()), &moments)
    }

    /// Top-1 accuracy of `params` on the held-out validation split, or
    /// `None` when no split is loaded. BN is recomputed uncharged and the
    /// forward passes are booked as eval time — validation scoring guides
    /// the averaging policy, it is not training compute.
    pub fn val_acc(
        &self,
        params: &ParamSet,
        seed: u64,
        clock: &mut ClusterClock,
    ) -> Result<Option<f64>> {
        let Some(val) = self.val else { return Ok(None) };
        let bn = self.recompute_bn(params, seed, clock, false)?;
        // accuracy-only: the loss tail is skipped on backends that can
        let stats = self.evaluate_acc_on(val, params, &bn, clock, usize::MAX)?;
        Ok(Some(stats.accuracy1()))
    }

    /// Convenience: recompute BN (uncharged) then evaluate.
    pub fn bn_and_eval(
        &self,
        params: &ParamSet,
        seed: u64,
        clock: &mut ClusterClock,
    ) -> Result<BatchStats> {
        let bn = self.recompute_bn(params, seed, clock, false)?;
        self.evaluate(params, &bn, clock)
    }

    /// Whether a run may actually spawn the prefetch producer thread:
    /// requested, with a thread budget, and not already inside a coarser
    /// fan-out (phase-2 workers own the cores). Never affects results or
    /// the modeled clock — only wall time.
    pub(crate) fn spawn_prefetch(&self) -> bool {
        self.prefetch && self.threads > 1 && !parallel::in_parallel_region()
    }
}

/// Configuration of one synchronous data-parallel training segment.
#[derive(Debug, Clone)]
pub struct SyncTrainConfig {
    /// number of data-parallel devices (1 = single-device fused path)
    pub devices: usize,
    /// global batch size (must be devices * exec_batch)
    pub global_batch: usize,
    /// hard stop after this many epochs
    pub max_epochs: usize,
    /// early stop once the epoch's training accuracy reaches this (1.0 = off)
    pub stop_train_acc: f64,
    pub sched: Schedule,
    /// schedule step offset (composing phases)
    pub sched_offset: usize,
    /// RNG stream id for sampling/augmentation (worker identity)
    pub seed_stream: u64,
    pub seed: u64,
}

/// Outcome of a sync segment.
#[derive(Debug, Clone, Copy)]
pub struct TrainProgress {
    pub steps: usize,
    pub epochs: f64,
    /// training accuracy over the last completed epoch
    pub train_acc: f64,
    pub train_loss: f64,
}

/// Run synchronous SGD: `devices` workers each compute gradients on a
/// `global_batch / devices` shard, gradients are ring-averaged, and the
/// host applies the Nesterov update (phase 1 of Algorithm 1). With
/// `devices == 1` the fused on-device train step is used instead (the
/// phase-2 / small-batch path).
///
/// `observer` is called after every optimizer step with (global step index,
/// params) — the hook the figure benches use.
pub fn run_sync_training(
    env: &TrainEnv,
    params: &mut ParamSet,
    momentum: &mut ParamSet,
    cfg: &SyncTrainConfig,
    clock: &mut ClusterClock,
    mut observer: impl FnMut(usize, &ParamSet, &BatchStats),
) -> Result<TrainProgress> {
    if cfg.global_batch != cfg.devices * env.exec_batch {
        return Err(Error::config(format!(
            "global batch {} != devices {} x exec batch {}",
            cfg.global_batch, cfg.devices, env.exec_batch
        )));
    }
    if cfg.global_batch > env.train.n {
        return Err(Error::config("global batch larger than the dataset"));
    }
    let sgd = env.sgd_config();
    // zero-copy ownership handoff of the momentum arena for the segment
    // (flat::sgd_step gates its own fan-out on the arena size)
    let mut opt = SgdOptimizer { cfg: sgd, momentum: momentum.take() };
    let mut sampler = EpochSampler::new(env.train.n, cfg.global_batch, cfg.seed, cfg.seed_stream);
    let mut batcher = Batcher::new(env.exec_batch, env.image_size(), env.augment);
    // stateless counter-keyed augmentation: global row r of step t draws
    // from Rng::counter(seed ^ 0xAE6, stream, t, r) — a pure function, so
    // the producer thread (or any shard order) reproduces the serial
    // assembly bit for bit
    let aug = AugStream { seed: cfg.seed ^ 0xAE6, stream: cfg.seed_stream };
    let devices = cfg.devices;
    let train = env.train;

    let steps_per_epoch = sampler.batches_per_epoch();
    let total_steps = cfg.max_epochs * steps_per_epoch;
    let mut epoch_stats = BatchStats::default();
    let mut last_epoch_acc = 0.0;
    let mut last_epoch_loss = f64::INFINITY;
    let mut steps = 0usize;

    let step_compute = env.cost.train_step_time(env.exec_batch);
    let ar_time = env.cost.allreduce_time(cfg.devices);
    let data_time = env.cost.assembly_time(cfg.global_batch);
    // assembly of step t+1 can hide behind the whole device-side step t
    let step_budget = step_compute + if devices > 1 { ar_time } else { 0.0 };
    // fan the per-step shard gradients out only when one gradient is worth
    // more than a thread spawn (fwd+bwd ~ 3x fwd FLOPs per example)
    let grad_work = 3 * env.engine.manifest().flops_fwd_per_example as usize * env.exec_batch;
    let shard_threads = parallel::gate(env.threads, grad_work);

    // double-buffer per-device HostBatches when the producer thread may
    // run; a single slot otherwise (assemble-then-compute, same bits)
    let overlap = env.spawn_prefetch();
    let slots: Vec<Vec<HostBatch>> =
        prefetch::make_slots(overlap, || (0..devices).map(|_| batcher.make_batch()).collect());

    // the producer: a pure function of the step index (sampler order is
    // deterministic, augmentation is counter-keyed)
    let produce = move |step: usize, out: &mut Vec<HostBatch>| {
        let global = sampler.next_batch();
        if devices == 1 {
            batcher.assemble_step_into(train, global, aug, step as u64, 0, &mut out[0]);
        } else {
            let per = global.len() / devices;
            for (d, hb) in out.iter_mut().enumerate() {
                let rows = &global[d * per..(d + 1) * per];
                batcher.assemble_step_into(train, rows, aug, step as u64, (d * per) as u64, hb);
            }
        }
    };

    // the consumer: the device-side step + bookkeeping (main thread)
    let consume = |step: usize, batches: &mut Vec<HostBatch>| -> Result<bool> {
        let lr = cfg.sched.lr(cfg.sched_offset + step);
        let stats = if devices == 1 {
            env.engine.train_step(
                params.as_mut_slice(),
                opt.momentum.as_mut_slice(),
                &batches[0],
                lr,
            )?
        } else {
            // per-device gradients are pure functions of (params, batch):
            // compute them on real OS threads, then reduce in device order
            let results = parallel::parallel_map(
                shard_threads,
                batches.iter().collect(),
                |_, hb| env.engine.grad(params.as_slice(), hb),
            );
            let mut worker_grads: Vec<Vec<f32>> = Vec::with_capacity(devices);
            let mut stats = BatchStats::default();
            for g in results {
                let g = g?;
                stats.accumulate(&g.stats);
                worker_grads.push(g.grads);
            }
            // in-place ring: after this, worker_grads[0] is the mean arena
            allreduce::ring_mean_inplace(&mut worker_grads)?;
            opt.step_mt(params, &worker_grads[0], lr, env.threads)?;
            stats
        };
        // cluster time: all devices compute in parallel, then sync; input
        // assembly hides behind the step when the pipeline overlaps
        clock.advance_compute(step_compute);
        if devices > 1 {
            clock.advance_comm(ar_time);
        }
        clock.note_data(data_time, step_budget, env.prefetch);
        epoch_stats.accumulate(&stats);
        steps += 1;
        observer(cfg.sched_offset + steps - 1, params, &stats);

        if steps % steps_per_epoch == 0 {
            last_epoch_acc = epoch_stats.accuracy1();
            last_epoch_loss = epoch_stats.mean_loss();
            crate::debug!(
                "epoch {} train acc {:.4} loss {:.4}",
                steps / steps_per_epoch,
                last_epoch_acc,
                last_epoch_loss
            );
            epoch_stats = BatchStats::default();
            if last_epoch_acc >= cfg.stop_train_acc {
                return Ok(false);
            }
        }
        Ok(true)
    };

    prefetch::run_pipeline(total_steps, slots, overlap, produce, consume)?;

    *momentum = opt.momentum;
    Ok(TrainProgress {
        steps,
        epochs: steps as f64 / steps_per_epoch as f64,
        train_acc: last_epoch_acc,
        train_loss: last_epoch_loss,
    })
}
