//! Sequential Stochastic Weight Averaging (Izmailov et al. 2018) — the
//! baseline SWAP is compared against in §5.3 / Table 4.
//!
//! A cyclic (sawtooth) learning-rate schedule runs for `cycles` cycles of
//! `cycle_epochs` each on ONE model; a weight sample is taken at the end of
//! every cycle (the low-LR point); the samples are averaged and BN is
//! recomputed. Unlike SWAP the samples are sequential, so the cluster time
//! is the *sum* of all cycles (on the devices used), not the max.

use super::trainer::{run_sync_training, SyncTrainConfig, TrainEnv};
use crate::model::{BnState, ParamSet};
use crate::optim::Schedule;
use crate::runtime::BatchStats;
use crate::sim::ClusterClock;
use crate::util::Result;

#[derive(Debug, Clone)]
pub struct SwaConfig {
    /// data-parallel devices for the cyclic run (large-batch SWA uses many,
    /// small-batch SWA uses 1)
    pub devices: usize,
    pub cycles: usize,
    pub cycle_epochs: usize,
    pub high_lr: f32,
    pub low_lr: f32,
    pub seed: u64,
    pub seed_stream: u64,
}

pub struct SwaResult {
    /// the sampled models (one per cycle)
    pub samples: Vec<ParamSet>,
    /// last iterate before averaging and its test stats
    pub last_stats: BatchStats,
    pub averaged: ParamSet,
    pub final_bn: BnState,
    pub final_stats: BatchStats,
    pub clock: ClusterClock,
    pub wall_seconds: f64,
}

/// Run SWA starting from `params` (continues in place).
pub fn run_swa(
    env: &TrainEnv,
    params: &mut ParamSet,
    cfg: &SwaConfig,
    clock: &mut ClusterClock,
) -> Result<SwaResult> {
    let wall0 = std::time::Instant::now();
    let mut momentum = params.zeros_like();
    let mut samples = Vec::with_capacity(cfg.cycles);

    let steps_per_epoch = env.train.n / (cfg.devices * env.exec_batch);
    let period = cfg.cycle_epochs * steps_per_epoch;
    let sched = Schedule::Cyclic {
        high: cfg.high_lr,
        low: cfg.low_lr,
        period: period.max(1),
    };

    for _cycle in 0..cfg.cycles {
        run_sync_training(
            env,
            params,
            &mut momentum,
            &SyncTrainConfig {
                devices: cfg.devices,
                global_batch: cfg.devices * env.exec_batch,
                max_epochs: cfg.cycle_epochs,
                stop_train_acc: 1.1,
                sched: sched.clone(),
                sched_offset: 0, // each cycle restarts the sawtooth
                seed_stream: cfg.seed_stream,
                seed: cfg.seed,
            },
            clock,
            |_, _, _| {},
        )?;
        samples.push(params.clone());
    }

    // reporting-only: the last SGD iterate before averaging
    let last_stats = env.bn_and_eval(params, cfg.seed, clock)?;

    // average + BN recompute (charged, as in SWAP phase 3) — streaming
    // flat-arena mean, no per-sample clones
    let averaged = ParamSet::average_mt(&samples, env.threads)?;
    let final_bn = env.recompute_bn(&averaged, cfg.seed, clock, true)?;
    let final_stats = env.evaluate(&averaged, &final_bn, clock)?;

    Ok(SwaResult {
        samples,
        last_stats,
        averaged,
        final_bn,
        final_stats,
        clock: *clock,
        wall_seconds: wall0.elapsed().as_secs_f64(),
    })
}
