//! Sequential Stochastic Weight Averaging (Izmailov et al. 2018) — the
//! baseline SWAP is compared against in §5.3 / Table 4.
//!
//! A cyclic (sawtooth) learning-rate schedule runs for `cycles` cycles of
//! `cycle_epochs` each on ONE model; a weight sample is taken at the end of
//! every cycle (the low-LR point) and streamed into the configured
//! [`AveragingPolicy`]; the running average is finalized and BN is
//! recomputed. Unlike SWAP the samples are sequential, so the cluster time
//! is the *sum* of all cycles (on the devices used), not the max.
//!
//! Memory: samples stream into the policy as they are produced — nothing
//! retains O(cycles x W) clones (pinned by rust/tests/alloc_regression.rs).
//! The full per-cycle trail is opt-in via `keep_samples` for the analysis
//! figures that genuinely need every point.

use super::averaging::{maybe_val_acc, AveragingSpec, Candidate, CandidateKind};
use super::trainer::{run_sync_training, SyncTrainConfig, TrainEnv};
use crate::data::EpochSampler;
use crate::model::{BnState, ParamSet};
use crate::optim::Schedule;
use crate::runtime::BatchStats;
use crate::sim::ClusterClock;
use crate::util::{Error, Json, Result};

#[derive(Debug, Clone)]
pub struct SwaConfig {
    /// data-parallel devices for the cyclic run (large-batch SWA uses many,
    /// small-batch SWA uses 1)
    pub devices: usize,
    pub cycles: usize,
    pub cycle_epochs: usize,
    pub high_lr: f32,
    pub low_lr: f32,
    pub seed: u64,
    pub seed_stream: u64,
    /// how the end-of-cycle samples are combined (default Uniform — the
    /// historical terminal mean, bitwise-pinned)
    pub averaging: AveragingSpec,
    /// retain a clone of every end-of-cycle sample in `SwaResult::samples`
    /// (figure instrumentation only; the averaging itself streams)
    pub keep_samples: bool,
}

pub struct SwaResult {
    /// the sampled models (one per cycle) — empty unless
    /// `SwaConfig::keep_samples` was set
    pub samples: Vec<ParamSet>,
    /// last iterate before averaging and its test stats
    pub last_stats: BatchStats,
    pub averaged: ParamSet,
    pub final_bn: BnState,
    pub final_stats: BatchStats,
    /// the averaging policy's final scalar state (diagnostics / persistence)
    pub averaging_state: Json,
    pub clock: ClusterClock,
    pub wall_seconds: f64,
}

/// Run SWA starting from `params` (continues in place).
pub fn run_swa(
    env: &TrainEnv,
    params: &mut ParamSet,
    cfg: &SwaConfig,
    clock: &mut ClusterClock,
) -> Result<SwaResult> {
    let wall0 = std::time::Instant::now();
    let mut momentum = params.zeros_like();
    let mut samples = Vec::with_capacity(if cfg.keep_samples { cfg.cycles } else { 0 });
    let mut policy = cfg.averaging.build();

    // the cyclic period and the trainer's step count MUST come from the
    // same definition (EpochSampler::steps_per_epoch), or on a
    // non-divisible n the sawtooth's low-LR point drifts off the true
    // end-of-cycle sample (the hard check below pins the alignment)
    let global_batch = cfg.devices * env.exec_batch;
    let steps_per_epoch = EpochSampler::steps_per_epoch(env.train.n, global_batch);
    let period = cfg.cycle_epochs * steps_per_epoch;
    let sched = Schedule::Cyclic {
        high: cfg.high_lr,
        low: cfg.low_lr,
        period: period.max(1),
    };

    for cycle in 0..cfg.cycles {
        let prog = run_sync_training(
            env,
            params,
            &mut momentum,
            &SyncTrainConfig {
                devices: cfg.devices,
                global_batch,
                max_epochs: cfg.cycle_epochs,
                stop_train_acc: 1.1,
                sched: sched.clone(),
                sched_offset: 0, // each cycle restarts the sawtooth
                seed_stream: cfg.seed_stream,
                seed: cfg.seed,
            },
            clock,
            |_, _, _| {},
        )?;
        if prog.steps != period {
            return Err(Error::invalid(format!(
                "swa: cycle {cycle} ran {} steps but the cyclic schedule \
                 period is {period} ({} epochs x {steps_per_epoch} steps/epoch \
                 on n={} batch={global_batch}) — the end-of-cycle sample \
                 would drift off the low-LR point",
                prog.steps, cfg.cycle_epochs, env.train.n
            )));
        }
        let val_acc = maybe_val_acc(policy.as_ref(), env, params, cfg.seed, clock)?;
        policy.observe(
            params,
            Candidate { kind: CandidateKind::CycleEnd(cycle), val_acc },
            env.threads,
        )?;
        if cfg.keep_samples {
            samples.push(params.clone());
        }
    }

    // reporting-only: the last SGD iterate before averaging
    let last_stats = env.bn_and_eval(params, cfg.seed, clock)?;

    // finalize the streamed average + BN recompute (charged, as in SWAP
    // phase 3)
    let averaged = policy.average(env.threads)?;
    let averaging_state = policy.state();
    let final_bn = env.recompute_bn(&averaged, cfg.seed, clock, true)?;
    let final_stats = env.evaluate(&averaged, &final_bn, clock)?;

    Ok(SwaResult {
        samples,
        last_stats,
        averaged,
        final_bn,
        final_stats,
        averaging_state,
        clock: *clock,
        wall_seconds: wall0.elapsed().as_secs_f64(),
    })
}
