//! The paper's comparison arms: pure small-batch and pure large-batch SGD
//! (Tables 1-3, rows 1-2). Both reuse the shared synchronous trainer; the
//! only differences are device count / global batch / schedule.

use super::trainer::{run_sync_training, SyncTrainConfig, TrainEnv, TrainProgress};
use crate::metrics::RunOutcome;
use crate::model::ParamSet;
use crate::optim::Schedule;
use crate::runtime::Backend;
use crate::sim::ClusterClock;
use crate::util::Result;

/// One plain SGD training arm.
#[derive(Debug, Clone)]
pub struct BaselineConfig {
    pub devices: usize,
    pub epochs: usize,
    pub sched: Schedule,
    /// early stop on training accuracy (1.0+ = train the full epochs)
    pub stop_train_acc: f64,
    pub seed: u64,
}

pub struct BaselineResult {
    pub outcome: RunOutcome,
    pub progress: TrainProgress,
    pub params: ParamSet,
    pub clock: ClusterClock,
}

/// Train one model with `devices`-way synchronous data parallelism
/// (devices=1 is the small-batch single-device arm), then evaluate with
/// freshly recomputed BN statistics.
pub fn run_baseline(env: &TrainEnv, cfg: &BaselineConfig) -> Result<BaselineResult> {
    let wall0 = std::time::Instant::now();
    let mut clock = ClusterClock::new();
    let mut params = ParamSet::init(env.engine.manifest(), cfg.seed);
    let mut momentum = params.zeros_like();
    let progress = run_sync_training(
        env,
        &mut params,
        &mut momentum,
        &SyncTrainConfig {
            devices: cfg.devices,
            global_batch: cfg.devices * env.exec_batch,
            max_epochs: cfg.epochs,
            stop_train_acc: cfg.stop_train_acc,
            sched: cfg.sched.clone(),
            sched_offset: 0,
            seed_stream: 0,
            seed: cfg.seed,
        },
        &mut clock,
        |_, _, _| {},
    )?;
    // Reporting-only BN recompute + eval (running-stat maintenance is free
    // in a standard training loop, so it is not charged as training time).
    let stats = env.bn_and_eval(&params, cfg.seed, &mut clock)?;
    let outcome = RunOutcome {
        test_acc1: stats.accuracy1(),
        test_acc5: stats.accuracy5(),
        test_loss: stats.mean_loss(),
        cluster_seconds: clock.seconds,
        wall_seconds: wall0.elapsed().as_secs_f64(),
    };
    Ok(BaselineResult { outcome, progress, params, clock })
}
