//! Pure-Rust kernels for the native backend: 3x3 convolution lowering
//! (im2col/col2im), the matmul family, batch-norm train/eval (+ backward),
//! max pooling, softmax cross-entropy and the Nesterov-SGD update.
//!
//! Each kernel is the host twin of a python reference oracle in
//! `python/compile/kernels/ref.py` / `python/compile/model.py`;
//! `rust/tests/kernel_parity.rs` pins them against checked-in JSON fixtures
//! generated from those oracles (tolerance 1e-4).
//!
//! Activations are flat NHWC `Vec<f32>` viewed as row-major (B*H*W, C)
//! matrices, so convolution is `im2col` + one matmul — the same lowering
//! the Pallas/MXU path uses.
//!
//! ## Two matmul tiers
//!
//! The production matmul family lives in [`super::gemm`]: cache-blocked,
//! register-tiled, panel-packed, branch-free. The original branchy scalar
//! ikj loops are kept here as `*_reference` oracles; on finite inputs the
//! two tiers are **bitwise identical** (same per-element ascending-k
//! accumulation chain — `rust/tests/gemm_oracle.rs` pins this over random
//! and model-emitted shapes). The allocating `matmul`/`matmul_tn`/
//! `matmul_nt` wrappers below route to the blocked tier; hot paths call
//! the `gemm::*_into` entry points with workspace-owned buffers instead.
//!
//! The heavy kernels take a `threads` argument and split their *output
//! rows* across scoped worker threads (`coordinator::parallel`). Every
//! output element is produced by exactly one thread with the sequential
//! accumulation order, so results are bitwise identical for any
//! `threads`; the spawn gate is per-chunk — a thread is only spawned if
//! its own share of the work exceeds `PAR_MIN_WORK`, so tiny kernels (the
//! 8c -> classes head) never fan out.
//!
//! Most kernels come in two forms: an `*_into` variant writing into
//! caller-owned buffers (what the zero-allocation model hot path uses)
//! and an allocating convenience wrapper for tests and benches.

use super::gemm;
use crate::coordinator::parallel::{
    gate_per_chunk, parallel_row_chunks, parallel_row_chunks2,
};
use crate::util::simd::{self, Tier};

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::{
    _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps, _mm256_sub_ps,
};

#[cfg(target_arch = "aarch64")]
use std::arch::aarch64::{vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32, vsubq_f32};

pub const BN_EPS: f32 = 1e-5;

/// Minimum per-worker work (inner-loop ops) for the spawn gate: a worker
/// thread is only worth spawning if its chunk exceeds this. Tuned loosely
/// — the result never depends on it, only the wall time.
const PAR_MIN_WORK: usize = 1 << 18;

/// Effective worker count for a kernel invocation of `work` inner ops:
/// enough workers that each gets at least `PAR_MIN_WORK`, capped at the
/// thread budget.
fn par(threads: usize, work: usize) -> usize {
    gate_per_chunk(threads, work, PAR_MIN_WORK)
}

// ---------------------------------------------------------------------------
// matmul family (f32, accumulate in f32; per-element adds in the same order
// on every path so any thread count — and either tier — is bitwise
// reproducible)
// ---------------------------------------------------------------------------

/// out(m,n) = a(m,k) @ b(k,n) via the blocked GEMM tier (allocating
/// convenience wrapper; hot paths use `gemm::matmul_into`).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    let mut scratch = gemm::GemmScratch::default();
    gemm::matmul_into(&mut out, a, b, m, k, n, threads, &mut scratch);
    out
}

/// out(m,n) = aᵀ @ b where a is (r,m) and b is (r,n) — the dW matmul,
/// blocked tier.
pub fn matmul_tn(a: &[f32], b: &[f32], r: usize, m: usize, n: usize, threads: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    let mut scratch = gemm::GemmScratch::default();
    gemm::matmul_tn_into(&mut out, a, b, r, m, n, threads, &mut scratch);
    out
}

/// out(m,n) = a @ bᵀ where a is (m,k) and b is (n,k) — the dX matmul,
/// blocked tier.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    let mut scratch = gemm::GemmScratch::default();
    gemm::matmul_nt_into(&mut out, a, b, m, k, n, threads, &mut scratch);
    out
}

/// Reference oracle: the original branchy scalar ikj matmul (with the
/// historical `av == 0.0` skip, which only diverges from the blocked
/// tier on NaN/Inf inputs).
pub fn matmul_reference(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    parallel_row_chunks(par(threads, m * k * n), &mut out, n, |row0, chunk| {
        for (li, orow) in chunk.chunks_mut(n).enumerate() {
            let i = row0 + li;
            let arow = &a[i * k..(i + 1) * k];
            for (p, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    });
    out
}

/// Reference oracle for the dW matmul: out(m,n) = aᵀ @ b with a (r,m),
/// b (r,n). The reduction over `r` stays innermost-sequential per output
/// row (adds in ascending `row` order); only the output rows are
/// partitioned.
pub fn matmul_tn_reference(
    a: &[f32],
    b: &[f32],
    r: usize,
    m: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), r * m);
    debug_assert_eq!(b.len(), r * n);
    let mut out = vec![0.0f32; m * n];
    parallel_row_chunks(par(threads, r * m * n), &mut out, n, |row0, chunk| {
        let cm = chunk.len() / n;
        for row in 0..r {
            let arow = &a[row * m + row0..row * m + row0 + cm];
            let brow = &b[row * n..(row + 1) * n];
            for (li, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let orow = &mut chunk[li * n..(li + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    });
    out
}

/// Reference oracle for the dX matmul: out(m,n) = a @ bᵀ with a (m,k),
/// b (n,k).
pub fn matmul_nt_reference(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    parallel_row_chunks(par(threads, m * k * n), &mut out, n, |row0, chunk| {
        for (li, orow) in chunk.chunks_mut(n).enumerate() {
            let i = row0 + li;
            let arow = &a[i * k..(i + 1) * k];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (&av, &bv) in arow.iter().zip(brow) {
                    acc += av * bv;
                }
                *o = acc;
            }
        }
    });
    out
}

// ---------------------------------------------------------------------------
// im2col / col2im for 3x3 SAME convolution (split across batch images —
// each image's patch rows / input gradients are disjoint). The forward
// and dW GEMMs never materialize the patch matrix (gemm::ASrc::Im2col
// packs panels straight from the image); im2col itself remains as the
// oracle definition of that virtual matrix, and col2im as the backward
// scatter of the (materialized) patch gradients.
// ---------------------------------------------------------------------------

/// (B,H,W,C) -> (B*H*W, 9*C) patches; patch channel order is (dy, dx, c)
/// row-major, matching the (9*Cin, Cout) conv weight layout of
/// `python/compile/model.py::im2col`.
pub fn im2col(x: &[f32], b: usize, h: usize, w: usize, c: usize, threads: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), b * h * w * c);
    let per_image = h * w * 9 * c;
    let mut out = vec![0.0f32; b * per_image];
    parallel_row_chunks(
        par(threads, b * per_image),
        &mut out,
        per_image,
        |img0, chunk| {
            for (li, dst) in chunk.chunks_mut(per_image).enumerate() {
                let bi = img0 + li;
                for y in 0..h {
                    for xx in 0..w {
                        let row = (y * w + xx) * 9 * c;
                        for dy in 0..3 {
                            let iy = y + dy;
                            if iy < 1 || iy > h {
                                continue; // zero padding row
                            }
                            let iy = iy - 1;
                            for dx in 0..3 {
                                let ix = xx + dx;
                                if ix < 1 || ix > w {
                                    continue; // zero padding col
                                }
                                let ix = ix - 1;
                                let src = ((bi * h + iy) * w + ix) * c;
                                let d = row + (dy * 3 + dx) * c;
                                dst[d..d + c].copy_from_slice(&x[src..src + c]);
                            }
                        }
                    }
                }
            }
        },
    );
    out
}

/// Adjoint of `im2col` into a caller buffer: scatter patch gradients
/// (B*H*W, 9*C) back onto the input image gradient (B,H,W,C). Patches
/// never cross image boundaries, so per-image partitioning scatters into
/// disjoint output regions.
pub fn col2im_into(
    dp: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    threads: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(dp.len(), b * h * w * 9 * c);
    debug_assert_eq!(dx.len(), b * h * w * c);
    let per_in = h * w * c;
    let per_patch = h * w * 9 * c;
    parallel_row_chunks(par(threads, b * per_patch), dx, per_in, |img0, chunk| {
        for (li, dimg) in chunk.chunks_mut(per_in).enumerate() {
            dimg.fill(0.0);
            let bi = img0 + li;
            for y in 0..h {
                for xx in 0..w {
                    let row = ((bi * h + y) * w + xx) * 9 * c;
                    for dy in 0..3 {
                        let iy = y + dy;
                        if iy < 1 || iy > h {
                            continue;
                        }
                        let iy = iy - 1;
                        for dx_off in 0..3 {
                            let ix = xx + dx_off;
                            if ix < 1 || ix > w {
                                continue;
                            }
                            let ix = ix - 1;
                            let dst = (iy * w + ix) * c;
                            let src = row + (dy * 3 + dx_off) * c;
                            for ci in 0..c {
                                dimg[dst + ci] += dp[src + ci];
                            }
                        }
                    }
                }
            }
        }
    });
}

/// Allocating wrapper over [`col2im_into`].
pub fn col2im(dp: &[f32], b: usize, h: usize, w: usize, c: usize, threads: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; b * h * w * c];
    col2im_into(dp, b, h, w, c, threads, &mut dx);
    dx
}

// ---------------------------------------------------------------------------
// batch norm (batch statistics in train mode; biased variance). The
// channel reductions (mean/var, dgamma/dbeta) stay sequential — they are
// O(rows*c) against the matmuls' O(rows*9c*cout) and a parallel reduction
// would reorder the f32 sums; the elementwise normalize loops are split
// across threads AND dispatch on the SIMD tier (`util::simd`): lanes hold
// channels, every op is a separately rounded sub/mul/add (never FMA), so
// each tier reproduces the scalar loop bitwise.
// ---------------------------------------------------------------------------

/// Forward with batch statistics over `rows` = B*H*W samples of `c`
/// channels, into caller buffers. `y` is pre-ReLU.
#[allow(clippy::too_many_arguments)]
pub fn bn_train_into(
    u: &[f32],
    gamma: &[f32],
    beta: &[f32],
    rows: usize,
    c: usize,
    threads: usize,
    y: &mut [f32],
    xhat: &mut [f32],
    mean: &mut [f32],
    var: &mut [f32],
    invstd: &mut [f32],
) {
    debug_assert_eq!(u.len(), rows * c);
    debug_assert_eq!(y.len(), rows * c);
    debug_assert_eq!(xhat.len(), rows * c);
    debug_assert_eq!(mean.len(), c);
    debug_assert_eq!(var.len(), c);
    debug_assert_eq!(invstd.len(), c);
    let inv_n = 1.0 / rows as f32;
    mean.fill(0.0);
    var.fill(0.0);
    for r in 0..rows {
        let urow = &u[r * c..(r + 1) * c];
        for (m, &v) in mean.iter_mut().zip(urow) {
            *m += v;
        }
    }
    for m in mean.iter_mut() {
        *m *= inv_n;
    }
    for r in 0..rows {
        let urow = &u[r * c..(r + 1) * c];
        for ((vv, &m), &v) in var.iter_mut().zip(mean.iter()).zip(urow) {
            let d = v - m;
            *vv += d * d;
        }
    }
    for vv in var.iter_mut() {
        *vv *= inv_n;
    }
    for (s, &v) in invstd.iter_mut().zip(var.iter()) {
        *s = 1.0 / (v + BN_EPS).sqrt();
    }
    let meanr: &[f32] = mean;
    let invstdr: &[f32] = invstd;
    let tier = simd::active();
    parallel_row_chunks2(
        par(threads, rows * c),
        xhat,
        y,
        c,
        c,
        |row0, cx, cy| {
            for (li, (xrow, yrow)) in cx.chunks_mut(c).zip(cy.chunks_mut(c)).enumerate() {
                let r = row0 + li;
                bn_norm_row(tier, &u[r * c..(r + 1) * c], meanr, invstdr, gamma, beta, xrow, yrow);
            }
        },
    );
}

/// Allocating wrapper over [`bn_train_into`]: returns
/// (y, xhat, mean, var, invstd).
#[allow(clippy::type_complexity)]
pub fn bn_train(
    u: &[f32],
    gamma: &[f32],
    beta: &[f32],
    rows: usize,
    c: usize,
    threads: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0f32; rows * c];
    let mut xhat = vec![0.0f32; rows * c];
    let mut mean = vec![0.0f32; c];
    let mut var = vec![0.0f32; c];
    let mut invstd = vec![0.0f32; c];
    bn_train_into(
        u, gamma, beta, rows, c, threads, &mut y, &mut xhat, &mut mean, &mut var, &mut invstd,
    );
    (y, xhat, mean, var, invstd)
}

/// Backward through train-mode batch norm, into caller buffers. `dy` is
/// the gradient w.r.t. the pre-ReLU output; fills (du, dgamma, dbeta).
/// `scale` is a c-length scratch for the per-channel factor.
#[allow(clippy::too_many_arguments)]
pub fn bn_train_bwd_into(
    dy: &[f32],
    xhat: &[f32],
    invstd: &[f32],
    gamma: &[f32],
    rows: usize,
    c: usize,
    threads: usize,
    du: &mut [f32],
    dgamma: &mut [f32],
    dbeta: &mut [f32],
    scale: &mut [f32],
) {
    debug_assert_eq!(dy.len(), rows * c);
    debug_assert_eq!(du.len(), rows * c);
    debug_assert_eq!(dgamma.len(), c);
    debug_assert_eq!(dbeta.len(), c);
    debug_assert_eq!(scale.len(), c);
    dgamma.fill(0.0);
    dbeta.fill(0.0);
    for r in 0..rows {
        for ci in 0..c {
            let i = r * c + ci;
            dgamma[ci] += dy[i] * xhat[i];
            dbeta[ci] += dy[i];
        }
    }
    let inv_n = 1.0 / rows as f32;
    // du = gamma * invstd / N * (N*dy - dbeta - xhat * dgamma)
    for ((s, &g), &is) in scale.iter_mut().zip(gamma).zip(invstd) {
        *s = g * is * inv_n;
    }
    let n = rows as f32;
    let scaler: &[f32] = scale;
    let dgammar: &[f32] = dgamma;
    let dbetar: &[f32] = dbeta;
    let tier = simd::active();
    parallel_row_chunks(par(threads, rows * c), du, c, |row0, chunk| {
        for (li, drow) in chunk.chunks_mut(c).enumerate() {
            let r = row0 + li;
            let (dyrow, xrow) = (&dy[r * c..(r + 1) * c], &xhat[r * c..(r + 1) * c]);
            bn_bwd_row(tier, dyrow, xrow, scaler, dgammar, dbetar, n, drow);
        }
    });
}

/// Allocating wrapper over [`bn_train_bwd_into`]: returns
/// (du, dgamma, dbeta).
pub fn bn_train_bwd(
    dy: &[f32],
    xhat: &[f32],
    invstd: &[f32],
    gamma: &[f32],
    rows: usize,
    c: usize,
    threads: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut du = vec![0.0f32; rows * c];
    let mut dgamma = vec![0.0f32; c];
    let mut dbeta = vec![0.0f32; c];
    let mut scale = vec![0.0f32; c];
    bn_train_bwd_into(
        dy, xhat, invstd, gamma, rows, c, threads, &mut du, &mut dgamma, &mut dbeta, &mut scale,
    );
    (du, dgamma, dbeta)
}

/// Forward with externally supplied running statistics (evaluation mode),
/// into a caller buffer. `scale` is a c-length scratch.
#[allow(clippy::too_many_arguments)]
pub fn bn_eval_into(
    u: &[f32],
    gamma: &[f32],
    beta: &[f32],
    mean: &[f32],
    var: &[f32],
    rows: usize,
    c: usize,
    threads: usize,
    y: &mut [f32],
    scale: &mut [f32],
) {
    debug_assert_eq!(u.len(), rows * c);
    debug_assert_eq!(y.len(), rows * c);
    debug_assert_eq!(scale.len(), c);
    for ((s, &g), &v) in scale.iter_mut().zip(gamma).zip(var) {
        *s = g / (v + BN_EPS).sqrt();
    }
    let scaler: &[f32] = scale;
    let tier = simd::active();
    parallel_row_chunks(par(threads, rows * c), y, c, |row0, chunk| {
        for (li, yrow) in chunk.chunks_mut(c).enumerate() {
            let r = row0 + li;
            bn_eval_row(tier, &u[r * c..(r + 1) * c], mean, scaler, beta, yrow);
        }
    });
}

/// Allocating wrapper over [`bn_eval_into`].
#[allow(clippy::too_many_arguments)]
pub fn bn_eval(
    u: &[f32],
    gamma: &[f32],
    beta: &[f32],
    mean: &[f32],
    var: &[f32],
    rows: usize,
    c: usize,
    threads: usize,
) -> Vec<f32> {
    let mut y = vec![0.0f32; rows * c];
    let mut scale = vec![0.0f32; c];
    bn_eval_into(u, gamma, beta, mean, var, rows, c, threads, &mut y, &mut scale);
    y
}

// ---------------------------------------------------------------------------
// bn per-row dispatch bodies. Lanes hold channels; the vector prefix
// returns how far it got and a scalar tail finishes the ragged remainder
// in channel order. Unavailable tiers fall through to the scalar loop.
// ---------------------------------------------------------------------------

/// xhat = (u - mean) * invstd;  y = gamma * xhat + beta — one row.
#[allow(clippy::too_many_arguments)]
fn bn_norm_row(
    tier: Tier,
    urow: &[f32],
    mean: &[f32],
    invstd: &[f32],
    gamma: &[f32],
    beta: &[f32],
    xrow: &mut [f32],
    yrow: &mut [f32],
) {
    let c = urow.len();
    let done = match tier {
        // SAFETY: gated on runtime avx2 detection; every slice is one
        // c-length row/param vector, so the lane loads stay in bounds.
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { bn_norm_avx2(urow, mean, invstd, gamma, beta, xrow, yrow) },
        // SAFETY: gated on runtime neon detection, same bounds contract.
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { bn_norm_neon(urow, mean, invstd, gamma, beta, xrow, yrow) },
        _ => 0,
    };
    for ci in done..c {
        let xh = (urow[ci] - mean[ci]) * invstd[ci];
        xrow[ci] = xh;
        yrow[ci] = gamma[ci] * xh + beta[ci];
    }
}

/// du = scale * ((n * dy - dbeta) - xhat * dgamma) — one row, the exact
/// scalar evaluation order.
#[allow(clippy::too_many_arguments)]
fn bn_bwd_row(
    tier: Tier,
    dyrow: &[f32],
    xrow: &[f32],
    scale: &[f32],
    dgamma: &[f32],
    dbeta: &[f32],
    n: f32,
    drow: &mut [f32],
) {
    let c = drow.len();
    let done = match tier {
        // SAFETY: gated on runtime avx2 detection; c-length rows as above.
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { bn_bwd_avx2(dyrow, xrow, scale, dgamma, dbeta, n, drow) },
        // SAFETY: gated on runtime neon detection, same bounds contract.
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { bn_bwd_neon(dyrow, xrow, scale, dgamma, dbeta, n, drow) },
        _ => 0,
    };
    for ci in done..c {
        drow[ci] = scale[ci] * (n * dyrow[ci] - dbeta[ci] - xrow[ci] * dgamma[ci]);
    }
}

/// y = (u - mean) * scale + beta — one eval-mode row.
fn bn_eval_row(
    tier: Tier,
    urow: &[f32],
    mean: &[f32],
    scale: &[f32],
    beta: &[f32],
    yrow: &mut [f32],
) {
    let c = urow.len();
    let done = match tier {
        // SAFETY: gated on runtime avx2 detection; c-length rows as above.
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { bn_eval_avx2(urow, mean, scale, beta, yrow) },
        // SAFETY: gated on runtime neon detection, same bounds contract.
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { bn_eval_neon(urow, mean, scale, beta, yrow) },
        _ => 0,
    };
    for ci in done..c {
        yrow[ci] = (urow[ci] - mean[ci]) * scale[ci] + beta[ci];
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn bn_norm_avx2(
    urow: &[f32],
    mean: &[f32],
    invstd: &[f32],
    gamma: &[f32],
    beta: &[f32],
    xrow: &mut [f32],
    yrow: &mut [f32],
) -> usize {
    let n8 = urow.len() & !7;
    let mut i = 0;
    while i < n8 {
        let u = _mm256_loadu_ps(urow.as_ptr().add(i));
        let m = _mm256_loadu_ps(mean.as_ptr().add(i));
        let s = _mm256_loadu_ps(invstd.as_ptr().add(i));
        let xh = _mm256_mul_ps(_mm256_sub_ps(u, m), s);
        _mm256_storeu_ps(xrow.as_mut_ptr().add(i), xh);
        let g = _mm256_loadu_ps(gamma.as_ptr().add(i));
        let b = _mm256_loadu_ps(beta.as_ptr().add(i));
        _mm256_storeu_ps(yrow.as_mut_ptr().add(i), _mm256_add_ps(_mm256_mul_ps(g, xh), b));
        i += 8;
    }
    n8
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn bn_bwd_avx2(
    dyrow: &[f32],
    xrow: &[f32],
    scale: &[f32],
    dgamma: &[f32],
    dbeta: &[f32],
    n: f32,
    drow: &mut [f32],
) -> usize {
    let n8 = drow.len() & !7;
    let nv = _mm256_set1_ps(n);
    let mut i = 0;
    while i < n8 {
        let dy = _mm256_loadu_ps(dyrow.as_ptr().add(i));
        let xh = _mm256_loadu_ps(xrow.as_ptr().add(i));
        let db = _mm256_loadu_ps(dbeta.as_ptr().add(i));
        let dg = _mm256_loadu_ps(dgamma.as_ptr().add(i));
        let sc = _mm256_loadu_ps(scale.as_ptr().add(i));
        let t = _mm256_sub_ps(_mm256_mul_ps(nv, dy), db);
        let t = _mm256_sub_ps(t, _mm256_mul_ps(xh, dg));
        _mm256_storeu_ps(drow.as_mut_ptr().add(i), _mm256_mul_ps(sc, t));
        i += 8;
    }
    n8
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn bn_eval_avx2(
    urow: &[f32],
    mean: &[f32],
    scale: &[f32],
    beta: &[f32],
    yrow: &mut [f32],
) -> usize {
    let n8 = urow.len() & !7;
    let mut i = 0;
    while i < n8 {
        let u = _mm256_loadu_ps(urow.as_ptr().add(i));
        let m = _mm256_loadu_ps(mean.as_ptr().add(i));
        let s = _mm256_loadu_ps(scale.as_ptr().add(i));
        let b = _mm256_loadu_ps(beta.as_ptr().add(i));
        let y = _mm256_add_ps(_mm256_mul_ps(_mm256_sub_ps(u, m), s), b);
        _mm256_storeu_ps(yrow.as_mut_ptr().add(i), y);
        i += 8;
    }
    n8
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn bn_norm_neon(
    urow: &[f32],
    mean: &[f32],
    invstd: &[f32],
    gamma: &[f32],
    beta: &[f32],
    xrow: &mut [f32],
    yrow: &mut [f32],
) -> usize {
    let n4 = urow.len() & !3;
    let mut i = 0;
    while i < n4 {
        let u = vld1q_f32(urow.as_ptr().add(i));
        let m = vld1q_f32(mean.as_ptr().add(i));
        let s = vld1q_f32(invstd.as_ptr().add(i));
        let xh = vmulq_f32(vsubq_f32(u, m), s);
        vst1q_f32(xrow.as_mut_ptr().add(i), xh);
        let g = vld1q_f32(gamma.as_ptr().add(i));
        let b = vld1q_f32(beta.as_ptr().add(i));
        vst1q_f32(yrow.as_mut_ptr().add(i), vaddq_f32(vmulq_f32(g, xh), b));
        i += 4;
    }
    n4
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn bn_bwd_neon(
    dyrow: &[f32],
    xrow: &[f32],
    scale: &[f32],
    dgamma: &[f32],
    dbeta: &[f32],
    n: f32,
    drow: &mut [f32],
) -> usize {
    let n4 = drow.len() & !3;
    let nv = vdupq_n_f32(n);
    let mut i = 0;
    while i < n4 {
        let dy = vld1q_f32(dyrow.as_ptr().add(i));
        let xh = vld1q_f32(xrow.as_ptr().add(i));
        let db = vld1q_f32(dbeta.as_ptr().add(i));
        let dg = vld1q_f32(dgamma.as_ptr().add(i));
        let sc = vld1q_f32(scale.as_ptr().add(i));
        let t = vsubq_f32(vmulq_f32(nv, dy), db);
        let t = vsubq_f32(t, vmulq_f32(xh, dg));
        vst1q_f32(drow.as_mut_ptr().add(i), vmulq_f32(sc, t));
        i += 4;
    }
    n4
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn bn_eval_neon(
    urow: &[f32],
    mean: &[f32],
    scale: &[f32],
    beta: &[f32],
    yrow: &mut [f32],
) -> usize {
    let n4 = urow.len() & !3;
    let mut i = 0;
    while i < n4 {
        let u = vld1q_f32(urow.as_ptr().add(i));
        let m = vld1q_f32(mean.as_ptr().add(i));
        let s = vld1q_f32(scale.as_ptr().add(i));
        let b = vld1q_f32(beta.as_ptr().add(i));
        let y = vaddq_f32(vmulq_f32(vsubq_f32(u, m), s), b);
        vst1q_f32(yrow.as_mut_ptr().add(i), y);
        i += 4;
    }
    n4
}

// ---------------------------------------------------------------------------
// ReLU
// ---------------------------------------------------------------------------

/// a = max(y, 0) into a caller buffer (y is kept for the backward mask).
pub fn relu_into(y: &[f32], a: &mut [f32]) {
    debug_assert_eq!(y.len(), a.len());
    for (o, &v) in a.iter_mut().zip(y) {
        *o = v.max(0.0);
    }
}

/// Allocating wrapper over [`relu_into`].
pub fn relu(y: &[f32]) -> Vec<f32> {
    let mut a = vec![0.0f32; y.len()];
    relu_into(y, &mut a);
    a
}

/// dy = da * [y > 0] into a caller buffer.
pub fn relu_bwd_into(da: &[f32], y: &[f32], dy: &mut [f32]) {
    debug_assert_eq!(da.len(), y.len());
    debug_assert_eq!(da.len(), dy.len());
    for ((o, &d), &v) in dy.iter_mut().zip(da).zip(y) {
        *o = if v > 0.0 { d } else { 0.0 };
    }
}

/// Allocating wrapper over [`relu_bwd_into`].
pub fn relu_bwd(da: &[f32], y: &[f32]) -> Vec<f32> {
    let mut dy = vec![0.0f32; da.len()];
    relu_bwd_into(da, y, &mut dy);
    dy
}

// ---------------------------------------------------------------------------
// max pooling
// ---------------------------------------------------------------------------

/// 2x2/stride-2 max pool of (B,H,W,C) into caller buffers: the pooled
/// activations and the flat input index of each window's max (first max
/// wins on ties).
pub fn maxpool2_into(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    y: &mut [f32],
    idx: &mut [u32],
) {
    debug_assert_eq!(x.len(), b * h * w * c);
    let (ho, wo) = (h / 2, w / 2);
    debug_assert_eq!(y.len(), b * ho * wo * c);
    debug_assert_eq!(idx.len(), b * ho * wo * c);
    for bi in 0..b {
        for py in 0..ho {
            for px in 0..wo {
                for ci in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_i = 0usize;
                    for oy in 0..2 {
                        for ox in 0..2 {
                            let i = ((bi * h + 2 * py + oy) * w + 2 * px + ox) * c + ci;
                            if x[i] > best {
                                best = x[i];
                                best_i = i;
                            }
                        }
                    }
                    let o = ((bi * ho + py) * wo + px) * c + ci;
                    y[o] = best;
                    idx[o] = best_i as u32;
                }
            }
        }
    }
}

/// Allocating wrapper over [`maxpool2_into`].
pub fn maxpool2(x: &[f32], b: usize, h: usize, w: usize, c: usize) -> (Vec<f32>, Vec<u32>) {
    let (ho, wo) = (h / 2, w / 2);
    let mut y = vec![0.0f32; b * ho * wo * c];
    let mut idx = vec![0u32; b * ho * wo * c];
    maxpool2_into(x, b, h, w, c, &mut y, &mut idx);
    (y, idx)
}

/// Route pooled gradients back to the argmax positions (zeroes `dx`
/// first).
pub fn maxpool2_bwd_into(dy: &[f32], idx: &[u32], dx: &mut [f32]) {
    debug_assert_eq!(dy.len(), idx.len());
    dx.fill(0.0);
    for (&d, &i) in dy.iter().zip(idx) {
        dx[i as usize] += d;
    }
}

/// Allocating wrapper over [`maxpool2_bwd_into`].
pub fn maxpool2_bwd(dy: &[f32], idx: &[u32], in_len: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; in_len];
    maxpool2_bwd_into(dy, idx, &mut dx);
    dx
}

/// Global max pool over the spatial dims of (B,HW,C) -> (B,C) into caller
/// buffers; also fills flat argmax indices for the backward pass.
pub fn global_maxpool_into(
    x: &[f32],
    b: usize,
    hw: usize,
    c: usize,
    y: &mut [f32],
    idx: &mut [u32],
) {
    debug_assert_eq!(x.len(), b * hw * c);
    debug_assert_eq!(y.len(), b * c);
    debug_assert_eq!(idx.len(), b * c);
    y.fill(f32::NEG_INFINITY);
    idx.fill(0);
    for bi in 0..b {
        for s in 0..hw {
            for ci in 0..c {
                let i = (bi * hw + s) * c + ci;
                let o = bi * c + ci;
                if x[i] > y[o] {
                    y[o] = x[i];
                    idx[o] = i as u32;
                }
            }
        }
    }
}

/// Allocating wrapper over [`global_maxpool_into`].
pub fn global_maxpool(x: &[f32], b: usize, hw: usize, c: usize) -> (Vec<f32>, Vec<u32>) {
    let mut y = vec![0.0f32; b * c];
    let mut idx = vec![0u32; b * c];
    global_maxpool_into(x, b, hw, c, &mut y, &mut idx);
    (y, idx)
}

pub fn global_maxpool_bwd(dy: &[f32], idx: &[u32], in_len: usize) -> Vec<f32> {
    maxpool2_bwd(dy, idx, in_len)
}

// ---------------------------------------------------------------------------
// softmax cross-entropy (sum over the batch) + top-1/top-5 counts
// ---------------------------------------------------------------------------

/// Fills `dl` with d(sum_loss)/dlogits and returns
/// (sum_loss, ncorrect1, ncorrect5). Top-k correctness uses the strict
/// rank of the true logit, i.e. ties do not count against the true class
/// — the `ref.py::cross_entropy` rule. Sequential: the f64 loss sum must
/// keep one accumulation order.
pub fn cross_entropy_into(
    logits: &[f32],
    labels: &[i32],
    b: usize,
    k: usize,
    dl: &mut [f32],
) -> (f64, i64, i64) {
    debug_assert_eq!(logits.len(), b * k);
    debug_assert_eq!(labels.len(), b);
    debug_assert_eq!(dl.len(), b * k);
    let mut sum_loss = 0.0f64;
    let (mut c1, mut c5) = (0i64, 0i64);
    for i in 0..b {
        let row = &logits[i * k..(i + 1) * k];
        let y = labels[i] as usize;
        debug_assert!(y < k);
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|&l| (l - m).exp()).sum();
        let lse = z.ln() + m;
        let t = row[y];
        sum_loss += (lse - t) as f64;
        let rank = row.iter().filter(|&&l| l > t).count();
        if rank < 1 {
            c1 += 1;
        }
        if rank < 5 {
            c5 += 1;
        }
        let drow = &mut dl[i * k..(i + 1) * k];
        for (d, &l) in drow.iter_mut().zip(row) {
            *d = (l - m).exp() / z;
        }
        drow[y] -= 1.0;
    }
    (sum_loss, c1, c5)
}

/// Top-1/top-5 correct counts from raw logits — the accuracy half of
/// [`cross_entropy_into`] without the softmax/loss tail (no `exp`/`ln`
/// per logit, no gradient fill). Uses the identical rank rule
/// (`rank = #logits strictly above the label's`), so any caller that
/// discards loss gets bit-identical accuracy counts, cheaper.
pub fn top_counts(logits: &[f32], labels: &[i32], b: usize, k: usize) -> (i64, i64) {
    debug_assert_eq!(logits.len(), b * k);
    debug_assert_eq!(labels.len(), b);
    let (mut c1, mut c5) = (0i64, 0i64);
    for i in 0..b {
        let row = &logits[i * k..(i + 1) * k];
        let y = labels[i] as usize;
        debug_assert!(y < k);
        let t = row[y];
        let rank = row.iter().filter(|&&l| l > t).count();
        if rank < 1 {
            c1 += 1;
        }
        if rank < 5 {
            c5 += 1;
        }
    }
    (c1, c5)
}

/// Allocating wrapper over [`cross_entropy_into`]: returns
/// (sum_loss, ncorrect1, ncorrect5, d(sum_loss)/dlogits).
pub fn cross_entropy(
    logits: &[f32],
    labels: &[i32],
    b: usize,
    k: usize,
) -> (f64, i64, i64, Vec<f32>) {
    let mut dl = vec![0.0f32; b * k];
    let (sum_loss, c1, c5) = cross_entropy_into(logits, labels, b, k, &mut dl);
    (sum_loss, c1, c5, dl)
}

// ---------------------------------------------------------------------------
// Nesterov SGD with coupled weight decay (the L1 sgd kernel's update rule)
// ---------------------------------------------------------------------------

/// g' = g + wd*p;  m' = mu*m + g';  p' = p - lr*(g' + mu*m')
pub fn sgd_nesterov_inplace(p: &mut [f32], m: &mut [f32], g: &[f32], lr: f32, mu: f32, wd: f32) {
    debug_assert_eq!(p.len(), m.len());
    debug_assert_eq!(p.len(), g.len());
    for i in 0..p.len() {
        let g2 = g[i] + wd * p[i];
        let m2 = mu * m[i] + g2;
        p[i] -= lr * (g2 + mu * m2);
        m[i] = m2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity_and_shapes() {
        // (2,2) @ I = same
        let a = [1.0, 2.0, 3.0, 4.0];
        let eye = [1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &eye, 2, 2, 2, 1), a.to_vec());
        // (1,3)@(3,2)
        let out = matmul(&[1.0, 2.0, 3.0], &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0], 1, 3, 2, 1);
        assert_eq!(out, vec![4.0, 5.0]);
    }

    #[test]
    fn matmul_transposed_variants_agree() {
        // random-ish small case cross-checked against plain matmul
        let a: Vec<f32> = (0..6).map(|i| i as f32 * 0.5 - 1.0).collect(); // (2,3)
        let b: Vec<f32> = (0..8).map(|i| 1.0 - i as f32 * 0.25).collect(); // (2,4)
        // aᵀ(3,2) @ b(2,4) via matmul_tn(a, b, r=2, m=3, n=4)
        let tn = matmul_tn(&a, &b, 2, 3, 4, 1);
        let mut at = vec![0.0f32; 6];
        for i in 0..2 {
            for j in 0..3 {
                at[j * 2 + i] = a[i * 3 + j];
            }
        }
        assert_eq!(tn, matmul(&at, &b, 3, 2, 4, 1));
        // a(2,3) @ cᵀ where c is (4,3): matmul_nt(a, c, 2, 3, 4)
        let c: Vec<f32> = (0..12).map(|i| (i as f32).sin()).collect();
        let nt = matmul_nt(&a, &c, 2, 3, 4, 1);
        let mut ct = vec![0.0f32; 12];
        for i in 0..4 {
            for j in 0..3 {
                ct[j * 4 + i] = c[i * 3 + j];
            }
        }
        let want = matmul(&a, &ct, 2, 3, 4, 1);
        for (x, y) in nt.iter().zip(&want) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    /// Pseudo-random but deterministic test buffer.
    fn wave(n: usize, f: f32) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * f).sin() * 1.7).collect()
    }

    #[test]
    fn blocked_equals_reference_bitwise_including_zeros() {
        // exact zeros scattered into A exercise the removed `av == 0.0`
        // sparsity branch: the reference skips those terms, the blocked
        // tier adds them — bitwise identical on finite data
        let (m, k, n) = (37, 29, 13);
        let mut a = wave(m * k, 0.59);
        for v in a.iter_mut().step_by(7) {
            *v = 0.0;
        }
        let b = wave(k * n, 0.41);
        for t in [1, 4] {
            assert_eq!(
                matmul(&a, &b, m, k, n, t),
                matmul_reference(&a, &b, m, k, n, t),
                "matmul t={t}"
            );
        }
        let (r, tm, tn_) = (29, 13, 11);
        let mut ta = wave(r * tm, 0.33);
        for v in ta.iter_mut().step_by(5) {
            *v = 0.0;
        }
        let tb = wave(r * tn_, 0.21);
        for t in [1, 3] {
            assert_eq!(
                matmul_tn(&ta, &tb, r, tm, tn_, t),
                matmul_tn_reference(&ta, &tb, r, tm, tn_, t),
                "matmul_tn t={t}"
            );
        }
        let (nm, nk, nn) = (19, 31, 7);
        let na = wave(nm * nk, 0.87);
        let nb = wave(nn * nk, 0.93);
        for t in [1, 2] {
            assert_eq!(
                matmul_nt(&na, &nb, nm, nk, nn, t),
                matmul_nt_reference(&na, &nb, nm, nk, nn, t),
                "matmul_nt t={t}"
            );
        }
    }

    #[test]
    fn parallel_kernels_bitwise_match_sequential() {
        // sizes above the per-chunk spawn gate so the threaded paths
        // actually engage; every kernel must be bitwise identical across
        // thread counts
        let (m, k, n) = (2048, 36, 16); // m*k*n = 1.18M >= 2 chunks of 2^18
        let a = wave(m * k, 0.71);
        let b = wave(k * n, 1.13);
        let seq = matmul(&a, &b, m, k, n, 1);
        for t in [2, 3, 8] {
            assert_eq!(seq, matmul(&a, &b, m, k, n, t), "matmul t={t}");
        }
        let seq_ref = matmul_reference(&a, &b, m, k, n, 1);
        assert_eq!(seq, seq_ref, "blocked vs reference");
        for t in [2, 8] {
            assert_eq!(seq_ref, matmul_reference(&a, &b, m, k, n, t), "reference t={t}");
        }

        let (r, tm, tn_) = (2048, 36, 16);
        let ta = wave(r * tm, 0.37);
        let tb = wave(r * tn_, 0.91);
        let seq = matmul_tn(&ta, &tb, r, tm, tn_, 1);
        for t in [2, 5] {
            assert_eq!(seq, matmul_tn(&ta, &tb, r, tm, tn_, t), "matmul_tn t={t}");
        }
        assert_eq!(seq, matmul_tn_reference(&ta, &tb, r, tm, tn_, 1), "tn vs reference");

        let na = wave(m * k, 0.53);
        let nb = wave(n * k, 0.29);
        let seq = matmul_nt(&na, &nb, m, k, n, 1);
        for t in [2, 4] {
            assert_eq!(seq, matmul_nt(&na, &nb, m, k, n, t), "matmul_nt t={t}");
        }
        assert_eq!(seq, matmul_nt_reference(&na, &nb, m, k, n, 1), "nt vs reference");

        let (ib, ih, iw, ic) = (64, 16, 16, 8); // 64*16*16*9*8 = 1.18M
        let x = wave(ib * ih * iw * ic, 0.61);
        let seq = im2col(&x, ib, ih, iw, ic, 1);
        assert_eq!(seq, im2col(&x, ib, ih, iw, ic, 4), "im2col");
        let dp = wave(ib * ih * iw * 9 * ic, 0.47);
        let seq = col2im(&dp, ib, ih, iw, ic, 1);
        assert_eq!(seq, col2im(&dp, ib, ih, iw, ic, 4), "col2im");

        let (rows, c) = (16384, 32);
        let u = wave(rows * c, 0.83);
        let gamma = wave(c, 0.19);
        let beta = wave(c, 0.67);
        let s = bn_train(&u, &gamma, &beta, rows, c, 1);
        let p = bn_train(&u, &gamma, &beta, rows, c, 4);
        assert_eq!(s.0, p.0, "bn_train y");
        assert_eq!(s.1, p.1, "bn_train xhat");
        assert_eq!(s.2, p.2, "bn_train mean");

        let dy = wave(rows * c, 0.31);
        let sb = bn_train_bwd(&dy, &s.1, &s.4, &gamma, rows, c, 1);
        let pb = bn_train_bwd(&dy, &s.1, &s.4, &gamma, rows, c, 4);
        assert_eq!(sb.0, pb.0, "bn_train_bwd du");
        assert_eq!(sb.1, pb.1, "bn_train_bwd dgamma");

        let mean = wave(c, 0.11);
        let var: Vec<f32> = wave(c, 0.23).iter().map(|v| v * v + 0.5).collect();
        let se = bn_eval(&u, &gamma, &beta, &mean, &var, rows, c, 1);
        assert_eq!(se, bn_eval(&u, &gamma, &beta, &mean, &var, rows, c, 4), "bn_eval");
    }

    #[test]
    fn im2col_center_tap_is_identity() {
        // 1x1 channel: the (dy=1,dx=1) column equals the input pixel
        let x: Vec<f32> = (0..9).map(|i| i as f32).collect(); // (1,3,3,1)
        let p = im2col(&x, 1, 3, 3, 1, 1);
        assert_eq!(p.len(), 9 * 9);
        for pix in 0..9 {
            assert_eq!(p[pix * 9 + 4], x[pix]);
        }
        // top-left output pixel has zero padding at (dy=0,dx=0)
        assert_eq!(p[0], 0.0);
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // <im2col(x), y> == <x, col2im(y)> — adjointness on a small case
        let (b, h, w, c) = (1, 4, 3, 2);
        let n = b * h * w * c;
        let x: Vec<f32> = (0..n).map(|i| ((i * 7 + 3) % 11) as f32 - 5.0).collect();
        let yv: Vec<f32> = (0..n * 9).map(|i| ((i * 5 + 1) % 13) as f32 - 6.0).collect();
        let px = im2col(&x, b, h, w, c, 1);
        let lhs: f64 = px.iter().zip(&yv).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let aty = col2im(&yv, b, h, w, c, 1);
        let rhs: f64 = x.iter().zip(&aty).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        assert!((lhs - rhs).abs() < 1e-3 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
    }

    #[test]
    fn bn_train_normalizes() {
        let u = [1.0f32, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0];
        let (y, xhat, mean, var, invstd) = bn_train(&u, &[1.0, 1.0], &[0.0, 0.0], 4, 2, 1);
        assert!((mean[0] - 2.5).abs() < 1e-6);
        assert!((mean[1] - 25.0).abs() < 1e-6);
        assert!((var[0] - 1.25).abs() < 1e-5);
        // normalized output has ~zero mean, ~unit variance per channel
        let m0: f32 = (0..4).map(|r| y[r * 2]).sum::<f32>() / 4.0;
        assert!(m0.abs() < 1e-5);
        let v0: f32 = (0..4).map(|r| y[r * 2] * y[r * 2]).sum::<f32>() / 4.0;
        assert!((v0 - 1.0).abs() < 1e-3);
        assert_eq!(xhat.len(), 8);
        assert!(invstd[0] > 0.0);
    }

    #[test]
    fn bn_bwd_gradients_sum_to_zero() {
        // sum over the batch of du must vanish (mean subtraction)
        let u: Vec<f32> = (0..12).map(|i| (i as f32).cos() * 2.0).collect();
        let gamma = [0.7f32, -1.2, 0.4];
        let beta = [0.1f32, 0.0, -0.3];
        let (_y, xhat, _mean, _var, invstd) = bn_train(&u, &gamma, &beta, 4, 3, 1);
        let dy: Vec<f32> = (0..12).map(|i| (i as f32 * 1.7).sin()).collect();
        let (du, dgamma, dbeta) = bn_train_bwd(&dy, &xhat, &invstd, &gamma, 4, 3, 1);
        for ci in 0..3 {
            let s: f32 = (0..4).map(|r| du[r * 3 + ci]).sum();
            assert!(s.abs() < 1e-4, "channel {ci}: du sums to {s}");
        }
        assert_eq!(dgamma.len(), 3);
        assert_eq!(dbeta.len(), 3);
    }

    #[test]
    fn bn_rows_match_scalar_bitwise_per_tier() {
        // a ragged channel count exercises both the lane prefix and the
        // scalar tail of every tier this host can run
        let c = 21;
        let urow = wave(c, 0.47);
        let mean = wave(c, 0.13);
        let invstd: Vec<f32> = wave(c, 0.29).iter().map(|v| v.abs() + 0.5).collect();
        let gamma = wave(c, 0.61);
        let beta = wave(c, 0.83);
        let dyrow = wave(c, 0.37);
        let dgamma = wave(c, 0.19);
        let dbeta = wave(c, 0.71);
        for tier in simd::tiers_available() {
            let (mut x1, mut y1) = (vec![0.0f32; c], vec![0.0f32; c]);
            bn_norm_row(Tier::Scalar, &urow, &mean, &invstd, &gamma, &beta, &mut x1, &mut y1);
            let (mut x2, mut y2) = (vec![0.0f32; c], vec![0.0f32; c]);
            bn_norm_row(tier, &urow, &mean, &invstd, &gamma, &beta, &mut x2, &mut y2);
            assert_eq!(x1, x2, "bn_norm xhat {tier:?}");
            assert_eq!(y1, y2, "bn_norm y {tier:?}");

            let mut d1 = vec![0.0f32; c];
            bn_bwd_row(Tier::Scalar, &dyrow, &x1, &invstd, &dgamma, &dbeta, 4.0, &mut d1);
            let mut d2 = vec![0.0f32; c];
            bn_bwd_row(tier, &dyrow, &x1, &invstd, &dgamma, &dbeta, 4.0, &mut d2);
            assert_eq!(d1, d2, "bn_bwd {tier:?}");

            let mut e1 = vec![0.0f32; c];
            bn_eval_row(Tier::Scalar, &urow, &mean, &invstd, &beta, &mut e1);
            let mut e2 = vec![0.0f32; c];
            bn_eval_row(tier, &urow, &mean, &invstd, &beta, &mut e2);
            assert_eq!(e1, e2, "bn_eval {tier:?}");
        }
    }

    #[test]
    fn maxpool_routes_gradient_to_argmax() {
        #[rustfmt::skip]
        let x = [1.0f32, 5.0,
                 3.0, 2.0]; // (1,2,2,1)
        let (y, idx) = maxpool2(&x, 1, 2, 2, 1);
        assert_eq!(y, vec![5.0]);
        let dx = maxpool2_bwd(&[2.0], &idx, 4);
        assert_eq!(dx, vec![0.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn global_maxpool_picks_channel_max() {
        // (1, 3, 2): channel 0 max at s=2, channel 1 max at s=0
        let x = [0.0f32, 9.0, 1.0, -1.0, 7.0, 3.0];
        let (y, idx) = global_maxpool(&x, 1, 3, 2);
        assert_eq!(y, vec![7.0, 9.0]);
        let dx = global_maxpool_bwd(&[1.0, 1.0], &idx, 6);
        assert_eq!(dx, vec![0.0, 1.0, 0.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn cross_entropy_uniform_logits() {
        let (loss, c1, c5, dl) = cross_entropy(&[0.0; 8], &[3, 1], 2, 4);
        // uniform over 4 classes: loss = 2*ln(4); ties -> rank 0 -> correct
        assert!((loss - 2.0 * (4.0f64).ln()).abs() < 1e-5);
        assert_eq!(c1, 2);
        assert_eq!(c5, 2);
        // gradient rows sum to zero
        for i in 0..2 {
            let s: f32 = dl[i * 4..(i + 1) * 4].iter().sum();
            assert!(s.abs() < 1e-6);
        }
        assert!((dl[3] - (0.25 - 1.0)).abs() < 1e-6);
    }

    #[test]
    fn sgd_matches_host_optimizer_rule() {
        let mut p = [1.0f32];
        let mut m = [0.0f32];
        sgd_nesterov_inplace(&mut p, &mut m, &[0.3], 0.2, 0.9, 0.01);
        // g2 = 0.31, m2 = 0.31, p -= 0.2*(0.31 + 0.279)
        assert!((m[0] - 0.31).abs() < 1e-6);
        assert!((p[0] - (1.0 - 0.2 * (0.31 + 0.9 * 0.31))).abs() < 1e-6);
    }
}
