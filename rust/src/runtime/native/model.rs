//! Native ResNet9s: the exact topology of `python/compile/model.py`
//! (davidcpage's DAWNBench CIFAR net, paper §5.1), forward + hand-derived
//! backward over the flat NHWC kernels in `super::kernels`.
//!
//! ```text
//! prep  : conv3x3( 3 ->  c) + BN + ReLU                      [H]
//! layer1: conv3x3( c -> 2c) + BN + ReLU + maxpool2           [H -> H/2]
//! res1  : x + 2 x [conv3x3(2c -> 2c) + BN + ReLU]            [H/2]
//! layer2: conv3x3(2c -> 4c) + BN + ReLU + maxpool2           [H/2 -> H/4]
//! layer3: conv3x3(4c -> 8c) + BN + ReLU + maxpool2           [H/4 -> H/8]
//! res3  : x + 2 x [conv3x3(8c -> 8c) + BN + ReLU]            [H/8]
//! head  : global maxpool + linear(8c -> classes) * 0.125
//! ```
//!
//! Parameters are the manifest-ordered flat list (per conv layer: w, gamma,
//! beta; then head.w, head.b — 26 tensors); BN moments are (mean, var) per
//! conv layer — 16 tensors. The backward pass was validated against
//! `jax.grad` of the python model (rust/tests/kernel_parity.rs).

use super::kernels as k;

pub const HEAD_SCALE: f32 = 0.125;
pub const NUM_CONV_LAYERS: usize = 8;
pub const NUM_PARAM_TENSORS: usize = 3 * NUM_CONV_LAYERS + 2;

/// Static architecture dimensions.
#[derive(Debug, Clone, Copy)]
pub struct Dims {
    pub width: usize,
    pub num_classes: usize,
    pub image_size: usize,
}

/// The conv layers in forward order: (name, cin, cout, spatial side at the
/// conv input). Mirrors `model.py::_conv_layers` + the pooling schedule.
pub fn conv_layers(d: &Dims) -> [(&'static str, usize, usize, usize); NUM_CONV_LAYERS] {
    let c = d.width;
    let h = d.image_size;
    [
        ("prep", 3, c, h),
        ("layer1", c, 2 * c, h),
        ("res1a", 2 * c, 2 * c, h / 2),
        ("res1b", 2 * c, 2 * c, h / 2),
        ("layer2", 2 * c, 4 * c, h / 2),
        ("layer3", 4 * c, 8 * c, h / 4),
        ("res3a", 8 * c, 8 * c, h / 8),
        ("res3b", 8 * c, 8 * c, h / 8),
    ]
}

/// Forward FLOPs per example (multiply-adds x2), as `aot.py` computes it.
pub fn flops_fwd_per_example(d: &Dims) -> u64 {
    let mut total: u64 = 0;
    for (_name, cin, cout, side) in conv_layers(d) {
        total += 2 * (side * side) as u64 * (9 * cin) as u64 * cout as u64;
    }
    total += 2 * (8 * d.width) as u64 * d.num_classes as u64;
    total
}

/// Per-block saved context for the backward pass.
struct BlockSave {
    /// conv input activations (B, side, side, cin), flat NHWC
    x: Vec<f32>,
    side: usize,
    cin: usize,
    cout: usize,
    /// normalized conv output
    xhat: Vec<f32>,
    invstd: Vec<f32>,
    /// pre-ReLU block output (ReLU mask)
    y: Vec<f32>,
}

/// Everything `backward` needs from the train forward pass.
pub struct TrainCtx {
    batch: usize,
    saves: Vec<BlockSave>,
    /// (argmax indices, input length) for the three 2x2 pools
    pools: [(Vec<u32>, usize); 3],
    /// pooled head features (B, 8c)
    h: Vec<f32>,
    /// global-maxpool argmax (into the res3 output)
    hmax: Vec<u32>,
    /// res3 output length
    r3_len: usize,
}

/// Output of the train-mode forward pass.
pub struct TrainForward {
    pub logits: Vec<f32>,
    /// flat [mean0, var0, mean1, var1, ...] in conv-layer order
    pub moments: Vec<Vec<f32>>,
    pub ctx: TrainCtx,
}

fn block_fwd_train(
    b: usize,
    side: usize,
    cin: usize,
    cout: usize,
    x: Vec<f32>,
    w: &[f32],
    gamma: &[f32],
    beta: &[f32],
    threads: usize,
) -> (Vec<f32>, BlockSave, Vec<f32>, Vec<f32>) {
    let rows = b * side * side;
    let patches = k::im2col(&x, b, side, side, cin, threads);
    let u = k::matmul(&patches, w, rows, 9 * cin, cout, threads);
    let (y, xhat, mean, var, invstd) = k::bn_train(&u, gamma, beta, rows, cout, threads);
    let a = k::relu(&y);
    let save = BlockSave { x, side, cin, cout, xhat, invstd, y };
    (a, save, mean, var)
}

fn block_fwd_eval(
    b: usize,
    side: usize,
    cin: usize,
    cout: usize,
    x: &[f32],
    w: &[f32],
    gamma: &[f32],
    beta: &[f32],
    mean: &[f32],
    var: &[f32],
    threads: usize,
) -> Vec<f32> {
    let rows = b * side * side;
    let patches = k::im2col(x, b, side, side, cin, threads);
    let u = k::matmul(&patches, w, rows, 9 * cin, cout, threads);
    k::relu(&k::bn_eval(&u, gamma, beta, mean, var, rows, cout, threads))
}

/// Backward through one block. Returns (dx (None for the first layer),
/// dw, dgamma, dbeta).
#[allow(clippy::type_complexity)]
fn block_bwd(
    b: usize,
    save: &BlockSave,
    w: &[f32],
    gamma: &[f32],
    da: &[f32],
    need_dx: bool,
    threads: usize,
) -> (Option<Vec<f32>>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let rows = b * save.side * save.side;
    let dy = k::relu_bwd(da, &save.y);
    let (du, dgamma, dbeta) =
        k::bn_train_bwd(&dy, &save.xhat, &save.invstd, gamma, rows, save.cout, threads);
    let patches = k::im2col(&save.x, b, save.side, save.side, save.cin, threads);
    let dw = k::matmul_tn(&patches, &du, rows, 9 * save.cin, save.cout, threads);
    let dx = if need_dx {
        let dp = k::matmul_nt(&du, w, rows, save.cout, 9 * save.cin, threads);
        Some(k::col2im(&dp, b, save.side, save.side, save.cin, threads))
    } else {
        None
    };
    (dx, dw, dgamma, dbeta)
}

fn add_into(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, &v) in acc.iter_mut().zip(x) {
        *a += v;
    }
}

/// Train-mode forward pass. `params` is the manifest-ordered list of flat
/// parameter slices (26 entries).
pub fn forward_train(
    d: &Dims,
    params: &[&[f32]],
    images: &[f32],
    b: usize,
    threads: usize,
) -> TrainForward {
    debug_assert_eq!(params.len(), NUM_PARAM_TENSORS);
    let layers = conv_layers(d);
    let mut saves = Vec::with_capacity(NUM_CONV_LAYERS);
    let mut moments = Vec::with_capacity(2 * NUM_CONV_LAYERS);
    let fwd = |li: usize, x: Vec<f32>, saves: &mut Vec<BlockSave>, moments: &mut Vec<Vec<f32>>| {
        let (_, cin, cout, side) = layers[li];
        let (a, save, mean, var) = block_fwd_train(
            b,
            side,
            cin,
            cout,
            x,
            params[3 * li],
            params[3 * li + 1],
            params[3 * li + 2],
            threads,
        );
        saves.push(save);
        moments.push(mean);
        moments.push(var);
        a
    };

    let h = d.image_size;
    let c = d.width;
    let a0 = fwd(0, images.to_vec(), &mut saves, &mut moments);
    let a1 = fwd(1, a0, &mut saves, &mut moments);
    let (p1, i1) = k::maxpool2(&a1, b, h, h, 2 * c);
    let m1 = fwd(2, p1.clone(), &mut saves, &mut moments);
    let mut r1 = fwd(3, m1, &mut saves, &mut moments);
    add_into(&mut r1, &p1); // res1: x + f(x)
    let a2 = fwd(4, r1, &mut saves, &mut moments);
    let (p2, i2) = k::maxpool2(&a2, b, h / 2, h / 2, 4 * c);
    let a3 = fwd(5, p2, &mut saves, &mut moments);
    let (p3, i3) = k::maxpool2(&a3, b, h / 4, h / 4, 8 * c);
    let m3 = fwd(6, p3.clone(), &mut saves, &mut moments);
    let mut r3 = fwd(7, m3, &mut saves, &mut moments);
    add_into(&mut r3, &p3); // res3: x + f(x)

    let hw3 = (h / 8) * (h / 8);
    let (hfeat, hmax) = k::global_maxpool(&r3, b, hw3, 8 * c);
    let mut logits = k::matmul(&hfeat, params[24], b, 8 * c, d.num_classes, threads);
    let bias = params[25];
    for bi in 0..b {
        for j in 0..d.num_classes {
            logits[bi * d.num_classes + j] =
                (logits[bi * d.num_classes + j] + bias[j]) * HEAD_SCALE;
        }
    }
    let r3_len = r3.len();
    let ctx = TrainCtx {
        batch: b,
        saves,
        pools: [
            (i1, b * h * h * 2 * c),
            (i2, b * (h / 2) * (h / 2) * 4 * c),
            (i3, b * (h / 4) * (h / 4) * 8 * c),
        ],
        h: hfeat,
        hmax,
        r3_len,
    };
    TrainForward { logits, moments, ctx }
}

/// Backward pass: gradient of the loss w.r.t. every parameter, given
/// d(loss)/d(logits). Returns flat gradient buffers in manifest order.
pub fn backward(
    d: &Dims,
    params: &[&[f32]],
    dlogits: &[f32],
    ctx: &TrainCtx,
    threads: usize,
) -> Vec<Vec<f32>> {
    let b = ctx.batch;
    let c8 = 8 * d.width;
    let nc = d.num_classes;
    let mut grads: Vec<Vec<f32>> = vec![Vec::new(); NUM_PARAM_TENSORS];

    // head: logits = (h @ W + bias) * HEAD_SCALE
    let ds: Vec<f32> = dlogits.iter().map(|&v| v * HEAD_SCALE).collect();
    grads[24] = k::matmul_tn(&ctx.h, &ds, b, c8, nc, threads);
    let mut dbias = vec![0.0f32; nc];
    for bi in 0..b {
        for j in 0..nc {
            dbias[j] += ds[bi * nc + j];
        }
    }
    grads[25] = dbias;
    let dh = k::matmul_nt(&ds, params[24], b, nc, c8, threads);

    // global max pool
    let dr3 = k::global_maxpool_bwd(&dh, &ctx.hmax, ctx.r3_len);

    let bwd = |li: usize, da: &[f32], need_dx: bool, grads: &mut Vec<Vec<f32>>| {
        let (dx, dw, dgamma, dbeta) = block_bwd(
            b,
            &ctx.saves[li],
            params[3 * li],
            params[3 * li + 1],
            da,
            need_dx,
            threads,
        );
        grads[3 * li] = dw;
        grads[3 * li + 1] = dgamma;
        grads[3 * li + 2] = dbeta;
        dx.unwrap_or_default()
    };

    // res3: r3 = p3 + res3b(res3a(p3))
    let dm3 = bwd(7, &dr3, true, &mut grads);
    let dp3_branch = bwd(6, &dm3, true, &mut grads);
    let mut dp3 = dr3;
    add_into(&mut dp3, &dp3_branch);

    // layer3 pool + block
    let da3 = k::maxpool2_bwd(&dp3, &ctx.pools[2].0, ctx.pools[2].1);
    let dp2 = bwd(5, &da3, true, &mut grads);

    // layer2 pool + block
    let da2 = k::maxpool2_bwd(&dp2, &ctx.pools[1].0, ctx.pools[1].1);
    let dr1 = bwd(4, &da2, true, &mut grads);

    // res1: r1 = p1 + res1b(res1a(p1))
    let dm1 = bwd(3, &dr1, true, &mut grads);
    let dp1_branch = bwd(2, &dm1, true, &mut grads);
    let mut dp1 = dr1;
    add_into(&mut dp1, &dp1_branch);

    // layer1 pool + block, then prep (no dx needed for the input image)
    let da1 = k::maxpool2_bwd(&dp1, &ctx.pools[0].0, ctx.pools[0].1);
    let da0 = bwd(1, &da1, true, &mut grads);
    let _ = bwd(0, &da0, false, &mut grads);

    grads
}

/// Moments-only forward pass (phase 3's `bnstats` entry point): runs the
/// blocks in train mode but keeps neither the backward context nor the
/// head — the per-layer (mean, biased var) pairs are the only output.
pub fn forward_moments(
    d: &Dims,
    params: &[&[f32]],
    images: &[f32],
    b: usize,
    threads: usize,
) -> Vec<Vec<f32>> {
    debug_assert_eq!(params.len(), NUM_PARAM_TENSORS);
    let layers = conv_layers(d);
    let mut moments = Vec::with_capacity(2 * NUM_CONV_LAYERS);
    let fwd = |li: usize, x: &[f32], moments: &mut Vec<Vec<f32>>| -> Vec<f32> {
        let (_, cin, cout, side) = layers[li];
        let rows = b * side * side;
        let patches = k::im2col(x, b, side, side, cin, threads);
        let u = k::matmul(&patches, params[3 * li], rows, 9 * cin, cout, threads);
        let (y, _xhat, mean, var, _invstd) =
            k::bn_train(&u, params[3 * li + 1], params[3 * li + 2], rows, cout, threads);
        moments.push(mean);
        moments.push(var);
        k::relu(&y)
    };
    let h = d.image_size;
    let c = d.width;
    let a0 = fwd(0, images, &mut moments);
    let a1 = fwd(1, &a0, &mut moments);
    let (p1, _) = k::maxpool2(&a1, b, h, h, 2 * c);
    let m1 = fwd(2, &p1, &mut moments);
    let mut r1 = fwd(3, &m1, &mut moments);
    add_into(&mut r1, &p1);
    let a2 = fwd(4, &r1, &mut moments);
    let (p2, _) = k::maxpool2(&a2, b, h / 2, h / 2, 4 * c);
    let a3 = fwd(5, &p2, &mut moments);
    let (p3, _) = k::maxpool2(&a3, b, h / 4, h / 4, 8 * c);
    let m3 = fwd(6, &p3, &mut moments);
    let _ = fwd(7, &m3, &mut moments); // res3b moments; output unused
    moments
}

/// Eval-mode forward pass with running BN statistics (mean/var pairs per
/// conv layer, manifest `bn_stats` order). Returns logits.
pub fn forward_eval(
    d: &Dims,
    params: &[&[f32]],
    bn: &[&[f32]],
    images: &[f32],
    b: usize,
    threads: usize,
) -> Vec<f32> {
    debug_assert_eq!(params.len(), NUM_PARAM_TENSORS);
    debug_assert_eq!(bn.len(), 2 * NUM_CONV_LAYERS);
    let layers = conv_layers(d);
    let fwd = |li: usize, x: &[f32]| -> Vec<f32> {
        let (_, cin, cout, side) = layers[li];
        block_fwd_eval(
            b,
            side,
            cin,
            cout,
            x,
            params[3 * li],
            params[3 * li + 1],
            params[3 * li + 2],
            bn[2 * li],
            bn[2 * li + 1],
            threads,
        )
    };
    let h = d.image_size;
    let c = d.width;
    let a0 = fwd(0, images);
    let a1 = fwd(1, &a0);
    let (p1, _) = k::maxpool2(&a1, b, h, h, 2 * c);
    let m1 = fwd(2, &p1);
    let mut r1 = fwd(3, &m1);
    add_into(&mut r1, &p1);
    let a2 = fwd(4, &r1);
    let (p2, _) = k::maxpool2(&a2, b, h / 2, h / 2, 4 * c);
    let a3 = fwd(5, &p2);
    let (p3, _) = k::maxpool2(&a3, b, h / 4, h / 4, 8 * c);
    let m3 = fwd(6, &p3);
    let mut r3 = fwd(7, &m3);
    add_into(&mut r3, &p3);
    let hw3 = (h / 8) * (h / 8);
    let (hfeat, _) = k::global_maxpool(&r3, b, hw3, 8 * c);
    let mut logits = k::matmul(&hfeat, params[24], b, 8 * c, d.num_classes, threads);
    let bias = params[25];
    for bi in 0..b {
        for j in 0..d.num_classes {
            logits[bi * d.num_classes + j] =
                (logits[bi * d.num_classes + j] + bias[j]) * HEAD_SCALE;
        }
    }
    logits
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> Dims {
        Dims { width: 2, num_classes: 4, image_size: 8 }
    }

    #[test]
    fn conv_layer_table_matches_python() {
        let d = dims();
        let l = conv_layers(&d);
        assert_eq!(l[0], ("prep", 3, 2, 8));
        assert_eq!(l[1], ("layer1", 2, 4, 8));
        assert_eq!(l[2], ("res1a", 4, 4, 4));
        assert_eq!(l[4], ("layer2", 4, 8, 4));
        assert_eq!(l[5], ("layer3", 8, 16, 2));
        assert_eq!(l[7], ("res3b", 16, 16, 1));
    }

    #[test]
    fn flops_match_aot_formula() {
        // width 4, image 16 (the tiny preset): recompute by hand
        let d = Dims { width: 4, num_classes: 10, image_size: 16 };
        let mut want: u64 = 0;
        for (cin, cout, side) in [
            (3usize, 4usize, 16usize),
            (4, 8, 16),
            (8, 8, 8),
            (8, 8, 8),
            (8, 16, 8),
            (16, 32, 4),
            (32, 32, 2),
            (32, 32, 2),
        ] {
            want += 2 * (side * side * 9 * cin * cout) as u64;
        }
        want += 2 * 32 * 10;
        assert_eq!(flops_fwd_per_example(&d), want);
    }
}
