//! Native ResNet9s: the exact topology of `python/compile/model.py`
//! (davidcpage's DAWNBench CIFAR net, paper §5.1), forward + hand-derived
//! backward over the flat NHWC kernels in `super::kernels` and the
//! blocked GEMM tier in `super::gemm`.
//!
//! ```text
//! prep  : conv3x3( 3 ->  c) + BN + ReLU                      [H]
//! layer1: conv3x3( c -> 2c) + BN + ReLU + maxpool2           [H -> H/2]
//! res1  : x + 2 x [conv3x3(2c -> 2c) + BN + ReLU]            [H/2]
//! layer2: conv3x3(2c -> 4c) + BN + ReLU + maxpool2           [H/2 -> H/4]
//! layer3: conv3x3(4c -> 8c) + BN + ReLU + maxpool2           [H/4 -> H/8]
//! res3  : x + 2 x [conv3x3(8c -> 8c) + BN + ReLU]            [H/8]
//! head  : global maxpool + linear(8c -> classes) * 0.125
//! ```
//!
//! Parameters are the manifest-ordered flat list (per conv layer: w, gamma,
//! beta; then head.w, head.b — 26 tensors); BN moments are (mean, var) per
//! conv layer — 16 tensors. The backward pass was validated against
//! `jax.grad` of the python model (rust/tests/kernel_parity.rs).
//!
//! Every entry point threads a [`Workspace`] through: activations, BN
//! saves, pool argmaxes, patch-gradient scratch and the flat gradient
//! arena all live in per-engine persistent buffers, so a steady-state
//! step allocates nothing (rust/tests/alloc_regression.rs). The conv
//! GEMMs pack im2col patches straight from the NHWC activations
//! (`gemm::ASrc::Im2col`), never materializing the patch matrix.

use super::gemm::{self, GemmScratch};
use super::kernels as k;
use super::qgemm::{self, QuantScratch, QuantTensor};
use super::workspace::Workspace;
use crate::util::simd::Tier;

pub const HEAD_SCALE: f32 = 0.125;
pub const NUM_CONV_LAYERS: usize = 8;
pub const NUM_PARAM_TENSORS: usize = 3 * NUM_CONV_LAYERS + 2;

/// Static architecture dimensions.
#[derive(Debug, Clone, Copy)]
pub struct Dims {
    pub width: usize,
    pub num_classes: usize,
    pub image_size: usize,
}

/// The conv layers in forward order: (name, cin, cout, spatial side at the
/// conv input). Mirrors `model.py::_conv_layers` + the pooling schedule.
pub fn conv_layers(d: &Dims) -> [(&'static str, usize, usize, usize); NUM_CONV_LAYERS] {
    let c = d.width;
    let h = d.image_size;
    [
        ("prep", 3, c, h),
        ("layer1", c, 2 * c, h),
        ("res1a", 2 * c, 2 * c, h / 2),
        ("res1b", 2 * c, 2 * c, h / 2),
        ("layer2", 2 * c, 4 * c, h / 2),
        ("layer3", 4 * c, 8 * c, h / 4),
        ("res3a", 8 * c, 8 * c, h / 8),
        ("res3b", 8 * c, 8 * c, h / 8),
    ]
}

/// Forward FLOPs per example (multiply-adds x2), as `aot.py` computes it.
pub fn flops_fwd_per_example(d: &Dims) -> u64 {
    let mut total: u64 = 0;
    for (_name, cin, cout, side) in conv_layers(d) {
        total += 2 * (side * side) as u64 * (9 * cin) as u64 * cout as u64;
    }
    total += 2 * (8 * d.width) as u64 * d.num_classes as u64;
    total
}

type Layers = [(&'static str, usize, usize, usize); NUM_CONV_LAYERS];

/// Offset of layer `li`'s first parameter (its conv weight) in the flat
/// manifest-ordered arena; `li == NUM_CONV_LAYERS` gives the head.w
/// offset. Matches `param_specs` exactly: per layer w, gamma, beta.
fn param_offset(layers: &Layers, li: usize) -> usize {
    let mut off = 0;
    for (_, cin, cout, _) in layers.iter().take(li) {
        off += 9 * cin * cout + 2 * cout;
    }
    off
}

fn add_into(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, &v) in acc.iter_mut().zip(x) {
        *a += v;
    }
}

/// One conv+BN+ReLU block, train mode: conv into the `u` scratch (fused
/// im2col packing), batch statistics + normalize into the layer's saves,
/// ReLU into `out`.
#[allow(clippy::too_many_arguments)]
fn block_fwd_train(
    li: usize,
    layers: &Layers,
    params: &[&[f32]],
    b: usize,
    threads: usize,
    x: &[f32],
    out: &mut [f32],
    u: &mut [f32],
    xhat: &mut [Vec<f32>; NUM_CONV_LAYERS],
    yact: &mut [Vec<f32>; NUM_CONV_LAYERS],
    mean: &mut [Vec<f32>; NUM_CONV_LAYERS],
    var: &mut [Vec<f32>; NUM_CONV_LAYERS],
    invstd: &mut [Vec<f32>; NUM_CONV_LAYERS],
    gs: &mut GemmScratch,
) {
    let (_, cin, cout, side) = layers[li];
    let rows = b * side * side;
    let n = rows * cout;
    debug_assert_eq!(x.len(), rows * cin);
    debug_assert_eq!(out.len(), n);
    let us = &mut u[..n];
    gemm::conv3x3_into(us, x, b, side, side, cin, params[3 * li], cout, threads, gs);
    k::bn_train_into(
        us,
        params[3 * li + 1],
        params[3 * li + 2],
        rows,
        cout,
        threads,
        &mut yact[li][..n],
        &mut xhat[li][..n],
        &mut mean[li][..cout],
        &mut var[li][..cout],
        &mut invstd[li][..cout],
    );
    k::relu_into(&yact[li][..n], out);
}

/// One conv+BN+ReLU block, eval mode with running statistics.
#[allow(clippy::too_many_arguments)]
fn block_fwd_eval(
    li: usize,
    layers: &Layers,
    params: &[&[f32]],
    bn: &[&[f32]],
    b: usize,
    threads: usize,
    x: &[f32],
    out: &mut [f32],
    u: &mut [f32],
    v: &mut [f32],
    scale: &mut [f32],
    gs: &mut GemmScratch,
) {
    let (_, cin, cout, side) = layers[li];
    let rows = b * side * side;
    let n = rows * cout;
    let us = &mut u[..n];
    gemm::conv3x3_into(us, x, b, side, side, cin, params[3 * li], cout, threads, gs);
    k::bn_eval_into(
        us,
        params[3 * li + 1],
        params[3 * li + 2],
        bn[2 * li],
        bn[2 * li + 1],
        rows,
        cout,
        threads,
        &mut v[..n],
        &mut scale[..cout],
    );
    k::relu_into(&v[..n], out);
}

/// Backward through one block: `da` is the gradient w.r.t. the post-ReLU
/// output; writes dw/dgamma/dbeta straight into the flat `grads` arena
/// and, when `dx` is given, the gradient w.r.t. the conv input.
#[allow(clippy::too_many_arguments)]
fn block_bwd(
    li: usize,
    layers: &Layers,
    params: &[&[f32]],
    b: usize,
    threads: usize,
    x: &[f32],
    da: &[f32],
    dx: Option<&mut [f32]>,
    xhat: &[Vec<f32>; NUM_CONV_LAYERS],
    yact: &[Vec<f32>; NUM_CONV_LAYERS],
    invstd: &[Vec<f32>; NUM_CONV_LAYERS],
    u: &mut [f32],
    v: &mut [f32],
    scale: &mut [f32],
    dp: &mut [f32],
    grads: &mut [f32],
    gs: &mut GemmScratch,
) {
    let (_, cin, cout, side) = layers[li];
    let rows = b * side * side;
    let n = rows * cout;
    debug_assert_eq!(da.len(), n);
    // dy = da * [y > 0]
    k::relu_bwd_into(da, &yact[li][..n], &mut u[..n]);
    // carve this layer's (w, gamma, beta) gradient slices out of the arena
    let off = param_offset(layers, li);
    let wlen = 9 * cin * cout;
    let (dw, rest) = grads[off..off + wlen + 2 * cout].split_at_mut(wlen);
    let (dgamma, dbeta) = rest.split_at_mut(cout);
    k::bn_train_bwd_into(
        &u[..n],
        &xhat[li][..n],
        &invstd[li][..cout],
        params[3 * li + 1],
        rows,
        cout,
        threads,
        &mut v[..n],
        dgamma,
        dbeta,
        &mut scale[..cout],
    );
    // dW = patchesᵀ @ du, packing patches straight from the saved input
    gemm::conv3x3_dw_into(dw, x, b, side, side, cin, &v[..n], cout, threads, gs);
    if let Some(dx) = dx {
        let np = rows * 9 * cin;
        gemm::matmul_nt_into(
            &mut dp[..np],
            &v[..n],
            params[3 * li],
            rows,
            cout,
            9 * cin,
            threads,
            gs,
        );
        k::col2im_into(&dp[..np], b, side, side, cin, threads, dx);
    }
}

/// Train-mode forward pass into the workspace: fills `ws.logits`, the
/// per-layer BN saves/moments and every buffer the backward pass needs.
/// `params` is the manifest-ordered list of flat parameter views (26).
pub fn forward_train_ws(
    d: &Dims,
    params: &[&[f32]],
    images: &[f32],
    b: usize,
    threads: usize,
    ws: &mut Workspace,
) {
    debug_assert_eq!(params.len(), NUM_PARAM_TENSORS);
    ws.ensure(d, b);
    let layers = conv_layers(d);
    let h = d.image_size;
    let c = d.width;
    let nc = d.num_classes;
    let Workspace {
        gemm: gs,
        x0,
        x1,
        x2,
        x3,
        x4,
        x5,
        x6,
        x7,
        xhat,
        yact,
        mean,
        var,
        invstd,
        pool_idx,
        hmax,
        u,
        act,
        r3,
        hfeat,
        logits,
        ..
    } = ws;

    macro_rules! fwd {
        ($li:expr, $x:expr, $out:expr) => {
            block_fwd_train(
                $li, &layers, params, b, threads, $x, $out, u, xhat, yact, mean, var, invstd, gs,
            )
        };
    }

    let n0 = b * h * h * 3;
    x0[..n0].copy_from_slice(&images[..n0]);
    let x1n = b * h * h * c;
    fwd!(0, &x0[..n0], &mut x1[..x1n]);
    let a1n = b * h * h * 2 * c;
    fwd!(1, &x1[..x1n], &mut act[..a1n]);
    let p1n = b * (h / 2) * (h / 2) * 2 * c;
    k::maxpool2_into(
        &act[..a1n],
        b,
        h,
        h,
        2 * c,
        &mut x2[..p1n],
        &mut pool_idx[0][..p1n],
    );
    fwd!(2, &x2[..p1n], &mut x3[..p1n]);
    fwd!(3, &x3[..p1n], &mut x4[..p1n]);
    add_into(&mut x4[..p1n], &x2[..p1n]); // res1: x + f(x)
    let a4n = b * (h / 2) * (h / 2) * 4 * c;
    fwd!(4, &x4[..p1n], &mut act[..a4n]);
    let p2n = b * (h / 4) * (h / 4) * 4 * c;
    k::maxpool2_into(
        &act[..a4n],
        b,
        h / 2,
        h / 2,
        4 * c,
        &mut x5[..p2n],
        &mut pool_idx[1][..p2n],
    );
    let a5n = b * (h / 4) * (h / 4) * 8 * c;
    fwd!(5, &x5[..p2n], &mut act[..a5n]);
    let p3n = b * (h / 8) * (h / 8) * 8 * c;
    k::maxpool2_into(
        &act[..a5n],
        b,
        h / 4,
        h / 4,
        8 * c,
        &mut x6[..p3n],
        &mut pool_idx[2][..p3n],
    );
    fwd!(6, &x6[..p3n], &mut x7[..p3n]);
    fwd!(7, &x7[..p3n], &mut r3[..p3n]);
    add_into(&mut r3[..p3n], &x6[..p3n]); // res3: x + f(x)

    let hw3 = (h / 8) * (h / 8);
    let c8 = 8 * c;
    k::global_maxpool_into(&r3[..p3n], b, hw3, c8, &mut hfeat[..b * c8], &mut hmax[..b * c8]);
    gemm::matmul_into(
        &mut logits[..b * nc],
        &hfeat[..b * c8],
        params[24],
        b,
        c8,
        nc,
        threads,
        gs,
    );
    let bias = params[25];
    for bi in 0..b {
        for j in 0..nc {
            logits[bi * nc + j] = (logits[bi * nc + j] + bias[j]) * HEAD_SCALE;
        }
    }
}

/// Backward pass: reads `ws.dl` (gradient of the *mean* batch loss w.r.t.
/// the logits, pre head-scale) plus the forward saves, and fills the flat
/// manifest-ordered `ws.grads` arena.
pub fn backward_ws(d: &Dims, params: &[&[f32]], b: usize, threads: usize, ws: &mut Workspace) {
    debug_assert_eq!(params.len(), NUM_PARAM_TENSORS);
    let layers = conv_layers(d);
    let h = d.image_size;
    let c = d.width;
    let nc = d.num_classes;
    let c8 = 8 * c;
    let Workspace {
        gemm: gs,
        x0,
        x1,
        x2,
        x3,
        x4,
        x5,
        x6,
        x7,
        xhat,
        yact,
        invstd,
        pool_idx,
        hmax,
        u,
        v,
        hfeat,
        scale,
        dl,
        dh,
        ga,
        gb,
        gres,
        dp,
        grads,
        ..
    } = ws;

    // head: logits = (h @ W + bias) * HEAD_SCALE
    let ndl = b * nc;
    for dv in dl[..ndl].iter_mut() {
        *dv *= HEAD_SCALE;
    }
    let hw_off = param_offset(&layers, NUM_CONV_LAYERS);
    let hw_len = c8 * nc;
    gemm::matmul_tn_into(
        &mut grads[hw_off..hw_off + hw_len],
        &hfeat[..b * c8],
        &dl[..ndl],
        b,
        c8,
        nc,
        threads,
        gs,
    );
    {
        let dbias = &mut grads[hw_off + hw_len..hw_off + hw_len + nc];
        dbias.fill(0.0);
        for bi in 0..b {
            for j in 0..nc {
                dbias[j] += dl[bi * nc + j];
            }
        }
    }
    gemm::matmul_nt_into(&mut dh[..b * c8], &dl[..ndl], params[24], b, nc, c8, threads, gs);

    // global max pool: route dh back onto the res3 output
    let p3n = b * (h / 8) * (h / 8) * c8;
    k::maxpool2_bwd_into(&dh[..b * c8], &hmax[..b * c8], &mut gres[..p3n]);

    macro_rules! bwd {
        ($li:expr, $x:expr, $da:expr, $dx:expr) => {
            block_bwd(
                $li, &layers, params, b, threads, $x, $da, $dx, xhat, yact, invstd, u, v, scale,
                dp, grads, gs,
            )
        };
    }

    // res3: r3 = p3 + res3b(res3a(p3))
    bwd!(7, &x7[..p3n], &gres[..p3n], Some(&mut ga[..p3n]));
    bwd!(6, &x6[..p3n], &ga[..p3n], Some(&mut gb[..p3n]));
    add_into(&mut gres[..p3n], &gb[..p3n]);

    // layer3 pool + block
    let a5n = b * (h / 4) * (h / 4) * 8 * c;
    k::maxpool2_bwd_into(&gres[..p3n], &pool_idx[2][..p3n], &mut ga[..a5n]);
    let p2n = b * (h / 4) * (h / 4) * 4 * c;
    bwd!(5, &x5[..p2n], &ga[..a5n], Some(&mut gb[..p2n]));

    // layer2 pool + block
    let a4n = b * (h / 2) * (h / 2) * 4 * c;
    k::maxpool2_bwd_into(&gb[..p2n], &pool_idx[1][..p2n], &mut ga[..a4n]);
    let p1n = b * (h / 2) * (h / 2) * 2 * c;
    bwd!(4, &x4[..p1n], &ga[..a4n], Some(&mut gres[..p1n]));

    // res1: r1 = p1 + res1b(res1a(p1))
    bwd!(3, &x3[..p1n], &gres[..p1n], Some(&mut ga[..p1n]));
    bwd!(2, &x2[..p1n], &ga[..p1n], Some(&mut gb[..p1n]));
    add_into(&mut gres[..p1n], &gb[..p1n]);

    // layer1 pool + block, then prep (no dx needed for the input image)
    let a1n = b * h * h * 2 * c;
    k::maxpool2_bwd_into(&gres[..p1n], &pool_idx[0][..p1n], &mut ga[..a1n]);
    let x1n = b * h * h * c;
    bwd!(1, &x1[..x1n], &ga[..a1n], Some(&mut gb[..x1n]));
    let n0 = b * h * h * 3;
    bwd!(0, &x0[..n0], &gb[..x1n], None);
}

/// Eval-mode forward pass with running BN statistics (mean/var pairs per
/// conv layer, manifest `bn_stats` order). Fills `ws.logits`.
pub fn forward_eval_ws(
    d: &Dims,
    params: &[&[f32]],
    bn: &[&[f32]],
    images: &[f32],
    b: usize,
    threads: usize,
    ws: &mut Workspace,
) {
    debug_assert_eq!(params.len(), NUM_PARAM_TENSORS);
    debug_assert_eq!(bn.len(), 2 * NUM_CONV_LAYERS);
    ws.ensure(d, b);
    let layers = conv_layers(d);
    let h = d.image_size;
    let c = d.width;
    let nc = d.num_classes;
    let Workspace {
        gemm: gs,
        x1,
        x2,
        x3,
        x4,
        x5,
        x6,
        x7,
        pool_idx,
        hmax,
        u,
        v,
        act,
        r3,
        hfeat,
        logits,
        scale,
        ..
    } = ws;

    macro_rules! fwd {
        ($li:expr, $x:expr, $out:expr) => {
            block_fwd_eval($li, &layers, params, bn, b, threads, $x, $out, u, v, scale, gs)
        };
    }

    let n0 = b * h * h * 3;
    let x1n = b * h * h * c;
    fwd!(0, &images[..n0], &mut x1[..x1n]);
    let a1n = b * h * h * 2 * c;
    fwd!(1, &x1[..x1n], &mut act[..a1n]);
    let p1n = b * (h / 2) * (h / 2) * 2 * c;
    k::maxpool2_into(
        &act[..a1n],
        b,
        h,
        h,
        2 * c,
        &mut x2[..p1n],
        &mut pool_idx[0][..p1n],
    );
    fwd!(2, &x2[..p1n], &mut x3[..p1n]);
    fwd!(3, &x3[..p1n], &mut x4[..p1n]);
    add_into(&mut x4[..p1n], &x2[..p1n]);
    let a4n = b * (h / 2) * (h / 2) * 4 * c;
    fwd!(4, &x4[..p1n], &mut act[..a4n]);
    let p2n = b * (h / 4) * (h / 4) * 4 * c;
    k::maxpool2_into(
        &act[..a4n],
        b,
        h / 2,
        h / 2,
        4 * c,
        &mut x5[..p2n],
        &mut pool_idx[1][..p2n],
    );
    let a5n = b * (h / 4) * (h / 4) * 8 * c;
    fwd!(5, &x5[..p2n], &mut act[..a5n]);
    let p3n = b * (h / 8) * (h / 8) * 8 * c;
    k::maxpool2_into(
        &act[..a5n],
        b,
        h / 4,
        h / 4,
        8 * c,
        &mut x6[..p3n],
        &mut pool_idx[2][..p3n],
    );
    fwd!(6, &x6[..p3n], &mut x7[..p3n]);
    fwd!(7, &x7[..p3n], &mut r3[..p3n]);
    add_into(&mut r3[..p3n], &x6[..p3n]);

    let hw3 = (h / 8) * (h / 8);
    let c8 = 8 * c;
    k::global_maxpool_into(&r3[..p3n], b, hw3, c8, &mut hfeat[..b * c8], &mut hmax[..b * c8]);
    gemm::matmul_into(
        &mut logits[..b * nc],
        &hfeat[..b * c8],
        params[24],
        b,
        c8,
        nc,
        threads,
        gs,
    );
    let bias = params[25];
    for bi in 0..b {
        for j in 0..nc {
            logits[bi * nc + j] = (logits[bi * nc + j] + bias[j]) * HEAD_SCALE;
        }
    }
}

/// The int8 serving model: every conv weight and the head linear weight
/// quantized (per-tensor symmetric) and pre-packed into GEMM panels once
/// at load. BN gamma/beta/moments, the head bias and every non-GEMM op
/// (BN-eval, ReLU, pooling, residual adds) stay f32 — only the GEMMs run
/// in the quantized domain, which is where the FLOPs are.
pub struct QuantModel {
    /// per conv layer: the packed `(9·cin, cout)` weight
    pub convs: Vec<QuantTensor>,
    /// the packed `(8c, num_classes)` head weight
    pub head: QuantTensor,
}

impl QuantModel {
    /// Quantize a manifest-ordered parameter view set (what
    /// `NativeBackend::param_views` yields) for model `d`.
    pub fn from_params(d: &Dims, params: &[&[f32]]) -> QuantModel {
        debug_assert_eq!(params.len(), NUM_PARAM_TENSORS);
        let layers = conv_layers(d);
        let convs = layers
            .iter()
            .enumerate()
            .map(|(li, (_n, cin, cout, _s))| QuantTensor::quantize(params[3 * li], 9 * cin, *cout))
            .collect();
        let head = QuantTensor::quantize(params[24], 8 * d.width, d.num_classes);
        QuantModel { convs, head }
    }
}

/// One conv+BN+ReLU block, eval mode, int8 conv: dynamic activation
/// quantization + pre-packed weight panels; BN/ReLU stay f32.
#[allow(clippy::too_many_arguments)]
fn block_fwd_eval_q(
    li: usize,
    layers: &Layers,
    qm: &QuantModel,
    params: &[&[f32]],
    bn: &[&[f32]],
    b: usize,
    threads: usize,
    tier: Tier,
    x: &[f32],
    out: &mut [f32],
    u: &mut [f32],
    v: &mut [f32],
    scale: &mut [f32],
    qs: &mut QuantScratch,
) {
    let (_, cin, cout, side) = layers[li];
    let rows = b * side * side;
    let n = rows * cout;
    let us = &mut u[..n];
    qgemm::qconv3x3_into(us, x, b, side, side, cin, &qm.convs[li], threads, tier, qs);
    k::bn_eval_into(
        us,
        params[3 * li + 1],
        params[3 * li + 2],
        bn[2 * li],
        bn[2 * li + 1],
        rows,
        cout,
        threads,
        &mut v[..n],
        &mut scale[..cout],
    );
    k::relu_into(&v[..n], out);
}

/// [`forward_eval_ws`] on the int8 tier: the same eval chain with every
/// GEMM replaced by its quantized counterpart, pinned to an explicit
/// dispatch [`Tier`]. Fills `ws.logits`. Logits agree with the f32 path
/// under the parity-tolerance contract (top-1 agreement + bounded logit
/// error, `rust/tests/serving.rs`) — not bitwise; that is inherent to
/// quantization. Across SIMD tiers the *quantized* path itself IS
/// bitwise deterministic (exact i32 accumulation).
#[allow(clippy::too_many_arguments)]
pub fn forward_eval_q_ws(
    d: &Dims,
    qm: &QuantModel,
    params: &[&[f32]],
    bn: &[&[f32]],
    images: &[f32],
    b: usize,
    threads: usize,
    tier: Tier,
    ws: &mut Workspace,
) {
    debug_assert_eq!(params.len(), NUM_PARAM_TENSORS);
    debug_assert_eq!(bn.len(), 2 * NUM_CONV_LAYERS);
    ws.ensure(d, b);
    let layers = conv_layers(d);
    let h = d.image_size;
    let c = d.width;
    let nc = d.num_classes;
    let Workspace {
        quant: qs,
        x1,
        x2,
        x3,
        x4,
        x5,
        x6,
        x7,
        pool_idx,
        hmax,
        u,
        v,
        act,
        r3,
        hfeat,
        logits,
        scale,
        ..
    } = ws;

    macro_rules! fwd {
        ($li:expr, $x:expr, $out:expr) => {
            block_fwd_eval_q(
                $li, &layers, qm, params, bn, b, threads, tier, $x, $out, u, v, scale, qs,
            )
        };
    }

    let n0 = b * h * h * 3;
    let x1n = b * h * h * c;
    fwd!(0, &images[..n0], &mut x1[..x1n]);
    let a1n = b * h * h * 2 * c;
    fwd!(1, &x1[..x1n], &mut act[..a1n]);
    let p1n = b * (h / 2) * (h / 2) * 2 * c;
    k::maxpool2_into(
        &act[..a1n],
        b,
        h,
        h,
        2 * c,
        &mut x2[..p1n],
        &mut pool_idx[0][..p1n],
    );
    fwd!(2, &x2[..p1n], &mut x3[..p1n]);
    fwd!(3, &x3[..p1n], &mut x4[..p1n]);
    add_into(&mut x4[..p1n], &x2[..p1n]);
    let a4n = b * (h / 2) * (h / 2) * 4 * c;
    fwd!(4, &x4[..p1n], &mut act[..a4n]);
    let p2n = b * (h / 4) * (h / 4) * 4 * c;
    k::maxpool2_into(
        &act[..a4n],
        b,
        h / 2,
        h / 2,
        4 * c,
        &mut x5[..p2n],
        &mut pool_idx[1][..p2n],
    );
    let a5n = b * (h / 4) * (h / 4) * 8 * c;
    fwd!(5, &x5[..p2n], &mut act[..a5n]);
    let p3n = b * (h / 8) * (h / 8) * 8 * c;
    k::maxpool2_into(
        &act[..a5n],
        b,
        h / 4,
        h / 4,
        8 * c,
        &mut x6[..p3n],
        &mut pool_idx[2][..p3n],
    );
    fwd!(6, &x6[..p3n], &mut x7[..p3n]);
    fwd!(7, &x7[..p3n], &mut r3[..p3n]);
    add_into(&mut r3[..p3n], &x6[..p3n]);

    let hw3 = (h / 8) * (h / 8);
    let c8 = 8 * c;
    k::global_maxpool_into(&r3[..p3n], b, hw3, c8, &mut hfeat[..b * c8], &mut hmax[..b * c8]);
    qgemm::qmatmul_into(&mut logits[..b * nc], &hfeat[..b * c8], b, &qm.head, threads, tier, qs);
    let bias = params[25];
    for bi in 0..b {
        for j in 0..nc {
            logits[bi * nc + j] = (logits[bi * nc + j] + bias[j]) * HEAD_SCALE;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> Dims {
        Dims { width: 2, num_classes: 4, image_size: 8 }
    }

    #[test]
    fn conv_layer_table_matches_python() {
        let d = dims();
        let l = conv_layers(&d);
        assert_eq!(l[0], ("prep", 3, 2, 8));
        assert_eq!(l[1], ("layer1", 2, 4, 8));
        assert_eq!(l[2], ("res1a", 4, 4, 4));
        assert_eq!(l[4], ("layer2", 4, 8, 4));
        assert_eq!(l[5], ("layer3", 8, 16, 2));
        assert_eq!(l[7], ("res3b", 16, 16, 1));
    }

    #[test]
    fn flops_match_aot_formula() {
        // width 4, image 16 (the tiny preset): recompute by hand
        let d = Dims { width: 4, num_classes: 10, image_size: 16 };
        let mut want: u64 = 0;
        for (cin, cout, side) in [
            (3usize, 4usize, 16usize),
            (4, 8, 16),
            (8, 8, 8),
            (8, 8, 8),
            (8, 16, 8),
            (16, 32, 4),
            (32, 32, 2),
            (32, 32, 2),
        ] {
            want += 2 * (side * side * 9 * cin * cout) as u64;
        }
        want += 2 * 32 * 10;
        assert_eq!(flops_fwd_per_example(&d), want);
    }

    #[test]
    fn param_offsets_walk_the_manifest_order() {
        let d = dims();
        let layers = conv_layers(&d);
        assert_eq!(param_offset(&layers, 0), 0);
        // prep: 27*2 w + 2 gamma + 2 beta
        assert_eq!(param_offset(&layers, 1), 27 * 2 + 4);
        let total: usize = layers.iter().map(|(_, ci, co, _)| 9 * ci * co + 2 * co).sum();
        assert_eq!(param_offset(&layers, NUM_CONV_LAYERS), total);
    }
}
