//! The **native** execution backend: pure-Rust ResNet9s forward/backward
//! (`model`), flat-NHWC kernels (`kernels`), the blocked GEMM tier
//! (`gemm`), the persistent kernel workspace (`workspace`) and an
//! in-memory manifest builder — no AOT artifacts, no XLA toolchain,
//! bitwise-deterministic.
//!
//! This is the default backend: it makes the whole SWAP coordinator
//! hermetically testable (`cargo test` runs end-to-end SWAP on synthetic
//! data with it) and is the baseline every accelerator backend is checked
//! against (rust/tests/kernel_parity.rs pins it to the python oracles).
//!
//! The engine owns a pool of [`workspace::Workspace`]s behind a mutex:
//! every entry point pops one workspace for the duration of the call and
//! returns it afterwards, so concurrent callers (SWAP phase-2 workers,
//! phase-1 shards) never contend inside a step and a steady-state
//! `train_step` performs **zero heap allocations**
//! (rust/tests/alloc_regression.rs).

pub mod gemm;
pub mod kernels;
pub mod model;
pub mod qgemm;
pub mod workspace;

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use super::backend::Backend;
use super::manifest::{Manifest, ModelMeta, TensorSpec};
use super::types::{BatchStats, GradResult, HostBatch};
use crate::model::ParamLayout;
use crate::util::{Error, Result};

use self::model::Dims;
use self::workspace::Workspace;

/// Upper bound on pooled workspaces: enough for any realistic concurrent
/// fan-out (phase-2 workers are capped far below this); beyond it a
/// returning workspace is simply dropped. The pool vector is pre-reserved
/// to this capacity so returning a workspace never reallocates.
const WORKSPACE_POOL_CAP: usize = 64;

/// Construction parameters of a native backend (the analogue of an AOT
/// preset's `manifest.json`). Widths/classes mirror `python/compile/aot.py`.
#[derive(Debug, Clone)]
pub struct NativeSpec {
    pub preset: String,
    pub width: usize,
    pub num_classes: usize,
    pub image_size: usize,
    /// Nesterov momentum / coupled weight decay (paper §5.1)
    pub momentum: f32,
    pub weight_decay: f32,
    /// advertised batch sizes (informational — the native backend accepts
    /// any batch size, unlike per-batch AOT executables)
    pub batches: Vec<usize>,
    /// worker threads the heavy kernels (GEMM/BN/col2im) may split
    /// output rows across; 1 = fully sequential. Any value produces
    /// bitwise-identical results (see `coordinator::parallel`).
    pub threads: usize,
}

impl NativeSpec {
    pub fn new(preset: &str, width: usize, num_classes: usize, image_size: usize) -> Self {
        NativeSpec {
            preset: preset.to_string(),
            width,
            num_classes,
            image_size,
            momentum: 0.9,
            weight_decay: 5e-4,
            batches: Vec::new(),
            threads: 1,
        }
    }

    pub fn with_batches(mut self, batches: &[usize]) -> Self {
        self.batches = batches.to_vec();
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// The fast unit/integration-test model (aot.py's `tiny` preset).
    pub fn tiny() -> Self {
        NativeSpec::new("tiny", 4, 10, 16).with_batches(&[8])
    }

    fn dims(&self) -> Dims {
        Dims {
            width: self.width,
            num_classes: self.num_classes,
            image_size: self.image_size,
        }
    }
}

/// Ordered parameter specs — the manifest/rust layout contract, identical
/// to `python/compile/model.py::param_specs`.
pub fn param_specs(spec: &NativeSpec) -> Vec<TensorSpec> {
    let mut out = Vec::with_capacity(model::NUM_PARAM_TENSORS);
    for (name, cin, cout, _side) in model::conv_layers(&spec.dims()) {
        out.push(TensorSpec { name: format!("{name}.w"), shape: vec![cin * 9, cout] });
        out.push(TensorSpec { name: format!("{name}.gamma"), shape: vec![cout] });
        out.push(TensorSpec { name: format!("{name}.beta"), shape: vec![cout] });
    }
    out.push(TensorSpec {
        name: "head.w".to_string(),
        shape: vec![8 * spec.width, spec.num_classes],
    });
    out.push(TensorSpec { name: "head.b".to_string(), shape: vec![spec.num_classes] });
    out
}

/// Ordered BN running-statistic specs (mean, var per conv layer).
pub fn bn_specs(spec: &NativeSpec) -> Vec<TensorSpec> {
    let mut out = Vec::with_capacity(2 * model::NUM_CONV_LAYERS);
    for (name, _cin, cout, _side) in model::conv_layers(&spec.dims()) {
        out.push(TensorSpec { name: format!("{name}.mean"), shape: vec![cout] });
        out.push(TensorSpec { name: format!("{name}.var"), shape: vec![cout] });
    }
    out
}

/// Build the layout contract in memory — the native twin of parsing
/// `artifacts/<preset>/manifest.json`.
pub fn native_manifest(spec: &NativeSpec) -> Manifest {
    let params = param_specs(spec);
    let num_params = params.iter().map(|s| s.numel()).sum();
    Manifest {
        preset: spec.preset.clone(),
        model: ModelMeta {
            arch: "resnet9s".to_string(),
            width: spec.width,
            num_classes: spec.num_classes,
            image_size: spec.image_size,
            momentum: spec.momentum,
            weight_decay: spec.weight_decay,
            head_scale: model::HEAD_SCALE,
            bn_eps: kernels::BN_EPS,
        },
        params,
        bn_stats: bn_specs(spec),
        num_params,
        batches: spec.batches.clone(),
        executables: BTreeMap::new(),
        flops_fwd_per_example: model::flops_fwd_per_example(&spec.dims()),
        dir: PathBuf::new(),
    }
}

/// The pure-Rust engine.
pub struct NativeBackend {
    manifest: Manifest,
    /// the arena packing convention, built once from the manifest — the
    /// single source of per-tensor offsets for every entry point
    param_layout: Arc<ParamLayout>,
    bn_layout: Arc<ParamLayout>,
    dims: Dims,
    /// kernel worker-thread budget (never changes results, only wall time)
    threads: usize,
    /// persistent kernel workspaces: one per concurrent caller, reused
    /// across steps (the zero-allocation steady state)
    workspaces: Mutex<Vec<Box<Workspace>>>,
}

impl NativeBackend {
    pub fn new(spec: NativeSpec) -> Result<Self> {
        if spec.width == 0 || spec.num_classes < 2 {
            return Err(Error::config(format!(
                "native backend: width {} / num_classes {} invalid",
                spec.width, spec.num_classes
            )));
        }
        if spec.image_size == 0 || spec.image_size % 8 != 0 {
            return Err(Error::config(format!(
                "native backend: image_size {} must be a positive multiple of 8 \
                 (three 2x2 pools)",
                spec.image_size
            )));
        }
        let dims = Dims {
            width: spec.width,
            num_classes: spec.num_classes,
            image_size: spec.image_size,
        };
        let threads = spec.threads.max(1);
        let manifest = native_manifest(&spec);
        let param_layout = ParamLayout::of_params(&manifest);
        let bn_layout = ParamLayout::of_bn(&manifest);
        Ok(NativeBackend {
            manifest,
            param_layout,
            bn_layout,
            dims,
            threads,
            workspaces: Mutex::new(Vec::with_capacity(WORKSPACE_POOL_CAP)),
        })
    }

    /// The tiny test model (width 4, 10 classes, 16x16 images).
    pub fn tiny() -> Self {
        NativeBackend::new(NativeSpec::tiny()).expect("tiny spec is valid")
    }

    fn check_batch(&self, batch: &HostBatch) -> Result<()> {
        let im = self.dims.image_size;
        if batch.image_size != im {
            return Err(Error::shape(format!(
                "batch image size {} != model image size {im}",
                batch.image_size
            )));
        }
        if batch.images.len() != batch.batch * im * im * 3 {
            return Err(Error::shape(format!(
                "image buffer {} != {}x{im}x{im}x3",
                batch.images.len(),
                batch.batch
            )));
        }
        if batch.labels.len() != batch.batch {
            return Err(Error::shape("label count != batch size"));
        }
        let k = self.dims.num_classes as i32;
        if batch.labels.iter().any(|&y| y < 0 || y >= k) {
            return Err(Error::invalid(format!("label out of range [0,{k})")));
        }
        Ok(())
    }

    /// Run `f` with a pooled workspace: pop one (or build the pool's
    /// first on a cold start), hand it to `f`, return it afterwards.
    /// Steady-state this allocates nothing — the pool vector is
    /// pre-reserved and the workspace buffers are grow-only.
    fn with_workspace<R>(&self, f: impl FnOnce(&mut Workspace) -> R) -> R {
        let popped = self.workspaces.lock().unwrap().pop();
        let mut ws = match popped {
            Some(w) => w,
            None => Box::new(Workspace::new()),
        };
        let out = f(&mut ws);
        let mut pool = self.workspaces.lock().unwrap();
        if pool.len() < pool.capacity() {
            pool.push(ws);
        }
        out
    }

    /// Manifest-ordered immutable views over the parameter arena, sliced
    /// at the layout's per-tensor boundaries (a fixed-size array: no
    /// allocation on the hot path).
    fn param_views<'a>(
        &self,
        params: &'a [f32],
    ) -> Result<[&'a [f32]; model::NUM_PARAM_TENSORS]> {
        if params.len() != self.param_layout.total() {
            return Err(Error::shape(format!(
                "param arena has {} f32s, manifest wants {}",
                params.len(),
                self.param_layout.total()
            )));
        }
        debug_assert_eq!(self.param_layout.len(), model::NUM_PARAM_TENSORS);
        let mut v = [&params[0..0]; model::NUM_PARAM_TENSORS];
        for (i, slot) in v.iter_mut().enumerate() {
            *slot = &params[self.param_layout.range(i)];
        }
        Ok(v)
    }

    /// Manifest-ordered views over the BN running-statistics arena.
    fn bn_views<'a>(
        &self,
        bn_stats: &'a [f32],
    ) -> Result<[&'a [f32]; 2 * model::NUM_CONV_LAYERS]> {
        if bn_stats.len() != self.bn_layout.total() {
            return Err(Error::shape(format!(
                "bn arena has {} f32s, manifest wants {}",
                bn_stats.len(),
                self.bn_layout.total()
            )));
        }
        debug_assert_eq!(self.bn_layout.len(), 2 * model::NUM_CONV_LAYERS);
        let mut v = [&bn_stats[0..0]; 2 * model::NUM_CONV_LAYERS];
        for (i, slot) in v.iter_mut().enumerate() {
            *slot = &bn_stats[self.bn_layout.range(i)];
        }
        Ok(v)
    }

    /// Shared grad path: train-mode forward + backward of the mean loss
    /// into the workspace's flat gradient arena (`ws.grads`).
    fn grad_into_ws(
        &self,
        params: &[f32],
        batch: &HostBatch,
        ws: &mut Workspace,
    ) -> Result<BatchStats> {
        self.check_batch(batch)?;
        let p = self.param_views(params)?;
        let b = batch.batch;
        let nc = self.dims.num_classes;
        model::forward_train_ws(&self.dims, &p, &batch.images, b, self.threads, ws);
        let (sum_loss, c1, c5) = kernels::cross_entropy_into(
            &ws.logits[..b * nc],
            &batch.labels,
            b,
            nc,
            &mut ws.dl[..b * nc],
        );
        // grads of the MEAN batch loss (the python grad_step convention)
        let inv_b = 1.0 / b as f32;
        for d in ws.dl[..b * nc].iter_mut() {
            *d *= inv_b;
        }
        model::backward_ws(&self.dims, &p, b, self.threads, ws);
        debug_assert!(ws.grads.len() >= self.manifest.num_params);
        Ok(BatchStats {
            sum_loss,
            correct1: c1,
            correct5: c5,
            examples: b as i64,
        })
    }

    /// Static model dimensions.
    pub fn dims(&self) -> Dims {
        self.dims
    }

    /// Validate a raw (unlabeled) image buffer for a batch of `b`.
    fn check_images(&self, images: &[f32], b: usize) -> Result<()> {
        let im = self.dims.image_size;
        if images.len() != b * im * im * 3 {
            return Err(Error::shape(format!(
                "image buffer {} != {b}x{im}x{im}x3",
                images.len()
            )));
        }
        Ok(())
    }

    /// Logits-only eval forward into a caller buffer, using a caller-owned
    /// [`Workspace`] — the serving hot path (shard workers own their
    /// workspace so steady-state inference allocates nothing) and the
    /// logits half of [`Backend::eval_batch`] without the
    /// cross-entropy/loss tail. `threads` is per call: serving shards run
    /// at 1 (the shard fan-out is the parallelism).
    #[allow(clippy::too_many_arguments)]
    pub fn eval_logits_ws(
        &self,
        params: &[f32],
        bn_stats: &[f32],
        images: &[f32],
        b: usize,
        threads: usize,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> Result<()> {
        self.check_images(images, b)?;
        let p = self.param_views(params)?;
        let bn = self.bn_views(bn_stats)?;
        let nc = self.dims.num_classes;
        if out.len() != b * nc {
            return Err(Error::shape(format!("logits buffer {} != {b}x{nc}", out.len())));
        }
        model::forward_eval_ws(&self.dims, &p, &bn, images, b, threads.max(1), ws);
        out.copy_from_slice(&ws.logits[..b * nc]);
        Ok(())
    }

    /// [`NativeBackend::eval_logits_ws`] on the int8 tier: same chain,
    /// quantized GEMMs from the pre-packed [`model::QuantModel`], pinned
    /// to an explicit SIMD [`crate::util::simd::Tier`].
    #[allow(clippy::too_many_arguments)]
    pub fn eval_logits_quant_ws(
        &self,
        qm: &model::QuantModel,
        params: &[f32],
        bn_stats: &[f32],
        images: &[f32],
        b: usize,
        threads: usize,
        tier: crate::util::simd::Tier,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> Result<()> {
        self.check_images(images, b)?;
        let p = self.param_views(params)?;
        let bn = self.bn_views(bn_stats)?;
        let nc = self.dims.num_classes;
        if out.len() != b * nc {
            return Err(Error::shape(format!("logits buffer {} != {b}x{nc}", out.len())));
        }
        model::forward_eval_q_ws(&self.dims, qm, &p, &bn, images, b, threads.max(1), tier, ws);
        out.copy_from_slice(&ws.logits[..b * nc]);
        Ok(())
    }

    /// Quantize the parameter arena into a pre-packed int8 serving model
    /// (per-tensor symmetric scales, computed once here).
    pub fn quantize_model(&self, params: &[f32]) -> Result<model::QuantModel> {
        let p = self.param_views(params)?;
        Ok(model::QuantModel::from_params(&self.dims, &p))
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn grad(&self, params: &[f32], batch: &HostBatch) -> Result<GradResult> {
        self.with_workspace(|ws| {
            let stats = self.grad_into_ws(params, batch, ws)?;
            // the trait returns an owned arena: one copy out of the
            // workspace (train_step, the steady-state path, avoids it)
            let grads = ws.grads[..self.manifest.num_params].to_vec();
            Ok(GradResult { grads, stats })
        })
    }

    fn train_step(
        &self,
        params: &mut [f32],
        momentum: &mut [f32],
        batch: &HostBatch,
        lr: f32,
    ) -> Result<BatchStats> {
        if momentum.len() != params.len() {
            return Err(Error::shape(format!(
                "momentum arena has {} f32s, params {}",
                momentum.len(),
                params.len()
            )));
        }
        let (mu, wd) = (self.manifest.model.momentum, self.manifest.model.weight_decay);
        self.with_workspace(|ws| {
            let stats = self.grad_into_ws(params, batch, ws)?;
            // one fused pass over the whole arena (same elementwise order
            // as the legacy per-tensor loop — bitwise identical)
            crate::tensor::flat::sgd_step(
                self.threads,
                params,
                momentum,
                &ws.grads[..self.manifest.num_params],
                lr,
                mu,
                wd,
            );
            Ok(stats)
        })
    }

    fn eval_batch(
        &self,
        params: &[f32],
        bn_stats: &[f32],
        batch: &HostBatch,
    ) -> Result<BatchStats> {
        self.check_batch(batch)?;
        let p = self.param_views(params)?;
        let bn = self.bn_views(bn_stats)?;
        let b = batch.batch;
        let nc = self.dims.num_classes;
        self.with_workspace(|ws| {
            model::forward_eval_ws(&self.dims, &p, &bn, &batch.images, b, self.threads, ws);
            let (sum_loss, c1, c5) = kernels::cross_entropy_into(
                &ws.logits[..b * nc],
                &batch.labels,
                b,
                nc,
                &mut ws.dl[..b * nc],
            );
            Ok(BatchStats {
                sum_loss,
                correct1: c1,
                correct5: c5,
                examples: b as i64,
            })
        })
    }

    fn supports_logits_only(&self) -> bool {
        true
    }

    fn eval_batch_top1(
        &self,
        params: &[f32],
        bn_stats: &[f32],
        batch: &HostBatch,
    ) -> Result<BatchStats> {
        self.check_batch(batch)?;
        let p = self.param_views(params)?;
        let bn = self.bn_views(bn_stats)?;
        let b = batch.batch;
        let nc = self.dims.num_classes;
        self.with_workspace(|ws| {
            model::forward_eval_ws(&self.dims, &p, &bn, &batch.images, b, self.threads, ws);
            // logits-only tail: rank counting, no softmax/loss (the exact
            // top-k rule of cross_entropy_into, so accuracy is identical)
            let (c1, c5) = kernels::top_counts(&ws.logits[..b * nc], &batch.labels, b, nc);
            Ok(BatchStats {
                sum_loss: 0.0,
                correct1: c1,
                correct5: c5,
                examples: b as i64,
            })
        })
    }

    fn bn_moments(&self, params: &[f32], batch: &HostBatch) -> Result<Vec<f32>> {
        self.check_batch(batch)?;
        let p = self.param_views(params)?;
        self.with_workspace(|ws| {
            // train-mode forward: the per-layer batch moments are exactly
            // the bnstats entry point's output (the head is negligible)
            model::forward_train_ws(
                &self.dims,
                &p,
                &batch.images,
                batch.batch,
                self.threads,
                ws,
            );
            let total = self.bn_layout.total();
            let mut flat = Vec::with_capacity(total);
            let layers = model::conv_layers(&self.dims);
            for (li, (_name, _cin, cout, _side)) in layers.iter().enumerate() {
                flat.extend_from_slice(&ws.mean[li][..*cout]);
                flat.extend_from_slice(&ws.var[li][..*cout]);
            }
            if flat.len() != total {
                return Err(Error::shape(format!(
                    "bn moments produced {} elements, manifest wants {total}",
                    flat.len()
                )));
            }
            Ok(flat)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_manifest_matches_artifact_contract() {
        let b = NativeBackend::tiny();
        let m = b.manifest();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.model.arch, "resnet9s");
        assert_eq!(m.params.len(), 26);
        assert_eq!(m.bn_stats.len(), 16);
        assert_eq!(m.params[0].name, "prep.w");
        assert_eq!(m.params[0].shape, vec![27, 4]);
        assert_eq!(m.params[24].name, "head.w");
        assert_eq!(m.params[24].shape, vec![32, 10]);
        assert_eq!(m.params[25].name, "head.b");
        assert_eq!(m.bn_stats[0].name, "prep.mean");
        assert_eq!(m.bn_stats[15].name, "res3b.var");
        let declared: usize = m.params.iter().map(|s| s.numel()).sum();
        assert_eq!(m.num_params, declared);
        assert!(m.flops_fwd_per_example > 0);
        assert!(m.batches.contains(&8));
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(NativeBackend::new(NativeSpec::new("x", 0, 10, 16)).is_err());
        assert!(NativeBackend::new(NativeSpec::new("x", 4, 1, 16)).is_err());
        assert!(NativeBackend::new(NativeSpec::new("x", 4, 10, 12)).is_err());
        assert!(NativeBackend::new(NativeSpec::new("x", 4, 10, 0)).is_err());
    }

    #[test]
    fn rejects_malformed_batches_and_params() {
        use crate::model::ParamSet;
        let b = NativeBackend::tiny();
        let params = ParamSet::init(b.manifest(), 0);
        let bad = HostBatch {
            images: vec![0.0; 10],
            labels: vec![0, 1],
            batch: 2,
            image_size: 16,
        };
        assert!(b.grad(params.as_slice(), &bad).is_err());
        let good = HostBatch {
            images: vec![0.0; 2 * 16 * 16 * 3],
            labels: vec![0, 11], // label out of range
            batch: 2,
            image_size: 16,
        };
        assert!(b.grad(params.as_slice(), &good).is_err());
        let ok = HostBatch {
            images: vec![0.1; 2 * 16 * 16 * 3],
            labels: vec![0, 3],
            batch: 2,
            image_size: 16,
        };
        assert!(b.grad(&params.as_slice()[..5], &ok).is_err());
        assert!(b.grad(params.as_slice(), &ok).is_ok());
    }

    #[test]
    fn workspace_reuse_is_bitwise_stable() {
        // the pooled workspace is dirty after the first call; every
        // buffer must be fully (re)written, so repeated calls are
        // bitwise identical — including across intervening batch sizes
        use crate::model::ParamSet;
        let b = NativeBackend::tiny();
        let params = ParamSet::init(b.manifest(), 7);
        let mk = |n: usize| HostBatch {
            images: (0..n * 16 * 16 * 3)
                .map(|i| ((i % 17) as f32 - 8.0) * 0.1)
                .collect(),
            labels: (0..n).map(|i| (i % 10) as i32).collect(),
            batch: n,
            image_size: 16,
        };
        let hb = mk(4);
        let g1 = b.grad(params.as_slice(), &hb).unwrap();
        let big = mk(8); // grows the pooled workspace
        let _ = b.grad(params.as_slice(), &big).unwrap();
        let g2 = b.grad(params.as_slice(), &hb).unwrap();
        assert_eq!(g1.grads, g2.grads);
        assert_eq!(g1.stats.sum_loss.to_bits(), g2.stats.sum_loss.to_bits());
        // moments are sliced to the true cout even on the grown workspace
        let m1 = b.bn_moments(params.as_slice(), &hb).unwrap();
        let m2 = b.bn_moments(params.as_slice(), &hb).unwrap();
        assert_eq!(m1, m2);
    }
}
