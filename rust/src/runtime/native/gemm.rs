//! Cache-blocked, register-tiled GEMM micro-kernels with panel packing —
//! the production matmul family of the native backend.
//!
//! ## Why this is fast
//!
//! The reference kernels in `super::kernels` (`matmul_reference` et al.)
//! walk the operands in place with a branchy scalar ikj loop: every inner
//! iteration re-derives slice bounds, tests `av == 0.0` (which defeats
//! vectorization on dense data) and streams the full B matrix per output
//! row. The blocked path instead:
//!
//! * packs the left operand into `MR`-wide, k-major **A panels** and the
//!   right operand into `NR`-wide, k-major **B panels**, so the micro-
//!   kernel reads two contiguous streams;
//! * computes an `MR x NR` register tile per output block with a straight
//!   (branch-free) multiply-add loop the auto-vectorizer can lower to SIMD;
//! * blocks the reduction dimension at `KC` and the packed rows at `MC` so
//!   the panels live in L1/L2 across the sweep.
//!
//! The im2col patch matrix of the 3x3 convolutions is never materialized:
//! [`ASrc::Im2col`] / [`ASrc::Im2colCols`] pack conv patches straight from
//! the NHWC image into the A panels (forward `patches @ W` and backward
//! `patchesᵀ @ dU` respectively), skipping the (B·H·W, 9·C)
//! materialize-then-repack round trip.
//!
//! ## SIMD dispatch
//!
//! The micro-kernel exists per [`Tier`]: the portable scalar loop (the
//! oracle and always-available fallback), an explicit AVX2 kernel on
//! x86_64 and a NEON kernel on aarch64, selected by one-time runtime
//! feature detection (`util::simd`, overridable via the `simd` config
//! knob / `SWAP_SIMD` env var). The vector kernels span the `NR = 8`
//! output **columns** with register lanes, so lane `j` replays output
//! element `(i, j)`'s scalar chain instruction for instruction — and they
//! use separate multiply + add vector ops (two roundings, the scalar op
//! sequence), never fused multiply-add, whose single rounding would
//! break parity. Ragged edge strips (`nr < NR`) take the scalar kernel:
//! the edge is a vanishing share of the FLOPs and skipping masked loads
//! keeps the hot kernel branch-free.
//!
//! ## Why it is still bitwise deterministic
//!
//! Every output element is an f32 accumulation chain that starts at 0.0
//! and adds `a[i][p] * b[p][j]` in ascending-`p` order — exactly the
//! per-element order of the reference kernels. Tiling never reorders a
//! chain: the first `KC` block initializes the register tile from zero,
//! later blocks reload the partial result (f32 store/load is exact) and
//! keep adding in ascending `p` order. Threads partition **output rows
//! only** (the reduction is never split), so `threads = N` is bitwise
//! identical to `threads = 1`, and the whole family is bitwise identical
//! to the reference kernels on finite inputs (the reference's
//! `av == 0.0` skip only diverges when B holds NaN/Inf — pinned by
//! `rust/tests/gemm_oracle.rs`, which also pins SIMD == scalar per tier).
//!
//! All entry points are `*_into`: outputs and packing buffers come from
//! the caller (the per-engine [`super::workspace::Workspace`]), so a
//! steady-state call performs zero heap allocations.

use crate::coordinator::parallel;
use crate::util::simd::{self, Tier};

/// Register micro-tile rows (output rows per tile).
pub const MR: usize = 8;
/// Register micro-tile columns (output columns per tile).
pub const NR: usize = 8;
/// Packed row-block height: `MC x KC` A panels are packed per thread.
pub const MC: usize = 64;
/// Reduction block: panels cover `KC` of the k dimension at a time.
pub const KC: usize = 256;

/// Minimum multiply-add ops per worker before the row partition spawns
/// another thread (wall-time knob only; results never depend on it).
const GEMM_MIN_WORK: usize = 1 << 18;

/// Per-thread packing scratch (the A panels of one row chunk).
#[derive(Default)]
pub struct PackBuf {
    a: Vec<f32>,
}

/// Call-shared GEMM scratch owned by the engine workspace: one packed
/// B-panel buffer (read by every worker) plus one [`PackBuf`] per worker.
/// Buffers grow to the largest shape seen and are then reused verbatim.
#[derive(Default)]
pub struct GemmScratch {
    bpack: Vec<f32>,
    packs: Vec<PackBuf>,
}

/// Left operand of a blocked GEMM: how to pack `MR`-wide k-major A panels.
#[derive(Clone, Copy)]
pub enum ASrc<'a> {
    /// Dense row-major `(m, lda)` matrix; element `(i, p) = a[i * lda + p]`.
    Rows { a: &'a [f32], lda: usize },
    /// Dense row-major `(k, lda)` matrix read transposed; element
    /// `(i, p) = a[p * lda + i]` (the `matmul_tn` left operand).
    Cols { a: &'a [f32], lda: usize },
    /// Virtual im2col patch matrix of a 3x3 SAME conv over NHWC `x`:
    /// `(b*h*w, 9*c)`, element `(row, p)` = patch channel `p` of output
    /// pixel `row` (zero at the padding taps).
    Im2col { x: &'a [f32], b: usize, h: usize, w: usize, c: usize },
    /// The transposed virtual patch matrix: element `(i, p)` = patch
    /// channel `i` of output pixel `p` (the dW left operand).
    Im2colCols { x: &'a [f32], b: usize, h: usize, w: usize, c: usize },
}

/// Right operand: how to pack `NR`-wide k-major B panels.
#[derive(Clone, Copy)]
pub enum BSrc<'a> {
    /// Dense row-major `(k, n)`; element `(p, j) = b[p * n + j]`.
    Rows { b: &'a [f32] },
    /// Dense row-major `(n, k)` read transposed; element
    /// `(p, j) = b[j * k + p]` (the `matmul_nt` right operand).
    Cols { b: &'a [f32] },
}

/// out(m,n) = a(m,k) @ b(k,n), blocked. Bitwise equal to
/// `kernels::matmul_reference` on finite inputs, for every `threads`,
/// dispatching on the process-wide [`simd::active`] tier.
pub fn matmul_into(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
    scratch: &mut GemmScratch,
) {
    matmul_into_tier(out, a, b, m, k, n, threads, simd::active(), scratch);
}

/// [`matmul_into`] pinned to an explicit dispatch [`Tier`] — what the
/// per-tier parity tests and benches drive; every tier is bitwise
/// identical.
#[allow(clippy::too_many_arguments)]
pub fn matmul_into_tier(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
    tier: Tier,
    scratch: &mut GemmScratch,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    gemm_into(out, ASrc::Rows { a, lda: k }, BSrc::Rows { b }, m, k, n, threads, tier, scratch);
}

/// out(m,n) = aᵀ @ b where a is (r,m) and b is (r,n) — the dW matmul.
pub fn matmul_tn_into(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    r: usize,
    m: usize,
    n: usize,
    threads: usize,
    scratch: &mut GemmScratch,
) {
    matmul_tn_into_tier(out, a, b, r, m, n, threads, simd::active(), scratch);
}

/// [`matmul_tn_into`] pinned to an explicit dispatch [`Tier`].
#[allow(clippy::too_many_arguments)]
pub fn matmul_tn_into_tier(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    r: usize,
    m: usize,
    n: usize,
    threads: usize,
    tier: Tier,
    scratch: &mut GemmScratch,
) {
    debug_assert_eq!(a.len(), r * m);
    debug_assert_eq!(b.len(), r * n);
    gemm_into(out, ASrc::Cols { a, lda: m }, BSrc::Rows { b }, m, r, n, threads, tier, scratch);
}

/// out(m,n) = a(m,k) @ bᵀ where b is (n,k) — the dX matmul.
pub fn matmul_nt_into(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
    scratch: &mut GemmScratch,
) {
    matmul_nt_into_tier(out, a, b, m, k, n, threads, simd::active(), scratch);
}

/// [`matmul_nt_into`] pinned to an explicit dispatch [`Tier`].
#[allow(clippy::too_many_arguments)]
pub fn matmul_nt_into_tier(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
    tier: Tier,
    scratch: &mut GemmScratch,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    gemm_into(out, ASrc::Rows { a, lda: k }, BSrc::Cols { b }, m, k, n, threads, tier, scratch);
}

/// Fused 3x3 SAME convolution forward: out(b*h*w, cout) = im2col(x) @ w,
/// packing patches straight from the NHWC image (no patch matrix).
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_into(
    out: &mut [f32],
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    weights: &[f32],
    cout: usize,
    threads: usize,
    scratch: &mut GemmScratch,
) {
    conv3x3_into_tier(out, x, b, h, w, c, weights, cout, threads, simd::active(), scratch);
}

/// [`conv3x3_into`] pinned to an explicit dispatch [`Tier`].
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_into_tier(
    out: &mut [f32],
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    weights: &[f32],
    cout: usize,
    threads: usize,
    tier: Tier,
    scratch: &mut GemmScratch,
) {
    debug_assert_eq!(x.len(), b * h * w * c);
    debug_assert_eq!(weights.len(), 9 * c * cout);
    gemm_into(
        out,
        ASrc::Im2col { x, b, h, w, c },
        BSrc::Rows { b: weights },
        b * h * w,
        9 * c,
        cout,
        threads,
        tier,
        scratch,
    );
}

/// Fused conv weight gradient: out(9*c, cout) = im2col(x)ᵀ @ du, packing
/// transposed patches straight from the NHWC image.
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_dw_into(
    out: &mut [f32],
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    du: &[f32],
    cout: usize,
    threads: usize,
    scratch: &mut GemmScratch,
) {
    conv3x3_dw_into_tier(out, x, b, h, w, c, du, cout, threads, simd::active(), scratch);
}

/// [`conv3x3_dw_into`] pinned to an explicit dispatch [`Tier`].
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_dw_into_tier(
    out: &mut [f32],
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    du: &[f32],
    cout: usize,
    threads: usize,
    tier: Tier,
    scratch: &mut GemmScratch,
) {
    debug_assert_eq!(x.len(), b * h * w * c);
    debug_assert_eq!(du.len(), b * h * w * cout);
    gemm_into(
        out,
        ASrc::Im2colCols { x, b, h, w, c },
        BSrc::Rows { b: du },
        9 * c,
        b * h * w,
        cout,
        threads,
        tier,
        scratch,
    );
}

/// The shared blocked driver: pack B once (before any thread is spawned),
/// partition output rows across workers, and run the packed micro-kernel
/// sweep per chunk with that worker's own A-panel scratch, dispatching
/// each full-width strip on `tier`.
#[allow(clippy::too_many_arguments)]
fn gemm_into(
    out: &mut [f32],
    a: ASrc<'_>,
    b: BSrc<'_>,
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
    tier: Tier,
    scratch: &mut GemmScratch,
) {
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        for o in out.iter_mut() {
            *o = 0.0;
        }
        return;
    }
    pack_b(b, k, n, threads, &mut scratch.bpack);
    let workers = parallel::gate_per_chunk(threads, m * k * n, GEMM_MIN_WORK);
    if scratch.packs.len() < workers.max(1) {
        scratch.packs.resize_with(workers.max(1), PackBuf::default);
    }
    let bpack = &scratch.bpack[..];
    parallel::parallel_row_chunks_scratch(
        workers,
        out,
        n,
        MR,
        &mut scratch.packs,
        |row0, chunk, pack| gemm_chunk(a, bpack, row0, k, n, tier, chunk, pack),
    );
}

/// One worker's share: rows `[row0, row0 + chunk.len()/n)` of the output.
#[allow(clippy::too_many_arguments)]
fn gemm_chunk(
    a: ASrc<'_>,
    bpack: &[f32],
    row0: usize,
    k: usize,
    n: usize,
    tier: Tier,
    chunk: &mut [f32],
    pack: &mut PackBuf,
) {
    let rows = chunk.len() / n;
    let nstrips = (n + NR - 1) / NR;
    let mut pc = 0;
    while pc < k {
        let kc = KC.min(k - pc);
        let mut ic = 0;
        while ic < rows {
            let mc = MC.min(rows - ic);
            pack_a(a, row0 + ic, mc, pc, kc, &mut pack.a);
            let groups = (mc + MR - 1) / MR;
            for g in 0..groups {
                let ir = g * MR;
                let mr = MR.min(mc - ir);
                let apanel = &pack.a[g * kc * MR..(g + 1) * kc * MR];
                for s in 0..nstrips {
                    let j0 = s * NR;
                    let nr = NR.min(n - j0);
                    let bpanel = &bpack[s * k * NR + pc * NR..s * k * NR + (pc + kc) * NR];
                    let (crow, first) = (ic + ir, pc == 0);
                    match tier {
                        // SAFETY: the avx2 arm only becomes active after
                        // runtime feature detection (Tier::available /
                        // simd::resolve), and nr == NR guarantees the
                        // full-width loads/stores stay in bounds.
                        #[cfg(target_arch = "x86_64")]
                        Tier::Avx2 if nr == NR => unsafe {
                            micro_kernel_avx2(kc, apanel, bpanel, chunk, crow, j0, n, mr, first)
                        },
                        // SAFETY: same contract as the avx2 arm, gated on
                        // runtime neon detection.
                        #[cfg(target_arch = "aarch64")]
                        Tier::Neon if nr == NR => unsafe {
                            micro_kernel_neon(kc, apanel, bpanel, chunk, crow, j0, n, mr, first)
                        },
                        // ragged edge strips (nr < NR) and tiers of a
                        // foreign arch fall back to the scalar kernel
                        _ => micro_kernel(kc, apanel, bpanel, chunk, crow, j0, n, mr, nr, first),
                    }
                }
            }
            ic += mc;
        }
        pc += kc;
    }
}

/// The scalar register micro-kernel — the always-available dispatch tier
/// and the parity oracle for the vector tiers: an `MR x NR` accumulator
/// tile swept over one `kc`-long panel pair. `first` selects
/// init-from-zero (first k block) vs reload of the stored partial (later
/// blocks); either way each element's chain is ascending-k from 0.0, the
/// reference order.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_kernel(
    kc: usize,
    apanel: &[f32],
    bpanel: &[f32],
    chunk: &mut [f32],
    crow: usize,
    j0: usize,
    n: usize,
    mr: usize,
    nr: usize,
    first: bool,
) {
    let mut acc = [[0.0f32; NR]; MR];
    if !first {
        for (i, arow) in acc.iter_mut().enumerate().take(mr) {
            let base = (crow + i) * n + j0;
            arow[..nr].copy_from_slice(&chunk[base..base + nr]);
        }
    }
    for p in 0..kc {
        let av = &apanel[p * MR..p * MR + MR];
        let bv = &bpanel[p * NR..p * NR + NR];
        for (i, arow) in acc.iter_mut().enumerate() {
            let ai = av[i];
            for (j, cell) in arow.iter_mut().enumerate() {
                *cell += ai * bv[j];
            }
        }
    }
    for (i, arow) in acc.iter().enumerate().take(mr) {
        let base = (crow + i) * n + j0;
        chunk[base..base + nr].copy_from_slice(&arow[..nr]);
    }
}

/// AVX2 micro-kernel for full-width (`nr == NR`) strips: accumulator row
/// `i` is one 8-lane f32 vector holding output columns `j0..j0+NR`, so
/// lane `j` replays output element `(crow+i, j0+j)`'s scalar chain
/// instruction for instruction. Multiply and add stay two separately
/// rounded vector ops — **never** FMA, whose single rounding would
/// diverge from [`micro_kernel`] — so this tier is bitwise identical to
/// the scalar tier. Ragged `mr < MR` groups compute all `MR` rows (the A
/// panel is zero-padded) and store only `mr`, exactly like the scalar
/// kernel.
///
/// # Safety
///
/// Requires AVX2 (dispatch is gated on runtime detection), panels of at
/// least `kc * MR` / `kc * NR` elements, and `nr == NR` so rows
/// `crow..crow+mr` of `chunk` hold `NR` in-bounds columns at `j0`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn micro_kernel_avx2(
    kc: usize,
    apanel: &[f32],
    bpanel: &[f32],
    chunk: &mut [f32],
    crow: usize,
    j0: usize,
    n: usize,
    mr: usize,
    first: bool,
) {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_setzero_ps,
        _mm256_storeu_ps,
    };
    debug_assert!(apanel.len() >= kc * MR);
    debug_assert!(bpanel.len() >= kc * NR);
    debug_assert!(mr >= 1 && (crow + mr - 1) * n + j0 + NR <= chunk.len());
    let mut acc = [_mm256_setzero_ps(); MR];
    if !first {
        for (i, row) in acc.iter_mut().enumerate().take(mr) {
            *row = _mm256_loadu_ps(chunk.as_ptr().add((crow + i) * n + j0));
        }
    }
    let ap = apanel.as_ptr();
    for p in 0..kc {
        let bv = _mm256_loadu_ps(bpanel.as_ptr().add(p * NR));
        for (i, row) in acc.iter_mut().enumerate() {
            let ai = _mm256_set1_ps(*ap.add(p * MR + i));
            // mul then add: two roundings, the scalar chain — not fma
            *row = _mm256_add_ps(*row, _mm256_mul_ps(ai, bv));
        }
    }
    for (i, row) in acc.iter().enumerate().take(mr) {
        _mm256_storeu_ps(chunk.as_mut_ptr().add((crow + i) * n + j0), *row);
    }
}

/// NEON micro-kernel for full-width strips: accumulator row `i` is two
/// 4-lane f32 vectors over output columns `j0..j0+NR`. Same contract as
/// the AVX2 tier — separate multiply + add (no FMA), lane-for-lane the
/// scalar chains, ragged `mr` handled by computing `MR` rows and storing
/// `mr`.
///
/// # Safety
///
/// Requires NEON (dispatch is gated on runtime detection), panels of at
/// least `kc * MR` / `kc * NR` elements, and `nr == NR` so rows
/// `crow..crow+mr` of `chunk` hold `NR` in-bounds columns at `j0`.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn micro_kernel_neon(
    kc: usize,
    apanel: &[f32],
    bpanel: &[f32],
    chunk: &mut [f32],
    crow: usize,
    j0: usize,
    n: usize,
    mr: usize,
    first: bool,
) {
    use std::arch::aarch64::{vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32};
    debug_assert!(apanel.len() >= kc * MR);
    debug_assert!(bpanel.len() >= kc * NR);
    debug_assert!(mr >= 1 && (crow + mr - 1) * n + j0 + NR <= chunk.len());
    let mut lo = [vdupq_n_f32(0.0); MR];
    let mut hi = [vdupq_n_f32(0.0); MR];
    if !first {
        for (i, (rl, rh)) in lo.iter_mut().zip(hi.iter_mut()).enumerate().take(mr) {
            let base = chunk.as_ptr().add((crow + i) * n + j0);
            *rl = vld1q_f32(base);
            *rh = vld1q_f32(base.add(4));
        }
    }
    let ap = apanel.as_ptr();
    for p in 0..kc {
        let b0 = vld1q_f32(bpanel.as_ptr().add(p * NR));
        let b1 = vld1q_f32(bpanel.as_ptr().add(p * NR + 4));
        for (i, (rl, rh)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
            let ai = vdupq_n_f32(*ap.add(p * MR + i));
            *rl = vaddq_f32(*rl, vmulq_f32(ai, b0));
            *rh = vaddq_f32(*rh, vmulq_f32(ai, b1));
        }
    }
    for (i, (rl, rh)) in lo.iter().zip(hi.iter()).enumerate().take(mr) {
        let base = chunk.as_mut_ptr().add((crow + i) * n + j0);
        vst1q_f32(base, *rl);
        vst1q_f32(base.add(4), *rh);
    }
}

/// Pack rows `[row0, row0 + mc)` x reduction `[pc, pc + kc)` of the left
/// operand into `MR`-row groups, k-major (`buf[g][p * MR + i]`), zero-
/// padding the ragged last group so the micro-kernel reads full tiles.
fn pack_a(a: ASrc<'_>, row0: usize, mc: usize, pc: usize, kc: usize, buf: &mut Vec<f32>) {
    let groups = (mc + MR - 1) / MR;
    buf.clear();
    buf.resize(groups * kc * MR, 0.0);
    match a {
        ASrc::Rows { a, lda } => {
            for g in 0..groups {
                let mr = MR.min(mc - g * MR);
                let dst = &mut buf[g * kc * MR..(g + 1) * kc * MR];
                for il in 0..mr {
                    let row = row0 + g * MR + il;
                    let src = &a[row * lda + pc..row * lda + pc + kc];
                    for (p, &v) in src.iter().enumerate() {
                        dst[p * MR + il] = v;
                    }
                }
            }
        }
        ASrc::Cols { a, lda } => {
            for g in 0..groups {
                let i0 = row0 + g * MR;
                let mr = MR.min(mc - g * MR);
                let dst = &mut buf[g * kc * MR..(g + 1) * kc * MR];
                for p in 0..kc {
                    let src = &a[(pc + p) * lda + i0..(pc + p) * lda + i0 + mr];
                    dst[p * MR..p * MR + mr].copy_from_slice(src);
                }
            }
        }
        ASrc::Im2col { x, b: _, h, w, c } => {
            for g in 0..groups {
                let mr = MR.min(mc - g * MR);
                let dst = &mut buf[g * kc * MR..(g + 1) * kc * MR];
                for il in 0..mr {
                    let r = row0 + g * MR + il;
                    let bi = r / (h * w);
                    let rem = r % (h * w);
                    let y = rem / w;
                    let xx = rem % w;
                    // walk the (dy, dx, ci) taps overlapping [pc, pc+kc)
                    let mut p = pc;
                    while p < pc + kc {
                        let tap = p / c;
                        let ci0 = p % c;
                        let take = (c - ci0).min(pc + kc - p);
                        let (dy, dxo) = (tap / 3, tap % 3);
                        let iy = y + dy;
                        let ix = xx + dxo;
                        if iy >= 1 && iy <= h && ix >= 1 && ix <= w {
                            let src = ((bi * h + iy - 1) * w + ix - 1) * c + ci0;
                            for q in 0..take {
                                dst[(p - pc + q) * MR + il] = x[src + q];
                            }
                        }
                        p += take;
                    }
                }
            }
        }
        ASrc::Im2colCols { x, b: _, h, w, c } => {
            for g in 0..groups {
                let i0 = row0 + g * MR;
                let mr = MR.min(mc - g * MR);
                let dst = &mut buf[g * kc * MR..(g + 1) * kc * MR];
                // per-lane tap offsets of patch columns i0..i0+mr
                let mut dys = [0usize; MR];
                let mut dxs = [0usize; MR];
                let mut cis = [0usize; MR];
                for il in 0..mr {
                    let tap = (i0 + il) / c;
                    dys[il] = tap / 3;
                    dxs[il] = tap % 3;
                    cis[il] = (i0 + il) % c;
                }
                for p in 0..kc {
                    let r = pc + p;
                    let bi = r / (h * w);
                    let rem = r % (h * w);
                    let y = rem / w;
                    let xx = rem % w;
                    let drow = &mut dst[p * MR..p * MR + MR];
                    for il in 0..mr {
                        let iy = y + dys[il];
                        let ix = xx + dxs[il];
                        if iy >= 1 && iy <= h && ix >= 1 && ix <= w {
                            drow[il] = x[((bi * h + iy - 1) * w + ix - 1) * c + cis[il]];
                        }
                    }
                }
            }
        }
    }
}

/// Pack the right operand into `NR`-column strips, k-major per strip
/// (`out[s][p * NR + jj]`), zero-padding the ragged last strip. Strips
/// are independent, so the fill is split across workers (this matters
/// for the dW GEMMs, where B = dU is the largest operand of the call);
/// each strip's bytes are a pure function of `b`, so the packed panel is
/// identical for every worker count.
fn pack_b(b: BSrc<'_>, k: usize, n: usize, threads: usize, out: &mut Vec<f32>) {
    let nstrips = (n + NR - 1) / NR;
    out.clear();
    out.resize(nstrips * k * NR, 0.0);
    let workers = parallel::gate_per_chunk(threads, k * n, GEMM_MIN_WORK);
    parallel::parallel_row_chunks(workers, &mut out[..], k * NR, |s0, chunk| {
        for (ls, dst) in chunk.chunks_mut(k * NR).enumerate() {
            let j0 = (s0 + ls) * NR;
            let nr = NR.min(n - j0);
            match b {
                BSrc::Rows { b } => {
                    for p in 0..k {
                        dst[p * NR..p * NR + nr]
                            .copy_from_slice(&b[p * n + j0..p * n + j0 + nr]);
                    }
                }
                BSrc::Cols { b } => {
                    for jj in 0..nr {
                        let src = &b[(j0 + jj) * k..(j0 + jj + 1) * k];
                        for (p, &v) in src.iter().enumerate() {
                            dst[p * NR + jj] = v;
                        }
                    }
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wave(n: usize, f: f32) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * f).sin() * 1.3).collect()
    }

    /// Plain triple loop in the reference accumulation order.
    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                for j in 0..n {
                    out[i * n + j] += av * b[p * n + j];
                }
            }
        }
        out
    }

    #[test]
    fn every_available_tier_matches_naive() {
        let mut scratch = GemmScratch::default();
        // shapes crossing the KC boundary and both ragged tile edges
        for &(m, k, n) in &[(5usize, 300usize, 8usize), (16, 257, 24), (33, 64, 13)] {
            let a = wave(m * k, 0.41);
            let b = wave(k * n, 0.59);
            let want = naive(&a, &b, m, k, n);
            for tier in simd::tiers_available() {
                for threads in [1, 3] {
                    let mut out = vec![f32::NAN; m * n];
                    matmul_into_tier(&mut out, &a, &b, m, k, n, threads, tier, &mut scratch);
                    assert_eq!(out, want, "tier={tier:?} m={m} k={k} n={n} t={threads}");
                }
            }
        }
    }

    #[test]
    fn blocked_matches_naive_over_odd_shapes() {
        let mut scratch = GemmScratch::default();
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 7, 5),
            (8, 8, 8),
            (9, 300, 17),
            (70, 33, 9),
            (130, 520, 12),
        ] {
            let a = wave(m * k, 0.37);
            let b = wave(k * n, 0.73);
            let want = naive(&a, &b, m, k, n);
            for threads in [1, 2, 4] {
                let mut out = vec![f32::NAN; m * n];
                matmul_into(&mut out, &a, &b, m, k, n, threads, &mut scratch);
                assert_eq!(out, want, "m={m} k={k} n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn tn_and_nt_match_explicit_transposes() {
        let mut scratch = GemmScratch::default();
        let (r, m, n) = (41, 13, 11);
        let a = wave(r * m, 0.51);
        let b = wave(r * n, 0.29);
        let mut at = vec![0.0f32; m * r];
        for i in 0..r {
            for j in 0..m {
                at[j * r + i] = a[i * m + j];
            }
        }
        let want = naive(&at, &b, m, r, n);
        let mut out = vec![0.0f32; m * n];
        matmul_tn_into(&mut out, &a, &b, r, m, n, 2, &mut scratch);
        assert_eq!(out, want, "tn");

        let (m2, k2, n2) = (17, 23, 9);
        let a2 = wave(m2 * k2, 0.61);
        let b2 = wave(n2 * k2, 0.43); // (n, k)
        let mut bt = vec![0.0f32; k2 * n2];
        for i in 0..n2 {
            for j in 0..k2 {
                bt[j * n2 + i] = b2[i * k2 + j];
            }
        }
        let want = naive(&a2, &bt, m2, k2, n2);
        let mut out = vec![0.0f32; m2 * n2];
        matmul_nt_into(&mut out, &a2, &b2, m2, k2, n2, 3, &mut scratch);
        assert_eq!(out, want, "nt");
    }

    #[test]
    fn fused_conv_matches_materialized_patches() {
        let mut scratch = GemmScratch::default();
        let (b, h, w, c, cout) = (2usize, 5usize, 4usize, 3usize, 6usize);
        let x = wave(b * h * w * c, 0.77);
        let wts = wave(9 * c * cout, 0.31);
        let patches = super::super::kernels::im2col(&x, b, h, w, c, 1);
        let want = naive(&patches, &wts, b * h * w, 9 * c, cout);
        let mut out = vec![0.0f32; b * h * w * cout];
        conv3x3_into(&mut out, &x, b, h, w, c, &wts, cout, 2, &mut scratch);
        assert_eq!(out, want, "fused conv fwd");

        // dW: patchesᵀ @ du
        let du = wave(b * h * w * cout, 0.23);
        let mut pt = vec![0.0f32; 9 * c * b * h * w];
        let (rr, mm) = (b * h * w, 9 * c);
        for i in 0..rr {
            for j in 0..mm {
                pt[j * rr + i] = patches[i * mm + j];
            }
        }
        let want = naive(&pt, &du, mm, rr, cout);
        let mut out = vec![0.0f32; mm * cout];
        conv3x3_dw_into(&mut out, &x, b, h, w, c, &du, cout, 2, &mut scratch);
        assert_eq!(out, want, "fused conv dW");
    }
}
