//! int8 post-training-quantized GEMM tier — the serving speed lever.
//!
//! ## Scheme
//!
//! Per-tensor **symmetric** quantization: `scale = amax(|t|) / 127`,
//! `q = round(v / scale)` clamped to `[-127, 127]` (never -128, so the
//! grid is symmetric and `|q| <= 127` everywhere). Conv/linear *weights*
//! are quantized **once at load** from the flat arena and pre-packed into
//! GEMM panels ([`QuantTensor`]); *activations* are quantized dynamically
//! per layer call (one amax pass + one rounding pass over the layer
//! input, O(rows·c) against the GEMM's O(rows·9·c·cout)). The product is
//! accumulated exactly in i32 and dequantized in one fused pass:
//! `out_f32 = acc_i32 * (scale_a * scale_w)`. Zero always quantizes to
//! zero, so the conv's implicit SAME padding is exact in the quantized
//! domain too.
//!
//! ## Kernel structure
//!
//! The blocked driver reuses the f32 tier's shape exactly (`super::gemm`):
//! `MR x NR` register tiles, `MC`/`KC` cache blocking, fused im2col A-panel
//! packing straight from the (quantized) NHWC image, threads partitioning
//! output rows only. Two deltas:
//!
//! * panels hold **i16** (values are i8-range; widening at pack time lets
//!   the vector kernels multiply without unpack steps), laid out in
//!   **k-pairs**: B panels interleave two consecutive k rows per column
//!   (`[b[2p][j], b[2p+1][j]]` pairs), A panels store the even-k lane
//!   row then the odd-k lane row per pair. Odd `k` is zero-padded.
//! * the AVX2 kernel maps one k-pair to a single `_mm256_madd_epi16`
//!   (multiply i16 pairs, add horizontally into i32 lanes) + one
//!   `_mm256_add_epi32` — 2 ops per 2 k's against the f32 tier's
//!   mul + add per k, which is where the int8 throughput win comes from.
//!   NEON widens with `vmlal_n_s16`; the scalar kernel is a plain i32
//!   multiply-accumulate and is always available.
//!
//! i32 accumulation is exact (|i8·i8| <= 16129, so any `k` up to ~133k
//! fits i32 with full headroom — asserted), hence **every dispatch tier
//! is bitwise identical**: scalar == AVX2 == NEON down to the final f32
//! dequantization. int8-vs-f32 parity is a *tolerance* contract (top-1
//! agreement + bounded logit error), pinned by `rust/tests/serving.rs`.
//!
//! All entry points are `*_into` over a caller-owned [`QuantScratch`]:
//! buffers grow to the largest shape seen and steady-state calls perform
//! zero heap allocations (pinned by `rust/tests/alloc_regression.rs`).

use super::gemm::{KC, MC, MR, NR};
use crate::coordinator::parallel;
use crate::util::simd::Tier;

/// Minimum multiply-add ops per worker before the row partition spawns
/// another thread (wall-time knob only; results never depend on it).
const QGEMM_MIN_WORK: usize = 1 << 18;

/// Quantization scale for a tensor with absolute maximum `amax`. An
/// all-zero tensor gets scale 1.0 (everything quantizes to 0 exactly).
pub fn quant_scale(amax: f32) -> f32 {
    if amax > 0.0 {
        amax / 127.0
    } else {
        1.0
    }
}

/// Round-to-nearest symmetric quantization of one value (clamped i8 range).
#[inline]
fn quant_val(v: f32, inv_scale: f32) -> i8 {
    (v * inv_scale).round().clamp(-127.0, 127.0) as i8
}

/// Quantize `x` into `qx` (same length) and return the scale. One amax
/// pass + one rounding pass; no allocation.
pub fn quantize_into(x: &[f32], qx: &mut [i8]) -> f32 {
    debug_assert_eq!(x.len(), qx.len());
    let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let scale = quant_scale(amax);
    let inv = 1.0 / scale;
    for (q, &v) in qx.iter_mut().zip(x) {
        *q = quant_val(v, inv);
    }
    scale
}

/// A weight tensor quantized once at load: per-tensor symmetric scale and
/// the values pre-packed into `NR`-column, k-pair-interleaved i16 B
/// panels (strip `s`, pair `p`, column `j` holds `[b[2p][j], b[2p+1][j]]`
/// at `panels[s·kp·2NR + p·2NR + 2j .. +2]`; odd `k` zero-padded).
pub struct QuantTensor {
    panels: Vec<i16>,
    /// number of k-pairs per strip: `(k + 1) / 2`
    kp: usize,
    /// per-tensor symmetric scale (`dequant = q * scale`)
    pub scale: f32,
    /// reduction length (rows of the unquantized `(k, n)` weight)
    pub k: usize,
    /// output columns
    pub n: usize,
}

impl QuantTensor {
    /// Quantize a dense row-major `(k, n)` weight and pre-pack its panels.
    pub fn quantize(w: &[f32], k: usize, n: usize) -> QuantTensor {
        assert_eq!(w.len(), k * n, "weight shape mismatch");
        // headroom proof: k * 127 * 127 must fit i32 for exact accumulation
        assert!(
            (k as u64) * 127 * 127 <= i32::MAX as u64,
            "k too large for exact i32 accumulation"
        );
        let amax = w.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = quant_scale(amax);
        let inv = 1.0 / scale;
        let nstrips = (n + NR - 1) / NR;
        let kp = (k + 1) / 2;
        let mut panels = vec![0i16; nstrips * kp * 2 * NR];
        for s in 0..nstrips {
            let j0 = s * NR;
            let nr = NR.min(n - j0);
            let strip = &mut panels[s * kp * 2 * NR..(s + 1) * kp * 2 * NR];
            for p in 0..k {
                let row = &w[p * n + j0..p * n + j0 + nr];
                let dst = &mut strip[(p / 2) * 2 * NR..(p / 2) * 2 * NR + 2 * NR];
                for (jj, &v) in row.iter().enumerate() {
                    dst[2 * jj + (p & 1)] = quant_val(v, inv) as i16;
                }
            }
        }
        QuantTensor { panels, kp, scale, k, n }
    }

    /// Packed panel bytes of column strip `s`.
    fn strip(&self, s: usize) -> &[i16] {
        &self.panels[s * self.kp * 2 * NR..(s + 1) * self.kp * 2 * NR]
    }
}

/// Left operand of a quantized GEMM (mirrors `gemm::ASrc` over i8 data).
#[derive(Clone, Copy)]
pub enum QASrc<'a> {
    /// Dense row-major `(m, lda)`; element `(i, p) = a[i * lda + p]`.
    Rows { a: &'a [i8], lda: usize },
    /// Virtual im2col patch matrix of a 3x3 SAME conv over quantized NHWC
    /// `x`: `(b*h*w, 9*c)`, zero at the padding taps (exact — 0 is on the
    /// symmetric grid).
    Im2col { x: &'a [i8], b: usize, h: usize, w: usize, c: usize },
}

/// Per-thread packing scratch (the i16 A panels of one row chunk).
#[derive(Default)]
pub struct QPackBuf {
    a: Vec<i16>,
}

/// Call-shared quantized-eval scratch owned by the engine workspace:
/// the dynamic activation quantization buffer, the i32 accumulator arena
/// and one [`QPackBuf`] per worker. Grow-only, reused verbatim.
#[derive(Default)]
pub struct QuantScratch {
    qx: Vec<i8>,
    acc: Vec<i32>,
    packs: Vec<QPackBuf>,
}

/// Quantized fused 3x3 SAME conv forward:
/// `out(b*h*w, cout) = dequant(im2col(quant(x)) @ wq)`.
#[allow(clippy::too_many_arguments)]
pub fn qconv3x3_into(
    out: &mut [f32],
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    wq: &QuantTensor,
    threads: usize,
    tier: Tier,
    qs: &mut QuantScratch,
) {
    debug_assert_eq!(x.len(), b * h * w * c);
    debug_assert_eq!(wq.k, 9 * c);
    let (m, n) = (b * h * w, wq.n);
    debug_assert_eq!(out.len(), m * n);
    let QuantScratch { qx, acc, packs } = qs;
    grow_i8(qx, x.len());
    let sa = quantize_into(x, &mut qx[..x.len()]);
    grow_i32(acc, m * n);
    let a = QASrc::Im2col { x: &qx[..x.len()], b, h, w, c };
    qgemm_into(&mut acc[..m * n], a, wq, m, threads, tier, packs);
    dequant_into(out, &acc[..m * n], sa * wq.scale);
}

/// Quantized dense matmul (the classifier head):
/// `out(m,n) = dequant(quant(a) @ wq)`.
pub fn qmatmul_into(
    out: &mut [f32],
    a: &[f32],
    m: usize,
    wq: &QuantTensor,
    threads: usize,
    tier: Tier,
    qs: &mut QuantScratch,
) {
    let (k, n) = (wq.k, wq.n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(out.len(), m * n);
    let QuantScratch { qx, acc, packs } = qs;
    grow_i8(qx, a.len());
    let sa = quantize_into(a, &mut qx[..a.len()]);
    grow_i32(acc, m * n);
    let src = QASrc::Rows { a: &qx[..a.len()], lda: k };
    qgemm_into(&mut acc[..m * n], src, wq, m, threads, tier, packs);
    dequant_into(out, &acc[..m * n], sa * wq.scale);
}

fn grow_i8(buf: &mut Vec<i8>, n: usize) {
    if buf.len() < n {
        buf.resize(n, 0);
    }
}

fn grow_i32(buf: &mut Vec<i32>, n: usize) {
    if buf.len() < n {
        buf.resize(n, 0);
    }
}

fn dequant_into(out: &mut [f32], acc: &[i32], scale: f32) {
    for (o, &v) in out.iter_mut().zip(acc) {
        *o = v as f32 * scale;
    }
}

/// The blocked quantized driver: B panels are already packed inside `wq`,
/// so only the A side packs per call. Output rows are partitioned across
/// workers exactly like the f32 tier; i32 accumulation is exact, so the
/// result is identical for every `threads` and every dispatch tier.
fn qgemm_into(
    out: &mut [i32],
    a: QASrc<'_>,
    wq: &QuantTensor,
    m: usize,
    threads: usize,
    tier: Tier,
    packs: &mut Vec<QPackBuf>,
) {
    let (k, n) = (wq.k, wq.n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        out.fill(0);
        return;
    }
    let workers = parallel::gate_per_chunk(threads, m * k * n, QGEMM_MIN_WORK);
    if packs.len() < workers.max(1) {
        packs.resize_with(workers.max(1), QPackBuf::default);
    }
    parallel::parallel_row_chunks_scratch(workers, out, n, MR, packs, |row0, chunk, pack| {
        qgemm_chunk(a, wq, row0, n, tier, chunk, pack)
    });
}

/// One worker's share: rows `[row0, row0 + chunk.len()/n)` of the output,
/// swept in k-pair blocks of `KC/2` pairs and row blocks of `MC`.
fn qgemm_chunk(
    a: QASrc<'_>,
    wq: &QuantTensor,
    row0: usize,
    n: usize,
    tier: Tier,
    chunk: &mut [i32],
    pack: &mut QPackBuf,
) {
    let rows = chunk.len() / n;
    let nstrips = (n + NR - 1) / NR;
    let kp_block = KC / 2;
    let mut pp = 0;
    while pp < wq.kp {
        let kpc = kp_block.min(wq.kp - pp);
        let mut ic = 0;
        while ic < rows {
            let mc = MC.min(rows - ic);
            pack_a_q(a, wq.k, row0 + ic, mc, pp, kpc, &mut pack.a);
            let groups = (mc + MR - 1) / MR;
            for g in 0..groups {
                let ir = g * MR;
                let mr = MR.min(mc - ir);
                let apanel = &pack.a[g * kpc * 2 * MR..(g + 1) * kpc * 2 * MR];
                for s in 0..nstrips {
                    let j0 = s * NR;
                    let nr = NR.min(n - j0);
                    let strip = wq.strip(s);
                    let bpanel = &strip[pp * 2 * NR..(pp + kpc) * 2 * NR];
                    let (crow, first) = (ic + ir, pp == 0);
                    match tier {
                        // SAFETY: the avx2 arm only becomes active after
                        // runtime feature detection, and nr == NR keeps the
                        // full-width loads/stores in bounds.
                        #[cfg(target_arch = "x86_64")]
                        Tier::Avx2 if nr == NR => unsafe {
                            qmicro_avx2(kpc, apanel, bpanel, chunk, crow, j0, n, mr, first)
                        },
                        // SAFETY: same contract, gated on runtime neon
                        // detection.
                        #[cfg(target_arch = "aarch64")]
                        Tier::Neon if nr == NR => unsafe {
                            qmicro_neon(kpc, apanel, bpanel, chunk, crow, j0, n, mr, first)
                        },
                        _ => qmicro_scalar(kpc, apanel, bpanel, chunk, crow, j0, n, mr, nr, first),
                    }
                }
            }
            ic += mc;
        }
        pp += kpc;
    }
}

/// Pack rows `[row0, row0+mc)` x k-pairs `[pp, pp+kpc)` of the left
/// operand into `MR`-row groups: per pair, the even-k lane row then the
/// odd-k lane row (`buf[g][p·2MR + i]` / `buf[g][p·2MR + MR + i]`),
/// zero-padding ragged groups and the odd-`k` tail.
fn pack_a_q(
    a: QASrc<'_>,
    k: usize,
    row0: usize,
    mc: usize,
    pp: usize,
    kpc: usize,
    buf: &mut Vec<i16>,
) {
    let groups = (mc + MR - 1) / MR;
    buf.clear();
    buf.resize(groups * kpc * 2 * MR, 0);
    // the contiguous column window [c0, c1) covered by pairs [pp, pp+kpc)
    let c0 = 2 * pp;
    let c1 = (2 * (pp + kpc)).min(k);
    match a {
        QASrc::Rows { a, lda } => {
            for g in 0..groups {
                let mr = MR.min(mc - g * MR);
                let dst = &mut buf[g * kpc * 2 * MR..(g + 1) * kpc * 2 * MR];
                for il in 0..mr {
                    let row = row0 + g * MR + il;
                    let src = &a[row * lda + c0..row * lda + c1];
                    for (q, &v) in src.iter().enumerate() {
                        let p = c0 + q;
                        dst[(p / 2 - pp) * 2 * MR + (p & 1) * MR + il] = v as i16;
                    }
                }
            }
        }
        QASrc::Im2col { x, b: _, h, w, c } => {
            for g in 0..groups {
                let mr = MR.min(mc - g * MR);
                let dst = &mut buf[g * kpc * 2 * MR..(g + 1) * kpc * 2 * MR];
                for il in 0..mr {
                    let r = row0 + g * MR + il;
                    let bi = r / (h * w);
                    let rem = r % (h * w);
                    let y = rem / w;
                    let xx = rem % w;
                    // walk the (dy, dx, ci) taps overlapping [c0, c1)
                    let mut p = c0;
                    while p < c1 {
                        let tap = p / c;
                        let ci0 = p % c;
                        let take = (c - ci0).min(c1 - p);
                        let (dy, dxo) = (tap / 3, tap % 3);
                        let iy = y + dy;
                        let ix = xx + dxo;
                        if iy >= 1 && iy <= h && ix >= 1 && ix <= w {
                            let src = ((bi * h + iy - 1) * w + ix - 1) * c + ci0;
                            for q in 0..take {
                                let col = p + q;
                                dst[(col / 2 - pp) * 2 * MR + (col & 1) * MR + il] =
                                    x[src + q] as i16;
                            }
                        }
                        p += take;
                    }
                }
            }
        }
    }
}

/// The scalar quantized micro-kernel — always available and the reference
/// the vector tiers must match bitwise (i32 accumulation is exact, so
/// they do, by arithmetic not by ordering discipline).
#[allow(clippy::too_many_arguments)]
#[inline]
fn qmicro_scalar(
    kpc: usize,
    apanel: &[i16],
    bpanel: &[i16],
    chunk: &mut [i32],
    crow: usize,
    j0: usize,
    n: usize,
    mr: usize,
    nr: usize,
    first: bool,
) {
    let mut acc = [[0i32; NR]; MR];
    if !first {
        for (i, arow) in acc.iter_mut().enumerate().take(mr) {
            let base = (crow + i) * n + j0;
            arow[..nr].copy_from_slice(&chunk[base..base + nr]);
        }
    }
    for p in 0..kpc {
        let ae = &apanel[p * 2 * MR..p * 2 * MR + MR];
        let ao = &apanel[p * 2 * MR + MR..p * 2 * MR + 2 * MR];
        let bv = &bpanel[p * 2 * NR..(p + 1) * 2 * NR];
        for (i, arow) in acc.iter_mut().enumerate() {
            let (a0, a1) = (ae[i] as i32, ao[i] as i32);
            for (j, cell) in arow.iter_mut().enumerate() {
                *cell += a0 * bv[2 * j] as i32 + a1 * bv[2 * j + 1] as i32;
            }
        }
    }
    for (i, arow) in acc.iter().enumerate().take(mr) {
        let base = (crow + i) * n + j0;
        chunk[base..base + nr].copy_from_slice(&arow[..nr]);
    }
}

/// AVX2 quantized micro-kernel for full-width strips: one k-pair is one
/// `_mm256_madd_epi16` — each 32-bit lane multiplies the (even, odd) a
/// pair against column `j`'s interleaved `(b_even[j], b_odd[j])` and sums
/// into i32 — plus one `_mm256_add_epi32` into the accumulator row. This
/// is the int8 throughput lever: ~1 multiply op per 2 k's vs the f32
/// tier's mul + add per k. Exact i32 arithmetic ⇒ bitwise identical to
/// [`qmicro_scalar`].
///
/// # Safety
///
/// Requires AVX2 (dispatch is gated on runtime detection), panels of at
/// least `kpc·2MR` / `kpc·2NR` elements, and `nr == NR` so rows
/// `crow..crow+mr` of `chunk` hold `NR` in-bounds columns at `j0`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn qmicro_avx2(
    kpc: usize,
    apanel: &[i16],
    bpanel: &[i16],
    chunk: &mut [i32],
    crow: usize,
    j0: usize,
    n: usize,
    mr: usize,
    first: bool,
) {
    use std::arch::x86_64::{
        __m256i, _mm256_add_epi32, _mm256_loadu_si256, _mm256_madd_epi16, _mm256_set1_epi32,
        _mm256_setzero_si256, _mm256_storeu_si256,
    };
    debug_assert!(apanel.len() >= kpc * 2 * MR);
    debug_assert!(bpanel.len() >= kpc * 2 * NR);
    debug_assert!(mr >= 1 && (crow + mr - 1) * n + j0 + NR <= chunk.len());
    let mut acc = [_mm256_setzero_si256(); MR];
    if !first {
        for (i, row) in acc.iter_mut().enumerate().take(mr) {
            *row = _mm256_loadu_si256(chunk.as_ptr().add((crow + i) * n + j0) as *const __m256i);
        }
    }
    let ap = apanel.as_ptr();
    for p in 0..kpc {
        let bv = _mm256_loadu_si256(bpanel.as_ptr().add(p * 2 * NR) as *const __m256i);
        let ae = ap.add(p * 2 * MR);
        let ao = ae.add(MR);
        for (i, row) in acc.iter_mut().enumerate() {
            // pack (a_even, a_odd) into one i32 lane value, broadcast: the
            // i16 halves line up with the interleaved b pairs
            let pair = (*ae.add(i) as u16 as u32) | ((*ao.add(i) as u16 as u32) << 16);
            let av = _mm256_set1_epi32(pair as i32);
            *row = _mm256_add_epi32(*row, _mm256_madd_epi16(av, bv));
        }
    }
    for (i, row) in acc.iter().enumerate().take(mr) {
        _mm256_storeu_si256(chunk.as_mut_ptr().add((crow + i) * n + j0) as *mut __m256i, *row);
    }
}

/// NEON quantized micro-kernel for full-width strips: `vld2q_s16`
/// deinterleaves one k-pair's B row into even/odd column vectors and
/// `vmlal_n_s16` widens i16·i16 into the i32 accumulators. Exact i32
/// arithmetic ⇒ bitwise identical to [`qmicro_scalar`].
///
/// # Safety
///
/// Requires NEON (dispatch is gated on runtime detection), panels of at
/// least `kpc·2MR` / `kpc·2NR` elements, and `nr == NR` so rows
/// `crow..crow+mr` of `chunk` hold `NR` in-bounds columns at `j0`.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn qmicro_neon(
    kpc: usize,
    apanel: &[i16],
    bpanel: &[i16],
    chunk: &mut [i32],
    crow: usize,
    j0: usize,
    n: usize,
    mr: usize,
    first: bool,
) {
    use std::arch::aarch64::{
        vdupq_n_s32, vget_high_s16, vget_low_s16, vld1q_s32, vld2q_s16, vmlal_n_s16, vst1q_s32,
    };
    debug_assert!(apanel.len() >= kpc * 2 * MR);
    debug_assert!(bpanel.len() >= kpc * 2 * NR);
    debug_assert!(mr >= 1 && (crow + mr - 1) * n + j0 + NR <= chunk.len());
    let mut lo = [vdupq_n_s32(0); MR];
    let mut hi = [vdupq_n_s32(0); MR];
    if !first {
        for (i, (rl, rh)) in lo.iter_mut().zip(hi.iter_mut()).enumerate().take(mr) {
            let base = chunk.as_ptr().add((crow + i) * n + j0);
            *rl = vld1q_s32(base);
            *rh = vld1q_s32(base.add(4));
        }
    }
    let ap = apanel.as_ptr();
    for p in 0..kpc {
        // .0 = even-k row b[2p][0..8], .1 = odd-k row b[2p+1][0..8]
        let b2 = vld2q_s16(bpanel.as_ptr().add(p * 2 * NR));
        let ae = ap.add(p * 2 * MR);
        let ao = ae.add(MR);
        for (i, (rl, rh)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
            let (a0, a1) = (*ae.add(i), *ao.add(i));
            *rl = vmlal_n_s16(*rl, vget_low_s16(b2.0), a0);
            *rl = vmlal_n_s16(*rl, vget_low_s16(b2.1), a1);
            *rh = vmlal_n_s16(*rh, vget_high_s16(b2.0), a0);
            *rh = vmlal_n_s16(*rh, vget_high_s16(b2.1), a1);
        }
    }
    for (i, (rl, rh)) in lo.iter().zip(hi.iter()).enumerate().take(mr) {
        let base = chunk.as_mut_ptr().add((crow + i) * n + j0);
        vst1q_s32(base, *rl);
        vst1q_s32(base.add(4), *rh);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::simd;

    fn wave(n: usize, f: f32) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * f + 0.3).sin() * 1.1).collect()
    }

    /// Naive quantized reference: quantize both operands the same way the
    /// production path does, accumulate in i32, dequantize.
    fn naive_q(a: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut qa = vec![0i8; m * k];
        let sa = quantize_into(a, &mut qa);
        let mut qw = vec![0i8; k * n];
        let sw = quantize_into(w, &mut qw);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                for p in 0..k {
                    acc += qa[i * k + p] as i32 * qw[p * n + j] as i32;
                }
                out[i * n + j] = acc as f32 * (sa * sw);
            }
        }
        out
    }

    #[test]
    fn quantize_round_trip_error_is_bounded() {
        let x = wave(257, 0.7);
        let mut q = vec![0i8; x.len()];
        let s = quantize_into(&x, &mut q);
        let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        for (&v, &qv) in x.iter().zip(&q) {
            assert!((v - qv as f32 * s).abs() <= s * 0.5 + 1e-7, "err > half step at {v}");
        }
        assert!((s - amax / 127.0).abs() < 1e-7);
        // zero is exact on the symmetric grid
        let mut q0 = [0i8; 1];
        quantize_into(&[0.0], &mut q0);
        assert_eq!(q0[0], 0);
    }

    #[test]
    fn qmatmul_matches_naive_quantized_on_every_tier() {
        // shapes crossing the KC boundary, odd k (pair padding) and both
        // ragged tile edges
        for &(m, k, n) in &[(5usize, 301usize, 8usize), (16, 257, 24), (33, 64, 13), (1, 9, 10)] {
            let a = wave(m * k, 0.41);
            let w = wave(k * n, 0.59);
            let want = naive_q(&a, &w, m, k, n);
            let wq = QuantTensor::quantize(&w, k, n);
            let mut scalar = vec![f32::NAN; m * n];
            let mut qs = QuantScratch::default();
            qmatmul_into(&mut scalar, &a, m, &wq, 1, Tier::Scalar, &mut qs);
            assert_eq!(scalar, want, "scalar vs naive m={m} k={k} n={n}");
            for tier in simd::tiers_available() {
                for threads in [1, 3] {
                    let mut out = vec![f32::NAN; m * n];
                    qmatmul_into(&mut out, &a, m, &wq, threads, tier, &mut qs);
                    for (i, (g, s)) in out.iter().zip(&scalar).enumerate() {
                        assert_eq!(
                            g.to_bits(),
                            s.to_bits(),
                            "tier {tier:?} t{threads} [{i}] m={m} k={k} n={n}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn qconv_matches_quantized_patches() {
        let (b, h, w, c, cout) = (2usize, 5usize, 4usize, 3usize, 6usize);
        let x = wave(b * h * w * c, 0.77);
        let wts = wave(9 * c * cout, 0.31);
        // reference: materialized quantized patch matrix (padding taps are
        // zero, which quantizes to zero exactly)
        let mut qx = vec![0i8; x.len()];
        let sa = quantize_into(&x, &mut qx);
        let patches_f = super::super::kernels::im2col(&x, b, h, w, c, 1);
        let mut qpatches = vec![0i8; patches_f.len()];
        let inv = 1.0 / sa;
        for (q, &v) in qpatches.iter_mut().zip(&patches_f) {
            *q = quant_val(v, inv);
        }
        let mut qw = vec![0i8; wts.len()];
        let sw = quantize_into(&wts, &mut qw);
        let (m, k, n) = (b * h * w, 9 * c, cout);
        let mut want = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                for p in 0..k {
                    acc += qpatches[i * k + p] as i32 * qw[p * n + j] as i32;
                }
                want[i * n + j] = acc as f32 * (sa * sw);
            }
        }
        let wq = QuantTensor::quantize(&wts, k, n);
        for tier in simd::tiers_available() {
            let mut out = vec![f32::NAN; m * n];
            let mut qs = QuantScratch::default();
            qconv3x3_into(&mut out, &x, b, h, w, c, &wq, 2, tier, &mut qs);
            assert_eq!(out, want, "fused qconv vs quantized patches ({tier:?})");
        }
    }

    #[test]
    fn quantized_error_is_small_relative_to_f32() {
        let (m, k, n) = (12usize, 72usize, 16usize);
        let a = wave(m * k, 0.37);
        let w = wave(k * n, 0.73);
        let mut exact = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a[i * k + p] as f64 * w[p * n + j] as f64;
                }
                exact[i * n + j] = acc as f32;
            }
        }
        let wq = QuantTensor::quantize(&w, k, n);
        let mut out = vec![0.0f32; m * n];
        let mut qs = QuantScratch::default();
        qmatmul_into(&mut out, &a, m, &wq, 1, Tier::Scalar, &mut qs);
        let amax = exact.iter().fold(0.0f32, |mx, &v| mx.max(v.abs()));
        for (g, e) in out.iter().zip(&exact) {
            assert!((g - e).abs() <= 0.02 * amax + 1e-3, "quant err too large: {g} vs {e}");
        }
    }
}
