//! The per-engine persistent kernel workspace: every buffer the native
//! ResNet9s forward/backward/eval/BN-recompute paths write, owned in one
//! place and reused across steps so a steady-state training step performs
//! **zero heap allocations** (pinned by `rust/tests/alloc_regression.rs`).
//!
//! [`Workspace::ensure`] sizes all sub-arenas from the model [`Dims`] and
//! the batch size; buffers only ever grow (to the largest batch seen), so
//! after the first step of a run every call is pure reuse. The engine
//! keeps a pool of workspaces behind a mutex (`NativeBackend`): each
//! concurrent caller (SWAP phase-2 workers, phase-1 shards) pops its own
//! workspace for the duration of one entry point, so the pool adds no
//! cross-thread contention inside a step.
//!
//! Nothing here is numeric: the workspace is pure storage. The bitwise
//! determinism story lives in `gemm`/`kernels` (fixed k-order, output
//! tiles partitioned) and is unaffected by where the buffers come from.

use super::gemm::GemmScratch;
use super::model::{conv_layers, Dims, NUM_CONV_LAYERS};
use super::qgemm::QuantScratch;

/// All mutable state of one native forward/backward invocation.
#[derive(Default)]
pub struct Workspace {
    /// packed GEMM panels: shared B panel + per-worker A packing buffers
    pub gemm: GemmScratch,
    /// int8 eval-tier scratch (activation quantization buffer, i32
    /// accumulators, i16 A panels); grows lazily inside the quantized
    /// entry points, so f32-only callers never pay for it
    pub quant: QuantScratch,

    // -- saved conv-input activations (x0 = a copy of the images) -------
    pub x0: Vec<f32>,
    pub x1: Vec<f32>,
    pub x2: Vec<f32>,
    pub x3: Vec<f32>,
    pub x4: Vec<f32>,
    pub x5: Vec<f32>,
    pub x6: Vec<f32>,
    pub x7: Vec<f32>,

    // -- per-layer BN saves for the backward pass -----------------------
    pub xhat: [Vec<f32>; NUM_CONV_LAYERS],
    /// pre-ReLU block outputs (the ReLU mask)
    pub yact: [Vec<f32>; NUM_CONV_LAYERS],
    pub mean: [Vec<f32>; NUM_CONV_LAYERS],
    pub var: [Vec<f32>; NUM_CONV_LAYERS],
    pub invstd: [Vec<f32>; NUM_CONV_LAYERS],

    // -- pooling argmaxes ----------------------------------------------
    pub pool_idx: [Vec<u32>; 3],
    pub hmax: Vec<u32>,

    // -- forward chain scratch -----------------------------------------
    /// conv output pre-BN (max rows x cout over layers)
    pub u: Vec<f32>,
    /// second rows x cout scratch: BN-eval output on the eval path,
    /// ReLU-backward dy on the grad path
    pub v: Vec<f32>,
    /// post-ReLU pre-pool activations (layer1/layer2/layer3)
    pub act: Vec<f32>,
    /// res3 block output (residual sum)
    pub r3: Vec<f32>,
    /// pooled head features (B, 8c)
    pub hfeat: Vec<f32>,
    pub logits: Vec<f32>,
    /// per-channel BN scratch (max cout)
    pub scale: Vec<f32>,

    // -- backward chain scratch ----------------------------------------
    /// d(mean loss)/dlogits, rescaled in place by the head scale
    pub dl: Vec<f32>,
    /// gradient w.r.t. the pooled head features
    pub dh: Vec<f32>,
    /// activation-gradient ping/pong + retained residual-branch gradient
    pub ga: Vec<f32>,
    pub gb: Vec<f32>,
    pub gres: Vec<f32>,
    /// conv patch gradients (max rows x 9 cin)
    pub dp: Vec<f32>,
    /// the flat manifest-ordered gradient arena
    pub grads: Vec<f32>,
}

fn grow_f32(v: &mut Vec<f32>, n: usize) {
    if v.len() < n {
        v.resize(n, 0.0);
    }
}

fn grow_u32(v: &mut Vec<u32>, n: usize) {
    if v.len() < n {
        v.resize(n, 0);
    }
}

impl Workspace {
    pub fn new() -> Self {
        Workspace::default()
    }

    /// Size every sub-arena for a batch of `b` examples of model `d`.
    /// Grow-only: steady-state calls with a batch no larger than the
    /// biggest seen allocate nothing.
    pub fn ensure(&mut self, d: &Dims, b: usize) {
        let layers = conv_layers(d);
        let h = d.image_size;
        let c = d.width;
        let nc = d.num_classes;

        let xs: [&mut Vec<f32>; NUM_CONV_LAYERS] = [
            &mut self.x0,
            &mut self.x1,
            &mut self.x2,
            &mut self.x3,
            &mut self.x4,
            &mut self.x5,
            &mut self.x6,
            &mut self.x7,
        ];
        let mut max_uc = 0usize; // max rows*cout
        let mut max_act = 0usize; // max of rows*cin and rows*cout
        let mut max_dp = 0usize; // max rows*9cin
        let mut num_params = 0usize;
        for (li, x) in xs.into_iter().enumerate() {
            let (_name, cin, cout, side) = layers[li];
            let rows = b * side * side;
            grow_f32(x, rows * cin);
            grow_f32(&mut self.xhat[li], rows * cout);
            grow_f32(&mut self.yact[li], rows * cout);
            grow_f32(&mut self.mean[li], cout);
            grow_f32(&mut self.var[li], cout);
            grow_f32(&mut self.invstd[li], cout);
            max_uc = max_uc.max(rows * cout);
            max_act = max_act.max(rows * cin.max(cout));
            max_dp = max_dp.max(rows * 9 * cin);
            num_params += 9 * cin * cout + 2 * cout;
        }
        num_params += 8 * c * nc + nc;

        grow_u32(&mut self.pool_idx[0], b * (h / 2) * (h / 2) * 2 * c);
        grow_u32(&mut self.pool_idx[1], b * (h / 4) * (h / 4) * 4 * c);
        grow_u32(&mut self.pool_idx[2], b * (h / 8) * (h / 8) * 8 * c);
        grow_u32(&mut self.hmax, b * 8 * c);

        grow_f32(&mut self.u, max_uc);
        grow_f32(&mut self.v, max_uc);
        grow_f32(&mut self.act, max_uc);
        grow_f32(&mut self.r3, b * (h / 8) * (h / 8) * 8 * c);
        grow_f32(&mut self.hfeat, b * 8 * c);
        grow_f32(&mut self.logits, b * nc);
        grow_f32(&mut self.scale, 8 * c);

        grow_f32(&mut self.dl, b * nc);
        grow_f32(&mut self.dh, b * 8 * c);
        grow_f32(&mut self.ga, max_act);
        grow_f32(&mut self.gb, max_act);
        grow_f32(&mut self.gres, max_act);
        grow_f32(&mut self.dp, max_dp);
        grow_f32(&mut self.grads, num_params);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_sizes_match_model_and_is_idempotent() {
        let d = Dims { width: 4, num_classes: 10, image_size: 16 };
        let mut ws = Workspace::new();
        ws.ensure(&d, 8);
        // x0 holds the images, grads the whole parameter arena
        assert_eq!(ws.x0.len(), 8 * 16 * 16 * 3);
        let num_params: usize = conv_layers(&d)
            .iter()
            .map(|(_, cin, cout, _)| 9 * cin * cout + 2 * cout)
            .sum::<usize>()
            + 8 * 4 * 10
            + 10;
        assert_eq!(ws.grads.len(), num_params);
        assert_eq!(ws.logits.len(), 8 * 10);
        let u_len = ws.u.len();
        // growing for a smaller batch is a no-op
        ws.ensure(&d, 4);
        assert_eq!(ws.u.len(), u_len);
        // a larger batch grows
        ws.ensure(&d, 16);
        assert!(ws.u.len() > u_len);
    }
}
