//! Backend-agnostic runtime types: the host-side batch and the statistics
//! every backend entry point returns. Kept free of any XLA types so the
//! native backend and the coordinator compile without the `xla` feature.

/// One mini-batch on the host, NHWC images + labels.
#[derive(Debug, Clone)]
pub struct HostBatch {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub batch: usize,
    pub image_size: usize,
}

/// Loss/accuracy statistics returned by every executable.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BatchStats {
    pub sum_loss: f64,
    pub correct1: i64,
    pub correct5: i64,
    pub examples: i64,
}

impl BatchStats {
    pub fn accumulate(&mut self, other: &BatchStats) {
        self.sum_loss += other.sum_loss;
        self.correct1 += other.correct1;
        self.correct5 += other.correct5;
        self.examples += other.examples;
    }

    pub fn mean_loss(&self) -> f64 {
        if self.examples == 0 {
            0.0
        } else {
            self.sum_loss / self.examples as f64
        }
    }

    pub fn accuracy1(&self) -> f64 {
        if self.examples == 0 {
            0.0
        } else {
            self.correct1 as f64 / self.examples as f64
        }
    }

    pub fn accuracy5(&self) -> f64 {
        if self.examples == 0 {
            0.0
        } else {
            self.correct5 as f64 / self.examples as f64
        }
    }
}

/// Gradient result of a backend `grad` call: one contiguous arena in
/// manifest parameter order (the weight-space flattening convention of
/// `model::flat`), plus the batch statistics.
pub struct GradResult {
    pub grads: Vec<f32>,
    pub stats: BatchStats,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_accumulate_and_ratios() {
        let mut a = BatchStats { sum_loss: 2.0, correct1: 1, correct5: 3, examples: 4 };
        let b = BatchStats { sum_loss: 6.0, correct1: 3, correct5: 3, examples: 4 };
        a.accumulate(&b);
        assert_eq!(a.examples, 8);
        assert_eq!(a.mean_loss(), 1.0);
        assert_eq!(a.accuracy1(), 0.5);
        assert_eq!(a.accuracy5(), 0.75);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = BatchStats::default();
        assert_eq!(s.mean_loss(), 0.0);
        assert_eq!(s.accuracy1(), 0.0);
        assert_eq!(s.accuracy5(), 0.0);
    }
}
