//! Runtime layer: the xla-crate PJRT client wrapper that loads and executes
//! the AOT artifacts (HLO text) produced by `make artifacts`.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.

pub mod engine;
pub mod literal;
pub mod manifest;

pub use engine::{BatchStats, Engine, GradResult, HostBatch};
pub use manifest::{Manifest, ModelMeta, TensorSpec};
