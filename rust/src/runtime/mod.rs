//! Runtime layer: pluggable execution backends behind the `Backend` trait.
//!
//! * `native`  — pure-Rust ResNet9s forward/backward (the default; no
//!   artifacts or XLA toolchain required, hermetically testable).
//! * `engine`  — PJRT client executing the AOT HLO artifacts produced by
//!   `python -m compile.aot` (cargo feature `xla`; the checked-in `xla`
//!   dependency is a compile-only stub, see rust/vendor/xla/README.md).
//!
//! `manifest` is the layout contract both backends share: it pins the
//! order of parameter / BN-stat tensors crossing the backend boundary.

pub mod backend;
#[cfg(feature = "xla")]
pub mod engine;
#[cfg(feature = "xla")]
pub mod literal;
pub mod manifest;
pub mod native;
pub mod types;

pub use backend::Backend;
#[cfg(feature = "xla")]
pub use engine::Engine;
pub use manifest::{Manifest, ModelMeta, TensorSpec};
pub use native::{NativeBackend, NativeSpec};
pub use types::{BatchStats, GradResult, HostBatch};
