//! Artifact manifest: the layout contract between the AOT exporter
//! (python/compile/aot.py) and the rust runtime.
//!
//! The manifest pins the *order* in which parameter / BN-stat tensors are
//! fed to and returned from every executable; `runtime::engine` composes
//! argument lists from it and `model::ParamSet` allocates from it.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::{Error, Json, Result};

/// Shape + name of one tensor crossing the HLO boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Static model metadata baked into the artifacts.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub arch: String,
    pub width: usize,
    pub num_classes: usize,
    pub image_size: usize,
    pub momentum: f32,
    pub weight_decay: f32,
    pub head_scale: f32,
    pub bn_eps: f32,
}

/// Parsed artifacts/<preset>/manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub model: ModelMeta,
    pub params: Vec<TensorSpec>,
    pub bn_stats: Vec<TensorSpec>,
    pub num_params: usize,
    pub batches: Vec<usize>,
    /// executable key (e.g. "grad_b64") -> file name
    pub executables: BTreeMap<String, String>,
    pub flops_fwd_per_example: u64,
    /// directory the manifest was loaded from (artifact file resolution)
    pub dir: PathBuf,
}

fn specs_from(v: &Json, what: &str) -> Result<Vec<TensorSpec>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| Error::json(format!("{what}: expected array")))?;
    arr.iter()
        .map(|e| {
            let name = e
                .req("name")?
                .as_str()
                .ok_or_else(|| Error::json("spec name not a string"))?
                .to_string();
            let shape = e
                .req("shape")?
                .as_arr()
                .ok_or_else(|| Error::json("spec shape not an array"))?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| Error::json("shape dim not a usize"))
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorSpec { name, shape })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Io(std::io::Error::new(
                e.kind(),
                format!(
                    "{}: {e} (generate artifacts with `python -m compile.aot`)",
                    dir.display()
                ),
            ))
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let j = Json::parse(text)?;
        let m = j.req("model")?;
        let getf = |k: &str| -> Result<f64> {
            m.req(k)?
                .as_f64()
                .ok_or_else(|| Error::json(format!("model.{k} not a number")))
        };
        let model = ModelMeta {
            arch: m
                .req("arch")?
                .as_str()
                .ok_or_else(|| Error::json("model.arch"))?
                .to_string(),
            width: getf("width")? as usize,
            num_classes: getf("num_classes")? as usize,
            image_size: getf("image_size")? as usize,
            momentum: getf("momentum")? as f32,
            weight_decay: getf("weight_decay")? as f32,
            head_scale: getf("head_scale")? as f32,
            bn_eps: getf("bn_eps")? as f32,
        };
        let params = specs_from(j.req("params")?, "params")?;
        let bn_stats = specs_from(j.req("bn_stats")?, "bn_stats")?;
        let num_params = j
            .req("num_params")?
            .as_usize()
            .ok_or_else(|| Error::json("num_params"))?;
        let declared: usize = params.iter().map(|s| s.numel()).sum();
        if declared != num_params {
            return Err(Error::json(format!(
                "num_params {num_params} != sum of param shapes {declared}"
            )));
        }
        let batches = j
            .req("batches")?
            .as_arr()
            .ok_or_else(|| Error::json("batches"))?
            .iter()
            .map(|b| b.as_usize().ok_or_else(|| Error::json("batch size")))
            .collect::<Result<Vec<_>>>()?;
        let executables = j
            .req("executables")?
            .as_obj()
            .ok_or_else(|| Error::json("executables"))?
            .iter()
            .map(|(k, v)| {
                Ok((
                    k.clone(),
                    v.as_str()
                        .ok_or_else(|| Error::json("executable path"))?
                        .to_string(),
                ))
            })
            .collect::<Result<BTreeMap<_, _>>>()?;
        let flops = j
            .req("flops_fwd_per_example")?
            .as_f64()
            .ok_or_else(|| Error::json("flops"))? as u64;
        Ok(Manifest {
            preset: j
                .req("preset")?
                .as_str()
                .ok_or_else(|| Error::json("preset"))?
                .to_string(),
            model,
            params,
            bn_stats,
            num_params,
            batches,
            executables,
            flops_fwd_per_example: flops,
            dir,
        })
    }

    /// Path of an executable's HLO text by key ("grad_b64", ...).
    pub fn hlo_path(&self, key: &str) -> Result<PathBuf> {
        let fname = self
            .executables
            .get(key)
            .ok_or_else(|| Error::config(format!("no executable '{key}' in manifest (have: {:?})",
                                                 self.executables.keys().collect::<Vec<_>>())))?;
        Ok(self.dir.join(fname))
    }

    /// Model weight footprint in bytes (f32) — the all-reduce message size.
    pub fn param_bytes(&self) -> u64 {
        self.num_params as u64 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "preset": "unit",
      "model": {"arch":"resnet9s","width":4,"num_classes":10,"image_size":16,
                "momentum":0.9,"weight_decay":0.0005,"head_scale":0.125,"bn_eps":1e-05},
      "params": [{"name":"prep.w","shape":[27,4]},{"name":"prep.gamma","shape":[4]}],
      "bn_stats": [{"name":"prep.mean","shape":[4]},{"name":"prep.var","shape":[4]}],
      "num_params": 112,
      "batches": [8],
      "executables": {"grad_b8": "grad_b8.hlo.txt"},
      "flops_fwd_per_example": 123
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/x")).unwrap();
        assert_eq!(m.preset, "unit");
        assert_eq!(m.model.num_classes, 10);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].numel(), 108);
        assert_eq!(m.num_params, 112);
        assert_eq!(m.batches, vec![8]);
        assert_eq!(m.param_bytes(), 448);
        assert_eq!(
            m.hlo_path("grad_b8").unwrap(),
            PathBuf::from("/tmp/x/grad_b8.hlo.txt")
        );
        assert!(m.hlo_path("nope").is_err());
        assert!((m.model.bn_eps - 1e-5).abs() < 1e-12);
    }

    #[test]
    fn rejects_wrong_num_params() {
        let bad = SAMPLE.replace("\"num_params\": 112", "\"num_params\": 999");
        assert!(Manifest::parse(&bad, PathBuf::new()).is_err());
    }

    #[test]
    fn rejects_missing_keys() {
        assert!(Manifest::parse("{}", PathBuf::new()).is_err());
    }
}
