//! The execution-backend contract.
//!
//! The coordinator (trainer / SWAP / baselines / landscape) drives training
//! exclusively through this trait, so the same Algorithm-1 code runs on
//! * the **native** backend (`runtime::native`) — pure-Rust ResNet9s
//!   forward/backward, hermetic, the default; and
//! * the **PJRT/XLA** backend (`runtime::engine`, cargo feature `xla`) —
//!   executes the AOT HLO artifacts exported by `python -m compile.aot`.
//!
//! Weight-shaped state crosses this boundary as **flat arenas**: one
//! contiguous `&[f32]` per vector, tensors packed back-to-back in manifest
//! order (`model::flat`'s convention). Backends slice per-tensor views out
//! of the arena with their manifest's shapes — per-tensor materialization
//! exists only at this edge (e.g. XLA literals), never on the coordinator
//! side.
//!
//! The four entry points mirror the four per-preset executables of the
//! artifact contract (`grad_b*`, `train_b*`, `eval_b*`, `bnstats_b*`);
//! `manifest()` pins tensor order and model metadata for both.

use super::manifest::Manifest;
use super::types::{BatchStats, GradResult, HostBatch};
use crate::util::Result;

/// A model-execution engine: gradients, fused train steps, evaluation and
/// batch-norm moment recomputation over flat host arenas.
///
/// `Send + Sync` is part of the contract: the coordinator shares one
/// engine across OS threads (phase-2 workers, phase-1 device shards run
/// concurrently — see `coordinator::parallel`), so any interior state must
/// be thread-safe (the PJRT engine guards its executable cache with a
/// mutex; the native backend keeps a mutex-guarded pool of kernel
/// workspaces — each concurrent caller pops its own, so calls never
/// contend inside a step).
pub trait Backend: Send + Sync {
    /// Short backend identifier ("native", "xla") for logs.
    fn name(&self) -> &'static str;

    /// The layout contract: parameter/BN tensor order + model metadata.
    fn manifest(&self) -> &Manifest;

    /// Whether this backend accepts arbitrary batch sizes — in particular
    /// the ragged final evaluation batch (`n % exec_batch` examples). The
    /// native backend does; AOT per-batch-size executables don't, and
    /// evaluation then falls back to whole batches only (the tail is
    /// dropped, as before ragged support existed) instead of erroring on
    /// a missing `eval_b{tail}` artifact.
    fn supports_ragged_batch(&self) -> bool {
        true
    }

    /// Phase-1 entry point: gradients of the *mean* batch loss as one
    /// manifest-ordered arena, plus loss/accuracy statistics of the batch.
    /// `params` is the manifest-ordered parameter arena (`num_params`
    /// f32s).
    fn grad(&self, params: &[f32], batch: &HostBatch) -> Result<GradResult>;

    /// Phase-2 entry point: fused gradient + Nesterov-SGD update (coupled
    /// weight decay, constants from the manifest). Updates the `params`
    /// and `momentum` arenas in place.
    fn train_step(
        &self,
        params: &mut [f32],
        momentum: &mut [f32],
        batch: &HostBatch,
        lr: f32,
    ) -> Result<BatchStats>;

    /// Evaluation with externally supplied running BN statistics (the
    /// flat mean/var arena in manifest `bn_stats` order).
    fn eval_batch(
        &self,
        params: &[f32],
        bn_stats: &[f32],
        batch: &HostBatch,
    ) -> Result<BatchStats>;

    /// Whether [`Backend::eval_batch_top1`] actually skips the loss tail.
    /// Purely informational (the default delegate is always correct);
    /// lets callers report which path accuracy-only sweeps took.
    fn supports_logits_only(&self) -> bool {
        false
    }

    /// Accuracy-only evaluation: identical `correct1`/`correct5`/`examples`
    /// to [`Backend::eval_batch`], but `sum_loss` is **not** part of the
    /// contract (backends that can skip the cross-entropy tail return
    /// 0.0). Callers that discard loss — validation-gated averaging,
    /// serving-style accuracy sweeps — should come through here. The
    /// default delegates to `eval_batch`, so backends without a dedicated
    /// logits-only path (the XLA engine's AOT executables) stay correct
    /// unchanged.
    fn eval_batch_top1(
        &self,
        params: &[f32],
        bn_stats: &[f32],
        batch: &HostBatch,
    ) -> Result<BatchStats> {
        self.eval_batch(params, bn_stats, batch)
    }

    /// Phase-3 entry point: batch-norm moments (mean, biased var per conv
    /// layer) of one batch, as a flat arena in manifest `bn_stats` order.
    fn bn_moments(&self, params: &[f32], batch: &HostBatch) -> Result<Vec<f32>>;
}
