//! PJRT engine (cargo feature `xla`): loads AOT HLO-text artifacts,
//! compiles them once per process, and exposes the `Backend` entry points
//! (grad / train / eval / bnstats) over host tensors. This is the only
//! module that executes XLA code; the coordinator above it never sees a
//! literal.
//!
//! Pattern: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use super::backend::Backend;
use super::literal::{batch_to_literals, literal_f32, literal_i32, lr_literal, slice_to_literal};
use super::manifest::Manifest;
use super::types::{BatchStats, GradResult, HostBatch};
use crate::model::ParamLayout;
use crate::util::{Error, Result};

/// Compiled-executable cache + typed call surface.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// arena packing convention (per-tensor offsets), built once from the
    /// manifest — shared with `model::flat`'s flattening convention
    param_layout: Arc<ParamLayout>,
    bn_layout: Arc<ParamLayout>,
    // Mutex (not RefCell): `Backend: Send + Sync` so the coordinator can
    // drive one engine from many worker threads concurrently. Executables
    // are Arc'd so the cache lock is dropped BEFORE execution — concurrent
    // callers must not serialize behind each other's execute().
    execs: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    /// executions performed, by key (profiling / tests)
    calls: Mutex<HashMap<String, u64>>,
}

impl Engine {
    /// Load a preset's artifacts, e.g. `Engine::load("artifacts/cifar10sim")`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        let param_layout = ParamLayout::of_params(&manifest);
        let bn_layout = ParamLayout::of_bn(&manifest);
        Ok(Engine {
            client,
            manifest,
            param_layout,
            bn_layout,
            execs: Mutex::new(HashMap::new()),
            calls: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Number of times each executable ran (keyed by "grad_b64", ...).
    pub fn call_counts(&self) -> HashMap<String, u64> {
        self.calls.lock().unwrap().clone()
    }

    fn ensure_compiled(&self, key: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        // hold the cache lock across the compile: concurrent threads that
        // miss on the same key must wait for one compilation, not each
        // redo the expensive compile and discard N-1 results
        let mut execs = self.execs.lock().unwrap();
        if let Some(exe) = execs.get(key) {
            return Ok(exe.clone());
        }
        let path = self.manifest.hlo_path(key)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::invalid("non-utf8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        crate::debug!("compiled {key} from {}", path.display());
        execs.insert(key.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact by key with raw literals; returns the flattened
    /// output tuple. Public so the landscape/analysis modules and tests can
    /// drive executables directly.
    pub fn run_raw(&self, key: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        // the Arc is cloned out of the cache lock, so worker threads run
        // their executables concurrently (only compilation serializes)
        let exe = self.ensure_compiled(key)?;
        *self.calls.lock().unwrap().entry(key.to_string()).or_insert(0) += 1;
        let result = exe.execute::<xla::Literal>(args)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Carve per-tensor literals out of a contiguous manifest-ordered
    /// arena — the only place parameter data is materialized per tensor.
    /// Offsets come from the shared `ParamLayout`, never a second walk.
    fn arena_to_literals(
        layout: &ParamLayout,
        arena: &[f32],
        what: &str,
    ) -> Result<Vec<xla::Literal>> {
        if arena.len() != layout.total() {
            return Err(Error::shape(format!(
                "{what} arena has {} f32s, manifest wants {}",
                arena.len(),
                layout.total()
            )));
        }
        let mut out = Vec::with_capacity(layout.len());
        for i in 0..layout.len() {
            out.push(slice_to_literal(&arena[layout.range(i)], &layout.spec(i).shape)?);
        }
        Ok(out)
    }

    fn params_to_literals(&self, params: &[f32]) -> Result<Vec<xla::Literal>> {
        Self::arena_to_literals(&self.param_layout, params, "param")
    }

    /// Copy per-tensor output literals back into a contiguous arena,
    /// validating each tensor's element count against the layout.
    fn literals_into_arena(
        layout: &ParamLayout,
        outs: &[xla::Literal],
        arena: &mut [f32],
        what: &str,
    ) -> Result<()> {
        for (i, lit) in outs.iter().enumerate().take(layout.len()) {
            let v = lit.to_vec::<f32>()?;
            let r = layout.range(i);
            if v.len() != r.len() {
                return Err(Error::shape(format!(
                    "{what} output {}: {} elements, manifest wants {}",
                    layout.spec(i).name,
                    v.len(),
                    r.len()
                )));
            }
            arena[r].copy_from_slice(&v);
        }
        Ok(())
    }

    fn stats_from(&self, outs: &[xla::Literal], batch: usize) -> Result<BatchStats> {
        let n = outs.len();
        Ok(BatchStats {
            sum_loss: literal_f32(&outs[n - 3])? as f64,
            correct1: literal_i32(&outs[n - 2])? as i64,
            correct5: literal_i32(&outs[n - 1])? as i64,
            examples: batch as i64,
        })
    }
}

impl Backend for Engine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// AOT executables are compiled per batch size — a ragged tail batch
    /// has no artifact, so the eval loop must stick to whole batches.
    fn supports_ragged_batch(&self) -> bool {
        false
    }

    /// Phase-1 gradients: `grad_b{B}`.
    fn grad(&self, params: &[f32], batch: &HostBatch) -> Result<GradResult> {
        let key = format!("grad_b{}", batch.batch);
        let mut args = self.params_to_literals(params)?;
        let (img, lab) = batch_to_literals(batch)?;
        args.push(img);
        args.push(lab);
        let outs = self.run_raw(&key, &args)?;
        let np = self.manifest.params.len();
        if outs.len() != np + 3 {
            return Err(Error::shape(format!(
                "grad returned {} outputs, want {}",
                outs.len(),
                np + 3
            )));
        }
        let mut grads = vec![0.0f32; self.manifest.num_params];
        Self::literals_into_arena(&self.param_layout, &outs[..np], &mut grads, "grad")?;
        let stats = self.stats_from(&outs, batch.batch)?;
        Ok(GradResult { grads, stats })
    }

    /// Phase-2 fused step: `train_b{B}`. Updates the params/momentum
    /// arenas in place.
    fn train_step(
        &self,
        params: &mut [f32],
        momentum: &mut [f32],
        batch: &HostBatch,
        lr: f32,
    ) -> Result<BatchStats> {
        let key = format!("train_b{}", batch.batch);
        let np = self.manifest.params.len();
        if momentum.len() != params.len() {
            return Err(Error::shape(format!(
                "momentum arena has {} f32s, params {}",
                momentum.len(),
                params.len()
            )));
        }
        let mut args = self.params_to_literals(params)?;
        args.extend(Self::arena_to_literals(&self.param_layout, momentum, "momentum")?);
        let (img, lab) = batch_to_literals(batch)?;
        args.push(img);
        args.push(lab);
        args.push(lr_literal(lr)?);
        let outs = self.run_raw(&key, &args)?;
        if outs.len() != 2 * np + 3 {
            return Err(Error::shape(format!(
                "train returned {} outputs, want {}",
                outs.len(),
                2 * np + 3
            )));
        }
        Self::literals_into_arena(&self.param_layout, &outs[..np], params, "train params")?;
        Self::literals_into_arena(
            &self.param_layout,
            &outs[np..2 * np],
            momentum,
            "train momentum",
        )?;
        self.stats_from(&outs, batch.batch)
    }

    /// Evaluation with running BN stats: `eval_b{B}`.
    fn eval_batch(
        &self,
        params: &[f32],
        bn_stats: &[f32],
        batch: &HostBatch,
    ) -> Result<BatchStats> {
        let key = format!("eval_b{}", batch.batch);
        let mut args = self.params_to_literals(params)?;
        args.extend(Self::arena_to_literals(&self.bn_layout, bn_stats, "bn")?);
        let (img, lab) = batch_to_literals(batch)?;
        args.push(img);
        args.push(lab);
        let outs = self.run_raw(&key, &args)?;
        self.stats_from(&outs, batch.batch)
    }

    /// BN moments of one batch: `bnstats_b{B}` (phase 3).
    fn bn_moments(&self, params: &[f32], batch: &HostBatch) -> Result<Vec<f32>> {
        let key = format!("bnstats_b{}", batch.batch);
        let mut args = self.params_to_literals(params)?;
        let (img, _lab) = batch_to_literals(batch)?;
        args.push(img);
        let outs = self.run_raw(&key, &args)?;
        if outs.len() != self.manifest.bn_stats.len() {
            return Err(Error::shape(format!(
                "bnstats returned {} outputs, want {}",
                outs.len(),
                self.manifest.bn_stats.len()
            )));
        }
        let mut flat = vec![0.0f32; self.bn_layout.total()];
        Self::literals_into_arena(&self.bn_layout, &outs, &mut flat, "bnstats")?;
        Ok(flat)
    }
}
