//! Host `Tensor` ⇄ XLA `Literal` conversion, plus small scalar helpers.
//! This is the only file where tensor data crosses the PJRT boundary.

use super::types::HostBatch;
use crate::tensor::Tensor;
use crate::util::{Error, Result};

/// A host batch as (images, labels) literals — the trailing inputs of every
/// executable.
pub fn batch_to_literals(hb: &HostBatch) -> Result<(xla::Literal, xla::Literal)> {
    Ok((
        images_to_literal(&hb.images, hb.batch, hb.image_size)?,
        i32s_to_literal(&hb.labels),
    ))
}

/// Flat arena view -> literal with an explicit shape (the flat-params
/// boundary: per-tensor literals are carved out of one contiguous arena).
pub fn slice_to_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let flat = xla::Literal::vec1(data);
    if shape.is_empty() {
        // rank-0: reshape to scalar
        return Ok(flat.reshape(&[])?);
    }
    let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
    Ok(flat.reshape(&dims)?)
}

/// Host tensor -> literal with the tensor's shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    slice_to_literal(t.data(), t.shape())
}

/// Literal (f32) -> host tensor, preserving the literal's shape.
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Tensor::new(dims, data)
}

/// i32 vector literal (labels).
pub fn i32s_to_literal(xs: &[i32]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

/// Scalar f32 out of a (possibly rank-0 or rank-1) literal.
pub fn literal_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Scalar i32.
pub fn literal_i32(lit: &xla::Literal) -> Result<i32> {
    Ok(lit.get_first_element::<i32>()?)
}

/// The (1,) f32 learning-rate input of `train_b*`.
pub fn lr_literal(lr: f32) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&[lr]).reshape(&[1])?)
}

/// Batched image literal from a flat buffer (B*H*W*3 f32, NHWC).
pub fn images_to_literal(flat: &[f32], b: usize, hw: usize) -> Result<xla::Literal> {
    if flat.len() != b * hw * hw * 3 {
        return Err(Error::shape(format!(
            "image buffer {} != {b}x{hw}x{hw}x3",
            flat.len()
        )));
    }
    Ok(xla::Literal::vec1(flat).reshape(&[b as i64, hw as i64, hw as i64, 3])?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_tensor_roundtrip() {
        let t = Tensor::scalar(3.5);
        let lit = tensor_to_literal(&t).unwrap();
        assert_eq!(literal_f32(&lit).unwrap(), 3.5);
    }

    #[test]
    fn i32_literals() {
        let lit = i32s_to_literal(&[1, 2, 3]);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
        assert_eq!(literal_i32(&lit).unwrap(), 1);
    }

    #[test]
    fn lr_literal_shape() {
        let lit = lr_literal(0.25).unwrap();
        assert_eq!(literal_f32(&lit).unwrap(), 0.25);
        assert_eq!(lit.element_count(), 1);
    }

    #[test]
    fn images_shape_checked() {
        assert!(images_to_literal(&vec![0.0; 2 * 4 * 4 * 3], 2, 4).is_ok());
        assert!(images_to_literal(&vec![0.0; 5], 2, 4).is_err());
    }
}
