//! swap-train: reproduction of "Stochastic Weight Averaging in Parallel:
//! Large-Batch Training That Generalizes Well" (Gupta, Akle Serrano,
//! DeCoste — ICLR 2020).
//!
//! Three layers (DESIGN.md):
//! * L3 (this crate): the SWAP coordinator — phase orchestration, gradient
//!   all-reduce, independent workers, weight averaging, BN recompute,
//!   schedules, data pipeline, metrics, virtual-cluster clock.
//! * L2/L1 (python/, build-time only): JAX ResNet9s + Pallas kernels,
//!   AOT-lowered to HLO text artifacts.
//! * runtime: PJRT CPU client executing the artifacts.
pub mod analysis;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod landscape;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod testutil;
pub mod util;
