//! swap-train: reproduction of "Stochastic Weight Averaging in Parallel:
//! Large-Batch Training That Generalizes Well" (Gupta, Akle Serrano,
//! DeCoste — ICLR 2020).
//!
//! Layers:
//! * **coordinator** (L3): the SWAP algorithm — phase orchestration,
//!   gradient all-reduce, independent workers, weight averaging, BN
//!   recompute, schedules, data pipeline, metrics, virtual-cluster clock.
//! * **runtime**: pluggable execution backends behind [`runtime::Backend`]:
//!   - `native` (default): pure-Rust ResNet9s forward/backward — hermetic,
//!     deterministic, no external toolchain; what `cargo test` exercises
//!     end-to-end.
//!   - `xla` (cargo feature `xla`): PJRT client executing AOT HLO
//!     artifacts lowered from the JAX/Pallas model (python/, build-time
//!     only). The checked-in `xla` dependency is a compile-only stub;
//!     see rust/vendor/xla/README.md.
//!
//! Backend selection is a config knob (`--set backend=native|xla`); the
//! numerical contract between backends is pinned by
//! rust/tests/kernel_parity.rs against fixtures generated from the python
//! reference kernels.
pub mod analysis;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod landscape;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod tensor;
pub mod testutil;
pub mod util;
