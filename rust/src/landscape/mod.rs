//! Loss-landscape visualization (§4, Figures 2 and 3): planes through
//! weight vectors + error surfaces over them.

pub mod grid;
pub mod plane;

pub use grid::{eval_grid, GridPoint, GridResult, GridSpec};
pub use plane::Plane;
