//! The 2D affine plane through three weight vectors — the visualization
//! device of §4 (Garipov et al. / Izmailov et al. style). Figures 2 and 3
//! plot train/test error over the plane spanned by {LB, SGD, SWAP} or
//! {SGD1, SGD2, SGD3} with SWAP projected in.

use crate::model::ParamSet;
use crate::tensor::{self, Tensor};
use crate::util::{Error, Result};

/// Orthonormal basis (u, v) of the plane through theta1, theta2, theta3,
/// with theta1 as origin.
pub struct Plane {
    pub origin: ParamSet,
    pub u: Vec<Tensor>,
    pub v: Vec<Tensor>,
    /// plane coordinates of the three anchors
    pub anchors: [(f64, f64); 3],
}

impl Plane {
    pub fn through(theta1: &ParamSet, theta2: &ParamSet, theta3: &ParamSet) -> Result<Plane> {
        let d2 = tensor::sets_sub(&theta2.tensors, &theta1.tensors)?;
        let d3 = tensor::sets_sub(&theta3.tensors, &theta1.tensors)?;
        let n2 = tensor::sets_norm(&d2);
        if n2 == 0.0 {
            return Err(Error::invalid("plane: theta2 == theta1"));
        }
        let mut u = d2;
        tensor::sets_scale(&mut u, (1.0 / n2) as f32);
        // Gram-Schmidt
        let a3 = tensor::sets_dot(&d3, &u)?;
        let n3 = tensor::sets_norm(&d3);
        let mut vres = d3;
        tensor::sets_axpy(&mut vres, -a3 as f32, &u)?;
        let nv = tensor::sets_norm(&vres);
        // relative threshold: f32 Gram-Schmidt leaves ~1e-7 of residual on
        // exactly collinear points
        if nv < 1e-5 * n3.max(1e-12) {
            return Err(Error::invalid("plane: three points are collinear"));
        }
        tensor::sets_scale(&mut vres, (1.0 / nv) as f32);
        Ok(Plane {
            origin: theta1.clone(),
            u,
            v: vres,
            anchors: [(0.0, 0.0), (n2, 0.0), (a3, nv)],
        })
    }

    /// The weight vector at plane coordinates (alpha, beta).
    pub fn point(&self, alpha: f64, beta: f64) -> Result<ParamSet> {
        let mut t = self.origin.clone();
        tensor::sets_axpy(&mut t.tensors, alpha as f32, &self.u)?;
        tensor::sets_axpy(&mut t.tensors, beta as f32, &self.v)?;
        Ok(t)
    }

    /// Project an arbitrary weight vector onto plane coordinates.
    pub fn project(&self, theta: &ParamSet) -> Result<(f64, f64)> {
        let d = tensor::sets_sub(&theta.tensors, &self.origin.tensors)?;
        Ok((tensor::sets_dot(&d, &self.u)?, tensor::sets_dot(&d, &self.v)?))
    }

    /// Distance from the plane (how far off-plane a projected point is).
    pub fn residual(&self, theta: &ParamSet) -> Result<f64> {
        let (a, b) = self.project(theta)?;
        let on_plane = self.point(a, b)?;
        theta.distance(&on_plane)
    }

    /// A bounding box (with margin) around the anchors — the grid extent
    /// Figures 2/3 use.
    pub fn bounds(&self, margin: f64) -> (std::ops::Range<f64>, std::ops::Range<f64>) {
        let xs: Vec<f64> = self.anchors.iter().map(|a| a.0).collect();
        let ys: Vec<f64> = self.anchors.iter().map(|a| a.1).collect();
        let (x0, x1) = (
            xs.iter().cloned().fold(f64::INFINITY, f64::min),
            xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        );
        let (y0, y1) = (
            ys.iter().cloned().fold(f64::INFINITY, f64::min),
            ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        );
        let (dx, dy) = ((x1 - x0).max(1e-9), (y1 - y0).max(1e-9));
        (
            x0 - margin * dx..x1 + margin * dx,
            y0 - margin * dy..y1 + margin * dy,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::property;

    fn pset(vals: Vec<f32>) -> ParamSet {
        ParamSet {
            tensors: vec![Tensor::new(vec![vals.len()], vals).unwrap()],
        }
    }

    #[test]
    fn orthonormal_basis() {
        let p = Plane::through(
            &pset(vec![0.0, 0.0, 0.0]),
            &pset(vec![2.0, 0.0, 0.0]),
            &pset(vec![1.0, 3.0, 0.0]),
        )
        .unwrap();
        assert!((tensor::sets_norm(&p.u) - 1.0).abs() < 1e-6);
        assert!((tensor::sets_norm(&p.v) - 1.0).abs() < 1e-6);
        assert!(tensor::sets_dot(&p.u, &p.v).unwrap().abs() < 1e-6);
    }

    #[test]
    fn anchors_recovered_by_point() {
        let t1 = pset(vec![1.0, 2.0, 3.0]);
        let t2 = pset(vec![-1.0, 0.5, 2.0]);
        let t3 = pset(vec![0.0, -1.0, 1.0]);
        let p = Plane::through(&t1, &t2, &t3).unwrap();
        for (anchor, theta) in p.anchors.iter().zip([&t1, &t2, &t3]) {
            let recon = p.point(anchor.0, anchor.1).unwrap();
            assert!(recon.distance(theta).unwrap() < 1e-5);
        }
    }

    #[test]
    fn project_inverts_point_property() {
        property(40, |g| {
            let n = g.usize_in(3..30);
            let mk = |g: &mut crate::testutil::Gen| {
                pset((0..n).map(|_| g.normal()).collect())
            };
            let (t1, t2, t3) = (mk(g), mk(g), mk(g));
            let p = match Plane::through(&t1, &t2, &t3) {
                Ok(p) => p,
                Err(_) => return, // collinear draw — fine
            };
            let (a, b) = (g.f64_in(-2.0..2.0), g.f64_in(-2.0..2.0));
            let theta = p.point(a, b).unwrap();
            let (a2, b2) = p.project(&theta).unwrap();
            assert!((a - a2).abs() < 1e-3 * (1.0 + a.abs()), "{a} vs {a2}");
            assert!((b - b2).abs() < 1e-3 * (1.0 + b.abs()), "{b} vs {b2}");
            // points ON the plane have ~zero residual
            assert!(p.residual(&theta).unwrap() < 1e-3);
        });
    }

    #[test]
    fn collinear_rejected() {
        let t1 = pset(vec![0.0, 0.0]);
        let t2 = pset(vec![1.0, 1.0]);
        let t3 = pset(vec![2.0, 2.0]);
        assert!(Plane::through(&t1, &t2, &t3).is_err());
        assert!(Plane::through(&t1, &t1, &t3).is_err());
    }

    #[test]
    fn bounds_contain_anchors() {
        let p = Plane::through(
            &pset(vec![0.0, 0.0, 1.0]),
            &pset(vec![3.0, 0.0, 1.0]),
            &pset(vec![0.0, 2.0, 1.0]),
        )
        .unwrap();
        let (bx, by) = p.bounds(0.3);
        for (a, b) in p.anchors {
            assert!(bx.contains(&a) || a == bx.end);
            assert!(by.contains(&b) || b == by.end);
        }
    }
}
