//! The 2D affine plane through three weight vectors — the visualization
//! device of §4 (Garipov et al. / Izmailov et al. style). Figures 2 and 3
//! plot train/test error over the plane spanned by {LB, SGD, SWAP} or
//! {SGD1, SGD2, SGD3} with SWAP projected in.
//!
//! The basis vectors are flat arenas and all the geometry (axpy / dot /
//! norm) runs on contiguous slices via `model::flat` — grid evaluation
//! materializes each probe point with two fused axpys over one buffer.

use crate::model::{FlatParams, ParamSet};
use crate::util::{Error, Result};

/// Orthonormal basis (u, v) of the plane through theta1, theta2, theta3,
/// with theta1 as origin.
pub struct Plane {
    pub origin: ParamSet,
    pub u: FlatParams,
    pub v: FlatParams,
    /// plane coordinates of the three anchors
    pub anchors: [(f64, f64); 3],
}

impl Plane {
    pub fn through(theta1: &ParamSet, theta2: &ParamSet, theta3: &ParamSet) -> Result<Plane> {
        let d2 = theta2.sub(theta1)?;
        let d3 = theta3.sub(theta1)?;
        let n2 = d2.norm(1);
        if n2 == 0.0 {
            return Err(Error::invalid("plane: theta2 == theta1"));
        }
        let mut u = d2;
        u.scale((1.0 / n2) as f32, 1);
        // Gram-Schmidt
        let a3 = d3.dot(&u, 1)?;
        let n3 = d3.norm(1);
        let mut vres = d3;
        vres.axpy(-a3 as f32, &u, 1)?;
        let nv = vres.norm(1);
        // relative threshold: f32 Gram-Schmidt leaves ~1e-7 of residual on
        // exactly collinear points
        if nv < 1e-5 * n3.max(1e-12) {
            return Err(Error::invalid("plane: three points are collinear"));
        }
        vres.scale((1.0 / nv) as f32, 1);
        Ok(Plane {
            origin: theta1.clone(),
            u,
            v: vres,
            anchors: [(0.0, 0.0), (n2, 0.0), (a3, nv)],
        })
    }

    /// The weight vector at plane coordinates (alpha, beta).
    pub fn point(&self, alpha: f64, beta: f64) -> Result<ParamSet> {
        self.point_mt(alpha, beta, 1)
    }

    /// Chunk-parallel variant (grid evaluation); bitwise identical to
    /// `point` for any thread count.
    pub fn point_mt(&self, alpha: f64, beta: f64, threads: usize) -> Result<ParamSet> {
        let mut t = self.origin.clone();
        t.axpy(alpha as f32, &self.u, threads)?;
        t.axpy(beta as f32, &self.v, threads)?;
        Ok(t)
    }

    /// Project an arbitrary weight vector onto plane coordinates.
    pub fn project(&self, theta: &ParamSet) -> Result<(f64, f64)> {
        self.project_mt(theta, 1)
    }

    pub fn project_mt(&self, theta: &ParamSet, threads: usize) -> Result<(f64, f64)> {
        let d = theta.sub_mt(&self.origin, threads)?;
        Ok((d.dot(&self.u, threads)?, d.dot(&self.v, threads)?))
    }

    /// Distance from the plane (how far off-plane a projected point is).
    pub fn residual(&self, theta: &ParamSet) -> Result<f64> {
        let (a, b) = self.project(theta)?;
        let on_plane = self.point(a, b)?;
        theta.distance(&on_plane, 1)
    }

    /// A bounding box (with margin) around the anchors — the grid extent
    /// Figures 2/3 use.
    pub fn bounds(&self, margin: f64) -> (std::ops::Range<f64>, std::ops::Range<f64>) {
        let xs: Vec<f64> = self.anchors.iter().map(|a| a.0).collect();
        let ys: Vec<f64> = self.anchors.iter().map(|a| a.1).collect();
        let (x0, x1) = (
            xs.iter().cloned().fold(f64::INFINITY, f64::min),
            xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        );
        let (y0, y1) = (
            ys.iter().cloned().fold(f64::INFINITY, f64::min),
            ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        );
        let (dx, dy) = ((x1 - x0).max(1e-9), (y1 - y0).max(1e-9));
        (
            x0 - margin * dx..x1 + margin * dx,
            y0 - margin * dy..y1 + margin * dy,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::property;

    fn pset(vals: Vec<f32>) -> ParamSet {
        ParamSet::from_vec(vals)
    }

    #[test]
    fn orthonormal_basis() {
        let p = Plane::through(
            &pset(vec![0.0, 0.0, 0.0]),
            &pset(vec![2.0, 0.0, 0.0]),
            &pset(vec![1.0, 3.0, 0.0]),
        )
        .unwrap();
        assert!((p.u.norm(1) - 1.0).abs() < 1e-6);
        assert!((p.v.norm(1) - 1.0).abs() < 1e-6);
        assert!(p.u.dot(&p.v, 1).unwrap().abs() < 1e-6);
    }

    #[test]
    fn anchors_recovered_by_point() {
        let t1 = pset(vec![1.0, 2.0, 3.0]);
        let t2 = pset(vec![-1.0, 0.5, 2.0]);
        let t3 = pset(vec![0.0, -1.0, 1.0]);
        let p = Plane::through(&t1, &t2, &t3).unwrap();
        for (anchor, theta) in p.anchors.iter().zip([&t1, &t2, &t3]) {
            let recon = p.point(anchor.0, anchor.1).unwrap();
            assert!(recon.distance(theta, 1).unwrap() < 1e-5);
        }
    }

    #[test]
    fn project_inverts_point_property() {
        property(40, |g| {
            let n = g.usize_in(3..30);
            let mk = |g: &mut crate::testutil::Gen| {
                pset((0..n).map(|_| g.normal()).collect())
            };
            let (t1, t2, t3) = (mk(g), mk(g), mk(g));
            let p = match Plane::through(&t1, &t2, &t3) {
                Ok(p) => p,
                Err(_) => return, // collinear draw — fine
            };
            let (a, b) = (g.f64_in(-2.0..2.0), g.f64_in(-2.0..2.0));
            let theta = p.point(a, b).unwrap();
            let (a2, b2) = p.project(&theta).unwrap();
            assert!((a - a2).abs() < 1e-3 * (1.0 + a.abs()), "{a} vs {a2}");
            assert!((b - b2).abs() < 1e-3 * (1.0 + b.abs()), "{b} vs {b2}");
            // points ON the plane have ~zero residual
            assert!(p.residual(&theta).unwrap() < 1e-3);
        });
    }

    #[test]
    fn point_and_project_threads_bitwise() {
        let t1 = pset((0..4097).map(|i| (i as f32 * 0.013).sin()).collect());
        let t2 = pset((0..4097).map(|i| (i as f32 * 0.031).cos()).collect());
        let t3 = pset((0..4097).map(|i| (i as f32 * 0.007).sin() + 0.1).collect());
        let p = Plane::through(&t1, &t2, &t3).unwrap();
        let seq = p.point(0.7, -1.3).unwrap();
        let sp = p.project(&t3).unwrap();
        for threads in [2, 4] {
            assert_eq!(seq, p.point_mt(0.7, -1.3, threads).unwrap());
            let pp = p.project_mt(&t3, threads).unwrap();
            assert_eq!(sp.0.to_bits(), pp.0.to_bits());
            assert_eq!(sp.1.to_bits(), pp.1.to_bits());
        }
    }

    #[test]
    fn collinear_rejected() {
        let t1 = pset(vec![0.0, 0.0]);
        let t2 = pset(vec![1.0, 1.0]);
        let t3 = pset(vec![2.0, 2.0]);
        assert!(Plane::through(&t1, &t2, &t3).is_err());
        assert!(Plane::through(&t1, &t1, &t3).is_err());
    }

    #[test]
    fn bounds_contain_anchors() {
        let p = Plane::through(
            &pset(vec![0.0, 0.0, 1.0]),
            &pset(vec![3.0, 0.0, 1.0]),
            &pset(vec![0.0, 2.0, 1.0]),
        )
        .unwrap();
        let (bx, by) = p.bounds(0.3);
        for (a, b) in p.anchors {
            assert!(bx.contains(&a) || a == bx.end);
            assert!(by.contains(&b) || b == by.end);
        }
    }
}
