//! Error-surface evaluation over a plane (Figures 2 and 3).
//!
//! For every grid point (alpha, beta): materialize theta = origin + alpha u
//! + beta v, recompute BN statistics (one pass over training batches — the
//! §4 procedure: "compute the batch-norm statistics for that model, then
//! evaluate"), and measure train and test error.

use super::plane::Plane;
use crate::coordinator::TrainEnv;
use crate::metrics::SeriesLog;
use crate::sim::ClusterClock;
use crate::util::Result;

/// Grid resolution and evaluation budget.
#[derive(Debug, Clone)]
pub struct GridSpec {
    /// points per axis
    pub n: usize,
    /// margin around the anchors' bounding box
    pub margin: f64,
    /// max train/test batches per point (keeps grids tractable)
    pub max_eval_batches: usize,
}

impl Default for GridSpec {
    fn default() -> Self {
        GridSpec { n: 15, margin: 0.35, max_eval_batches: 4 }
    }
}

/// One evaluated grid point.
#[derive(Debug, Clone, Copy)]
pub struct GridPoint {
    pub alpha: f64,
    pub beta: f64,
    pub train_err: f64,
    pub test_err: f64,
    pub train_loss: f64,
    pub test_loss: f64,
}

/// The evaluated surface + the argmin of test error ("BEST" in Figure 3).
pub struct GridResult {
    pub points: Vec<GridPoint>,
    pub best_test: GridPoint,
    pub spec: GridSpec,
}

pub fn eval_grid(
    env: &TrainEnv,
    plane: &Plane,
    spec: &GridSpec,
    seed: u64,
    clock: &mut ClusterClock,
) -> Result<GridResult> {
    let (bx, by) = plane.bounds(spec.margin);
    let lin = |r: &std::ops::Range<f64>, i: usize| {
        r.start + (r.end - r.start) * i as f64 / (spec.n - 1).max(1) as f64
    };
    let mut points = Vec::with_capacity(spec.n * spec.n);
    let mut best: Option<GridPoint> = None;
    for i in 0..spec.n {
        for j in 0..spec.n {
            let (alpha, beta) = (lin(&bx, i), lin(&by, j));
            let theta = plane.point_mt(alpha, beta, env.threads)?;
            let bn = env.recompute_bn(&theta, seed, clock, false)?;
            let tr = env.evaluate_on(env.train, &theta, &bn, clock, spec.max_eval_batches)?;
            let te = env.evaluate_on(env.test, &theta, &bn, clock, spec.max_eval_batches)?;
            let p = GridPoint {
                alpha,
                beta,
                train_err: 1.0 - tr.accuracy1(),
                test_err: 1.0 - te.accuracy1(),
                train_loss: tr.mean_loss(),
                test_loss: te.mean_loss(),
            };
            points.push(p);
            if best.map(|b| p.test_err < b.test_err).unwrap_or(true) {
                best = Some(p);
            }
        }
        crate::debug!("grid row {}/{} done", i + 1, spec.n);
    }
    Ok(GridResult {
        points,
        best_test: best.unwrap(),
        spec: spec.clone(),
    })
}

impl GridResult {
    /// CSV series: alpha, beta, train_err, test_err, train_loss, test_loss.
    pub fn to_series(&self) -> SeriesLog {
        let mut s = SeriesLog::new(&[
            "alpha", "beta", "train_err", "test_err", "train_loss", "test_loss",
        ]);
        for p in &self.points {
            s.push(&[p.alpha, p.beta, p.train_err, p.test_err, p.train_loss, p.test_loss]);
        }
        s
    }

    /// Error at the grid point nearest to the given plane coordinates.
    pub fn nearest(&self, alpha: f64, beta: f64) -> &GridPoint {
        self.points
            .iter()
            .min_by(|a, b| {
                let da = (a.alpha - alpha).powi(2) + (a.beta - beta).powi(2);
                let db = (b.alpha - alpha).powi(2) + (b.beta - beta).powi(2);
                da.partial_cmp(&db).unwrap()
            })
            .unwrap()
    }
}
