//! Training-dynamics analysis (§4.2, Figure 4): cosine similarity between
//! the descent direction −g_t and the direction toward the SWAP average
//! Δθ = θ_swap − θ_t, plus weight-travel statistics (Hoffer et al.-style
//! distance from initialization). All the geometry runs on flat arenas —
//! the backend's gradient arena is wrapped into a `FlatParams` over the
//! model's shared layout without copying or reshaping.

use crate::coordinator::TrainEnv;
use crate::data::{AugmentSpec, Batcher};
use crate::metrics::SeriesLog;
use crate::model::{FlatParams, ParamSet};
use crate::runtime::Backend;
use crate::util::{Result, Rng};

/// Cosine series along a snapshot trail: for every (step, theta_t) compute
/// a fresh mini-batch gradient g_t and report
/// cos(−g_t, theta_target − theta_t) — Figure 4's y-axis.
pub fn cosine_to_target(
    env: &TrainEnv,
    trail: &[(usize, ParamSet)],
    target: &ParamSet,
    seed: u64,
) -> Result<SeriesLog> {
    let mut out = SeriesLog::new(&["step", "cosine", "grad_norm", "dist_to_target"]);
    let b = env.exec_batch;
    let mut rng = Rng::stream(seed, 0xF16);
    let batcher = Batcher::new(b, env.image_size(), AugmentSpec::none());
    let mut hb = batcher.make_batch();
    for (step, theta) in trail {
        // a random clean training batch for the gradient probe
        let idx: Vec<usize> = (0..b).map(|_| rng.below(env.train.n)).collect();
        batcher.assemble_clean_into(env.train, &idx, &mut hb);
        let g = env.engine.grad(theta.as_slice(), &hb)?;
        // -g direction vs (target - theta)
        let delta = target.sub(theta)?;
        let mut neg = FlatParams::from_data(theta.layout().clone(), g.grads)?;
        neg.scale(-1.0, 1);
        let cos = neg.cosine(&delta, 1)?;
        out.push(&[*step as f64, cos, neg.norm(1), delta.norm(1)]);
    }
    Ok(out)
}

/// Distance of every snapshot from a reference point (weight travel,
/// Hoffer et al.'s "distance from initialization").
pub fn travel_series(trail: &[(usize, ParamSet)], reference: &ParamSet) -> Result<SeriesLog> {
    let mut out = SeriesLog::new(&["step", "distance"]);
    for (step, theta) in trail {
        out.push(&[*step as f64, theta.distance(reference, 1)?]);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pset(vals: Vec<f32>) -> ParamSet {
        ParamSet::from_vec(vals)
    }

    #[test]
    fn travel_series_distances() {
        let trail = vec![
            (0usize, pset(vec![0.0, 0.0])),
            (10, pset(vec![3.0, 4.0])),
        ];
        let s = travel_series(&trail, &pset(vec![0.0, 0.0])).unwrap();
        assert_eq!(s.column("distance").unwrap(), vec![0.0, 5.0]);
        assert_eq!(s.column("step").unwrap(), vec![0.0, 10.0]);
    }
}
