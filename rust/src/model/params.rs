//! Parameter / BN-state containers aligned to the artifact manifest order.
//!
//! Since the flat-arena refactor both are contiguous [`FlatParams`] arenas
//! over a shared [`ParamLayout`]: `ParamSet` IS a flat weight vector (the
//! alias keeps the coordinator's vocabulary), and `BnState` wraps one over
//! the manifest's `bn_stats` layout.
//!
//! Initialization matches python/compile/model.py's scheme (He-normal conv
//! weights, BN gamma=1 beta=0, zero biases) — the *values* need not match
//! python (training starts from rust-side init), only the convention, and
//! the RNG stream is consumed in manifest order exactly as the legacy
//! per-tensor init did (bitwise-identical seeds).

use std::sync::Arc;

use super::flat::{FlatParams, ParamLayout};
use crate::runtime::manifest::Manifest;
use crate::util::{Error, Result};

/// An ordered set of parameters — one contiguous arena in manifest order.
pub type ParamSet = FlatParams;

/// Running batch-norm statistics (mean=0, var=1 until recomputed), as a
/// flat arena over the manifest's `bn_stats` layout.
#[derive(Debug, Clone, PartialEq)]
pub struct BnState {
    pub flat: FlatParams,
}

impl BnState {
    pub fn init(manifest: &Manifest) -> Self {
        let layout = ParamLayout::of_bn(manifest);
        let mut flat = FlatParams::zeros(layout.clone());
        for i in 0..layout.len() {
            if layout.spec(i).name.ends_with(".var") {
                flat.view_mut(i).iter_mut().for_each(|v| *v = 1.0);
            }
        }
        BnState { flat }
    }

    /// Wrap an existing flat moment arena (backend `bn_moments` output).
    pub fn from_flat(flat: FlatParams) -> Self {
        BnState { flat }
    }

    /// Average a list of per-batch flat moment arenas into running
    /// statistics — phase 3 of SWAP (Algorithm 1, line 28). Plain
    /// arithmetic mean over batches, the SWA-standard recompute.
    pub fn from_moments(layout: Arc<ParamLayout>, batches: &[Vec<f32>]) -> Result<Self> {
        let first = batches
            .first()
            .ok_or_else(|| Error::invalid("bn from_moments: no batches"))?;
        if first.len() != layout.total() {
            return Err(Error::shape(format!(
                "bn moments have {} elements, layout wants {}",
                first.len(),
                layout.total()
            )));
        }
        if batches.iter().any(|b| b.len() != first.len()) {
            return Err(Error::shape("bn from_moments: ragged batches"));
        }
        let mut flat = FlatParams::zeros(layout);
        let views: Vec<&[f32]> = batches.iter().map(|b| b.as_slice()).collect();
        crate::tensor::flat::mean_into(1, flat.data_mut(), &views);
        Ok(BnState { flat })
    }

    /// The flat mean/var arena (manifest `bn_stats` order) — what crosses
    /// the `Backend::eval_batch` boundary.
    pub fn as_slice(&self) -> &[f32] {
        self.flat.data()
    }

    /// Flat view of stat tensor `i`.
    pub fn view(&self, i: usize) -> &[f32] {
        self.flat.view(i)
    }

    pub fn layout(&self) -> &Arc<ParamLayout> {
        self.flat.layout()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        let text = r#"{
          "preset": "unit",
          "model": {"arch":"resnet9s","width":4,"num_classes":10,"image_size":16,
                    "momentum":0.9,"weight_decay":0.0005,"head_scale":0.125,"bn_eps":1e-05},
          "params": [{"name":"prep.w","shape":[27,4]},
                     {"name":"prep.gamma","shape":[4]},
                     {"name":"prep.beta","shape":[4]},
                     {"name":"head.b","shape":[10]}],
          "bn_stats": [{"name":"prep.mean","shape":[4]},{"name":"prep.var","shape":[4]}],
          "num_params": 126,
          "batches": [8],
          "executables": {},
          "flops_fwd_per_example": 1
        }"#;
        Manifest::parse(text, PathBuf::new()).unwrap()
    }

    #[test]
    fn init_shapes_and_conventions() {
        let m = manifest();
        let p = ParamSet::init(&m, 0);
        assert_eq!(p.layout().len(), 4);
        assert_eq!(p.numel(), 126);
        // gamma all ones, beta/bias all zeros
        assert!(p.view(1).iter().all(|&x| x == 1.0));
        assert!(p.view(2).iter().all(|&x| x == 0.0));
        assert!(p.view(3).iter().all(|&x| x == 0.0));
        // conv weights: nonzero, roughly He-scaled
        let w = p.view(0);
        assert!(w.iter().any(|&x| x != 0.0));
        let sq: f64 = w.iter().map(|&x| x as f64 * x as f64).sum();
        let std = (sq / w.len() as f64).sqrt();
        let expect = (2.0f64 / 27.0).sqrt();
        assert!((std - expect).abs() < expect * 0.5, "std {std} vs {expect}");
    }

    #[test]
    fn init_deterministic_per_seed() {
        let m = manifest();
        assert_eq!(ParamSet::init(&m, 7), ParamSet::init(&m, 7));
        assert_ne!(ParamSet::init(&m, 7), ParamSet::init(&m, 8));
    }

    #[test]
    fn zeros_like_matches_shapes() {
        let m = manifest();
        let p = ParamSet::init(&m, 0);
        let z = p.zeros_like();
        assert_eq!(z.numel(), p.numel());
        assert!(z.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn average_and_distance() {
        let m = manifest();
        let a = ParamSet::init(&m, 1);
        let b = ParamSet::init(&m, 2);
        let avg = ParamSet::average(&[a.clone(), b.clone()]).unwrap();
        // distance(avg, a) == distance(avg, b) for a 2-mean
        let da = avg.distance(&a, 1).unwrap();
        let db = avg.distance(&b, 1).unwrap();
        assert!((da - db).abs() < 1e-6 * da.max(1.0));
        assert!(avg.distance(&avg, 1).unwrap() == 0.0);
    }

    #[test]
    fn bn_state_init_mean0_var1() {
        let m = manifest();
        let bn = BnState::init(&m);
        assert!(bn.view(0).iter().all(|&x| x == 0.0));
        assert!(bn.view(1).iter().all(|&x| x == 1.0));
    }

    #[test]
    fn bn_from_moments_averages() {
        let m = manifest();
        let layout = ParamLayout::of_bn(&m); // (mean[4], var[4])
        let b1 = vec![0.0, 2.0, 0.0, 2.0, 1.0, 1.0, 1.0, 1.0];
        let b2 = vec![2.0, 0.0, 2.0, 0.0, 3.0, 1.0, 3.0, 1.0];
        let bn = BnState::from_moments(layout, &[b1, b2]).unwrap();
        assert_eq!(bn.view(0), &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(bn.view(1), &[2.0, 1.0, 2.0, 1.0]);
    }

    #[test]
    fn bn_from_moments_validates() {
        let m = manifest();
        let layout = ParamLayout::of_bn(&m);
        assert!(BnState::from_moments(layout.clone(), &[]).is_err());
        assert!(BnState::from_moments(layout.clone(), &[vec![0.0; 3]]).is_err());
        assert!(
            BnState::from_moments(layout, &[vec![0.0; 8], vec![0.0; 7]]).is_err()
        );
    }
}
