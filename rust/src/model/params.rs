//! Parameter / BN-state containers aligned to the artifact manifest order.
//!
//! Initialization matches python/compile/model.py's scheme (He-normal conv
//! weights, BN gamma=1 beta=0, zero biases) — the *values* need not match
//! python (training starts from rust-side init), only the convention.

use crate::runtime::manifest::{Manifest, TensorSpec};
use crate::tensor::Tensor;
use crate::util::{Result, Rng};

/// An ordered set of parameter tensors (manifest order).
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSet {
    pub tensors: Vec<Tensor>,
}

impl ParamSet {
    /// He-normal initialization from the manifest specs.
    pub fn init(manifest: &Manifest, seed: u64) -> Self {
        let mut rng = Rng::stream(seed, 0x9a9a);
        let tensors = manifest
            .params
            .iter()
            .map(|spec| init_tensor(spec, &mut rng))
            .collect();
        ParamSet { tensors }
    }

    /// All-zeros set with matching shapes (momentum buffers).
    pub fn zeros_like(&self) -> Self {
        ParamSet {
            tensors: self
                .tensors
                .iter()
                .map(|t| Tensor::zeros(t.shape().to_vec()))
                .collect(),
        }
    }

    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    pub fn as_slice(&self) -> &[Tensor] {
        &self.tensors
    }

    pub fn as_mut_slice(&mut self) -> &mut [Tensor] {
        &mut self.tensors
    }

    /// Euclidean distance to another set (weight-travel statistics).
    pub fn distance(&self, other: &ParamSet) -> Result<f64> {
        crate::tensor::sets_distance(&self.tensors, &other.tensors)
    }

    /// Mean of several sets — SWAP phase 3 (host-side path).
    pub fn average(sets: &[ParamSet]) -> Result<ParamSet> {
        let slices: Vec<Vec<Tensor>> = sets.iter().map(|s| s.tensors.clone()).collect();
        Ok(ParamSet {
            tensors: crate::tensor::average_sets(&slices)?,
        })
    }
}

fn init_tensor(spec: &TensorSpec, rng: &mut Rng) -> Tensor {
    let name = spec.name.as_str();
    if name.ends_with(".w") {
        let fan_in = spec.shape[0] as f32;
        let sigma = (2.0 / fan_in).sqrt();
        Tensor::from_fn(spec.shape.clone(), |_| rng.normal_scaled(0.0, sigma))
    } else if name.ends_with(".gamma") {
        Tensor::full(spec.shape.clone(), 1.0)
    } else {
        // beta, biases
        Tensor::zeros(spec.shape.clone())
    }
}

/// Running batch-norm statistics (mean=0, var=1 until recomputed).
#[derive(Debug, Clone, PartialEq)]
pub struct BnState {
    pub tensors: Vec<Tensor>,
}

impl BnState {
    pub fn init(manifest: &Manifest) -> Self {
        let tensors = manifest
            .bn_stats
            .iter()
            .map(|spec| {
                if spec.name.ends_with(".var") {
                    Tensor::full(spec.shape.clone(), 1.0)
                } else {
                    Tensor::zeros(spec.shape.clone())
                }
            })
            .collect();
        BnState { tensors }
    }

    /// Average a list of per-batch moment sets into running statistics —
    /// phase 3 of SWAP (Algorithm 1, line 28). Plain arithmetic mean over
    /// batches of the batch means/vars, the SWA-standard recompute.
    pub fn from_moments(moment_batches: &[Vec<Tensor>]) -> Result<Self> {
        Ok(BnState {
            tensors: crate::tensor::average_sets(moment_batches)?,
        })
    }

    pub fn as_slice(&self) -> &[Tensor] {
        &self.tensors
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        let text = r#"{
          "preset": "unit",
          "model": {"arch":"resnet9s","width":4,"num_classes":10,"image_size":16,
                    "momentum":0.9,"weight_decay":0.0005,"head_scale":0.125,"bn_eps":1e-05},
          "params": [{"name":"prep.w","shape":[27,4]},
                     {"name":"prep.gamma","shape":[4]},
                     {"name":"prep.beta","shape":[4]},
                     {"name":"head.b","shape":[10]}],
          "bn_stats": [{"name":"prep.mean","shape":[4]},{"name":"prep.var","shape":[4]}],
          "num_params": 126,
          "batches": [8],
          "executables": {},
          "flops_fwd_per_example": 1
        }"#;
        Manifest::parse(text, PathBuf::new()).unwrap()
    }

    #[test]
    fn init_shapes_and_conventions() {
        let m = manifest();
        let p = ParamSet::init(&m, 0);
        assert_eq!(p.tensors.len(), 4);
        assert_eq!(p.numel(), 126);
        // gamma all ones, beta/bias all zeros
        assert!(p.tensors[1].data().iter().all(|&x| x == 1.0));
        assert!(p.tensors[2].data().iter().all(|&x| x == 0.0));
        assert!(p.tensors[3].data().iter().all(|&x| x == 0.0));
        // conv weights: nonzero, roughly He-scaled
        let w = &p.tensors[0];
        assert!(w.data().iter().any(|&x| x != 0.0));
        let std = (w.sq_norm() / w.numel() as f64).sqrt();
        let expect = (2.0f64 / 27.0).sqrt();
        assert!((std - expect).abs() < expect * 0.5, "std {std} vs {expect}");
    }

    #[test]
    fn init_deterministic_per_seed() {
        let m = manifest();
        assert_eq!(ParamSet::init(&m, 7), ParamSet::init(&m, 7));
        assert_ne!(ParamSet::init(&m, 7), ParamSet::init(&m, 8));
    }

    #[test]
    fn zeros_like_matches_shapes() {
        let m = manifest();
        let p = ParamSet::init(&m, 0);
        let z = p.zeros_like();
        assert_eq!(z.numel(), p.numel());
        assert!(z.tensors.iter().all(|t| t.data().iter().all(|&x| x == 0.0)));
    }

    #[test]
    fn average_and_distance() {
        let m = manifest();
        let a = ParamSet::init(&m, 1);
        let b = ParamSet::init(&m, 2);
        let avg = ParamSet::average(&[a.clone(), b.clone()]).unwrap();
        // distance(avg, a) == distance(avg, b) for a 2-mean
        let da = avg.distance(&a).unwrap();
        let db = avg.distance(&b).unwrap();
        assert!((da - db).abs() < 1e-6 * da.max(1.0));
        assert!(avg.distance(&avg).unwrap() == 0.0);
    }

    #[test]
    fn bn_state_init_mean0_var1() {
        let m = manifest();
        let bn = BnState::init(&m);
        assert!(bn.tensors[0].data().iter().all(|&x| x == 0.0));
        assert!(bn.tensors[1].data().iter().all(|&x| x == 1.0));
    }

    #[test]
    fn bn_from_moments_averages() {
        let b1 = vec![
            Tensor::new(vec![2], vec![0.0, 2.0]).unwrap(),
            Tensor::new(vec![2], vec![1.0, 1.0]).unwrap(),
        ];
        let b2 = vec![
            Tensor::new(vec![2], vec![2.0, 0.0]).unwrap(),
            Tensor::new(vec![2], vec![3.0, 1.0]).unwrap(),
        ];
        let bn = BnState::from_moments(&[b1, b2]).unwrap();
        assert_eq!(bn.tensors[0].data(), &[1.0, 1.0]);
        assert_eq!(bn.tensors[1].data(), &[2.0, 1.0]);
    }
}
