//! The flat weight-space arena — the canonical representation of every
//! weight-shaped vector in the system (parameters, gradients, momentum,
//! BN moments, SWA/SWAP model banks, snapshot trails).
//!
//! A [`FlatParams`] is one contiguous `Vec<f32>` plus a shared
//! [`ParamLayout`] (`Arc`, built once from the manifest) that records the
//! named offsets and shapes of the tensors packed inside, in manifest
//! order. All weight-space arithmetic — the fused optimizer step, ring
//! all-reduce, phase-3 averaging, and the landscape-plane geometry — runs
//! directly on the arena through the chunk-parallel kernels in
//! [`crate::tensor::flat`]; per-tensor [`Tensor`] views exist only at the
//! backend/manifest edge (fixtures, legacy oracles, conversions).
//!
//! Flattening convention: tensors are packed back-to-back in manifest
//! order (`params[0]`, `params[1]`, ...), each in its own row-major
//! layout. `layout.range(i)` is tensor `i`'s subslice of the arena.

use std::ops::Range;
use std::sync::Arc;

use crate::runtime::manifest::{Manifest, TensorSpec};
use crate::tensor::{flat, Tensor};
use crate::util::{Error, Result, Rng};

/// Immutable layout of a flat arena: named tensor specs (manifest order)
/// plus their precomputed offsets. Built once, shared via `Arc` by every
/// weight vector of the same model.
#[derive(Debug, PartialEq)]
pub struct ParamLayout {
    specs: Vec<TensorSpec>,
    /// offsets.len() == specs.len() + 1; offsets[i]..offsets[i+1] is
    /// tensor i's subslice
    offsets: Vec<usize>,
}

impl ParamLayout {
    /// Build a layout from ordered tensor specs.
    pub fn from_specs(specs: Vec<TensorSpec>) -> Arc<Self> {
        let mut offsets = Vec::with_capacity(specs.len() + 1);
        let mut off = 0usize;
        offsets.push(0);
        for s in &specs {
            off += s.numel();
            offsets.push(off);
        }
        Arc::new(ParamLayout { specs, offsets })
    }

    /// The parameter layout of a manifest (what `ParamSet` uses).
    pub fn of_params(m: &Manifest) -> Arc<Self> {
        Self::from_specs(m.params.clone())
    }

    /// The BN running-statistics layout of a manifest (what `BnState` uses).
    pub fn of_bn(m: &Manifest) -> Arc<Self> {
        Self::from_specs(m.bn_stats.clone())
    }

    /// A synthetic single-tensor layout (tests / ad-hoc vectors).
    pub fn single(n: usize) -> Arc<Self> {
        Self::from_specs(vec![TensorSpec { name: "t0".to_string(), shape: vec![n] }])
    }

    /// Number of tensors in the layout.
    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Total element count of the arena.
    pub fn total(&self) -> usize {
        *self.offsets.last().expect("offsets never empty")
    }

    pub fn spec(&self, i: usize) -> &TensorSpec {
        &self.specs[i]
    }

    pub fn specs(&self) -> &[TensorSpec] {
        &self.specs
    }

    /// Arena subrange of tensor `i`.
    pub fn range(&self, i: usize) -> Range<usize> {
        self.offsets[i]..self.offsets[i + 1]
    }

    /// All per-tensor ranges, in order (reduction chunk boundaries).
    pub fn ranges(&self) -> Vec<Range<usize>> {
        (0..self.len()).map(|i| self.range(i)).collect()
    }

    /// Index of a tensor by manifest name.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.specs.iter().position(|s| s.name == name)
    }
}

/// One weight vector: a contiguous f32 arena over a shared layout.
#[derive(Debug, Clone, PartialEq)]
pub struct FlatParams {
    layout: Arc<ParamLayout>,
    data: Vec<f32>,
}

impl FlatParams {
    /// All-zeros arena for a layout.
    pub fn zeros(layout: Arc<ParamLayout>) -> Self {
        let n = layout.total();
        FlatParams { layout, data: vec![0.0; n] }
    }

    /// Wrap an existing arena, validating its length against the layout.
    pub fn from_data(layout: Arc<ParamLayout>, data: Vec<f32>) -> Result<Self> {
        if data.len() != layout.total() {
            return Err(Error::shape(format!(
                "flat arena has {} elements, layout wants {}",
                data.len(),
                layout.total()
            )));
        }
        Ok(FlatParams { layout, data })
    }

    /// A single-tensor vector (tests / ad-hoc weight-space points).
    pub fn from_vec(data: Vec<f32>) -> Self {
        let layout = ParamLayout::single(data.len());
        FlatParams { layout, data }
    }

    /// Flatten per-tensor views into an arena, validating count + shapes
    /// against the layout (the backend/manifest edge, fixtures).
    pub fn from_tensors(layout: Arc<ParamLayout>, tensors: &[Tensor]) -> Result<Self> {
        if tensors.len() != layout.len() {
            return Err(Error::shape(format!(
                "{} tensors for a {}-tensor layout",
                tensors.len(),
                layout.len()
            )));
        }
        let mut data = Vec::with_capacity(layout.total());
        for (t, spec) in tensors.iter().zip(layout.specs()) {
            if t.shape() != spec.shape.as_slice() {
                return Err(Error::shape(format!(
                    "tensor {}: shape {:?} != layout {:?}",
                    spec.name,
                    t.shape(),
                    spec.shape
                )));
            }
            data.extend_from_slice(t.data());
        }
        Ok(FlatParams { layout, data })
    }

    /// He-normal parameter initialization from the manifest (conv weights
    /// `.w` He-scaled, `.gamma` ones, beta/biases zero). Consumes the RNG
    /// stream in manifest order, exactly like the legacy per-tensor init.
    pub fn init(manifest: &Manifest, seed: u64) -> Self {
        let layout = ParamLayout::of_params(manifest);
        let mut data = vec![0.0f32; layout.total()];
        let mut rng = Rng::stream(seed, 0x9a9a);
        for i in 0..layout.len() {
            let r = layout.range(i);
            let spec = layout.spec(i);
            let slice = &mut data[r];
            if spec.name.ends_with(".w") {
                let fan_in = spec.shape[0] as f32;
                let sigma = (2.0 / fan_in).sqrt();
                for v in slice.iter_mut() {
                    *v = rng.normal_scaled(0.0, sigma);
                }
            } else if spec.name.ends_with(".gamma") {
                for v in slice.iter_mut() {
                    *v = 1.0;
                }
            }
            // beta / biases stay zero
        }
        FlatParams { layout, data }
    }

    /// All-zeros vector with the same layout (momentum buffers).
    pub fn zeros_like(&self) -> Self {
        FlatParams {
            layout: self.layout.clone(),
            data: vec![0.0; self.data.len()],
        }
    }

    pub fn layout(&self) -> &Arc<ParamLayout> {
        &self.layout
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// The whole arena — what crosses the `Backend` boundary.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Flat view of tensor `i` (manifest order).
    pub fn view(&self, i: usize) -> &[f32] {
        &self.data[self.layout.range(i)]
    }

    pub fn view_mut(&mut self, i: usize) -> &mut [f32] {
        let r = self.layout.range(i);
        &mut self.data[r]
    }

    /// Materialize tensor `i` (backend/manifest edge only).
    pub fn tensor(&self, i: usize) -> Tensor {
        Tensor::new(self.layout.spec(i).shape.clone(), self.view(i).to_vec())
            .expect("layout shapes are consistent by construction")
    }

    /// Materialize the whole per-tensor list (legacy oracles, fixtures).
    pub fn to_tensors(&self) -> Vec<Tensor> {
        (0..self.layout.len()).map(|i| self.tensor(i)).collect()
    }

    /// Move the arena out, leaving an empty (0-element) shell behind —
    /// the zero-copy ownership handoff the trainer's optimizer uses.
    pub fn take(&mut self) -> FlatParams {
        FlatParams {
            layout: self.layout.clone(),
            data: std::mem::take(&mut self.data),
        }
    }

    fn check_same(&self, other: &FlatParams) -> Result<()> {
        if Arc::ptr_eq(&self.layout, &other.layout) || self.layout == other.layout {
            Ok(())
        } else {
            Err(Error::shape("flat params: layout mismatch"))
        }
    }

    // ------------------------------------------------------------------
    // Weight-space arithmetic (chunk-parallel flat kernels; results are
    // bitwise-identical for every `threads` value)
    // ------------------------------------------------------------------

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|a| *a = v);
    }

    /// self += alpha * x
    pub fn axpy(&mut self, alpha: f32, x: &FlatParams, threads: usize) -> Result<()> {
        self.check_same(x)?;
        flat::axpy(threads, &mut self.data, alpha, &x.data);
        Ok(())
    }

    /// self += x — the streaming-accumulation step of the averaging
    /// policies. One `add_assign_mt` per candidate followed by a single
    /// `scale(1/n)` reproduces `average_mt`'s accumulation order bitwise
    /// (see `tensor::flat::add`).
    pub fn add_assign_mt(&mut self, x: &FlatParams, threads: usize) -> Result<()> {
        self.check_same(x)?;
        flat::add(threads, &mut self.data, &x.data);
        Ok(())
    }

    /// self *= alpha
    pub fn scale(&mut self, alpha: f32, threads: usize) {
        flat::scale(threads, &mut self.data, alpha);
    }

    /// self - other, as a new vector (landscape direction vectors).
    pub fn sub(&self, other: &FlatParams) -> Result<FlatParams> {
        self.sub_mt(other, 1)
    }

    /// Chunk-parallel subtraction; bitwise identical for any thread count.
    pub fn sub_mt(&self, other: &FlatParams, threads: usize) -> Result<FlatParams> {
        self.check_same(other)?;
        let mut out = self.clone();
        flat::axpy(threads, &mut out.data, -1.0, &other.data);
        Ok(out)
    }

    /// Full weight-space inner product (f64, per-tensor partial order).
    pub fn dot(&self, x: &FlatParams, threads: usize) -> Result<f64> {
        self.check_same(x)?;
        Ok(flat::dot_ranges(threads, &self.data, &x.data, &self.layout.ranges()))
    }

    pub fn sq_norm(&self, threads: usize) -> f64 {
        flat::sq_norm_ranges(threads, &self.data, &self.layout.ranges())
    }

    pub fn norm(&self, threads: usize) -> f64 {
        self.sq_norm(threads).sqrt()
    }

    /// Cosine similarity; 0 for degenerate (zero) vectors — the Figure-4
    /// convention of the legacy `sets_cosine`.
    pub fn cosine(&self, x: &FlatParams, threads: usize) -> Result<f64> {
        let na = self.norm(threads);
        let nb = x.norm(threads);
        if na == 0.0 || nb == 0.0 {
            return Ok(0.0);
        }
        Ok(self.dot(x, threads)? / (na * nb))
    }

    /// Euclidean distance (weight-travel statistics).
    pub fn distance(&self, other: &FlatParams, threads: usize) -> Result<f64> {
        self.check_same(other)?;
        Ok(flat::distance_ranges(threads, &self.data, &other.data, &self.layout.ranges()))
    }

    /// Streaming mean of several weight vectors — SWAP phase 3. One output
    /// allocation, no per-worker clones, chunk-parallel across `threads`.
    pub fn average_mt(sets: &[FlatParams], threads: usize) -> Result<FlatParams> {
        let first = sets
            .first()
            .ok_or_else(|| Error::invalid("average: no sets"))?;
        for s in &sets[1..] {
            first.check_same(s)?;
        }
        let mut out = FlatParams {
            layout: first.layout.clone(),
            data: vec![0.0; first.data.len()],
        };
        let views: Vec<&[f32]> = sets.iter().map(|s| s.data.as_slice()).collect();
        flat::mean_into(threads, &mut out.data, &views);
        Ok(out)
    }

    /// Sequential mean (same bits as `average_mt` for any thread count).
    pub fn average(sets: &[FlatParams]) -> Result<FlatParams> {
        Self::average_mt(sets, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<TensorSpec> {
        vec![
            TensorSpec { name: "a.w".to_string(), shape: vec![2, 3] },
            TensorSpec { name: "a.gamma".to_string(), shape: vec![3] },
            TensorSpec { name: "b".to_string(), shape: vec![] },
        ]
    }

    #[test]
    fn layout_offsets_and_lookup() {
        let l = ParamLayout::from_specs(specs());
        assert_eq!(l.len(), 3);
        assert_eq!(l.total(), 10);
        assert_eq!(l.range(0), 0..6);
        assert_eq!(l.range(1), 6..9);
        assert_eq!(l.range(2), 9..10);
        assert_eq!(l.index_of("a.gamma"), Some(1));
        assert_eq!(l.index_of("nope"), None);
        assert_eq!(l.ranges(), vec![0..6, 6..9, 9..10]);
    }

    #[test]
    fn tensors_roundtrip_through_arena() {
        let l = ParamLayout::from_specs(specs());
        let tensors = vec![
            Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap(),
            Tensor::new(vec![3], vec![7.0, 8.0, 9.0]).unwrap(),
            Tensor::scalar(-1.0),
        ];
        let fp = FlatParams::from_tensors(l.clone(), &tensors).unwrap();
        assert_eq!(fp.numel(), 10);
        assert_eq!(fp.view(1), &[7.0, 8.0, 9.0]);
        assert_eq!(fp.to_tensors(), tensors);
    }

    #[test]
    fn from_tensors_validates_shapes() {
        let l = ParamLayout::from_specs(specs());
        let bad = vec![
            Tensor::new(vec![3, 2], vec![0.0; 6]).unwrap(), // transposed
            Tensor::new(vec![3], vec![0.0; 3]).unwrap(),
            Tensor::scalar(0.0),
        ];
        assert!(FlatParams::from_tensors(l.clone(), &bad).is_err());
        assert!(FlatParams::from_tensors(l.clone(), &[]).is_err());
        assert!(FlatParams::from_data(l, vec![0.0; 9]).is_err());
    }

    #[test]
    fn arithmetic_and_layout_mismatch() {
        let mut a = FlatParams::from_vec(vec![1.0, 1.0]);
        let d = FlatParams::from_vec(vec![1.0, -1.0]);
        a.axpy(2.0, &d, 1).unwrap();
        assert_eq!(a.data(), &[3.0, -1.0]);
        a.scale(0.5, 1);
        assert_eq!(a.data(), &[1.5, -0.5]);
        let other = FlatParams::from_vec(vec![0.0; 3]);
        assert!(a.axpy(1.0, &other, 1).is_err());
        assert!(a.dot(&other, 1).is_err());
        assert!(a.distance(&other, 1).is_err());
    }

    #[test]
    fn geometry_matches_tensor_oracle() {
        let a = FlatParams::from_vec(vec![3.0, 4.0]);
        let z = a.zeros_like();
        assert_eq!(a.norm(1), 5.0);
        assert_eq!(a.distance(&z, 1).unwrap(), 5.0);
        assert_eq!(a.distance(&z, 4).unwrap(), 5.0);
        let b = FlatParams::from_vec(vec![4.0, -3.0]);
        assert_eq!(a.dot(&b, 1).unwrap(), 0.0);
        assert_eq!(a.cosine(&b, 1).unwrap(), 0.0);
        assert!((a.cosine(&a, 1).unwrap() - 1.0).abs() < 1e-12);
        assert_eq!(a.cosine(&z, 1).unwrap(), 0.0);
    }

    #[test]
    fn average_is_elementwise_mean() {
        let a = FlatParams::from_vec(vec![0.0, 2.0]);
        let b = FlatParams::from_vec(vec![4.0, 0.0]);
        let avg = FlatParams::average(&[a.clone(), b]).unwrap();
        assert_eq!(avg.data(), &[2.0, 1.0]);
        assert!(FlatParams::average(&[]).is_err());
        let same = FlatParams::average(&[a.clone()]).unwrap();
        assert_eq!(same, a);
    }

    #[test]
    fn average_parallel_bitwise_equals_sequential() {
        // crosses the spawn gate so the chunked path really runs
        let n = 250_001;
        let sets: Vec<FlatParams> = (0..5)
            .map(|w| {
                FlatParams::from_data(
                    ParamLayout::single(n),
                    (0..n).map(|i| ((i * 31 + w * 7) as f32 * 0.01).sin()).collect(),
                )
                .unwrap()
            })
            .collect();
        let seq = FlatParams::average_mt(&sets, 1).unwrap();
        for threads in [2, 4, 9] {
            assert_eq!(seq, FlatParams::average_mt(&sets, threads).unwrap());
        }
    }

    #[test]
    fn take_leaves_empty_shell() {
        let mut a = FlatParams::from_vec(vec![1.0, 2.0]);
        let b = a.take();
        assert_eq!(b.data(), &[1.0, 2.0]);
        assert!(a.data().is_empty());
    }
}
