//! Checkpoint save/load: own little-endian binary format (no serde/bincode
//! in the vendored crate set).
//!
//! Layout:
//!   magic "SWAPCKP1" (8 bytes)
//!   u32 tensor count
//!   per tensor: u32 name_len, name bytes (utf-8),
//!               u32 rank, u64 dims[rank],
//!               f32 data[prod(dims)]
//!
//! Used for: phase-1 -> phase-2 handoff on disk, SWA model banks, and the
//! landscape tools (they reload the LB/SGD/SWAP anchor points).

use std::io::{Read, Write};
use std::path::Path;

use crate::tensor::Tensor;
use crate::util::{Error, Result};

const MAGIC: &[u8; 8] = b"SWAPCKP1";

/// Save named tensors (order preserved).
pub fn save(path: impl AsRef<Path>, named: &[(String, &Tensor)]) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(named.len() as u32).to_le_bytes());
    for (name, t) in named {
        let nb = name.as_bytes();
        buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
        buf.extend_from_slice(nb);
        buf.extend_from_slice(&(t.shape().len() as u32).to_le_bytes());
        for d in t.shape() {
            buf.extend_from_slice(&(*d as u64).to_le_bytes());
        }
        for x in t.data() {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    let tmp = path.as_ref().with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&buf)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path.as_ref())?; // atomic publish
    Ok(())
}

/// Load all tensors with their names, in file order.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<(String, Tensor)>> {
    let mut f = std::fs::File::open(path.as_ref())?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    let mut r = Reader { b: &buf, i: 0 };
    let magic = r.take(8)?;
    if magic != MAGIC {
        return Err(Error::invalid(format!(
            "{}: not a swap checkpoint",
            path.as_ref().display()
        )));
    }
    let count = r.u32()? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = r.u32()? as usize;
        let name = String::from_utf8(r.take(name_len)?.to_vec())
            .map_err(|_| Error::invalid("bad checkpoint name"))?;
        let rank = r.u32()? as usize;
        if rank > 16 {
            return Err(Error::invalid("implausible tensor rank"));
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(r.u64()? as usize);
        }
        let n: usize = shape.iter().product();
        let bytes = r.take(n * 4)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push((name, Tensor::new(shape, data)?));
    }
    if r.i != buf.len() {
        return Err(Error::invalid("trailing bytes in checkpoint"));
    }
    Ok(out)
}

/// Save a plain tensor list with synthesized names (param sets).
pub fn save_tensors(path: impl AsRef<Path>, names: &[String], tensors: &[Tensor]) -> Result<()> {
    if names.len() != tensors.len() {
        return Err(Error::invalid("names/tensors length mismatch"));
    }
    let named: Vec<(String, &Tensor)> = names
        .iter()
        .cloned()
        .zip(tensors.iter())
        .collect();
    save(path, &named)
}

/// Load into a plain tensor list, verifying names match the expectation.
pub fn load_tensors(path: impl AsRef<Path>, expect_names: &[String]) -> Result<Vec<Tensor>> {
    let named = load(path)?;
    if named.len() != expect_names.len() {
        return Err(Error::invalid(format!(
            "checkpoint has {} tensors, want {}",
            named.len(),
            expect_names.len()
        )));
    }
    named
        .into_iter()
        .zip(expect_names)
        .map(|((name, t), want)| {
            if &name != want {
                return Err(Error::invalid(format!(
                    "checkpoint tensor '{name}' where '{want}' expected"
                )));
            }
            Ok(t)
        })
        .collect()
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.i + n;
        let s = self
            .b
            .get(self.i..end)
            .ok_or_else(|| Error::invalid("truncated checkpoint"))?;
        self.i = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("swap-ckpt-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let p = tmpfile("roundtrip");
        let a = Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 1e-7, -1e7]).unwrap();
        let b = Tensor::scalar(42.0);
        save(&p, &[("a".into(), &a), ("b".into(), &b)]).unwrap();
        let loaded = load(&p).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0].0, "a");
        assert_eq!(loaded[0].1, a);
        assert_eq!(loaded[1].1, b);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn load_tensors_checks_names() {
        let p = tmpfile("names");
        let a = Tensor::zeros(vec![3]);
        save_tensors(&p, &["x".into()], &[a]).unwrap();
        assert!(load_tensors(&p, &["x".into()]).is_ok());
        assert!(load_tensors(&p, &["y".into()]).is_err());
        assert!(load_tensors(&p, &["x".into(), "z".into()]).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let p = tmpfile("garbage");
        std::fs::write(&p, b"not a checkpoint at all").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_truncated() {
        let p = tmpfile("trunc");
        let a = Tensor::zeros(vec![100]);
        save(&p, &[("a".into(), &a)]).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 10]).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
