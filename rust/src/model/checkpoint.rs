//! Checkpoint save/load: own little-endian binary format (no serde/bincode
//! in the vendored crate set).
//!
//! Layout:
//!   magic "SWAPCKP1" (8 bytes)
//!   u32 tensor count
//!   per tensor: u32 name_len, name bytes (utf-8),
//!               u32 rank, u64 dims[rank],
//!               f32 data[prod(dims)]
//!
//! Used for: phase-1 -> phase-2 handoff on disk, SWA model banks, and the
//! landscape tools (they reload the LB/SGD/SWAP anchor points).
//!
//! The flat-arena entry points (`save_flat` / `load_flat`) keep the same
//! on-disk format but read/write each weight vector through ONE contiguous
//! buffer: records are emitted straight from `ParamLayout` subslices and
//! loaded back into a single `Vec<f32>` arena — no per-tensor
//! materialization. Checkpoints written before the refactor load
//! unchanged.
//!
//! Every header field is validated against the remaining buffer length
//! BEFORE any allocation or read, so a truncated or hostile file errors
//! cleanly instead of over-allocating.

use std::io::{Read, Write};
use std::path::Path;

use super::flat::ParamLayout;
use crate::tensor::Tensor;
use crate::util::{Error, Result};

const MAGIC: &[u8; 8] = b"SWAPCKP1";
/// Checkpoints never hold tensors beyond rank 16 (the model is rank <= 2).
const MAX_RANK: usize = 16;
/// Minimum bytes one tensor record can occupy (empty name, rank 0, no
/// data): u32 name_len + u32 rank.
const MIN_RECORD_BYTES: usize = 8;

fn write_record(buf: &mut Vec<u8>, name: &str, shape: &[usize], data: &[f32]) {
    let nb = name.as_bytes();
    buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
    buf.extend_from_slice(nb);
    buf.extend_from_slice(&(shape.len() as u32).to_le_bytes());
    for d in shape {
        buf.extend_from_slice(&(*d as u64).to_le_bytes());
    }
    for x in data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn publish(path: impl AsRef<Path>, buf: &[u8]) -> Result<()> {
    let tmp = path.as_ref().with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(buf)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path.as_ref())?; // atomic publish
    Ok(())
}

/// Save named tensors (order preserved).
pub fn save(path: impl AsRef<Path>, named: &[(String, &Tensor)]) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(named.len() as u32).to_le_bytes());
    for (name, t) in named {
        write_record(&mut buf, name, t.shape(), t.data());
    }
    publish(path, &buf)
}

/// Save a flat arena under its layout's names — each record is written
/// from the arena subslice directly (one contiguous source per vector).
pub fn save_flat(path: impl AsRef<Path>, layout: &ParamLayout, data: &[f32]) -> Result<()> {
    if data.len() != layout.total() {
        return Err(Error::shape(format!(
            "save_flat: arena has {} elements, layout wants {}",
            data.len(),
            layout.total()
        )));
    }
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(layout.len() as u32).to_le_bytes());
    for i in 0..layout.len() {
        let spec = layout.spec(i);
        write_record(&mut buf, &spec.name, &spec.shape, &data[layout.range(i)]);
    }
    publish(path, &buf)
}

/// Parse one record header, validating every field against the remaining
/// buffer before any allocation. Returns (name, shape).
fn read_header(r: &mut Reader) -> Result<(String, Vec<usize>)> {
    let name_len = r.u32()? as usize;
    if name_len > r.remaining() {
        return Err(Error::invalid("checkpoint name extends past end of file"));
    }
    let name = String::from_utf8(r.take(name_len)?.to_vec())
        .map_err(|_| Error::invalid("bad checkpoint name"))?;
    let rank = r.u32()? as usize;
    if rank > MAX_RANK {
        return Err(Error::invalid("implausible tensor rank"));
    }
    if rank.saturating_mul(8) > r.remaining() {
        return Err(Error::invalid("checkpoint shape extends past end of file"));
    }
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        let d = usize::try_from(r.u64()?)
            .map_err(|_| Error::invalid("shape dim overflows usize"))?;
        shape.push(d);
    }
    Ok((name, shape))
}

/// Element count of a validated shape; errors if the product overflows or
/// the implied data bytes exceed what is left in the buffer.
fn checked_numel(shape: &[usize], remaining: usize) -> Result<usize> {
    let n = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| Error::invalid("tensor shape product overflows"))?;
    let bytes = n
        .checked_mul(4)
        .ok_or_else(|| Error::invalid("tensor byte size overflows"))?;
    if bytes > remaining {
        return Err(Error::invalid("tensor data extends past end of file"));
    }
    Ok(n)
}

fn open_reader<'a>(buf: &'a [u8], path: &Path) -> Result<(Reader<'a>, usize)> {
    let mut r = Reader { b: buf, i: 0 };
    let magic = r.take(8)?;
    if magic != MAGIC {
        return Err(Error::invalid(format!(
            "{}: not a swap checkpoint",
            path.display()
        )));
    }
    let count = r.u32()? as usize;
    // every record occupies at least MIN_RECORD_BYTES, so a hostile count
    // cannot force a huge Vec::with_capacity
    if count > r.remaining() / MIN_RECORD_BYTES {
        return Err(Error::invalid("implausible tensor count"));
    }
    Ok((r, count))
}

/// Load all tensors with their names, in file order.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<(String, Tensor)>> {
    let mut f = std::fs::File::open(path.as_ref())?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    let (mut r, count) = open_reader(&buf, path.as_ref())?;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let (name, shape) = read_header(&mut r)?;
        let n = checked_numel(&shape, r.remaining())?;
        let bytes = r.take(n * 4)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push((name, Tensor::new(shape, data)?));
    }
    if r.i != buf.len() {
        return Err(Error::invalid("trailing bytes in checkpoint"));
    }
    Ok(out)
}

/// Load a checkpoint straight into a flat arena, verifying the record
/// names and shapes against `layout` (one contiguous destination).
pub fn load_flat(path: impl AsRef<Path>, layout: &ParamLayout) -> Result<Vec<f32>> {
    let mut f = std::fs::File::open(path.as_ref())?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    let (mut r, count) = open_reader(&buf, path.as_ref())?;
    if count != layout.len() {
        return Err(Error::invalid(format!(
            "checkpoint has {} tensors, layout wants {}",
            count,
            layout.len()
        )));
    }
    let mut arena = vec![0.0f32; layout.total()];
    for i in 0..count {
        let (name, shape) = read_header(&mut r)?;
        let spec = layout.spec(i);
        if name != spec.name {
            return Err(Error::invalid(format!(
                "checkpoint tensor '{name}' where '{}' expected",
                spec.name
            )));
        }
        if shape != spec.shape {
            return Err(Error::invalid(format!(
                "checkpoint tensor '{name}': shape {shape:?} != layout {:?}",
                spec.shape
            )));
        }
        let n = checked_numel(&shape, r.remaining())?;
        let bytes = r.take(n * 4)?;
        let dst = &mut arena[layout.range(i)];
        for (d, c) in dst.iter_mut().zip(bytes.chunks_exact(4)) {
            *d = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
    }
    if r.i != buf.len() {
        return Err(Error::invalid("trailing bytes in checkpoint"));
    }
    Ok(arena)
}

/// Save a plain tensor list with synthesized names (param sets).
pub fn save_tensors(path: impl AsRef<Path>, names: &[String], tensors: &[Tensor]) -> Result<()> {
    if names.len() != tensors.len() {
        return Err(Error::invalid("names/tensors length mismatch"));
    }
    let named: Vec<(String, &Tensor)> = names
        .iter()
        .cloned()
        .zip(tensors.iter())
        .collect();
    save(path, &named)
}

/// Load into a plain tensor list, verifying names match the expectation.
pub fn load_tensors(path: impl AsRef<Path>, expect_names: &[String]) -> Result<Vec<Tensor>> {
    let named = load(path)?;
    if named.len() != expect_names.len() {
        return Err(Error::invalid(format!(
            "checkpoint has {} tensors, want {}",
            named.len(),
            expect_names.len()
        )));
    }
    named
        .into_iter()
        .zip(expect_names)
        .map(|((name, t), want)| {
            if &name != want {
                return Err(Error::invalid(format!(
                    "checkpoint tensor '{name}' where '{want}' expected"
                )));
            }
            Ok(t)
        })
        .collect()
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .i
            .checked_add(n)
            .ok_or_else(|| Error::invalid("checkpoint offset overflows"))?;
        let s = self
            .b
            .get(self.i..end)
            .ok_or_else(|| Error::invalid("truncated checkpoint"))?;
        self.i = end;
        Ok(s)
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorSpec;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("swap-ckpt-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let p = tmpfile("roundtrip");
        let a = Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 1e-7, -1e7]).unwrap();
        let b = Tensor::scalar(42.0);
        save(&p, &[("a".into(), &a), ("b".into(), &b)]).unwrap();
        let loaded = load(&p).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0].0, "a");
        assert_eq!(loaded[0].1, a);
        assert_eq!(loaded[1].1, b);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn flat_roundtrip_and_legacy_compat() {
        let p = tmpfile("flat-roundtrip");
        let layout = ParamLayout::from_specs(vec![
            TensorSpec { name: "x.w".into(), shape: vec![2, 2] },
            TensorSpec { name: "x.b".into(), shape: vec![3] },
        ]);
        let arena: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0, -1.0, -2.0, -3.0];
        save_flat(&p, &layout, &arena).unwrap();
        // flat reload
        assert_eq!(load_flat(&p, &layout).unwrap(), arena);
        // the per-tensor loader reads the very same file
        let named = load(&p).unwrap();
        assert_eq!(named[0].0, "x.w");
        assert_eq!(named[0].1.shape(), &[2, 2]);
        assert_eq!(named[1].1.data(), &[-1.0, -2.0, -3.0]);
        // and a file written per-tensor flat-loads
        let named_refs: Vec<(String, &Tensor)> = vec![
            ("x.w".into(), &named[0].1),
            ("x.b".into(), &named[1].1),
        ];
        save(&p, &named_refs).unwrap();
        assert_eq!(load_flat(&p, &layout).unwrap(), arena);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn load_flat_checks_names_and_shapes() {
        let p = tmpfile("flat-names");
        let layout = ParamLayout::from_specs(vec![TensorSpec {
            name: "x".into(),
            shape: vec![3],
        }]);
        save_flat(&p, &layout, &[1.0, 2.0, 3.0]).unwrap();
        let wrong_name = ParamLayout::from_specs(vec![TensorSpec {
            name: "y".into(),
            shape: vec![3],
        }]);
        assert!(load_flat(&p, &wrong_name).is_err());
        let wrong_shape = ParamLayout::from_specs(vec![TensorSpec {
            name: "x".into(),
            shape: vec![1, 3],
        }]);
        assert!(load_flat(&p, &wrong_shape).is_err());
        let wrong_count = ParamLayout::from_specs(vec![
            TensorSpec { name: "x".into(), shape: vec![3] },
            TensorSpec { name: "z".into(), shape: vec![1] },
        ]);
        assert!(load_flat(&p, &wrong_count).is_err());
        assert!(save_flat(&p, &layout, &[1.0]).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn load_tensors_checks_names() {
        let p = tmpfile("names");
        let a = Tensor::zeros(vec![3]);
        save_tensors(&p, &["x".into()], &[a]).unwrap();
        assert!(load_tensors(&p, &["x".into()]).is_ok());
        assert!(load_tensors(&p, &["y".into()]).is_err());
        assert!(load_tensors(&p, &["x".into(), "z".into()]).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let p = tmpfile("garbage");
        std::fs::write(&p, b"not a checkpoint at all").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_truncated() {
        let p = tmpfile("trunc");
        let a = Tensor::zeros(vec![100]);
        save(&p, &[("a".into(), &a)]).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 10]).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    /// Hostile headers must error cleanly BEFORE any big allocation.
    #[test]
    fn rejects_hostile_headers() {
        let p = tmpfile("hostile");
        let mut base: Vec<u8> = Vec::new();
        base.extend_from_slice(MAGIC);

        // count far beyond what the buffer could hold
        let mut b = base.clone();
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&p, &b).unwrap();
        assert!(load(&p).is_err());

        // name_len beyond the end of the file
        let mut b = base.clone();
        b.extend_from_slice(&1u32.to_le_bytes()); // count = 1
        b.extend_from_slice(&1_000_000u32.to_le_bytes()); // name_len
        std::fs::write(&p, &b).unwrap();
        assert!(load(&p).is_err());

        // implausible rank
        let mut b = base.clone();
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes()); // name_len = 1
        b.push(b'x');
        b.extend_from_slice(&17u32.to_le_bytes()); // rank 17 > MAX_RANK
        std::fs::write(&p, &b).unwrap();
        assert!(load(&p).is_err());

        // shape product that overflows usize
        let mut b = base.clone();
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes());
        b.push(b'x');
        b.extend_from_slice(&2u32.to_le_bytes()); // rank 2
        b.extend_from_slice(&(u64::MAX / 2).to_le_bytes());
        b.extend_from_slice(&4u64.to_le_bytes());
        std::fs::write(&p, &b).unwrap();
        assert!(load(&p).is_err());

        // plausible-looking shape whose data would extend past the end
        let mut b = base.clone();
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u32.to_le_bytes());
        b.push(b'x');
        b.extend_from_slice(&1u32.to_le_bytes()); // rank 1
        b.extend_from_slice(&1_000_000u64.to_le_bytes()); // 4MB of data...
        b.extend_from_slice(&[0u8; 16]); // ...but only 16 bytes present
        std::fs::write(&p, &b).unwrap();
        assert!(load(&p).is_err());

        // the flat loader applies the same validation
        let layout = ParamLayout::from_specs(vec![TensorSpec {
            name: "x".into(),
            shape: vec![1_000_000],
        }]);
        assert!(load_flat(&p, &layout).is_err());

        std::fs::remove_file(&p).ok();
    }
}
