//! Model-state layer: parameter sets aligned to the manifest, batch-norm
//! running statistics, weight averaging, and checkpointing.

pub mod checkpoint;
pub mod params;

pub use params::{BnState, ParamSet};

use crate::runtime::Manifest;
use crate::util::Result;

/// Save a ParamSet (+ optional momentum) under the manifest's tensor names.
pub fn save_params(
    path: impl AsRef<std::path::Path>,
    manifest: &Manifest,
    params: &ParamSet,
) -> Result<()> {
    let names: Vec<String> = manifest.params.iter().map(|s| s.name.clone()).collect();
    checkpoint::save_tensors(path, &names, &params.tensors)
}

/// Load a ParamSet saved by `save_params`, verifying names.
pub fn load_params(
    path: impl AsRef<std::path::Path>,
    manifest: &Manifest,
) -> Result<ParamSet> {
    let names: Vec<String> = manifest.params.iter().map(|s| s.name.clone()).collect();
    Ok(ParamSet {
        tensors: checkpoint::load_tensors(path, &names)?,
    })
}
