//! Model-state layer: the flat weight-space arena (`FlatParams` +
//! `ParamLayout`), parameter sets and batch-norm statistics aligned to the
//! manifest, weight averaging, and checkpointing.

pub mod checkpoint;
pub mod flat;
pub mod params;

pub use flat::{FlatParams, ParamLayout};
pub use params::{BnState, ParamSet};

use crate::runtime::Manifest;
use crate::util::Result;

/// Save a ParamSet under the manifest's tensor names — one contiguous
/// read per vector straight from the arena.
pub fn save_params(
    path: impl AsRef<std::path::Path>,
    manifest: &Manifest,
    params: &ParamSet,
) -> Result<()> {
    let layout = ParamLayout::of_params(manifest);
    checkpoint::save_flat(path, &layout, params.data())
}

/// Load a ParamSet saved by `save_params`, verifying names and shapes
/// against the manifest layout — one contiguous write per vector.
pub fn load_params(
    path: impl AsRef<std::path::Path>,
    manifest: &Manifest,
) -> Result<ParamSet> {
    let layout = ParamLayout::of_params(manifest);
    let data = checkpoint::load_flat(path, &layout)?;
    ParamSet::from_data(layout, data)
}

/// The combined layout of a *servable* model bundle: every parameter
/// tensor followed by every BN running-statistic tensor, manifest order.
/// One file holds everything inference needs — `swap serve-model` loads
/// it without touching training state.
fn model_bundle_layout(manifest: &Manifest) -> std::sync::Arc<ParamLayout> {
    let mut specs = manifest.params.clone();
    specs.extend(manifest.bn_stats.iter().cloned());
    ParamLayout::from_specs(specs)
}

/// Save a servable model bundle (parameters + BN running statistics) as
/// one checkpoint. Same validated `SWAPCKP1` container as `save_params`,
/// atomically published.
pub fn save_model(
    path: impl AsRef<std::path::Path>,
    manifest: &Manifest,
    params: &ParamSet,
    bn: &BnState,
) -> Result<()> {
    let layout = model_bundle_layout(manifest);
    let mut data = Vec::with_capacity(layout.total());
    data.extend_from_slice(params.data());
    data.extend_from_slice(bn.as_slice());
    checkpoint::save_flat(path, &layout, &data)
}

/// Load a servable model bundle saved by [`save_model`], verifying every
/// tensor name/shape against the manifest.
pub fn load_model(
    path: impl AsRef<std::path::Path>,
    manifest: &Manifest,
) -> Result<(ParamSet, BnState)> {
    let layout = model_bundle_layout(manifest);
    let data = checkpoint::load_flat(path, &layout)?;
    let p_layout = ParamLayout::of_params(manifest);
    let bn_layout = ParamLayout::of_bn(manifest);
    let np = p_layout.total();
    let params = ParamSet::from_data(p_layout, data[..np].to_vec())?;
    let bn = BnState::from_flat(FlatParams::from_data(bn_layout, data[np..].to_vec())?);
    Ok((params, bn))
}
