//! Model-state layer: the flat weight-space arena (`FlatParams` +
//! `ParamLayout`), parameter sets and batch-norm statistics aligned to the
//! manifest, weight averaging, and checkpointing.

pub mod checkpoint;
pub mod flat;
pub mod params;

pub use flat::{FlatParams, ParamLayout};
pub use params::{BnState, ParamSet};

use crate::runtime::Manifest;
use crate::util::Result;

/// Save a ParamSet under the manifest's tensor names — one contiguous
/// read per vector straight from the arena.
pub fn save_params(
    path: impl AsRef<std::path::Path>,
    manifest: &Manifest,
    params: &ParamSet,
) -> Result<()> {
    let layout = ParamLayout::of_params(manifest);
    checkpoint::save_flat(path, &layout, params.data())
}

/// Load a ParamSet saved by `save_params`, verifying names and shapes
/// against the manifest layout — one contiguous write per vector.
pub fn load_params(
    path: impl AsRef<std::path::Path>,
    manifest: &Manifest,
) -> Result<ParamSet> {
    let layout = ParamLayout::of_params(manifest);
    let data = checkpoint::load_flat(path, &layout)?;
    ParamSet::from_data(layout, data)
}
