//! The server: N shard worker threads over the shared dynamic batcher.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::batcher::{Acquire, BatchQueue, Slot};
use super::engine::{argmax, ServeModel, ShardEngine};
use super::stats::{Counters, ServerStats};
use crate::util::{Error, Result};

/// Batching/sharding knobs (the `serve_*` config family).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// shard engine workers, each with its own workspace (`serve_threads`)
    pub shards: usize,
    /// largest coalesced batch (`serve_max_batch`)
    pub max_batch: usize,
    /// longest a batch waits for co-batched requests past its first
    /// request (`serve_max_delay_us`); 0 = never wait
    pub max_delay: Duration,
    /// request slot arena size (`serve_queue_depth`); saturation sheds
    /// new requests with [`Error::Overloaded`] rather than blocking them
    /// or growing a queue without bound
    pub queue_slots: usize,
}

impl ServeConfig {
    /// Sensible defaults for `shards` workers: batches of 8, a 2 ms
    /// coalescing window, and enough slots to keep every shard busy with
    /// a full batch while another full batch queues behind it.
    pub fn for_shards(shards: usize) -> ServeConfig {
        let shards = shards.max(1);
        ServeConfig {
            shards,
            max_batch: 8,
            max_delay: Duration::from_micros(2000),
            queue_slots: shards * 8 * 2,
        }
    }

    fn validate(&self) -> Result<()> {
        if self.shards == 0 || self.max_batch == 0 {
            return Err(Error::config("serve: shards and max_batch must be >= 1"));
        }
        if self.queue_slots < self.max_batch {
            return Err(Error::config(format!(
                "serve: queue_slots {} < max_batch {} can never fill a batch",
                self.queue_slots, self.max_batch
            )));
        }
        Ok(())
    }
}

struct Inner {
    model: Arc<ServeModel>,
    queue: BatchQueue,
    slots: Vec<Slot>,
    counters: Counters,
}

/// A running inference server: shard workers live for the server's
/// lifetime; `Drop` shuts the queue down and joins them (in-flight
/// requests complete, blocked clients get an error).
pub struct Server {
    inner: Arc<Inner>,
    cfg: ServeConfig,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Preallocate the slot arena, warm one [`ShardEngine`] per shard
    /// (growing every buffer to the largest batch shape), and start the
    /// workers.
    pub fn start(model: Arc<ServeModel>, cfg: ServeConfig) -> Result<Server> {
        cfg.validate()?;
        let (il, nc) = (model.image_len(), model.num_classes());
        let slots = (0..cfg.queue_slots).map(|_| Slot::new(il, nc)).collect();
        let inner = Arc::new(Inner {
            model: model.clone(),
            queue: BatchQueue::new(cfg.queue_slots),
            slots,
            counters: Counters::default(),
        });
        let mut workers = Vec::with_capacity(cfg.shards);
        for shard in 0..cfg.shards {
            // warm on the spawning thread so start() surfaces engine
            // errors instead of burying them in a worker
            let mut eng = ShardEngine::new(&model, cfg.max_batch);
            eng.warm(&model)?;
            let inner = inner.clone();
            let (max_batch, max_delay) = (cfg.max_batch, cfg.max_delay);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("swap-serve-{shard}"))
                    .spawn(move || worker_loop(&inner, eng, max_batch, max_delay))
                    .map_err(|e| Error::invalid(format!("serve: spawn failed: {e}")))?,
            );
        }
        Ok(Server { inner, cfg, workers })
    }

    /// Serve one classification request: claims a request slot (a
    /// saturated server sheds with [`Error::Overloaded`] — retryable),
    /// blocks until the batched inference completes, writes the logits
    /// row into `logits_out` and returns the top-1 class. Zero
    /// allocations on the steady-state path.
    pub fn classify_into(&self, image: &[f32], logits_out: &mut [f32]) -> Result<usize> {
        if logits_out.len() != self.inner.model.num_classes() {
            return Err(Error::shape(format!(
                "logits buffer {} != num_classes {}",
                logits_out.len(),
                self.inner.model.num_classes()
            )));
        }
        self.request(image, Some(logits_out))
    }

    /// [`Server::classify_into`] without copying the logits out.
    pub fn classify(&self, image: &[f32]) -> Result<usize> {
        self.request(image, None)
    }

    fn request(&self, image: &[f32], logits_out: Option<&mut [f32]>) -> Result<usize> {
        if image.len() != self.inner.model.image_len() {
            return Err(Error::shape(format!(
                "request image {} f32s != model image {}",
                image.len(),
                self.inner.model.image_len()
            )));
        }
        let idx = match self.inner.queue.try_acquire() {
            Acquire::Slot(idx) => idx,
            Acquire::Full => {
                self.inner.counters.sheds.fetch_add(1, Ordering::Relaxed);
                return Err(Error::overloaded(format!(
                    "serve: all {} request slots in flight — shed; retry after backoff \
                     or raise serve_queue_depth",
                    self.cfg.queue_slots
                )));
            }
            Acquire::Shutdown => return Err(Error::invalid("serve: server is shut down")),
        };
        let slot = &self.inner.slots[idx as usize];
        {
            let mut st = slot.m.lock().unwrap();
            st.image.copy_from_slice(image);
            st.done = false;
            st.failed = false;
        }
        self.inner.queue.submit(idx);
        let (top1, failed) = {
            let mut st = slot.m.lock().unwrap();
            while !st.done {
                st = slot.cv.wait(st).unwrap();
            }
            if let Some(out) = logits_out {
                out.copy_from_slice(&st.logits);
            }
            (st.top1, st.failed)
        };
        self.inner.queue.release(idx);
        if failed {
            return Err(Error::invalid("serve: inference failed for this request"));
        }
        Ok(top1)
    }

    pub fn stats(&self) -> ServerStats {
        self.inner.counters.snapshot()
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    pub fn model(&self) -> &ServeModel {
        &self.inner.model
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.inner.queue.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One shard worker: pop a coalesced batch, stage the slot images into
/// the shard's own buffers, infer, complete the slots. All buffers are
/// preallocated (`batch` indices, engine staging, workspace) — the loop
/// body allocates nothing.
fn worker_loop(inner: &Inner, mut eng: ShardEngine, max_batch: usize, max_delay: Duration) {
    let nc = inner.model.num_classes();
    let mut batch: Vec<u32> = Vec::with_capacity(max_batch);
    while inner.queue.next_batch(&mut batch, max_batch, max_delay) {
        let b = batch.len();
        for (j, &idx) in batch.iter().enumerate() {
            let st = inner.slots[idx as usize].m.lock().unwrap();
            eng.image_slot(j).copy_from_slice(&st.image);
        }
        let ok = eng.infer(&inner.model, b).is_ok();
        if !ok {
            inner.counters.infer_errors.fetch_add(b as u64, Ordering::Relaxed);
        }
        for (j, &idx) in batch.iter().enumerate() {
            let slot = &inner.slots[idx as usize];
            let mut st = slot.m.lock().unwrap();
            if ok {
                let row = &eng.staged_logits()[j * nc..(j + 1) * nc];
                st.logits.copy_from_slice(row);
                st.top1 = argmax(row);
                st.failed = false;
            } else {
                st.logits.fill(0.0);
                st.top1 = 0;
                st.failed = true;
            }
            st.done = true;
            drop(st);
            slot.cv.notify_all();
        }
        inner.counters.note_batch(b);
    }
}
