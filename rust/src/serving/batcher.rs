//! The dynamic batcher: a fixed slot arena for in-flight requests and a
//! bounded pending queue that coalesces singles into GEMM-friendly
//! batches.
//!
//! Everything is preallocated at server start: `queue_slots` request
//! slots (each with its image/logits buffers, mutex and condvar) plus a
//! capacity-reserved `VecDeque`/free-list of slot indices. Steady-state
//! operation is pure index shuffling under short mutexes — **zero heap
//! allocations** (there are deliberately no channels here: `std::sync::mpsc`
//! allocates per send).
//!
//! Flow: a client acquires a free slot (blocking while the arena is
//! full — natural backpressure), writes its image, submits the index and
//! waits on the slot's condvar. A shard worker pops the first pending
//! index, then keeps popping until either `max_batch` is reached or
//! `max_delay` has elapsed since the batch opened (`Condvar::wait_timeout`
//! on the queue), runs the batch, writes logits back and signals each
//! slot. Latency is bounded by construction: a request waits at most
//! `max_delay` for co-batching plus one inference.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One in-flight request's state: staging buffers + completion flag.
pub(crate) struct SlotState {
    pub image: Vec<f32>,
    pub logits: Vec<f32>,
    pub top1: usize,
    pub done: bool,
    /// the worker failed this request (logits are zeroed)
    pub failed: bool,
}

/// A request slot: state under its own mutex + a completion condvar, so
/// completing one request never wakes unrelated waiters.
pub(crate) struct Slot {
    pub m: Mutex<SlotState>,
    pub cv: Condvar,
}

impl Slot {
    pub fn new(image_len: usize, num_classes: usize) -> Slot {
        Slot {
            m: Mutex::new(SlotState {
                image: vec![0.0; image_len],
                logits: vec![0.0; num_classes],
                top1: 0,
                done: false,
                failed: false,
            }),
            cv: Condvar::new(),
        }
    }
}

struct QState {
    pending: VecDeque<u32>,
    free: Vec<u32>,
    shutdown: bool,
}

/// The shared pending/free bookkeeping of the slot arena.
pub(crate) struct BatchQueue {
    m: Mutex<QState>,
    /// new pending work (or shutdown) — workers wait here
    cv_work: Condvar,
    /// a slot returned to the free list — blocked clients wait here
    cv_free: Condvar,
}

impl BatchQueue {
    pub fn new(slots: usize) -> BatchQueue {
        BatchQueue {
            m: Mutex::new(QState {
                pending: VecDeque::with_capacity(slots),
                free: (0..slots as u32).rev().collect(),
                shutdown: false,
            }),
            cv_work: Condvar::new(),
            cv_free: Condvar::new(),
        }
    }

    /// Claim a free slot, blocking while the arena is saturated
    /// (backpressure). `None` once the server is shutting down.
    pub fn acquire_free(&self) -> Option<u32> {
        let mut st = self.m.lock().unwrap();
        loop {
            if st.shutdown {
                return None;
            }
            if let Some(idx) = st.free.pop() {
                return Some(idx);
            }
            st = self.cv_free.wait(st).unwrap();
        }
    }

    /// Enqueue a filled slot for batching and wake one worker.
    pub fn submit(&self, idx: u32) {
        let mut st = self.m.lock().unwrap();
        st.pending.push_back(idx);
        drop(st);
        self.cv_work.notify_one();
    }

    /// Return a completed slot to the free list.
    pub fn release(&self, idx: u32) {
        let mut st = self.m.lock().unwrap();
        st.free.push(idx);
        drop(st);
        self.cv_free.notify_one();
    }

    /// Collect the next batch into `out` (cleared first): block for the
    /// first request, then coalesce up to `max_batch` pending requests,
    /// waiting at most `max_delay` past the batch opening for stragglers.
    /// Returns `false` when the server is shut down and the queue fully
    /// drained (workers exit then — in-flight requests still complete).
    pub fn next_batch(&self, out: &mut Vec<u32>, max_batch: usize, max_delay: Duration) -> bool {
        out.clear();
        let mut st = self.m.lock().unwrap();
        loop {
            if let Some(idx) = st.pending.pop_front() {
                out.push(idx);
                break;
            }
            if st.shutdown {
                return false;
            }
            st = self.cv_work.wait(st).unwrap();
        }
        let deadline = Instant::now() + max_delay;
        while out.len() < max_batch {
            if let Some(idx) = st.pending.pop_front() {
                out.push(idx);
                continue;
            }
            if st.shutdown {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _timeout) = self.cv_work.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        true
    }

    /// Flip the shutdown flag and wake everyone (blocked clients error
    /// out, workers drain and exit).
    pub fn shutdown(&self) {
        let mut st = self.m.lock().unwrap();
        st.shutdown = true;
        drop(st);
        self.cv_work.notify_all();
        self.cv_free.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coalesces_up_to_max_batch() {
        let q = BatchQueue::new(8);
        for _ in 0..5 {
            let idx = q.acquire_free().unwrap();
            q.submit(idx);
        }
        let mut batch = Vec::with_capacity(4);
        assert!(q.next_batch(&mut batch, 4, Duration::from_millis(1)));
        assert_eq!(batch.len(), 4);
        assert!(q.next_batch(&mut batch, 4, Duration::from_millis(1)));
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn shutdown_unblocks_everyone() {
        let q = BatchQueue::new(1);
        let a = q.acquire_free().unwrap();
        q.shutdown();
        // saturated arena + shutdown: a new client gets None, not a hang
        assert_eq!(q.acquire_free(), None);
        // a worker with no pending work exits
        let mut batch = Vec::new();
        assert!(!q.next_batch(&mut batch, 4, Duration::from_millis(1)));
        // but in-flight work still drains
        q.submit(a);
        assert!(q.next_batch(&mut batch, 4, Duration::from_millis(1)));
        assert_eq!(batch, vec![a]);
        assert!(!q.next_batch(&mut batch, 4, Duration::from_millis(1)));
    }

    #[test]
    fn release_recycles_slots() {
        let q = BatchQueue::new(2);
        let a = q.acquire_free().unwrap();
        let b = q.acquire_free().unwrap();
        assert_ne!(a, b);
        q.release(a);
        assert_eq!(q.acquire_free(), Some(a));
    }
}
