//! The dynamic batcher: a fixed slot arena for in-flight requests and a
//! bounded pending queue that coalesces singles into GEMM-friendly
//! batches.
//!
//! Everything is preallocated at server start: `queue_slots` request
//! slots (each with its image/logits buffers, mutex and condvar) plus a
//! capacity-reserved `VecDeque`/free-list of slot indices. Steady-state
//! operation is pure index shuffling under short mutexes — **zero heap
//! allocations** (there are deliberately no channels here: `std::sync::mpsc`
//! allocates per send).
//!
//! Flow: a client tries to claim a free slot — a saturated arena **sheds
//! the request immediately** ([`Acquire::Full`], surfaced to callers as
//! an explicit overload error) instead of blocking, so saturation shows
//! up at the edge as a retryable signal rather than as unbounded queueing
//! delay. A successful client writes its image, submits the index and
//! waits on the slot's condvar. A shard worker pops the first pending
//! index, then keeps popping until either `max_batch` is reached or
//! `max_delay` has elapsed since the batch opened (`Condvar::wait_timeout`
//! on the queue), runs the batch, writes logits back and signals each
//! slot. Latency is bounded by construction: an admitted request waits at
//! most `max_delay` for co-batching plus one inference.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One in-flight request's state: staging buffers + completion flag.
pub(crate) struct SlotState {
    pub image: Vec<f32>,
    pub logits: Vec<f32>,
    pub top1: usize,
    pub done: bool,
    /// the worker failed this request (logits are zeroed)
    pub failed: bool,
}

/// A request slot: state under its own mutex + a completion condvar, so
/// completing one request never wakes unrelated waiters.
pub(crate) struct Slot {
    pub m: Mutex<SlotState>,
    pub cv: Condvar,
}

impl Slot {
    pub fn new(image_len: usize, num_classes: usize) -> Slot {
        Slot {
            m: Mutex::new(SlotState {
                image: vec![0.0; image_len],
                logits: vec![0.0; num_classes],
                top1: 0,
                done: false,
                failed: false,
            }),
            cv: Condvar::new(),
        }
    }
}

struct QState {
    pending: VecDeque<u32>,
    free: Vec<u32>,
    shutdown: bool,
}

/// Outcome of a slot claim: the three states a client must distinguish
/// (admitted / shed / shutting down) map to distinct error classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Acquire {
    /// claimed this slot index
    Slot(u32),
    /// every slot is in flight — shed the request (retryable overload)
    Full,
    /// the server is shutting down (terminal)
    Shutdown,
}

/// The shared pending/free bookkeeping of the slot arena.
pub(crate) struct BatchQueue {
    m: Mutex<QState>,
    /// new pending work (or shutdown) — workers wait here
    cv_work: Condvar,
}

impl BatchQueue {
    pub fn new(slots: usize) -> BatchQueue {
        BatchQueue {
            m: Mutex::new(QState {
                pending: VecDeque::with_capacity(slots),
                free: (0..slots as u32).rev().collect(),
                shutdown: false,
            }),
            cv_work: Condvar::new(),
        }
    }

    /// Try to claim a free slot. Never blocks: a saturated arena returns
    /// [`Acquire::Full`] so the caller can shed the request with an
    /// explicit overload error instead of queueing without bound.
    pub fn try_acquire(&self) -> Acquire {
        let mut st = self.m.lock().unwrap();
        if st.shutdown {
            return Acquire::Shutdown;
        }
        match st.free.pop() {
            Some(idx) => Acquire::Slot(idx),
            None => Acquire::Full,
        }
    }

    /// Enqueue a filled slot for batching and wake one worker.
    pub fn submit(&self, idx: u32) {
        let mut st = self.m.lock().unwrap();
        st.pending.push_back(idx);
        drop(st);
        self.cv_work.notify_one();
    }

    /// Return a completed slot to the free list.
    pub fn release(&self, idx: u32) {
        let mut st = self.m.lock().unwrap();
        st.free.push(idx);
    }

    /// Collect the next batch into `out` (cleared first): block for the
    /// first request, then coalesce up to `max_batch` pending requests,
    /// waiting at most `max_delay` past the batch opening for stragglers.
    /// Returns `false` when the server is shut down and the queue fully
    /// drained (workers exit then — in-flight requests still complete).
    pub fn next_batch(&self, out: &mut Vec<u32>, max_batch: usize, max_delay: Duration) -> bool {
        out.clear();
        let mut st = self.m.lock().unwrap();
        loop {
            if let Some(idx) = st.pending.pop_front() {
                out.push(idx);
                break;
            }
            if st.shutdown {
                return false;
            }
            st = self.cv_work.wait(st).unwrap();
        }
        let deadline = Instant::now() + max_delay;
        while out.len() < max_batch {
            if let Some(idx) = st.pending.pop_front() {
                out.push(idx);
                continue;
            }
            if st.shutdown {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _timeout) = self.cv_work.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        true
    }

    /// Flip the shutdown flag and wake the workers (they drain pending
    /// work and exit; new claims see [`Acquire::Shutdown`]).
    pub fn shutdown(&self) {
        let mut st = self.m.lock().unwrap();
        st.shutdown = true;
        drop(st);
        self.cv_work.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn claim(q: &BatchQueue) -> u32 {
        match q.try_acquire() {
            Acquire::Slot(idx) => idx,
            other => panic!("expected a slot, got {other:?}"),
        }
    }

    #[test]
    fn coalesces_up_to_max_batch() {
        let q = BatchQueue::new(8);
        for _ in 0..5 {
            let idx = claim(&q);
            q.submit(idx);
        }
        let mut batch = Vec::with_capacity(4);
        assert!(q.next_batch(&mut batch, 4, Duration::from_millis(1)));
        assert_eq!(batch.len(), 4);
        assert!(q.next_batch(&mut batch, 4, Duration::from_millis(1)));
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn saturated_arena_sheds_instead_of_blocking() {
        let q = BatchQueue::new(2);
        let a = claim(&q);
        let b = claim(&q);
        // every slot in flight: the claim returns immediately with Full
        assert_eq!(q.try_acquire(), Acquire::Full);
        // releasing any slot readmits new work
        q.release(b);
        assert_eq!(q.try_acquire(), Acquire::Slot(b));
        q.release(a);
    }

    #[test]
    fn shutdown_unblocks_everyone() {
        let q = BatchQueue::new(1);
        let a = claim(&q);
        q.shutdown();
        // saturated arena + shutdown: a new client is told Shutdown (not
        // Full — there is no point retrying), and never hangs
        assert_eq!(q.try_acquire(), Acquire::Shutdown);
        // a worker with no pending work exits
        let mut batch = Vec::new();
        assert!(!q.next_batch(&mut batch, 4, Duration::from_millis(1)));
        // but in-flight work still drains
        q.submit(a);
        assert!(q.next_batch(&mut batch, 4, Duration::from_millis(1)));
        assert_eq!(batch, vec![a]);
        assert!(!q.next_batch(&mut batch, 4, Duration::from_millis(1)));
    }

    #[test]
    fn release_recycles_slots() {
        let q = BatchQueue::new(2);
        let a = claim(&q);
        let b = claim(&q);
        assert_ne!(a, b);
        q.release(a);
        assert_eq!(q.try_acquire(), Acquire::Slot(a));
        q.release(b);
    }
}
