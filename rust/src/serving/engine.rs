//! The inference engine side of serving: the loaded (and optionally
//! quantized) model, and the per-shard execution state.

use crate::model::{BnState, ParamSet};
use crate::runtime::native::model::QuantModel;
use crate::runtime::native::workspace::Workspace;
use crate::runtime::native::{NativeBackend, NativeSpec};
use crate::runtime::Backend;
use crate::util::{simd, Error, Result};

/// Which numeric tier a server runs inference on (the `serve_quant` knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeTier {
    /// The bitwise-deterministic f32 eval path (`forward_eval_ws`).
    F32,
    /// int8 post-training-quantized GEMMs (`forward_eval_q_ws`): faster,
    /// f32 parity under a tolerance contract (top-1 + logit error), and
    /// itself bitwise deterministic across SIMD tiers.
    Int8,
}

impl ServeTier {
    pub fn from_knob(knob: &str) -> Result<ServeTier> {
        match knob {
            "f32" => Ok(ServeTier::F32),
            "int8" => Ok(ServeTier::Int8),
            other => Err(Error::config(format!(
                "serve_quant must be one of f32|int8, got '{other}'"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ServeTier::F32 => "f32",
            ServeTier::Int8 => "int8",
        }
    }
}

/// A deployable model: the native engine, the averaged parameters, the BN
/// running statistics, and (on the int8 tier) the pre-packed quantized
/// weights — everything computed once at load, shared read-only by all
/// shard workers.
pub struct ServeModel {
    pub engine: NativeBackend,
    pub params: ParamSet,
    pub bn: BnState,
    pub tier: ServeTier,
    /// present iff `tier == Int8` (per-tensor scales + packed i16 panels)
    pub quant: Option<QuantModel>,
}

impl ServeModel {
    /// Assemble from in-memory state, validating both arenas against the
    /// engine layout and quantizing the weights if the tier asks for it.
    pub fn new(
        engine: NativeBackend,
        params: ParamSet,
        bn: BnState,
        tier: ServeTier,
    ) -> Result<ServeModel> {
        let m = engine.manifest();
        if params.data().len() != m.num_params {
            return Err(Error::shape(format!(
                "serve model: param arena {} != manifest {}",
                params.data().len(),
                m.num_params
            )));
        }
        let quant = match tier {
            ServeTier::F32 => None,
            ServeTier::Int8 => Some(engine.quantize_model(params.as_slice())?),
        };
        // fail fast on a bn arena the eval path would reject per request
        let probe = vec![0.0f32; engine.dims().image_size.pow(2) * 3];
        let mut ws = Workspace::new();
        let mut logits = vec![0.0f32; engine.dims().num_classes];
        let (p, b) = (params.as_slice(), bn.as_slice());
        engine.eval_logits_ws(p, b, &probe, 1, 1, &mut ws, &mut logits)?;
        Ok(ServeModel { engine, params, bn, tier, quant })
    }

    /// Load a servable checkpoint bundle (`model::save_model`) for the
    /// given spec and tier.
    pub fn load(
        spec: NativeSpec,
        path: impl AsRef<std::path::Path>,
        tier: ServeTier,
    ) -> Result<ServeModel> {
        let engine = NativeBackend::new(spec)?;
        let (params, bn) = crate::model::load_model(path, engine.manifest())?;
        ServeModel::new(engine, params, bn, tier)
    }

    pub fn num_classes(&self) -> usize {
        self.engine.dims().num_classes
    }

    /// f32 count of one NHWC request image.
    pub fn image_len(&self) -> usize {
        let im = self.engine.dims().image_size;
        im * im * 3
    }
}

/// One shard worker's execution state: a dedicated grow-only [`Workspace`]
/// plus fixed batch staging buffers. Nothing here is shared — each worker
/// thread owns its `ShardEngine` outright, so inference never contends on
/// the engine's workspace pool and steady-state calls allocate nothing.
pub struct ShardEngine {
    ws: Box<Workspace>,
    images: Vec<f32>,
    logits: Vec<f32>,
    image_len: usize,
    num_classes: usize,
    max_batch: usize,
}

impl ShardEngine {
    pub fn new(model: &ServeModel, max_batch: usize) -> ShardEngine {
        let max_batch = max_batch.max(1);
        ShardEngine {
            ws: Box::new(Workspace::new()),
            images: vec![0.0; max_batch * model.image_len()],
            logits: vec![0.0; max_batch * model.num_classes()],
            image_len: model.image_len(),
            num_classes: model.num_classes(),
            max_batch,
        }
    }

    /// Pre-grow every buffer for every batch shape up to `max_batch` by
    /// running one inference at the largest and smallest shapes; after
    /// this, [`ShardEngine::infer`] never allocates (any `b` between the
    /// two reuses the max-shape buffers — grow-only).
    pub fn warm(&mut self, model: &ServeModel) -> Result<()> {
        self.infer(model, self.max_batch)?;
        self.infer(model, 1)?;
        Ok(())
    }

    /// The logits staged by the last [`ShardEngine::infer`] call (rows
    /// beyond that call's batch size are stale).
    pub fn staged_logits(&self) -> &[f32] {
        &self.logits
    }

    /// Mutable staging row for request `j` of the next batch.
    pub fn image_slot(&mut self, j: usize) -> &mut [f32] {
        let il = self.image_len;
        &mut self.images[j * il..(j + 1) * il]
    }

    /// Run the staged batch of `b` requests on the model's tier; returns
    /// the `b * num_classes` logits. Intra-op threads stay at 1 — the
    /// shard fan-out is the parallelism.
    pub fn infer(&mut self, model: &ServeModel, b: usize) -> Result<&[f32]> {
        debug_assert!((1..=self.max_batch).contains(&b));
        let images = &self.images[..b * self.image_len];
        let out = &mut self.logits[..b * self.num_classes];
        match (model.tier, &model.quant) {
            (ServeTier::Int8, Some(qm)) => model.engine.eval_logits_quant_ws(
                qm,
                model.params.as_slice(),
                model.bn.as_slice(),
                images,
                b,
                1,
                simd::active(),
                &mut self.ws,
                out,
            )?,
            _ => model.engine.eval_logits_ws(
                model.params.as_slice(),
                model.bn.as_slice(),
                images,
                b,
                1,
                &mut self.ws,
                out,
            )?,
        }
        Ok(&self.logits[..b * self.num_classes])
    }
}

/// First-max argmax over one logits row — the serving prediction rule
/// (consistent with the rank rule: a class ties the winner only at a
/// higher index, and the winner has rank 0).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (j, &v) in row.iter().enumerate().skip(1) {
        if v > row[best] {
            best = j;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_knob_parses() {
        assert_eq!(ServeTier::from_knob("f32").unwrap(), ServeTier::F32);
        assert_eq!(ServeTier::from_knob("int8").unwrap(), ServeTier::Int8);
        assert!(ServeTier::from_knob("fp16").is_err());
    }

    #[test]
    fn argmax_is_first_max() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[-2.0, -1.0, -3.0]), 1);
    }
}
