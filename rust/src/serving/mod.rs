//! Batched inference serving on the averaged model — the deployment
//! subsystem (`swap serve-model`).
//!
//! SWAP's product is a single averaged model; this module serves it. The
//! architecture is built around the two invariants the native runtime
//! already guarantees:
//!
//! * **Zero-allocation steady state.** Requests live in a fixed slot
//!   arena ([`batcher`]), the pending queue is a capacity-reserved ring,
//!   and every shard worker owns its own grow-only `Workspace` — after
//!   warmup, a served request performs zero heap allocations end to end
//!   (pinned by `rust/tests/alloc_regression.rs`).
//! * **Per-example batch invariance.** The eval forward is per-example
//!   independent (row-partitioned GEMM chains, BN from running stats,
//!   per-example pools), so the dynamic batcher can coalesce any mix of
//!   requests into one ragged batch and return f32 logits **bitwise
//!   identical** to serving each request at batch = 1 (pinned by
//!   `rust/tests/serving.rs`).
//!
//! [`server::Server`] owns N shard workers; each loop pops a batch from
//! the shared [`batcher::BatchQueue`] (waiting up to `max_delay` to
//! coalesce up to `max_batch` singles — bounded latency, GEMM-friendly
//! shapes), runs it through its [`engine::ShardEngine`] on the f32 or
//! int8 tier, and completes the slots. The int8 tier
//! (`runtime::native::qgemm`) trades bitwise f32 parity for throughput
//! under a tolerance contract: top-1 agreement + bounded logit error.

pub mod batcher;
pub mod engine;
pub mod server;
pub mod stats;

pub use engine::{argmax, ServeModel, ServeTier, ShardEngine};
pub use server::{ServeConfig, Server};
pub use stats::{percentile, ServerStats};
