//! Serving counters and latency summaries.

use std::sync::atomic::{AtomicU64, Ordering};

/// Lock-free server counters, updated by shard workers per batch.
#[derive(Default)]
pub(crate) struct Counters {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub max_batch_seen: AtomicU64,
    pub infer_errors: AtomicU64,
    pub sheds: AtomicU64,
}

impl Counters {
    pub fn note_batch(&self, size: usize) {
        self.requests.fetch_add(size as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.max_batch_seen.fetch_max(size as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> ServerStats {
        ServerStats {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            max_batch_seen: self.max_batch_seen.load(Ordering::Relaxed),
            infer_errors: self.infer_errors.load(Ordering::Relaxed),
            sheds: self.sheds.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time snapshot of a server's counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerStats {
    /// requests completed
    pub requests: u64,
    /// inference batches run
    pub batches: u64,
    /// largest coalesced batch observed
    pub max_batch_seen: u64,
    /// requests that failed inside inference (completed with zero logits)
    pub infer_errors: u64,
    /// requests shed at admission because every slot was in flight
    /// (clients saw `Error::Overloaded`; not counted in `requests`)
    pub sheds: u64,
}

impl ServerStats {
    /// Mean coalesced batch size — the dynamic batcher's effectiveness.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// Nearest-rank percentile (`p` in [0, 100]) over an **ascending-sorted**
/// sample slice. Returns 0.0 on an empty slice.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "input must be sorted");
    let rank = (p / 100.0 * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn counters_accumulate() {
        let c = Counters::default();
        c.note_batch(3);
        c.note_batch(5);
        c.note_batch(1);
        let s = c.snapshot();
        assert_eq!(s.requests, 9);
        assert_eq!(s.batches, 3);
        assert_eq!(s.max_batch_seen, 5);
        assert!((s.mean_batch() - 3.0).abs() < 1e-12);
    }
}
