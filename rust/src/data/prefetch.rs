//! Input prefetching: overlap batch assembly with backend compute.
//!
//! Because augmentation is a stateless counter-keyed pure function
//! (`data::augment`), assembling step t+1 on a background thread while
//! the backend computes step t cannot change a single bit of any batch —
//! prefetching is purely a wall-clock optimization. The machinery is a
//! bounded slot queue (double buffer) built on the same zero-dependency
//! std primitives as `coordinator::parallel`: slots cycle
//! producer -> ready -> consumer -> free -> producer, so the steady state
//! allocates nothing.
//!
//! `run_pipeline` is the single entry point the training loops use; with
//! `overlap = false` (or a single slot) it degrades to the plain
//! assemble-then-compute loop on the calling thread, producing the same
//! results by construction.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::util::Result;

/// Bounded hand-off queue for reusable slots (the double buffer).
pub struct PrefetchQueue<T> {
    state: Mutex<QueueState<T>>,
    free_cv: Condvar,
    ready_cv: Condvar,
}

struct QueueState<T> {
    free: VecDeque<T>,
    ready: VecDeque<T>,
    /// producer has published its last slot
    finished: bool,
    /// hard stop (consumer error / early exit): both sides unblock
    shutdown: bool,
}

impl<T> PrefetchQueue<T> {
    pub fn new(slots: Vec<T>) -> Self {
        PrefetchQueue {
            state: Mutex::new(QueueState {
                free: slots.into(),
                ready: VecDeque::new(),
                finished: false,
                shutdown: false,
            }),
            free_cv: Condvar::new(),
            ready_cv: Condvar::new(),
        }
    }

    /// Producer side: wait for a recycled slot. `None` after `shutdown`.
    pub fn acquire_free(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.shutdown {
                return None;
            }
            if let Some(t) = st.free.pop_front() {
                return Some(t);
            }
            st = self.free_cv.wait(st).unwrap();
        }
    }

    /// Producer side: hand a filled slot to the consumer.
    pub fn publish(&self, t: T) {
        let mut st = self.state.lock().unwrap();
        st.ready.push_back(t);
        drop(st);
        self.ready_cv.notify_one();
    }

    /// Consumer side: wait for the next filled slot (FIFO — a single
    /// producer publishes steps in order). Already-published slots are
    /// drained even after `finish`/`shutdown`; `None` once the queue is
    /// empty and no more slots are coming.
    pub fn acquire_ready(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(t) = st.ready.pop_front() {
                return Some(t);
            }
            if st.shutdown || st.finished {
                return None;
            }
            st = self.ready_cv.wait(st).unwrap();
        }
    }

    /// Consumer side: recycle a consumed slot's buffers.
    pub fn release(&self, t: T) {
        let mut st = self.state.lock().unwrap();
        st.free.push_back(t);
        drop(st);
        self.free_cv.notify_one();
    }

    /// Producer side: no more slots will be published.
    pub fn finish(&self) {
        let mut st = self.state.lock().unwrap();
        st.finished = true;
        drop(st);
        self.ready_cv.notify_all();
    }

    /// Either side: abort — every blocked call returns `None`.
    pub fn shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        drop(st);
        self.free_cv.notify_all();
        self.ready_cv.notify_all();
    }
}

/// Drive `steps` pipeline steps: `produce(step, slot)` fills a slot (batch
/// assembly), `consume(step, slot)` uses it (the training step; returns
/// `Ok(false)` to stop early, e.g. the epoch-accuracy early exit).
///
/// With `overlap` set and at least two slots, production runs on ONE
/// background thread (scoped — joined before return) while consumption
/// stays on the calling thread, double-buffering through the slot queue;
/// otherwise both run interleaved on the calling thread. The two modes
/// are bitwise-identical because `produce` must be a pure function of
/// `step` (the counter-RNG contract) — only wall time changes.
pub fn run_pipeline<S, P, C>(
    steps: usize,
    mut slots: Vec<S>,
    overlap: bool,
    mut produce: P,
    mut consume: C,
) -> Result<()>
where
    S: Send,
    P: FnMut(usize, &mut S) + Send,
    C: FnMut(usize, &mut S) -> Result<bool>,
{
    assert!(!slots.is_empty(), "run_pipeline needs at least one slot");
    if steps == 0 {
        return Ok(());
    }
    if !overlap || slots.len() < 2 || steps == 1 {
        for step in 0..steps {
            produce(step, &mut slots[0]);
            if !consume(step, &mut slots[0])? {
                break;
            }
        }
        return Ok(());
    }
    let queue = PrefetchQueue::new(slots);
    let q = &queue;
    std::thread::scope(|scope| -> Result<()> {
        // shut the queue on EVERY exit path of either side — early stop,
        // error, or panic — so the other side can never stay blocked
        // while the scope joins (already-published slots still drain)
        struct Shutdown<'a, T>(&'a PrefetchQueue<T>);
        impl<T> Drop for Shutdown<'_, T> {
            fn drop(&mut self) {
                self.0.shutdown();
            }
        }
        scope.spawn(move || {
            let _guard = Shutdown(q);
            for step in 0..steps {
                let Some(mut slot) = q.acquire_free() else { return };
                produce(step, &mut slot);
                q.publish(slot);
            }
            q.finish();
        });
        let _guard = Shutdown(q);
        for step in 0..steps {
            let Some(mut slot) = q.acquire_ready() else { break };
            let cont = consume(step, &mut slot)?;
            q.release(slot);
            if !cont {
                break;
            }
        }
        Ok(())
    })
}

/// The standard slot set for [`run_pipeline`]: a double buffer when the
/// producer may overlap with compute, a single reused slot otherwise.
/// ONE definition of the pipeline depth, shared by every consumer.
pub fn make_slots<S>(overlap: bool, mut make: impl FnMut() -> S) -> Vec<S> {
    (0..if overlap { 2 } else { 1 }).map(|_| make()).collect()
}

/// `SWAP_PREFETCH` environment override for the `prefetch` config knob:
/// `0|false|off|no` disables, `1|true|on|yes` enables, unset (or
/// unrecognized) leaves the knob in charge.
pub fn env_override() -> Option<bool> {
    let v = std::env::var("SWAP_PREFETCH").ok()?;
    match v.trim().to_ascii_lowercase().as_str() {
        "0" | "false" | "off" | "no" => Some(false),
        "1" | "true" | "on" | "yes" => Some(true),
        _ => None,
    }
}

/// Default prefetch mode when nothing is configured: the env override if
/// set, else on (overlap is bitwise-free).
pub fn default_prefetch() -> bool {
    env_override().unwrap_or(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_matches_serial_bitwise() {
        // produce is a pure function of step -> overlap changes nothing
        let run = |overlap: bool| -> Vec<u64> {
            let mut seen = Vec::new();
            let slots: Vec<u64> = vec![0, 0];
            run_pipeline(
                17,
                slots,
                overlap,
                |step, slot| *slot = (step as u64).wrapping_mul(0x9E37_79B9) ^ 7,
                |step, slot| {
                    assert_eq!(*slot, (step as u64).wrapping_mul(0x9E37_79B9) ^ 7);
                    seen.push(*slot);
                    Ok(true)
                },
            )
            .unwrap();
            seen
        };
        assert_eq!(run(false), run(true));
        assert_eq!(run(true).len(), 17);
    }

    #[test]
    fn pipeline_consumes_steps_in_order() {
        let mut order = Vec::new();
        run_pipeline(
            9,
            vec![0usize, 0],
            true,
            |step, slot| *slot = step,
            |step, slot| {
                assert_eq!(*slot, step);
                order.push(step);
                Ok(true)
            },
        )
        .unwrap();
        assert_eq!(order, (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn early_stop_unblocks_producer() {
        // the consumer stops at step 2 while the producer wants 1000
        // steps; shutdown must let the scoped producer exit (this test
        // hanging = the bug)
        let mut n = 0;
        run_pipeline(
            1000,
            vec![(); 2],
            true,
            |_, _| {},
            |step, _| {
                n += 1;
                Ok(step < 2)
            },
        )
        .unwrap();
        assert_eq!(n, 3);
    }

    #[test]
    fn consumer_error_propagates_and_joins() {
        let err = run_pipeline(
            100,
            vec![(); 2],
            true,
            |_, _| {},
            |step, _| {
                if step == 1 {
                    Err(crate::util::Error::invalid("boom"))
                } else {
                    Ok(true)
                }
            },
        );
        assert!(err.is_err());
    }

    #[test]
    fn zero_steps_is_a_noop() {
        run_pipeline(0, vec![0u8], true, |_, _| {}, |_, _| Ok(true)).unwrap();
    }

    #[test]
    fn single_slot_degrades_to_serial() {
        let mut seen = Vec::new();
        run_pipeline(
            4,
            vec![0usize],
            true, // requested, but one slot cannot overlap
            |step, slot| *slot = step * 2,
            |_, slot| {
                seen.push(*slot);
                Ok(true)
            },
        )
        .unwrap();
        assert_eq!(seen, vec![0, 2, 4, 6]);
    }

    #[test]
    fn env_override_parses() {
        // don't touch the process env (tests run threaded) — only the
        // pure parsing path is exercised here via default_prefetch's
        // contract: with no env var set it must default to on
        if std::env::var("SWAP_PREFETCH").is_err() {
            assert!(default_prefetch());
        } else {
            // CI's prefetch lane sets it: override must agree
            assert!(env_override().is_some());
        }
    }
}
