//! Batch assembly: gather dataset rows by index, apply augmentation, and
//! produce the `HostBatch` the runtime uploads. The hot training loops
//! assemble *into* a reused `HostBatch` (`assemble_step_into`), so
//! steady-state steps perform no allocation — and because augmentation is
//! keyed by a stateless counter (`(seed, stream, step, row)`), assembly is
//! **order-free**: any thread may assemble any shard of any step in any
//! interleaving and produce bitwise-identical batches. That property is
//! what lets the prefetcher build step t+1 on a background thread while
//! the backend computes step t.

use super::augment::{augment_at, AugStream, AugmentSpec};
use super::synth::Dataset;
use crate::runtime::HostBatch;

/// Reusable batch assembler. `batch` is the *maximum* batch size; a ragged
/// final evaluation batch (fewer indices) is allowed and produces a
/// correspondingly smaller `HostBatch`.
pub struct Batcher {
    batch: usize,
    image_size: usize,
    augment: AugmentSpec,
    /// scratch for `augment::shift` — grown once, reused for every example
    scratch: Vec<f32>,
}

impl Batcher {
    pub fn new(batch: usize, image_size: usize, augment: AugmentSpec) -> Self {
        Batcher { batch, image_size, augment, scratch: Vec::new() }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// An empty `HostBatch` with capacity for a full batch, meant to be
    /// reused across `assemble_*_into` calls (no per-step allocation).
    pub fn make_batch(&self) -> HostBatch {
        HostBatch {
            images: Vec::with_capacity(self.batch * self.image_size * self.image_size * 3),
            labels: Vec::with_capacity(self.batch),
            batch: 0,
            image_size: self.image_size,
        }
    }

    /// Gather rows into `out` (shared by the augmented and clean paths).
    fn gather(&self, ds: &Dataset, idx: &[usize], out: &mut HostBatch) {
        assert!(
            !idx.is_empty() && idx.len() <= self.batch,
            "index count {} not in 1..={}",
            idx.len(),
            self.batch
        );
        assert_eq!(ds.image_size, self.image_size);
        let pix = ds.pixels_per_image();
        out.batch = idx.len();
        out.image_size = self.image_size;
        out.images.resize(idx.len() * pix, 0.0);
        out.labels.resize(idx.len(), 0);
        for (row, &i) in idx.iter().enumerate() {
            out.images[row * pix..(row + 1) * pix].copy_from_slice(ds.image(i));
            out.labels[row] = ds.labels[i];
        }
    }

    /// Counter-keyed augmented assembly: global row `row0 + r` of step
    /// `step` is augmented with `Rng::counter(key.seed, key.stream, step,
    /// row0 + r)`. Shards of one step assemble the same pixels regardless
    /// of which `Batcher`, thread, or call order produced them.
    pub fn assemble_step_into(
        &mut self,
        ds: &Dataset,
        idx: &[usize],
        key: AugStream,
        step: u64,
        row0: u64,
        out: &mut HostBatch,
    ) {
        self.gather(ds, idx, out);
        if self.augment.is_noop() {
            return;
        }
        let spec = self.augment;
        let hw = self.image_size;
        let pix = ds.pixels_per_image();
        for r in 0..idx.len() {
            let img = &mut out.images[r * pix..(r + 1) * pix];
            augment_at(img, hw, &spec, &mut self.scratch, key, step, row0 + r as u64);
        }
    }

    /// Assembly without augmentation (eval / BN-recompute batches) — no
    /// RNG is constructed at all.
    pub fn assemble_clean_into(&self, ds: &Dataset, idx: &[usize], out: &mut HostBatch) {
        self.gather(ds, idx, out);
    }

    /// Convenience: augmented assembly into a fresh `HostBatch` (tests,
    /// benches — the training loops use `assemble_step_into`).
    pub fn assemble_step(
        &mut self,
        ds: &Dataset,
        idx: &[usize],
        key: AugStream,
        step: u64,
        row0: u64,
    ) -> HostBatch {
        let mut out = self.make_batch();
        self.assemble_step_into(ds, idx, key, step, row0, &mut out);
        out
    }

    /// Allocating variant of `assemble_clean_into`.
    pub fn assemble_clean(&self, ds: &Dataset, idx: &[usize]) -> HostBatch {
        let mut out = self.make_batch();
        self.assemble_clean_into(ds, idx, &mut out);
        out
    }
}

/// Iterate the whole dataset in fixed-size batches (sequential order). The
/// trailing partial batch IS yielded, so a full pass covers all `n`
/// examples — evaluation must not silently drop the tail. (The native
/// backend accepts any batch size; backends with per-batch AOT
/// executables opt out via `Backend::supports_ragged_batch` and keep the
/// whole-batches-only behavior.)
pub fn sequential_batches(n: usize, batch: usize) -> impl Iterator<Item = Vec<usize>> {
    let chunks = (n + batch - 1) / batch;
    (0..chunks).map(move |b| ((b * batch)..((b + 1) * batch).min(n)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{Generator, SynthSpec};

    fn dataset() -> Dataset {
        Generator::new(SynthSpec::for_preset(10, 16, 7)).sample(40, 10)
    }

    fn key() -> AugStream {
        AugStream { seed: 9, stream: 3 }
    }

    #[test]
    fn assemble_gathers_rows() {
        let ds = dataset();
        let b = Batcher::new(4, 16, AugmentSpec::none());
        let hb = b.assemble_clean(&ds, &[3, 1, 0, 2]);
        assert_eq!(hb.batch, 4);
        assert_eq!(hb.labels, vec![ds.labels[3], ds.labels[1], ds.labels[0], ds.labels[2]]);
        let pix = ds.pixels_per_image();
        assert_eq!(&hb.images[..pix], ds.image(3));
    }

    #[test]
    fn augmented_assemble_differs_but_labels_match() {
        let ds = dataset();
        let mut b = Batcher::new(4, 16, AugmentSpec::cifar_default());
        let hb = b.assemble_step(&ds, &[0, 1, 2, 3], key(), 0, 0);
        assert_eq!(hb.labels, &ds.labels[..4]);
        let pix = ds.pixels_per_image();
        // with flip+shift+cutout, at least one image must change
        let changed = (0..4).any(|r| hb.images[r * pix..(r + 1) * pix] != *ds.image(r));
        assert!(changed);
    }

    #[test]
    fn counter_assembly_is_order_free() {
        // THE pipelining property: assembling a step as one whole batch,
        // as two shards, shards in reverse order, or with a different
        // Batcher instance — all bitwise identical.
        let ds = dataset();
        let pix = ds.pixels_per_image();
        let mut a = Batcher::new(8, 16, AugmentSpec::cifar_default());
        let whole = a.assemble_step(&ds, &[0, 1, 2, 3, 4, 5, 6, 7], key(), 5, 0);

        let mut b = Batcher::new(8, 16, AugmentSpec::cifar_default());
        let hi = b.assemble_step(&ds, &[4, 5, 6, 7], key(), 5, 4); // second shard FIRST
        let lo = b.assemble_step(&ds, &[0, 1, 2, 3], key(), 5, 0);
        assert_eq!(&whole.images[..4 * pix], &lo.images[..]);
        assert_eq!(&whole.images[4 * pix..], &hi.images[..]);

        // different step or row offset -> different augmentation stream
        let other_step = b.assemble_step(&ds, &[0, 1, 2, 3, 4, 5, 6, 7], key(), 6, 0);
        assert_ne!(whole.images, other_step.images);
    }

    #[test]
    fn assemble_into_reuses_buffers_without_allocating() {
        let ds = dataset();
        let mut b = Batcher::new(4, 16, AugmentSpec::cifar_default());
        let mut out = b.make_batch();
        b.assemble_step_into(&ds, &[0, 1, 2, 3], key(), 0, 0, &mut out);
        let cap_i = out.images.capacity();
        let cap_l = out.labels.capacity();
        let ptr = out.images.as_ptr();
        for step in 1..6 {
            b.assemble_step_into(&ds, &[4, 5, 6, 7], key(), step, 0, &mut out);
        }
        assert_eq!(out.images.capacity(), cap_i, "image buffer must be reused");
        assert_eq!(out.labels.capacity(), cap_l, "label buffer must be reused");
        assert_eq!(out.images.as_ptr(), ptr, "no reallocation across steps");
        assert_eq!(out.labels, vec![ds.labels[4], ds.labels[5], ds.labels[6], ds.labels[7]]);
    }

    #[test]
    fn ragged_final_batch_assembles_smaller() {
        let ds = dataset();
        let b = Batcher::new(16, 16, AugmentSpec::none());
        let mut out = b.make_batch();
        b.assemble_clean_into(&ds, &[38, 39], &mut out);
        assert_eq!(out.batch, 2);
        assert_eq!(out.labels.len(), 2);
        assert_eq!(out.images.len(), 2 * ds.pixels_per_image());
    }

    #[test]
    fn sequential_batches_cover_whole_dataset() {
        let batches: Vec<Vec<usize>> = sequential_batches(10, 3).collect();
        assert_eq!(batches.len(), 4);
        assert_eq!(batches[2], vec![6, 7, 8]);
        assert_eq!(batches[3], vec![9], "trailing partial batch must be yielded");
        let flat: Vec<usize> = batches.into_iter().flatten().collect();
        assert_eq!(flat, (0..10).collect::<Vec<_>>());
        // exactly divisible: no empty tail
        assert_eq!(sequential_batches(9, 3).count(), 3);
        assert_eq!(sequential_batches(2, 8).collect::<Vec<_>>(), vec![vec![0, 1]]);
    }

    #[test]
    #[should_panic(expected = "index count")]
    fn too_many_indices_panics() {
        let ds = dataset();
        let b = Batcher::new(4, 16, AugmentSpec::none());
        b.assemble_clean(&ds, &[0, 1, 2, 3, 4]);
    }
}
