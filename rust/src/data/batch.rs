//! Batch assembly: gather dataset rows by index, apply augmentation, and
//! produce the `HostBatch` the runtime uploads. Buffers are reused across
//! steps (no allocation in the training loop).

use super::augment::{augment, AugmentSpec};
use super::synth::Dataset;
use crate::runtime::HostBatch;
use crate::util::Rng;

/// Reusable batch assembler.
pub struct Batcher {
    batch: usize,
    image_size: usize,
    augment: AugmentSpec,
    buf_images: Vec<f32>,
    buf_labels: Vec<i32>,
}

impl Batcher {
    pub fn new(batch: usize, image_size: usize, augment: AugmentSpec) -> Self {
        Batcher {
            batch,
            image_size,
            augment,
            buf_images: vec![0.0; batch * image_size * image_size * 3],
            buf_labels: vec![0; batch],
        }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Assemble indices into a HostBatch (clones out of the reuse buffers).
    pub fn assemble(&mut self, ds: &Dataset, idx: &[usize], rng: &mut Rng) -> HostBatch {
        assert_eq!(idx.len(), self.batch, "index count != batch size");
        assert_eq!(ds.image_size, self.image_size);
        let pix = ds.pixels_per_image();
        for (row, &i) in idx.iter().enumerate() {
            let dst = &mut self.buf_images[row * pix..(row + 1) * pix];
            dst.copy_from_slice(ds.image(i));
            augment(dst, self.image_size, &self.augment, rng);
            self.buf_labels[row] = ds.labels[i];
        }
        HostBatch {
            images: self.buf_images.clone(),
            labels: self.buf_labels.clone(),
            batch: self.batch,
            image_size: self.image_size,
        }
    }

    /// Assemble without augmentation (eval batches / BN recompute).
    pub fn assemble_clean(&mut self, ds: &Dataset, idx: &[usize]) -> HostBatch {
        let mut rng = Rng::new(0);
        let saved = self.augment;
        self.augment = AugmentSpec::none();
        let out = self.assemble(ds, idx, &mut rng);
        self.augment = saved;
        out
    }
}

/// Iterate the whole dataset in fixed-size batches (sequential order,
/// trailing partial batch dropped) — evaluation and BN recompute passes.
pub fn sequential_batches(n: usize, batch: usize) -> impl Iterator<Item = Vec<usize>> {
    let full = n / batch;
    (0..full).map(move |b| ((b * batch)..((b + 1) * batch)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{Generator, SynthSpec};

    fn dataset() -> Dataset {
        Generator::new(SynthSpec::for_preset(10, 16, 7)).sample(40, 10)
    }

    #[test]
    fn assemble_gathers_rows() {
        let ds = dataset();
        let mut b = Batcher::new(4, 16, AugmentSpec::none());
        let hb = b.assemble_clean(&ds, &[3, 1, 0, 2]);
        assert_eq!(hb.batch, 4);
        assert_eq!(hb.labels, vec![ds.labels[3], ds.labels[1], ds.labels[0], ds.labels[2]]);
        let pix = ds.pixels_per_image();
        assert_eq!(&hb.images[..pix], ds.image(3));
    }

    #[test]
    fn augmented_assemble_differs_but_labels_match() {
        let ds = dataset();
        let mut b = Batcher::new(4, 16, AugmentSpec::cifar_default());
        let mut rng = Rng::new(3);
        let hb = b.assemble(&ds, &[0, 1, 2, 3], &mut rng);
        assert_eq!(hb.labels, &ds.labels[..4]);
        let pix = ds.pixels_per_image();
        // with flip+shift+cutout, at least one image must change
        let changed = (0..4).any(|r| hb.images[r * pix..(r + 1) * pix] != *ds.image(r));
        assert!(changed);
    }

    #[test]
    fn sequential_batches_cover_prefix() {
        let batches: Vec<Vec<usize>> = sequential_batches(10, 3).collect();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[2], vec![6, 7, 8]);
    }

    #[test]
    #[should_panic(expected = "index count")]
    fn wrong_index_count_panics() {
        let ds = dataset();
        let mut b = Batcher::new(4, 16, AugmentSpec::none());
        b.assemble_clean(&ds, &[0, 1]);
    }
}
