//! Data pipeline: synthetic datasets, per-epoch shuffling + sharding, and
//! the paper's augmentation (flip / shift / cutout). See DESIGN.md for why
//! synthetic data substitutes CIFAR/ImageNet in this environment.

pub mod augment;
pub mod batch;
pub mod sampler;
pub mod synth;

pub use augment::AugmentSpec;
pub use batch::{sequential_batches, Batcher};
pub use sampler::{shard, EpochSampler};
pub use synth::{Dataset, Generator, SynthSpec};
