//! The input subsystem: pluggable dataset sources (synthetic generator,
//! on-disk CIFAR binaries), per-epoch shuffling + sharding, the paper's
//! augmentation (flip / shift / cutout) keyed by a stateless counter RNG,
//! and the prefetch pipeline that overlaps batch assembly with backend
//! compute. See DESIGN.md for why synthetic data substitutes
//! CIFAR/ImageNet in this environment.

pub mod augment;
pub mod batch;
pub mod cifar;
pub mod prefetch;
pub mod sampler;
pub mod source;
pub mod synth;

pub use augment::{AugStream, AugmentSpec};
pub use batch::{sequential_batches, Batcher};
pub use cifar::CifarVariant;
pub use sampler::{shard, EpochSampler};
pub use source::{CifarSource, DataSource, SynthSource};
pub use synth::{Dataset, Generator, SynthSpec};
