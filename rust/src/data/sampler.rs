//! Epoch sampling and worker sharding.
//!
//! * Phase 1 (synchronous large batch): one global shuffled order per
//!   epoch; each global batch of size B1 is split into W disjoint shards of
//!   B1/W (Algorithm 1, line 11).
//! * Phase 2 (independent workers): each worker owns its own sampler with a
//!   distinct RNG stream, i.e. "different randomizations of the data"
//!   (Algorithm 1, line 22).

use crate::util::Rng;

/// Per-epoch reshuffling batch sampler. Drops the trailing partial batch
//  (AOT executables are compiled for fixed batch sizes).
#[derive(Debug)]
pub struct EpochSampler {
    n: usize,
    batch: usize,
    seed: u64,
    stream: u64,
    epoch: usize,
    order: Vec<usize>,
    cursor: usize,
}

impl EpochSampler {
    /// `stream` distinguishes workers; same (seed, stream) replays exactly.
    pub fn new(n: usize, batch: usize, seed: u64, stream: u64) -> Self {
        assert!(batch > 0 && batch <= n, "batch {batch} vs n {n}");
        let mut s = EpochSampler {
            n,
            batch,
            seed,
            stream,
            epoch: 0,
            order: Vec::new(),
            cursor: 0,
        };
        s.reshuffle();
        s
    }

    fn reshuffle(&mut self) {
        let mut rng = Rng::stream(
            self.seed ^ 0x5A5A_0000,
            self.stream.wrapping_mul(1_000_003) + self.epoch as u64,
        );
        self.order = rng.permutation(self.n);
        self.cursor = 0;
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    pub fn batches_per_epoch(&self) -> usize {
        Self::steps_per_epoch(self.n, self.batch)
    }

    /// Steps one epoch of `n` examples yields at a global batch of
    /// `batch`, with the ragged tail dropped — THE definition every
    /// coordinator must price schedules and modeled clocks with. The
    /// trainer's actual step count is `max_epochs *` this, so a cyclic
    /// schedule whose period is built from the same helper always puts
    /// its low-LR point exactly at the end of a cycle (regression-pinned
    /// on non-divisible `n` in rust/tests/averaging_policy.rs).
    pub fn steps_per_epoch(n: usize, batch: usize) -> usize {
        n / batch
    }

    /// Next batch of indices; rolls into a fresh epoch when exhausted.
    pub fn next_batch(&mut self) -> &[usize] {
        if self.cursor + self.batch > self.n {
            self.epoch += 1;
            self.reshuffle();
        }
        let out = &self.order[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        out
    }

    /// Fractional epochs elapsed (for schedules indexed in epochs).
    pub fn epochs_elapsed(&self) -> f64 {
        self.epoch as f64 + self.cursor as f64 / self.n as f64
    }
}

/// Split a global batch into `workers` contiguous disjoint shards.
/// Panics if not divisible — the caller (config) guarantees B1 % W == 0.
pub fn shard<'a>(global: &'a [usize], workers: usize) -> Vec<&'a [usize]> {
    assert!(workers > 0 && global.len() % workers == 0,
            "batch {} not divisible by {workers}", global.len());
    let per = global.len() / workers;
    (0..workers).map(|w| &global[w * per..(w + 1) * per]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn epoch_covers_all_indices_without_repeats() {
        let mut s = EpochSampler::new(100, 10, 1, 0);
        let mut seen = HashSet::new();
        for _ in 0..10 {
            for &i in s.next_batch() {
                assert!(seen.insert(i), "duplicate index {i} within epoch");
            }
        }
        assert_eq!(seen.len(), 100);
    }

    #[test]
    fn partial_batch_dropped() {
        let mut s = EpochSampler::new(25, 10, 1, 0);
        assert_eq!(s.batches_per_epoch(), 2);
        s.next_batch();
        s.next_batch();
        assert_eq!(s.epoch(), 0);
        s.next_batch(); // rolls to epoch 1 (only 5 left < 10)
        assert_eq!(s.epoch(), 1);
    }

    #[test]
    fn epochs_reshuffle_differently() {
        let mut s = EpochSampler::new(64, 64, 3, 0);
        let e0: Vec<usize> = s.next_batch().to_vec();
        let e1: Vec<usize> = s.next_batch().to_vec();
        assert_ne!(e0, e1);
        let mut sorted = e1.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn same_stream_replays_different_streams_diverge() {
        let mut a = EpochSampler::new(50, 10, 9, 4);
        let mut b = EpochSampler::new(50, 10, 9, 4);
        let mut c = EpochSampler::new(50, 10, 9, 5);
        assert_eq!(a.next_batch(), b.next_batch());
        assert_ne!(a.next_batch(), c.next_batch());
    }

    #[test]
    fn shard_partition_disjoint_and_complete() {
        let global: Vec<usize> = (0..64).collect();
        let shards = shard(&global, 8);
        assert_eq!(shards.len(), 8);
        let mut all = HashSet::new();
        for sh in &shards {
            assert_eq!(sh.len(), 8);
            for &i in *sh {
                assert!(all.insert(i));
            }
        }
        assert_eq!(all.len(), 64);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn shard_requires_divisibility() {
        let global: Vec<usize> = (0..10).collect();
        shard(&global, 3);
    }

    #[test]
    fn epochs_elapsed_monotone() {
        let mut s = EpochSampler::new(40, 10, 2, 0);
        let mut last = -1.0;
        for _ in 0..12 {
            let e = s.epochs_elapsed();
            assert!(e >= last);
            last = e;
            s.next_batch();
        }
    }
}
