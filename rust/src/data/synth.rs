//! Synthetic image-classification datasets (the environment has no CIFAR /
//! ImageNet downloads — DESIGN.md documents this substitution).
//!
//! Generative model, chosen so that the phenomena SWAP exercises survive:
//!   * K class anchors z_k ~ N(0, I_d) in a d-dim latent space,
//!   * a fixed random projection P : R^d -> R^{H*W*3} shared by all classes,
//!   * image_i = tanh( P (z_{y_i} + sigma_intra * eps_i) + sigma_pix * n_i )
//!
//! Within-class latent scatter (sigma_intra) makes the classes overlap, so
//! a decision boundary must be *learned*; the tanh nonlinearity keeps
//! pixels in [-1, 1] (the normalization the model expects) and makes the
//! map non-linear so the conv net is not trivially optimal. Small train
//! sets (config) produce a real train/test generalization gap, which is
//! what Tables 1-3 measure.
//!
//! Train and test samples are drawn from the SAME distribution (same
//! anchors/projection, disjoint RNG streams) — exactly the i.i.d. setting
//! of the paper's datasets.

use crate::util::Rng;

/// Dataset on the host: NHWC f32 images in [-1, 1] + int labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
    pub image_size: usize,
    pub num_classes: usize,
}

impl Dataset {
    /// Split the last `k` examples off into their own dataset (held-out
    /// validation for sources that cannot mint fresh examples). Returns
    /// (head of n-k, tail of k); the head's examples are bit-identical to
    /// the first n-k examples of the original.
    pub fn split_tail(mut self, k: usize) -> crate::util::Result<(Dataset, Dataset)> {
        if k == 0 || k >= self.n {
            return Err(crate::util::Error::config(format!(
                "split_tail: k={k} must be in 1..{} (dataset size)",
                self.n
            )));
        }
        let pix = self.image_size * self.image_size * 3;
        let head_n = self.n - k;
        let tail = Dataset {
            images: self.images.split_off(head_n * pix),
            labels: self.labels.split_off(head_n),
            n: k,
            image_size: self.image_size,
            num_classes: self.num_classes,
        };
        self.n = head_n;
        Ok((self, tail))
    }
}

/// Generation parameters.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub num_classes: usize,
    pub image_size: usize,
    pub latent_dim: usize,
    /// within-class latent noise (class overlap / task difficulty)
    pub sigma_intra: f32,
    /// white pixel noise added before tanh
    pub sigma_pixel: f32,
    pub seed: u64,
}

impl SynthSpec {
    pub fn for_preset(num_classes: usize, image_size: usize, seed: u64) -> Self {
        SynthSpec {
            num_classes,
            image_size,
            latent_dim: 48,
            sigma_intra: 2.5,
            sigma_pixel: 0.5,
            seed,
        }
    }
}

/// The frozen generative model (anchors + projection). Build once per
/// preset, then sample disjoint train/test sets from it.
pub struct Generator {
    spec: SynthSpec,
    anchors: Vec<f32>,    // (K, d)
    projection: Vec<f32>, // (d, H*W*3)
}

impl Generator {
    pub fn new(spec: SynthSpec) -> Self {
        let d = spec.latent_dim;
        let pix = spec.image_size * spec.image_size * 3;
        let mut rng_a = Rng::stream(spec.seed, 1);
        let anchors: Vec<f32> = (0..spec.num_classes * d)
            .map(|_| rng_a.normal())
            .collect();
        let mut rng_p = Rng::stream(spec.seed, 2);
        // scale so that (P z) has O(1) entries: var = d * (1/sqrt(d))^2 = 1
        let scale = 1.0 / (d as f32).sqrt();
        let projection: Vec<f32> = (0..d * pix).map(|_| rng_p.normal() * scale).collect();
        Generator { spec, anchors, projection }
    }

    pub fn spec(&self) -> &SynthSpec {
        &self.spec
    }

    /// Sample `n` labelled images using RNG stream `split` (train=10,
    /// test=11, ... — callers pick disjoint streams).
    pub fn sample(&self, n: usize, split: u64) -> Dataset {
        let spec = &self.spec;
        let d = spec.latent_dim;
        let pix = spec.image_size * spec.image_size * 3;
        let mut rng = Rng::stream(spec.seed, 1000 + split);
        let mut images = vec![0.0f32; n * pix];
        let mut labels = vec![0i32; n];
        let mut latent = vec![0.0f32; d];
        for i in 0..n {
            let y = rng.below(spec.num_classes);
            labels[i] = y as i32;
            let anchor = &self.anchors[y * d..(y + 1) * d];
            for (l, a) in latent.iter_mut().zip(anchor) {
                *l = *a + spec.sigma_intra * rng.normal();
            }
            let img = &mut images[i * pix..(i + 1) * pix];
            // img = tanh(P^T latent + pixel noise)
            for (j, out) in img.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for (k, l) in latent.iter().enumerate() {
                    acc += self.projection[k * pix + j] * l;
                }
                *out = (acc + spec.sigma_pixel * rng.normal()).tanh();
            }
        }
        Dataset {
            images,
            labels,
            n,
            image_size: spec.image_size,
            num_classes: spec.num_classes,
        }
    }
}

impl Dataset {
    pub fn pixels_per_image(&self) -> usize {
        self.image_size * self.image_size * 3
    }

    /// Borrow image i as a flat NHWC slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let p = self.pixels_per_image();
        &self.images[i * p..(i + 1) * p]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> Generator {
        Generator::new(SynthSpec::for_preset(10, 16, 42))
    }

    #[test]
    fn shapes_and_ranges() {
        let g = gen();
        let ds = g.sample(32, 10);
        assert_eq!(ds.n, 32);
        assert_eq!(ds.images.len(), 32 * 16 * 16 * 3);
        assert_eq!(ds.labels.len(), 32);
        assert!(ds.images.iter().all(|&x| (-1.0..=1.0).contains(&x)));
        assert!(ds.labels.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn deterministic_per_seed_and_split() {
        let g = gen();
        let a = g.sample(8, 10);
        let b = g.sample(8, 10);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let c = g.sample(8, 11);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn classes_are_separable_but_not_trivially() {
        // nearest-anchor-in-pixel-space classification should beat chance
        // but stay below perfect — the task is learnable and non-trivial.
        let g = gen();
        let train = g.sample(200, 10);
        let test = g.sample(100, 11);
        let pix = train.pixels_per_image();
        // class centroids from train
        let mut centroid = vec![0.0f64; 10 * pix];
        let mut count = [0usize; 10];
        for i in 0..train.n {
            let y = train.labels[i] as usize;
            count[y] += 1;
            for (c, x) in centroid[y * pix..(y + 1) * pix]
                .iter_mut()
                .zip(train.image(i))
            {
                *c += *x as f64;
            }
        }
        for y in 0..10 {
            if count[y] > 0 {
                for c in &mut centroid[y * pix..(y + 1) * pix] {
                    *c /= count[y] as f64;
                }
            }
        }
        let mut correct = 0;
        for i in 0..test.n {
            let img = test.image(i);
            let mut best = (f64::INFINITY, 0usize);
            for y in 0..10 {
                let d: f64 = centroid[y * pix..(y + 1) * pix]
                    .iter()
                    .zip(img)
                    .map(|(c, x)| (c - *x as f64) * (c - *x as f64))
                    .sum();
                if d < best.0 {
                    best = (d, y);
                }
            }
            if best.1 == test.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.n as f64;
        assert!(acc > 0.3, "task too hard: centroid acc {acc}");
        assert!(acc < 0.999, "task trivial: centroid acc {acc}");
    }

    #[test]
    fn split_tail_head_is_prefix_and_tail_is_suffix() {
        let g = gen();
        let full = g.sample(10, 10);
        let pix = full.pixels_per_image();
        let (head, tail) = full.clone().split_tail(3).unwrap();
        assert_eq!(head.n, 7);
        assert_eq!(tail.n, 3);
        assert_eq!(head.images, full.images[..7 * pix]);
        assert_eq!(head.labels, full.labels[..7]);
        assert_eq!(tail.images, full.images[7 * pix..]);
        assert_eq!(tail.labels, full.labels[7..]);
        assert_eq!(tail.image_size, full.image_size);
        assert_eq!(tail.num_classes, full.num_classes);
        // degenerate splits error instead of silently emptying a side
        assert!(full.clone().split_tail(0).is_err());
        assert!(full.clone().split_tail(10).is_err());
    }

    #[test]
    fn label_distribution_roughly_uniform() {
        let g = gen();
        let ds = g.sample(1000, 10);
        let mut counts = [0usize; 10];
        for &y in &ds.labels {
            counts[y as usize] += 1;
        }
        for c in counts {
            assert!(c > 50, "class starved: {counts:?}");
        }
    }
}
