//! Pluggable dataset sources: where the train/test `Dataset`s of one
//! experiment come from. The synthetic generator (the environment's
//! CIFAR/ImageNet substitute, see DESIGN.md) and the on-disk CIFAR binary
//! loader implement one trait, selected by the `data` config knob — the
//! training loops never know which one fed them.

use std::path::PathBuf;

use super::cifar::{self, CifarVariant, Split};
use super::synth::{Dataset, Generator, SynthSpec};
use crate::util::Result;

/// A source that can materialize the train and test datasets of one
/// experiment. `load` is called once, when the lab is built.
pub trait DataSource: Send + Sync {
    fn name(&self) -> &'static str;
    /// (train, test)
    fn load(&self) -> Result<(Dataset, Dataset)>;

    /// (train, test, val): like [`DataSource::load`], plus an optional
    /// held-out validation split of `val_examples` examples for
    /// validation-gated averaging policies. `val_examples == 0` means no
    /// split (val is `None`) and must leave train/test bitwise identical
    /// to `load`. The default carves the validation examples off the tail
    /// of the train split (shrinking it); sources that can mint fresh
    /// examples should override and keep train untouched instead.
    fn load_with_val(&self, val_examples: usize) -> Result<(Dataset, Dataset, Option<Dataset>)> {
        let (train, test) = self.load()?;
        if val_examples == 0 {
            return Ok((train, test, None));
        }
        let (train, val) = train.split_tail(val_examples)?;
        Ok((train, test, Some(val)))
    }
}

/// The synthetic generator (default): train/test sampled from the same
/// frozen generative model on disjoint RNG streams.
pub struct SynthSource {
    pub num_classes: usize,
    pub image_size: usize,
    pub seed: u64,
    pub n_train: usize,
    pub n_test: usize,
}

impl DataSource for SynthSource {
    fn name(&self) -> &'static str {
        "synth"
    }

    fn load(&self) -> Result<(Dataset, Dataset)> {
        let gen = Generator::new(SynthSpec::for_preset(
            self.num_classes,
            self.image_size,
            self.seed,
        ));
        Ok((gen.sample(self.n_train, 10), gen.sample(self.n_test, 11)))
    }

    /// The generator mints the validation split from its own disjoint RNG
    /// stream (split 12), so train keeps all n_train examples and stays
    /// bitwise identical to a run without validation — enabling the
    /// adaptive policy never perturbs the training trajectory.
    fn load_with_val(&self, val_examples: usize) -> Result<(Dataset, Dataset, Option<Dataset>)> {
        let (train, test) = self.load()?;
        if val_examples == 0 {
            return Ok((train, test, None));
        }
        let gen = Generator::new(SynthSpec::for_preset(
            self.num_classes,
            self.image_size,
            self.seed,
        ));
        Ok((train, test, Some(gen.sample(val_examples, 12))))
    }
}

/// On-disk CIFAR-10/100 binary directory, truncated to the configured
/// n_train/n_test (erroring if the directory holds fewer examples).
pub struct CifarSource {
    variant: CifarVariant,
    dir: PathBuf,
    n_train: usize,
    n_test: usize,
}

impl CifarSource {
    pub fn new(
        variant: CifarVariant,
        dir: impl Into<PathBuf>,
        n_train: usize,
        n_test: usize,
    ) -> Self {
        CifarSource { variant, dir: dir.into(), n_train, n_test }
    }
}

impl DataSource for CifarSource {
    fn name(&self) -> &'static str {
        self.variant.name()
    }

    fn load(&self) -> Result<(Dataset, Dataset)> {
        // only the requested prefix is decoded and retained — a full 50k
        // CIFAR download serving a small n_train costs neither the decode
        // nor the resident memory of the rest
        Ok((
            cifar::load_prefix(self.variant, &self.dir, Split::Train, self.n_train, "n_train")?,
            cifar::load_prefix(self.variant, &self.dir, Split::Test, self.n_test, "n_test")?,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_source_matches_direct_generation() {
        // the source must reproduce the pre-refactor Lab construction
        // bitwise (train split 10, test split 11)
        let src = SynthSource {
            num_classes: 10,
            image_size: 16,
            seed: 42,
            n_train: 24,
            n_test: 8,
        };
        let (train, test) = src.load().unwrap();
        let gen = Generator::new(SynthSpec::for_preset(10, 16, 42));
        let want_train = gen.sample(24, 10);
        let want_test = gen.sample(8, 11);
        assert_eq!(train.images, want_train.images);
        assert_eq!(train.labels, want_train.labels);
        assert_eq!(test.images, want_test.images);
        assert_eq!(test.labels, want_test.labels);
        assert_eq!(src.name(), "synth");
    }

    #[test]
    fn synth_val_split_leaves_train_untouched() {
        let src = SynthSource {
            num_classes: 10,
            image_size: 16,
            seed: 42,
            n_train: 24,
            n_test: 8,
        };
        let (plain_train, plain_test) = src.load().unwrap();
        let (train, test, val) = src.load_with_val(6).unwrap();
        // enabling validation must not move a single training pixel
        assert_eq!(train.images, plain_train.images);
        assert_eq!(train.labels, plain_train.labels);
        assert_eq!(test.images, plain_test.images);
        let val = val.unwrap();
        assert_eq!(val.n, 6);
        let gen = Generator::new(SynthSpec::for_preset(10, 16, 42));
        let want = gen.sample(6, 12);
        assert_eq!(val.images, want.images);
        assert_eq!(val.labels, want.labels);
        // val_examples == 0 → no split at all
        let (_, _, none) = src.load_with_val(0).unwrap();
        assert!(none.is_none());
    }

    #[test]
    fn cifar_source_missing_dir_errors() {
        let src = CifarSource::new(CifarVariant::Cifar10, "/nonexistent/cifar", 8, 2);
        assert!(src.load().is_err());
        assert_eq!(src.name(), "cifar10");
    }
}
