//! Training-time data augmentation, mirroring the paper's CIFAR pipeline:
//! random horizontal flip, random shift (pad-and-crop), and cutout
//! (DeVries & Taylor 2017 — explicitly used by the paper, §5.1).
//!
//! All ops work in-place on a single NHWC image slice (H*W*3 f32).

use crate::util::Rng;

/// Augmentation policy (per-preset config).
#[derive(Debug, Clone, Copy)]
pub struct AugmentSpec {
    pub flip: bool,
    /// max |shift| in pixels for pad-and-crop (0 disables)
    pub shift: usize,
    /// cutout square side (0 disables)
    pub cutout: usize,
}

impl AugmentSpec {
    pub fn cifar_default() -> Self {
        AugmentSpec { flip: true, shift: 2, cutout: 4 }
    }

    pub fn none() -> Self {
        AugmentSpec { flip: false, shift: 0, cutout: 0 }
    }
}

/// Apply the policy to one image in place.
pub fn augment(img: &mut [f32], hw: usize, spec: &AugmentSpec, rng: &mut Rng) {
    debug_assert_eq!(img.len(), hw * hw * 3);
    if spec.flip && rng.coin(0.5) {
        hflip(img, hw);
    }
    if spec.shift > 0 {
        let dy = rng.below(2 * spec.shift + 1) as isize - spec.shift as isize;
        let dx = rng.below(2 * spec.shift + 1) as isize - spec.shift as isize;
        if dy != 0 || dx != 0 {
            shift(img, hw, dy, dx);
        }
    }
    if spec.cutout > 0 {
        // cutout centre may be anywhere (standard implementation clips the
        // square at the borders)
        let cy = rng.below(hw);
        let cx = rng.below(hw);
        cutout(img, hw, cy, cx, spec.cutout);
    }
}

/// Mirror horizontally.
pub fn hflip(img: &mut [f32], hw: usize) {
    for y in 0..hw {
        for x in 0..hw / 2 {
            let xr = hw - 1 - x;
            for c in 0..3 {
                img.swap((y * hw + x) * 3 + c, (y * hw + xr) * 3 + c);
            }
        }
    }
}

/// Translate by (dy, dx), zero-filling exposed pixels (pad-and-crop).
pub fn shift(img: &mut [f32], hw: usize, dy: isize, dx: isize) {
    let src = img.to_vec();
    img.iter_mut().for_each(|p| *p = 0.0);
    for y in 0..hw as isize {
        let sy = y - dy;
        if !(0..hw as isize).contains(&sy) {
            continue;
        }
        for x in 0..hw as isize {
            let sx = x - dx;
            if !(0..hw as isize).contains(&sx) {
                continue;
            }
            let d = ((y as usize) * hw + x as usize) * 3;
            let s = ((sy as usize) * hw + sx as usize) * 3;
            img[d..d + 3].copy_from_slice(&src[s..s + 3]);
        }
    }
}

/// Zero a (side x side) square centred at (cy, cx), clipped at borders.
pub fn cutout(img: &mut [f32], hw: usize, cy: usize, cx: usize, side: usize) {
    let half = side / 2;
    let y0 = cy.saturating_sub(half);
    let x0 = cx.saturating_sub(half);
    let y1 = (cy + half + side % 2).min(hw);
    let x1 = (cx + half + side % 2).min(hw);
    for y in y0..y1 {
        for x in x0..x1 {
            let d = (y * hw + x) * 3;
            img[d..d + 3].iter_mut().for_each(|p| *p = 0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(hw: usize) -> Vec<f32> {
        (0..hw * hw * 3).map(|i| i as f32).collect()
    }

    #[test]
    fn hflip_is_involution() {
        let hw = 5;
        let orig = ramp(hw);
        let mut img = orig.clone();
        hflip(&mut img, hw);
        assert_ne!(img, orig);
        hflip(&mut img, hw);
        assert_eq!(img, orig);
    }

    #[test]
    fn hflip_moves_first_to_last_column() {
        let hw = 3;
        let mut img = ramp(hw);
        let first = img[0];
        hflip(&mut img, hw);
        assert_eq!(img[(hw - 1) * 3], first);
    }

    #[test]
    fn shift_zero_fills() {
        let hw = 4;
        let mut img = vec![1.0; hw * hw * 3];
        shift(&mut img, hw, 1, 0); // down by one: first row zero
        assert!(img[..hw * 3].iter().all(|&p| p == 0.0));
        assert!(img[hw * 3..].iter().all(|&p| p == 1.0));
    }

    #[test]
    fn shift_roundtrip_loses_border_only() {
        let hw = 6;
        let orig = ramp(hw);
        let mut img = orig.clone();
        shift(&mut img, hw, 1, 1);
        shift(&mut img, hw, -1, -1);
        // interior pixels identical
        for y in 0..hw - 1 {
            for x in 0..hw - 1 {
                let d = (y * hw + x) * 3;
                assert_eq!(img[d], orig[d], "pixel {y},{x}");
            }
        }
    }

    #[test]
    fn cutout_zeroes_square_only() {
        let hw = 8;
        let mut img = vec![1.0; hw * hw * 3];
        cutout(&mut img, hw, 4, 4, 2);
        let zeros = img.iter().filter(|&&p| p == 0.0).count();
        assert_eq!(zeros, 2 * 2 * 3);
        assert_eq!(img[(4 * hw + 4) * 3], 0.0);
    }

    #[test]
    fn cutout_clips_at_border() {
        let hw = 8;
        let mut img = vec![1.0; hw * hw * 3];
        cutout(&mut img, hw, 0, 0, 4);
        let zeros = img.iter().filter(|&&p| p == 0.0).count();
        assert_eq!(zeros, 2 * 2 * 3); // half the square fell off the edge
    }

    #[test]
    fn augment_none_is_identity() {
        let hw = 4;
        let orig = ramp(hw);
        let mut img = orig.clone();
        let mut rng = crate::util::Rng::new(0);
        augment(&mut img, hw, &AugmentSpec::none(), &mut rng);
        assert_eq!(img, orig);
    }

    #[test]
    fn augment_deterministic_per_seed() {
        let hw = 8;
        let spec = AugmentSpec::cifar_default();
        let mut a = ramp(hw);
        let mut b = ramp(hw);
        augment(&mut a, hw, &spec, &mut crate::util::Rng::new(5));
        augment(&mut b, hw, &spec, &mut crate::util::Rng::new(5));
        assert_eq!(a, b);
    }
}
