//! Training-time data augmentation, mirroring the paper's CIFAR pipeline:
//! random horizontal flip, random shift (pad-and-crop), and cutout
//! (DeVries & Taylor 2017 — explicitly used by the paper, §5.1).
//!
//! All ops work in-place on a single NHWC image slice (H*W*3 f32).
//!
//! Randomness is **counter-based and stateless**: example `row` of step
//! `step` in stream `(seed, stream)` is augmented with
//! `Rng::counter(seed, stream, step, row)` — a pure function of the key,
//! never a draw from a shared sequential stream. Augmenting an example
//! therefore does not depend on which examples were augmented before it,
//! so batch assembly is order-free: any thread may assemble any shard in
//! any interleaving and produce bitwise-identical batches (the property
//! the prefetching input pipeline is built on).

use crate::util::Rng;

/// Augmentation policy (per-preset config).
#[derive(Debug, Clone, Copy)]
pub struct AugmentSpec {
    pub flip: bool,
    /// max |shift| in pixels for pad-and-crop (0 disables)
    pub shift: usize,
    /// cutout square side (0 disables)
    pub cutout: usize,
}

impl AugmentSpec {
    pub fn cifar_default() -> Self {
        AugmentSpec { flip: true, shift: 2, cutout: 4 }
    }

    pub fn none() -> Self {
        AugmentSpec { flip: false, shift: 0, cutout: 0 }
    }

    /// True when the policy cannot change any pixel (no RNG is consulted).
    pub fn is_noop(&self) -> bool {
        !self.flip && self.shift == 0 && self.cutout == 0
    }
}

/// Identity of one augmentation stream: which `(seed, stream)` family a
/// batch belongs to (worker / phase identity). The per-example generator
/// is derived on demand from `(step, row)` — see the module docs.
#[derive(Debug, Clone, Copy)]
pub struct AugStream {
    pub seed: u64,
    pub stream: u64,
}

impl AugStream {
    /// The pure per-example generator for global row `row` of step `step`.
    pub fn rng(&self, step: u64, row: u64) -> Rng {
        Rng::counter(self.seed, self.stream, step, row)
    }
}

/// Apply the policy to one image in place, keyed by `(key, step, row)`.
/// `scratch` is a reusable buffer for [`shift`] (no per-example
/// allocation once it has grown to one image).
pub fn augment_at(
    img: &mut [f32],
    hw: usize,
    spec: &AugmentSpec,
    scratch: &mut Vec<f32>,
    key: AugStream,
    step: u64,
    row: u64,
) {
    if spec.is_noop() {
        return;
    }
    let mut rng = key.rng(step, row);
    augment_with(img, hw, spec, scratch, &mut rng);
}

/// Apply the policy with an explicit generator. The draw order (flip,
/// shift dy, shift dx, cutout cy, cutout cx) is part of the determinism
/// contract — changing it changes every augmented pixel stream.
pub fn augment_with(
    img: &mut [f32],
    hw: usize,
    spec: &AugmentSpec,
    scratch: &mut Vec<f32>,
    rng: &mut Rng,
) {
    debug_assert_eq!(img.len(), hw * hw * 3);
    if spec.flip && rng.coin(0.5) {
        hflip(img, hw);
    }
    if spec.shift > 0 {
        let dy = rng.below(2 * spec.shift + 1) as isize - spec.shift as isize;
        let dx = rng.below(2 * spec.shift + 1) as isize - spec.shift as isize;
        if dy != 0 || dx != 0 {
            shift(img, hw, dy, dx, scratch);
        }
    }
    if spec.cutout > 0 {
        // cutout centre may be anywhere (standard implementation clips the
        // square at the borders)
        let cy = rng.below(hw);
        let cx = rng.below(hw);
        cutout(img, hw, cy, cx, spec.cutout);
    }
}

/// Mirror horizontally.
pub fn hflip(img: &mut [f32], hw: usize) {
    for y in 0..hw {
        for x in 0..hw / 2 {
            let xr = hw - 1 - x;
            for c in 0..3 {
                img.swap((y * hw + x) * 3 + c, (y * hw + xr) * 3 + c);
            }
        }
    }
}

/// Translate by (dy, dx), zero-filling exposed pixels (pad-and-crop).
/// `scratch` holds the source copy; its capacity is reused across calls,
/// so the steady-state hot loop performs no allocation.
pub fn shift(img: &mut [f32], hw: usize, dy: isize, dx: isize, scratch: &mut Vec<f32>) {
    scratch.clear();
    scratch.extend_from_slice(img);
    img.iter_mut().for_each(|p| *p = 0.0);
    for y in 0..hw as isize {
        let sy = y - dy;
        if !(0..hw as isize).contains(&sy) {
            continue;
        }
        for x in 0..hw as isize {
            let sx = x - dx;
            if !(0..hw as isize).contains(&sx) {
                continue;
            }
            let d = ((y as usize) * hw + x as usize) * 3;
            let s = ((sy as usize) * hw + sx as usize) * 3;
            img[d..d + 3].copy_from_slice(&scratch[s..s + 3]);
        }
    }
}

/// Zero a (side x side) square centred at (cy, cx), clipped at borders.
pub fn cutout(img: &mut [f32], hw: usize, cy: usize, cx: usize, side: usize) {
    let half = side / 2;
    let y0 = cy.saturating_sub(half);
    let x0 = cx.saturating_sub(half);
    let y1 = (cy + half + side % 2).min(hw);
    let x1 = (cx + half + side % 2).min(hw);
    for y in y0..y1 {
        for x in x0..x1 {
            let d = (y * hw + x) * 3;
            img[d..d + 3].iter_mut().for_each(|p| *p = 0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(hw: usize) -> Vec<f32> {
        (0..hw * hw * 3).map(|i| i as f32).collect()
    }

    #[test]
    fn hflip_is_involution() {
        let hw = 5;
        let orig = ramp(hw);
        let mut img = orig.clone();
        hflip(&mut img, hw);
        assert_ne!(img, orig);
        hflip(&mut img, hw);
        assert_eq!(img, orig);
    }

    #[test]
    fn hflip_moves_first_to_last_column() {
        let hw = 3;
        let mut img = ramp(hw);
        let first = img[0];
        hflip(&mut img, hw);
        assert_eq!(img[(hw - 1) * 3], first);
    }

    #[test]
    fn shift_zero_fills() {
        let hw = 4;
        let mut img = vec![1.0; hw * hw * 3];
        let mut scratch = Vec::new();
        shift(&mut img, hw, 1, 0, &mut scratch); // down by one: first row zero
        assert!(img[..hw * 3].iter().all(|&p| p == 0.0));
        assert!(img[hw * 3..].iter().all(|&p| p == 1.0));
    }

    #[test]
    fn shift_roundtrip_loses_border_only() {
        let hw = 6;
        let orig = ramp(hw);
        let mut img = orig.clone();
        let mut scratch = Vec::new();
        shift(&mut img, hw, 1, 1, &mut scratch);
        shift(&mut img, hw, -1, -1, &mut scratch);
        // interior pixels identical
        for y in 0..hw - 1 {
            for x in 0..hw - 1 {
                let d = (y * hw + x) * 3;
                assert_eq!(img[d], orig[d], "pixel {y},{x}");
            }
        }
    }

    #[test]
    fn shift_reuses_scratch_capacity() {
        let hw = 8;
        let mut img = vec![1.0; hw * hw * 3];
        let mut scratch = Vec::new();
        shift(&mut img, hw, 1, 0, &mut scratch);
        let cap = scratch.capacity();
        let ptr = scratch.as_ptr();
        for d in 1..4isize {
            shift(&mut img, hw, d % 3 - 1, -d % 2, &mut scratch);
        }
        assert_eq!(scratch.capacity(), cap, "scratch must not regrow");
        assert_eq!(scratch.as_ptr(), ptr, "scratch must not reallocate");
    }

    #[test]
    fn cutout_zeroes_square_only() {
        let hw = 8;
        let mut img = vec![1.0; hw * hw * 3];
        cutout(&mut img, hw, 4, 4, 2);
        let zeros = img.iter().filter(|&&p| p == 0.0).count();
        assert_eq!(zeros, 2 * 2 * 3);
        assert_eq!(img[(4 * hw + 4) * 3], 0.0);
    }

    #[test]
    fn cutout_clips_at_border() {
        let hw = 8;
        let mut img = vec![1.0; hw * hw * 3];
        cutout(&mut img, hw, 0, 0, 4);
        let zeros = img.iter().filter(|&&p| p == 0.0).count();
        assert_eq!(zeros, 2 * 2 * 3); // half the square fell off the edge
    }

    #[test]
    fn augment_none_is_identity() {
        let hw = 4;
        let orig = ramp(hw);
        let mut img = orig.clone();
        let mut scratch = Vec::new();
        let key = AugStream { seed: 0, stream: 0 };
        augment_at(&mut img, hw, &AugmentSpec::none(), &mut scratch, key, 0, 0);
        assert_eq!(img, orig);
        assert!(scratch.is_empty(), "noop must not touch the scratch");
    }

    #[test]
    fn augment_at_is_pure_per_key() {
        // the same (key, step, row) always produces the same pixels, no
        // matter what was augmented before — the order-free contract
        let hw = 8;
        let spec = AugmentSpec::cifar_default();
        let key = AugStream { seed: 5, stream: 2 };
        let mut scratch = Vec::new();
        let mut a = ramp(hw);
        augment_at(&mut a, hw, &spec, &mut scratch, key, 3, 7);
        // interleave unrelated work on the same scratch
        let mut other = ramp(hw);
        augment_at(&mut other, hw, &spec, &mut scratch, key, 9, 1);
        let mut b = ramp(hw);
        augment_at(&mut b, hw, &spec, &mut scratch, key, 3, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn augment_at_varies_with_step_and_row() {
        let hw = 8;
        let spec = AugmentSpec::cifar_default();
        let key = AugStream { seed: 5, stream: 2 };
        let mut scratch = Vec::new();
        let base = {
            let mut img = ramp(hw);
            augment_at(&mut img, hw, &spec, &mut scratch, key, 0, 0);
            img
        };
        // over many (step, row) coordinates, at least one must differ from
        // the base draw (overwhelmingly likely for all of them)
        let varies = (1..16u64).any(|k| {
            let mut img = ramp(hw);
            augment_at(&mut img, hw, &spec, &mut scratch, key, k, k);
            img != base
        });
        assert!(varies, "counter coordinates must change the augmentation");
    }
}
