//! On-disk CIFAR-10 / CIFAR-100 loader for the standard binary record
//! format (`data_batch_*.bin` / `train.bin`): each record is
//! `label_bytes` label bytes followed by one 32x32x3 image stored
//! channel-planar (1024-byte R plane, then G, then B; row-major within a
//! plane). CIFAR-100 records carry two label bytes (coarse, fine); the
//! fine label is used. Pixels are mapped to f32 in [-1, 1] and transposed
//! to the NHWC layout the runtime expects.
//!
//! Validation is hardened the same way `checkpoint::load` is: every
//! length and label is checked against the actual bytes BEFORE the pixel
//! buffer is allocated, so a truncated, mis-sized, or hostile file errors
//! cleanly instead of producing garbage tensors or over-allocating.

use std::path::{Path, PathBuf};

use super::synth::Dataset;
use crate::util::{Error, Result};

/// CIFAR images are always 32x32 RGB.
pub const CIFAR_HW: usize = 32;
const PLANE: usize = CIFAR_HW * CIFAR_HW;
const REC_PIXELS: usize = 3 * PLANE;

/// Which binary flavor a directory holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CifarVariant {
    Cifar10,
    Cifar100,
}

impl CifarVariant {
    pub fn name(self) -> &'static str {
        match self {
            CifarVariant::Cifar10 => "cifar10",
            CifarVariant::Cifar100 => "cifar100",
        }
    }

    /// The single `data`-knob-name -> variant resolver (config validation
    /// and source construction must never drift apart).
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "cifar10" => Some(CifarVariant::Cifar10),
            "cifar100" => Some(CifarVariant::Cifar100),
            _ => None,
        }
    }

    pub fn num_classes(self) -> usize {
        match self {
            CifarVariant::Cifar10 => 10,
            CifarVariant::Cifar100 => 100,
        }
    }

    /// Label bytes per record; the *last* one is the (fine) label.
    pub fn label_bytes(self) -> usize {
        match self {
            CifarVariant::Cifar10 => 1,
            CifarVariant::Cifar100 => 2,
        }
    }

    pub fn record_bytes(self) -> usize {
        self.label_bytes() + REC_PIXELS
    }

    /// The training files present in `dir` (standard names), in order.
    /// CIFAR-10 accepts a contiguous `data_batch_1..k` prefix (small
    /// fixtures) but a GAP — a later batch present while an earlier one
    /// is missing — is a broken download and errors loudly rather than
    /// silently training on a reshuffled subset.
    fn train_files(self, dir: &Path) -> Result<Vec<PathBuf>> {
        let mut files: Vec<PathBuf> = Vec::new();
        match self {
            CifarVariant::Cifar10 => {
                let mut first_missing = None;
                for i in 1..=5usize {
                    let p = dir.join(format!("data_batch_{i}.bin"));
                    if !p.exists() {
                        first_missing.get_or_insert(i);
                    } else if let Some(j) = first_missing {
                        return Err(Error::invalid(format!(
                            "cifar10 training files in {} have a gap: \
                             data_batch_{j}.bin is missing but data_batch_{i}.bin exists",
                            dir.display()
                        )));
                    } else {
                        files.push(p);
                    }
                }
            }
            CifarVariant::Cifar100 => {
                let p = dir.join("train.bin");
                if p.exists() {
                    files.push(p);
                }
            }
        }
        if files.is_empty() {
            return Err(Error::invalid(format!(
                "no {} training files in {} (expected {})",
                self.name(),
                dir.display(),
                match self {
                    CifarVariant::Cifar10 => "data_batch_1.bin ...",
                    CifarVariant::Cifar100 => "train.bin",
                }
            )));
        }
        Ok(files)
    }

    fn test_file(self, dir: &Path) -> PathBuf {
        match self {
            CifarVariant::Cifar10 => dir.join("test_batch.bin"),
            CifarVariant::Cifar100 => dir.join("test.bin"),
        }
    }
}

/// Train or test half of a directory.
#[derive(Debug, Clone, Copy)]
pub enum Split {
    Train,
    Test,
}

/// Decode up to `limit` of one file's records, appending to the caller's
/// buffers (so a multi-file split accumulates into ONE reserved
/// allocation). The file's length and EVERY label — including records
/// past `limit` — are validated before the f32 pixel buffer grows, so a
/// hostile tail cannot hide behind a small n_train.
pub fn parse_records_into(
    bytes: &[u8],
    variant: CifarVariant,
    what: &str,
    limit: usize,
    images: &mut Vec<f32>,
    labels: &mut Vec<i32>,
) -> Result<()> {
    let rec = variant.record_bytes();
    if bytes.is_empty() {
        return Err(Error::invalid(format!("{what}: empty {} file", variant.name())));
    }
    if bytes.len() % rec != 0 {
        return Err(Error::invalid(format!(
            "{what}: {} bytes is not a whole number of {rec}-byte records \
             (truncated, or not the {} binary format)",
            bytes.len(),
            variant.name()
        )));
    }
    let count = bytes.len() / rec;
    let lb = variant.label_bytes();
    for r in 0..count {
        let label = bytes[r * rec + lb - 1] as usize;
        if label >= variant.num_classes() {
            return Err(Error::invalid(format!(
                "{what}: record {r} has label {label}, out of range for {} \
                 ({} classes)",
                variant.name(),
                variant.num_classes()
            )));
        }
    }
    let decode = count.min(limit);
    let base = images.len();
    images.resize(base + decode * REC_PIXELS, 0.0);
    labels.reserve(decode);
    for r in 0..decode {
        let src = &bytes[r * rec..(r + 1) * rec];
        labels.push(src[lb - 1] as i32);
        let pix = &src[lb..];
        let dst = &mut images[base + r * REC_PIXELS..base + (r + 1) * REC_PIXELS];
        // channel-planar -> interleaved NHWC, bytes -> [-1, 1]
        for c in 0..3 {
            for (p, &v) in pix[c * PLANE..(c + 1) * PLANE].iter().enumerate() {
                dst[p * 3 + c] = v as f32 / 127.5 - 1.0;
            }
        }
    }
    Ok(())
}

/// Decode one file's records into fresh buffers (tests / one-off probes).
pub fn parse_records(
    bytes: &[u8],
    variant: CifarVariant,
    what: &str,
) -> Result<(Vec<f32>, Vec<i32>)> {
    let mut images = Vec::new();
    let mut labels = Vec::new();
    parse_records_into(bytes, variant, what, usize::MAX, &mut images, &mut labels)?;
    Ok((images, labels))
}

fn split_files(variant: CifarVariant, dir: &Path, split: Split) -> Result<Vec<PathBuf>> {
    match split {
        Split::Train => variant.train_files(dir),
        Split::Test => {
            let f = variant.test_file(dir);
            if !f.exists() {
                return Err(Error::invalid(format!(
                    "missing {} test file {}",
                    variant.name(),
                    f.display()
                )));
            }
            Ok(vec![f])
        }
    }
}

/// Load one whole split of a CIFAR directory as a `Dataset`.
pub fn load_split(variant: CifarVariant, dir: &Path, split: Split) -> Result<Dataset> {
    load_prefix(variant, dir, split, usize::MAX, "load_split")
}

/// Load the first `want` examples of a split (the config's
/// n_train/n_test), erroring when the split holds fewer — asking for
/// more data than exists must fail loudly, not train silently on a short
/// epoch. Only the requested prefix is decoded and retained: a 50k-record
/// directory serving n_train=1024 neither converts nor keeps the rest
/// (`usize::MAX` = the whole split).
pub fn load_prefix(
    variant: CifarVariant,
    dir: &Path,
    split: Split,
    want: usize,
    what: &str,
) -> Result<Dataset> {
    let files = split_files(variant, dir, split)?;
    // availability check from the on-disk sizes: every file's length must
    // be whole records (re-validated against the actual bytes when read)
    let rec = variant.record_bytes();
    let mut total = 0usize;
    for f in &files {
        let len = std::fs::metadata(f)?.len() as usize;
        if len == 0 || len % rec != 0 {
            return Err(Error::invalid(format!(
                "{}: {len} bytes is not a whole number of {rec}-byte records \
                 (truncated, or not the {} binary format)",
                f.display(),
                variant.name()
            )));
        }
        total += len / rec;
    }
    let want = if want == usize::MAX { total } else { want };
    if want == 0 || want > total {
        return Err(Error::invalid(format!(
            "{what} = {want}, but the on-disk split holds {total} examples"
        )));
    }
    let mut images: Vec<f32> = Vec::with_capacity(want * REC_PIXELS);
    let mut labels: Vec<i32> = Vec::with_capacity(want);
    for f in &files {
        if labels.len() == want {
            break;
        }
        let bytes = std::fs::read(f)?;
        let need = want - labels.len();
        let what = f.display().to_string();
        parse_records_into(&bytes, variant, &what, need, &mut images, &mut labels)?;
    }
    if labels.len() != want {
        // a file shrank between the size scan and the read (concurrent
        // re-download): fail loudly, never train on a short epoch
        return Err(Error::invalid(format!(
            "{what} = {want}, but only {} examples could be read",
            labels.len()
        )));
    }
    Ok(Dataset {
        n: want,
        images,
        labels,
        image_size: CIFAR_HW,
        num_classes: variant.num_classes(),
    })
}

/// One record of the deterministic fixture pattern shared by the loader
/// tests, the `data_pipeline` bench, and
/// `python/tools/gen_cifar_fixture.py`: label = `i % classes`, plane byte
/// `(c, p)` = `(i*7 + c*31 + p*13) % 256`. Test support, not loader API.
#[doc(hidden)]
pub fn fixture_record(variant: CifarVariant, i: usize) -> Vec<u8> {
    let mut b = Vec::with_capacity(variant.record_bytes());
    if variant.label_bytes() == 2 {
        b.push(0); // coarse label (ignored by the loader)
    }
    b.push((i % variant.num_classes()) as u8);
    for c in 0..3 {
        for p in 0..PLANE {
            b.push(((i * 7 + c * 31 + p * 13) % 256) as u8);
        }
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One well-formed record with the shared deterministic pattern.
    fn record(variant: CifarVariant, i: usize) -> Vec<u8> {
        fixture_record(variant, i)
    }

    #[test]
    fn parse_roundtrips_labels_and_layout() {
        for variant in [CifarVariant::Cifar10, CifarVariant::Cifar100] {
            let mut bytes = Vec::new();
            for i in 0..3 {
                bytes.extend_from_slice(&record(variant, i));
            }
            let (images, labels) = parse_records(&bytes, variant, "t").unwrap();
            assert_eq!(labels, vec![0, 1, 2]);
            assert_eq!(images.len(), 3 * REC_PIXELS);
            // record 1, channel 2, plane offset 5 lands at NHWC index 5*3+2
            let want = ((7 + 2 * 31 + 5 * 13) % 256) as f32 / 127.5 - 1.0;
            assert_eq!(images[REC_PIXELS + 5 * 3 + 2], want);
            assert!(images.iter().all(|&x| (-1.0..=1.0).contains(&x)));
        }
    }

    #[test]
    fn truncated_and_empty_files_error() {
        let variant = CifarVariant::Cifar10;
        assert!(parse_records(&[], variant, "t").is_err());
        let mut bytes = record(variant, 0);
        bytes.pop(); // cut one byte mid-record
        let err = parse_records(&bytes, variant, "t").unwrap_err();
        assert!(err.to_string().contains("records"), "{err}");
    }

    #[test]
    fn out_of_range_label_errors_before_decoding() {
        let variant = CifarVariant::Cifar10;
        let mut bytes = record(variant, 0);
        bytes[0] = 10; // only 0..=9 are valid
        let err = parse_records(&bytes, variant, "t").unwrap_err();
        assert!(err.to_string().contains("label"), "{err}");
    }

    #[test]
    fn parse_limit_decodes_prefix_but_validates_all_labels() {
        let variant = CifarVariant::Cifar10;
        let mut bytes = Vec::new();
        for i in 0..4 {
            bytes.extend_from_slice(&record(variant, i));
        }
        let mut images = Vec::new();
        let mut labels = Vec::new();
        parse_records_into(&bytes, variant, "t", 2, &mut images, &mut labels).unwrap();
        assert_eq!(labels, vec![0, 1]);
        assert_eq!(images.len(), 2 * REC_PIXELS);
        // a hostile label BEYOND the decode limit must still be caught
        let rec = variant.record_bytes();
        bytes[3 * rec] = 99;
        let err = parse_records_into(&bytes, variant, "t", 2, &mut images, &mut labels)
            .unwrap_err();
        assert!(err.to_string().contains("label"), "{err}");
    }
}
